"""Figs. 10-11 reproduction: trace-based scaling simulation, 4..2048 workers.

Ring all-reduce (startup linear in N — Fig. 10) and double binary trees
(log N — Fig. 11), GoogleNet + ResNet-50 on the K80/10GbE constants.
Expected paper behaviours, all checked here:

  * WFBP and SyncEASGD speedup curves CROSS (ring, medium N);
  * MG-WFBP >= max(WFBP, SyncEASGD) everywhere;
  * 64-worker ring: MG-WFBP ~1.7x over WFBP / ~1.3x over SyncEASGD;
  * at >= 256 ring workers MG-WFBP converges to single-layer comms;
  * with double binary trees WFBP-family stays ahead of SyncEASGD.

The whole study routes through ``repro.sim.sweep.run_sweep``: each
(algorithm, model, strategy) triple is ONE sweep over the full
N=4..2048 grid — a single jitted device call on the fleet backend
(``repro.sim.fleet``), the portable numpy closed forms otherwise — and
per-N speedups are derived from the sweep's ``t_iter`` via the paper's
Eqs. 4-5.  The event-driven twin — same clusters through the
``repro.sim`` engine, plus the scenarios the closed form cannot express
— is ``benchmarks/cluster_sim.py``, which also asserts the two paths
agree; the fleet-vs-numpy wall-clock gap is enforced by
``benchmarks/fleet_bench.py``.
"""

from __future__ import annotations

from benchmarks.paper_profiles import tensor_profile
from repro.sim.fleet import fleet_available
from repro.sim.scenarios import PAPER_ALPHA, PAPER_BETA, PAPER_GAMMA
from repro.sim.sweep import SweepGrid, run_sweep

# the paper's full §7 range: 4 .. 2048 workers
SCALING_NS = tuple(2 ** p for p in range(2, 12))


def run() -> list[tuple[str, float, str]]:
    backend = "fleet" if fleet_available() else "numpy"
    grid = SweepGrid(n_workers=SCALING_NS)
    rows = []
    for alg in ("ring", "double_binary_trees"):
        for mname in ("googlenet", "resnet50"):
            specs, t_f = tensor_profile(mname)
            denom = t_f + sum(s.t_b for s in specs)   # t_f + t_b (Eq. 4)
            res = {}
            for strat in ("wfbp", "single", "mgwfbp"):
                r = run_sweep(specs, t_f, grid, algorithm=alg,
                              strategy=strat, alpha=PAPER_ALPHA,
                              beta=PAPER_BETA, gamma=PAPER_GAMMA,
                              backend=backend)
                assert r.backend == backend, (r.backend, backend)
                assert not r.used_engine.any()
                res[strat] = r
            cross = mg_at_64 = None
            prev_rel = None
            converged_256 = None
            for ni, n in enumerate(SCALING_NS):
                s = {}
                for strat, r in res.items():
                    t_c_no = float(r.t_iter[ni, 0, 0, 0]) - denom
                    s[strat] = n / (1.0 + t_c_no / denom)   # Eqs. 4-5
                rel = s["wfbp"] - s["single"]
                if prev_rel is not None and rel * prev_rel < 0 and \
                        cross is None:
                    cross = n
                prev_rel = rel
                if n == 64:
                    mg_at_64 = (s["mgwfbp"] / s["wfbp"],
                                s["mgwfbp"] / s["single"])
                if n == 256:
                    converged_256 = \
                        res["mgwfbp"].plans[(256, 1.0)].num_buckets
                assert s["mgwfbp"] >= max(s["wfbp"], s["single"]) - 1e-9, \
                    (alg, mname, n)
                rows.append((f"scaling.{alg}.{mname}.N{n}.mgwfbp_eff",
                             s["mgwfbp"] / n,
                             f"wfbp={s['wfbp']/n:.2f} "
                             f"single={s['single']/n:.2f} scaling-eff"))
            if alg == "ring":
                rows.append((f"scaling.{alg}.{mname}.crossover_N",
                             cross or -1,
                             "WFBP/SyncEASGD curves cross (paper Fig. 10)"))
                rows.append((f"scaling.{alg}.{mname}.mg_speedup64_vs_wfbp",
                             mg_at_64[0],
                             f"vs_single={mg_at_64[1]:.2f} (paper: ~1.7/1.3)"))
                rows.append((f"scaling.{alg}.{mname}.buckets_at_256",
                             converged_256,
                             "->1 = converged to SyncEASGD (paper §6.4)"))
    return rows
