"""Figs. 10-11 reproduction: trace-based scaling simulation, 4..2048 workers.

Ring all-reduce (startup linear in N — Fig. 10) and double binary trees
(log N — Fig. 11), GoogleNet + ResNet-50 on the K80/10GbE constants.
Expected paper behaviours, all checked here:

  * WFBP and SyncEASGD speedup curves CROSS (ring, medium N);
  * MG-WFBP >= max(WFBP, SyncEASGD) everywhere;
  * 64-worker ring: MG-WFBP ~1.7x over WFBP / ~1.3x over SyncEASGD;
  * at >= 256 ring workers MG-WFBP converges to single-layer comms;
  * with double binary trees WFBP-family stays ahead of SyncEASGD.

This suite is the closed-form FAST PATH over the shared scenario-catalog
constants (``repro.sim.scenarios.PAPER_ALPHA/BETA/GAMMA``); the
event-driven twin — same clusters through the ``repro.sim`` engine, plus
the scenarios the closed form cannot express — is
``benchmarks/cluster_sim.py``, which also asserts the two paths agree.
"""

from __future__ import annotations

from benchmarks.paper_profiles import tensor_profile
from repro.core.planner import make_plan
from repro.core.simulator import simulate, speedup
from repro.sim.network import FlatTopology
from repro.sim.scenarios import PAPER_ALPHA, PAPER_BETA, PAPER_GAMMA


def run() -> list[tuple[str, float, str]]:
    rows = []
    for alg in ("ring", "double_binary_trees"):
        for mname in ("googlenet", "resnet50"):
            specs, t_f = tensor_profile(mname)
            cross = mg_at_64 = None
            prev_rel = None
            converged_256 = None
            for p in range(2, 12):
                n = 2 ** p
                model = FlatTopology(alg, n, PAPER_ALPHA, PAPER_BETA,
                                     PAPER_GAMMA).linear_model()
                s = {}
                for strat in ("wfbp", "single", "mgwfbp"):
                    plan = make_plan(strat, specs, model)
                    s[strat] = speedup(specs, plan, model, t_f, n)
                rel = s["wfbp"] - s["single"]
                if prev_rel is not None and rel * prev_rel < 0 and \
                        cross is None:
                    cross = n
                prev_rel = rel
                if n == 64:
                    mg_at_64 = (s["mgwfbp"] / s["wfbp"],
                                s["mgwfbp"] / s["single"])
                if n == 256:
                    plan = make_plan("mgwfbp", specs, model)
                    converged_256 = plan.num_buckets
                assert s["mgwfbp"] >= max(s["wfbp"], s["single"]) - 1e-9, \
                    (alg, mname, n)
                rows.append((f"scaling.{alg}.{mname}.N{n}.mgwfbp_eff",
                             s["mgwfbp"] / n,
                             f"wfbp={s['wfbp']/n:.2f} "
                             f"single={s['single']/n:.2f} scaling-eff"))
            if alg == "ring":
                rows.append((f"scaling.{alg}.{mname}.crossover_N",
                             cross or -1,
                             "WFBP/SyncEASGD curves cross (paper Fig. 10)"))
                rows.append((f"scaling.{alg}.{mname}.mg_speedup64_vs_wfbp",
                             mg_at_64[0],
                             f"vs_single={mg_at_64[1]:.2f} (paper: ~1.7/1.3)"))
                rows.append((f"scaling.{alg}.{mname}.buckets_at_256",
                             converged_256,
                             "->1 = converged to SyncEASGD (paper §6.4)"))
    return rows
