"""Figs. 6-9 reproduction: non-overlapped communication cost per strategy.

For each paper CNN × cluster, simulate WFBP / SyncEASGD / MG-WFBP /
DP-optimal and report computation time, non-overlapped communication
(t_c^no) and the improvement of MG-WFBP over the best baseline — the
paper's headline table.  Expected (paper §6.3): MG-WFBP always >= both
baselines, 1.2-1.36x on K80/10GbE, up to ~1.7x in the scaled settings.
"""

from __future__ import annotations

from benchmarks.paper_profiles import (K80_FLOPS, PAPER_MODELS, V100_FLOPS,
                                       tensor_profile)
from repro.core import cost_model as cm
from repro.core.simulator import compare_strategies

CLUSTERS = {
    "k80_10gbe": ("cluster1_k80_10gbe", K80_FLOPS),
    "v100_10gbe": ("cluster2_v100_10gbe", V100_FLOPS),
    "v100_ib": ("cluster3_v100_ib", V100_FLOPS),
}


def run() -> list[tuple[str, float, str]]:
    rows = []
    violations = 0
    for cname, (ckey, flops) in CLUSTERS.items():
        a, b = cm.PAPER_CLUSTERS[ckey]
        model = cm.AllReduceModel(a, b)
        for mname in PAPER_MODELS:
            specs, t_f = tensor_profile(mname, device_flops=flops)
            res = compare_strategies(specs, model, t_f)
            best_base = min(res["wfbp"].t_iter, res["single"].t_iter)
            speedup = best_base / res["mgwfbp"].t_iter
            if res["mgwfbp"].t_iter > best_base + 1e-12:
                violations += 1
            rows.append((
                f"nonoverlap.{cname}.{mname}.mgwfbp_iter_ms",
                res["mgwfbp"].t_iter * 1e3,
                f"wfbp={res['wfbp'].t_iter*1e3:.1f}ms "
                f"single={res['single'].t_iter*1e3:.1f}ms "
                f"tc_no={res['mgwfbp'].t_c_no*1e3:.2f}ms "
                f"speedup_vs_best={speedup:.3f}x"))
    rows.append(("nonoverlap.mgwfbp_never_slower_violations", violations,
                 "paper claim: must be 0"))
    return rows
