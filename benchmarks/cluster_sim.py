"""Event-driven cluster-simulation suite (beyond Figs. 10-11).

Reproduces the paper's §7 *structural* scaling results through the new
``repro.sim`` engine instead of the closed form — WFBP and SyncEASGD
speedup curves cross under ring, MG-WFBP dominates both everywhere — then
runs the scenarios only an event engine can express:

  * straggler sweep        (sync-SGD step time is a max over workers)
  * straggler eviction     (StragglerMonitor -> evict -> replan in-loop)
  * elastic resize         (online (a, b) refit -> replan mid-run)
  * bursty background      (processor-sharing link contention)
  * two-job contention     (independent jobs time-sharing one network)
  * contention-aware fixpoint (plan -> simulate -> refit -> replan; must
    beat both WFBP and the exclusive-link MG-WFBP plan under contention)
  * batched sweep          (vectorized closed form vs the engine, point by
    point, plus the wall-time ratio between the two paths)
  * schedule crossover     (the paper cluster under BSP vs pipelined
    all-reduce vs 1F1B vs local SGD: merged-gradient bucketing must help
    strictly LESS under PipelinedAllReduce and LocalSGD than under BSP —
    the DeAR-style structural result; the grids run through the
    schedule-aware batched sweep, cross-validated against the engine)
  * multi-job co-planning  (repro.core.coplanner: jointly replanned 2-job
    and mixed-schedule 3-job fleets must beat the one-sided PR-2 fixpoint
    and independently-planned MG-WFBP on joint makespan — its own suite,
    archived as BENCH_coplanner.json)
  * fault injection        (repro.sim.faults + repro.train.resilience: one
    seeded FaultPlan against the resilience controller and the naive
    restore-everything baseline; goodput/MTTR/replay bars plus the
    determinism bar — its own suite, archived as BENCH_faults.json via
    ``--faults``)

Every scenario's timeline round-trips through Chrome-trace JSON
(``repro.sim.trace``), which is also asserted here.  ``python
benchmarks/cluster_sim.py --schedules`` runs just the schedule rows and
``--coplan`` just the co-planning rows (the CI smoke steps).
"""

from __future__ import annotations

import json
import os
import tempfile
import time

from benchmarks.paper_profiles import tensor_profile
from repro.core.planner import make_plan, plan_wfbp
from repro.core.simulator import simulate
from repro.sim import scenarios, trace
from repro.sim.engine import ClusterSim, JobSpec
from repro.sim.network import FlatTopology
from repro.sim.schedules import BSP, LocalSGD, OneFoneB, PipelinedAllReduce
from repro.sim.sweep import SweepGrid, run_sweep
from repro.sim.workers import make_workers

EPS = 1e-9


def _speedup(n: int, t_iter: float, t_f: float, t_b: float) -> float:
    """Paper Eqs. 4-5 on an engine-measured iteration time."""
    t_c_no = max(t_iter - (t_f + t_b), 0.0)
    return n / (1.0 + t_c_no / (t_f + t_b))


def _engine_t_iter(sim) -> float:
    job = next(iter(sim.run().jobs.values()))
    return job.iterations[-1].t_iter


def _scaling_rows(rows: list) -> None:
    for alg in ("ring", "double_binary_trees"):
        for mname in ("googlenet", "resnet50"):
            specs, t_f = tensor_profile(mname)
            t_b = sum(s.t_b for s in specs)
            cross = None
            prev_rel = None
            max_dev = 0.0
            for p in range(2, 12):
                n = 2 ** p
                model = FlatTopology(alg, n, scenarios.PAPER_ALPHA,
                                     scenarios.PAPER_BETA,
                                     scenarios.PAPER_GAMMA).linear_model()
                s = {}
                for strat in ("wfbp", "single", "mgwfbp"):
                    plan = make_plan(strat, specs, model)
                    sim = scenarios.paper_scaling(
                        specs, t_f, n, algorithm=alg, strategy=strat,
                        plan=plan)
                    t_iter = _engine_t_iter(sim)
                    s[strat] = _speedup(n, t_iter, t_f, t_b)
                    # engine vs closed form on the shared domain
                    ref = simulate(specs, plan, model, t_f).t_iter
                    max_dev = max(max_dev, abs(ref - t_iter))
                rel = s["wfbp"] - s["single"]
                if prev_rel is not None and rel * prev_rel < 0 and \
                        cross is None:
                    cross = n
                prev_rel = rel
                assert s["mgwfbp"] >= max(s["wfbp"], s["single"]) - EPS, \
                    (alg, mname, n, s)
                rows.append((f"cluster_sim.scaling.{alg}.{mname}.N{n}",
                             s["mgwfbp"] / n,
                             f"wfbp={s['wfbp']/n:.2f} "
                             f"single={s['single']/n:.2f} engine-eff"))
            assert max_dev < 1e-9, (alg, mname, max_dev)
            if alg == "ring":
                assert cross is not None, \
                    f"{mname}: WFBP/SyncEASGD ring curves never crossed"
                rows.append((f"cluster_sim.scaling.ring.{mname}.crossover_N",
                             cross, "curves cross (paper Fig. 10, engine)"))
            rows.append((f"cluster_sim.scaling.{alg}.{mname}.engine_vs_cf",
                         max_dev, "max |engine - closed form| seconds"))


def _straggler_rows(rows: list) -> None:
    specs, t_f = tensor_profile("googlenet")
    n = 16
    prev = None
    for factor in (1.0, 1.25, 1.5, 2.0, 3.0):
        sim = scenarios.straggler(specs, t_f, n, slow_factor=factor)
        t_iter = _engine_t_iter(sim)
        if prev is not None:
            assert t_iter >= prev - EPS, (factor, t_iter, prev)
        rows.append((f"cluster_sim.straggler.x{factor:g}", t_iter * 1e3,
                     "ms/iter, 1 slow worker of 16 (sync-SGD max)"))
        if factor == 1.0:
            base = t_iter
        prev = t_iter
    rows.append(("cluster_sim.straggler.stretch_at_3x", prev / base,
                 "t_iter(3x straggler)/t_iter(homogeneous)"))


def _elastic_rows(rows: list) -> None:
    specs, t_f = tensor_profile("googlenet")
    n_before, n_after = 8, 32
    sim, report = scenarios.elastic_resize(
        specs, t_f, n_before=n_before, n_after=n_after, resize_at=1,
        iters=4)
    res = sim.run()
    job = res.job("train")
    t_before = job.iterations[0].t_iter
    t_after = job.iterations[-1].t_iter
    assert report.plan_after is not None, "resize hook never fired"

    # ideal: a fresh run planned directly for the post-resize cluster
    ideal = _engine_t_iter(scenarios.paper_scaling(specs, t_f, n_after))
    if not report.used_fallback:
        # exact-fit world: online refit must recover the true model and
        # land the run on the from-scratch plan
        assert abs(t_after - ideal) < 1e-9, (t_after, ideal)
        true_model = FlatTopology(
            "ring", n_before, scenarios.PAPER_ALPHA, scenarios.PAPER_BETA,
            scenarios.PAPER_GAMMA).linear_model()
        rows.append(("cluster_sim.elastic.refit_a_rel_err",
                     abs(report.fitted.a - true_model.a) /
                     max(true_model.a, 1e-30),
                     f"fitted a={report.fitted.a:.3e} vs true"))
    rows.append(("cluster_sim.elastic.t_iter_before_ms", t_before * 1e3,
                 f"N={n_before} buckets={report.plan_before.num_buckets}"))
    rows.append(("cluster_sim.elastic.t_iter_after_ms", t_after * 1e3,
                 f"N={n_after} buckets="
                 f"{report.plan_after.num_buckets} (refit+replanned)"))
    rows.append(("cluster_sim.elastic.vs_fresh_plan", t_after / ideal,
                 "1.0 = online replan matches from-scratch plan"))

    # chrome trace round-trip on this scenario's full timeline
    fd, path = tempfile.mkstemp(suffix=".json")
    try:
        os.close(fd)
        trace.write_chrome_trace(path, res.spans)
        back = trace.read_chrome_trace(path)
        with open(path) as f:
            n_events = len(json.load(f)["traceEvents"])
        assert back == res.spans, "chrome trace did not round-trip"
        assert n_events == len(res.spans) > 0
    finally:
        os.unlink(path)
    rows.append(("cluster_sim.elastic.trace_events", len(res.spans),
                 "spans round-tripped through chrome-trace JSON"))


def _contention_rows(rows: list) -> None:
    specs, t_f = tensor_profile("googlenet")
    # bursty background traffic
    quiet = _engine_t_iter(scenarios.paper_scaling(specs, t_f, 16, iters=4))
    noisy_sim = scenarios.bursty(specs, t_f, 16, burst_flows=3,
                                 horizon_iters=4)
    noisy = _engine_t_iter(noisy_sim)
    assert noisy >= quiet - EPS
    rows.append(("cluster_sim.bursty.stretch", noisy / quiet,
                 "t_iter under 3-flow bursts / quiet network"))

    # two jobs sharing the link
    specs_b, t_f_b = tensor_profile("resnet50")
    alone_a = _engine_t_iter(scenarios.paper_scaling(specs, t_f, 8, iters=2))
    alone_b = _engine_t_iter(scenarios.paper_scaling(specs_b, t_f_b, 8,
                                                     iters=2))
    shared = scenarios.two_jobs(specs, t_f, specs_b, t_f_b,
                                n_workers=8, iters=2).run()
    both_a = shared.job("job_a").iterations[-1].t_iter
    both_b = shared.job("job_b").iterations[-1].t_iter
    assert both_a >= alone_a - EPS and both_b >= alone_b - EPS
    rows.append(("cluster_sim.two_jobs.stretch_a", both_a / alone_a,
                 "googlenet t_iter shared/alone (link contention)"))
    rows.append(("cluster_sim.two_jobs.stretch_b", both_b / alone_b,
                 "resnet50 t_iter shared/alone (link contention)"))


def _eviction_rows(rows: list) -> None:
    specs, t_f = tensor_profile("googlenet")
    sim, report = scenarios.straggler_eviction(specs, t_f, 16,
                                               slow_factor=3.0, iters=6)
    job = sim.run().job("train")
    assert report.evictions, "monitor never evicted the straggler"
    evict_at = report.evictions[0][0]
    before = job.iterations[evict_at].t_iter
    after = job.iterations[-1].t_iter
    assert after < before / 1.5, (before, after)
    rows.append(("cluster_sim.eviction.iter", evict_at,
                 f"evicted {','.join(report.evicted_workers)} "
                 f"(EWMA > 1.5x median after warmup)"))
    rows.append(("cluster_sim.eviction.recovery", before / after,
                 "t_iter(with 3x straggler)/t_iter(after eviction+replan)"))


def _fixpoint_rows(rows: list) -> None:
    """The contention-aware planning loop on the two-job scenario."""
    specs, t_f = tensor_profile("resnet50")
    n, iters = 32, 2
    model = FlatTopology("ring", n, scenarios.PAPER_ALPHA,
                         scenarios.PAPER_BETA,
                         scenarios.PAPER_GAMMA).linear_model()
    plan_b = make_plan("mgwfbp", specs, model)

    def measure(plan_a):
        sim = scenarios.two_jobs(specs, t_f, specs, t_f, n_workers=n,
                                 iters=iters, plan_a=plan_a, plan_b=plan_b)
        job = sim.run().job("job_a")
        return sum(job.t_iters) / len(job.t_iters)

    fix = scenarios.contended_two_jobs_plan(specs, t_f, specs, t_f,
                                            n_workers=n, iters=iters,
                                            damping=0.3)
    t_wfbp = measure(plan_wfbp(specs))
    t_excl = measure(plan_b)            # exclusive-link MG-WFBP plan
    assert fix.converged and len(fix.rounds) <= 6, \
        (fix.converged, len(fix.rounds))
    # the acceptance bar: the fixpoint plan beats BOTH static baselines
    assert fix.observed_t < t_wfbp - EPS, (fix.observed_t, t_wfbp)
    assert fix.observed_t < t_excl - EPS, (fix.observed_t, t_excl)
    rows.append(("cluster_sim.fixpoint.t_iter_ms", fix.observed_t * 1e3,
                 f"contention-aware plan, 2x resnet50 N={n} "
                 f"({len(fix.rounds)} rounds, converged)"))
    rows.append(("cluster_sim.fixpoint.vs_wfbp", t_wfbp / fix.observed_t,
                 f"wfbp={t_wfbp*1e3:.1f}ms / fixpoint (>1 = fixpoint wins)"))
    rows.append(("cluster_sim.fixpoint.vs_exclusive_mgwfbp",
                 t_excl / fix.observed_t,
                 f"exclusive mgwfbp={t_excl*1e3:.1f}ms / fixpoint"))
    best = fix.rounds[fix.best_round]
    rows.append(("cluster_sim.fixpoint.predicted_vs_observed",
                 best.predicted_t / best.observed_t,
                 "closed form under refit (a,b) vs engine (contended)"))

    # cross-validation on the engine's exactly-predictable domain: with no
    # contention the observed samples are exact draws from a + b*M, the
    # refit recovers the model, and the loop converges immediately with
    # closed-form == engine to 1e-9.
    def evaluate_alone(plan):
        job = JobSpec(name="j", specs=list(specs), plan=plan, t_f=t_f,
                      workers=make_workers(n),
                      topology=FlatTopology("ring", n,
                                            scenarios.PAPER_ALPHA,
                                            scenarios.PAPER_BETA,
                                            scenarios.PAPER_GAMMA),
                      compute_mode="analytic")
        jr = ClusterSim([job]).run().job("j")
        return jr.iterations[-1].t_iter, jr.bucket_samples

    from repro.core.planner import plan_contention_aware
    alone = plan_contention_aware(specs, model, evaluate_alone, t_f=t_f)
    assert alone.converged and len(alone.rounds) <= 2, len(alone.rounds)
    dev = abs(alone.rounds[-1].predicted_t - alone.rounds[-1].observed_t)
    assert dev < 1e-9, dev
    rows.append(("cluster_sim.fixpoint.uncontended_dev_s", dev,
                 "|closed form - engine| with no contention (exact)"))


def _sweep_rows(rows: list) -> None:
    """Batched closed-form sweep == engine, point for point, but faster."""
    specs, t_f = tensor_profile("googlenet")
    grid = SweepGrid(n_workers=(4, 16, 64, 256, 1024, 2048),
                     bandwidth_scales=(0.5, 1.0, 2.0), seeds=(0, 1, 2))
    kw = dict(alpha=scenarios.PAPER_ALPHA, beta=scenarios.PAPER_BETA,
              gamma=scenarios.PAPER_GAMMA, iters=2, jitter_sigma=0.15)
    t0 = time.perf_counter()
    fast = run_sweep(specs, t_f, grid, **kw)
    t_fast = time.perf_counter() - t0
    assert not fast.used_engine.any()
    assert fast.planner_scratch == 1, fast.planner_scratch
    t0 = time.perf_counter()
    slow = run_sweep(specs, t_f, grid, force_engine=True, **kw)
    t_slow = time.perf_counter() - t0
    assert slow.used_engine.all()
    dev = float(abs(fast.t_iter - slow.t_iter).max())
    assert dev < 1e-9, dev
    n_pts = fast.t_iter.size
    rows.append(("cluster_sim.sweep.points", n_pts,
                 f"grid {grid.shape} x {fast.iters} iters, "
                 f"planner scratch={fast.planner_scratch} "
                 f"incr={fast.planner_incremental}"))
    rows.append(("cluster_sim.sweep.max_dev_vs_engine", dev,
                 "max |batched closed form - engine| seconds"))
    rows.append(("cluster_sim.sweep.wall_speedup", t_slow / t_fast,
                 f"engine {t_slow*1e3:.0f}ms / batched {t_fast*1e3:.0f}ms"))


def _schedule_rows(rows: list) -> None:
    """Schedule-crossed paper cluster: per-schedule steady-state times and
    the bucketing-gain crossover (the acceptance bar: merged-gradient
    bucketing helps less under pipelined all-reduce than under BSP).

    The grids run through the schedule-aware batched sweep
    (``run_sweep(schedule=...)``): each schedule's closed form evaluates
    the whole (N,) grid without the engine, and one engine pass
    cross-validates every point to 1e-9 (plus the wall-time ratio row)."""
    specs, t_f = tensor_profile("resnet50")
    schedules = [BSP(), PipelinedAllReduce(0.5), OneFoneB(4), LocalSGD(4)]
    iters = 6
    ns = (16, 64)
    grid = SweepGrid(n_workers=ns)
    kw = dict(alpha=scenarios.PAPER_ALPHA, beta=scenarios.PAPER_BETA,
              gamma=scenarios.PAPER_GAMMA, iters=iters)
    spans = {}                          # (schedule label, strat) -> span[n]
    t_fast = t_slow = 0.0
    max_dev = 0.0
    for sched in schedules:
        for strat in ("wfbp", "mgwfbp"):
            t0 = time.perf_counter()
            fast = run_sweep(specs, t_f, grid, strategy=strat,
                             schedule=sched, **kw)
            t_fast += time.perf_counter() - t0
            assert not fast.used_engine.any(), (sched, strat)
            t0 = time.perf_counter()
            slow = run_sweep(specs, t_f, grid, strategy=strat,
                             schedule=sched, force_engine=True, **kw)
            t_slow += time.perf_counter() - t0
            assert slow.used_engine.all()
            max_dev = max(max_dev,
                          float(abs(fast.t_iter - slow.t_iter).max()),
                          float(abs(fast.span - slow.span).max()))
            spans[(sched.label, strat)] = fast.span[:, 0, 0]
    assert max_dev < 1e-9, max_dev
    for ni, n in enumerate(ns):
        gains = {}
        for sched in schedules:
            # pipeline-fill-inclusive average: comparable across barrier
            # and frontier schedules
            ts = {strat: spans[(sched.label, strat)][ni] / iters
                  for strat in ("wfbp", "mgwfbp")}
            gains[sched.label] = ts["wfbp"] / ts["mgwfbp"]
            rows.append((f"cluster_sim.schedules.{sched.label}.N{n}",
                         ts["mgwfbp"] * 1e3,
                         f"ms/iter mgwfbp (wfbp={ts['wfbp']*1e3:.1f}ms, "
                         f"gain={gains[sched.label]:.3f})"))
        g_bsp = gains["bsp"]
        for label in ("pipelined0.5", "localsgd4"):
            # the crossover: these schedules already hide/skip
            # communication, so merging buys strictly less than under BSP
            assert gains[label] < g_bsp - EPS, (n, label, gains, g_bsp)
        rows.append((f"cluster_sim.schedules.gain_ratio_pipelined.N{n}",
                     gains["pipelined0.5"] / g_bsp,
                     "bucketing gain vs BSP's (<1 = merging helps less)"))
        rows.append((f"cluster_sim.schedules.gain_ratio_localsgd.N{n}",
                     gains["localsgd4"] / g_bsp,
                     "bucketing gain vs BSP's (<1 = merging helps less)"))
    rows.append(("cluster_sim.schedules.sweep_max_dev_vs_engine", max_dev,
                 "max |schedule closed form - engine| seconds, all grids"))
    rows.append(("cluster_sim.schedules.sweep_wall_speedup",
                 t_slow / t_fast,
                 f"engine {t_slow*1e3:.0f}ms / batched {t_fast*1e3:.0f}ms"))


def _coplan_rows(rows: list) -> None:
    """Multi-job co-planning (repro.core.coplanner) on shared fabric.

    Two acceptance bars:

    * 2x resnet50 at N=32 (the PR-2 contention bench): the joint
      best-response makespan is <= the one-sided fixpoint's (job_a
      optimized against a frozen mgwfbp neighbour) and < the
      independently-planned MG-WFBP assignment's;
    * a mixed-schedule 3-job fleet (BSP + pipelined + local SGD): the
      co-planned assignment beats independently-planned MG-WFBP — the
      schedules shape the contention each job must plan around.
    """
    specs, t_f = tensor_profile("resnet50")
    n, iters = 32, 2

    def joint_makespan(jobs, plans, **kw):
        return scenarios.shared_link_jobs(jobs, n_workers=n, iters=iters,
                                          plans=plans, **kw).run().makespan

    # -- 2 jobs, same profile, BSP: joint vs one-sided vs independent ----
    jobs = [scenarios.CoJobSpec("job_a", tuple(specs), t_f),
            scenarios.CoJobSpec("job_b", tuple(specs), t_f)]
    joint = scenarios.contended_jobs_plan(jobs, n_workers=n, iters=iters,
                                          damping=0.3)
    # symmetric fleets may trade mirror assignments to the round budget
    # instead of reaching an exact fixed point; the guarantee is the
    # budget plus best-observed tracking, so assert those
    assert len(joint.rounds) <= 3 + 5 * len(jobs), len(joint.rounds)
    one_sided = scenarios.contended_two_jobs_plan(
        specs, t_f, specs, t_f, n_workers=n, iters=iters, damping=0.3)
    model = FlatTopology("ring", n, scenarios.PAPER_ALPHA,
                         scenarios.PAPER_BETA,
                         scenarios.PAPER_GAMMA).linear_model()
    plan_b = make_plan("mgwfbp", specs, model)
    m_one_sided = joint_makespan(
        jobs, {"job_a": one_sided.plan, "job_b": plan_b})
    m_indep = joint_makespan(jobs, {"job_a": plan_b, "job_b": plan_b})
    m_wfbp = joint_makespan(
        jobs, {j.name: plan_wfbp(specs) for j in jobs})
    # the acceptance bar: jointly replanning both jobs dominates the
    # one-sided loop (which in turn dominates the static baselines)
    assert joint.makespan <= m_one_sided + EPS, \
        (joint.makespan, m_one_sided)
    assert joint.makespan < m_indep - EPS, (joint.makespan, m_indep)
    assert joint.makespan < m_wfbp - EPS, (joint.makespan, m_wfbp)
    rows.append(("coplanner.two_jobs.makespan_ms", joint.makespan * 1e3,
                 f"co-planned joint makespan, 2x resnet50 N={n} "
                 f"({len(joint.rounds)} rounds, "
                 f"{'converged' if joint.converged else 'budget-stopped'})"))
    rows.append(("coplanner.two_jobs.vs_one_sided",
                 m_one_sided / joint.makespan,
                 f"one-sided fixpoint={m_one_sided*1e3:.1f}ms / co-planned "
                 f"(>=1 = co-planning wins)"))
    rows.append(("coplanner.two_jobs.vs_independent",
                 m_indep / joint.makespan,
                 f"independent mgwfbp={m_indep*1e3:.1f}ms / co-planned"))
    rows.append(("coplanner.two_jobs.vs_wfbp", m_wfbp / joint.makespan,
                 f"wfbp={m_wfbp*1e3:.1f}ms / co-planned"))

    # -- 3 jobs, mixed schedules: the cross-schedule co-plan -------------
    specs_g, t_f_g = tensor_profile("googlenet")
    mixed = [scenarios.CoJobSpec("bsp", tuple(specs), t_f),
             scenarios.CoJobSpec("pipelined", tuple(specs_g), t_f_g,
                                 schedule=PipelinedAllReduce(0.5)),
             scenarios.CoJobSpec("localsgd", tuple(specs_g), t_f_g,
                                 schedule=LocalSGD(2))]
    joint3 = scenarios.contended_jobs_plan(mixed, n_workers=n, iters=2,
                                           damping=0.3)
    m_indep3 = joint_makespan(
        mixed, {j.name: make_plan("mgwfbp",
                                  list(j.specs), model) for j in mixed})
    assert joint3.makespan < m_indep3 - EPS, (joint3.makespan, m_indep3)
    rows.append(("coplanner.mixed3.makespan_ms", joint3.makespan * 1e3,
                 f"co-planned joint makespan, bsp+pipelined+localsgd N={n} "
                 f"({len(joint3.rounds)} rounds)"))
    rows.append(("coplanner.mixed3.vs_independent",
                 m_indep3 / joint3.makespan,
                 f"independent mgwfbp={m_indep3*1e3:.1f}ms / co-planned "
                 f"(>1 = co-planning wins)"))
    # shared-effective-model mode: one contended model per link
    shared = scenarios.contended_jobs_plan(jobs, n_workers=n, iters=iters,
                                           damping=0.3, shared_model=True)
    assert shared.makespan <= m_indep + EPS
    rows.append(("coplanner.two_jobs.shared_model_makespan_ms",
                 shared.makespan * 1e3,
                 "per-link aggregate-occupancy fit "
                 f"({len(shared.rounds)} rounds)"))


def _hier_coplan_rows(rows: list) -> None:
    """Per-link path models on hierarchical fleets (the --hier-coplan
    grid): 2-4 jobs x {flat, hierarchical} topology x {per-job refit,
    shared per-link}.

    Each hierarchical job runs on its own ICI pods and every cross-pod
    leg shares ONE congested DCN uplink (~1.2 Gb/s-class, startup-heavy —
    the regime where the shard's contention stretch actually moves the
    optimum).  The acceptance ordering, asserted per point:

        shared per-link co-plan <= per-job flat refit <= independent
        MG-WFBP

    The first inequality is made structural by seeding the per-link run
    with the flat-refit assignment; the second by the co-planner's seed
    guarantee.  At the 4-job point the per-link decomposition must beat
    independent planning STRICTLY — the headline: flat effective models
    smear the private-ICI and shared-DCN stretch into one pair, while
    per-link refit pins the uncontended ICI legs and pools every job's
    DCN telemetry into one shared fit.
    """
    sg, t_f_g = tensor_profile("googlenet")
    sr, t_f_r = tensor_profile("resnet50")
    pods, chips = 2, 8
    hw = dict(dcn_bw=1.5e8, dcn_alpha=2e-3, ici_bw=2e9, ici_alpha=2e-5)
    kw = dict(pods=pods, chips_per_pod=chips, iters=2, max_rounds=4,
              damping=0.3)
    n = pods * chips
    flat_kw = dict(n_workers=n, iters=2, max_rounds=4, damping=0.3)
    for n_jobs in (2, 3, 4):
        jobs = []
        for i in range(n_jobs):
            s, t = (sg, t_f_g) if i % 2 == 0 else (sr, t_f_r)
            jobs.append(scenarios.CoJobSpec(f"job{i}", tuple(s), t))
        # flat single-link topology (the PR-4 regime), both refit modes
        flat_per_job = scenarios.contended_jobs_plan(jobs, **flat_kw)
        flat_shared = scenarios.contended_jobs_plan(jobs,
                                                    shared_model=True,
                                                    **flat_kw)
        rows.append((f"coplanner.hier.flatlink.J{n_jobs}.per_job_ms",
                     flat_per_job.makespan * 1e3,
                     f"flat link, per-job refit "
                     f"({len(flat_per_job.rounds)} rounds)"))
        rows.append((f"coplanner.hier.flatlink.J{n_jobs}.shared_ms",
                     flat_shared.makespan * 1e3,
                     f"flat link, pooled whole-link fit "
                     f"({len(flat_shared.rounds)} rounds)"))
        # hierarchical: private ICI pods + one shared DCN uplink
        hier_flat = scenarios.hierarchical_jobs_plan(jobs, per_link=False,
                                                     **kw, **hw)
        hier_shared = scenarios.hierarchical_jobs_plan(
            jobs, per_link=True, shared_model=True,
            extra_seed_plans=hier_flat.plans, **kw, **hw)
        # independent baseline: the scenario's own default planning
        # (every unpinned job plans with its exclusive-link strategy)
        m_indep = scenarios.hierarchical_shared_jobs(
            jobs, pods=pods, chips_per_pod=chips, iters=2,
            **hw).run().makespan
        # the acceptance ordering (structural via seeds, so == is legal)
        assert hier_shared.makespan <= hier_flat.makespan + EPS, \
            (n_jobs, hier_shared.makespan, hier_flat.makespan)
        assert hier_flat.makespan <= m_indep + EPS, \
            (n_jobs, hier_flat.makespan, m_indep)
        rows.append((f"coplanner.hier.J{n_jobs}.flat_refit_ms",
                     hier_flat.makespan * 1e3,
                     f"per-job flat effective (a,b) "
                     f"({len(hier_flat.rounds)} rounds)"))
        rows.append((f"coplanner.hier.J{n_jobs}.shared_per_link_ms",
                     hier_shared.makespan * 1e3,
                     f"shared per-link path refit "
                     f"({len(hier_shared.rounds)} rounds)"))
        rows.append((f"coplanner.hier.J{n_jobs}.vs_flat_refit",
                     hier_flat.makespan / hier_shared.makespan,
                     "flat refit / shared per-link (>=1 = per-link wins)"))
        rows.append((f"coplanner.hier.J{n_jobs}.vs_independent",
                     m_indep / hier_shared.makespan,
                     f"independent mgwfbp={m_indep*1e3:.1f}ms / "
                     f"shared per-link"))
        if n_jobs == 4:
            # the headline point: enough DCN claimants that the flat
            # smear is measurably wrong — per-link must win outright
            assert hier_shared.makespan < m_indep - EPS, \
                (hier_shared.makespan, m_indep)
            assert hier_shared.makespan < hier_flat.makespan - EPS, \
                (hier_shared.makespan, hier_flat.makespan)


def _obs_rows(rows: list) -> None:
    """Observability smoke (CI gate for the obs acceptance criteria):

    * flight recording stays off the hot path — an instrumented run of a
      sizable events-mode scenario finishes within 5% of the
      uninstrumented wall time (best-of-N to shed scheduler noise);
    * the recorded ring round-trips losslessly through JSONL;
    * the drift monitor stays silent on a calibrated model and fires +
      recovers on a mid-run bandwidth degradation.
    """
    from repro.obs.recorder import FlightRecorder, read_jsonl

    specs, t_f = trace.synthetic_specs(40, seed=13)

    def one_wall(rec):
        # enough iterations that one timed sample is tens of ms — a
        # single scheduler hiccup must not dominate the ratio
        sim = scenarios.paper_scaling(specs, t_f, 32, iters=48,
                                      compute_mode="events", seed=5)
        sim.recorder = rec
        t0 = time.perf_counter()
        sim.run()
        return time.perf_counter() - t0

    # interleave base/instrumented pairs so slow drift in machine load
    # (CI neighbors, turbo states) hits both sides of each pair equally,
    # then take the median per-pair ratio: a scheduler spike poisons one
    # pair, not the statistic (true recording cost is ~9us/iteration,
    # ~1% of this run — the budget polices regressions, not noise)
    ratios = []
    rec = None
    for _ in range(9):
        base = one_wall(None)
        r = FlightRecorder()
        ratios.append(one_wall(r) / base)
        rec = r
    ratio = sorted(ratios)[len(ratios) // 2]
    assert ratio <= 1.05, \
        f"instrumented run {ratio:.3f}x uninstrumented (budget 1.05x)"
    assert len(rec.iterations("train")) == 48
    rows.append(("cluster_sim.obs.overhead_ratio", ratio,
                 "instrumented / uninstrumented wall (budget <= 1.05)"))

    # lossless JSONL round-trip of the recorded ring
    fd, path = tempfile.mkstemp(suffix=".jsonl")
    try:
        os.close(fd)
        rec.write(path)
        back = read_jsonl(path)
        assert tuple(back) == rec.records, "flight-recorder JSONL drifted"
    finally:
        os.unlink(path)
    rows.append(("cluster_sim.obs.jsonl_records", len(rec.records),
                 "records round-tripped bit-for-bit through JSONL"))

    # drift monitor: silent when calibrated ...
    calm_sim, calm = scenarios.drift_monitored(specs, t_f, iters=6,
                                               degrade_at=None)
    calm_sim.run()
    assert not calm.alerts, f"false drift alerts: {calm.alerts}"
    rows.append(("cluster_sim.obs.calibrated_residual",
                 max(r for _, r in calm.residuals),
                 "max EWMA residual on a calibrated model (0 alerts)"))

    # ... alert -> refit -> replan -> recovered when the fabric degrades
    drift_rec = FlightRecorder()
    deg_sim, deg = scenarios.drift_monitored(specs, t_f, iters=8,
                                             degrade_at=2,
                                             degrade_factor=4.0,
                                             recorder=drift_rec)
    deg_sim.run()
    assert deg.alerts and deg.replans >= 1, "degradation never alerted"
    post = [r for i, r in deg.residuals if i > deg.alerts[-1].iteration]
    assert post and max(post) <= deg.monitor.threshold, \
        f"post-replan residuals not recovered: {post}"
    assert drift_rec.events("drift_alert"), "alert missing from recorder"
    rows.append(("cluster_sim.obs.drift_alert_iter",
                 deg.alerts[0].iteration,
                 f"{len(deg.alerts)} alert(s), {deg.replans} replan(s), "
                 f"post-replan residual {max(post):.2e}"))


def _fault_rows(rows: list) -> None:
    """Fault injection + resilience controller vs the naive baseline.

    One seeded FaultPlan (crash, preemption with notice, link flap, slow
    host, checkpoint failure) hits two otherwise identical runs; the
    acceptance bars: controller goodput strictly above the baseline's,
    every fault recovered within a bounded number of iterations, and the
    whole thing deterministic (same seed -> identical flight-recorder
    stream)."""
    from repro.obs.recorder import FlightRecorder
    from repro.sim import faults

    specs, t_f = trace.synthetic_specs(48, seed=7)
    t_iter_est = t_f + sum(s.t_b for s in specs)
    iters = 30
    plan = faults.FaultPlan(events=(
        faults.WorkerCrash(5.2 * t_iter_est, worker="w6"),
        faults.Preemption(11.5 * t_iter_est, worker="w3",
                          notice_s=3 * t_iter_est),
        faults.LinkDegradation(16.3 * t_iter_est, link="net", factor=0.4,
                               duration=4 * t_iter_est),
        faults.SlowHostOnset(20.1 * t_iter_est, worker="w1", factor=3.0),
        faults.CheckpointFailure(8.0 * t_iter_est, count=1),
    ), seed=7)

    def one(resilient, recorder=None):
        sim, rep = scenarios.faulty_long_run(
            specs, t_f, iters=iters, plan=plan, resilient=resilient,
            recorder=recorder)
        sim.run()
        return rep

    rec_a, rec_b = FlightRecorder(16384), FlightRecorder(16384)
    ctrl = one(True, rec_a)
    naive = one(False)
    again = one(True, rec_b)
    a, b = ctrl.availability, naive.availability
    assert a.goodput > b.goodput + EPS, (a.goodput, b.goodput)
    assert a.unrecovered == 0, a
    bound = max((i.steps_to_recover or 0)
                for i in ctrl.controller.incidents)
    assert bound <= 3, ctrl.controller.incidents
    assert rec_a.records == rec_b.records, "fault run not deterministic"
    rows.append(("cluster_sim.faults.controller_goodput", a.goodput,
                 f"useful steps/s ({a.useful_steps} useful, "
                 f"{a.wasted_steps} wasted)"))
    rows.append(("cluster_sim.faults.baseline_goodput", b.goodput,
                 f"naive restore-everything ({b.useful_steps} useful, "
                 f"{b.wasted_steps} wasted)"))
    rows.append(("cluster_sim.faults.goodput_gain", a.goodput / b.goodput,
                 "controller / naive (>1 = controller wins)"))
    rows.append(("cluster_sim.faults.mttr_p95_ms", a.mttr_p95 * 1e3,
                 f"{len(a.mttr)} incidents recovered, "
                 f"max {bound} iteration(s) to recover"))
    rows.append(("cluster_sim.faults.replayed_fraction_naive",
                 b.replayed_fraction,
                 f"controller replays {a.replayed_fraction:.3f}"))
    rows.append(("cluster_sim.faults.recorder_events",
                 len(rec_a.records),
                 "identical across two seeded runs (determinism)"))


def run() -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    _scaling_rows(rows)
    _straggler_rows(rows)
    _eviction_rows(rows)
    _elastic_rows(rows)
    _contention_rows(rows)
    _fixpoint_rows(rows)
    _sweep_rows(rows)
    _schedule_rows(rows)
    return rows


def run_schedules_smoke() -> list[tuple[str, float, str]]:
    """Just the per-schedule rows — the fast CI smoke step."""
    rows: list[tuple[str, float, str]] = []
    _schedule_rows(rows)
    return rows


def run_coplan() -> list[tuple[str, float, str]]:
    """The co-planning suite (its own BENCH_coplanner.json artifact)."""
    rows: list[tuple[str, float, str]] = []
    _coplan_rows(rows)
    _hier_coplan_rows(rows)
    return rows


def run_hier_coplan() -> list[tuple[str, float, str]]:
    """Just the per-link hierarchical grid — the fast CI smoke step."""
    rows: list[tuple[str, float, str]] = []
    _hier_coplan_rows(rows)
    return rows


def run_obs() -> list[tuple[str, float, str]]:
    """Just the observability rows — the CI obs smoke step."""
    rows: list[tuple[str, float, str]] = []
    _obs_rows(rows)
    return rows


def run_faults() -> list[tuple[str, float, str]]:
    """Just the fault-injection rows — the CI faults smoke step
    (BENCH_faults.json)."""
    rows: list[tuple[str, float, str]] = []
    _fault_rows(rows)
    return rows


if __name__ == "__main__":
    import sys

    if "--schedules" in sys.argv:
        rows = run_schedules_smoke()
    elif "--coplan" in sys.argv:
        rows = run_coplan()
    elif "--hier-coplan" in sys.argv:
        rows = run_hier_coplan()
    elif "--obs" in sys.argv:
        rows = run_obs()
    elif "--faults" in sys.argv:
        rows = run_faults()
    else:
        rows = run()
    print("name,us_per_call,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.3f},{derived}")
