"""Fig. 5 reproduction: tensor-size distributions vs the startup threshold.

For each paper CNN and each assigned LM architecture, report how many
gradient tensors are individually *latency-dominated* (transmission time <
startup time a, i.e. bytes < a/b) on the paper's K80/10GbE cluster and on
the TPU pod model — the structural fact that makes merging profitable.
"""

from __future__ import annotations

import jax

from benchmarks.paper_profiles import PAPER_MODELS, tensor_profile
from repro.core import cost_model as cm
from repro.core.bucketer import leaf_metadata
from repro.models import registry


def run() -> list[tuple[str, float, str]]:
    rows = []
    a, b = cm.PAPER_CLUSTERS["cluster1_k80_10gbe"]
    thresh = a / b
    for model in PAPER_MODELS:
        specs, _ = tensor_profile(model)
        small = sum(1 for s in specs if s.nbytes < thresh)
        rows.append((f"tensor_dist.{model}.n_tensors", len(specs),
                     f"{small} latency-dominated (<{thresh/1e6:.1f}MB) "
                     f"= {small/len(specs):.0%}"))
    tpu = cm.production_comm_model((16, 16), ("data", "model"))
    tpu_thresh = tpu.a / tpu.b if tpu.b else 0
    for arch in registry.list_archs():
        bundle = registry.get_arch(arch)
        shapes = jax.eval_shape(
            lambda bb=bundle: bb.model().init(jax.random.PRNGKey(0)))
        metas = leaf_metadata(shapes)
        small = sum(1 for m in metas if m.nbytes < tpu_thresh)
        rows.append((f"tensor_dist.{arch}.n_tensors", len(metas),
                     f"{small} latency-dominated on TPU pod "
                     f"(<{tpu_thresh/1e3:.0f}KB)"))
    return rows
