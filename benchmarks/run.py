"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).

  allreduce_model  — Fig. 4   (linear all-reduce model fit)
  tensor_dist      — Fig. 5   (tensor-size distributions)
  nonoverlap       — Figs 6-9 (t_c^no per strategy per cluster)
  scaling_sim      — Figs 10-11 (4..2048-worker closed-form fast path)
  cluster_sim      — §7 via the event engine + beyond-paper scenarios
                     (stragglers, elastic refit+replan, bursts, contention)
  planner_bench    — §4.2     (O(L^2) one-time planning cost)
  kernels_bench    — kernels  (structural tile/bandwidth notes)
  roofline         — EXPERIMENTS.md §Roofline terms from dry-run artifacts
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (allreduce_model, cluster_sim, kernels_bench,
                            nonoverlap, planner_bench, roofline,
                            scaling_sim, tensor_dist)
    suites = [
        ("allreduce_model", allreduce_model.run),
        ("tensor_dist", tensor_dist.run),
        ("nonoverlap", nonoverlap.run),
        ("scaling_sim", scaling_sim.run),
        ("cluster_sim", cluster_sim.run),
        ("planner_bench", planner_bench.run),
        ("kernels_bench", kernels_bench.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites:
        try:
            for row_name, value, derived in fn():
                print(f"{row_name},{value:.3f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
