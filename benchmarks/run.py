"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).

  allreduce_model  — Fig. 4   (linear all-reduce model fit)
  tensor_dist      — Fig. 5   (tensor-size distributions)
  nonoverlap       — Figs 6-9 (t_c^no per strategy per cluster)
  scaling_sim      — Figs 10-11 (4..2048-worker closed-form fast path)
  cluster_sim      — §7 via the event engine + beyond-paper scenarios
                     (stragglers, eviction, elastic refit+replan, bursts,
                     contention fixpoint, batched sweeps, and the
                     schedule crossover: per-schedule rows for BSP vs
                     pipelined all-reduce vs 1F1B vs local SGD, asserting
                     merged bucketing helps less off-BSP; CI also runs
                     `cluster_sim.py --schedules` as a fast smoke step)
  coplanner        — multi-job co-planning (repro.core.coplanner): joint
                     makespan of co-planned vs one-sided-fixpoint vs
                     independently-planned MG-WFBP vs WFBP on shared
                     fabric, incl. a mixed-schedule 3-job fleet (CI also
                     runs `cluster_sim.py --coplan` as a smoke step)
  obs              — observability smoke (repro.obs): instrumentation
                     overhead budget (<= 1.05x), flight-recorder JSONL
                     round-trip, drift monitor silent-when-calibrated /
                     alert-refit-replan-recover on degradation (CI also
                     runs `cluster_sim.py --obs` as a smoke step)
  faults           — fault injection + resilience controller: seeded
                     FaultPlan vs naive baseline, goodput/MTTR/replayed
                     fraction and the determinism bar (CI also runs
                     `cluster_sim.py --faults` as a smoke step)
  planner_bench    — §4.2 one-time O(L^2) cost + the incremental planner
                     fast path (>= 10x replan speedup enforced)
  fleet_bench      — jitted fleet backend (repro.sim.fleet): >= 10x
                     evaluation-stage speedup over the pure-Python
                     closed forms enforced on the N=4..2048 headline
                     grid, plus a 100-job co-planning round scored in
                     one device call (own CI step via ``--fleet``)
  whatif_bench     — batched planning + what-if serving: >= 10x
                     planning-stage speedup over per-point
                     plan_dp_optimal on a 256-case L=512 batch, a
                     100-job plan+score round faster than the PR-9
                     score-only path, and warm-snapshot query bursts
                     pinned to one plan + one evaluate kernel call via
                     the obs counters (own CI step via ``--whatif``)
  kernels_bench    — kernels  (structural tile/bandwidth notes)
  roofline         — EXPERIMENTS.md §Roofline terms from dry-run artifacts

Perf-trajectory tracking: the suites named in ``BENCH_JSON`` additionally
write machine-readable ``BENCH_<suite>.json`` files (wall time of the
whole suite plus every row) into the working directory, so CI can archive
them and perf regressions are diffable across PRs.  With
``--emit-metrics`` the run also dumps a snapshot of the metrics registry
(``repro.obs.metrics``) to ``BENCH_metrics.json`` — planner counters,
co-plan rounds, drift alerts, step-time histograms.
"""

from __future__ import annotations

import json
import sys
import time
import traceback

# suite name -> artifact path (cwd-relative); wall-time + simulated-time
# metrics for the perf-critical suites tracked across PRs.
BENCH_JSON = {
    "planner_bench": "BENCH_planner.json",
    "cluster_sim": "BENCH_cluster_sim.json",
    "coplanner": "BENCH_coplanner.json",
    "obs": "BENCH_obs.json",
    "faults": "BENCH_faults.json",
    "real_loop": "BENCH_real_loop.json",
    "fleet": "BENCH_fleet.json",
    "whatif": "BENCH_whatif.json",
}

# --emit-metrics artifact: a snapshot of the process-local metrics
# registry (planner counters, drift alerts, sim/step histograms) taken
# after all suites ran — the perf trajectory then includes *behavioral*
# counters, not just wall times.
METRICS_JSON = "BENCH_metrics.json"


def write_bench_json(name: str, wall_s: float,
                     rows: list[tuple[str, float, str]],
                     error: str | None = None) -> None:
    """One artifact per tracked suite — written on failure too (with the
    error recorded), so a failing CI run still archives what it measured."""
    path = BENCH_JSON[name]
    payload = {
        "suite": name,
        "wall_s": wall_s,
        "error": error,
        "rows": [{"name": n, "value": v, "derived": d} for n, v, d in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def main() -> None:
    from benchmarks import (allreduce_model, cluster_sim, fleet_bench,
                            kernels_bench, nonoverlap, planner_bench,
                            real_loop, roofline, scaling_sim, tensor_dist)
    suites = [
        ("allreduce_model", allreduce_model.run),
        ("tensor_dist", tensor_dist.run),
        ("nonoverlap", nonoverlap.run),
        ("scaling_sim", scaling_sim.run),
        ("cluster_sim", cluster_sim.run),
        ("coplanner", cluster_sim.run_coplan),
        ("obs", cluster_sim.run_obs),
        ("faults", cluster_sim.run_faults),
        ("planner_bench", planner_bench.run),
        ("kernels_bench", kernels_bench.run),
        ("roofline", roofline.run),
    ]
    if "--real-loop" in sys.argv:
        # the measured-cost closed loop needs a real (forced) 4-device
        # mesh and several jit compiles — its own CI step, not part of
        # the default sweep
        suites = [("real_loop", real_loop.run)]
    if "--fleet" in sys.argv:
        # the fleet-backend speedup gate: wall-clock sensitive, so it
        # runs alone (no jit-cache or CPU contention from other suites)
        suites = [("fleet", fleet_bench.run)]
    if "--whatif" in sys.argv:
        # batched planning + what-if serving gates: also wall-clock
        # sensitive, also its own CI step
        from benchmarks import whatif_bench
        suites = [("whatif", whatif_bench.run)]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites:
        t0 = time.perf_counter()
        try:
            rows = fn()
            wall = time.perf_counter() - t0
            for row_name, value, derived in rows:
                print(f"{row_name},{value:.3f},{derived}")
            if name in BENCH_JSON:
                write_bench_json(name, wall, rows)
        except Exception as e:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}")
            if name in BENCH_JSON:
                write_bench_json(name, time.perf_counter() - t0, [],
                                 error=f"{type(e).__name__}: {e}")
    if "--emit-metrics" in sys.argv:
        from repro.obs.metrics import REGISTRY
        with open(METRICS_JSON, "w") as f:
            json.dump(REGISTRY.snapshot().to_dict(), f, indent=1)
        print(f"metrics.snapshot,0,{METRICS_JSON} "
              f"({len(REGISTRY.names())} metrics)")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
