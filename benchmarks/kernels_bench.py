"""Kernel microbenchmarks (CPU): Pallas interpret-mode correctness-path
timing vs the pure-jnp oracle.  Wall times on CPU are NOT the TPU story —
the derived column reports the structural quantities that matter for the
target (VMEM tile footprint, HBM round-trips saved)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> list[tuple[str, float, str]]:
    rows = []
    b, s, hq, hkv, d = 1, 256, 4, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    t_ref = _time(lambda *a: fa_ref.attention_ref(*a), q, k, v)
    vmem = (128 * d + 2 * 128 * d + 128 * d) * 4 / 1024
    rows.append(("kernels.flash_attention.ref_us", t_ref * 1e6,
                 f"tile VMEM={vmem:.0f}KB/step blocks=128x128 "
                 f"(S^2 bytes never materialized)"))

    x = jax.random.normal(jax.random.PRNGKey(3), (4096, 1024))
    sc = jnp.ones((1024,))
    t_ref = _time(lambda *a: rn_ref.rmsnorm_ref(*a), x, sc)
    rows.append(("kernels.rmsnorm.ref_us", t_ref * 1e6,
                 "fused kernel saves 1 HBM round-trip of x"))
    return rows
