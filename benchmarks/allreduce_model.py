"""Fig. 4 reproduction: the linear all-reduce cost model T(M) = a + bM.

We synthesize noisy all-reduce measurements from the paper's fitted cluster
constants (Fig. 4 captions), re-fit by least squares, and report recovery
error — validating the fitting path the real system uses at startup
(core/cost_model.fit).  Also verifies the merge-gain identity (Eq. 11) on
the fitted models.
"""

from __future__ import annotations

import numpy as np

from repro.core import cost_model as cm


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    for cluster, (a, b) in cm.PAPER_CLUSTERS.items():
        sizes = np.logspace(3, 26, 60, base=2)
        noise = rng.normal(1.0, 0.03, sizes.shape)
        times = (a + b * sizes) * noise
        fit = cm.fit(sizes, times, cluster)
        err_a = abs(fit.a - a) / a
        err_b = abs(fit.b - b) / b
        gain = fit.merge_gain(1 << 20, 1 << 20)
        rows.append((f"allreduce_fit.{cluster}.a_us", fit.a * 1e6,
                     f"true={a*1e6:.0f}us err={err_a:.1%}"))
        rows.append((f"allreduce_fit.{cluster}.b_ns_per_B", fit.b * 1e9,
                     f"true={b*1e9:.2f} err={err_b:.1%}"))
        rows.append((f"allreduce_fit.{cluster}.merge_gain_us", gain * 1e6,
                     "== a (Eq. 11)"))
    return rows
