"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) artifact:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HBM_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / (links × link_bw)

HLO FLOPs and collective bytes come from the trip-count-corrected parser
(utils/hlo.py) and are *per-device* (the compiled module is one SPMD
partition).  Gradient reductions carry an fp32-wire CPU workaround
(comm.py), so all-reduce / reduce-scatter bytes are divided by 2 to reflect
the bf16 wire used on the TPU target.

The MEMORY term is ANALYTIC, not HLO-parsed: the CPU backend's fusion
boundaries bear no relation to the TPU pipeline's, so HLO operand-byte sums
overcount HBM traffic by ~2 orders of magnitude (kept in the artifact as a
diagnostic only).  The analytic model counts, per device per step:

  train   — 3 passes over local param bytes (read fwd, read bwd, optimizer
            rw incl. moments) + activation traffic c·tokens·d_model·layers
            (c = 12 fwd+bwd with block remat) + logits tokens·vocab·2·2B;
  prefill — 1 param pass + activations + KV-cache write;
  decode  — 1 param pass + full KV-cache read (+tiny writes): the classic
            decode bandwidth bound.

Hardware: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (v5e brief).
"""

from __future__ import annotations

import glob
import json
import os

from repro.core.cost_model import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                            "dryrun")
ICI_LINKS = 2          # links usable per collective step on a 2D-torus axis
ACT_FACTOR_TRAIN = 12.0  # activation HBM touches per token-dim, fwd+bwd
ACT_FACTOR_FWD = 4.0


def _analytic_memory_bytes(rec: dict) -> float:
    """Per-device HBM bytes per step (see module docstring)."""
    from repro.configs.base import SHAPES
    from repro.models import registry

    bundle = registry.get_arch(rec["arch"])
    cfg = bundle.cfg
    shape = SHAPES[rec["shape"]]
    devices = rec.get("devices", 256)
    mf = rec.get("model_flops", {})
    total_params = mf.get("total_params", 0)
    kind = rec["kind"]

    # local parameter bytes: TP/EP shard the params across the model axis
    # (and data for experts); ZeRO-3 additionally shards over data;
    # DP-replicated leaves live whole per chip.
    tp = 16 if bundle.parallel.tp_enabled else 1
    ep = 16 if bundle.parallel.ep_axis else 1
    param_local = total_params * 2.0 / (tp * ep if cfg.moe else tp)
    if kind == "train" and bundle.parallel.zero == 3:
        param_local /= 16  # FSDP over the data axis (gathered transiently)

    dp = devices / (16 if bundle.parallel.tp_enabled else 1)
    if kind == "train":
        tokens_local = shape.global_batch * shape.seq_len / dp
        opt_bytes = param_local * (6.0 if "bf" in
                                   bundle.optimizer_state_dtype else 10.0)
        act = ACT_FACTOR_TRAIN * tokens_local * cfg.d_model * 2.0 * \
            max(cfg.num_layers, 1)
        logits = tokens_local * cfg.vocab_size * 2.0 * 2.0 / tp
        return 3.0 * param_local + opt_bytes + act + logits

    # serving: batch shards over data only (model axis idle for tp_enabled
    # small models; see EXPERIMENTS.md notes)
    batch_local = max(shape.global_batch / min(dp, shape.global_batch), 1)
    kv_heads = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    attn_layers = sum(1 for i in range(cfg.num_layers)
                      if cfg.block_kind(i)["mixer"] == "attn")
    win_layers = sum(1 for i in range(cfg.num_layers)
                     if cfg.block_kind(i)["window"])
    full_layers = attn_layers - win_layers
    win = cfg.sliding_window or shape.seq_len
    kv_bytes = batch_local * 2 * kv_heads * (hd / tp if tp > 1 else hd) * \
        2.0 * (full_layers * shape.seq_len + win_layers *
               min(win, shape.seq_len))
    if cfg.enc_dec:
        kv_bytes *= 2  # cross-attention cache
    if kind == "prefill":
        tokens_local = shape.global_batch * shape.seq_len / min(
            dp, shape.global_batch)
        act = ACT_FACTOR_FWD * tokens_local * cfg.d_model * 2.0 * \
            max(cfg.num_layers, 1)
        return param_local + act + kv_bytes
    return param_local + kv_bytes  # decode: read weights + read cache


def roofline_row(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return None
    h = rec["hlo"]
    flops = h["flops"]
    bytes_ = _analytic_memory_bytes(rec)
    # bf16-wire correction: XLA:CPU promotes bf16 reductions AND MoE
    # all-to-alls to f32 (verified against the pre-optimization StableHLO,
    # which carries bf16 — see DESIGN.md §7.5); halve those classes.
    promoted = (h["collective_by_type"].get("all-reduce", 0.0)
                + h["collective_by_type"].get("reduce-scatter", 0.0)
                + h["collective_by_type"].get("all-to-all", 0.0))
    coll = h["collective_bytes"] - promoted / 2.0
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_ / HBM_BW
    t_coll = coll / (ICI_LINKS * ICI_BW_PER_LINK)
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = rec.get("model_flops", {})
    devices = rec.get("devices", 256)
    model_per_dev = mf.get("model_flops", 0.0) / devices
    useful = model_per_dev / flops if flops else 0.0
    bound = max(t_compute, t_memory, t_coll)
    # roofline fraction: useful model compute time / achieved bound time
    frac = (model_per_dev / PEAK_FLOPS_BF16) / bound if bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "strategy": rec.get("strategy", "mgwfbp"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_dev": model_per_dev, "hlo_flops_per_dev": flops,
        "useful_ratio": useful, "roofline_fraction": frac,
        "collective_counts": h.get("collective_count", {}),
    }


def load_all(art_dir: str = ARTIFACT_DIR, mesh: str | None = None,
             strategy_suffix: bool = False) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        base = os.path.basename(f)
        if not strategy_suffix and base.count("__") > 2:
            continue  # strategy-override artifacts are perf-loop only
        rec = json.load(open(f))
        if mesh and rec.get("mesh") != mesh:
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def improvement_note(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("merge/overlap more of the gradient traffic or reshard to "
                "cut resharding collectives")
    if d == "memory":
        return ("reduce remat recompute traffic / fuse norms-attention to "
                "cut HBM round trips")
    return ("cut redundant recompute (remat policy) so HLO FLOPs approach "
            "6ND")


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |\n")
    return "".join(out)


def run() -> list[tuple[str, float, str]]:
    rows = load_all(mesh="single")
    out = []
    for r in rows:
        out.append((
            f"roofline.{r['arch']}.{r['shape']}.bound_ms",
            max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e3,
            f"dom={r['dominant']} frac={r['roofline_fraction']:.2f} "
            f"useful={r['useful_ratio']:.2f}"))
    if not out:
        out.append(("roofline.no_artifacts", 0.0,
                    "run launch/dryrun.py first"))
    return out
