"""Fleet backend benchmark: the §7-scale grid and a 100-job co-plan round.

Two headline claims, both CI-enforced (any assertion failure fails the
suite and therefore the build):

* **Evaluation-stage speedup >= 10x.**  On the headline grid — ResNet-50
  under WFBP bucketing (161 buckets, the bucket-heavy regime), N =
  4..2048 workers × bandwidth scales, 8 iterations, a straggling worker
  — evaluating every point through the jitted fleet kernel
  (``repro.sim.fleet.evaluate_cases``, case construction included) must
  be >= 10x faster than the pure-Python per-point closed forms it
  replaces (``sweep._barrier_t_iter`` exactly as ``run_sweep``'s numpy
  backend drives it), and agree to 1e-9.  Full ``run_sweep`` walls for
  both backends are reported as context rows (ungated: at realistic
  sizes those walls are dominated by the *planner*, which is shared by
  every backend — the kernel removes the evaluation bottleneck, not the
  planning one).
* **100-job co-planning round in one device call.**  A 100-job fleet
  with mixed schedules scores its whole seed round — 101 candidate
  assignments × 100 jobs = 10100 scenario cases — through
  ``FleetEvaluator.batch`` in a single jitted call, bit-identical to the
  sequential per-assignment path, and the full ``CoPlanner`` run keeps
  the seed guarantee (never worse than the best seed assignment).

The whole-grid-in-one-call property is also asserted: the N=2048 grid
produces exactly one fleet evaluation (``SweepResult.backend ==
"fleet"``, no engine fallbacks).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.paper_profiles import tensor_profile
from repro.core import planner as planner_mod
from repro.core.coplanner import CoPlanner
from repro.core.cost_model import AllReduceModel
from repro.core.simulator import bucket_arrays, spec_arrays
from repro.sim import fleet
from repro.sim.coplan_profiles import make_fleet_jobs
from repro.sim.scenarios import PAPER_ALPHA, PAPER_BETA, PAPER_GAMMA
from repro.sim.sweep import SweepGrid, _barrier_t_iter, run_sweep

# headline grid: the paper's full N range × a bandwidth sweep; one
# deterministic straggler so the heterogeneous path is exercised without
# paying the (backend-shared) host-side jitter table
HEADLINE_NS = tuple(sorted(
    {2 ** p for p in range(2, 12)} | {3 * 2 ** p for p in range(1, 10)}))
HEADLINE_BWS = tuple(float(b) for b in np.linspace(0.5, 4.0, 40))
HEADLINE_ITERS = 8
HEADLINE_SLOW = {0: 1.3}
MIN_SPEEDUP = 10.0
ATOL = 1e-9


def _headline_points():
    """The (plan, model, s_max) grid both evaluation paths score."""
    specs, t_f = tensor_profile("resnet50")
    prefix_bytes, prefix_t = spec_arrays(specs)
    t_b_total = float(prefix_t[-1])
    # WFBP bucketing: model-independent, so the (shared) planning cost
    # stays out of the timed evaluation stage
    s_max = np.full((1, HEADLINE_ITERS), max(HEADLINE_SLOW.values()))
    points = []
    for n in HEADLINE_NS:
        for bw in HEADLINE_BWS:
            model = AllReduceModel(PAPER_ALPHA + PAPER_GAMMA * n,
                                   PAPER_BETA / bw)
            plan = planner_mod.make_plan("wfbp", specs, model)
            points.append((plan, model))
    return specs, t_f, t_b_total, prefix_bytes, prefix_t, s_max, points


def _time_numpy_eval(specs, t_f, t_b_total, prefix_bytes, prefix_t,
                     s_max, points):
    """The replaced path: per-point bucket arrays + python recurrence,
    exactly as ``run_sweep(backend="numpy")`` executes it."""
    t0 = time.perf_counter()
    out = np.empty((len(points), s_max.shape[0], HEADLINE_ITERS))
    for pi, (plan, model) in enumerate(points):
        bucket_bytes, ready_off = bucket_arrays(prefix_bytes, prefix_t,
                                                plan)
        bucket_t = np.array([model.time(b) for b in bucket_bytes],
                            dtype=np.float64)
        out[pi] = _barrier_t_iter(None, bucket_t, ready_off, t_f,
                                  t_b_total, s_max)
    return time.perf_counter() - t0, out


def _time_fleet_eval(specs, t_f, prefix_bytes, prefix_t, s_max, points):
    """The replacement: case construction (with the geometry memo the
    sweep also uses) + ONE jitted device call."""
    t0 = time.perf_counter()
    geom: dict = {}
    cases = [fleet.make_case(specs, plan, model, t_f=t_f, s_max=s_max,
                             prefix_bytes=prefix_bytes, prefix_t=prefix_t,
                             cache=geom)
             for plan, model in points]
    res = fleet.evaluate_cases(cases, iters=HEADLINE_ITERS)
    return time.perf_counter() - t0, res.t_iter


def _headline_rows() -> list[tuple[str, float, str]]:
    setup = _headline_points()
    n_points = len(setup[-1])

    # compile once (cold), then measure warm — CI archives both
    t0 = time.perf_counter()
    _time_fleet_eval(setup[0], setup[1], *setup[3:])
    compile_s = time.perf_counter() - t0
    t_np, ref = _time_numpy_eval(*setup)
    t_fl, got = _time_fleet_eval(setup[0], setup[1], *setup[3:])
    diff = float(np.abs(got - ref).max())
    speedup = t_np / t_fl
    assert diff <= ATOL, f"fleet vs numpy diverged: {diff:.3e}"
    assert speedup >= MIN_SPEEDUP, \
        (f"fleet evaluation speedup {speedup:.1f}x < {MIN_SPEEDUP}x "
         f"(numpy {t_np * 1e3:.1f}ms, fleet {t_fl * 1e3:.1f}ms, "
         f"{n_points} points)")

    # context: full run_sweep walls (shared planner dominates both) and
    # the one-call property on the paper grid
    specs, t_f = setup[0], setup[1]
    grid = SweepGrid(n_workers=HEADLINE_NS,
                     bandwidth_scales=HEADLINE_BWS[:8])
    kw = dict(alpha=PAPER_ALPHA, beta=PAPER_BETA, gamma=PAPER_GAMMA,
              iters=HEADLINE_ITERS, slow=HEADLINE_SLOW, strategy="wfbp")
    t0 = time.perf_counter()
    rn = run_sweep(specs, t_f, grid, backend="numpy", **kw)
    sweep_np = time.perf_counter() - t0
    run_sweep(specs, t_f, grid, backend="fleet", **kw)   # compile shape
    t0 = time.perf_counter()
    rf = run_sweep(specs, t_f, grid, backend="fleet", **kw)
    sweep_fl = time.perf_counter() - t0
    assert rf.backend == "fleet" and not rf.used_engine.any()
    assert rf.fallback_points == 0
    sweep_diff = float(np.abs(rf.t_iter - rn.t_iter).max())
    assert sweep_diff <= ATOL, sweep_diff
    assert 2048 in rf.grid.n_workers

    return [
        ("fleet.headline.numpy_eval_ms", t_np * 1e3,
         f"{n_points} points x {HEADLINE_ITERS} iters, 161 buckets"),
        ("fleet.headline.fleet_eval_ms", t_fl * 1e3,
         "one jitted call, warm (case build included)"),
        ("fleet.headline.eval_speedup", speedup,
         f">= {MIN_SPEEDUP:.0f}x enforced; maxdiff {diff:.1e}"),
        ("fleet.headline.compile_ms", compile_s * 1e3,
         "first-call jit compile (paid once per process/shape)"),
        ("fleet.headline.sweep_numpy_ms", sweep_np * 1e3,
         "full run_sweep wall, numpy backend (planner-dominated)"),
        ("fleet.headline.sweep_fleet_ms", sweep_fl * 1e3,
         f"full run_sweep wall to N=2048, fleet backend "
         f"(maxdiff {sweep_diff:.1e})"),
    ]


def _coplan_rows() -> list[tuple[str, float, str]]:
    jobs = make_fleet_jobs(100)
    evaluator = fleet.FleetEvaluator(jobs, iters=4)
    plans0 = {j.name: planner_mod.Planner(list(j.specs), j.model).plan()
              for j in jobs}
    assignments = [dict(plans0, **{j.name: j.seed_plans[0]}) for j in jobs]
    assignments.append({j.name: j.seed_plans[0] for j in jobs})

    evaluator.batch(assignments[:1])            # warm the round shape
    evaluator.batch(assignments)                # warm the batched shape
    t0 = time.perf_counter()
    batched = evaluator.batch(assignments)      # ONE device call
    t_batch = time.perf_counter() - t0
    t0 = time.perf_counter()
    sequential = [evaluator(a) for a in assignments]
    t_seq = time.perf_counter() - t0
    for b, s in zip(batched, sequential):
        assert b.makespan == s.makespan, (b.makespan, s.makespan)
        for name in b.jobs:
            assert b.jobs[name].t_iter == s.jobs[name].t_iter

    # the full co-plan keeps the seed guarantee, and the batched seed
    # round produces the identical result to a batch-less evaluator
    t0 = time.perf_counter()
    res = CoPlanner(jobs, evaluator, max_rounds=1).run()
    t_coplan = time.perf_counter() - t0
    seed_best = min(r.makespan for r in res.rounds if r.kind == "seed")
    assert res.makespan <= seed_best + 1e-12, (res.makespan, seed_best)
    res_seq = CoPlanner(jobs, lambda p: evaluator(p), max_rounds=1).run()
    assert res_seq.makespan == res.makespan
    assert {n: p.buckets for n, p in res.plans.items()} == \
        {n: p.buckets for n, p in res_seq.plans.items()}

    n_cases = len(assignments) * len(jobs)
    return [
        ("fleet.coplan100.batched_round_ms", t_batch * 1e3,
         f"{len(assignments)} assignments x {len(jobs)} jobs = "
         f"{n_cases} cases, one jitted call"),
        ("fleet.coplan100.sequential_round_ms", t_seq * 1e3,
         f"same round, one evaluate per assignment "
         f"({t_seq / t_batch:.1f}x slower)"),
        ("fleet.coplan100.coplanner_wall_ms", t_coplan * 1e3,
         f"full CoPlanner run, makespan {res.makespan:.4f}s "
         f"(= batch-less result, seed guarantee holds)"),
    ]


def run() -> list[tuple[str, float, str]]:
    if not fleet.fleet_available():   # pragma: no cover - jax is baked in
        raise RuntimeError("fleet benchmark needs jax")
    return _headline_rows() + _coplan_rows()
