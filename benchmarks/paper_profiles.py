"""Per-model tensor profiles for the paper's evaluated DNNs (Table 4).

The paper evaluates CNNs (GoogleNet, ResNet-50/152, DenseNet-161/201,
Inception-v4).  We reconstruct per-tensor (bytes, t_b) profiles from the
published tensor counts / parameter totals / MACs (Table 4) and the
qualitative size distribution of Fig. 5 (a large fraction of tiny BN/bias
tensors, e.g. "ResNet-152 has 150 tensors of 1024 bytes"), with backward
time distributed proportional to parameter count.  These drive the
reproduction of Figs. 6-11 in the simulator.
"""

from __future__ import annotations

import numpy as np

from repro.core.planner import TensorSpec

# name: (num_tensors, params, macs_per_sample, batch)      (paper Table 4)
PAPER_MODELS = {
    "googlenet": (59, 13e6, 1.43e9, 64),
    "resnet50": (161, 25.5e6, 3.9e9, 32),
    "resnet152": (467, 60.1e6, 11.61e9, 128),
    "densenet161": (484, 28.6e6, 7.85e9, 64),
    "densenet201": (604, 20e6, 4.39e9, 64),
    "inceptionv4": (449, 42.6e6, 6.16e9, 128),
}

# K80 single-GPU effective throughput for backward+forward, tuned so the
# simulated iteration times land in the paper's Fig. 6-7 range.
K80_FLOPS = 2.0e12
V100_FLOPS = 1.2e13


def tensor_profile(model: str, device_flops: float = K80_FLOPS,
                   dtype_bytes: int = 4, seed: int = 0):
    """Backward-ordered TensorSpecs for one paper model."""
    n_tensors, n_params, macs, batch = PAPER_MODELS[model]
    rng = np.random.default_rng(seed)
    # Fig. 5 structure: ~60% tiny tensors (256..4096 params), ~35% medium
    # conv kernels, ~5% big (fc / final convs).
    n_tiny = int(n_tensors * 0.62)
    n_med = int(n_tensors * 0.33)
    n_big = n_tensors - n_tiny - n_med
    tiny = rng.integers(64, 2048, n_tiny)
    med = rng.integers(1 << 14, 1 << 19, n_med)
    big = rng.integers(1 << 20, 1 << 22, n_big)
    sizes = np.concatenate([tiny, med, big]).astype(float)
    rng.shuffle(sizes)
    sizes *= n_params / sizes.sum()                 # normalize to Table 4
    sizes = np.maximum(sizes.astype(int), 1)

    # forward+backward compute time: 3x MACs (fwd 1x, bwd 2x), 2 flops/MAC
    t_total = 3.0 * 2.0 * macs * batch / device_flops
    t_b_total = t_total * 2.0 / 3.0
    t_f = t_total / 3.0
    t_b = sizes / sizes.sum() * t_b_total
    specs = [TensorSpec(f"{model}.t{i}", int(s) * dtype_bytes, float(t))
             for i, (s, t) in enumerate(zip(sizes, t_b))]
    return specs, t_f
