"""Real-loop smoke: measured-cost planning on an actual 4-device CPU mesh.

The closed loop the paper runs once offline — measure (a, b) and per-tensor
t_b, plan, execute — driven end to end on real jitted train steps with 4
forced host devices, plus the online half (refit + replan + step swap) via
:class:`repro.train.replan.ReplanController`.

Assertions (the acceptance gate):

* the DP plan built from MEASURED costs predicts a step time <= the wfbp
  plan under the same fitted model (DP optimality on real numbers — if the
  fit were degenerate or the simulate replay inconsistent, this breaks);
* the closed-loop controller refits from live IterationRecords and, seeded
  with the wfbp plan, swaps at least once toward a merged plan.

Wall-clock rows are informational (CPU psum timing is too noisy to gate).
Runs in a subprocess so ``XLA_FLAGS`` lands before jax imports and the
parent process keeps its single device.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json, time
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ShapeConfig
from repro.core import bucketer, planner as planner_mod, profiler
from repro.core.simulator import simulate
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import make_mesh, use_mesh
from repro.models import registry
from repro.obs import recorder
from repro.train import replan
from repro.train.step import build_train_step

bundle = registry.reduced_arch("qwen2-1.5b")
par = dataclasses.replace(bundle.parallel, dp_axes=("data",), zero=0,
                          ep_axis="", attn_chunk=32)
shape = ShapeConfig("tiny", "train", 16, 8)
run_cfg = dataclasses.replace(bundle.run_config("train_4k", par),
                              shape=shape, microbatch=0)
model = bundle.model(par)
mesh = make_mesh((4,), ("data",))

# 1. MEASURE: fit (a, b) from real timed collectives, t_f / per-tensor t_b
#    from the real jitted loss + VJP.
mdl = replan.measure_comm_model(mesh, ("data",),
                                sizes_bytes=(1 << 14, 1 << 18, 1 << 21),
                                n_iters=2)
params = model.init(jax.random.PRNGKey(0))
pipe = DataPipeline(bundle.cfg, shape, seed=0)
batch = pipe.batch_at(0)
metas = bucketer.leaf_metadata(params)
t_f, tb_table = profiler.measure_loss_profile(
    lambda p, b: model.loss(p, b), (params, batch), metas, n_iters=2)

# 2. PLAN from the measured costs; wfbp is the baseline partition.
with use_mesh(mesh):
    _, _, art = build_train_step(model, run_cfg, mesh, strategy="wfbp",
                                 tb_table=tb_table, comm_model=mdl)
specs = art.specs
plan_wfbp = art.plan
plan_dp = planner_mod.Planner(specs, mdl).plan()
pred_wfbp = simulate(specs, plan_wfbp, mdl, t_f)
pred_dp = simulate(specs, plan_dp, mdl, t_f)
assert pred_dp.t_iter <= pred_wfbp.t_iter + 1e-12, (
    f"DP plan predicts {pred_dp.t_iter} > wfbp {pred_wfbp.t_iter} "
    "under the measured model")

# 3. EXECUTE + REFIT + REPLAN: live controller seeded with wfbp.
rec = recorder.FlightRecorder()
steps = 6
with use_mesh(mesh):
    ctl, init_fn, cart = replan.closed_loop(
        model, run_cfg, mesh, strategy="wfbp", tb_table=tb_table,
        comm_model=mdl, t_f=t_f, recorder=rec,
        warmup=1, interval=2, hysteresis=1e-9)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cart.state_pspecs,
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(init_fn(jax.random.PRNGKey(0)), sh)
    walls = []
    for s in range(steps):
        fn = ctl.step_fn
        t0 = time.perf_counter()
        state, m = fn(state, pipe.batch_at(s))
        jax.block_until_ready(m)
        walls.append(time.perf_counter() - t0)

assert ctl.swaps, "controller never swapped off the wfbp seed"
assert rec.events("planner_update"), "no planner_update events recorded"
wall_after = min(walls[-2:])      # best-of post-swap (compile excluded)

print(json.dumps({
    "a_us": mdl.a * 1e6, "b_ns_per_byte": mdl.b * 1e9,
    "t_f_ms": t_f * 1e3, "tb_total_ms": sum(tb_table.values()) * 1e3,
    "num_tensors": len(specs),
    "wfbp_buckets": plan_wfbp.num_buckets, "dp_buckets": plan_dp.num_buckets,
    "pred_wfbp_ms": pred_wfbp.t_iter * 1e3,
    "pred_dp_ms": pred_dp.t_iter * 1e3,
    "swaps": len(ctl.swaps), "refits": len(ctl.decisions),
    "wall_step_ms": wall_after * 1e3,
}))
print("REAL-LOOP-OK")
"""


def run() -> list[tuple[str, float, str]]:
    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    if "REAL-LOOP-OK" not in res.stdout:
        raise RuntimeError(
            f"real_loop subprocess failed\nstdout:\n{res.stdout[-2000:]}\n"
            f"stderr:\n{res.stderr[-2000:]}")
    payload = json.loads(res.stdout.strip().splitlines()[-2])
    speedup = (payload["pred_wfbp_ms"] / payload["pred_dp_ms"]
               if payload["pred_dp_ms"] > 0 else 1.0)
    return [
        ("real_loop.measured_a", payload["a_us"],
         f"fitted startup us (b={payload['b_ns_per_byte']:.3f} ns/B)"),
        ("real_loop.t_f", payload["t_f_ms"] * 1e3,
         f"measured forward ms={payload['t_f_ms']:.2f} "
         f"tb_total_ms={payload['tb_total_ms']:.2f}"),
        ("real_loop.pred_wfbp", payload["pred_wfbp_ms"] * 1e3,
         f"predicted wfbp step ms={payload['pred_wfbp_ms']:.2f} "
         f"({payload['wfbp_buckets']} buckets)"),
        ("real_loop.pred_planned", payload["pred_dp_ms"] * 1e3,
         f"predicted planned step ms={payload['pred_dp_ms']:.2f} "
         f"({payload['dp_buckets']} buckets) <= wfbp "
         f"(x{speedup:.2f})"),
        ("real_loop.swaps", float(payload["swaps"]),
         f"live step swaps ({payload['refits']} refits, "
         f"{payload['num_tensors']} tensors)"),
        ("real_loop.wall_step", payload["wall_step_ms"] * 1e3,
         f"post-swap wall step ms={payload['wall_step_ms']:.2f} "
         "(informational: CPU mesh)"),
    ]


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value:.3f},{derived}")
