"""Batched planning + what-if serving benchmark (``run.py --whatif``).

Three gated claims, each pinned with asserts so CI fails loudly when the
perf story regresses:

1. **Planning-stage speedup.**  One warm ``fleet.plan_cases`` call over
   a 256-case batch at L=512 must beat 256 per-point
   ``plan_dp_optimal`` calls (the exact O(L^2) Python oracle) by >= 10x,
   with bit-equal buckets on every case.  The O(L) incremental
   ``Planner`` is a *different* contender: per point it stays faster
   than the O(L^2)-masked batched kernel at these sizes (the kernel
   pays L extra work per layer to be data-parallel), so the crossover
   rows report that honestly — batch against the exact oracle, or
   against any per-point Python loop that cannot amortize, is where the
   kernel wins; a single warm incremental planner is not.

2. **Plan+score beats score-only.**  A full 100-job co-plan round that
   PLANS all 100 responses (one ``plan_cases`` call) and SCORES all 101
   candidate assignments (one ``evaluate_cases`` call) must take less
   wall time than the PR-9 score-only path (one sequential
   ``FleetEvaluator`` call per assignment, no planning at all).

3. **What-if burst = one device call.**  A 16-query burst against a
   warm 100-job :class:`~repro.serve.whatif.FleetSnapshot` must consume
   exactly ONE plan-kernel call + ONE evaluate-kernel call and ZERO
   ``Planner`` scratch rebuilds (pinned via the metrics-registry
   delta), and an identical repeat burst must hit the result cache on
   every query.  Per-query latency rows (p50/p95 over single-query
   asks) and the cache hit rate ride along for the perf trajectory.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import planner as planner_mod
from repro.core.cost_model import AllReduceModel
from repro.obs.metrics import REGISTRY
from repro.serve.whatif import FleetSnapshot, WhatIfQuery, WhatIfServer
from repro.sim import fleet
from repro.sim.coplan_profiles import make_fleet_jobs

PLAN_L = 512                    # layers in the synthetic planning profile
PLAN_CASES = 256                # batch width of the headline planning gate
MIN_PLAN_SPEEDUP = 10.0         # vs per-point plan_dp_optimal
BURST = 16                      # what-if burst size for the counter gate
LATENCY_ASKS = 64               # single-query asks for the p50/p95 rows


def _plan_profile() -> list[planner_mod.TensorSpec]:
    """Deterministic L=512 profile: mixed tensor sizes (1B..4MB) and
    sub-100us backward times, VGG/ResNet-like spread."""
    rng = np.random.RandomState(0)
    return [planner_mod.TensorSpec(f"t{i}", int(rng.randint(1, 1 << 22)),
                                   float(rng.rand() * 1e-4))
            for i in range(PLAN_L)]


def _plan_models() -> list[AllReduceModel]:
    """256 distinct (a, b) points — a bandwidth/latency sweep."""
    return [AllReduceModel(a=1e-4 * (1 + 0.01 * k),
                           b=5e-10 / (0.5 + 0.01 * k))
            for k in range(PLAN_CASES)]


def _planning_rows() -> list[tuple[str, float, str]]:
    specs = _plan_profile()
    models = _plan_models()
    from repro.core.simulator import spec_arrays
    pb, pt = spec_arrays(specs)
    cases = [fleet.make_plan_case(specs, m, prefix_bytes=pb, prefix_t=pt)
             for m in models]

    t0 = time.perf_counter()
    fleet.plan_cases(cases)                     # compile
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched = fleet.plan_cases(cases)           # ONE warm device call
    t_batch = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracle = [planner_mod.plan_dp_optimal(specs, m) for m in models]
    t_oracle = time.perf_counter() - t0
    for got, ref in zip(batched, oracle):
        assert got.buckets == ref.buckets, (got.buckets, ref.buckets)
    speedup = t_oracle / t_batch
    assert speedup >= MIN_PLAN_SPEEDUP, \
        f"planning speedup {speedup:.1f}x < {MIN_PLAN_SPEEDUP}x"

    rows = [
        ("whatif.plan512.batched_ms", t_batch * 1e3,
         f"{PLAN_CASES} cases x L={PLAN_L}, one warm plan_cases call "
         f"(compile {t_compile * 1e3:.0f} ms)"),
        ("whatif.plan512.dp_oracle_ms", t_oracle * 1e3,
         f"per-point plan_dp_optimal, {speedup:.1f}x slower "
         f"(>= {MIN_PLAN_SPEEDUP:.0f}x enforced, buckets bit-equal)"),
    ]
    # the crossover, documented not gated: per point, the O(L)
    # incremental planner beats the O(L^2)-masked batched kernel
    inc = planner_mod.Planner(specs, models[0])
    for width in (8, 64, PLAN_CASES):
        sub = cases[:width]
        fleet.plan_cases(sub)                   # compile this width
        t0 = time.perf_counter()
        fleet.plan_cases(sub)
        t_k = time.perf_counter() - t0
        t0 = time.perf_counter()
        for m in models[:width]:
            inc.replan(m)
        t_p = time.perf_counter() - t0
        rows.append((
            f"whatif.plan512.crossover_c{width}_ms", t_k * 1e3,
            f"plan_cases vs {width} warm Planner.replan "
            f"({t_p * 1e3:.1f} ms, {t_k / t_p:.2f}x ratio)"))
    return rows


def _plan_score_rows() -> list[tuple[str, float, str]]:
    jobs = make_fleet_jobs(100)
    evaluator = fleet.FleetEvaluator(jobs, iters=4)
    plans0 = {j.name: planner_mod.Planner(list(j.specs), j.model).plan()
              for j in jobs}
    assignments = [dict(plans0, **{j.name: j.seed_plans[0]}) for j in jobs]
    assignments.append({j.name: j.seed_plans[0] for j in jobs})
    problems = [(j.specs, j.model) for j in jobs]

    evaluator.batch(assignments[:1])            # warm the round shapes
    evaluator.batch(assignments)
    fleet.plan_batched(problems)                # warm the planning shape

    t0 = time.perf_counter()
    planned = fleet.plan_batched(problems)      # PLAN all 100 responses
    scored = evaluator.batch(assignments)       # SCORE all 101 candidates
    t_plan_score = time.perf_counter() - t0

    t0 = time.perf_counter()
    sequential = [evaluator(a) for a in assignments]   # PR-9 score-only
    t_score_only = time.perf_counter() - t0

    assert t_plan_score < t_score_only, (t_plan_score, t_score_only)
    for b, s in zip(scored, sequential):
        assert b.makespan == s.makespan, (b.makespan, s.makespan)
    for j, p in zip(jobs, planned):             # responses stay exact
        assert p.buckets == planner_mod.plan_dp_optimal(
            list(j.specs), j.model).buckets, j.name
    return [
        ("whatif.coplan100.plan_score_ms", t_plan_score * 1e3,
         f"plan {len(jobs)} responses + score {len(assignments)} "
         f"assignments, 2 device calls"),
        ("whatif.coplan100.score_only_seq_ms", t_score_only * 1e3,
         f"PR-9 sequential score-only round, "
         f"{t_score_only / t_plan_score:.1f}x slower than plan+score"),
    ]


def _burst(jobs, k: int) -> list[WhatIfQuery]:
    """A 16-query burst over a 100-job snapshot; ``k`` varies the
    parameters so distinct bursts never share cache keys."""
    eps = 1e-4 * k
    qs = [WhatIfQuery("scale_bandwidth", jobs[i].name,
                      scale=1.25 + 0.25 * i + eps) for i in range(8)]
    qs += [WhatIfQuery("move_job", jobs[8 + i].name,
                       model=AllReduceModel(a=2e-4 + 1e-5 * i + eps * 1e-2,
                                            b=4e-10, name=f"path{i}"))
           for i in range(4)]
    qs += [WhatIfQuery("resize", jobs[12 + i].name,
                       t_f=jobs[12 + i].t_f * (1.5 + 0.5 * i + eps))
           for i in range(2)]
    qs.append(WhatIfQuery("remove_job", jobs[(14 + k) % 20].name))
    qs.append(WhatIfQuery(
        "add_job", f"newjob{k}",
        job=dataclasses.replace(jobs[15], name=f"newjob{k}",
                                t_f=jobs[15].t_f * (1 + eps))))
    assert len(qs) == BURST
    return qs


def _whatif_rows() -> list[tuple[str, float, str]]:
    jobs = make_fleet_jobs(100)
    t0 = time.perf_counter()
    snap = FleetSnapshot(jobs, iters=8)         # one plan_cases call
    snap.warm()                                 # one evaluate_cases call
    t_warm = time.perf_counter() - t0
    server = WhatIfServer(snap)

    server.ask(_burst(jobs, k=99))              # compile the burst shapes

    before = REGISTRY.snapshot()
    t0 = time.perf_counter()
    answers = server.ask(_burst(jobs, k=0))
    t_burst = time.perf_counter() - t0
    delta = REGISTRY.snapshot().delta(before)
    # THE acceptance gate: a warm-snapshot burst is one batched plan +
    # one batched evaluation, with no per-job Python planning loop
    assert delta.value("fleet_kernel_calls_total", kernel="plan") == 1
    assert delta.value("fleet_kernel_calls_total", kernel="evaluate") == 1
    assert delta.value("planner_scratch_plans_total") == 0
    assert delta.value("whatif_cache_hits_total") == 0
    assert not any(a.cached for a in answers)

    before = REGISTRY.snapshot()
    repeat = server.ask(_burst(jobs, k=0))      # identical burst
    delta = REGISTRY.snapshot().delta(before)
    assert delta.value("whatif_cache_hits_total") == BURST
    assert delta.value("fleet_kernel_calls_total", kernel="plan") == 0
    assert delta.value("fleet_kernel_calls_total", kernel="evaluate") == 0
    assert all(a.cached for a in repeat)
    for a, r in zip(answers, repeat):
        assert a.makespan == r.makespan

    # per-query latency: single-query asks, all cache misses.  Jobs mix
    # tensor profiles, so 1-case kernel shapes differ per profile — one
    # warm pass over the same jobs compiles them all first.
    for i in range(LATENCY_ASKS):
        server.ask([WhatIfQuery("scale_bandwidth", jobs[i % 50].name,
                                scale=100.0 + i)])
    lat = []
    for i in range(LATENCY_ASKS):
        q = WhatIfQuery("scale_bandwidth", jobs[i % 50].name,
                        scale=2.0 + 1e-3 * i)
        t0 = time.perf_counter()
        server.ask([q])
        lat.append(time.perf_counter() - t0)
    lat.sort()
    p50 = lat[len(lat) // 2]
    p95 = lat[min(len(lat) - 1, int(0.95 * (len(lat) - 1)))]

    hits = REGISTRY.snapshot()
    served = sum(hits.metrics["whatif_queries_total"]["series"].values())
    cached = hits.value("whatif_cache_hits_total")
    return [
        ("whatif.snapshot100.warm_ms", t_warm * 1e3,
         f"{len(jobs)}-job snapshot: batched default plans + baseline "
         f"spans, makespan {snap.makespan:.4f}s"),
        ("whatif.burst16.wall_ms", t_burst * 1e3,
         f"{BURST} mixed queries, warm snapshot: 1 plan + 1 evaluate "
         f"kernel call, 0 scratch rebuilds (counter-pinned)"),
        ("whatif.query.p50_ms", p50 * 1e3,
         f"single-query ask latency over {LATENCY_ASKS} misses"),
        ("whatif.query.p95_ms", p95 * 1e3, "same distribution"),
        ("whatif.cache.hit_rate", cached / served,
         f"{cached:g} of {served:g} queries served from cache "
         f"(repeat burst pinned at 100%)"),
    ]


def run() -> list[tuple[str, float, str]]:
    if not fleet.fleet_available():   # pragma: no cover - jax is baked in
        raise RuntimeError("what-if benchmark needs jax")
    return _planning_rows() + _plan_score_rows() + _whatif_rows()
