"""Algorithm 1 complexity check: O(L^2), one-time cost (paper §4.2).

Measures wall time of the faithful Algorithm 1 and the DP-optimal planner
for L up to 2048 tensors — both must stay far below one training step, so
the 'no side-effect to training performance' claim holds even for the
largest assigned model (deepseek-67b: ~600 tensors unrolled)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.cost_model import AllReduceModel
from repro.core.planner import TensorSpec, plan_dp_optimal, plan_mgwfbp


def run() -> list[tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    model = AllReduceModel(9.72e-4, 1.97e-9)
    prev = None
    for L in (64, 256, 1024, 2048):
        specs = [TensorSpec(f"t{i}", int(rng.integers(256, 1 << 22)),
                            float(rng.uniform(1e-5, 1e-3)))
                 for i in range(L)]
        t0 = time.perf_counter()
        plan_mgwfbp(specs, model)
        t1 = time.perf_counter() - t0
        t0 = time.perf_counter()
        plan_dp_optimal(specs, model)
        t2 = time.perf_counter() - t0
        growth = "" if prev is None else f"alg1 growth x{t1/prev:.1f}"
        prev = t1
        rows.append((f"planner.alg1.L{L}_us", t1 * 1e6,
                     f"dp_optimal={t2*1e6:.0f}us {growth}"))
    return rows
