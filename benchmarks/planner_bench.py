"""Planning-cost benchmarks: one-time O(L^2) reference vs the fast path.

The paper's §4.2 claim is that the merge plan is a one-time O(L^2) cost,
"without affecting the training performance".  That holds for a single
static plan — but this repo replans *in the loop* (elastic resizes,
straggler evictions, contention fixpoints, scenario sweeps), so the
planning cost itself is a hot path.  This suite measures:

  * the faithful Algorithm 1 and DP-optimal reference planners (O(L^2));
  * the incremental planner's from-scratch build (O(L));
  * incremental replanning at L=512 — cost-model swaps, point updates,
    appends — which must be >= 10x faster than a from-scratch
    ``plan_mgwfbp`` (asserted);
  * the counter guard: a model-update sweep through one ``Planner`` must
    never rebuild state from scratch (``scratch_plans`` stays 1).  CI runs
    ``python benchmarks/planner_bench.py --check`` to enforce exactly
    this, so a regression that silently falls back to from-scratch
    replanning where the incremental path applies fails the build.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.cost_model import AllReduceModel
from repro.core.planner import (Planner, SpecDelta, TensorSpec,
                                plan_dp_optimal, plan_mgwfbp)

REPLAN_L = 512          # the CI-guarded size
REPLAN_UPDATES = 32
MIN_SPEEDUP = 10.0      # incremental replan vs from-scratch Algorithm 1


def _specs(L: int, seed: int = 0) -> list[TensorSpec]:
    rng = np.random.default_rng(seed)
    return [TensorSpec(f"t{i}", int(rng.integers(256, 1 << 22)),
                       float(rng.uniform(1e-5, 1e-3)))
            for i in range(L)]


def _bench_replan(L: int = REPLAN_L, updates: int = REPLAN_UPDATES,
                  ) -> dict[str, float]:
    """Measure from-scratch vs incremental replanning at size L."""
    specs = _specs(L)
    base = AllReduceModel(9.72e-4, 1.97e-9)
    models = [AllReduceModel(base.a * (1 + 0.01 * k), base.b)
              for k in range(1, updates + 1)]

    t0 = time.perf_counter()
    plan_mgwfbp(specs, base)
    t_scratch_alg1 = time.perf_counter() - t0
    t0 = time.perf_counter()
    plan_dp_optimal(specs, base)
    t_scratch_dp = time.perf_counter() - t0

    planner = Planner(specs, base)
    t0 = time.perf_counter()
    for m in models:
        planner.replan(m)
    t_model = (time.perf_counter() - t0) / updates

    rng = np.random.default_rng(1)
    deltas = [SpecDelta(updates={
        int(rng.integers(0, L)): TensorSpec(
            f"u{k}", int(rng.integers(256, 1 << 22)),
            float(rng.uniform(1e-5, 1e-3)))})
        for k in range(updates)]
    t0 = time.perf_counter()
    for d in deltas:
        planner.update(d)
    t_point = (time.perf_counter() - t0) / updates

    t0 = time.perf_counter()
    for k in range(updates):
        planner.append(TensorSpec(f"a{k}", 1 << 20, 1e-4))
    t_append = (time.perf_counter() - t0) / updates

    return {
        "scratch_alg1": t_scratch_alg1,
        "scratch_dp": t_scratch_dp,
        "incr_model": t_model,
        "incr_point": t_point,
        "incr_append": t_append,
        "speedup": t_scratch_alg1 / t_model,
        "scratch_plans": planner.scratch_plans,
        "incremental_updates": planner.incremental_updates,
    }


def check_incremental(L: int = REPLAN_L) -> dict[str, float]:
    """The CI guard: counters + speedup floor at the guarded size.

    Raises if the update sweep rebuilt planner state from scratch anywhere
    the incremental path applies, or if the speedup target is missed.
    """
    r = _bench_replan(L)
    if r["scratch_plans"] != 1:
        raise AssertionError(
            f"incremental planner rebuilt from scratch {r['scratch_plans']}x "
            f"during an update sweep at L={L} — the incremental path was "
            f"bypassed (expected exactly 1 initial build)")
    if r["incremental_updates"] != 3 * REPLAN_UPDATES:
        raise AssertionError(
            f"expected {3 * REPLAN_UPDATES} incremental updates, "
            f"counted {r['incremental_updates']}")
    if r["speedup"] < MIN_SPEEDUP:
        raise AssertionError(
            f"incremental replan speedup {r['speedup']:.1f}x < "
            f"{MIN_SPEEDUP}x target at L={L} "
            f"(scratch {r['scratch_alg1']*1e3:.2f}ms vs incremental "
            f"{r['incr_model']*1e3:.3f}ms)")
    return r


def run() -> list[tuple[str, float, str]]:
    rows = []
    model = AllReduceModel(9.72e-4, 1.97e-9)
    prev = None
    for L in (64, 256, 1024, 2048):
        specs = _specs(L)
        t0 = time.perf_counter()
        plan_mgwfbp(specs, model)
        t1 = time.perf_counter() - t0
        t0 = time.perf_counter()
        plan_dp_optimal(specs, model)
        t2 = time.perf_counter() - t0
        t0 = time.perf_counter()
        Planner(specs, model).plan()
        t3 = time.perf_counter() - t0
        growth = "" if prev is None else f"alg1 growth x{t1/prev:.1f}"
        prev = t1
        rows.append((f"planner.alg1.L{L}_us", t1 * 1e6,
                     f"dp_optimal={t2*1e6:.0f}us incr_scratch={t3*1e6:.0f}us "
                     f"{growth}"))

    r = check_incremental()
    rows.append((f"planner.replan.scratch_alg1.L{REPLAN_L}_us",
                 r["scratch_alg1"] * 1e6, "from-scratch Algorithm 1"))
    rows.append((f"planner.replan.incremental.L{REPLAN_L}_us",
                 r["incr_model"] * 1e6,
                 f"cost-model swap via Planner.update "
                 f"(point={r['incr_point']*1e6:.0f}us "
                 f"append={r['incr_append']*1e6:.0f}us)"))
    rows.append((f"planner.replan.speedup.L{REPLAN_L}", r["speedup"],
                 f"incremental vs from-scratch (>= {MIN_SPEEDUP}x enforced); "
                 f"scratch_plans={r['scratch_plans']:.0f}"))
    return rows


def main(argv: list[str]) -> int:
    if "--check" in argv:
        r = check_incremental()
        print(f"planner incremental-path guard OK at L={REPLAN_L}: "
              f"speedup {r['speedup']:.0f}x, "
              f"scratch_plans={r['scratch_plans']:.0f}, "
              f"incremental_updates={r['incremental_updates']:.0f}")
        return 0
    for name, value, derived in run():
        print(f"{name},{value:.3f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
