"""Planner correctness: Algorithm 1, DP optimality, baselines.

Key property results (also reported in EXPERIMENTS.md):

* ``plan_dp_optimal`` is certified optimal: never worse than exhaustive
  search over all 2^(L-1) contiguous plans.
* The paper's Algorithm 1 matches the optimum in the large majority of
  random instances but is *not* always optimal (greedy local criterion,
  gaps up to ~6% on adversarial instances) — an honest reproduction
  finding; the paper's Theorem 1 proof is a local-exchange argument that
  does not cover interactions between merge decisions.
"""

import pytest

from repro.core.cost_model import AllReduceModel
from repro.core.planner import (MergePlan, TensorSpec, make_plan,
                                plan_dp_optimal, plan_fixed_size,
                                plan_mgwfbp, plan_single, plan_wfbp)
from repro.core.simulator import simulate

# The hypothesis property tests (DP optimality vs brute force, MG-WFBP
# dominance, near-optimality) live in tests/test_planner_props.py.


def _mk_specs(sizes, times):
    return [TensorSpec(f"t{i}", s, t) for i, (s, t) in
            enumerate(zip(sizes, times))]


def test_extremes():
    """a -> 0 favours WFBP granularity; a -> inf favours single bucket."""
    specs = _mk_specs([1 << 20] * 8, [1e-3] * 8)
    no_startup = AllReduceModel(0.0, 1e-9)
    plan = plan_mgwfbp(specs, no_startup)
    t = simulate(specs, plan, no_startup).t_iter
    t_wfbp = simulate(specs, plan_wfbp(specs), no_startup).t_iter
    assert t <= t_wfbp + 1e-12

    huge_startup = AllReduceModel(10.0, 1e-9)
    plan = plan_mgwfbp(specs, huge_startup)
    assert plan.num_buckets == 1  # converges to SyncEASGD (paper §6.4)


def test_plan_structure():
    specs = _mk_specs([100, 200, 300, 400], [1e-3] * 4)
    plan = plan_fixed_size(specs, 350)
    assert plan.num_tensors == 4
    # close a bucket once accumulated bytes reach the cap
    assert [sum(specs[i].nbytes for i in b) for b in plan.buckets] == \
        [600, 400]
    flags = plan.merged_flags()
    assert flags == [True, True, False, False]
    rebuilt = MergePlan.from_merged_flags(flags)
    assert rebuilt.buckets == plan.buckets


def test_plan_validation():
    with pytest.raises(ValueError):
        MergePlan(((1, 0),))        # not contiguous
    with pytest.raises(ValueError):
        MergePlan(((0,), (2,)))     # gap


def test_make_plan_dispatch():
    specs = _mk_specs([100, 200], [1e-3, 1e-3])
    model = AllReduceModel(1e-3, 1e-9)
    for s in ("wfbp", "single", "mgwfbp", "dp_optimal", "fixed:150"):
        p = make_plan(s, specs, model)
        assert p.num_tensors == 2
    with pytest.raises(ValueError):
        make_plan("nope", specs, model)


def test_alg1_known_suboptimal_cases_exist():
    """Regression-documenting test: record that Algorithm 1 can be beaten
    (gap observed during reproduction; see EXPERIMENTS.md §Planner)."""
    import random
    random.seed(0)
    beaten = 0
    for _ in range(300):
        n = random.randint(1, 9)
        specs = _mk_specs(
            [random.randint(1, 500) * 1024 for _ in range(n)],
            [random.uniform(1e-4, 5e-3) for _ in range(n)])
        model = AllReduceModel(random.uniform(0, 2e-3),
                               random.uniform(1e-10, 5e-9))
        t1 = simulate(specs, plan_mgwfbp(specs, model), model).t_iter
        td = simulate(specs, plan_dp_optimal(specs, model), model).t_iter
        if t1 > td + 1e-9:
            beaten += 1
    assert 0 < beaten < 60   # suboptimal sometimes, not usually
