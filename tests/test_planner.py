"""Planner correctness: Algorithm 1, DP optimality, baselines.

Key property results (also reported in EXPERIMENTS.md):

* ``plan_dp_optimal`` is certified optimal: never worse than exhaustive
  search over all 2^(L-1) contiguous plans.
* The paper's Algorithm 1 matches the optimum in the large majority of
  random instances but is *not* always optimal (greedy local criterion,
  gaps up to ~6% on adversarial instances) — an honest reproduction
  finding; the paper's Theorem 1 proof is a local-exchange argument that
  does not cover interactions between merge decisions.
"""

import pytest
from _hypothesis_compat import hypothesis, st

from repro.core.cost_model import AllReduceModel
from repro.core.planner import (MergePlan, TensorSpec, make_plan,
                                plan_brute_force, plan_dp_optimal,
                                plan_fixed_size, plan_mgwfbp, plan_single,
                                plan_wfbp)
from repro.core.simulator import simulate


def _mk_specs(sizes, times):
    return [TensorSpec(f"t{i}", s, t) for i, (s, t) in
            enumerate(zip(sizes, times))]


specs_strategy = st.integers(1, 8).flatmap(
    lambda n: st.tuples(
        st.lists(st.integers(1, 1 << 22), min_size=n, max_size=n),
        st.lists(st.floats(1e-6, 5e-3), min_size=n, max_size=n),
    ))

model_strategy = st.tuples(st.floats(0, 2e-3), st.floats(1e-11, 1e-8))


@hypothesis.given(specs_strategy, model_strategy)
@hypothesis.settings(max_examples=150, deadline=None)
def test_dp_optimal_is_optimal(sizes_times, ab):
    sizes, times = sizes_times
    specs = _mk_specs(sizes, times)
    model = AllReduceModel(*ab)
    t_dp = simulate(specs, plan_dp_optimal(specs, model), model).t_iter
    t_bf = simulate(specs, plan_brute_force(specs, model), model).t_iter
    assert t_dp <= t_bf + 1e-12


@hypothesis.given(specs_strategy, model_strategy)
@hypothesis.settings(max_examples=150, deadline=None)
def test_mgwfbp_beats_or_matches_baselines(sizes_times, ab):
    """The paper's central claim: MG-WFBP <= min(WFBP, SyncEASGD)."""
    sizes, times = sizes_times
    specs = _mk_specs(sizes, times)
    model = AllReduceModel(*ab)
    t_mg = simulate(specs, plan_mgwfbp(specs, model), model).t_iter
    t_wfbp = simulate(specs, plan_wfbp(specs), model).t_iter
    t_single = simulate(specs, plan_single(specs), model).t_iter
    assert t_mg <= min(t_wfbp, t_single) + 1e-12


@hypothesis.given(specs_strategy, model_strategy)
@hypothesis.settings(max_examples=100, deadline=None)
def test_mgwfbp_near_optimal(sizes_times, ab):
    """Algorithm 1 is within 10% of the certified optimum (empirically it
    matches exactly in ~94% of instances; see module docstring)."""
    sizes, times = sizes_times
    specs = _mk_specs(sizes, times)
    model = AllReduceModel(*ab)
    t_mg = simulate(specs, plan_mgwfbp(specs, model), model).t_iter
    t_dp = simulate(specs, plan_dp_optimal(specs, model), model).t_iter
    assert t_mg <= 1.10 * t_dp + 1e-12


def test_extremes():
    """a -> 0 favours WFBP granularity; a -> inf favours single bucket."""
    specs = _mk_specs([1 << 20] * 8, [1e-3] * 8)
    no_startup = AllReduceModel(0.0, 1e-9)
    plan = plan_mgwfbp(specs, no_startup)
    t = simulate(specs, plan, no_startup).t_iter
    t_wfbp = simulate(specs, plan_wfbp(specs), no_startup).t_iter
    assert t <= t_wfbp + 1e-12

    huge_startup = AllReduceModel(10.0, 1e-9)
    plan = plan_mgwfbp(specs, huge_startup)
    assert plan.num_buckets == 1  # converges to SyncEASGD (paper §6.4)


def test_plan_structure():
    specs = _mk_specs([100, 200, 300, 400], [1e-3] * 4)
    plan = plan_fixed_size(specs, 350)
    assert plan.num_tensors == 4
    # close a bucket once accumulated bytes reach the cap
    assert [sum(specs[i].nbytes for i in b) for b in plan.buckets] == \
        [600, 400]
    flags = plan.merged_flags()
    assert flags == [True, True, False, False]
    rebuilt = MergePlan.from_merged_flags(flags)
    assert rebuilt.buckets == plan.buckets


def test_plan_validation():
    with pytest.raises(ValueError):
        MergePlan(((1, 0),))        # not contiguous
    with pytest.raises(ValueError):
        MergePlan(((0,), (2,)))     # gap


def test_make_plan_dispatch():
    specs = _mk_specs([100, 200], [1e-3, 1e-3])
    model = AllReduceModel(1e-3, 1e-9)
    for s in ("wfbp", "single", "mgwfbp", "dp_optimal", "fixed:150"):
        p = make_plan(s, specs, model)
        assert p.num_tensors == 2
    with pytest.raises(ValueError):
        make_plan("nope", specs, model)


def test_alg1_known_suboptimal_cases_exist():
    """Regression-documenting test: record that Algorithm 1 can be beaten
    (gap observed during reproduction; see EXPERIMENTS.md §Planner)."""
    import random
    random.seed(0)
    beaten = 0
    for _ in range(300):
        n = random.randint(1, 9)
        specs = _mk_specs(
            [random.randint(1, 500) * 1024 for _ in range(n)],
            [random.uniform(1e-4, 5e-3) for _ in range(n)])
        model = AllReduceModel(random.uniform(0, 2e-3),
                               random.uniform(1e-10, 5e-9))
        t1 = simulate(specs, plan_mgwfbp(specs, model), model).t_iter
        td = simulate(specs, plan_dp_optimal(specs, model), model).t_iter
        if t1 > td + 1e-9:
            beaten += 1
    assert 0 < beaten < 60   # suboptimal sometimes, not usually
