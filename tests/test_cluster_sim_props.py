"""Cluster-engine property tests: engine == closed form on the shared
domain, straggler monotonicity; skipped without the real hypothesis
package."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
from prop_strategies import mk_specs, model_strategy, specs_strategy  # noqa: E402

from repro.core.cost_model import AllReduceModel  # noqa: E402
from repro.core.planner import make_plan, plan_brute_force  # noqa: E402
from repro.core.simulator import cross_validate, simulate  # noqa: E402
from repro.sim import event_driven_t_iter, scenarios, trace  # noqa: E402

STRATEGIES = ("wfbp", "single", "mgwfbp", "dp_optimal")
SPECS = specs_strategy()
MODELS = model_strategy()


@hypothesis.given(SPECS, MODELS, st.floats(0, 0.01),
                  st.sampled_from(["events", "analytic"]))
@hypothesis.settings(max_examples=60, deadline=None)
def test_engine_matches_closed_form(sizes_times, ab, t_f, compute_mode):
    specs = mk_specs(*sizes_times)
    model = AllReduceModel(*ab)
    for strat in STRATEGIES:
        plan = make_plan(strat, specs, model)
        t_cf = simulate(specs, plan, model, t_f).t_iter
        t_eng = event_driven_t_iter(specs, plan, model, t_f,
                                    n_workers=4, compute_mode=compute_mode)
        assert t_eng == pytest.approx(t_cf, abs=1e-9)


@hypothesis.given(SPECS, MODELS)
@hypothesis.settings(max_examples=25, deadline=None)
def test_engine_matches_closed_form_on_optimal_plan(sizes_times, ab):
    """Same identity on the certified-optimal brute-force plan."""
    specs = mk_specs(*sizes_times)
    model = AllReduceModel(*ab)
    plan = plan_brute_force(specs, model)
    cross_validate(specs, plan, model, t_f=1e-3, atol=1e-9, n_workers=3)


@hypothesis.given(st.floats(1.0, 4.0), st.floats(0.0, 2.0))
@hypothesis.settings(max_examples=20, deadline=None)
def test_straggler_monotonicity(factor, extra):
    """Sequential-comm sync SGD: slowing a worker down more never makes
    the iteration faster."""
    specs, t_f = trace.synthetic_specs(12, seed=4)
    t1 = scenarios.straggler(specs, t_f, 6, slow_factor=factor) \
        .run().job("train").t_iters[-1]
    t2 = scenarios.straggler(specs, t_f, 6, slow_factor=factor + extra) \
        .run().job("train").t_iters[-1]
    assert t2 >= t1 - 1e-12
