"""Per-kernel allclose vs pure-jnp oracles, shape/dtype sweeps
(interpret=True executes the kernel body on CPU).

The randomized shape sweeps live in tests/test_kernels_props.py
(hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bucket_pack import ops as bp_ops, ref as bp_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,skv,hq,hkv,d", [
    (2, 128, 128, 4, 2, 64),
    (1, 100, 100, 8, 8, 32),
    (2, 257, 257, 4, 1, 128),
    (1, 64, 64, 2, 2, 96),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 37),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, sq, skv, hq, hkv, d, causal,
                                     window, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, sq, hq, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, hkv, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, hkv, d), dtype)
    o = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=64, block_k=64, interpret=True)
    r = fa_ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_rejects_bad_gqa():
    q = jnp.zeros((1, 8, 3, 16))
    k = jnp.zeros((1, 8, 2, 16))
    with pytest.raises(ValueError):
        fa_ops.flash_attention(q, k, v=k, interpret=True)


# ---------------------------------------------------------------------------
# bucket pack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shapes", [
    [(33,), (128, 7), (512,)],
    [(1,)],
    [(5, 5), (1000,), (3, 5, 7), (2048,), (17,)],
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bucket_pack_roundtrip(shapes, dtype):
    leaves = [jax.random.normal(jax.random.PRNGKey(i), s).astype(dtype)
              for i, s in enumerate(shapes)]
    packed = bp_ops.pack(leaves, interpret=True)
    rref = bp_ref.pack_ref(leaves)
    np.testing.assert_array_equal(np.asarray(packed, np.float32),
                                  np.asarray(rref, np.float32))
    outs = bp_ops.unpack(packed, [l.shape for l in leaves],
                         [l.dtype for l in leaves], interpret=True)
    for o, l in zip(outs, leaves):
        np.testing.assert_array_equal(np.asarray(o, np.float32),
                                      np.asarray(l, np.float32))


def test_bucket_pack_many_leaves_chunked():
    """> MAX_SRCS_PER_CALL leaves exercises the chunked path."""
    leaves = [jnp.full((7,), float(i)) for i in range(40)]
    packed = bp_ops.pack(leaves, interpret=True)
    rref = bp_ref.pack_ref(leaves)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(rref))


def test_bucket_pack_mixed_dtype_default_promotes():
    """ops.pack / pack_ref / core.bucketer.pack share ONE default dtype
    rule (result_type promotion) — mixed-dtype buckets used to diverge
    (ops followed leaves[0].dtype, bucketer promoted)."""
    leaves = [jnp.ones((33,), jnp.bfloat16),
              jnp.full((70,), 2.0, jnp.float32)]
    packed = bp_ops.pack(leaves, interpret=True)
    rref = bp_ref.pack_ref(leaves)
    assert packed.dtype == rref.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(rref))


def test_bucket_pack_fallback_layout_identical():
    """The jnp fallback emits the same TILE-aligned buffer as the kernel,
    so a probe failure mid-fleet cannot change numerics or layout."""
    leaves = [jax.random.normal(jax.random.PRNGKey(i), s)
              for i, s in enumerate([(33,), (128, 7), (512,)])]
    packed = bp_ops.pack(leaves, interpret=True)
    bp_ops._KERNEL_OK[True] = False     # force the fallback path
    try:
        fb = bp_ops.pack(leaves, interpret=True)
        np.testing.assert_array_equal(np.asarray(packed), np.asarray(fb))
        outs = bp_ops.unpack(packed, [l.shape for l in leaves],
                             [l.dtype for l in leaves], interpret=True)
        for o, l in zip(outs, leaves):
            np.testing.assert_array_equal(np.asarray(o), np.asarray(l))
    finally:
        bp_ops._KERNEL_OK.pop(True, None)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64, 128), (100, 300), (7, 13, 65),
                                   (1, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), shape[-1:]).astype(dtype)
    o = rn_ops.rmsnorm(x, s, block_rows=64, interpret=True)
    r = rn_ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-5)


