"""Serving engine: batched generation, sampling, sharding specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.serve import sampling
from repro.serve.engine import ServeEngine


def test_greedy_sampling():
    logits = jnp.array([[0.0, 5.0, 1.0], [9.0, 0.0, 0.0]])
    out = sampling.greedy(logits)
    np.testing.assert_array_equal(np.asarray(out), [[1], [0]])


def test_temperature_topk():
    logits = jnp.array([[0.0, 10.0, 9.9, -5.0]])
    key = jax.random.PRNGKey(0)
    for i in range(10):
        t = sampling.temperature(logits, jax.random.fold_in(key, i),
                                 temp=0.5, top_k=2)
        assert int(t[0, 0]) in (1, 2)


def test_engine_generates():
    bundle = registry.reduced_arch("qwen2-1.5b")
    model = bundle.model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_len=64)
    prompts = [jnp.arange(10, dtype=jnp.int32),
               jnp.arange(5, dtype=jnp.int32)]
    outs = eng.generate(prompts, max_new_tokens=6)
    assert len(outs) == 2 and all(len(o) == 6 for o in outs)
    assert all(0 <= t < bundle.cfg.vocab_size for o in outs for t in o)


def test_engine_deterministic_greedy():
    bundle = registry.reduced_arch("xlstm-125m")
    model = bundle.model()
    params = model.init(jax.random.PRNGKey(0))
    eng1 = ServeEngine(model, params, max_len=48)
    eng2 = ServeEngine(model, params, max_len=48)
    p = [jnp.arange(8, dtype=jnp.int32)]
    assert eng1.generate(p, 5) == eng2.generate(p, 5)


def test_engine_encdec():
    bundle = registry.reduced_arch("whisper-base")
    model = bundle.model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_len=48)
    enc = jnp.zeros((2, 16, bundle.cfg.d_model), jnp.bfloat16)
    outs = eng.generate([jnp.arange(4, dtype=jnp.int32),
                         jnp.arange(4, dtype=jnp.int32)],
                        max_new_tokens=4, extra_batch={"enc_embeds": enc})
    assert len(outs) == 2 and all(len(o) == 4 for o in outs)
