"""System behaviour: public API surface + cross-component contracts."""

import jax
import pytest

import repro.core as core
from repro.configs.base import SHAPES
from repro.models import registry


def test_public_api_importable():
    from repro.core import (AllReduceModel, MergePlan, TensorSpec,
                            make_plan, simulate)
    from repro.train import build_train_step, checkpoint, fault
    from repro.serve import ServeEngine
    from repro.kernels.flash_attention import ops as fa
    assert callable(make_plan) and callable(simulate)


def test_all_assigned_archs_registered():
    assert sorted(registry.ARCHS) == sorted([
        "qwen2-1.5b", "deepseek-67b", "gemma3-12b", "stablelm-1.6b",
        "phi-3-vision-4.2b", "deepseek-moe-16b", "arctic-480b",
        "jamba-v0.1-52b", "whisper-base", "xlstm-125m"])
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}


def test_cell_assignment_covers_40_with_documented_skips():
    """40 (arch x shape) cells total; every skip carries a reason."""
    total = skipped = 0
    for arch in registry.list_archs():
        b = registry.get_arch(arch)
        for shape in SHAPES:
            total += 1
            if shape in b.skip_shapes:
                skipped += 1
                assert len(b.skip_shapes[shape]) > 10  # documented reason
    assert total == 40
    # long_500k runs for ssm/hybrid/local-window archs only
    runs_long = [a for a in registry.list_archs()
                 if "long_500k" not in registry.get_arch(a).skip_shapes]
    assert sorted(runs_long) == ["gemma3-12b", "jamba-v0.1-52b",
                                 "xlstm-125m"]


def test_input_specs_no_allocation():
    """input_specs are ShapeDtypeStructs — never device arrays."""
    for arch in ("qwen2-1.5b", "whisper-base", "phi-3-vision-4.2b"):
        b = registry.get_arch(arch)
        specs = registry.train_input_specs(b.cfg, SHAPES["train_4k"])
        for leaf in jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
        assert specs["tokens"].shape == (256, 4096)


def test_decode_input_specs_structural():
    b = registry.get_arch("gemma3-12b")
    model = b.model()
    specs = registry.decode_input_specs(b.cfg, SHAPES["decode_32k"], model)
    assert specs["tokens"].shape == (128, 1)
    ks = [l for p, l in jax.tree_util.tree_flatten_with_path(
        specs["cache"])[0] if "['k']" in jax.tree_util.keystr(p)]
    # sliding-window layers cache at most `window` slots
    assert min(x.shape[-3] for x in ks) == b.cfg.sliding_window
    assert max(x.shape[-3] for x in ks) == 32768


def test_plan_consistency_across_build():
    """build_plan is deterministic and honours the strategy override."""
    from repro.train.step import build_plan
    b = registry.get_arch("qwen2-1.5b")
    params_shape = jax.eval_shape(
        lambda: b.model().init(jax.random.PRNGKey(0)))
    run = b.run_config("train_4k")
    p1, _, specs, model = build_plan(params_shape, run, (16, 16),
                                     ("data", "model"))
    p2, _, _, _ = build_plan(params_shape, run, (16, 16), ("data", "model"))
    assert p1.buckets == p2.buckets
    pw, _, _, _ = build_plan(params_shape, run, (16, 16), ("data", "model"),
                             strategy="wfbp")
    assert pw.num_buckets == len(specs)
    ps, _, _, _ = build_plan(params_shape, run, (16, 16), ("data", "model"),
                             strategy="single")
    assert ps.num_buckets == 1
