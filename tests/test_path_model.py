"""Per-link path models: composition, refit, and the planning stack.

Anchors:

* **flat regression pin** — a single-phase :class:`PathModel` flattens to
  its (a, b) bit for bit, and ``plan_mgwfbp`` / ``Planner`` /
  ``plan_contention_aware`` produce bit-identical plans and round floats
  whether they are handed the flat model or its one-phase path;
* **hierarchical composition pin** — ``PathModel.flatten()`` is
  bit-equal to the pre-refactor ``HierarchicalModel.flat()`` for the
  ICI+DCN case, and ``Topology.phases`` / ``path_model`` are two views
  of one source of truth;
* **per-link telemetry conservation** — on a ``HierarchicalTopology``
  the ICI link is charged the full message per collective while the DCN
  link is charged the ``1/chips_per_pod`` shard;
* **per-link refit** — each link's (a_l, b_l) is recovered from that
  link's own occupancy samples, pooled per physical link in shared-model
  mode;
* **job churn** — ``coplan_incremental`` re-enters best response from
  the incumbent assignment and keeps the no-worse-than-seed guarantee.
"""

import pytest

from repro.core import coplanner, cost_model
from repro.core.coplanner import (CoJob, CoObservation, CoPlanner,
                                  JobObservation, coplan_incremental)
from repro.core.cost_model import (AllReduceModel, PathModel, PathPhase,
                                   blend_path, fit_path, single_path)
from repro.core.planner import (Planner, make_plan, plan_contention_aware,
                                plan_dp_optimal, plan_mgwfbp, plan_wfbp)
from repro.core.simulator import simulate
from repro.sim import scenarios, trace
from repro.sim.engine import ClusterSim, JobSpec
from repro.sim.network import (FlatTopology, HierarchicalTopology,
                               Topology)
from repro.sim.scenarios import CoJobSpec
from repro.sim.sweep import SweepGrid, run_sweep
from repro.sim.workers import make_workers

MODEL = AllReduceModel(5e-4, 2e-9)


# ---------------------------------------------------------------------------
# Composition: flatten() vs the pre-refactor flat formulas.
# ---------------------------------------------------------------------------

def test_single_phase_path_flattens_bit_equal():
    p = single_path(MODEL)
    flat = p.flatten()
    assert (flat.a, flat.b) == (MODEL.a, MODEL.b)
    for nbytes in (0, 1, 1 << 20, 1 << 30):
        assert p.time(nbytes) == MODEL.time(nbytes)


@pytest.mark.parametrize("pods,chips", [(2, 16), (4, 16), (2, 3), (3, 7)])
def test_hierarchical_path_flattens_bit_equal_to_flat(pods, chips):
    """The ICI+DCN composition rule: a = sum(a_l), b = sum(b_l) with the
    DCN phase's b already shard-diluted — bit-identical to the historic
    ``a = intra.a + inter.a``, ``b = intra.b + inter.b / intra_size``."""
    intra = cost_model.tpu_ici_ring(chips)
    inter = cost_model.tpu_dcn(pods)
    h = cost_model.HierarchicalModel(intra=intra, inter=inter,
                                     intra_size=chips)
    path = h.path()
    flat = path.flatten()
    assert flat.a == intra.a + inter.a
    assert flat.b == intra.b + inter.b / chips
    assert (h.flat().a, h.flat().b) == (flat.a, flat.b)
    # shard provenance: only 1/chips of the bytes cross the DCN link
    assert path.phases[1].shard_fraction == 1.0 / chips
    lb = path.link_bytes(1 << 20)
    assert lb["ici"] == float(1 << 20)
    assert lb["dcn"] == pytest.approx((1 << 20) / chips)


def test_topology_views_share_one_source_of_truth():
    """linear_model() and phases() are two views of path_model()."""
    topo = HierarchicalTopology(pods=4, chips_per_pod=16)
    path = topo.path_model()
    flat = topo.linear_model()
    assert (flat.a, flat.b) == (path.a, path.b)
    phases = topo.phases(1 << 20)
    assert [(p.link, p.startup, p.seconds_per_byte, p.shard_fraction)
            for p in phases] == \
        [(p.link, p.a, p.b, p.shard_fraction) for p in path.phases]
    assert topo.links == path.links == ("ici", "dcn")
    # single-pod degenerate: one ICI phase only
    single = HierarchicalTopology(pods=1, chips_per_pod=8)
    assert single.links == ("ici",)
    assert single.path_model().flatten().a == single.linear_model().a


def test_topology_from_path_model():
    path = PathModel((PathPhase("ici", 1e-5, 1e-10),
                      PathPhase("dcn", 2e-4, 5e-11, 0.25)))
    topo = Topology(path, n_workers=8)
    assert topo.links == ("ici", "dcn")
    assert topo.linear_model().a == path.a
    assert topo.link == "ici"


def test_path_validation():
    with pytest.raises(ValueError):
        PathModel(())
    with pytest.raises(ValueError):
        PathPhase("net", -1e-3, 1e-9)
    with pytest.raises(ValueError):
        PathPhase("net", 1e-3, 1e-9, 0.0)
    with pytest.raises(ValueError):
        PathPhase("net", 1e-3, 1e-9, 1.5)
    with pytest.raises(ValueError):
        blend_path(single_path(MODEL, "a"), single_path(MODEL, "b"), 0.5)


# ---------------------------------------------------------------------------
# Flat regression pin: every planner entry point, path vs flat model.
# ---------------------------------------------------------------------------

def test_planners_bit_identical_on_single_phase_path():
    specs, t_f = trace.synthetic_specs(32, seed=3)
    path = single_path(MODEL)
    assert plan_mgwfbp(specs, path).buckets == \
        plan_mgwfbp(specs, MODEL).buckets
    assert plan_dp_optimal(specs, path).buckets == \
        plan_dp_optimal(specs, MODEL).buckets
    p_flat, p_path = Planner(specs, MODEL), Planner(specs, path)
    assert p_path.plan().buckets == p_flat.plan().buckets
    assert p_path.finish_time == p_flat.finish_time
    # model swaps through a path replan stay bit-identical too
    new = AllReduceModel(1e-3, 1e-9)
    assert p_path.replan(single_path(new)).buckets == \
        p_flat.replan(new).buckets
    assert p_path.finish_time == p_flat.finish_time


def test_contention_fixpoint_bit_identical_on_single_phase_path():
    """plan_contention_aware(PathModel) reproduces the flat loop float
    for float: same rounds, same observed/predicted, same best plan."""
    specs, t_f = trace.synthetic_specs(20, seed=8)

    def evaluate(plan):
        job = JobSpec(name="j", specs=list(specs), plan=plan, t_f=t_f,
                      workers=make_workers(4),
                      topology=Topology(MODEL, n_workers=4))
        jr = ClusterSim([job]).run().job("j")
        return jr.iterations[-1].t_iter, jr.bucket_samples

    flat = plan_contention_aware(specs, MODEL, evaluate, t_f=t_f)
    path = plan_contention_aware(specs, single_path(MODEL), evaluate,
                                 t_f=t_f)
    assert path.plan.buckets == flat.plan.buckets
    assert len(path.rounds) == len(flat.rounds)
    assert [r.observed_t for r in path.rounds] == \
        [r.observed_t for r in flat.rounds]
    assert [r.predicted_t for r in path.rounds] == \
        [r.predicted_t for r in flat.rounds]
    assert (path.best_round, path.converged) == \
        (flat.best_round, flat.converged)


# ---------------------------------------------------------------------------
# Base-Topology rescale fallback (fitted single-link topologies).
# ---------------------------------------------------------------------------

def test_fitted_topology_rescale_falls_back_to_inversion():
    """Elastic resize on a fitted base Topology no longer raises: it
    inverts the fitted (a, b) through the declared algorithm's Table-2
    formula and re-predicts for the new membership."""
    from repro.sim import network

    a, b = cost_model.PAPER_CLUSTERS["cluster1_k80_10gbe"]
    topo = FlatTopology.from_fitted(a, b, n_workers=8)
    bigger = topo.rescale(32)
    expect = network.predicted_model("ring", a, b, 8, 32)
    assert isinstance(bigger, Topology)
    assert bigger.n_workers == 32
    assert bigger.linear_model().a == pytest.approx(expect.a)
    assert bigger.linear_model().b == pytest.approx(expect.b)
    # same membership is the identity; non-ring algorithms invert too
    assert topo.rescale(8) is topo
    dbt = FlatTopology.from_fitted(a, b, 8,
                                   algorithm="double_binary_trees")
    expect_dbt = network.predicted_model("double_binary_trees", a, b, 8, 16)
    assert dbt.rescale(16).linear_model().a == pytest.approx(expect_dbt.a)
    # degenerate memberships still refuse (no inversion at N < 2)
    with pytest.raises(ValueError):
        FlatTopology.from_fitted(a, b, 1).rescale(8)


def test_multi_phase_base_topology_refuses_rescale():
    """Inverting a composed multi-link (a, b) into single-link constants
    would silently collapse the path onto one link — the base class must
    refuse (subclasses with per-level constants rebuild exactly)."""
    path = PathModel((PathPhase("ici", 1e-5, 1e-10),
                      PathPhase("dcn", 2e-4, 5e-11, 0.25)))
    topo = Topology(path, n_workers=8)
    assert topo.rescale(8) is topo          # identity is still fine
    with pytest.raises(NotImplementedError, match="phase"):
        topo.rescale(16)
    # the hierarchical subclass knows its constants and rebuilds exactly
    hier = HierarchicalTopology(pods=2, chips_per_pod=4)
    assert hier.rescale(16).links == hier.links


def test_elastic_resize_on_fitted_topology_end_to_end():
    """The elastic-replan machinery runs through the fallback rescale:
    a mid-run resize on a paper-cluster (fitted) topology swaps workers,
    topology and plan without NotImplementedError."""
    specs, t_f = trace.synthetic_specs(12, seed=21)
    a, b = cost_model.PAPER_CLUSTERS["cluster2_v100_10gbe"]
    topo = FlatTopology.from_fitted(a, b, n_workers=4)

    def hook(sim, run, it):
        run.workers = make_workers(8)
        run.topology = run.topology.rescale(8)
        sim.ensure_links(run.topology)

    plan = make_plan("mgwfbp", specs, topo.linear_model())
    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(4), topology=topo, iters=3,
                  compute_mode="analytic", hooks={0: hook})
    res = ClusterSim([job]).run()
    assert len(res.job("train").iterations) == 3


# ---------------------------------------------------------------------------
# Per-link telemetry conservation on HierarchicalTopology.
# ---------------------------------------------------------------------------

def test_hierarchical_link_byte_conservation():
    """ICI is charged the full message per collective; DCN only the
    1/chips_per_pod shard that physically crosses pods."""
    specs, t_f = trace.synthetic_specs(10, seed=17)
    chips = 4
    sim = scenarios.hierarchical_pods(specs, t_f, pods=2,
                                      chips_per_pod=chips, iters=2)
    res = sim.run()
    jr = res.job("train")
    tele = jr.link_telemetry
    assert set(tele) == {"ici", "dcn"}
    assert tele["ici"][0] == pytest.approx(jr.bytes_communicated,
                                           abs=1e-6)
    assert tele["dcn"][0] == pytest.approx(jr.bytes_communicated / chips,
                                           abs=1e-6)
    # occupancy decomposes: per collective, ici + dcn legs == the whole
    ls = jr.link_samples
    whole = [t for _, t in jr.bucket_samples if t > 0]
    legs = [i + d for (_, i), (_, d) in zip(ls["ici"], ls["dcn"])]
    assert legs == pytest.approx(whole, rel=1e-12)
    # busy conservation on each link (single job: it gets all the share)
    for link in ("ici", "dcn"):
        assert sum(sim.links[link].owner_busy.values()) == \
            pytest.approx(sim.links[link].busy_s, abs=1e-9)


def test_shared_dcn_fleet_telemetry_conservation():
    """Two pod jobs share only the DCN uplink: private ICI telemetry is
    exclusively each job's own, and the shared link's per-owner bytes
    sum to everything admitted."""
    jobs = scenarios._two_pod_jobs(10)
    chips = 4
    sim = scenarios.hierarchical_shared_jobs(jobs, pods=2,
                                             chips_per_pod=chips, iters=2)
    res = sim.run()
    dcn_total = 0.0
    for j in jobs:
        jr = res.job(j.name)
        tele = jr.link_telemetry
        assert set(tele) == {f"{j.name}.ici", "dcn"}
        assert tele[f"{j.name}.ici"][0] == \
            pytest.approx(jr.bytes_communicated, abs=1e-6)
        assert tele["dcn"][0] == \
            pytest.approx(jr.bytes_communicated / chips, abs=1e-6)
        dcn_total += tele["dcn"][0]
        # the private link is untouched by the other job
        other = [x for x in jobs if x.name != j.name][0]
        assert f"{other.name}.ici" not in tele
    link = sim.links["dcn"]
    assert sum(link.owner_bytes.values()) == pytest.approx(dcn_total,
                                                           abs=1e-6)
    assert sum(link.owner_busy.values()) == pytest.approx(link.busy_s,
                                                          abs=1e-9)


# ---------------------------------------------------------------------------
# Per-link refit.
# ---------------------------------------------------------------------------

def _two_phase_path():
    return PathModel((PathPhase("ici", 1e-5, 2e-10),
                      PathPhase("dcn", 5e-4, 1e-10, 0.25)))


def test_fit_path_recovers_per_link_models():
    """Exact per-link samples reproduce each phase; a contended DCN leg
    moves ONLY the DCN phase."""
    base = _two_phase_path()
    sizes = (1 << 18, 1 << 22)
    stretched = {
        "ici": [(n, base.phases[0].time(n)) for n in sizes],
        "dcn": [(n, 2.0 * base.phases[1].time(n)) for n in sizes],
    }
    fitted = fit_path(base, stretched)
    assert fitted.phases[0].a == pytest.approx(base.phases[0].a, rel=1e-9)
    assert fitted.phases[0].b == pytest.approx(base.phases[0].b, rel=1e-9)
    assert fitted.phases[1].a == pytest.approx(2 * base.phases[1].a,
                                               rel=1e-9)
    assert fitted.phases[1].b == pytest.approx(2 * base.phases[1].b,
                                               rel=1e-9)
    assert fitted.phases[1].shard_fraction == base.phases[1].shard_fraction


def test_fit_path_rank_deficient_link_stretches():
    """One distinct size on a link can only stretch that link's phase."""
    base = _two_phase_path()
    n = 1 << 20
    fitted = fit_path(base, {"dcn": [(n, 3.0 * base.phases[1].time(n))]})
    assert fitted.phases[0] == base.phases[0]
    assert fitted.phases[1].a == pytest.approx(3 * base.phases[1].a)
    assert fitted.phases[1].b == pytest.approx(3 * base.phases[1].b)


def test_fit_path_no_link_samples_falls_back_to_whole_stretch():
    base = _two_phase_path()
    n = 1 << 20
    fitted = fit_path(base, {}, [(n, 1.5 * base.time(n))])
    assert fitted.a == pytest.approx(1.5 * base.a)
    assert fitted.b == pytest.approx(1.5 * base.b)
    assert fit_path(base, {}, []) is base


def test_coplanner_refit_pools_per_physical_link():
    """shared_model=True with path jobs: each job's DCN phase is refit
    from the UNION of both jobs' DCN samples (one distinct size each —
    only the pool spans two), while private ICI phases use own samples.
    This is the pooling the flat-model gating had to forbid."""
    specs, t_f = trace.synthetic_specs(6, seed=70)
    path_a = PathModel((PathPhase("a.ici", 1e-5, 2e-10),
                        PathPhase("dcn", 5e-4, 1e-10, 0.25)))
    path_b = PathModel((PathPhase("b.ici", 1e-5, 2e-10),
                        PathPhase("dcn", 5e-4, 1e-10, 0.25)))
    true_dcn = AllReduceModel(1e-3, 4e-10)
    jobs = [CoJob(name="a", specs=tuple(specs), model=path_a, t_f=t_f),
            CoJob(name="b", specs=tuple(specs), model=path_b, t_f=t_f)]
    obs = CoObservation(makespan=1.0, jobs={
        "a": JobObservation(
            t_iter=1.0, samples=((1 << 20, 1.0),),
            link_samples=(
                ("a.ici", ((1 << 20, path_a.phases[0].time(1 << 20)),)),
                ("dcn", ((1 << 20, true_dcn.time(1 << 20)),)))),
        "b": JobObservation(
            t_iter=1.0, samples=((1 << 22, 1.0),),
            link_samples=(
                ("b.ici", ((1 << 22, path_b.phases[0].time(1 << 22)),)),
                ("dcn", ((1 << 22, true_dcn.time(1 << 22)),)))),
    })

    def never(plans):   # pragma: no cover - _refit is driven directly
        raise AssertionError

    eff = {"a": path_a, "b": path_b}
    CoPlanner(jobs, never, damping=1.0, shared_model=True) \
        ._refit(obs, eff, jobs[0])
    dcn = eff["a"].phases[1]
    assert dcn.a == pytest.approx(true_dcn.a, rel=1e-9)
    assert dcn.b == pytest.approx(true_dcn.b, rel=1e-9)
    # private ICI: own (rank-deficient) sample can only stretch — here
    # the sample equals the prediction, so the phase is unchanged
    assert eff["a"].phases[0].a == pytest.approx(path_a.phases[0].a)
    assert eff["b"] is path_b           # only the sub-step's job refits
    # without shared_model the lone DCN sample cannot be LS-fit
    eff = {"a": path_a, "b": path_b}
    CoPlanner(jobs, never, damping=1.0)._refit(obs, eff, jobs[0])
    ratio = eff["a"].phases[1].b / eff["a"].phases[1].a
    assert ratio == pytest.approx(path_a.phases[1].b / path_a.phases[1].a)


def test_hierarchical_jobs_plan_guarantees_and_path_models():
    """The per-link co-plan keeps the no-worse-than-seed guarantee, its
    rounds carry PathModel effective models, and the observations carry
    the DCN leg samples the refit consumed."""
    jobs = scenarios._two_pod_jobs(14)
    fix = scenarios.hierarchical_jobs_plan(jobs, pods=2, chips_per_pod=4,
                                           iters=2, max_rounds=3,
                                           shared_model=True)
    seed_rounds = [r for r in fix.rounds if r.kind == "seed"]
    assert seed_rounds
    assert fix.makespan <= min(r.makespan for r in seed_rounds) + 1e-12
    for name in ("pod_a", "pod_b"):
        assert isinstance(fix.models[name], PathModel)
        assert fix.models[name].links == (f"{name}.ici", "dcn")
    for r in fix.rounds:
        for name in ("pod_a", "pod_b"):
            ls = dict(r.observation.jobs[name].link_samples)
            assert "dcn" in ls and f"{name}.ici" in ls
            assert all(t > 0 for _, t in ls["dcn"])


def test_hierarchical_flat_vs_path_seeded_ordering():
    """With the flat co-plan's assignment seeded into the per-link run,
    per-link shared ≤ per-job flat refit ≤ independent — the acceptance
    ordering the CI smoke step asserts at benchmark scale."""
    jobs = scenarios._two_pod_jobs(14)
    kw = dict(pods=2, chips_per_pod=4, iters=2, max_rounds=3)
    flat = scenarios.hierarchical_jobs_plan(jobs, per_link=False, **kw)
    shared = scenarios.hierarchical_jobs_plan(
        jobs, per_link=True, shared_model=True,
        extra_seed_plans=flat.plans, **kw)
    m_indep = scenarios.hierarchical_shared_jobs(
        jobs, pods=2, chips_per_pod=4, iters=2).run().makespan
    assert shared.makespan <= flat.makespan + 1e-12
    assert flat.makespan <= m_indep + 1e-12


# ---------------------------------------------------------------------------
# Job churn through the incremental co-planner.
# ---------------------------------------------------------------------------

def test_coplan_incremental_validates_warm_start():
    specs, t_f = trace.synthetic_specs(6, seed=2)
    job = CoJob(name="j", specs=tuple(specs), model=MODEL, t_f=t_f)

    def evaluate(plans):    # pragma: no cover - never reached
        raise AssertionError

    with pytest.raises(ValueError, match="unknown job"):
        CoPlanner([job], evaluate,
                  initial_plans={"ghost": plan_wfbp(specs)})
    with pytest.raises(ValueError, match="unknown job"):
        CoPlanner([job], evaluate, initial_models={"ghost": MODEL})
    with pytest.raises(ValueError, match="covers"):
        CoPlanner([job], evaluate,
                  initial_plans={"j": plan_wfbp(specs[:3])})
    # model-kind mismatches would silently flip the refit mode — refuse
    path_job = CoJob(name="p", specs=tuple(specs),
                     model=single_path(MODEL), t_f=t_f)
    with pytest.raises(ValueError, match="incompatible"):
        CoPlanner([path_job], evaluate, initial_models={"p": MODEL})
    with pytest.raises(ValueError, match="incompatible"):
        CoPlanner([job], evaluate,
                  initial_models={"j": single_path(MODEL)})
    with pytest.raises(ValueError, match="incompatible"):
        CoPlanner([path_job], evaluate,
                  initial_models={"p": single_path(MODEL, "other")})
    # same-kind warm starts are accepted
    CoPlanner([path_job], evaluate,
              initial_models={"p": single_path(MODEL)})


def test_coplan_incremental_drops_incompatible_incumbent_models():
    """A flat incumbent cannot seed a per-link path job: the survivor
    keeps its plan as warm start but refits from its own path model."""
    jobs = scenarios._two_pod_jobs(10)
    kw = dict(pods=2, chips_per_pod=4, iters=2, max_rounds=2)
    flat = scenarios.hierarchical_jobs_plan(jobs, per_link=False, **kw)
    co_jobs = []
    for j in jobs:
        topo = scenarios._pod_topology(j.name, 2, 4, "dcn")
        co_jobs.append(CoJob(
            name=j.name, specs=j.specs, model=topo.path_model(),
            t_f=j.t_f,
            seed_plans=(make_plan("mgwfbp", list(j.specs),
                                  topo.linear_model()),),
            links=topo.links))
    evaluate = scenarios._joint_evaluate(
        lambda candidate: scenarios.hierarchical_shared_jobs(
            jobs, pods=2, chips_per_pod=4, iters=2, plans=candidate),
        jobs)
    upd = coplan_incremental(flat, co_jobs, evaluate, max_rounds=2)
    for name in ("pod_a", "pod_b"):     # per-link refit stayed per-link
        assert isinstance(upd.models[name], PathModel)
    seed_rounds = [r for r in upd.rounds if r.kind == "seed"]
    assert upd.makespan <= min(r.makespan for r in seed_rounds) + 1e-12


def test_coplan_incremental_restart_of_fixed_point_is_immediate():
    """Warm-restarting a converged co-plan with its own plans/models on
    an unchanged fleet converges again without losing ground."""
    jobs = [CoJobSpec("a", *trace.synthetic_specs(12, seed=50)),
            CoJobSpec("b", *trace.synthetic_specs(20, seed=51))]
    first = scenarios.contended_jobs_plan(jobs, n_workers=4, iters=2,
                                          max_rounds=8)
    assert first.converged

    model = FlatTopology("ring", 4, scenarios.PAPER_ALPHA,
                         scenarios.PAPER_BETA,
                         scenarios.PAPER_GAMMA).linear_model()
    co_jobs = [CoJob(name=j.name, specs=j.specs, model=model, t_f=j.t_f,
                     seed_plans=(make_plan("mgwfbp", list(j.specs),
                                           model),),
                     links=("net",)) for j in jobs]
    evaluate = scenarios._joint_evaluate(
        lambda candidate: scenarios.shared_link_jobs(
            jobs, n_workers=4, iters=2, plans=candidate), jobs)
    again = coplan_incremental(first, co_jobs, evaluate, max_rounds=8)
    assert again.makespan <= first.makespan + 1e-12


def test_job_churn_arrival_keeps_seed_guarantee():
    """An arrival re-plans through the incumbent warm start; the updated
    assignment never loses to its seed candidates on the NEW fleet, and
    the incumbent plans are the warm entry point."""
    jobs = [CoJobSpec("a", *trace.synthetic_specs(12, seed=40)),
            CoJobSpec("b", *trace.synthetic_specs(16, seed=41))]
    late = CoJobSpec("late", *trace.synthetic_specs(10, seed=42),
                     start_time=0.02)
    sim, rep = scenarios.job_churn(jobs, arriving=[late], n_workers=4,
                                   iters=2, max_rounds=3)
    assert rep.arrived == ("late",)
    assert set(rep.updated.plans) == {"a", "b", "late"}
    seed_rounds = [r for r in rep.updated.rounds if r.kind == "seed"]
    assert rep.updated.makespan <= \
        min(r.makespan for r in seed_rounds) + 1e-12
    # the churn loop entered from the incumbent assignment
    first_response = [r for r in rep.updated.rounds
                      if r.kind == "response"][0]
    for name in ("a", "b"):
        assert first_response.plans[name].buckets == \
            rep.incumbent.plans[name].buckets
    res = sim.run()
    assert set(res.jobs) == {"a", "b", "late"}
    assert res.job("late").iterations[0].start >= 0.02


def test_job_churn_departure_drops_job():
    jobs = [CoJobSpec("a", *trace.synthetic_specs(12, seed=40)),
            CoJobSpec("b", *trace.synthetic_specs(16, seed=41)),
            CoJobSpec("c", *trace.synthetic_specs(10, seed=43))]
    sim, rep = scenarios.job_churn(jobs, departing=["c"], n_workers=4,
                                   iters=2, max_rounds=3)
    assert rep.departed == ("c",)
    assert set(rep.updated.plans) == {"a", "b"}
    assert set(sim.run().jobs) == {"a", "b"}
    with pytest.raises(ValueError, match="unknown"):
        scenarios.job_churn(jobs, departing=["ghost"], n_workers=4)
    with pytest.raises(ValueError, match="empty fleet"):
        scenarios.job_churn(jobs, departing=["a", "b", "c"], n_workers=4)


# ---------------------------------------------------------------------------
# Sweeps over hierarchical topologies.
# ---------------------------------------------------------------------------

def test_sweep_topology_factory_hierarchical():
    """The batched closed form runs over hierarchical topologies (the
    flattened path is still affine) and matches the engine point for
    point."""
    specs, t_f = trace.synthetic_specs(12, seed=5)
    chips = 4
    grid = SweepGrid(n_workers=(8, 16))

    def factory(n, bw):
        return HierarchicalTopology(n // chips, chips,
                                    dcn_bw=cost_model.DCN_BW * bw)

    fast = run_sweep(specs, t_f, grid, iters=2, topology_factory=factory)
    assert not fast.used_engine.any()
    slow = run_sweep(specs, t_f, grid, iters=2, topology_factory=factory,
                     force_engine=True)
    assert slow.used_engine.all()
    assert abs(fast.t_iter - slow.t_iter).max() < 1e-9
    with pytest.raises(ValueError, match="alpha"):
        run_sweep(specs, t_f, grid)
