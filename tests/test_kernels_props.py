"""Kernel property sweeps (interpret=True on CPU); skipped without the
real hypothesis package."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref  # noqa: E402
from repro.kernels.rmsnorm import ops as rn_ops  # noqa: E402


@hypothesis.given(
    st.integers(1, 2), st.integers(3, 80), st.integers(1, 3),
    st.sampled_from([16, 32, 64]), st.booleans())
@hypothesis.settings(max_examples=12, deadline=None)
def test_flash_attention_property(b, s, g, d, causal):
    hkv = 2
    hq = hkv * g
    q = jax.random.normal(jax.random.PRNGKey(3), (b, s, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, hkv, d))
    o = fa_ops.flash_attention(q, k, v, causal=causal, block_q=32,
                               block_k=32, interpret=True)
    r = fa_ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=3e-5,
                               atol=3e-5)


@hypothesis.given(st.integers(1, 50), st.sampled_from([8, 96, 128, 200]))
@hypothesis.settings(max_examples=10, deadline=None)
def test_rmsnorm_property(rows, d):
    x = jax.random.normal(jax.random.PRNGKey(rows), (rows, d))
    s = jnp.ones((d,))
    o = rn_ops.rmsnorm(x, s, block_rows=32, interpret=True)
    # unit-RMS property
    rms = np.sqrt(np.mean(np.asarray(o) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=2e-2)
