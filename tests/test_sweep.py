"""Batched sweep runner (repro.sim.sweep) correctness.

The contract: on the closed form's valid domain (single job, sequential
comm, no background traffic — heterogeneity and jitter included for
barrier schedules, homogeneous-only for pipelined/local-SGD) the batched
recurrence equals the event engine per point to 1e-9 — per-iteration
``t_iter`` AND whole-run ``span`` — and off that domain the sweep
transparently falls back to the engine and says so.

The randomized batched-recurrence == simulate() property lives in
tests/test_sweep_props.py (hypothesis).
"""

import numpy as np
import pytest

from repro.sim import scenarios, trace
from repro.sim.engine import ClusterSim, JobSpec
from repro.sim.network import Burst, FlatTopology
from repro.sim.schedules import (BSP, DAGSchedule, LocalSGD, OneFoneB,
                                 PipelinedAllReduce)
from repro.sim.sweep import SweepGrid, closed_form_valid, run_sweep
from repro.sim.workers import make_workers

A, B, G = scenarios.PAPER_ALPHA, scenarios.PAPER_BETA, scenarios.PAPER_GAMMA


def test_grid_validation():
    with pytest.raises(ValueError):
        SweepGrid(n_workers=())
    with pytest.raises(ValueError):
        SweepGrid(n_workers=(4,), bandwidth_scales=(0.0,))
    with pytest.raises(ValueError):
        SweepGrid(n_workers=(0,))


def test_closed_form_valid_conditions():
    assert closed_form_valid()
    assert not closed_form_valid(comm_mode="concurrent")
    assert not closed_form_valid(bursts=[Burst("net", 0.0, 1.0)])


def test_closed_form_valid_schedule_domains():
    """Barrier schedules tolerate heterogeneity; pipelined/local-SGD
    closed forms are homogeneous-only (except their BSP-degenerate
    parameter points); unknown schedules go to the engine."""
    for sched in (None, BSP(), OneFoneB(4)):
        assert closed_form_valid(schedule=sched, heterogeneous=True)
    for sched in (PipelinedAllReduce(0.5), LocalSGD(4)):
        assert closed_form_valid(schedule=sched)
        assert not closed_form_valid(schedule=sched, heterogeneous=True)
    # degenerate points ARE BSP, jitter included
    assert closed_form_valid(schedule=PipelinedAllReduce(0.0),
                             heterogeneous=True)
    assert closed_form_valid(schedule=LocalSGD(1), heterogeneous=True)
    assert not closed_form_valid(schedule=DAGSchedule())
    # contention still trumps everything
    assert not closed_form_valid(schedule=OneFoneB(4),
                                 bursts=[Burst("net", 0.0, 1.0)])


def test_sweep_matches_engine_heterogeneous():
    """Jitter + straggler stay on the fast path and match the engine."""
    specs, t_f = trace.synthetic_specs(20, seed=5)
    grid = SweepGrid(n_workers=(4, 32), bandwidth_scales=(0.5, 2.0),
                     seeds=(0, 3))
    res = run_sweep(specs, t_f, grid, alpha=A, beta=B, gamma=G, iters=3,
                    jitter_sigma=0.25, slow={0: 2.0})
    assert not res.used_engine.any()
    assert res.planner_scratch == 1
    assert res.planner_incremental == 3   # 4 grid points, 1 initial plan
    for ni, n in enumerate(grid.n_workers):
        for bi, bw in enumerate(grid.bandwidth_scales):
            topo = FlatTopology("ring", n, A, B / bw, G)
            for si, seed in enumerate(grid.seeds):
                job = JobSpec(name="train", specs=list(specs),
                              plan=res.plans[(n, bw)], t_f=t_f,
                              workers=make_workers(n, slow={0: 2.0},
                                                   jitter_sigma=0.25),
                              topology=topo, iters=3,
                              compute_mode="events")
                t_eng = ClusterSim([job], seed=seed).run().job("train") \
                    .t_iters
                np.testing.assert_allclose(res.t_iter[ni, bi, si], t_eng,
                                           atol=1e-9)


def test_sweep_engine_fallback_on_bursts():
    specs, t_f = trace.synthetic_specs(16, seed=6)
    grid = SweepGrid(n_workers=(8,))
    bursts = [Burst("net", 0.0, 10.0, flows=3)]
    noisy = run_sweep(specs, t_f, grid, alpha=A, beta=B, gamma=G, iters=2,
                      bursts=bursts)
    quiet = run_sweep(specs, t_f, grid, alpha=A, beta=B, gamma=G, iters=2)
    assert noisy.used_engine.all()
    assert not quiet.used_engine.any()
    assert (noisy.t_iter > quiet.t_iter + 1e-12).all()
    # the quiet fast-path point equals driving the engine directly
    job = JobSpec(name="train", specs=list(specs), plan=quiet.plans[(8, 1.0)],
                  t_f=t_f, workers=make_workers(8),
                  topology=FlatTopology("ring", 8, A, B, G), iters=2)
    t_eng = ClusterSim([job]).run().job("train").t_iters
    np.testing.assert_allclose(quiet.t_iter[0, 0, 0], t_eng, atol=1e-9)


def test_sweep_force_engine_agrees_with_fast_path():
    specs, t_f = trace.synthetic_specs(12, seed=8)
    grid = SweepGrid(n_workers=(4, 16), seeds=(0, 1))
    kw = dict(alpha=A, beta=B, gamma=G, iters=2, jitter_sigma=0.1)
    fast = run_sweep(specs, t_f, grid, **kw)
    slow = run_sweep(specs, t_f, grid, force_engine=True, **kw)
    assert slow.used_engine.all() and not fast.used_engine.any()
    np.testing.assert_allclose(fast.t_iter, slow.t_iter, atol=1e-9)
    np.testing.assert_allclose(fast.span, slow.span, atol=1e-9)


# ---------------------------------------------------------------------------
# Schedule-aware fast path.
# ---------------------------------------------------------------------------

SCHEDULE_POINTS = [
    (BSP(), 0.25),
    (OneFoneB(4), 0.25),            # barrier: jitter stays on the fast path
    (OneFoneB(2), 0.0),
    (PipelinedAllReduce(0.5), 0.0),   # frontier: homogeneous-only
    (PipelinedAllReduce(0.25), 0.0),
    (LocalSGD(3), 0.0),
    (PipelinedAllReduce(0.0), 0.25),  # degenerates: BSP with jitter
    (LocalSGD(1), 0.25),
    (OneFoneB(1), 0.25),
]


@pytest.mark.parametrize("schedule,jitter", SCHEDULE_POINTS,
                         ids=[f"{s.label}-j{j:g}"
                              for s, j in SCHEDULE_POINTS])
def test_schedule_sweep_matches_engine(schedule, jitter):
    """On each schedule's exactness domain the fast path equals the
    engine per iteration AND per whole-run span, to 1e-9."""
    specs, t_f = trace.synthetic_specs(18, seed=21)
    grid = SweepGrid(n_workers=(4, 16), bandwidth_scales=(0.5, 2.0),
                     seeds=(0, 2))
    kw = dict(alpha=A, beta=B, gamma=G, iters=5, jitter_sigma=jitter,
              schedule=schedule)
    fast = run_sweep(specs, t_f, grid, **kw)
    slow = run_sweep(specs, t_f, grid, force_engine=True, **kw)
    assert not fast.used_engine.any()
    assert slow.used_engine.all()
    np.testing.assert_allclose(fast.t_iter, slow.t_iter, atol=1e-9)
    np.testing.assert_allclose(fast.span, slow.span, atol=1e-9)


def test_schedule_sweep_heterogeneous_falls_back_to_engine():
    """Pipelined/local-SGD closed forms are homogeneous-only: jitter (or
    a slow worker) routes those grids through the engine."""
    specs, t_f = trace.synthetic_specs(10, seed=22)
    grid = SweepGrid(n_workers=(4,))
    for schedule in (PipelinedAllReduce(0.5), LocalSGD(3)):
        res = run_sweep(specs, t_f, grid, alpha=A, beta=B, gamma=G,
                        iters=3, jitter_sigma=0.2, schedule=schedule)
        assert res.used_engine.all()
        res = run_sweep(specs, t_f, grid, alpha=A, beta=B, gamma=G,
                        iters=3, slow={0: 2.0}, schedule=schedule)
        assert res.used_engine.all()


def test_pipelined_span_reflects_overlap():
    """Pipelined iterations overlap (the all-gather tail hides under the
    next forward), so the run span is strictly less than the sum of the
    per-iteration windows — while for barrier schedules they're equal."""
    specs, t_f = trace.synthetic_specs(16, seed=23)
    grid = SweepGrid(n_workers=(8,))
    kw = dict(alpha=A, beta=B, gamma=G, iters=4)
    pipe = run_sweep(specs, t_f, grid, schedule=PipelinedAllReduce(0.5),
                     **kw)
    assert not pipe.used_engine.any()
    assert float(pipe.span[0, 0, 0]) < \
        float(pipe.t_iter[0, 0, 0].sum()) - 1e-12
    bsp = run_sweep(specs, t_f, grid, schedule=BSP(), **kw)
    assert float(bsp.span[0, 0, 0]) == \
        pytest.approx(float(bsp.t_iter[0, 0, 0].sum()), abs=1e-12)
