"""HLO cost parser: validated against XLA's own cost_analysis on loop-free
programs; trip-count scaling validated against manual unrolling."""

import jax
import jax.numpy as jnp
import pytest

from repro.utils import hlo


def _flops(fn, *specs):
    comp = jax.jit(fn).lower(*specs).compile()
    return comp, hlo.analyze(comp.as_text())


def test_matches_xla_on_loop_free():
    d = 128

    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    x = jax.ShapeDtypeStruct((64, d), jnp.float32)
    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    comp, ours = _flops(f, x, w, w)
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # old JAX: one properties dict per partition
        ca = ca[0] if ca else {}
    xla = ca["flops"]
    # dot flops dominate; ours counts only dots, XLA adds elementwise
    assert ours.dot_flops == pytest.approx(2 * 2 * 64 * d * d)
    assert abs(ours.dot_flops - xla) / xla < 0.01


def test_scan_trip_count_scaling():
    d, L = 64, 7

    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c.sum()

    def unrolled(x, ws):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x.sum()

    x = jax.ShapeDtypeStruct((32, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    _, s = _flops(scanned, x, ws)
    _, u = _flops(unrolled, x, ws)
    assert s.flops == pytest.approx(u.flops, rel=0.01)
    assert s.flops == pytest.approx(2 * 32 * d * d * L, rel=0.01)


def test_nested_scan():
    d = 32

    def f(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        c, _ = jax.lax.scan(outer, x, ws)
        return c.sum()

    x = jax.ShapeDtypeStruct((8, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, d, d), jnp.float32)
    _, c = _flops(f, x, ws)
    assert c.flops == pytest.approx(2 * 8 * d * d * 3 * 4, rel=0.02)


def test_tuple_typed_while_ops_parsed():
    """Regression: tuple output types contain spaces + /*index=N*/ comments
    which previously defeated the op regex."""
    line = ("  %while.319 = (s32[], f32[8,1,1,4096]{3,2,1,0}, "
            "/*index=5*/f32[8,4096]{1,0}) while(%tuple.1), "
            "condition=%cond.1, body=%body.1")
    m = hlo._OP_RE.match(line)
    assert m is not None
    assert m.group(3) == "while"


def test_shape_bytes():
    assert hlo._shape_bytes("f32[8,4]{1,0}") == 128
    assert hlo._shape_bytes("bf16[10]") == 20
    assert hlo._shape_bytes("(s32[], f32[4])") == 20
    assert hlo._shape_bytes("pred[3]") == 3


def test_collective_bytes_empty():
    assert hlo.collective_bytes("") == {}
