"""Fleet backend (repro.sim.fleet) correctness.

The contract: on the closed form's validity domain a whole batch of
scenario cases — mixed schedules, mixed bucket counts, hierarchical
models, jittered/heterogeneous fleets — evaluated in ONE jitted call
equals the per-point numpy closed forms AND the event engine to 1e-9,
regardless of how the batch is padded or composed.  Randomized
pad-invariance and recurrence-equality properties live in
tests/test_fleet_props.py (hypothesis).
"""

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.coplanner import CoPlanner
from repro.core.cost_model import AllReduceModel, PathModel, PathPhase
from repro.core.planner import MergePlan, make_plan
from repro.core.simulator import simulate, spec_arrays
from repro.obs.metrics import REGISTRY
from repro.sim import scenarios, trace
from repro.sim.coplan_profiles import make_fleet_jobs
from repro.sim.fleet import (FleetEvaluator, evaluate_cases,
                             fleet_available, make_case)
from repro.sim.schedules import (BSP, DAGSchedule, LocalSGD, OneFoneB,
                                 PipelinedAllReduce)
from repro.sim.sweep import SweepGrid, run_sweep

A, B, G = scenarios.PAPER_ALPHA, scenarios.PAPER_BETA, scenarios.PAPER_GAMMA

# each schedule kind on its exactness domain (jitter only where the
# FleetForm says heterogeneous_ok), mirroring tests/test_sweep.py
SCHEDULE_POINTS = [
    (None, 0.25),
    (BSP(), 0.25),
    (OneFoneB(4), 0.25),
    (PipelinedAllReduce(0.5), 0.0),
    (LocalSGD(3), 0.0),
    (PipelinedAllReduce(0.0), 0.25),  # degenerates: BSP with jitter
    (LocalSGD(1), 0.25),
]
_IDS = [f"{'bsp' if s is None else s.label}-j{j:g}"
        for s, j in SCHEDULE_POINTS]


def test_fleet_available():
    assert fleet_available()


@pytest.mark.parametrize("schedule,jitter", SCHEDULE_POINTS, ids=_IDS)
def test_fleet_backend_matches_numpy_and_engine(schedule, jitter):
    """backend='fleet' == backend='numpy' == engine, t_iter AND span."""
    specs, t_f = trace.synthetic_specs(18, seed=21)
    grid = SweepGrid(n_workers=(4, 16), bandwidth_scales=(0.5, 2.0),
                     seeds=(0, 2))
    slow = {0: 1.5} if jitter else None
    kw = dict(alpha=A, beta=B, gamma=G, iters=5, jitter_sigma=jitter,
              slow=slow, schedule=schedule)
    fl = run_sweep(specs, t_f, grid, backend="fleet", **kw)
    np_ = run_sweep(specs, t_f, grid, backend="numpy", **kw)
    eng = run_sweep(specs, t_f, grid, force_engine=True, **kw)
    assert fl.backend == "fleet" and not fl.used_engine.any()
    assert np_.backend == "numpy"
    np.testing.assert_allclose(fl.t_iter, np_.t_iter, atol=1e-9)
    np.testing.assert_allclose(fl.span, np_.span, atol=1e-9)
    np.testing.assert_allclose(fl.t_iter, eng.t_iter, atol=1e-9)
    np.testing.assert_allclose(fl.span, eng.span, atol=1e-9)


def test_backend_dispatch_never_changes_fallback_domain():
    """Points off the closed-form domain go to the engine no matter the
    backend, and both backends report them identically."""
    specs, t_f = trace.synthetic_specs(10, seed=22)
    grid = SweepGrid(n_workers=(4, 8))
    kw = dict(alpha=A, beta=B, gamma=G, iters=3, jitter_sigma=0.2,
              schedule=LocalSGD(3))   # homogeneous-only + jitter
    fl = run_sweep(specs, t_f, grid, backend="fleet", **kw)
    np_ = run_sweep(specs, t_f, grid, backend="numpy", **kw)
    assert fl.used_engine.all() and np_.used_engine.all()
    assert fl.backend == "engine" and np_.backend == "engine"
    assert fl.fallback_points == np_.fallback_points \
        == grid.shape[0] * grid.shape[1] * len(grid.seeds)
    np.testing.assert_allclose(fl.t_iter, np_.t_iter, atol=1e-9)


def test_fallback_counter_increments():
    specs, t_f = trace.synthetic_specs(8, seed=3)
    grid = SweepGrid(n_workers=(4,), seeds=(0, 1))
    c = REGISTRY.counter("sweep_fallback_points_total", "")
    before = c.value(reason="forced", schedule="bsp")
    res = run_sweep(specs, t_f, grid, alpha=A, beta=B, gamma=G, iters=2,
                    force_engine=True)
    assert res.fallback_points == 2
    assert c.value(reason="forced", schedule="bsp") == before + 2
    clean = run_sweep(specs, t_f, grid, alpha=A, beta=B, gamma=G, iters=2)
    assert clean.fallback_points == 0
    assert c.value(reason="forced", schedule="bsp") == before + 2


def test_auto_backend_thresholds():
    """auto == numpy below the element threshold, fleet above; results
    identical either way."""
    specs, t_f = trace.synthetic_specs(12, seed=4)
    small = run_sweep(specs, t_f, SweepGrid(n_workers=(4,)), alpha=A,
                      beta=B, gamma=G, iters=2)
    assert small.backend == "numpy"
    grid = SweepGrid(n_workers=(4, 8, 16, 32),
                     bandwidth_scales=(0.5, 1.0, 2.0, 4.0),
                     seeds=(0, 1))
    auto = run_sweep(specs, t_f, grid, alpha=A, beta=B, gamma=G, iters=16)
    assert auto.backend == "fleet"
    np_ = run_sweep(specs, t_f, grid, alpha=A, beta=B, gamma=G, iters=16,
                    backend="numpy")
    np.testing.assert_allclose(auto.t_iter, np_.t_iter, atol=1e-9)


def test_backend_validation():
    specs, t_f = trace.synthetic_specs(6, seed=5)
    with pytest.raises(ValueError, match="backend"):
        run_sweep(specs, t_f, SweepGrid(n_workers=(4,)), alpha=A, beta=B,
                  gamma=G, backend="cuda")


# ---------------------------------------------------------------------------
# Direct case-level batching.
# ---------------------------------------------------------------------------

def _barrier_reference(specs, t_f, plan, model):
    """simulate()'s absolute comm timeline (t_f + relative recurrence)."""
    return simulate(specs, plan, model, t_f).t_iter


def test_mixed_batch_equals_singletons():
    """A heterogeneous batch — every schedule kind, ragged bucket counts,
    a PathModel — scores each case exactly as a singleton batch does."""
    cases = []
    for i, (schedule, _) in enumerate(SCHEDULE_POINTS):
        specs, t_f = trace.synthetic_specs(6 + 5 * i, seed=i)
        model = AllReduceModel(1e-4 * (i + 1), 4e-9) if i % 2 else \
            PathModel((PathPhase("ici", 1e-5, 1e-10),
                       PathPhase("dcn", 2e-4, 5e-11, 0.25)))
        plan = make_plan("wfbp" if i % 2 else "mgwfbp", specs, model)
        cases.append(make_case(specs, plan, model, schedule=schedule,
                               t_f=t_f))
    batched = evaluate_cases(cases, iters=4)
    for ci, c in enumerate(cases):
        single = evaluate_cases([c], iters=4)
        np.testing.assert_array_equal(batched.t_iter[ci],
                                      single.t_iter[0])
        np.testing.assert_array_equal(batched.span[ci], single.span[0])


def test_case_batch_matches_engine_closed_form():
    """Case-level evaluation equals simulate() for BSP cases (the Eq. 7/8
    oracle), including a hierarchical model through as_linear."""
    for seed, model in ((0, AllReduceModel(2e-4, 5e-9)),
                        (1, PathModel((PathPhase("ici", 1e-5, 1e-10),
                                       PathPhase("dcn", 2e-4, 5e-11,
                                                 0.25))))):
        specs, t_f = trace.synthetic_specs(14, seed=seed)
        plan = make_plan("mgwfbp", specs, model)
        res = evaluate_cases([make_case(specs, plan, model, t_f=t_f)])
        ref = _barrier_reference(specs, t_f, plan, model)
        np.testing.assert_allclose(res.t_iter[0, 0, 0], ref, atol=1e-12)


def test_zero_byte_bucket_gates_but_costs_nothing():
    """A real zero-byte bucket has zero duration yet its ready time still
    gates the recurrence — distinct from a masked padding row."""
    from repro.core.planner import TensorSpec
    specs = [TensorSpec("t0", 1 << 20, 1e-3),
             TensorSpec("t1", 0, 5e-3),        # zero bytes, late ready
             TensorSpec("t2", 1 << 20, 1e-3)]
    model = AllReduceModel(1e-3, 1e-9)
    plan = MergePlan(((0,), (1,), (2,)))
    res = evaluate_cases([make_case(specs, plan, model, t_f=0.0)])
    ref = simulate(specs, plan, model, 0.0).t_iter
    np.testing.assert_allclose(res.t_iter[0, 0, 0], ref, atol=1e-12)
    # the zero-byte bucket charged nothing: dropping it entirely is
    # cheaper or equal, never more expensive
    assert model.time(0) == 0.0


def test_make_case_validations():
    specs, t_f = trace.synthetic_specs(8, seed=7)
    model = AllReduceModel(1e-4, 1e-9)
    plan = make_plan("wfbp", specs, model)
    with pytest.raises(ValueError, match="no fleet form"):
        make_case(specs, plan, model, schedule=DAGSchedule())
    with pytest.raises(ValueError, match="covers"):
        make_case(specs[:-1], plan, model)
    with pytest.raises(ValueError, match="shaped"):
        make_case(specs, plan, model, s_max=np.ones(3))
    with pytest.raises(ValueError, match="homogeneous-only"):
        make_case(specs, plan, model, schedule=LocalSGD(3),
                  s_max=np.full((1, 2), 1.5))
    # barrier forms accept heterogeneity
    make_case(specs, plan, model, schedule=OneFoneB(4),
              s_max=np.full((1, 2), 1.5))


def test_evaluate_cases_validations():
    specs, t_f = trace.synthetic_specs(8, seed=7)
    model = AllReduceModel(1e-4, 1e-9)
    case = make_case(specs, make_plan("wfbp", specs, model), model)
    with pytest.raises(ValueError, match=">= 1 case"):
        evaluate_cases([])
    with pytest.raises(ValueError, match=">= 1 iteration"):
        evaluate_cases([case], iters=0)
    mk = lambda s: make_case(specs, make_plan("wfbp", specs, model),
                             model, s_max=s)
    with pytest.raises(ValueError, match="iterations"):
        evaluate_cases([mk(np.ones((1, 3)))], iters=2)
    with pytest.raises(ValueError, match="seed counts"):
        evaluate_cases([mk(np.ones((2, 2))), mk(np.ones((3, 2)))],
                       iters=2)


def test_geometry_cache_reused_across_models():
    specs, t_f = trace.synthetic_specs(10, seed=9)
    m1, m2 = AllReduceModel(1e-4, 1e-9), AllReduceModel(2e-4, 8e-9)
    plan = make_plan("wfbp", specs, m1)
    cache: dict = {}
    c1 = make_case(specs, plan, m1, cache=cache)
    assert len(cache) == 1
    c2 = make_case(specs, make_plan("wfbp", specs, m2), m2, cache=cache)
    assert len(cache) == 1                    # same structure: one entry
    assert c1.bucket_bytes is c2.bucket_bytes   # memoized geometry
    ref = evaluate_cases([make_case(specs, plan, m2)]).t_iter
    np.testing.assert_array_equal(evaluate_cases([c2]).t_iter, ref)


# ---------------------------------------------------------------------------
# Co-planner integration.
# ---------------------------------------------------------------------------

def test_fleet_evaluator_call_equals_batch():
    jobs = make_fleet_jobs(6)
    ev = FleetEvaluator(jobs, iters=4)
    plans = {j.name: j.seed_plans[0] for j in jobs}
    one = ev(plans)
    many = ev.batch([plans, plans])
    for obs in many:
        assert obs.makespan == one.makespan
        for name in plans:
            assert obs.jobs[name].t_iter == one.jobs[name].t_iter
            assert obs.jobs[name].samples == one.jobs[name].samples


def test_coplanner_batched_equals_sequential():
    """CoPlanner routed through FleetEvaluator.batch converges to the
    identical result as the same evaluator stripped of its batch hook,
    and the batched-evals counter moves."""
    jobs = make_fleet_jobs(8, seed=3)
    ev = FleetEvaluator(jobs, iters=4)
    c = REGISTRY.counter("coplanner_batched_evals_total", "")
    before = c.value()
    res_b = CoPlanner(jobs, ev, max_rounds=2).run()
    assert c.value() > before
    res_s = CoPlanner(jobs, lambda p: ev(p), max_rounds=2).run()
    assert res_b.makespan == res_s.makespan
    assert res_b.best_round == res_s.best_round
    assert len(res_b.rounds) == len(res_s.rounds)
    assert {n: p.buckets for n, p in res_b.plans.items()} == \
        {n: p.buckets for n, p in res_s.plans.items()}
    # the co-plan never loses to a static seed baseline
    seed_best = min(r.makespan for r in res_b.rounds if r.kind == "seed")
    assert res_b.makespan <= seed_best + 1e-12


def test_fleet_evaluator_mixed_schedules_match_schedule_forms():
    """Each job's observed t_iter equals its own schedule's closed form
    (span/iters), not some batch-averaged value."""
    jobs = make_fleet_jobs(4, seed=11)   # one of each schedule kind
    iters = 6
    ev = FleetEvaluator(jobs, iters=iters)
    plans = {j.name: j.seed_plans[0] for j in jobs}
    obs = ev(plans)
    for j in jobs:
        case = make_case(j.specs, plans[j.name], j.model,
                         schedule=j.schedule, t_f=j.t_f)
        span = float(evaluate_cases([case], iters=iters).span[0, 0])
        assert obs.jobs[j.name].t_iter == pytest.approx(span / iters,
                                                        abs=1e-15)
    assert obs.makespan == pytest.approx(
        max(o.t_iter for o in obs.jobs.values()) * iters, abs=1e-12)


def test_make_fleet_jobs_validation_and_determinism():
    with pytest.raises(ValueError):
        make_fleet_jobs(0)
    a = make_fleet_jobs(5, seed=2)
    b = make_fleet_jobs(5, seed=2)
    assert [j.name for j in a] == [j.name for j in b]
    for ja, jb in zip(a, b):
        assert ja.specs == jb.specs
        assert ja.seed_plans[0].buckets == jb.seed_plans[0].buckets
