"""Co-planner property tests; skipped without the real hypothesis package.

Three families:

* the alternating best-response loop always terminates within its round
  budget (seed rounds bounded by the seed-plan count + 1, response
  rounds by jobs x max_rounds) on random multi-job problems;
* the returned assignment's observed joint makespan is never worse than
  any seed candidate's — the no-worse-than-seed guarantee, for any
  deterministic evaluation environment;
* per-job link telemetry conserves: each job's byte account equals what
  it communicated, per-owner byte totals sum to everything admitted, and
  per-owner bandwidth shares (background included) sum to the link's
  busy wall time — on random two-job engine runs with random bursts.
"""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
from prop_strategies import mk_specs, specs_strategy  # noqa: E402

from repro.core.coplanner import (CoJob, CoObservation,  # noqa: E402
                                  JobObservation, coplan)
from repro.core.cost_model import AllReduceModel  # noqa: E402
from repro.core.planner import make_plan, plan_wfbp  # noqa: E402
from repro.core.simulator import simulate  # noqa: E402
from repro.sim import scenarios, trace  # noqa: E402
from repro.sim.network import Burst  # noqa: E402

MODEL = AllReduceModel(5e-4, 2e-9)
JOBS = st.lists(specs_strategy(min_n=1, max_n=6), min_size=1, max_size=3)


def _make_jobs(profiles):
    jobs = []
    for i, sizes_times in enumerate(profiles):
        specs = tuple(mk_specs(*sizes_times))
        jobs.append(CoJob(
            name=f"j{i}", specs=specs, model=MODEL, t_f=1e-3,
            seed_plans=(make_plan("mgwfbp", specs, MODEL),
                        plan_wfbp(specs))))
    return jobs


def _synthetic_evaluate(jobs):
    """Deterministic contended world without the engine: each job's
    effective model stretches with the *other* jobs' bucket counts (more
    neighbour collectives -> more contention), and the observation is
    the Eq. 7/8 closed form under that stretched model."""
    def evaluate(plans):
        out = {}
        for j in jobs:
            others = sum(plans[o.name].num_buckets
                         for o in jobs if o.name != j.name)
            stretch = 1.0 + 0.15 * others
            eff = j.model.scaled(stretch)
            t = simulate(j.specs, plans[j.name], eff, j.t_f).t_iter
            samples = tuple(
                (nb, eff.time(nb))
                for nb in plans[j.name].bucket_bytes(j.specs))
            out[j.name] = JobObservation(t_iter=t, samples=samples)
        return CoObservation(makespan=max(o.t_iter for o in out.values()),
                             jobs=out)
    return evaluate


@hypothesis.given(JOBS, st.integers(1, 4),
                  st.floats(0.1, 1.0))
@hypothesis.settings(max_examples=40, deadline=None)
def test_terminates_within_round_budget(profiles, max_rounds, damping):
    jobs = _make_jobs(profiles)
    fix = coplan(jobs, _synthetic_evaluate(jobs), max_rounds=max_rounds,
                 damping=damping)
    seed_rounds = [r for r in fix.rounds if r.kind == "seed"]
    response_rounds = [r for r in fix.rounds if r.kind == "response"]
    n_seeds = sum(len(j.seed_plans) for j in jobs)
    assert len(seed_rounds) <= n_seeds + 1      # + combined assignment
    assert len(response_rounds) <= len(jobs) * max_rounds
    assert 0 <= fix.best_round < len(fix.rounds)


@hypothesis.given(JOBS, st.floats(0.1, 1.0))
@hypothesis.settings(max_examples=40, deadline=None)
def test_makespan_never_worse_than_seed_candidates(profiles, damping):
    jobs = _make_jobs(profiles)
    fix = coplan(jobs, _synthetic_evaluate(jobs), damping=damping)
    seed_rounds = [r for r in fix.rounds if r.kind == "seed"]
    assert seed_rounds
    assert fix.makespan <= min(r.makespan for r in seed_rounds) + 1e-12
    # the result is the best observed round, full stop
    assert fix.makespan <= min(r.makespan for r in fix.rounds) + 1e-15


@hypothesis.given(specs_strategy(min_n=1, max_n=5),
                  specs_strategy(min_n=1, max_n=5),
                  st.integers(1, 2), st.integers(0, 3),
                  st.sampled_from(["wfbp", "single", "mgwfbp"]))
@hypothesis.settings(max_examples=30, deadline=None)
def test_link_telemetry_conserves(prof_a, prof_b, iters, burst_flows,
                                  strategy):
    specs_a, specs_b = mk_specs(*prof_a), mk_specs(*prof_b)
    bursts = [Burst("net", 0.0, 5.0, flows=burst_flows)] \
        if burst_flows else []
    jobs = [scenarios.CoJobSpec("a", tuple(specs_a), 1e-3,
                                strategy=strategy),
            scenarios.CoJobSpec("b", tuple(specs_b), 2e-3,
                                strategy=strategy)]
    sim = scenarios.shared_link_jobs(jobs, n_workers=2, iters=iters,
                                     bursts=bursts)
    res = sim.run()
    link = sim.links["net"]
    total_bytes = 0.0
    for name in ("a", "b"):
        jr = res.job(name)
        tele = jr.link_telemetry
        got = tele.get("net", (0.0, 0.0))[0]
        assert got == pytest.approx(jr.bytes_communicated, abs=1e-6)
        total_bytes += got
    assert sum(link.owner_bytes.values()) == \
        pytest.approx(total_bytes, abs=1e-6)
    assert sum(link.owner_busy.values()) == \
        pytest.approx(link.busy_s, abs=1e-9)
