"""Property-based obs invariants (skipped cleanly without hypothesis).

* histogram merge is associative and exact (fixed exponential buckets:
  a merge is an integer bucket-count sum, so grouping cannot matter);
* snapshot deltas of monotone metrics are non-negative and re-merge to
  the later snapshot;
* flight-recorder JSONL round-trip is the identity on random records.
"""

import json

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.obs import metrics, recorder  # noqa: E402

finite = st.floats(min_value=1e-9, max_value=1e9, allow_nan=False,
                   allow_infinity=False)
times = st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
                  allow_infinity=False)


def _hist_snapshot(values):
    reg = metrics.Registry()
    h = reg.histogram("t", "prop")
    for v in values:
        h.observe(v)
    return reg.snapshot()


def _assert_hists_equal(x: dict | None, y: dict | None):
    """Bucket counts / count / min / max merge EXACTLY (integer sums and
    min/max are associative); the float running ``sum`` is only
    associative up to rounding, so it gets an isclose."""
    if x is None or y is None:
        assert x == y
        return
    for field in ("counts", "count", "min", "max"):
        assert x[field] == y[field], field
    assert x["sum"] == pytest.approx(y["sum"], rel=1e-12, abs=1e-12)


@given(st.lists(finite, max_size=30), st.lists(finite, max_size=30),
       st.lists(finite, max_size=30))
@settings(max_examples=50, deadline=None)
def test_histogram_merge_associative(xs, ys, zs):
    a, b, c = _hist_snapshot(xs), _hist_snapshot(ys), _hist_snapshot(zs)
    left = a.merge(b).merge(c).hist("t")
    right = a.merge(b.merge(c)).hist("t")
    _assert_hists_equal(left, right)
    if left is not None:
        assert left["count"] == len(xs) + len(ys) + len(zs)
        # bucket counts are exact integer sums of the parts
        assert sum(left["counts"].values()) == left["count"]


@given(st.lists(finite, min_size=1, max_size=20),
       st.lists(finite, max_size=20))
@settings(max_examples=50, deadline=None)
def test_snapshot_delta_nonnegative_and_remergeable(first, second):
    reg = metrics.Registry()
    c = reg.counter("n_total", "prop")
    h = reg.histogram("t", "prop")
    for v in first:
        c.inc(v)
        h.observe(v)
    early = reg.snapshot()
    for v in second:
        c.inc(v)
        h.observe(v)
    late = reg.snapshot()
    d = late.delta(early)
    assert d.value("n_total") >= 0.0
    dh = d.hist("t")
    assert dh["count"] == len(second) >= 0
    assert all(n >= 0 for n in dh["counts"].values())
    # merging the delta back reconstructs the later snapshot — exactly
    # for the integer state, to rounding for the float running sums
    rem = early.merge(d)
    assert rem.value("n_total") == \
        pytest.approx(late.value("n_total"), rel=1e-12, abs=1e-12)
    _assert_hists_equal(rem.hist("t"), late.hist("t"))


pairs = st.lists(st.tuples(st.text(alphabet="abcxyz", min_size=1,
                                   max_size=4), times),
                 max_size=3).map(tuple)

bucket_records = st.builds(
    recorder.BucketRecord,
    bucket=st.integers(min_value=0, max_value=99),
    nbytes=st.integers(min_value=0, max_value=1 << 40),
    ready=times, start=times, end=times, comm_s=times)

iteration_records = st.builds(
    recorder.IterationRecord,
    source=st.sampled_from(["sim", "train"]),
    job=st.text(alphabet="abcdef", min_size=1, max_size=6),
    iteration=st.integers(min_value=0, max_value=10**6),
    start=times, end=times, backward_end=times,
    staleness=st.integers(min_value=0, max_value=64),
    buckets=st.lists(bucket_records, max_size=4).map(tuple),
    worker_compute=pairs, worker_start=pairs, worker_end=pairs,
    link_bytes=pairs, link_busy=pairs,
    args=st.dictionaries(st.text(alphabet="abc", min_size=1, max_size=3),
                         st.one_of(times, st.text(max_size=8)),
                         max_size=3))

event_records = st.builds(
    recorder.EventRecord,
    kind=st.sampled_from(["planner_update", "coplan_round", "drift_alert"]),
    time=times,
    source=st.sampled_from(["sim", "planner", "coplanner", "train"]),
    job=st.text(alphabet="abcdef", max_size=6),
    args=st.dictionaries(st.text(alphabet="xyz", min_size=1, max_size=3),
                         times, max_size=3))


@given(st.lists(st.one_of(iteration_records, event_records), max_size=20))
@settings(max_examples=50, deadline=None)
def test_recorder_round_trip_identity(records, tmp_path_factory):
    path = tmp_path_factory.mktemp("obs") / "rec.jsonl"
    recorder.write_jsonl(str(path), records)
    back = recorder.read_jsonl(str(path))
    assert back == records
    # and the wire format itself is plain JSON lines
    with open(path) as f:
        for line in f:
            assert json.loads(line)["type"] in ("iteration", "event")
