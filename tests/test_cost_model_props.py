"""Cost-model property tests (paper Eq. 11); skipped without the real
hypothesis package."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402

from repro.core import cost_model as cm  # noqa: E402


@hypothesis.given(st.floats(1e-6, 1e-2), st.floats(1e-11, 1e-8),
                  st.integers(1, 1 << 26), st.integers(1, 1 << 26))
@hypothesis.settings(max_examples=100, deadline=None)
def test_merge_gain_is_startup(a, b, m1, m2):
    """Eq. 11: T(M1) + T(M2) - T(M1+M2) == a (super-additivity)."""
    m = cm.AllReduceModel(a, b)
    assert m.merge_gain(m1, m2) == pytest.approx(a, rel=1e-9)
