"""Checkpoint: roundtrip, atomic LATEST, async, resume semantics."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint
from repro.train.train_state import TrainState


def _state(v=1.0):
    return TrainState(step=jnp.int32(7),
                      params={"w": jnp.full((4, 4), v),
                              "b": jnp.arange(3.0)},
                      opt_state=[{"m": jnp.zeros(5), "v": jnp.ones(5)}])


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 7, _state(2.0))
    assert checkpoint.latest_step(d) == 7
    restored, step, _ = checkpoint.restore(d, _state(0.0))
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored.params["w"]), 2.0)
    np.testing.assert_allclose(np.asarray(restored.opt_state[0]["v"]), 1.0)


def test_latest_pointer_moves(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, _state(1.0))
    checkpoint.save(d, 2, _state(2.0))
    assert checkpoint.latest_step(d) == 2
    restored, step, _ = checkpoint.restore(d, _state(0.0))
    np.testing.assert_allclose(np.asarray(restored.params["w"]), 2.0)
    # older checkpoint still restorable explicitly
    old, step, _ = checkpoint.restore(d, _state(0.0), step=1)
    np.testing.assert_allclose(np.asarray(old.params["w"]), 1.0)


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, _state())
    bad = TrainState(step=jnp.int32(0),
                     params={"w": jnp.zeros((2, 2)), "b": jnp.zeros(3)},
                     opt_state=[{"m": jnp.zeros(5), "v": jnp.zeros(5)}])
    with pytest.raises(ValueError, match="shape mismatch"):
        checkpoint.restore(d, bad)


def test_missing_checkpoint(tmp_path):
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(str(tmp_path), _state())


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ck = checkpoint.AsyncCheckpointer(d)
    ck.save(5, _state(5.0))
    ck.save(6, _state(6.0))  # waits for 5 internally
    ck.wait()
    assert checkpoint.latest_step(d) == 6
    restored, step, _ = checkpoint.restore(d, _state(0.0))
    np.testing.assert_allclose(np.asarray(restored.params["w"]), 6.0)


def test_extra_metadata(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 3, _state(), extra={"data_position": 123})
    _, _, extra = checkpoint.restore(d, _state())
    assert extra == {"data_position": 123}


# ---------------------------------------------------------------------------
# Crash safety (the resilience PR's hardening)
# ---------------------------------------------------------------------------

class _Kill(Exception):
    """Simulated crash mid-save (not OSError: must not be swallowed)."""


def test_crash_during_save_at_every_kill_point(tmp_path, monkeypatch):
    """Kill the save at EVERY fsync/rename boundary: whatever survives
    on disk must restore to a complete checkpoint (the prior step, or
    the new one if it was already published), and the wreckage must be
    sweepable without touching complete steps."""
    real_replace, real_fsync = os.replace, os.fsync
    ops = {"n": 0, "kill_at": None}

    def _counted(fn):
        def wrapper(*a, **k):
            ops["n"] += 1
            if ops["kill_at"] is not None and ops["n"] >= ops["kill_at"]:
                raise _Kill(f"op {ops['n']}")
            return fn(*a, **k)
        return wrapper

    monkeypatch.setattr(os, "replace", _counted(real_replace))
    monkeypatch.setattr(os, "fsync", _counted(real_fsync))

    def save_counted(d, step, v, kill_at=None):
        ops["n"], ops["kill_at"] = 0, kill_at
        try:
            checkpoint.save(d, step, _state(v))
        finally:
            ops["kill_at"] = None

    probe = str(tmp_path / "probe")
    os.makedirs(probe)
    save_counted(probe, 1, 1.0)
    total = ops["n"]
    assert total >= 5  # shard, meta, publish rename, LATEST, dir syncs

    for k in range(1, total + 1):
        d = str(tmp_path / f"kp{k:02d}")
        os.makedirs(d)
        save_counted(d, 1, 1.0)  # a known-good prior checkpoint
        with pytest.raises(_Kill):
            save_counted(d, 2, 2.0, kill_at=k)
        step = checkpoint.latest_step(d)
        assert step in (1, 2), f"kill point {k} lost all checkpoints"
        restored, got, _ = checkpoint.restore(d, _state(0.0))
        assert got == step
        np.testing.assert_allclose(np.asarray(restored.params["w"]),
                                   float(step))
        checkpoint.clean_stale_tmp(d)
        left = os.listdir(d)
        assert not any(n.startswith(".tmp_") or n == ".LATEST.tmp"
                       for n in left), f"kill point {k} left wreckage"
        assert checkpoint.latest_step(d) == step  # sweep kept the data


def test_latest_step_falls_back_to_scan(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 4, _state(4.0))
    checkpoint.save(d, 9, _state(9.0))
    # LATEST pointing at a tag that never landed (crash between the
    # step-dir rename and the LATEST update)
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("step_00000012")
    assert checkpoint.latest_step(d) == 9
    # LATEST missing entirely
    os.remove(os.path.join(d, "LATEST"))
    assert checkpoint.latest_step(d) == 9
    restored, step, _ = checkpoint.restore(d, _state(0.0))
    assert step == 9
    np.testing.assert_allclose(np.asarray(restored.params["w"]), 9.0)


def test_scan_steps_ignores_torn_directories(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 2, _state())
    os.makedirs(os.path.join(d, "step_00000005"))  # no meta.json: torn
    assert checkpoint.scan_steps(d) == [2]
    assert checkpoint.latest_step(d) == 2


def test_clean_stale_tmp(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, _state())
    os.makedirs(os.path.join(d, ".tmp_step_00000002"))
    with open(os.path.join(d, ".LATEST.tmp"), "w") as f:
        f.write("step_00000002")
    removed = checkpoint.clean_stale_tmp(d)
    assert len(removed) == 2
    assert sorted(os.listdir(d)) == ["LATEST", "step_00000001"]


def test_gc_keep_last(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(d, s, _state(float(s)))
    with pytest.raises(ValueError):
        checkpoint.gc_keep_last(d, 0)
    assert checkpoint.gc_keep_last(d, 2) == [1, 2, 3]
    assert checkpoint.scan_steps(d) == [4, 5]
    assert checkpoint.latest_step(d) == 5


def test_gc_never_collects_latest_tag(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3):
        checkpoint.save(d, s, _state(float(s)))
    # LATEST pinned to an older tag (e.g. an operator rollback)
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("step_00000001")
    removed = checkpoint.gc_keep_last(d, 1)
    assert removed == [2]
    assert checkpoint.scan_steps(d) == [1, 3]


def test_async_checkpointer_keep_last_and_sweep(tmp_path):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, ".tmp_step_00000099"))  # prior crash
    ck = checkpoint.AsyncCheckpointer(d, keep_last=2)
    assert not os.path.exists(os.path.join(d, ".tmp_step_00000099"))
    for s in (1, 2, 3, 4):
        ck.save(s, _state(float(s)))
    ck.wait()
    assert checkpoint.scan_steps(d) == [3, 4]
    with pytest.raises(ValueError):
        checkpoint.AsyncCheckpointer(d, keep_last=0)
