"""Checkpoint: roundtrip, atomic LATEST, async, resume semantics."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint
from repro.train.train_state import TrainState


def _state(v=1.0):
    return TrainState(step=jnp.int32(7),
                      params={"w": jnp.full((4, 4), v),
                              "b": jnp.arange(3.0)},
                      opt_state=[{"m": jnp.zeros(5), "v": jnp.ones(5)}])


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 7, _state(2.0))
    assert checkpoint.latest_step(d) == 7
    restored, step, _ = checkpoint.restore(d, _state(0.0))
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored.params["w"]), 2.0)
    np.testing.assert_allclose(np.asarray(restored.opt_state[0]["v"]), 1.0)


def test_latest_pointer_moves(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, _state(1.0))
    checkpoint.save(d, 2, _state(2.0))
    assert checkpoint.latest_step(d) == 2
    restored, step, _ = checkpoint.restore(d, _state(0.0))
    np.testing.assert_allclose(np.asarray(restored.params["w"]), 2.0)
    # older checkpoint still restorable explicitly
    old, step, _ = checkpoint.restore(d, _state(0.0), step=1)
    np.testing.assert_allclose(np.asarray(old.params["w"]), 1.0)


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, _state())
    bad = TrainState(step=jnp.int32(0),
                     params={"w": jnp.zeros((2, 2)), "b": jnp.zeros(3)},
                     opt_state=[{"m": jnp.zeros(5), "v": jnp.zeros(5)}])
    with pytest.raises(ValueError, match="shape mismatch"):
        checkpoint.restore(d, bad)


def test_missing_checkpoint(tmp_path):
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(str(tmp_path), _state())


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ck = checkpoint.AsyncCheckpointer(d)
    ck.save(5, _state(5.0))
    ck.save(6, _state(6.0))  # waits for 5 internally
    ck.wait()
    assert checkpoint.latest_step(d) == 6
    restored, step, _ = checkpoint.restore(d, _state(0.0))
    np.testing.assert_allclose(np.asarray(restored.params["w"]), 6.0)


def test_extra_metadata(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 3, _state(), extra={"data_position": 123})
    _, _, extra = checkpoint.restore(d, _state())
    assert extra == {"data_position": 123}
