"""Shared hypothesis strategies for the property-test modules.

Only imported from ``tests/test_*_props.py`` modules, each of which runs
``pytest.importorskip("hypothesis")`` before importing this file — so a
missing hypothesis package skips the property tests cleanly (the real
package is installed on every CI leg; one leg exercises this skip path).
"""

import hypothesis.strategies as st

from repro.core.planner import TensorSpec


def mk_specs(sizes, times):
    return [TensorSpec(f"t{i}", s, t) for i, (s, t) in
            enumerate(zip(sizes, times))]


def specs_strategy(min_n=1, max_n=8, min_bytes=1, max_bytes=1 << 22,
                   min_t=1e-6, max_t=5e-3):
    """(sizes, times) pairs in backward order."""
    return st.integers(min_n, max_n).flatmap(
        lambda n: st.tuples(
            st.lists(st.integers(min_bytes, max_bytes),
                     min_size=n, max_size=n),
            st.lists(st.floats(min_t, max_t), min_size=n, max_size=n)))


def model_strategy(min_a=0.0, max_a=2e-3, min_b=1e-11, max_b=1e-8):
    """(a, b) all-reduce cost-model parameters."""
    return st.tuples(st.floats(min_a, max_a), st.floats(min_b, max_b))
