"""Multi-job co-planning (repro.core.coplanner) correctness.

Anchors:

* **N=1 delegation** — `plan_contention_aware` is the single-job special
  case of `CoPlanner`; a verbatim reimplementation of the PR-2 fixpoint
  loop pins the equivalence round for round, float for float (on top of
  the pre-existing fixpoint tests, which keep passing unchanged);
* **2–4-job joint planning** — the alternating best-response loop
  terminates within its round budget and the best observed assignment is
  never worse than any seed candidate (per-job baselines AND the fully
  independent assignment) on joint makespan;
* **cross-schedule rounds** — per-job predictions use each job's own
  schedule closed form;
* **link-owner telemetry** — per-job bytes/busy sum to link totals, and
  background Burst traffic is accounted under its reserved owner, never
  in a job's samples.
"""

import pytest

from repro.core import coplanner, cost_model
from repro.core.coplanner import (CoJob, CoObservation, CoPlanner,
                                  JobObservation, coplan)
from repro.core.cost_model import AllReduceModel
from repro.core.planner import (Planner, effective_model, make_plan,
                                plan_contention_aware, plan_wfbp)
from repro.sim import scenarios, trace
from repro.sim.engine import ClusterSim, JobSpec, Topology
from repro.sim.network import BACKGROUND_OWNER, Burst, FlatTopology
from repro.sim.schedules import BSP, LocalSGD, PipelinedAllReduce
from repro.sim.scenarios import CoJobSpec
from repro.sim.workers import make_workers

MODEL = AllReduceModel(5e-4, 2e-9)


def _single_job_evaluate(specs, t_f, *, n_workers=4, bursts=()):
    """Engine evaluation of one job's candidate plan (optionally against
    background bursts, so the fixpoint has contention to correct for)."""
    def evaluate(plan):
        job = JobSpec(name="j", specs=list(specs), plan=plan, t_f=t_f,
                      workers=make_workers(n_workers),
                      topology=Topology(MODEL, n_workers=n_workers))
        jr = ClusterSim([job], bursts=list(bursts)).run().job("j")
        return jr.iterations[-1].t_iter, jr.bucket_samples
    return evaluate


def _reference_fixpoint(specs, model, evaluate, *, t_f=0.0, max_rounds=5,
                        damping=0.5, seed_plans=()):
    """The PR-2 single-job loop, reimplemented verbatim — the oracle the
    N=1 delegation must reproduce float for float."""
    from repro.core.simulator import simulate

    planner_ = Planner(specs, model)
    plan = planner_.plan()
    eff = model
    rounds = []          # (plan, model, observed, predicted, planned_under)
    best_round = 0
    cache = {}

    def observe(p):
        if p.buckets not in cache:
            cache[p.buckets] = evaluate(p)
        return cache[p.buckets]

    def push(entry):
        nonlocal best_round
        rounds.append(entry)
        if entry[2] < rounds[best_round][2]:
            best_round = len(rounds) - 1

    def predict(p, m):
        return simulate(specs, p, m, t_f).t_iter

    for sp in seed_plans:
        observed, _ = observe(sp)
        push((sp, eff, observed, predict(sp, eff), eff))
    seen = {plan.buckets}
    converged = False
    for _ in range(max_rounds):
        planned_under = eff
        observed, samples = observe(plan)
        fitted = effective_model(samples, eff)
        eff = cost_model.blend(eff, fitted, damping)
        push((plan, eff, observed, predict(plan, eff), planned_under))
        new_plan = planner_.replan(eff)
        if new_plan.buckets == plan.buckets or new_plan.buckets in seen:
            converged = True
            break
        seen.add(new_plan.buckets)
        plan = new_plan
    return rounds, best_round, converged


# ---------------------------------------------------------------------------
# N=1 delegation.
# ---------------------------------------------------------------------------

def test_n1_reproduces_reference_loop_bit_for_bit():
    """plan_contention_aware (now the N=1 CoPlanner) equals the verbatim
    PR-2 loop — same rounds, same floats, same best round — on a
    contended evaluation where the refit actually moves the model."""
    specs, t_f = trace.synthetic_specs(24, seed=33)
    bursts = (Burst("net", 0.0, 10.0, flows=2),)
    seeds = (make_plan("mgwfbp", specs, MODEL), plan_wfbp(specs))
    fix = plan_contention_aware(
        specs, MODEL, _single_job_evaluate(specs, t_f, bursts=bursts),
        t_f=t_f, damping=0.4, seed_plans=seeds)
    ref_rounds, ref_best, ref_conv = _reference_fixpoint(
        specs, MODEL, _single_job_evaluate(specs, t_f, bursts=bursts),
        t_f=t_f, damping=0.4, seed_plans=seeds)
    assert len(fix.rounds) == len(ref_rounds)
    assert fix.best_round == ref_best
    assert fix.converged == ref_conv
    for got, (plan, model, observed, predicted, planned_under) in \
            zip(fix.rounds, ref_rounds):
        assert got.plan.buckets == plan.buckets
        assert (got.model.a, got.model.b) == (model.a, model.b)
        assert got.observed_t == observed              # exact, no tolerance
        assert got.predicted_t == predicted
        assert (got.planned_under.a, got.planned_under.b) == \
            (planned_under.a, planned_under.b)
    assert fix.plan.buckets == ref_rounds[ref_best][0].buckets


def test_n1_coplanner_equals_plan_contention_aware():
    """Driving CoPlanner directly with one CoJob gives the same result as
    the plan_contention_aware wrapper."""
    specs, t_f = trace.synthetic_specs(18, seed=34)
    bursts = (Burst("net", 0.0, 5.0, flows=3),)
    evaluate = _single_job_evaluate(specs, t_f, bursts=bursts)
    fix = plan_contention_aware(specs, MODEL, evaluate, t_f=t_f)

    def joint_evaluate(plans):
        observed, samples = evaluate(plans["job"])
        return CoObservation(makespan=observed, jobs={
            "job": JobObservation(t_iter=observed, samples=tuple(samples))})

    co = coplan([CoJob(name="job", specs=tuple(specs), model=MODEL,
                       t_f=t_f)], joint_evaluate)
    alt = co.fixpoint("job")
    assert alt.plan.buckets == fix.plan.buckets
    assert (alt.model.a, alt.model.b) == (fix.model.a, fix.model.b)
    assert [r.observed_t for r in alt.rounds] == \
        [r.observed_t for r in fix.rounds]
    assert (alt.best_round, alt.converged) == \
        (fix.best_round, fix.converged)
    # the joint view agrees with the per-job view for a single job
    assert co.makespan == fix.observed_t
    assert co.observed_t("job") == fix.observed_t


# ---------------------------------------------------------------------------
# Joint planning: 2-4 jobs.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_jobs", [2, 3, 4])
def test_joint_terminates_and_never_loses_to_seeds(n_jobs):
    """The alternating best-response loop stays within its round budget
    and the returned assignment's observed joint makespan is <= every
    seed candidate's — including the fully independent assignment."""
    jobs = []
    for i in range(n_jobs):
        specs, t_f = trace.synthetic_specs(10 + 4 * i, seed=40 + i)
        jobs.append(CoJobSpec(f"job{i}", tuple(specs), t_f))
    max_rounds = 4
    fix = scenarios.contended_jobs_plan(jobs, n_workers=4, iters=2,
                                        max_rounds=max_rounds)
    seed_rounds = [r for r in fix.rounds if r.kind == "seed"]
    response_rounds = [r for r in fix.rounds if r.kind == "response"]
    assert seed_rounds and response_rounds
    # budget: one seed round per job (+1 combined) + n_jobs per sweep
    assert len(seed_rounds) <= n_jobs + 1
    assert len(response_rounds) <= n_jobs * max_rounds
    assert fix.makespan <= min(r.makespan for r in seed_rounds) + 1e-12
    assert set(fix.plans) == {j.name for j in jobs}
    for j in jobs:      # each job's plan still covers its own tensors
        assert fix.plans[j.name].num_tensors == len(j.specs)


def test_joint_two_identical_jobs_beat_independent_planning():
    """Two identical jobs on one link: the co-planned assignment's
    makespan is <= running both on their exclusive-link MG-WFBP plans
    (the seed guarantee, observed end to end through the engine)."""
    specs, t_f = trace.synthetic_specs(28, seed=45)
    jobs = [CoJobSpec("a", tuple(specs), t_f),
            CoJobSpec("b", tuple(specs), t_f)]
    fix = scenarios.contended_jobs_plan(jobs, n_workers=8, iters=2,
                                        damping=0.3)
    model = FlatTopology("ring", 8, scenarios.PAPER_ALPHA,
                         scenarios.PAPER_BETA,
                         scenarios.PAPER_GAMMA).linear_model()
    indep = make_plan("mgwfbp", specs, model)
    m_indep = scenarios.shared_link_jobs(
        jobs, n_workers=8, iters=2, plans={"a": indep, "b": indep}) \
        .run().makespan
    assert fix.makespan <= m_indep + 1e-12


def test_joint_converges_on_asymmetric_jobs():
    """Distinct profiles (no mirror symmetry to oscillate through) reach
    an exact fixed point or cycle within the budget."""
    a, t_f_a = trace.synthetic_specs(12, seed=50)
    b, t_f_b = trace.synthetic_specs(20, seed=51)
    fix = scenarios.contended_jobs_plan(
        [CoJobSpec("small", tuple(a), t_f_a),
         CoJobSpec("large", tuple(b), t_f_b)],
        n_workers=4, iters=2, max_rounds=8)
    assert fix.converged


# ---------------------------------------------------------------------------
# Cross-schedule co-planning.
# ---------------------------------------------------------------------------

def test_cross_schedule_predictions_use_each_jobs_closed_form():
    """In a mixed BSP + pipelined + local-SGD fleet, every round's
    per-job prediction equals that job's own Schedule.predict_t_iter
    under the round's effective model."""
    jobs = [
        CoJobSpec("bsp", *trace.synthetic_specs(12, seed=60)),
        CoJobSpec("pipe", *trace.synthetic_specs(14, seed=61),
                  schedule=PipelinedAllReduce(0.5)),
        CoJobSpec("local", *trace.synthetic_specs(16, seed=62),
                  schedule=LocalSGD(2)),
    ]
    fix = scenarios.contended_jobs_plan(jobs, n_workers=4, iters=2,
                                        max_rounds=3)
    schedules = {"bsp": BSP(), "pipe": PipelinedAllReduce(0.5),
                 "local": LocalSGD(2)}
    by_name = {j.name: j for j in jobs}
    for r in fix.rounds:
        for name, sched in schedules.items():
            j = by_name[name]
            expect = sched.predict_t_iter(j.specs, r.plans[name],
                                          r.models[name], j.t_f)
            assert r.predicted[name] == pytest.approx(expect, rel=1e-12)
    seed_rounds = [r for r in fix.rounds if r.kind == "seed"]
    assert fix.makespan <= min(r.makespan for r in seed_rounds) + 1e-12


def test_shared_effective_model_pools_link_samples():
    """shared_model=True refits a job from the aggregate sample pool of
    every job sharing its link — a job whose own samples span one size
    (rank-deficient alone, so per-job refit could only stretch the base
    model) gets the exact least-squares line through the pooled sizes."""
    specs, t_f = trace.synthetic_specs(6, seed=70)
    true = AllReduceModel(2e-3, 4e-9)
    jobs = [CoJob(name="a", specs=tuple(specs), model=MODEL, t_f=t_f,
                  links=("net",)),
            CoJob(name="b", specs=tuple(specs), model=MODEL, t_f=t_f,
                  links=("net",))]
    obs = CoObservation(makespan=1.0, jobs={
        # one distinct size per job: only the pooled set spans two
        "a": JobObservation(t_iter=1.0,
                            samples=((1 << 20, true.time(1 << 20)),)),
        "b": JobObservation(t_iter=1.0,
                            samples=((1 << 22, true.time(1 << 22)),)),
    })

    def never(plans):   # pragma: no cover - _refit is driven directly
        raise AssertionError

    eff = {"a": MODEL, "b": MODEL}
    CoPlanner(jobs, never, damping=1.0, shared_model=True) \
        ._refit(obs, eff, jobs[0])
    assert eff["a"].a == pytest.approx(true.a, rel=1e-9)
    assert eff["a"].b == pytest.approx(true.b, rel=1e-9)
    assert eff["b"] is MODEL            # only the sub-step's job refits
    # per-job mode on the same observation can only stretch the base
    eff = {"a": MODEL, "b": MODEL}
    CoPlanner(jobs, never, damping=1.0)._refit(obs, eff, jobs[0])
    assert eff["a"].b / eff["a"].a == pytest.approx(MODEL.b / MODEL.a)


def test_shared_effective_model_end_to_end():
    """The shared-model co-plan keeps the no-worse-than-seed guarantee."""
    specs, t_f = trace.synthetic_specs(20, seed=70)
    jobs = [CoJobSpec("a", tuple(specs), t_f),
            CoJobSpec("b", tuple(specs), t_f)]
    fix = scenarios.contended_jobs_plan(jobs, n_workers=4, iters=2,
                                        shared_model=True, max_rounds=3)
    seed_rounds = [r for r in fix.rounds if r.kind == "seed"]
    assert fix.makespan <= min(r.makespan for r in seed_rounds) + 1e-12


# ---------------------------------------------------------------------------
# Validation.
# ---------------------------------------------------------------------------

def test_coplanner_rejects_bad_configuration():
    specs, t_f = trace.synthetic_specs(4, seed=1)
    job = CoJob(name="j", specs=tuple(specs), model=MODEL, t_f=t_f)

    def evaluate(plans):    # pragma: no cover - never reached
        raise AssertionError

    with pytest.raises(ValueError):
        CoPlanner([], evaluate)
    with pytest.raises(ValueError):
        CoPlanner([job, job], evaluate)         # duplicate names
    with pytest.raises(ValueError):
        CoPlanner([job], evaluate, damping=0.0)
    with pytest.raises(ValueError):
        CoPlanner([job], evaluate, max_rounds=0)


def test_shared_link_jobs_rejects_unknown_plan_keys():
    """A typoed pin must error, not silently fall back to the strategy
    plan (a baseline comparison would measure the wrong assignment)."""
    specs, t_f = trace.synthetic_specs(6, seed=2)
    jobs = [CoJobSpec("job_a", tuple(specs), t_f)]
    with pytest.raises(ValueError, match="job_A"):
        scenarios.shared_link_jobs(
            jobs, plans={"job_A": make_plan("wfbp", specs)})


# ---------------------------------------------------------------------------
# Link-owner telemetry (the engine layer the co-planner consumes).
# ---------------------------------------------------------------------------

def test_per_job_link_bytes_sum_to_link_totals():
    """Across a two-job run, each job's final link telemetry matches its
    bytes_communicated, and the per-owner byte totals on the link sum to
    everything admitted."""
    a, t_f_a = trace.synthetic_specs(14, seed=80)
    b, t_f_b = trace.synthetic_specs(18, seed=81)
    sim = scenarios.two_jobs(a, t_f_a, b, t_f_b, n_workers=4, iters=2)
    res = sim.run()
    link = sim.links["net"]
    total = 0.0
    for name in ("job_a", "job_b"):
        jr = res.job(name)
        tele = jr.link_telemetry
        assert set(tele) == {"net"}
        nbytes, busy = tele["net"]
        assert nbytes == pytest.approx(jr.bytes_communicated, abs=1e-6)
        assert busy > 0.0
        total += nbytes
    assert sum(link.owner_bytes.values()) == pytest.approx(total, abs=1e-6)
    # busy conservation: per-owner shares sum to the link's busy wall time
    assert sum(link.owner_busy.values()) == \
        pytest.approx(link.busy_s, abs=1e-9)


def test_telemetry_is_cumulative_and_monotone():
    specs, t_f = trace.synthetic_specs(10, seed=82)
    res = scenarios.two_jobs(specs, t_f, specs, t_f, n_workers=2,
                             iters=3).run()
    for name in ("job_a", "job_b"):
        prev_bytes = prev_busy = 0.0
        for it in res.job(name).iterations:
            cur_bytes = dict(it.link_bytes).get("net", 0.0)
            cur_busy = dict(it.link_busy).get("net", 0.0)
            assert cur_bytes >= prev_bytes - 1e-12
            assert cur_busy >= prev_busy - 1e-12
            prev_bytes, prev_busy = cur_bytes, cur_busy


def test_background_bursts_excluded_from_job_telemetry():
    """Burst traffic lands on the reserved background owner: the job's
    byte account is burst-free while the background's busy share is
    real — so co-planner refits can never fit bursts into (a, b)."""
    specs, t_f = trace.synthetic_specs(12, seed=83)
    sim = scenarios.bursty(specs, t_f, 4, burst_flows=3, horizon_iters=2)
    res = sim.run()
    link = sim.links["net"]
    jr = res.job("train")
    nbytes, busy = jr.link_telemetry["net"]
    assert nbytes == pytest.approx(jr.bytes_communicated, abs=1e-6)
    assert link.owner_bytes.get(BACKGROUND_OWNER, 0.0) == 0.0
    assert link.owner_busy[BACKGROUND_OWNER] > 0.0
    assert sum(link.owner_busy.values()) == \
        pytest.approx(link.busy_s, abs=1e-9)
    # the job only received part of the busy time — bursts took the rest
    assert busy < link.busy_s - 1e-12


def test_contended_jobs_plan_observations_carry_telemetry():
    """The joint evaluate wires per-job link telemetry into every
    CoObservation (what shared-model mode and diagnostics consume)."""
    specs, t_f = trace.synthetic_specs(10, seed=84)
    jobs = [CoJobSpec("a", tuple(specs), t_f),
            CoJobSpec("b", tuple(specs), t_f)]
    fix = scenarios.contended_jobs_plan(jobs, n_workers=2, iters=1,
                                        max_rounds=2)
    for r in fix.rounds:
        for name in ("a", "b"):
            jo = r.observation.jobs[name]
            assert dict(jo.link_bytes).get("net", 0.0) > 0.0
            assert dict(jo.link_busy).get("net", 0.0) > 0.0


def test_eviction_loop_replans_through_coplanner():
    """straggler_eviction(contention_aware=True) runs the co-planner on
    the post-eviction contended fabric and installs its plan."""
    specs, t_f = trace.synthetic_specs(16, seed=85)
    sim, report = scenarios.straggler_eviction(
        specs, t_f, 8, slow_factor=3.0, iters=6, contention_aware=True,
        bursts=(Burst("net", 0.0, 30.0, flows=2),))
    sim.run()
    assert report.evictions, "straggler never evicted"
    assert report.fixpoints, "co-planner never ran"
    assert report.plans[-1].buckets == report.fixpoints[-1].plan.buckets
