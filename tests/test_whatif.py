"""What-if serving tests: snapshot lifecycle, every query kind against
a hand-computed oracle, cache/fingerprint isolation, obs counters."""

import dataclasses

import pytest

from repro.core.coplanner import CoJob, coplan
from repro.core.cost_model import AllReduceModel, as_linear
from repro.core.planner import MergePlan, TensorSpec, plan_dp_optimal
from repro.core.simulator import simulate
from repro.obs.metrics import REGISTRY
from repro.serve.whatif import FleetSnapshot, WhatIfQuery, WhatIfServer
from repro.sim.fleet import FleetEvaluator


def _job(name, sizes, a=1e-4, b=5e-10, t_f=1e-3):
    specs = tuple(TensorSpec(f"{name}.t{i}", s, 1e-4)
                  for i, s in enumerate(sizes))
    return CoJob(name=name, specs=specs, t_f=t_f,
                 model=AllReduceModel(a=a, b=b, name=f"{name}.link"))


def _jobs():
    return [_job("a", [1 << 20, 1 << 18, 1 << 16]),
            _job("b", [1 << 22, 1 << 10], a=2e-4, b=1e-9),
            _job("c", [1 << 12] * 5, a=5e-5, b=2e-10)]


def _span(job, model, iters=8, plan=None):
    """The per-job oracle the server must agree with: DP plan under the
    model, iters * simulated t_iter."""
    plan = plan or plan_dp_optimal(list(job.specs), model)
    return iters * simulate(list(job.specs), plan, model, job.t_f).t_iter


def test_snapshot_defaults_and_fingerprint():
    jobs = _jobs()
    snap = FleetSnapshot(jobs)
    for j in jobs:
        assert snap.plans[j.name].buckets == \
            plan_dp_optimal(list(j.specs), j.model).buckets
        assert snap.models[j.name] is j.model
    assert snap.makespan == pytest.approx(
        max(_span(j, j.model) for j in jobs), rel=1e-9)
    # any content change -> a different fingerprint
    assert FleetSnapshot(jobs).fingerprint == snap.fingerprint
    assert FleetSnapshot(jobs, iters=4).fingerprint != snap.fingerprint
    bumped = [dataclasses.replace(jobs[0], t_f=2e-3), *jobs[1:]]
    assert FleetSnapshot(bumped).fingerprint != snap.fingerprint


def test_snapshot_validates():
    jobs = _jobs()
    with pytest.raises(ValueError, match=">= 1 job"):
        FleetSnapshot([])
    with pytest.raises(ValueError, match="duplicate"):
        FleetSnapshot([jobs[0], jobs[0]])
    with pytest.raises(ValueError, match="covers"):
        FleetSnapshot(jobs, plans={"a": MergePlan(((0, 1),))})


def test_snapshot_from_coplan():
    jobs = _jobs()
    res = coplan(jobs, FleetEvaluator(jobs, iters=4), max_rounds=2)
    snap = FleetSnapshot.from_coplan(jobs, res, iters=4)
    assert snap.plans == dict(res.plans)
    assert snap.makespan == pytest.approx(res.makespan, rel=1e-9)


def test_scale_bandwidth_oracle():
    jobs = _jobs()
    server = WhatIfServer(FleetSnapshot(jobs))
    ans = server.scale_bandwidth("b", 4.0)
    lin = as_linear(jobs[1].model)
    faster = AllReduceModel(a=lin.a, b=lin.b / 4.0)
    want = _span(jobs[1], faster)
    assert ans.job_span == pytest.approx(want, rel=1e-9)
    assert ans.plan.buckets == \
        plan_dp_optimal(list(jobs[1].specs), faster).buckets
    others = [_span(j, j.model) for j in jobs if j.name != "b"]
    assert ans.makespan == pytest.approx(max([want, *others]), rel=1e-9)
    assert ans.baseline == pytest.approx(server.snapshot.makespan)
    assert ans.delta == ans.makespan - ans.baseline


def test_remove_and_add_job():
    jobs = _jobs()
    server = WhatIfServer(FleetSnapshot(jobs))
    gone = server.remove_job("b")
    assert gone.job_span is None and gone.plan is None
    assert gone.makespan == pytest.approx(
        max(_span(j, j.model) for j in jobs if j.name != "b"), rel=1e-9)

    new = _job("d", [1 << 21, 1 << 19], a=3e-4)
    added = server.add_job(new)
    assert added.job_span == pytest.approx(_span(new, new.model), rel=1e-9)
    assert added.makespan >= gone.makespan
    # an explicit plan is honored verbatim, not re-planned
    fixed = MergePlan(((0,), (1,)))
    pinned = server.add_job(new, plan=fixed)
    assert pinned.plan is fixed
    assert pinned.job_span == pytest.approx(
        _span(new, new.model, plan=fixed), rel=1e-9)


def test_move_and_resize():
    jobs = _jobs()
    server = WhatIfServer(FleetSnapshot(jobs))
    dest = AllReduceModel(a=5e-5, b=1e-10, name="fastpath")
    moved = server.move_job("a", dest)
    assert moved.job_span == pytest.approx(_span(jobs[0], dest), rel=1e-9)

    grown = dataclasses.replace(jobs[2], t_f=5e-3)
    resized = server.resize("c", t_f=5e-3)
    assert resized.job_span == pytest.approx(
        _span(grown, grown.model), rel=1e-9)
    wider = tuple(TensorSpec(f"w{i}", 1 << 16, 1e-4) for i in range(8))
    reshaped = server.resize("c", specs=wider)
    assert reshaped.plan.num_tensors == 8


def test_validation_errors():
    jobs = _jobs()
    server = WhatIfServer(FleetSnapshot(jobs))
    with pytest.raises(KeyError):
        server.remove_job("ghost")
    with pytest.raises(ValueError, match="already in snapshot"):
        server.add_job(jobs[0])
    with pytest.raises(ValueError, match="positive scale"):
        server.scale_bandwidth("a", 0.0)
    with pytest.raises(ValueError, match="changes nothing"):
        server.resize("a")
    with pytest.raises(ValueError, match="plan/specs mismatch"):
        server.add_job(_job("d", [1, 2, 3]), plan=MergePlan(((0,),)))
    with pytest.raises(ValueError, match="unknown query kind"):
        server.ask([WhatIfQuery("teleport", "a")])
    solo = WhatIfServer(FleetSnapshot(jobs[:1]))
    with pytest.raises(ValueError, match="last job"):
        solo.remove_job("a")


def test_cache_hits_and_counters():
    jobs = _jobs()
    server = WhatIfServer(FleetSnapshot(jobs))
    before = REGISTRY.snapshot()
    first = server.scale_bandwidth("a", 2.0)
    again = server.scale_bandwidth("a", 2.0)
    assert not first.cached and again.cached
    assert again.makespan == first.makespan
    delta = REGISTRY.snapshot().delta(before)
    assert delta.value("whatif_queries_total", kind="scale_bandwidth") == 2
    assert delta.value("whatif_cache_hits_total") == 1
    assert delta.hist("whatif_latency_seconds")["count"] == 2


def test_cache_keys_include_snapshot_fingerprint():
    """The same server object over a DIFFERENT snapshot must miss: keys
    embed the fleet fingerprint, so answers never leak across states."""
    jobs = _jobs()
    s1 = WhatIfServer(FleetSnapshot(jobs))
    a1 = s1.scale_bandwidth("a", 2.0)
    bumped = [dataclasses.replace(jobs[0], t_f=0.5), *jobs[1:]]
    s2 = WhatIfServer(FleetSnapshot(bumped))
    s2._cache = s1._cache               # share the physical cache
    a2 = s2.scale_bandwidth("a", 2.0)
    assert not a2.cached
    assert a2.makespan != a1.makespan


def test_cache_bound():
    server = WhatIfServer(FleetSnapshot(_jobs()), cache_size=2)
    for k in range(4):
        server.scale_bandwidth("a", 2.0 + k)
    assert len(server._cache) == 2
    with pytest.raises(ValueError, match="cache_size"):
        WhatIfServer(FleetSnapshot(_jobs()), cache_size=0)


def test_burst_mixes_kinds():
    """One ask() with every query kind answers each one exactly as the
    corresponding single-shot call does."""
    jobs = _jobs()
    new = _job("d", [1 << 15])
    queries = [WhatIfQuery("scale_bandwidth", "a", scale=2.0),
               WhatIfQuery("remove_job", "b"),
               WhatIfQuery("move_job", "c",
                           model=AllReduceModel(a=1e-5, b=1e-10)),
               WhatIfQuery("resize", "a", t_f=9e-3),
               WhatIfQuery("add_job", "d", job=new)]
    burst = WhatIfServer(FleetSnapshot(jobs)).ask(queries)
    fresh = WhatIfServer(FleetSnapshot(jobs))
    singles = [fresh.ask([q])[0] for q in queries]
    for b, s in zip(burst, singles):
        assert b.makespan == s.makespan
        assert b.job_span == s.job_span
