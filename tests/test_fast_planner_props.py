"""Incremental-planner property tests: the fast DP equals the O(L^2)
reference on random instances and after random update streams; skipped
without the real hypothesis package."""

import random

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
from prop_strategies import mk_specs, model_strategy, specs_strategy  # noqa: E402

from repro.core.cost_model import AllReduceModel  # noqa: E402
from repro.core.planner import (Planner, SpecDelta, TensorSpec,  # noqa: E402
                                plan_dp_optimal)
from repro.core.simulator import simulate  # noqa: E402


def _assert_matches_reference(planner: Planner, plan=None):
    specs, model = list(planner.specs), planner.model
    plan = plan if plan is not None else planner.plan()
    t_fast = simulate(specs, plan, model).t_iter
    t_ref = simulate(specs, plan_dp_optimal(specs, model), model).t_iter
    assert t_fast == pytest.approx(t_ref, rel=1e-9, abs=1e-15)


@hypothesis.given(specs_strategy(max_n=24, min_bytes=0, min_t=0),
                  model_strategy())
@hypothesis.settings(max_examples=120, deadline=None)
def test_matches_dp_optimal_from_scratch(sizes_times, ab):
    specs = mk_specs(*sizes_times)
    _assert_matches_reference(Planner(specs, AllReduceModel(*ab)))


@hypothesis.given(st.integers(0, 10_000))
@hypothesis.settings(max_examples=60, deadline=None)
def test_matches_dp_optimal_on_update_streams(seed):
    """Random spec streams: after every delta the incremental plan still
    matches a from-scratch reference plan — while never rebuilding."""
    rng = random.Random(seed)
    L = rng.randint(1, 20)
    specs = [TensorSpec(f"t{i}", rng.randint(0, 1 << 22),
                        rng.uniform(0, 5e-3)) for i in range(L)]
    model = AllReduceModel(rng.uniform(0, 2e-3), rng.uniform(1e-11, 1e-8))
    planner = Planner(specs, model)
    _assert_matches_reference(planner)
    for k in range(8):
        kind = rng.choice(["model", "point", "append", "truncate"])
        if kind == "model":
            model = AllReduceModel(rng.uniform(0, 2e-3),
                                   rng.uniform(1e-11, 1e-8))
            plan = planner.update(SpecDelta(model=model))
        elif kind == "point" and planner.num_tensors:
            idx = rng.randrange(planner.num_tensors)
            plan = planner.update(SpecDelta(updates={idx: TensorSpec(
                f"u{k}", rng.randint(0, 1 << 22), rng.uniform(0, 5e-3))}))
        elif kind == "truncate" and planner.num_tensors > 1:
            plan = planner.update(SpecDelta(
                truncate=rng.randint(1, planner.num_tensors)))
        else:
            plan = planner.update(SpecDelta(append=tuple(
                TensorSpec(f"a{k}.{j}", rng.randint(0, 1 << 20),
                           rng.uniform(0, 1e-3))
                for j in range(rng.randint(1, 3)))))
        _assert_matches_reference(planner, plan)
    assert planner.scratch_plans == 1
    assert planner.incremental_updates == 8
