"""Incremental planner (core.planner.Planner) correctness.

The anchor property: on every random instance — and after every random
update stream (cost-model swaps, point edits, appends, truncations) — the
incremental planner's plan achieves the same simulated iteration time as
the O(L^2) reference ``plan_dp_optimal``, which is itself certified
against brute force in test_planner.py.  Exact bucket equality is NOT
asserted (the fast recurrence reassociates floating-point arithmetic, so
knife-edge ties may resolve differently); time-equality is the meaningful
optimality statement.
"""

import random

import pytest
from _hypothesis_compat import hypothesis, st

from repro.core.cost_model import AllReduceModel
from repro.core.planner import (Planner, SpecDelta, TensorSpec, make_plan,
                                plan_dp_optimal, plan_incremental)
from repro.core.simulator import simulate

specs_strategy = st.integers(1, 24).flatmap(
    lambda n: st.tuples(
        st.lists(st.integers(0, 1 << 22), min_size=n, max_size=n),
        st.lists(st.floats(0, 5e-3), min_size=n, max_size=n)))

model_strategy = st.tuples(st.floats(0, 2e-3), st.floats(1e-11, 1e-8))


def _mk_specs(sizes, times):
    return [TensorSpec(f"t{i}", s, t) for i, (s, t) in
            enumerate(zip(sizes, times))]


def _assert_matches_reference(planner: Planner, plan=None):
    specs, model = list(planner.specs), planner.model
    plan = plan if plan is not None else planner.plan()
    t_fast = simulate(specs, plan, model).t_iter
    t_ref = simulate(specs, plan_dp_optimal(specs, model), model).t_iter
    assert t_fast == pytest.approx(t_ref, rel=1e-9, abs=1e-15)


@hypothesis.given(specs_strategy, model_strategy)
@hypothesis.settings(max_examples=120, deadline=None)
def test_matches_dp_optimal_from_scratch(sizes_times, ab):
    specs = _mk_specs(*sizes_times)
    _assert_matches_reference(Planner(specs, AllReduceModel(*ab)))


@hypothesis.given(st.integers(0, 10_000))
@hypothesis.settings(max_examples=60, deadline=None)
def test_matches_dp_optimal_on_update_streams(seed):
    """Random spec streams: after every delta the incremental plan still
    matches a from-scratch reference plan — while never rebuilding."""
    rng = random.Random(seed)
    L = rng.randint(1, 20)
    specs = [TensorSpec(f"t{i}", rng.randint(0, 1 << 22),
                        rng.uniform(0, 5e-3)) for i in range(L)]
    model = AllReduceModel(rng.uniform(0, 2e-3), rng.uniform(1e-11, 1e-8))
    planner = Planner(specs, model)
    _assert_matches_reference(planner)
    for k in range(8):
        kind = rng.choice(["model", "point", "append", "truncate"])
        if kind == "model":
            model = AllReduceModel(rng.uniform(0, 2e-3),
                                   rng.uniform(1e-11, 1e-8))
            plan = planner.update(SpecDelta(model=model))
        elif kind == "point" and planner.num_tensors:
            idx = rng.randrange(planner.num_tensors)
            plan = planner.update(SpecDelta(updates={idx: TensorSpec(
                f"u{k}", rng.randint(0, 1 << 22), rng.uniform(0, 5e-3))}))
        elif kind == "truncate" and planner.num_tensors > 1:
            plan = planner.update(SpecDelta(
                truncate=rng.randint(1, planner.num_tensors)))
        else:
            plan = planner.update(SpecDelta(append=tuple(
                TensorSpec(f"a{k}.{j}", rng.randint(0, 1 << 20),
                           rng.uniform(0, 1e-3))
                for j in range(rng.randint(1, 3)))))
        _assert_matches_reference(planner, plan)
    assert planner.scratch_plans == 1
    assert planner.incremental_updates == 8


def test_counters_track_incremental_path():
    specs = [TensorSpec(f"t{i}", 1 << 18, 1e-4) for i in range(32)]
    model = AllReduceModel(1e-4, 1e-9)
    p = Planner(specs, model)
    assert (p.scratch_plans, p.incremental_updates) == (1, 0)
    for k in range(5):
        p.replan(AllReduceModel(1e-4 * (k + 2), 1e-9))
    p.append(TensorSpec("x", 123, 1e-5))
    assert (p.scratch_plans, p.incremental_updates) == (1, 6)


def test_empty_and_single():
    model = AllReduceModel(1e-4, 1e-9)
    p = Planner([], model)
    assert p.plan().num_tensors == 0
    assert p.finish_time == 0.0
    p.append(TensorSpec("t0", 100, 1e-3))
    assert p.plan().buckets == ((0,),)
    assert p.finish_time == pytest.approx(1e-3 + model.time(100))


def test_zero_byte_tensors():
    """Empty buckets cost 0, not a — the DP must exploit that exactly."""
    specs = [TensorSpec("t0", 1 << 20, 1e-3),
             TensorSpec("t1", 0, 1e-3),
             TensorSpec("t2", 0, 1e-3)]
    model = AllReduceModel(1e-2, 1e-9)   # huge startup
    _assert_matches_reference(Planner(specs, model))


def test_incremental_strategy_dispatch():
    specs = [TensorSpec("t0", 100, 1e-3), TensorSpec("t1", 200, 1e-3)]
    model = AllReduceModel(1e-3, 1e-9)
    plan = make_plan("dp_incremental", specs, model)
    assert plan.strategy == "dp_incremental"
    assert plan.num_tensors == 2
    assert plan.buckets == plan_incremental(specs, model).buckets


def test_finish_time_matches_simulator():
    specs = [TensorSpec(f"t{i}", (i + 1) << 16, 1e-4) for i in range(10)]
    model = AllReduceModel(5e-4, 2e-9)
    p = Planner(specs, model)
    res = simulate(specs, p.plan(), model)
    assert res.comm_end == pytest.approx(
        max(p.finish_time, res.t_b_total), abs=1e-15)


def test_delta_validation():
    p = Planner([TensorSpec("t0", 100, 1e-3)], AllReduceModel(1e-3, 1e-9))
    with pytest.raises(IndexError):
        p.update(SpecDelta(updates={5: TensorSpec("x", 1, 1e-3)}))
    with pytest.raises(IndexError):
        p.update(SpecDelta(truncate=7))


def test_failed_update_leaves_state_intact():
    """A delta that is partially valid must be rejected atomically — no
    spec mutation, no stale DP frontier, no counter bump."""
    specs = [TensorSpec(f"t{i}", (i + 1) * 1000, 1e-4) for i in range(6)]
    p = Planner(specs, AllReduceModel(1e-3, 1e-9))
    before_plan = p.plan().buckets
    before_finish = p.finish_time
    with pytest.raises(IndexError):
        p.update(SpecDelta(updates={0: TensorSpec("big", 1 << 26, 1e-2),
                                    9: TensorSpec("x", 1, 1e-3)}))
    assert p.specs == tuple(specs)
    assert p.plan().buckets == before_plan
    assert p.finish_time == before_finish
    assert p.incremental_updates == 0
    _assert_matches_reference(p)


def test_truncate_then_append_roundtrip():
    specs = [TensorSpec(f"t{i}", (i + 1) * 1000, 1e-4) for i in range(12)]
    model = AllReduceModel(1e-4, 1e-9)
    p = Planner(specs, model)
    before = p.plan().buckets
    p.update(SpecDelta(truncate=6))
    p.update(SpecDelta(append=tuple(specs[6:])))
    assert p.plan().buckets == before
    assert p.scratch_plans == 1
