"""Incremental planner (core.planner.Planner) correctness.

The anchor property — on every random instance and after every random
update stream the incremental planner matches the O(L^2) reference
``plan_dp_optimal`` — lives in tests/test_fast_planner_props.py
(hypothesis).  This module keeps the deterministic unit coverage.
"""

import pytest

from repro.core.cost_model import AllReduceModel
from repro.core.planner import (Planner, SpecDelta, TensorSpec, make_plan,
                                plan_dp_optimal, plan_incremental)
from repro.core.simulator import simulate


def _assert_matches_reference(planner: Planner, plan=None):
    specs, model = list(planner.specs), planner.model
    plan = plan if plan is not None else planner.plan()
    t_fast = simulate(specs, plan, model).t_iter
    t_ref = simulate(specs, plan_dp_optimal(specs, model), model).t_iter
    assert t_fast == pytest.approx(t_ref, rel=1e-9, abs=1e-15)


def test_counters_track_incremental_path():
    specs = [TensorSpec(f"t{i}", 1 << 18, 1e-4) for i in range(32)]
    model = AllReduceModel(1e-4, 1e-9)
    p = Planner(specs, model)
    assert (p.scratch_plans, p.incremental_updates) == (1, 0)
    for k in range(5):
        p.replan(AllReduceModel(1e-4 * (k + 2), 1e-9))
    p.append(TensorSpec("x", 123, 1e-5))
    assert (p.scratch_plans, p.incremental_updates) == (1, 6)


def test_empty_and_single():
    model = AllReduceModel(1e-4, 1e-9)
    p = Planner([], model)
    assert p.plan().num_tensors == 0
    assert p.finish_time == 0.0
    p.append(TensorSpec("t0", 100, 1e-3))
    assert p.plan().buckets == ((0,),)
    assert p.finish_time == pytest.approx(1e-3 + model.time(100))


def test_zero_byte_tensors():
    """Empty buckets cost 0, not a — the DP must exploit that exactly."""
    specs = [TensorSpec("t0", 1 << 20, 1e-3),
             TensorSpec("t1", 0, 1e-3),
             TensorSpec("t2", 0, 1e-3)]
    model = AllReduceModel(1e-2, 1e-9)   # huge startup
    _assert_matches_reference(Planner(specs, model))


def test_incremental_strategy_dispatch():
    specs = [TensorSpec("t0", 100, 1e-3), TensorSpec("t1", 200, 1e-3)]
    model = AllReduceModel(1e-3, 1e-9)
    plan = make_plan("dp_incremental", specs, model)
    assert plan.strategy == "dp_incremental"
    assert plan.num_tensors == 2
    assert plan.buckets == plan_incremental(specs, model).buckets


def test_finish_time_matches_simulator():
    specs = [TensorSpec(f"t{i}", (i + 1) << 16, 1e-4) for i in range(10)]
    model = AllReduceModel(5e-4, 2e-9)
    p = Planner(specs, model)
    res = simulate(specs, p.plan(), model)
    assert res.comm_end == pytest.approx(
        max(p.finish_time, res.t_b_total), abs=1e-15)


def test_delta_validation():
    p = Planner([TensorSpec("t0", 100, 1e-3)], AllReduceModel(1e-3, 1e-9))
    with pytest.raises(IndexError):
        p.update(SpecDelta(updates={5: TensorSpec("x", 1, 1e-3)}))
    with pytest.raises(IndexError):
        p.update(SpecDelta(truncate=7))


def test_failed_update_leaves_state_intact():
    """A delta that is partially valid must be rejected atomically — no
    spec mutation, no stale DP frontier, no counter bump."""
    specs = [TensorSpec(f"t{i}", (i + 1) * 1000, 1e-4) for i in range(6)]
    p = Planner(specs, AllReduceModel(1e-3, 1e-9))
    before_plan = p.plan().buckets
    before_finish = p.finish_time
    with pytest.raises(IndexError):
        p.update(SpecDelta(updates={0: TensorSpec("big", 1 << 26, 1e-2),
                                    9: TensorSpec("x", 1, 1e-3)}))
    assert p.specs == tuple(specs)
    assert p.plan().buckets == before_plan
    assert p.finish_time == before_finish
    assert p.incremental_updates == 0
    _assert_matches_reference(p)


def test_truncate_then_append_roundtrip():
    specs = [TensorSpec(f"t{i}", (i + 1) * 1000, 1e-4) for i in range(12)]
    model = AllReduceModel(1e-4, 1e-9)
    p = Planner(specs, model)
    before = p.plan().buckets
    p.update(SpecDelta(truncate=6))
    p.update(SpecDelta(append=tuple(specs[6:])))
    assert p.plan().buckets == before
    assert p.scratch_plans == 1
