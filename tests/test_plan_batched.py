"""Deterministic batched-planner + geometry-cache tests (no hypothesis;
the numpy backend keeps most of them alive without jax)."""

import numpy as np
import pytest

from repro.core.cost_model import AllReduceModel
from repro.core.planner import (MergePlan, Planner, TensorSpec, make_plan,
                                plan_dp_optimal)
from repro.core.simulator import spec_arrays
from repro.obs.metrics import REGISTRY
from repro.sim import fleet

MODEL = AllReduceModel(a=1e-4, b=5e-10)


def _specs(sizes, t_b=1e-4):
    return [TensorSpec(f"t{i}", s, t_b) for i, s in enumerate(sizes)]


def _backends():
    return ("fleet", "numpy") if fleet.fleet_available() else ("numpy",)


def test_plan_cases_empty_batch():
    assert fleet.plan_cases([]) == []


@pytest.mark.parametrize("backend", _backends())
def test_plan_cases_zero_tensor_job(backend):
    """An L=0 problem plans host-side to the empty plan; its batch-mates
    are unaffected."""
    specs = _specs([100, 200, 300])
    got = fleet.plan_batched([([], MODEL), (specs, MODEL)],
                             backend=backend)
    assert got[0].buckets == ()
    assert got[0].strategy == "dp_batched"
    assert got[1].buckets == plan_dp_optimal(specs, MODEL).buckets


@pytest.mark.parametrize("backend", _backends())
def test_plan_cases_single_layer(backend):
    got = fleet.plan_batched([(_specs([1 << 20]), MODEL)],
                             backend=backend)[0]
    assert got.buckets == ((0,),)


@pytest.mark.parametrize("backend", _backends())
def test_plan_cases_all_zero_bytes(backend):
    """Zero-byte tensors cost nothing to merge — the oracle rides every
    tie toward bigger merges, and the kernel must follow."""
    specs = _specs([0, 0, 0, 0])
    got = fleet.plan_batched([(specs, MODEL)], backend=backend)[0]
    assert got.buckets == plan_dp_optimal(specs, MODEL).buckets


def test_make_plan_dispatches_dp_batched():
    specs = _specs([1 << 10, 1 << 22, 64, 1 << 18, 1 << 5])
    got = make_plan("dp_batched", specs, MODEL)
    assert got.strategy == "dp_batched"
    assert got.buckets == plan_dp_optimal(specs, MODEL).buckets


def test_plan_cases_counts_metrics():
    before = REGISTRY.snapshot()
    fleet.plan_batched([(_specs([1, 2, 3]), MODEL)], backend="numpy")
    delta = REGISTRY.snapshot().delta(before)
    assert delta.value("fleet_plan_cases_total", backend="numpy") == 1


def test_plan_cases_matches_planner_t_iter():
    """Cross-strategy sanity: dp_batched and the O(L) incremental
    planner may tie-break differently, but simulate() to the same
    t_iter (the repo-wide equality idiom)."""
    from repro.core.simulator import simulate
    rng = np.random.default_rng(7)
    for _ in range(5):
        specs = _specs(rng.integers(1, 1 << 22, size=12).tolist(),
                       t_b=5e-5)
        batched = fleet.plan_batched([(specs, MODEL)],
                                     backend="numpy")[0]
        inc = Planner(specs, MODEL).plan()
        assert simulate(specs, batched, MODEL).t_iter == \
            pytest.approx(simulate(specs, inc, MODEL).t_iter, rel=1e-9)


# -- geometry cache ------------------------------------------------------


def test_profile_fingerprint_distinguishes_profiles():
    a = spec_arrays(_specs([1, 2, 3]))
    b = spec_arrays(_specs([1, 2, 4]))
    assert fleet.profile_fingerprint(*a) == fleet.profile_fingerprint(*a)
    assert fleet.profile_fingerprint(*a) != fleet.profile_fingerprint(*b)


def test_geom_cache_lru_and_counters():
    cache = fleet.GeomCache(maxsize=2)
    before = REGISTRY.snapshot()
    cache["a"] = 1
    cache["b"] = 2
    assert cache["a"] == 1            # refresh: "a" is now most recent
    cache["c"] = 3                    # evicts "b", not "a"
    assert "b" not in cache
    assert cache["a"] == 1 and cache["c"] == 3
    delta = REGISTRY.snapshot().delta(before)
    assert delta.value("fleet_geom_cache_hits_total") == 3
    assert delta.value("fleet_geom_cache_evictions_total") == 1
    assert len(cache) == 2


def test_make_case_profile_key_shares_geometry():
    """Two make_case calls for the same profile+plan share one cache
    entry under an explicit profile_key — and a DIFFERENT profile with
    the same plan shape must not collide (the PR-9 footgun)."""
    specs_a = _specs([100, 200, 300, 400])
    specs_b = _specs([101, 200, 300, 400])
    plan = MergePlan(((0, 1), (2, 3)))
    cache = fleet.GeomCache()
    ka = fleet.profile_fingerprint(*spec_arrays(specs_a))
    kb = fleet.profile_fingerprint(*spec_arrays(specs_b))
    ca1 = fleet.make_case(specs_a, plan, MODEL, cache=cache, profile_key=ka)
    ca2 = fleet.make_case(specs_a, plan, MODEL, cache=cache, profile_key=ka)
    cb = fleet.make_case(specs_b, plan, MODEL, cache=cache, profile_key=kb)
    assert ca1.bucket_bytes is ca2.bucket_bytes
    assert cb.bucket_bytes is not ca1.bucket_bytes
    assert float(cb.bucket_bytes[0]) != float(ca1.bucket_bytes[0])


def test_make_case_fingerprints_when_key_omitted():
    """Without an explicit profile_key the key is derived from the
    prefix arrays — same-shape different-content profiles stay apart."""
    specs_a = _specs([100, 200])
    specs_b = _specs([150, 150])
    plan = MergePlan(((0, 1),))
    cache = fleet.GeomCache()
    ca = fleet.make_case(specs_a, plan, MODEL, cache=cache)
    cb = fleet.make_case(specs_b, plan, MODEL, cache=cache)
    assert len(cache) == 2              # no collision despite equal shape
    assert ca.bucket_bytes is not cb.bucket_bytes
    again = fleet.make_case(specs_a, plan, MODEL, cache=cache)
    assert again.bucket_bytes is ca.bucket_bytes
