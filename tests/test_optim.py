"""Optimizers: convergence, decay masking, packed-shard == tree update."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import clip, optimizers, schedule


@pytest.mark.parametrize("name", ["adamw", "sgdm"])
def test_converges_on_quadratic(name):
    opt = optimizers.make_optimizer(name, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    target = jnp.array([1.0, 1.0])
    lr = 0.1 if name == "adamw" else 0.05
    for step in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(g, params, state,
                                   jnp.int32(step), lr)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=1e-2)


def test_adamw_state_dtype():
    opt = optimizers.adamw(state_dtype="bfloat16")
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st = opt.init(params)
    assert st["w"]["m"].dtype == jnp.bfloat16
    assert st["w"]["v"].dtype == jnp.bfloat16


def test_weight_decay_mask():
    opt = optimizers.adamw()
    assert opt.weight_decay_mask("['blocks']['w_q']")
    assert not opt.weight_decay_mask("['blocks']['norm1']")
    assert not opt.weight_decay_mask("['mamba']['A_log']")
    assert not opt.weight_decay_mask("['attn']['b_q']")


def test_masked_flat_update_matches_tree_update():
    """ZeRO packed update == per-leaf tree update for a 1-shard 'cluster'."""
    from repro.train.step import _masked_update
    opt = optimizers.adamw(weight_decay=0.1)
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (64,))
    g = jax.random.normal(jax.random.PRNGKey(1), (64,))
    mask = jnp.concatenate([jnp.ones(32), jnp.zeros(32)])
    s = {"m": jnp.zeros(64), "v": jnp.zeros(64)}
    new_flat, _ = _masked_update(opt, g, p, s, jnp.int32(0), 0.01, mask, 0.1)

    tree_p = {"decay": p[:32], "nodecay": p[32:]}
    tree_g = {"decay": g[:32], "nodecay": g[32:]}
    st = opt.init(tree_p)
    new_decay, _ = opt.update_leaf(tree_g["decay"], tree_p["decay"],
                                   st["decay"], jnp.int32(0), 0.01)
    # update_leaf applies decay by default; for nodecay pass decay=False
    new_nodecay, _ = opt.update_leaf(tree_g["nodecay"], tree_p["nodecay"],
                                     st["nodecay"], jnp.int32(0), 0.01,
                                     decay=False)
    np.testing.assert_allclose(np.asarray(new_flat[:32]),
                               np.asarray(new_decay), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_flat[32:]),
                               np.asarray(new_nodecay), rtol=1e-6)


def test_schedules():
    lr = schedule.warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == pytest.approx(0.0)
    assert float(lr(10)) == pytest.approx(1.0, abs=0.01)
    assert float(lr(100)) == pytest.approx(0.1, abs=0.01)
    assert float(lr(55)) < float(lr(11))
    c = schedule.constant(0.5)
    assert float(c(0)) == float(c(1000)) == 0.5


def test_global_norm_clip():
    tree = {"a": jnp.array([3.0, 4.0])}
    n = clip.global_norm(tree)
    assert float(n) == pytest.approx(5.0)
    clipped, norm = clip.clip_by_global_norm(tree, 1.0)
    assert float(clip.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    same, _ = clip.clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0])
