"""Optimizers: convergence, decay masking, packed-shard == tree update."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import clip, optimizers, schedule


@pytest.mark.parametrize("name", ["adamw", "sgdm"])
def test_converges_on_quadratic(name):
    opt = optimizers.make_optimizer(name, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    target = jnp.array([1.0, 1.0])
    lr = 0.1 if name == "adamw" else 0.05
    for step in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(g, params, state,
                                   jnp.int32(step), lr)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=1e-2)


def test_adamw_state_dtype():
    opt = optimizers.adamw(state_dtype="bfloat16")
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st = opt.init(params)
    assert st["w"]["m"].dtype == jnp.bfloat16
    assert st["w"]["v"].dtype == jnp.bfloat16


def test_weight_decay_mask():
    opt = optimizers.adamw()
    assert opt.weight_decay_mask("['blocks']['w_q']")
    assert not opt.weight_decay_mask("['blocks']['norm1']")
    assert not opt.weight_decay_mask("['mamba']['A_log']")
    assert not opt.weight_decay_mask("['attn']['b_q']")


@pytest.mark.parametrize("name,kwargs", [
    ("adamw", {}),
    # non-default hyperparameters: the regression this parametrization
    # pins — flat_update used to hardcode b1=0.9/b2=0.95/eps=1e-8, so the
    # packed (ZeRO-1) path silently diverged from the tree path whenever a
    # run configured different betas.
    ("adamw", {"b1": 0.85, "b2": 0.999, "eps": 1e-6}),
    ("sgdm", {}),
    ("sgdm", {"momentum": 0.75}),
])
def test_masked_flat_update_matches_tree_update(name, kwargs):
    """ZeRO packed update == per-leaf tree update for a 1-shard 'cluster'."""
    opt = optimizers.make_optimizer(name, weight_decay=0.1, **kwargs)
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (64,))
    g = jax.random.normal(jax.random.PRNGKey(1), (64,))
    mask = jnp.concatenate([jnp.ones(32), jnp.zeros(32)])
    s = opt.init({"w": p})["w"]
    new_flat, _ = opt.flat_update(g, p, s, jnp.int32(0), 0.01, mask)

    tree_p = {"decay": p[:32], "nodecay": p[32:]}
    tree_g = {"decay": g[:32], "nodecay": g[32:]}
    st = opt.init(tree_p)
    new_decay, _ = opt.update_leaf(tree_g["decay"], tree_p["decay"],
                                   st["decay"], jnp.int32(0), 0.01)
    # update_leaf applies decay by default; for nodecay pass decay=False
    new_nodecay, _ = opt.update_leaf(tree_g["nodecay"], tree_p["nodecay"],
                                     st["nodecay"], jnp.int32(0), 0.01,
                                     decay=False)
    np.testing.assert_allclose(np.asarray(new_flat[:32]),
                               np.asarray(new_decay), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_flat[32:]),
                               np.asarray(new_nodecay), rtol=1e-6)


def test_flat_update_hyperparams_exposed():
    """flat_update must consume the constructor's hyperparameters — two
    optimizers differing only in b2 must produce different packed updates."""
    p = jax.random.normal(jax.random.PRNGKey(0), (16,))
    g = jax.random.normal(jax.random.PRNGKey(1), (16,))
    g2 = jax.random.normal(jax.random.PRNGKey(2), (16,))
    mask = jnp.ones(16)
    outs = []
    for b2 in (0.95, 0.999):
        opt = optimizers.make_optimizer("adamw", weight_decay=0.0, b2=b2)
        assert dict(opt.hyperparams)["b2"] == b2
        s = opt.init({"w": p})["w"]
        # two steps with DIFFERENT gradients: under a constant gradient the
        # bias-corrected v_hat is b2-independent, so b2 would not show up
        p1, s1 = opt.flat_update(g, p, s, jnp.int32(0), 0.01, mask)
        p2, _ = opt.flat_update(g2, p1, s1, jnp.int32(1), 0.01, mask)
        outs.append(np.asarray(p2))
    assert not np.allclose(outs[0], outs[1])


def test_schedules():
    lr = schedule.warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == pytest.approx(0.0)
    assert float(lr(10)) == pytest.approx(1.0, abs=0.01)
    assert float(lr(100)) == pytest.approx(0.1, abs=0.01)
    assert float(lr(55)) < float(lr(11))
    c = schedule.constant(0.5)
    assert float(c(0)) == float(c(1000)) == 0.5


def test_global_norm_clip():
    tree = {"a": jnp.array([3.0, 4.0])}
    n = clip.global_norm(tree)
    assert float(n) == pytest.approx(5.0)
    clipped, norm = clip.clip_by_global_norm(tree, 1.0)
    assert float(clip.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    same, _ = clip.clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0])
