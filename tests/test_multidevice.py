"""Multi-device distributed correctness (subprocess: needs
XLA_FLAGS=--xla_force_host_platform_device_count set before jax import, and
the rest of the suite must see 1 device).

The key check: 8-way data-parallel training with MG-WFBP bucketed
collectives produces the SAME loss trajectory as single-device training on
the identical global batch — distribution is semantically invisible.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import DataPipeline
    from repro.launch.mesh import make_mesh, use_mesh
    from repro.models import registry
    from repro.train.step import build_train_step

    def run(arch, mesh_shape, axes, dp_axes, zero, ep, steps=3):
        bundle = registry.reduced_arch(arch)
        par = dataclasses.replace(bundle.parallel, dp_axes=dp_axes,
                                  zero=zero, ep_axis=ep, attn_chunk=32,
                                  hierarchical=len(dp_axes) > 1)
        shape = ShapeConfig("tiny", "train", 32, 8)
        run_cfg = dataclasses.replace(bundle.run_config("train_4k", par),
                                      shape=shape, microbatch=0,
                                      learning_rate=1e-2)
        model = bundle.model(par)
        mesh = make_mesh(mesh_shape, axes)
        with use_mesh(mesh):
            step_fn, init_fn, art = build_train_step(model, run_cfg, mesh)
            sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              art.state_pspecs,
                              is_leaf=lambda x: isinstance(x, P))
            state = jax.device_put(init_fn(jax.random.PRNGKey(0)), sh)
            pipe = DataPipeline(bundle.cfg, shape, seed=0)
            jstep = jax.jit(step_fn)
            losses = []
            bsh = NamedSharding(mesh, art.batch_pspec)
            for s in range(steps):
                batch = jax.tree.map(lambda x: jax.device_put(x, bsh),
                                     pipe.batch_at(s))
                state, m = jstep(state, batch)
                losses.append(float(m["loss"]))
            return losses

    # 1) DP(8) == single device, identical global batch
    l_dp = run("qwen2-1.5b", (8,), ("data",), ("data",), 0, "")
    l_1 = run("qwen2-1.5b", (1,), ("data",), (), 0, "")
    for a, b in zip(l_dp, l_1):
        assert abs(a - b) < 5e-3, (l_dp, l_1)
    print("DP==single OK", l_dp)

    # 2) multi-pod mesh + zero1 + hierarchical runs and learns
    l_mp = run("qwen2-1.5b", (2, 2, 2), ("pod", "data", "model"),
               ("pod", "data"), 1, "", steps=4)
    assert all(np.isfinite(l_mp)), l_mp
    print("multipod zero1 OK", l_mp)

    # 3) EP MoE on multi-pod mesh
    l_ep = run("deepseek-moe-16b", (2, 2, 2), ("pod", "data", "model"),
               ("pod", "data"), 1, "data", steps=2)
    assert all(np.isfinite(l_ep)), l_ep
    if not hasattr(jax, "shard_map"):
        # old JAX degrades EP to local expert compute, so the run must be
        # numerically identical to EP disabled — this catches plan/grad-tree
        # misalignment in the degrade (expert leaves skipping the all-reduce)
        l_noep = run("deepseek-moe-16b", (2, 2, 2),
                     ("pod", "data", "model"), ("pod", "data"), 1, "",
                     steps=2)
        for a, b in zip(l_ep, l_noep):
            assert abs(a - b) < 1e-5, (l_ep, l_noep)
    print("EP moe OK", l_ep)
    print("ALL-MULTIDEVICE-PASS")
""")


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "ALL-MULTIDEVICE-PASS" in res.stdout, \
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}"
