"""Hypothesis fallback so property tests degrade gracefully.

When the real ``hypothesis`` package is installed we re-export it untouched.
When it is missing (the CI image does not ship it) we provide a tiny
deterministic stand-in implementing the small strategy surface these tests
use — ``integers``, ``floats``, ``booleans``, ``sampled_from``, ``lists``,
``tuples``, ``just`` and ``.flatmap``/``.map`` — with ``@given`` expanding to
a seeded random sweep of ``max_examples`` draws.  The fallback trades
shrinking and coverage-guided search for zero dependencies; failures print
the offending draw so they stay reproducible (the sweep is seeded per test
name).

Usage in test modules::

    from _hypothesis_compat import hypothesis, st
"""

from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    import types

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def flatmap(self, fn) -> "_Strategy":
            return _Strategy(lambda rng: fn(self.draw(rng)).draw(rng))

        def map(self, fn) -> "_Strategy":
            return _Strategy(lambda rng: fn(self.draw(rng)))

        def filter(self, pred, _max_tries: int = 1000) -> "_Strategy":
            def draw(rng):
                for _ in range(_max_tries):
                    v = self.draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too strict")
            return _Strategy(draw)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, **_kw):
        # Log-uniform-ish mix: hypothesis is fond of boundary values, so
        # include them explicitly for a little adversarial flavour.
        def draw(rng):
            r = rng.random()
            if r < 0.05:
                return min_value
            if r < 0.10:
                return max_value
            return rng.uniform(min_value, max_value)
        return _Strategy(draw)

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _just(value):
        return _Strategy(lambda rng: value)

    def _lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10
        return _Strategy(lambda rng: [
            elements.draw(rng) for _ in range(rng.randint(min_size, hi))])

    def _tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.floats = _floats
    st.booleans = _booleans
    st.sampled_from = _sampled_from
    st.just = _just
    st.lists = _lists
    st.tuples = _tuples
    st.SearchStrategy = _Strategy

    _DEFAULT_MAX_EXAMPLES = 100

    def _given(*g_strategies, **g_kw):
        if g_kw:
            raise NotImplementedError(
                "fallback @given supports positional strategies only")

        def deco(fn):
            # NB: no functools.wraps — pytest must see a zero-arg signature,
            # not the original one (it would mistake draws for fixtures).
            def wrapper():
                n = getattr(wrapper, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                rng = random.Random(fn.__qualname__)
                for i in range(n):
                    draws = tuple(s.draw(rng) for s in g_strategies)
                    try:
                        fn(*draws)
                    except Exception:
                        print(f"[hypothesis-compat] falsifying example "
                              f"#{i} for {fn.__qualname__}: {draws!r}")
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def _settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            # Works whether @settings sits above or below @given: the @given
            # wrapper checks its own attribute first, then the inner fn's.
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def _assume(condition):
        if not condition:
            raise NotImplementedError(
                "fallback hypothesis cannot reject examples; restructure "
                "the strategy instead of using assume()")

    hypothesis = types.ModuleType("hypothesis")
    hypothesis.given = _given
    hypothesis.settings = _settings
    hypothesis.assume = _assume
    hypothesis.strategies = st

__all__ = ["hypothesis", "st", "HAVE_HYPOTHESIS"]
