"""Resilience supervisor: policy, controller state machine, run loop."""

import jax.numpy as jnp
import pytest

from repro.obs.metrics import REGISTRY
from repro.obs.recorder import FlightRecorder
from repro.train import checkpoint, resilience
from repro.train.resilience import (BACKOFF, HALTED, RESTORING, RUNNING,
                                    ResilienceController, ResiliencePolicy)
from repro.train.train_state import TrainState


class _FakePipe:
    def batch_at(self, step):
        return {"x": float(step)}


def _mk_state(v):
    return TrainState(step=jnp.int32(0), params={"w": jnp.float32(v)},
                      opt_state=[])


# ---------------------------------------------------------------------------
# ResiliencePolicy
# ---------------------------------------------------------------------------

def test_backoff_is_seeded_exponential_with_bounded_jitter():
    pol = ResiliencePolicy(backoff_base=0.1, backoff_factor=2.0,
                           backoff_max=1.0, jitter=0.25, seed=42)
    for attempt in range(1, 8):
        d = min(0.1 * 2.0 ** (attempt - 1), 1.0)
        got = pol.backoff(attempt)
        assert d * 0.75 <= got <= d * 1.25
        # pure function of (seed, attempt, salt)
        assert got == pol.backoff(attempt)
    # salt decorrelates, seed changes the whole sequence
    assert pol.backoff(1, salt=1) != pol.backoff(1, salt=2)
    other = ResiliencePolicy(backoff_base=0.1, backoff_factor=2.0,
                             backoff_max=1.0, jitter=0.25, seed=43)
    assert other.backoff(1) != pol.backoff(1)


def test_backoff_without_jitter_is_exact():
    pol = ResiliencePolicy(backoff_base=0.05, backoff_factor=2.0,
                           backoff_max=0.15, jitter=0.0)
    assert pol.backoff(1) == pytest.approx(0.05)
    assert pol.backoff(2) == pytest.approx(0.10)
    assert pol.backoff(3) == pytest.approx(0.15)  # capped
    assert pol.backoff(9) == pytest.approx(0.15)


@pytest.mark.parametrize("kw", [
    {"max_retries": -1},
    {"max_restores": -1},
    {"backoff_factor": 0.5},
    {"backoff_base": 0.5, "backoff_max": 0.1},
    {"jitter": 1.5},
    {"min_workers": 0},
])
def test_policy_validation(kw):
    with pytest.raises(ValueError):
        ResiliencePolicy(**kw)


# ---------------------------------------------------------------------------
# ResilienceController state machine
# ---------------------------------------------------------------------------

def test_retry_restore_halt_ladder():
    pol = ResiliencePolicy(max_retries=2, max_restores=1, jitter=0.0)
    ctrl = ResilienceController(pol)
    assert ctrl.state == RUNNING

    act, d = ctrl.step_failed(1.0)
    assert (act, ctrl.state) == ("retry", BACKOFF) and d > 0
    act, _ = ctrl.step_failed(2.0)
    assert act == "retry"
    act, d = ctrl.step_failed(3.0)
    assert (act, d, ctrl.state) == ("restore", 0.0, RESTORING)
    assert ctrl.restores_left == 0
    # retry counter reset by the restore: the ladder starts over
    act, _ = ctrl.step_failed(4.0)
    assert act == "retry"
    ctrl.step_failed(5.0)
    act, _ = ctrl.step_failed(6.0)
    assert (act, ctrl.state) == ("halt", HALTED)


def test_step_ok_closes_incident_with_mttr():
    rec = FlightRecorder(64)
    ctrl = ResilienceController(ResiliencePolicy(), recorder=rec)
    inc = ctrl.fault_detected("crash", t_now=2.0, occurred=1.5, worker="w3")
    assert ctrl.open_incidents == [inc]
    ctrl.step_ok(4.0, 0.1)
    assert ctrl.open_incidents == []
    assert inc.mttr == pytest.approx(2.5)  # occurrence -> useful step
    assert inc.steps_to_recover == 1
    recov = rec.events("recovery")
    assert len(recov) == 1
    assert recov[0].args["fault"] == "crash"
    assert recov[0].args["worker"] == "w3"


def test_replay_accounting_after_restore():
    ctrl = ResilienceController(ResiliencePolicy())
    for t in range(5):
        ctrl.step_ok(float(t), 0.1)
    assert (ctrl.useful_steps, ctrl.wasted_steps) == (5, 0)
    ctrl.restored(2, t_now=5.0)  # replay steps 2..4
    # an incident opened before the replay only closes on NEW ground
    inc = ctrl.fault_detected("crash", 5.0, 5.0)
    for t in range(3):
        ctrl.step_ok(5.0 + t, 0.1)
        assert inc.recovered is None  # still replaying
    assert (ctrl.useful_steps, ctrl.wasted_steps) == (5, 3)
    ctrl.step_ok(9.0, 0.1)  # first step past the old high-water mark
    assert inc.recovered is not None
    assert (ctrl.useful_steps, ctrl.wasted_steps) == (6, 3)
    rep = ctrl.report(wall=10.0)
    assert rep.replayed_fraction == pytest.approx(3 / 9)
    assert rep.goodput == pytest.approx(0.6)


def test_evict_readmit_capacity_books():
    ctrl = ResilienceController(ResiliencePolicy(), n_workers=8)
    ctrl.monitor.record("w1", 9.0)
    ctrl.evict(["w1", "w2"], t_now=1.0, kind="evict_crash")
    assert (ctrl.n_active, ctrl.degraded) == (6, True)
    assert "w1" not in ctrl.monitor.ewma  # forgotten on eviction
    ctrl.readmit(["r1", "r2"], t_now=2.0)
    assert (ctrl.n_active, ctrl.degraded) == (8, False)
    rep = ctrl.report(wall=1.0)
    assert rep.actions["evict_crash"] == 1
    assert rep.actions["readmit"] == 1


def test_controller_metrics_land_in_registry():
    before = REGISTRY.counter("resilience_recoveries_total").value(
        kind="preempt")
    ctrl = ResilienceController(ResiliencePolicy())
    ctrl.fault_detected("preempt", 1.0, 0.5, worker="w0")
    ctrl.step_ok(2.0, 0.1)
    after = REGISTRY.counter("resilience_recoveries_total").value(
        kind="preempt")
    assert after == before + 1


def test_discard_and_ckpt_failure_are_counted_not_fatal():
    rec = FlightRecorder(64)
    ctrl = ResilienceController(ResiliencePolicy(), recorder=rec)
    ctrl.discard_step(1.0)
    ctrl.checkpoint_failed(2.0, RuntimeError("disk full"))
    assert ctrl.wasted_steps == 1
    assert ctrl.state == RUNNING
    assert len(rec.events("step_discarded")) == 1
    assert len(rec.events("ckpt_fail")) == 1


# ---------------------------------------------------------------------------
# run_supervised
# ---------------------------------------------------------------------------

def test_run_supervised_clean_run_reports(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path))

    def step_fn(state, batch):
        return (TrainState(state.step + 1, state.params, []), {})

    sleeps = []
    state, final, ctrl = resilience.run_supervised(
        step_fn, _mk_state(0.0), _FakePipe(), ck, 0, 6, ckpt_every=3,
        sleep_fn=sleeps.append)
    assert final == 6
    assert sleeps == []
    assert (ctrl.useful_steps, ctrl.wasted_steps) == (6, 0)
    assert ctrl.last_ckpt_step == 6
    assert checkpoint.latest_step(str(tmp_path)) == 6


def test_run_supervised_restore_budget_exhausted_reraises(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path))
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] > 2:
            raise RuntimeError("persistent failure")
        return (TrainState(state.step + 1, state.params, []), {})

    pol = ResiliencePolicy(max_retries=1, max_restores=2,
                           backoff_base=0.0, backoff_max=0.0, jitter=0.0)
    with pytest.raises(RuntimeError, match="persistent failure"):
        resilience.run_supervised(
            step_fn, _mk_state(0.0), _FakePipe(), ck, 0, 10,
            ckpt_every=2, policy=pol, sleep_fn=lambda d: None)


def test_run_supervised_tolerates_ckpt_write_failure(tmp_path):
    class FlakyCkpt(checkpoint.AsyncCheckpointer):
        def __init__(self, d):
            super().__init__(d)
            self.fails_left = 1

        def save(self, step, state, extra=None):
            if self.fails_left > 0:
                self.fails_left -= 1
                raise OSError("disk full")
            super().save(step, state, extra)

    ck = FlakyCkpt(str(tmp_path))

    def step_fn(state, batch):
        return (TrainState(state.step + 1, state.params, []), {})

    state, final, ctrl = resilience.run_supervised(
        step_fn, _mk_state(0.0), _FakePipe(), ck, 0, 4, ckpt_every=2)
    assert final == 4  # the failed cadence did not kill the run
    assert ctrl._actions.get("ckpt_fail") == 1
    assert checkpoint.latest_step(str(tmp_path)) == 4
