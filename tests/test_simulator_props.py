"""Closed-form simulator property tests (paper Eqs. 6-8 invariants);
skipped without the real hypothesis package."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
from prop_strategies import mk_specs, specs_strategy  # noqa: E402

from repro.core.cost_model import AllReduceModel  # noqa: E402
from repro.core.planner import make_plan  # noqa: E402
from repro.core.simulator import simulate  # noqa: E402


@hypothesis.given(specs_strategy(max_n=10, max_bytes=1 << 24, max_t=1e-2),
                  st.floats(0, 1e-3), st.floats(1e-11, 1e-8),
                  st.floats(0, 0.1))
@hypothesis.settings(max_examples=150, deadline=None)
def test_timeline_invariants(sizes_times, a, b, t_f):
    specs = mk_specs(*sizes_times)
    model = AllReduceModel(a, b)
    for strategy in ("wfbp", "single", "mgwfbp"):
        res = simulate(specs, make_plan(strategy, specs, model), model, t_f)
        # Eq. 7: a bucket's comm starts no earlier than its readiness and
        # no earlier than the previous bucket's end.
        prev_end = 0.0
        for ev in res.events:
            assert ev.start >= ev.ready - 1e-12
            assert ev.start >= prev_end - 1e-12
            assert ev.end == pytest.approx(
                ev.start + model.time(ev.nbytes), abs=1e-12)
            prev_end = ev.end
        assert res.comm_end >= res.t_b_total - 1e-12
        assert res.t_iter == pytest.approx(t_f + res.comm_end, abs=1e-12)
        assert res.t_c_no >= -1e-12
        assert 0.0 <= res.overlap_ratio <= 1.0 + 1e-12
