"""Event-driven cluster simulator (repro.sim) correctness.

The anchor result: on the shared domain — homogeneous workers, one job,
sequential comm — the engine's iteration time equals the closed-form
``core/simulator.simulate`` to 1e-9 for every planner, including the
brute-force-optimal plan.  Everything beyond that domain (stragglers,
jitter, contention, elastic resize) is tested for the properties the
closed form predicts at the boundary plus engine-specific invariants
(determinism under seed, straggler monotonicity, trace round-trips).
"""

import json

import pytest

from repro.core.cost_model import AllReduceModel
from repro.core.planner import make_plan, replan
from repro.core.simulator import simulate
from repro.sim import (ClusterSim, JobSpec, Topology, make_workers,
                       scenarios, trace)
from repro.sim.network import (FlatTopology, HierarchicalTopology,
                               invert_ring, predicted_ring)

STRATEGIES = ("wfbp", "single", "mgwfbp", "dp_optimal")

# The randomized engine == closed-form cross-validation and the straggler
# monotonicity sweep live in tests/test_cluster_sim_props.py (hypothesis).


# ---------------------------------------------------------------------------
# Cross-validation against the closed form.
# ---------------------------------------------------------------------------

def test_multi_iteration_steady_state():
    """Homogeneous BSP: every iteration takes exactly as long as the first."""
    specs, t_f = trace.synthetic_specs(20, seed=3)
    sim = scenarios.paper_scaling(specs, t_f, 8, iters=5,
                                  compute_mode="events")
    job = sim.run().job("train")
    t0 = job.iterations[0].t_iter
    for it in job.iterations[1:]:
        assert it.t_iter == pytest.approx(t0, abs=1e-9)


def test_hierarchical_phases_match_flat_model():
    """Uncontended two-phase ICI+DCN collective == its flat (a, b) view,
    so the unmodified planner stays valid on pod topologies."""
    specs, t_f = trace.synthetic_specs(16, seed=5)
    topo = HierarchicalTopology(pods=4, chips_per_pod=16)
    model = topo.linear_model()
    for strat in STRATEGIES:
        plan = make_plan(strat, specs, model)
        t_cf = simulate(specs, plan, model, t_f).t_iter
        job = JobSpec(name="j", specs=specs, plan=plan, t_f=t_f,
                      workers=make_workers(4), topology=topo)
        res = ClusterSim([job]).run()
        assert res.job("j").iterations[-1].t_iter == \
            pytest.approx(t_cf, abs=1e-9)


def test_events_and_analytic_agree_heterogeneous():
    """The per-tensor event streams and the vectorized ready times are two
    implementations of the same semantics — also off the homogeneous
    domain."""
    specs, t_f = trace.synthetic_specs(24, seed=11)
    for mode_kwargs in (dict(slow_factor=2.5), dict(jitter_sigma=0.3)):
        ts = []
        for cm in ("events", "analytic"):
            sim = scenarios.straggler(specs, t_f, 6, iters=3,
                                      compute_mode=cm, **mode_kwargs)
            ts.append(sim.run().job("train").t_iters)
        for a, b in zip(*ts):
            assert a == pytest.approx(b, abs=1e-9)


# ---------------------------------------------------------------------------
# Engine-specific invariants.
# ---------------------------------------------------------------------------

def test_deterministic_under_seed():
    specs, t_f = trace.synthetic_specs(16, seed=2)
    runs = [scenarios.straggler(specs, t_f, 8, jitter_sigma=0.25, iters=4,
                                seed=123).run()
            for _ in range(2)]
    assert runs[0].job("train").t_iters == runs[1].job("train").t_iters
    assert runs[0].spans == runs[1].spans
    other = scenarios.straggler(specs, t_f, 8, jitter_sigma=0.25, iters=4,
                                seed=124).run()
    assert other.job("train").t_iters != runs[0].job("train").t_iters


def test_straggler_slows_whole_fleet():
    specs, t_f = trace.synthetic_specs(16, seed=2)
    base = scenarios.straggler(specs, t_f, 8, slow_factor=1.0) \
        .run().job("train").t_iters[-1]
    slow = scenarios.straggler(specs, t_f, 8, slow_factor=3.0) \
        .run().job("train").t_iters[-1]
    assert slow > base * 1.5          # one 3x worker drags everyone


def test_contention_stretches_both_jobs():
    sa, tfa = trace.synthetic_specs(20, seed=6)
    sb, tfb = trace.synthetic_specs(14, seed=7)
    alone_a = scenarios.paper_scaling(sa, tfa, 4, iters=2) \
        .run().job("train").t_iters[-1]
    alone_b = scenarios.paper_scaling(sb, tfb, 4, iters=2) \
        .run().job("train").t_iters[-1]
    shared = scenarios.two_jobs(sa, tfa, sb, tfb, n_workers=4, iters=2).run()
    ta = shared.job("job_a").t_iters[-1]
    tb = shared.job("job_b").t_iters[-1]
    assert ta >= alone_a - 1e-12
    assert tb >= alone_b - 1e-12
    assert ta > alone_a or tb > alone_b   # somebody paid for sharing


def test_bursty_background_slows_training():
    specs, t_f = trace.synthetic_specs(16, seed=8)
    quiet = scenarios.paper_scaling(specs, t_f, 8, iters=3) \
        .run().job("train").t_iters[-1]
    noisy = scenarios.bursty(specs, t_f, 8, burst_flows=4,
                             horizon_iters=3).run().job("train").t_iters[-1]
    assert noisy >= quiet - 1e-12


def test_concurrent_mode_no_slower_than_sequential():
    """Removing the in-order issue constraint can only start collectives
    earlier; with fair sharing the last finish never regresses... is not a
    theorem under processor sharing, but it must hold on a plan whose
    buckets never overlap (single bucket)."""
    specs, t_f = trace.synthetic_specs(16, seed=9)
    model = AllReduceModel(1e-4, 1e-9)
    plan = make_plan("single", specs, model)
    ts = {}
    for mode in ("sequential", "concurrent"):
        job = JobSpec(name="j", specs=specs, plan=plan, t_f=t_f,
                      workers=make_workers(4), topology=Topology(model),
                      comm_mode=mode)
        ts[mode] = ClusterSim([job]).run().job("j").t_iters[-1]
    assert ts["concurrent"] == pytest.approx(ts["sequential"], abs=1e-12)


# ---------------------------------------------------------------------------
# Trace I/O + refit + elastic loop.
# ---------------------------------------------------------------------------

def test_chrome_trace_roundtrip(tmp_path):
    specs, t_f = trace.synthetic_specs(12, seed=10)
    res = scenarios.straggler(specs, t_f, 4, jitter_sigma=0.1, iters=2) \
        .run()
    assert res.spans
    path = str(tmp_path / "trace.json")
    trace.write_chrome_trace(path, res.spans)
    with open(path) as f:
        obj = json.load(f)
    assert all(ev["ph"] == "X" and ev["dur"] >= 0
               for ev in obj["traceEvents"])
    assert trace.read_chrome_trace(path) == res.spans


def test_foreign_chrome_trace_import():
    obj = {"traceEvents": [
        {"name": "op", "ph": "X", "pid": 1, "tid": 2, "ts": 1000.0,
         "dur": 500.0},
        {"name": "marker", "ph": "i", "pid": 1, "tid": 2, "ts": 0.0},
    ]}
    spans = trace.from_chrome_trace(obj)
    assert len(spans) == 1
    assert spans[0].start == pytest.approx(1e-3)
    assert spans[0].end == pytest.approx(1.5e-3)


def test_refit_recovers_model_from_engine_timings():
    """Bucket (bytes, duration) samples from an uncontended sequential run
    are exact draws from T(M) = a + b*M — the fit must recover (a, b)."""
    specs, t_f = trace.synthetic_specs(24, seed=12)
    model = AllReduceModel(5e-4, 2e-9)
    plan = make_plan("wfbp", specs, model)
    job = JobSpec(name="j", specs=specs, plan=plan, t_f=t_f,
                  workers=make_workers(4), topology=Topology(model))
    samples = ClusterSim([job]).run().job("j").bucket_samples
    fitted = trace.refit_model(samples)
    assert fitted.a == pytest.approx(model.a, rel=1e-6)
    assert fitted.b == pytest.approx(model.b, rel=1e-6)
    new_plan, new_model = trace.replan_from_samples("mgwfbp", specs, samples)
    assert new_plan.buckets == replan("mgwfbp", specs, model).buckets


def test_refit_rejects_degenerate_samples():
    with pytest.raises(ValueError):
        trace.refit_model([(1024, 1e-3)])
    with pytest.raises(ValueError):
        trace.refit_model([(1024, 1e-3), (1024, 1.1e-3)])


def test_ring_inversion_roundtrip():
    from repro.core import cost_model
    alpha, beta = 3e-5, 2e-9
    m8 = cost_model.ring(8, alpha, beta, 0.0)
    a_hat, b_hat = invert_ring(m8.a, m8.b, 8)
    assert a_hat == pytest.approx(alpha, rel=1e-12)
    assert b_hat == pytest.approx(beta, rel=1e-12)
    m32 = predicted_ring(m8.a, m8.b, 8, 32)
    ref = cost_model.ring(32, alpha, beta, 0.0)
    assert m32.a == pytest.approx(ref.a, rel=1e-12)
    assert m32.b == pytest.approx(ref.b, rel=1e-12)


@pytest.mark.parametrize("algorithm", ["ring", "double_binary_trees",
                                       "recursive_halving_doubling"])
@pytest.mark.parametrize("gamma_ratio", [0.0, 0.1])
def test_inversion_roundtrip_all_algorithms(algorithm, gamma_ratio):
    """Fit (a, b) at N=8, invert to (alpha, beta), re-predict N=64: must
    reproduce the Table-2 model exactly for every invertible collective."""
    from repro.core import cost_model
    from repro.sim.network import invert_model, predicted_model
    alpha, beta = 4e-5, 1.5e-9
    gamma = gamma_ratio * beta
    m8 = cost_model.make_model(algorithm, 8, alpha, beta, gamma)
    a_hat, b_hat = invert_model(algorithm, m8.a, m8.b, 8, gamma_ratio)
    assert a_hat == pytest.approx(alpha, rel=1e-12)
    assert b_hat == pytest.approx(beta, rel=1e-12)
    m64 = predicted_model(algorithm, m8.a, m8.b, 8, 64, gamma_ratio)
    ref = cost_model.make_model(algorithm, 64, alpha, beta, gamma)
    assert m64.a == pytest.approx(ref.a, rel=1e-12)
    assert m64.b == pytest.approx(ref.b, rel=1e-12)


def test_inversion_unknown_algorithm():
    from repro.sim.network import invert_model
    with pytest.raises(ValueError):
        invert_model("binary_tree", 1e-3, 1e-9, 8)


def test_elastic_resize_double_binary_trees():
    """The online refit loop now closes for non-ring collectives too."""
    specs, t_f = trace.synthetic_specs(24, seed=21)
    sim, report = scenarios.elastic_resize(
        specs, t_f, n_before=8, n_after=32, resize_at=1, iters=3,
        algorithm="double_binary_trees", strategy="dp_incremental")
    job = sim.run().job("train")
    assert report.plan_after is not None
    t_after = job.iterations[-1].t_iter
    fresh = scenarios.paper_scaling(specs, t_f, 32,
                                    algorithm="double_binary_trees",
                                    strategy="dp_incremental") \
        .run().job("train").t_iters[-1]
    if not report.used_fallback:
        assert t_after == pytest.approx(fresh, abs=1e-9)
    # the replan went through the incremental planner, not from scratch
    assert report.planner_scratch == 1
    assert report.planner_incremental >= 1


def test_elastic_resize_closes_replanning_loop():
    specs, t_f = trace.synthetic_specs(32, seed=13)
    n_after = 32
    sim, report = scenarios.elastic_resize(specs, t_f, n_before=8,
                                           n_after=n_after, resize_at=1,
                                           iters=4)
    res = sim.run()
    job = res.job("train")
    assert len(job.iterations) == 4
    assert report.plan_after is not None
    # post-resize iterations all use the new cluster + plan
    t_after = job.iterations[-1].t_iter
    fresh = scenarios.paper_scaling(specs, t_f, n_after) \
        .run().job("train").t_iters[-1]
    if not report.used_fallback:
        # exact refit -> the online replan equals planning from scratch
        assert report.fitted is not None
        assert t_after == pytest.approx(fresh, abs=1e-9)
    assert job.iterations[2].t_iter == pytest.approx(t_after, abs=1e-9)


# ---------------------------------------------------------------------------
# Straggler mitigation loop + contention-aware fixpoint.
# ---------------------------------------------------------------------------

def test_straggler_eviction_recovers_fleet():
    """Monitor -> evict -> replan: after the flagged 3x host leaves, the
    iteration time drops to (nearly) the homogeneous fleet's pace."""
    specs, t_f = trace.synthetic_specs(20, seed=17)
    sim, report = scenarios.straggler_eviction(specs, t_f, 8,
                                               slow_factor=3.0, iters=6)
    job = sim.run().job("train")
    assert report.evictions, "straggler never evicted"
    evict_at, names = report.evictions[0]
    assert names == ("w0",)
    assert "w0" not in report.monitor.ewma       # forgotten after eviction
    before = job.iterations[evict_at].t_iter
    after = job.iterations[-1].t_iter
    assert after < before / 1.5
    # remaining fleet is one short of the original, replanned for N-1
    ref = scenarios.straggler(specs, t_f, 7, slow_factor=1.0,
                              strategy="dp_incremental") \
        .run().job("train").t_iters[-1]
    assert after == pytest.approx(ref, abs=1e-9)


def test_straggler_eviction_keeps_min_workers():
    """With everyone slow, the monitor finds no outlier (median moves) and
    nothing is evicted — the loop must not shrink a healthy fleet."""
    specs, t_f = trace.synthetic_specs(12, seed=18)
    sim, report = scenarios.straggler_eviction(
        specs, t_f, 4, slow_factor=1.0, slow_workers=0, iters=4)
    sim.run()
    assert not report.evictions


def test_fixpoint_uncontended_is_exact():
    """No contention -> samples are exact a + b*M draws -> the refit
    reproduces the model, the loop converges immediately, and the
    closed-form prediction equals the engine observation."""
    from repro.core.planner import plan_contention_aware
    specs, t_f = trace.synthetic_specs(24, seed=19)
    model = AllReduceModel(5e-4, 2e-9)

    def evaluate(plan):
        job = JobSpec(name="j", specs=list(specs), plan=plan, t_f=t_f,
                      workers=make_workers(4), topology=Topology(model))
        jr = ClusterSim([job]).run().job("j")
        return jr.iterations[-1].t_iter, jr.bucket_samples

    fix = plan_contention_aware(specs, model, evaluate, t_f=t_f)
    assert fix.converged
    assert len(fix.rounds) <= 2
    last = fix.rounds[-1]
    assert last.predicted_t == pytest.approx(last.observed_t, abs=1e-9)
    assert fix.plan.buckets == make_plan("dp_incremental", specs,
                                         model).buckets


def test_fixpoint_converges_and_beats_baselines_on_two_jobs():
    """The satellite acceptance test: <= 5 fixpoint iterations on the
    multi-job scenario, contended iteration time <= the exclusive-link
    plan's (and WFBP's)."""
    specs, t_f = trace.synthetic_specs(40, seed=20)
    n, iters = 32, 2
    fix = scenarios.contended_two_jobs_plan(specs, t_f, specs, t_f,
                                            n_workers=n, iters=iters,
                                            damping=0.3)
    assert fix.converged
    assert len(fix.rounds) <= 6          # 1 seed eval + <= 5 fixpoint rounds
    model = FlatTopology("ring", n, scenarios.PAPER_ALPHA,
                         scenarios.PAPER_BETA,
                         scenarios.PAPER_GAMMA).linear_model()
    plan_b = make_plan("mgwfbp", specs, model)

    def measure(plan_a):
        sim = scenarios.two_jobs(specs, t_f, specs, t_f, n_workers=n,
                                 iters=iters, plan_a=plan_a, plan_b=plan_b)
        job = sim.run().job("job_a")
        return sum(job.t_iters) / len(job.t_iters)

    t_excl = measure(plan_b)
    t_wfbp = measure(make_plan("wfbp", specs))
    assert fix.observed_t <= t_excl + 1e-12
    assert fix.observed_t <= t_wfbp + 1e-12


def test_fixpoint_never_worse_than_seed_plans():
    """Seed plans are part of the candidate set, so the returned plan's
    observed time is <= every seed's."""
    from repro.core.planner import plan_contention_aware
    specs, t_f = trace.synthetic_specs(16, seed=22)
    model = AllReduceModel(8e-4, 3e-9)
    seeds = [make_plan("wfbp", specs), make_plan("single", specs),
             make_plan("mgwfbp", specs, model)]
    calls = []

    def evaluate(plan):
        job = JobSpec(name="j", specs=list(specs), plan=plan, t_f=t_f,
                      workers=make_workers(2), topology=Topology(model))
        jr = ClusterSim([job]).run().job("j")
        calls.append((plan.buckets, jr.iterations[-1].t_iter))
        return jr.iterations[-1].t_iter, jr.bucket_samples

    fix = plan_contention_aware(specs, model, evaluate, t_f=t_f,
                                seed_plans=seeds)
    # every distinct plan is evaluated exactly once (results are cached)
    assert len(calls) == len({b for b, _ in calls})
    assert len(fix.rounds) >= len(seeds)
    assert fix.observed_t <= min(t for _, t in calls) + 1e-15


def test_specs_json_roundtrip(tmp_path):
    specs, t_f = trace.synthetic_specs(10, seed=14)
    path = str(tmp_path / "profile.json")
    trace.specs_to_json(path, specs, t_f)
    specs2, t_f2 = trace.specs_from_json(path)
    assert specs2 == specs and t_f2 == t_f


def test_scenario_catalog_smoke():
    """Every catalog entry builds and completes, producing >= 1 iteration
    per job and a non-empty span timeline."""
    for name, build in scenarios.CATALOG.items():
        res = build().run()
        assert res.jobs, name
        for job in res.jobs.values():
            assert job.iterations, (name, job.name)
        assert res.spans, name


def test_worker_validation():
    with pytest.raises(ValueError):
        make_workers(0)
    with pytest.raises(ValueError):
        make_workers(4, slow={7: 2.0})
    from repro.sim.workers import WorkerProfile
    with pytest.raises(ValueError):
        WorkerProfile("w", slowdown=0.0)


def test_jobspec_validation():
    specs, t_f = trace.synthetic_specs(4, seed=15)
    model = AllReduceModel(1e-4, 1e-9)
    plan = make_plan("single", specs[:3], model)
    with pytest.raises(ValueError):
        JobSpec(name="j", specs=specs, plan=plan, t_f=t_f,
                workers=make_workers(2), topology=Topology(model))
