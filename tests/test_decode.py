"""Serving-path correctness: prefill + decode == full forward, recurrent
block equivalences, ring-buffer window caches."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MambaConfig, ModelConfig
from repro.models import mamba, registry, xlstm

DECODE_ARCHS = ["qwen2-1.5b", "gemma3-12b", "jamba-v0.1-52b", "xlstm-125m",
                "whisper-base", "deepseek-moe-16b", "phi-3-vision-4.2b"]


def _bundle(arch):
    b = registry.reduced_arch(arch)
    if b.cfg.moe is not None:
        # generous capacity: MoE token dropping is the one legitimate
        # prefill/decode divergence (see test_moe_capacity_drop_divergence)
        cfg = dataclasses.replace(
            b.cfg, moe=dataclasses.replace(b.cfg.moe, capacity_factor=8.0))
        b = dataclasses.replace(b, cfg=cfg)
    return b


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    b = _bundle(arch)
    model = b.model()
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 40
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S + 1), 0,
                                b.cfg.vocab_size)
    batch, batch_full = {"tokens": tokens[:, :S]}, {"tokens": tokens}
    if b.cfg.enc_dec:
        enc = jax.random.normal(jax.random.PRNGKey(9),
                                (B, 32, b.cfg.d_model)).astype(jnp.bfloat16)
        batch["enc_embeds"] = batch_full["enc_embeds"] = enc
    if b.cfg.frontend == "vision":
        pe = jax.random.normal(jax.random.PRNGKey(9),
                               (B, 8, b.cfg.d_model)).astype(jnp.bfloat16)
        batch["prefix_embeds"] = batch_full["prefix_embeds"] = pe

    gt, _ = model.prefill(params, batch_full, max_len=S + 8)
    _, cache = model.prefill(params, batch, max_len=S + 8)
    lg, _ = model.decode_step(params, cache, tokens[:, S:S + 1],
                              jnp.int32(S))
    gt = np.asarray(gt, np.float32)
    lg = np.asarray(lg[:, 0], np.float32)
    rel = np.abs(lg - gt).max() / max(np.abs(gt).max(), 1e-6)
    assert rel < 1e-3, f"{arch}: decode/forward divergence rel={rel}"


def test_multi_token_greedy_decode_stable():
    b = _bundle("qwen2-1.5b")
    model = b.model()
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                b.cfg.vocab_size)
    logits, cache = model.prefill(params, {"tokens": tokens}, max_len=32)
    outs = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(10):
        logits, cache = model.decode_step(params, cache, tok,
                                          jnp.int32(8 + t))
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        outs.append(int(tok[0, 0]))
    assert all(0 <= t < b.cfg.vocab_size for t in outs)


def test_sliding_window_ring_buffer():
    """Window cache holds only `window` slots yet matches the windowed
    full-attention forward."""
    arch = registry.reduced_arch("gemma3-12b")
    cfg = dataclasses.replace(arch.cfg, num_layers=2, sliding_window=16,
                              global_interval=2)
    b = dataclasses.replace(arch, cfg=cfg)
    model = b.model()
    params = model.init(jax.random.PRNGKey(0))
    S = 40  # > window -> ring wraps
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, S + 1), 0,
                                cfg.vocab_size)
    gt, _ = model.prefill(params, {"tokens": tokens}, max_len=S + 4)
    _, cache = model.prefill(params, {"tokens": tokens[:, :S]},
                             max_len=S + 4)
    # local layer cache must be window-sized
    k_shapes = [l.shape for p, l in
                jax.tree_util.tree_flatten_with_path(cache)[0]
                if "['k']" in jax.tree_util.keystr(p)]
    assert min(s[-3] for s in k_shapes) == 16
    lg, _ = model.decode_step(params, cache, tokens[:, S:S + 1],
                              jnp.int32(S))
    rel = (np.abs(np.asarray(lg[:, 0], np.float32) -
                  np.asarray(gt, np.float32)).max()
           / np.abs(np.asarray(gt, np.float32)).max())
    assert rel < 1e-3


def test_moe_capacity_drop_divergence_documented():
    """With tight capacity, prefill drops tokens that decode does not —
    the known train/serve MoE inconsistency (kept, documented)."""
    b = registry.reduced_arch("arctic-480b")
    cfg = dataclasses.replace(
        b.cfg, moe=dataclasses.replace(b.cfg.moe, capacity_factor=8.0))
    model = dataclasses.replace(b, cfg=cfg).model()
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(7), (2, 33), 0,
                                cfg.vocab_size)
    gt, _ = model.prefill(params, {"tokens": tokens}, max_len=40)
    _, cache = model.prefill(params, {"tokens": tokens[:, :32]}, max_len=40)
    lg, _ = model.decode_step(params, cache, tokens[:, 32:33], jnp.int32(32))
    rel = (np.abs(np.asarray(lg[:, 0], np.float32) -
                  np.asarray(gt, np.float32)).max()
           / np.abs(np.asarray(gt, np.float32)).max())
    assert rel < 1e-3  # generous capacity -> exact


# ---------------------------------------------------------------------------
# recurrent block equivalences
# ---------------------------------------------------------------------------

_CFG = ModelConfig(name="t", family="hybrid", num_layers=1, d_model=32,
                   num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128,
                   mamba=MambaConfig(d_state=8, d_conv=4, expand=2))


def test_mamba_chunked_equals_sequential():
    p = mamba.mamba_init(jax.random.PRNGKey(0), _CFG, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 50, 32))
    y_par, state = mamba.mamba_apply(p, x, chunk=16, return_state=True)
    cache = mamba.mamba_cache_init(_CFG, 2, jnp.float32)
    ys = []
    for t in range(50):
        y, cache = mamba.mamba_decode_step(p, cache, x[:, t:t + 1])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_par),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state["h"]),
                               np.asarray(cache["h"]), rtol=1e-4, atol=1e-4)


def test_mlstm_chunked_equals_sequential():
    p = xlstm.mlstm_init(jax.random.PRNGKey(2), _CFG, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 50, 32))
    y_par, state = xlstm.mlstm_apply(p, x, _CFG, chunk=16, return_state=True)
    cache = xlstm.mlstm_cache_init(_CFG, 2)
    ys = []
    for t in range(50):
        y, cache = xlstm.mlstm_decode_step(p, cache, x[:, t:t + 1], _CFG)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_par),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-3, atol=1e-3)


def test_slstm_chunked_equals_sequential():
    p = xlstm.slstm_init(jax.random.PRNGKey(3), _CFG, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 50, 32))
    y_par, state = xlstm.slstm_apply(p, x, chunk=16, return_state=True)
    cache = xlstm.slstm_cache_init(_CFG, 2)
    ys = []
    for t in range(50):
        y, cache = xlstm.slstm_decode_step(p, cache, x[:, t:t + 1])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_par),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state["c"]),
                               np.asarray(cache["c"]), rtol=1e-4, atol=1e-4)
