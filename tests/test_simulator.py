"""Pipeline simulator invariants (paper Eqs. 6-8).

The randomized Eq. 7 timeline invariants live in
tests/test_simulator_props.py (hypothesis)."""

import pytest

from repro.core.cost_model import AllReduceModel
from repro.core.planner import TensorSpec, plan_single, plan_wfbp
from repro.core.simulator import compare_strategies, simulate, speedup


def _specs(sizes, times):
    return [TensorSpec(f"t{i}", s, t) for i, (s, t) in
            enumerate(zip(sizes, times))]


def test_single_layer_closed_form():
    """SyncEASGD: t_iter = t_f + t_b + T(total) exactly (paper Eq. 9)."""
    specs = _specs([100, 200, 300], [1e-3, 2e-3, 3e-3])
    model = AllReduceModel(1e-3, 1e-9)
    res = simulate(specs, plan_single(specs), model, t_f=0.01)
    assert res.t_iter == pytest.approx(0.01 + 6e-3 + model.time(600))
    assert res.overlap_ratio == pytest.approx(0.0)


def test_wfbp_full_overlap_when_comm_fast():
    """Case 1 (paper Fig. 2a): fast comm hides under compute except the
    final tensor's all-reduce."""
    specs = _specs([8] * 5, [1.0] * 5)
    model = AllReduceModel(1e-6, 1e-9)
    res = simulate(specs, plan_wfbp(specs), model)
    assert res.t_c_no == pytest.approx(model.time(8), rel=1e-6)


def test_speedup_eq5():
    """S(N) = N / (1 + t_c_no/(t_f+t_b)) (paper Eqs. 4-5)."""
    specs = _specs([1 << 20] * 4, [1e-3] * 4)
    model = AllReduceModel(1e-3, 1e-9)
    res = simulate(specs, plan_wfbp(specs), model, t_f=2e-3)
    s = speedup(specs, plan_wfbp(specs), model, 2e-3, 16)
    assert s == pytest.approx(16 / (1 + res.t_c_no / (2e-3 + 4e-3)))
    assert s <= 16


def test_compare_strategies_keys():
    specs = _specs([100] * 3, [1e-3] * 3)
    res = compare_strategies(specs, AllReduceModel(1e-4, 1e-9))
    assert set(res) == {"wfbp", "single", "mgwfbp", "dp_optimal"}
