"""Non-BSP schedule correctness, via the shared conformance harness.

Anchors:

* the BSP schedule object is the engine default and cross-validates
  against ``core.simulator.simulate`` to 1e-9 (the PR-1 identity, now
  stated through the Schedule API);
* every schedule's degenerate parameter point reduces to BSP **exactly**
  (no tolerance) in both compute modes, with and without jitter;
* every schedule keeps per-worker clocks monotone, loses no gradients,
  and round-trips its trace — one parametrized suite over
  ``schedule_harness.SCHEDULE_FIXTURES``, so a new schedule is tested by
  adding one fixture line;
* each schedule's homogeneous closed form (``Schedule.predict_t_iter``)
  matches the engine to 1e-9 — the schedule-aware analogue of the
  closed-form cross-validation.
"""

import pytest

from schedule_harness import (MODEL, SCHEDULE_FIXTURES,
                              assert_degenerate_equals_bsp,
                              assert_frontier_monotone,
                              assert_no_lost_gradients,
                              assert_trace_roundtrips, run_job)
from repro.core.cost_model import AllReduceModel
from repro.core.planner import make_plan
from repro.core.simulator import simulate
from repro.sim import trace
from repro.sim.engine import ClusterSim, JobSpec, Topology, \
    event_driven_t_iter
from repro.sim.schedules import (BSP, DAGSchedule, DAGTask, LocalSGD,
                                 OneFoneB, PipelinedAllReduce)
from repro.sim.workers import make_workers

IDS = [s.label for s in SCHEDULE_FIXTURES]


# ---------------------------------------------------------------------------
# The conformance suite: one parametrized pass over every schedule.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", SCHEDULE_FIXTURES, ids=IDS)
@pytest.mark.parametrize("compute_mode,jitter", [("events", 0.0),
                                                 ("analytic", 0.0),
                                                 ("events", 0.25)])
def test_degenerate_reduces_to_bsp(schedule, compute_mode, jitter):
    assert_degenerate_equals_bsp(schedule, compute_mode=compute_mode,
                                 jitter_sigma=jitter, sim_seed=11)


@pytest.mark.parametrize("schedule", SCHEDULE_FIXTURES, ids=IDS)
@pytest.mark.parametrize("jitter", [0.0, 0.3])
def test_frontier_monotonicity(schedule, jitter):
    job, _, _ = run_job(schedule, jitter_sigma=jitter, iters=7, sim_seed=5)
    assert_frontier_monotone(job)


@pytest.mark.parametrize("schedule", SCHEDULE_FIXTURES, ids=IDS)
@pytest.mark.parametrize("strategy", ["mgwfbp", "wfbp", "single"])
def test_no_lost_gradients(schedule, strategy):
    job, _, plan = run_job(schedule, strategy=strategy, iters=7)
    assert_no_lost_gradients(job, plan, schedule)


@pytest.mark.parametrize("schedule", SCHEDULE_FIXTURES, ids=IDS)
def test_trace_roundtrip(schedule, tmp_path):
    job, spans, _ = run_job(schedule, jitter_sigma=0.1, iters=4)
    assert_trace_roundtrips(job, spans, tmp_path)


@pytest.mark.parametrize("schedule", SCHEDULE_FIXTURES, ids=IDS)
def test_predict_matches_engine(schedule):
    """Homogeneous + uncontended: the schedule's closed form equals the
    engine's steady state to 1e-9 (cross-validation per schedule)."""
    specs, t_f = trace.synthetic_specs(24, seed=9)
    plan = make_plan("mgwfbp", specs, MODEL)
    iters = 12
    job, _, _ = run_job(schedule, n_tensors=24, seed=9, iters=iters,
                        compute_mode="analytic")
    if isinstance(schedule, PipelinedAllReduce):
        # steady-state period: consecutive frontier starts
        engine = job.iterations[-1].start - job.iterations[-2].start
    elif isinstance(schedule, LocalSGD):
        # per-iteration average over the last full round
        h = schedule.h
        first = len(job.iterations) - h
        engine = (job.iterations[-1].end - job.iterations[first].start) / h
    else:
        engine = job.iterations[-1].t_iter
    predicted = schedule.predict_t_iter(specs, plan, MODEL, t_f)
    assert engine == pytest.approx(predicted, abs=1e-9)


@pytest.mark.parametrize("schedule", SCHEDULE_FIXTURES, ids=IDS)
def test_dependencies_are_acyclic(schedule):
    """The declared dependency edges form a DAG (next-iteration nodes,
    marked ', are distinct): the frontier can always advance."""
    edges = schedule.dependencies(num_buckets=3)
    assert edges
    nodes = {n for e in edges for n in e}
    indeg = {n: 0 for n in nodes}
    for _, dst in edges:
        indeg[dst] += 1
    frontier = [n for n, d in indeg.items() if d == 0]
    seen = 0
    while frontier:
        n = frontier.pop()
        seen += 1
        for src, dst in edges:
            if src == n:
                indeg[dst] -= 1
                if indeg[dst] == 0:
                    frontier.append(dst)
    assert seen == len(nodes), f"cycle in {schedule.label} dependencies"


# ---------------------------------------------------------------------------
# BSP: the schedule API restates the engine's founding identity.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["wfbp", "single", "mgwfbp",
                                      "dp_optimal"])
def test_bsp_cross_validates_against_closed_form(strategy):
    specs, t_f = trace.synthetic_specs(18, seed=4)
    model = AllReduceModel(8e-4, 3e-9)
    plan = make_plan(strategy, specs, model)
    t_cf = simulate(specs, plan, model, t_f).t_iter
    for compute_mode in ("events", "analytic"):
        t_eng = event_driven_t_iter(specs, plan, model, t_f, n_workers=4,
                                    compute_mode=compute_mode,
                                    schedule=BSP())
        assert t_eng == pytest.approx(t_cf, abs=1e-9)


def test_default_schedule_is_bsp():
    specs, t_f = trace.synthetic_specs(10, seed=1)
    plan = make_plan("mgwfbp", specs, MODEL)
    kw = dict(specs=specs, plan=plan, t_f=t_f, workers=make_workers(3),
              topology=Topology(MODEL), iters=3)
    implicit = ClusterSim([JobSpec(name="a", **kw)]).run().job("a")
    explicit = ClusterSim([JobSpec(name="a", schedule=BSP(), **kw)]) \
        .run().job("a")
    assert implicit.t_iters == explicit.t_iters
    assert [it.worker_start for it in implicit.iterations] == \
        [it.worker_start for it in explicit.iterations]


# ---------------------------------------------------------------------------
# Schedule-specific behaviour.
# ---------------------------------------------------------------------------

def test_pipelined_overlap_beats_bsp_period():
    """Deferring the all-gather helps whenever it fits under the next
    forward (the DeAR regime); construct that regime and check the
    steady-state period drops below BSP's iteration time."""
    specs, t_f = trace.synthetic_specs(24, seed=9)
    model = AllReduceModel(3e-4, 6e-10)     # light enough: f*comm < t_f
    plan = make_plan("mgwfbp", specs, model)
    comm = sum(model.time(b) for b in plan.bucket_bytes(specs))
    assert 0.5 * comm < t_f, "fixture must sit in the DeAR regime"

    def run(schedule):
        job_spec = JobSpec(name="j", specs=specs, plan=plan, t_f=t_f,
                           workers=make_workers(4),
                           topology=Topology(model), iters=8,
                           compute_mode="analytic", schedule=schedule)
        return ClusterSim([job_spec]).run().job("j")

    bsp = run(None)
    pipe = run(PipelinedAllReduce(0.5))
    period = pipe.iterations[-1].start - pipe.iterations[-2].start
    assert period < bsp.t_iters[-1] - 1e-12


def test_pipelined_bucket_occupancy_excludes_deferral_gap():
    """BucketTiming.duration must be fabric occupancy (RS + AG), not the
    whole ready->all-gather-end window — (a, b) refits depend on it."""
    job, _, _ = run_job(PipelinedAllReduce(0.5), iters=3)
    model_t = MODEL.time
    for it in job.iterations:
        for b in it.buckets:
            assert b.duration <= b.end - b.start + 1e-12
            assert b.duration == pytest.approx(model_t(b.nbytes), rel=1e-9)


def test_pipelined_staleness_free_and_worker_frontiers_drift():
    """With a straggler the pipelined frontier lets fast workers start the
    next forward before the slow one finishes backward... is false under
    synchronous RS (the last reduce-scatter gates everyone); what DOES
    drift is the backward start, via the per-worker fwd_end vs ag_done
    race.  Assert the frontier invariant that holds: every worker starts
    at max(own bwd end, rs end) >= the slow worker's compute end only at
    sync, and staleness stays 0."""
    specs, t_f = trace.synthetic_specs(16, seed=6)
    plan = make_plan("mgwfbp", specs, MODEL)
    job_spec = JobSpec(name="j", specs=specs, plan=plan, t_f=t_f,
                       workers=make_workers(3, slow={0: 2.0}),
                       topology=Topology(MODEL), iters=4,
                       compute_mode="analytic",
                       schedule=PipelinedAllReduce(0.5))
    job = ClusterSim([job_spec]).run().job("j")
    for it in job.iterations:
        assert it.staleness == 0
    assert_frontier_monotone(job)


def test_pipelined_worker_compute_excludes_ag_wait():
    """worker_compute is the per-host forward+backward seconds a
    StragglerMonitor consumes: the fleet-wide all-gather stall must not
    leak into it, or a 2x straggler looks like noise under pipelining."""
    specs, t_f = trace.synthetic_specs(16, seed=6)
    model = AllReduceModel(5e-3, 2e-7)      # comm-heavy: big ag_wait
    plan = make_plan("mgwfbp", specs, model)
    job_spec = JobSpec(name="j", specs=specs, plan=plan, t_f=t_f,
                       workers=make_workers(3, slow={0: 2.0}),
                       topology=Topology(model), iters=4,
                       compute_mode="analytic",
                       schedule=PipelinedAllReduce(0.5))
    job = ClusterSim([job_spec]).run().job("j")
    for it in job.iterations:
        compute = dict(it.worker_compute)
        assert compute["w0"] / compute["w1"] == pytest.approx(2.0, rel=1e-9)


def test_localsgd_staleness_and_traffic():
    job, _, plan = run_job(LocalSGD(4), iters=8)
    assert [it.staleness for it in job.iterations] == [1, 2, 3, 0] * 2
    bsp, _, _ = run_job(BSP(), iters=8)
    assert job.bytes_communicated == pytest.approx(
        bsp.bytes_communicated / 4)
    # only sync iterations carry buckets
    assert all(bool(it.buckets) == (it.staleness == 0)
               for it in job.iterations)


def test_localsgd_truncated_final_round_flushes():
    """iters not divisible by H: the run still ends on a sync."""
    job, _, plan = run_job(LocalSGD(4), iters=6)
    assert [it.staleness for it in job.iterations] == [1, 2, 3, 0, 1, 0]
    assert_no_lost_gradients(job, plan, LocalSGD(4))


def test_localsgd_absorbs_jitter_better_than_bsp():
    """A barrier every step pays the fleet max of every draw
    (sum-of-maxes); a barrier every H steps pays the max of each worker's
    H-step sum (max-of-sums <=).  Compare on a comm-free model so only
    the barrier discipline differs."""
    specs, t_f = trace.synthetic_specs(16, seed=8)
    model = AllReduceModel(0.0, 0.0)
    plan = make_plan("single", specs, model)

    def total(schedule):
        job_spec = JobSpec(name="j", specs=specs, plan=plan, t_f=t_f,
                           workers=make_workers(8, jitter_sigma=0.3),
                           topology=Topology(model), iters=8,
                           compute_mode="analytic", schedule=schedule)
        job = ClusterSim([job_spec], seed=3).run().job("j")
        return job.iterations[-1].end - job.iterations[0].start

    assert total(LocalSGD(4)) < total(None) - 1e-12


def test_onefoneb_compresses_overlap_window():
    """Gradient accumulation pushes every bucket's readiness into the last
    micro-batch's backward: less overlap, never a faster iteration than
    BSP on the same plan."""
    bsp, _, _ = run_job(BSP(), iters=3, compute_mode="analytic")
    for m in (2, 4, 8):
        f1b, _, _ = run_job(OneFoneB(m), iters=3, compute_mode="analytic")
        assert f1b.t_iters[-1] >= bsp.t_iters[-1] - 1e-12
        # compute totals unchanged: backward_end - start == t_f + t_b
        for a, b in zip(bsp.iterations, f1b.iterations):
            assert b.backward_end - b.start == \
                pytest.approx(a.backward_end - a.start, rel=1e-9)


def test_hooks_fire_under_schedules():
    """Per-iteration hooks (the elastic machinery) still work off-BSP:
    swap the plan mid-run under each schedule and check it takes effect."""
    specs, t_f = trace.synthetic_specs(16, seed=12)
    plan = make_plan("wfbp", specs, MODEL)
    merged = make_plan("single", specs, MODEL)

    def hook(sim, run, it):
        run.plan = merged

    for schedule in (None, PipelinedAllReduce(0.5), OneFoneB(2),
                     LocalSGD(2)):
        job_spec = JobSpec(name="j", specs=specs, plan=plan, t_f=t_f,
                           workers=make_workers(2),
                           topology=Topology(MODEL), iters=4,
                           compute_mode="analytic", schedule=schedule,
                           hooks={1: hook})
        job = ClusterSim([job_spec]).run().job("j")
        synced = [it for it in job.iterations if it.buckets]
        assert len(synced[0].buckets) == plan.num_buckets
        assert len(synced[-1].buckets) == 1


def test_schedule_validation():
    with pytest.raises(ValueError):
        OneFoneB(0)
    with pytest.raises(ValueError):
        LocalSGD(0)
    with pytest.raises(ValueError):
        PipelinedAllReduce(1.0)
    with pytest.raises(ValueError):
        PipelinedAllReduce(-0.1)
    specs, t_f = trace.synthetic_specs(4, seed=1)
    plan = make_plan("single", specs, MODEL)
    kw = dict(name="j", specs=specs, plan=plan, t_f=t_f,
              workers=make_workers(2), topology=Topology(MODEL))
    with pytest.raises(ValueError):
        JobSpec(comm_mode="concurrent",
                schedule=PipelinedAllReduce(0.5), **kw)
    with pytest.raises(TypeError):
        JobSpec(schedule="pipelined", **kw)


def test_dag_schedule_executes_and_validates():
    tasks = (
        DAGTask("fwd", duration=1.0, worker="w0"),
        DAGTask("bwd", duration=2.0, worker="w0", deps=("fwd",)),
        DAGTask("ar", duration=0.5, link="net", deps=("bwd",)),
        DAGTask("opt", duration=0.1, worker="w0", deps=("ar",)),
    )
    specs, t_f = trace.synthetic_specs(2, seed=1)
    job_spec = JobSpec(name="dag", specs=[], plan=make_plan("wfbp", []),
                       t_f=0.0, workers=make_workers(1),
                       topology=Topology(MODEL),
                       schedule=DAGSchedule(tasks))
    res = ClusterSim([job_spec]).run()
    job = res.job("dag")
    assert job.iterations[0].end == pytest.approx(3.6)
    assert {s.name for s in res.spans} == {"fwd", "bwd", "ar", "opt"}
    with pytest.raises(ValueError):        # cycle
        DAGSchedule((DAGTask("a", deps=("b",)), DAGTask("b", deps=("a",))))
    with pytest.raises(ValueError):        # dangling dep
        DAGSchedule((DAGTask("a", deps=("ghost",)),))
    with pytest.raises(ValueError):        # multi-iteration graphs
        JobSpec(name="dag", specs=[], plan=make_plan("wfbp", []), t_f=0.0,
                workers=make_workers(1), topology=Topology(MODEL),
                iters=2, schedule=DAGSchedule(tasks))


def test_frontier_spans_render_lanes():
    job, _, _ = run_job(LocalSGD(3), iters=6, jitter_sigma=0.2)
    lanes = trace.frontier_spans(job)
    assert all(s.cat == "frontier" and s.pid == "job/frontier"
               for s in lanes)
    by_iter = {}
    for s in lanes:
        by_iter.setdefault(s.args["iter"], []).append(s)
    assert sorted(by_iter) == [it.index for it in job.iterations]
    for it in job.iterations:
        starts = dict(it.worker_start)
        for s in by_iter[it.index]:
            assert s.start == starts[s.tid]
            assert s.args["staleness"] == it.staleness


def test_contention_fixpoint_under_schedule():
    """planner.plan_contention_aware(schedule=...) optimizes bucketing for
    the schedule actually running and still never loses to its seeds."""
    from repro.sim import scenarios
    specs, t_f = trace.synthetic_specs(32, seed=20)
    for schedule in (PipelinedAllReduce(0.5), LocalSGD(2)):
        fix = scenarios.contended_two_jobs_plan(
            specs, t_f, specs, t_f, n_workers=16, iters=2, damping=0.3,
            schedule=schedule)
        assert fix.converged
        assert len(fix.rounds) <= 6
        seed_round = fix.rounds[0]          # the mgwfbp seed plan
        assert fix.observed_t <= seed_round.observed_t + 1e-12


def test_merging_gains_less_under_pipelined():
    """The headline structural claim (cf. DeAR): deferring all-gathers
    already hides part of the communication, so merged-gradient bucketing
    buys less than it does under BSP."""
    specs, t_f = trace.synthetic_specs(40, seed=13, t_b_total=20e-3)

    def gain(schedule):
        ts = {}
        for strategy in ("wfbp", "mgwfbp"):
            job, _, _ = run_job(schedule, n_tensors=40, seed=13, iters=6,
                                strategy=strategy, compute_mode="analytic")
            ts[strategy] = (job.iterations[-1].end -
                            job.iterations[0].start)
        return ts["wfbp"] / ts["mgwfbp"]

    assert gain(PipelinedAllReduce(0.5)) < gain(BSP()) - 1e-9
