"""Path-model property tests; skipped without the hypothesis package.

* ``PathModel.flatten()`` equals the sum of its phases — for random
  phase lists, the flat affine view's time at any size matches summing
  the per-phase affine times (the composition rule the planner's
  exactness rests on), and merge gain under the flat view is the path's
  total startup;
* per-link byte accounting conserves: summing ``link_bytes`` over links
  is the message size weighted by each phase's shard fraction;
* ``fit_path`` on exact per-link samples is the identity (up to float
  noise), and ``blend_path(m, m, w) == m``.
"""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402

from repro.core.cost_model import (PathModel, PathPhase,  # noqa: E402
                                   blend_path, fit_path)

LINKS = st.sampled_from(["ici", "dcn", "net", "nvl"])
PHASES = st.builds(
    PathPhase,
    link=LINKS,
    a=st.floats(min_value=0.0, max_value=1e-2, allow_nan=False),
    b=st.floats(min_value=0.0, max_value=1e-8, allow_nan=False),
    shard_fraction=st.floats(min_value=1e-3, max_value=1.0,
                             allow_nan=False))
PATHS = st.builds(PathModel,
                  st.lists(PHASES, min_size=1, max_size=5).map(tuple))
SIZES = st.integers(min_value=1, max_value=1 << 32)


@hypothesis.given(PATHS, SIZES)
def test_flatten_equals_sum_of_phases(path, nbytes):
    flat = path.flatten()
    assert flat.a == pytest.approx(sum(p.a for p in path.phases),
                                   rel=1e-12, abs=0.0)
    assert flat.b == pytest.approx(sum(p.b for p in path.phases),
                                   rel=1e-12, abs=0.0)
    assert path.time(nbytes) == pytest.approx(
        sum(p.time(nbytes) for p in path.phases), rel=1e-9, abs=1e-18)
    assert flat.time(nbytes) == path.time(nbytes)
    assert path.time(0) == 0.0


@hypothesis.given(PATHS, SIZES, SIZES)
def test_merge_gain_is_total_startup(path, n1, n2):
    """Super-additivity (paper Eq. 11) survives the decomposition: the
    gain from merging two messages is the path's summed startup."""
    flat = path.flatten()
    gain = flat.time(n1) + flat.time(n2) - flat.time(n1 + n2)
    assert gain == pytest.approx(flat.a, rel=1e-6, abs=1e-15)


@hypothesis.given(PATHS, SIZES)
def test_link_bytes_conserve(path, nbytes):
    by_link = path.link_bytes(nbytes)
    assert set(by_link) == set(path.links)
    total = sum(by_link.values())
    expect = sum(p.shard_fraction * nbytes for p in path.phases)
    assert total == pytest.approx(expect, rel=1e-12)
    assert all(v <= nbytes * len(path.phases) for v in by_link.values())


@hypothesis.given(PATHS)
def test_fit_path_identity_on_exact_samples(path):
    """Two exact samples per link reproduce each link's aggregate phase
    costs (unique-link paths reproduce each phase exactly)."""
    sizes = (1 << 16, 1 << 24)
    samples = {
        link: [(n, sum(p.time(n) for p in path.phases_on(link)))
               for n in sizes]
        for link in path.links}
    fitted = fit_path(path, samples)
    for link in path.links:
        got_a = sum(p.a for p in fitted.phases_on(link))
        got_b = sum(p.b for p in fitted.phases_on(link))
        want_a = sum(p.a for p in path.phases_on(link))
        want_b = sum(p.b for p in path.phases_on(link))
        assert got_a == pytest.approx(want_a, rel=1e-6, abs=1e-12)
        assert got_b == pytest.approx(want_b, rel=1e-6, abs=1e-18)


@hypothesis.given(PATHS, st.floats(min_value=0.0, max_value=1.0,
                                   allow_nan=False))
def test_blend_path_self_is_identity(path, w):
    blended = blend_path(path, path, w)
    for got, want in zip(blended.phases, path.phases):
        assert got.link == want.link
        assert got.a == pytest.approx(want.a, rel=1e-12, abs=0.0)
        assert got.b == pytest.approx(want.b, rel=1e-12, abs=0.0)
        assert got.shard_fraction == want.shard_fraction
