"""Planner property tests (require the real hypothesis package;
skipped when it is absent — CI installs it on every leg)."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
from prop_strategies import mk_specs, model_strategy, specs_strategy  # noqa: E402

from repro.core.cost_model import AllReduceModel  # noqa: E402
from repro.core.planner import (plan_brute_force, plan_dp_optimal,  # noqa: E402
                                plan_mgwfbp, plan_single, plan_wfbp)
from repro.core.simulator import simulate  # noqa: E402

SPECS = specs_strategy()
MODELS = model_strategy()


@hypothesis.given(SPECS, MODELS)
@hypothesis.settings(max_examples=150, deadline=None)
def test_dp_optimal_is_optimal(sizes_times, ab):
    sizes, times = sizes_times
    specs = mk_specs(sizes, times)
    model = AllReduceModel(*ab)
    t_dp = simulate(specs, plan_dp_optimal(specs, model), model).t_iter
    t_bf = simulate(specs, plan_brute_force(specs, model), model).t_iter
    assert t_dp <= t_bf + 1e-12


@hypothesis.given(SPECS, MODELS)
@hypothesis.settings(max_examples=150, deadline=None)
def test_mgwfbp_beats_or_matches_baselines(sizes_times, ab):
    """The paper's central claim: MG-WFBP <= min(WFBP, SyncEASGD)."""
    sizes, times = sizes_times
    specs = mk_specs(sizes, times)
    model = AllReduceModel(*ab)
    t_mg = simulate(specs, plan_mgwfbp(specs, model), model).t_iter
    t_wfbp = simulate(specs, plan_wfbp(specs), model).t_iter
    t_single = simulate(specs, plan_single(specs), model).t_iter
    assert t_mg <= min(t_wfbp, t_single) + 1e-12


@hypothesis.given(SPECS, MODELS)
@hypothesis.settings(max_examples=100, deadline=None)
def test_mgwfbp_near_optimal(sizes_times, ab):
    """Algorithm 1 is within 10% of the certified optimum (empirically it
    matches exactly in ~94% of instances; see test_planner.py)."""
    sizes, times = sizes_times
    specs = mk_specs(sizes, times)
    model = AllReduceModel(*ab)
    t_mg = simulate(specs, plan_mgwfbp(specs, model), model).t_iter
    t_dp = simulate(specs, plan_dp_optimal(specs, model), model).t_iter
    assert t_mg <= 1.10 * t_dp + 1e-12
