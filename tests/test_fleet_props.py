"""Fleet-kernel property tests; skipped without the real hypothesis
package (and without jax, which the kernel needs)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
pytest.importorskip("jax", reason="fleet kernel needs jax")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402

from repro.core.cost_model import AllReduceModel  # noqa: E402
from repro.core.planner import MergePlan, TensorSpec, make_plan  # noqa: E402
from repro.core.simulator import simulate  # noqa: E402
from repro.sim.fleet import evaluate_cases, make_case  # noqa: E402
from repro.sim.schedules import (BSP, LocalSGD, OneFoneB,  # noqa: E402
                                 PipelinedAllReduce)


def _random_scenario(rng, *, allow_zero_bytes=True):
    L = int(rng.integers(1, 16))
    lo = 0 if allow_zero_bytes else 1
    specs = [TensorSpec(f"t{i}", int(rng.integers(lo, 1 << 22)),
                        float(rng.uniform(0, 5e-3))) for i in range(L)]
    model = AllReduceModel(float(rng.uniform(0, 2e-3)),
                           float(rng.uniform(1e-11, 1e-8)))
    t_f = float(rng.uniform(0, 0.01))
    # random contiguous partition, not a planner output: padding and
    # masking must hold for ANY legal plan shape
    cuts = sorted(rng.choice(L, size=int(rng.integers(0, L)),
                             replace=False))
    bounds = [0] + [int(c) for c in cuts if c] + [L]
    plan = MergePlan(tuple(tuple(range(a, b))
                           for a, b in zip(bounds, bounds[1:])))
    return specs, t_f, plan, model


@hypothesis.given(st.integers(0, 10_000))
@hypothesis.settings(max_examples=15, deadline=None)
def test_fleet_bsp_matches_simulate(seed):
    """One BSP case through the jitted kernel == the Eq. 7/8 oracle,
    zero-byte tensors included."""
    rng = np.random.default_rng(seed)
    specs, t_f, plan, model = _random_scenario(rng)
    ref = simulate(specs, plan, model, t_f).t_iter
    res = evaluate_cases([make_case(specs, plan, model, t_f=t_f)],
                         iters=2)
    np.testing.assert_allclose(res.t_iter[0, 0], [ref, ref], atol=1e-9)
    assert float(res.span[0, 0]) == pytest.approx(2 * ref, abs=1e-9)


@hypothesis.given(st.integers(0, 10_000))
@hypothesis.settings(max_examples=10, deadline=None)
def test_fleet_padding_invariance(seed):
    """A case's result never depends on its batch-mates: evaluating it
    alone (small K/C padding) equals evaluating it alongside cases with
    far more buckets (large padding) — for every schedule kind."""
    rng = np.random.default_rng(seed)
    scen = [_random_scenario(rng) for _ in range(4)]
    schedules = [None, OneFoneB(int(rng.integers(1, 5))),
                 PipelinedAllReduce(float(rng.uniform(0.0, 1.0))),
                 LocalSGD(int(rng.integers(1, 5)))]
    # a wide ragged filler so batch K-padding differs from singleton's
    big_specs = [TensorSpec(f"b{i}", 1 << 12, 1e-4) for i in range(40)]
    big_model = AllReduceModel(1e-4, 1e-9)
    filler = make_case(big_specs, make_plan("wfbp", big_specs, big_model),
                       big_model)
    cases = [make_case(s, p, m, schedule=sch, t_f=tf)
             for (s, tf, p, m), sch in zip(scen, schedules)] + [filler]
    batched = evaluate_cases(cases, iters=3)
    for ci, c in enumerate(cases):
        alone = evaluate_cases([c], iters=3)
        np.testing.assert_array_equal(batched.t_iter[ci],
                                      alone.t_iter[0])
        np.testing.assert_array_equal(batched.span[ci], alone.span[0])


@hypothesis.given(st.integers(0, 10_000))
@hypothesis.settings(max_examples=10, deadline=None)
def test_fleet_heterogeneous_barrier_matches_scaled_simulate(seed):
    """With a constant fleet-max scale s, the barrier recurrence equals
    simulate() on compute-stretched inputs (t_b and t_f scaled by s) —
    the closed form's definition of heterogeneity."""
    rng = np.random.default_rng(seed)
    specs, t_f, plan, model = _random_scenario(rng)
    s = float(rng.uniform(1.0, 2.5))
    stretched = [TensorSpec(x.name, x.nbytes, x.t_b * s) for x in specs]
    ref = simulate(stretched, plan, model, t_f * s).t_iter
    res = evaluate_cases(
        [make_case(specs, plan, model, t_f=t_f,
                   s_max=np.full((1, 1), s))], iters=1)
    np.testing.assert_allclose(res.t_iter[0, 0, 0], ref, atol=1e-9)
