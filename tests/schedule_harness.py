"""Shared conformance harness for :mod:`repro.sim.schedules`.

Every :class:`~repro.sim.schedules.Schedule` implementation goes through
one parametrized suite (tests/test_schedules.py): degenerate-case
equivalence to BSP (exact, no tolerance — 1 worker of pipelining, H=1,
1 micro-batch), frontier monotonicity, no-lost-gradient accounting, and a
Chrome-trace round trip.  **Adding a schedule to the codebase means adding
one fixture line to** ``SCHEDULE_FIXTURES`` — the suite does the rest.
"""

from repro.core.cost_model import AllReduceModel
from repro.core.planner import MergePlan, make_plan
from repro.sim import trace
from repro.sim.engine import ClusterSim, JobResult, JobSpec, Topology
from repro.sim.schedules import (BSP, LocalSGD, OneFoneB,
                                 PipelinedAllReduce, Schedule)
from repro.sim.workers import make_workers

# One line per schedule under conformance test.  BSP rides along so the
# suite also checks the trivial degenerate (BSP == BSP).
SCHEDULE_FIXTURES: tuple[Schedule, ...] = (
    BSP(),
    PipelinedAllReduce(),                   # ag_fraction = 0.5
    PipelinedAllReduce(ag_fraction=0.25),
    OneFoneB(4),
    OneFoneB(2),
    LocalSGD(4),
    LocalSGD(3),
)

MODEL = AllReduceModel(5e-4, 2e-9)


def run_job(schedule: Schedule | None, *, n_tensors: int = 20,
            seed: int = 3, n_workers: int = 4, iters: int = 6,
            strategy: str = "mgwfbp", compute_mode: str = "events",
            jitter_sigma: float = 0.0, sim_seed: int = 0,
            ) -> tuple[JobResult, list, MergePlan]:
    """One single-job cluster under ``schedule``; returns
    (job result, spans, plan)."""
    specs, t_f = trace.synthetic_specs(n_tensors, seed=seed)
    plan = make_plan(strategy, specs, MODEL)
    job = JobSpec(name="job", specs=specs, plan=plan, t_f=t_f,
                  workers=make_workers(n_workers,
                                       jitter_sigma=jitter_sigma),
                  topology=Topology(MODEL, n_workers=n_workers),
                  iters=iters, compute_mode=compute_mode,
                  schedule=schedule)
    res = ClusterSim([job], seed=sim_seed).run()
    return res.job("job"), res.spans, plan


def assert_degenerate_equals_bsp(schedule: Schedule, **kw) -> None:
    """``schedule.degenerate()`` must reproduce BSP EXACTLY — same floats,
    not approximately: the degenerate parameter point shares BSP's
    arithmetic expression for expression."""
    deg = schedule.degenerate()
    got, _, _ = run_job(deg, **kw)
    ref, _, _ = run_job(BSP(), **kw)
    assert got.t_iters == ref.t_iters, (deg, got.t_iters, ref.t_iters)
    assert got.bytes_communicated == ref.bytes_communicated
    for a, b in zip(ref.iterations, got.iterations):
        assert a.index == b.index
        assert a.start == b.start and a.end == b.end
        assert a.worker_start == b.worker_start
        assert a.worker_end == b.worker_end
        assert a.worker_compute == b.worker_compute
        assert b.staleness == 0
        assert len(a.buckets) == len(b.buckets)
        for x, y in zip(a.buckets, b.buckets):
            assert (x.bucket, x.nbytes) == (y.bucket, y.nbytes)
            assert x.ready == y.ready
            assert x.start == y.start
            # compare fabric occupancy, not `end`: a degenerate pipelined
            # schedule finishes its zero-cost all-gathers at the barrier,
            # which moves `end` but not the communication time
            assert x.duration == y.duration


def assert_frontier_monotone(job: JobResult) -> None:
    """Per-worker clocks never go backwards: each iteration's end is at or
    after its start, consecutive iterations of one worker don't overlap,
    and iteration indices/starts are ordered."""
    prev_end: dict[str, float] = {}
    prev_idx = -1
    prev_start = float("-inf")
    for it in job.iterations:
        assert it.index == prev_idx + 1
        prev_idx = it.index
        assert it.start >= prev_start
        prev_start = it.start
        assert it.end >= it.start
        ends = dict(it.worker_end)
        assert set(ends) == {w for w, _ in it.worker_start}
        for w, s in it.worker_start:
            assert ends[w] >= s, (it.index, w)
            if w in prev_end:
                assert s >= prev_end[w], (it.index, w, s, prev_end[w])
        prev_end.update(ends)


def assert_no_lost_gradients(job: JobResult, plan: MergePlan,
                             schedule: Schedule) -> None:
    """Every gradient is synchronized exactly once per sync point, and no
    gradient outlives a round: synchronous schedules sync all buckets every
    iteration; LocalSGD(H) syncs all buckets at staleness-0 iterations, at
    most H-1 apart, with nothing in between — and the run always ends on a
    sync (the flush)."""
    full = list(range(plan.num_buckets))
    since_sync = 0
    for it in job.iterations:
        if schedule.synchronous:
            assert it.staleness == 0
        if it.staleness == 0:
            since_sync = 0
            assert sorted(b.bucket for b in it.buckets) == full
        else:
            since_sync += 1
            assert not it.buckets, it
            h = getattr(schedule, "h", 1)
            assert it.staleness == since_sync < h
    assert job.iterations[-1].staleness == 0, "run must end on a sync"
    # fraction-weighted byte accounting agrees with the bucket records
    # (split halves sum back to exactly one plan's worth per sync)
    recorded = sum(b.nbytes for it in job.iterations for b in it.buckets)
    assert abs(job.bytes_communicated - recorded) < 1e-6


def assert_trace_roundtrips(job: JobResult, spans: list,
                            tmp_path) -> None:
    """Engine spans plus the per-worker frontier lanes survive a Chrome
    trace export/import losslessly."""
    lanes = trace.frontier_spans(job)
    assert len(lanes) == sum(len(it.worker_start) for it in job.iterations)
    path = str(tmp_path / "schedule_trace.json")
    all_spans = list(spans) + lanes
    trace.write_chrome_trace(path, all_spans)
    assert trace.read_chrome_trace(path) == all_spans
