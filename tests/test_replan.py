"""Online refit + replan: the controller that closes the sim->real loop.

Fast tests drive :class:`repro.train.replan.ReplanController` with
synthetic IterationRecords; the slow test runs the full loop on a real
4-device CPU mesh in a subprocess (instrument -> refit -> Planner.update
-> step swap) and pins that a swap never changes numerics.
"""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import planner as planner_mod
from repro.core.cost_model import AllReduceModel
from repro.core.planner import TensorSpec
from repro.core.simulator import simulate
from repro.obs.recorder import FlightRecorder, IterationRecord
from repro.train import replan


def _specs(n=8, nbytes=4 << 20, t_b=1e-3):
    return [TensorSpec(f"t{i}", nbytes, t_b) for i in range(n)]


def _record(i, t_iter):
    return IterationRecord(source="train", job="train", iteration=i,
                           start=float(i), end=float(i) + t_iter,
                           backward_end=float(i))


def test_controller_refits_and_swaps():
    """Observed comm 3x slower than modeled -> model rescales, the DP
    replan beats wfbp, and the rebuild callback swaps the step."""
    specs = _specs()
    model = AllReduceModel(1e-4, 1e-9)
    plan = planner_mod.plan_wfbp(specs)
    rec = FlightRecorder()
    rebuilt = []

    def rebuild(new_plan):
        rebuilt.append(new_plan)
        return lambda s, b: (s, b)          # stand-in step

    ctl = replan.ReplanController(specs, plan, model, rebuild=rebuild,
                                  recorder=rec, warmup=1, interval=2,
                                  damping=1.0, hysteresis=1e-6)
    pred = simulate(specs, plan, model)
    slow = pred.t_b_total + 3.0 * pred.t_c_no   # stretched fabric
    decisions = [ctl.observe(_record(i, slow)) for i in range(4)]
    fired = [d for d in decisions if d is not None]
    assert len(fired) == 1
    d = fired[0]
    assert d.stretch == pytest.approx(3.0, rel=1e-6)
    assert ctl.model.a == pytest.approx(3e-4, rel=1e-6)
    assert d.swapped and rebuilt and ctl.step_fn is not None
    assert ctl.plan.num_buckets < plan.num_buckets   # merged under higher a
    assert d.predicted_new < d.predicted_old
    # the planner's decision landed in the flight recorder
    assert rec.events("planner_update")


def test_controller_stable_when_prediction_holds():
    """Observations matching the model -> stretch 1, same plan, no swap."""
    specs = _specs()
    model = AllReduceModel(1e-4, 1e-9)
    plan = planner_mod.Planner(specs, model).plan()   # already optimal
    ctl = replan.ReplanController(specs, plan, model, warmup=1, interval=2,
                                  damping=1.0, hysteresis=0.05)
    pred = simulate(specs, plan, model)
    for i in range(6):
        ctl.observe(_record(i, pred.t_iter))
    assert ctl.decisions and all(not d.swapped for d in ctl.decisions)
    for d in ctl.decisions:
        assert d.stretch == pytest.approx(1.0, rel=1e-6)
    assert ctl.plan.buckets == plan.buckets


def test_controller_warmup_and_window():
    """No decision before warmup + a full window of records."""
    specs = _specs(4)
    model = AllReduceModel(1e-4, 1e-9)
    ctl = replan.ReplanController(specs, planner_mod.plan_wfbp(specs),
                                  model, warmup=3, interval=4)
    for i in range(6):                      # 3 warmup + 3 < interval
        assert ctl.observe(_record(i, 1.0)) is None
    assert ctl.observe(_record(6, 1.0)) is not None


def test_stretch_clamped():
    specs = _specs(4)
    model = AllReduceModel(1e-4, 1e-9)
    ctl = replan.ReplanController(specs, planner_mod.plan_wfbp(specs),
                                  model, warmup=0, interval=1, damping=1.0,
                                  max_stretch=5.0)
    d = ctl.observe(_record(0, 1e6))        # absurd wall time
    assert d.stretch == 5.0


def test_update_backward_times_incremental():
    specs = _specs(6)
    model = AllReduceModel(1e-4, 1e-9)
    ctl = replan.ReplanController(specs, planner_mod.plan_wfbp(specs), model)
    before = ctl.planner.scratch_plans
    ctl.update_backward_times({"t3": 5e-3, "t4": 6e-3})
    assert ctl.planner.scratch_plans == before      # incremental, no rebuild
    assert ctl.specs[3].t_b == 5e-3 and ctl.specs[4].t_b == 6e-3
    assert ctl.planner.specs[3].t_b == 5e-3
    # unknown / non-positive entries are ignored
    ctl.update_backward_times({"nope": 1.0, "t0": 0.0})
    assert ctl.specs[0].t_b == 1e-3


def test_drift_alerts_flow_to_recorder():
    specs = _specs()
    model = AllReduceModel(1e-4, 1e-9)
    plan = planner_mod.Planner(specs, model).plan()
    rec = FlightRecorder()
    ctl = replan.ReplanController(specs, plan, model, recorder=rec,
                                  warmup=2, interval=100,    # never refit
                                  drift_threshold=0.10)
    pred = simulate(specs, plan, model)
    for i in range(5):
        ctl.observe(_record(i, pred.t_iter * 2.0))   # sustained 100% drift
    assert rec.events("drift_alert")


def test_measure_comm_model_single_device():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    m = replan.measure_comm_model(mesh, ("data",),
                                  sizes_bytes=(1 << 12, 1 << 14),
                                  n_warmup=0, n_iters=1)
    assert m.a >= 0.0 and m.b >= 0.0
    assert m.time(1 << 20) > 0.0


# ---------------------------------------------------------------------------
# full loop on 4 real (forced-host) devices — subprocess so XLA_FLAGS land
# before jax import; the rest of the suite keeps seeing 1 device.
# ---------------------------------------------------------------------------

_LOOP_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import make_mesh, use_mesh
from repro.models import registry
from repro.obs import recorder
from repro.train import replan
from repro.train.step import build_train_step, instrument_step

bundle = registry.reduced_arch("qwen2-1.5b")
par = dataclasses.replace(bundle.parallel, dp_axes=("data",), zero=0,
                          ep_axis="", attn_chunk=32)
shape = ShapeConfig("tiny", "train", 16, 8)
run_cfg = dataclasses.replace(bundle.run_config("train_4k", par),
                              shape=shape, microbatch=0)
model = bundle.model(par)
mesh = make_mesh((4,), ("data",))

# 1. MEASURE: real timed collectives fit the effective (a, b)
mdl = replan.measure_comm_model(mesh, ("data",),
                                sizes_bytes=(1 << 14, 1 << 18, 1 << 21),
                                n_iters=2)
assert mdl.a >= 0.0 and mdl.time(1 << 20) > 0.0

def run(steps, use_replan):
    rec = recorder.FlightRecorder()
    with use_mesh(mesh):
        if use_replan:
            ctl, init_fn, art = replan.closed_loop(
                model, run_cfg, mesh, strategy="wfbp", comm_model=mdl,
                recorder=rec, warmup=1, interval=2, hysteresis=1e-9,
                damping=0.5)
        else:
            step_fn, init_fn, art = build_train_step(
                model, run_cfg, mesh, strategy="wfbp", comm_model=mdl)
            ctl = None
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), art.state_pspecs,
                          is_leaf=lambda x: isinstance(x, P))
        state = jax.device_put(init_fn(jax.random.PRNGKey(0)), sh)
        pipe = DataPipeline(bundle.cfg, shape, seed=0)
        fn = ctl.step_fn if ctl is not None else jax.jit(step_fn)
        for s in range(steps):
            if ctl is not None:
                fn = ctl.step_fn          # may have been swapped off-path
            state, m = fn(state, pipe.batch_at(s))
    return state, rec, ctl

# 2/3. EXECUTE + REFIT + REPLAN vs a never-replanned reference run
state_ref, _, _ = run(8, use_replan=False)
state_ctl, rec, ctl = run(8, use_replan=True)

assert ctl.decisions, "controller never refit"
assert ctl.swaps, "controller never swapped despite wfbp start + DP optimum"
assert rec.events("planner_update"), "Planner.update left no event trail"
assert rec.iterations("train"), "instrument_step recorded nothing"
swap = ctl.swaps[0]
assert swap.new_plan.num_buckets < swap.old_plan.num_buckets
assert swap.predicted_new <= swap.predicted_old

# 4. NUMERICS: a swap changes scheduling, never math — bit-identical params
for a, b in zip(jax.tree.leaves(state_ref.params),
                jax.tree.leaves(state_ctl.params)):
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))

# 5. KERNEL PARITY inside all-manual shard_map: the Pallas packed path ==
#    the plain concatenate path, for allreduce and for RS+AG
from repro.core import bucketer, comm, planner as planner_mod
from repro.train.step import _shard_map
tree = {"w": jnp.arange(4 * 600, dtype=jnp.float32).reshape(4, 600),
        "b": jnp.arange(40, dtype=jnp.float32) * 0.5}
metas = bucketer.leaf_metadata(tree)
specs = [planner_mod.TensorSpec(m.path, m.nbytes, 1e-4) for m in metas]
plan = planner_mod.plan_single(specs)

def make_ar(use_kernel):
    def body(t):
        return comm.bucketed_allreduce(t, plan, "data", mode="packed",
                                       use_kernel=use_kernel)
    return jax.jit(_shard_map(body, mesh, in_specs=(P(),), out_specs=P(),
                              manual_axes=frozenset({"data"})))

plain = make_ar(False)(tree)
kern = make_ar(True)(tree)
for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(kern)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

def make_rsag(use_kernel):
    def body(t):
        shards, bm = comm.bucketed_reduce_scatter(t, plan, "data",
                                                  use_kernel=use_kernel)
        return comm.bucketed_allgather(shards, bm, t, "data",
                                       use_kernel=use_kernel)
    return jax.jit(_shard_map(body, mesh, in_specs=(P(),), out_specs=P(),
                              manual_axes=frozenset({"data"})))

plain = make_rsag(False)(tree)
kern = make_rsag(True)(tree)
for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(kern)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

print("REPLAN-LOOP-PASS")
"""


@pytest.mark.slow
def test_closed_loop_multidevice():
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _LOOP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "REPLAN-LOOP-PASS" in res.stdout, \
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}"
