"""Observability spine: metrics, recorder, timeline, drift.

Covers the obs acceptance criteria end to end:

* registry semantics (labels, kinds, snapshot delta/merge exactness);
* flight-recorder ring eviction + lossless JSONL round-trip;
* engine/planner/co-planner emission into one recorder;
* drift monitor silent-when-calibrated, and the full
  degrade -> alert -> refit -> replan -> recovered loop;
* sim + real-step records merging into ONE golden-pinned Chrome trace
  (regen:  PYTHONPATH=src python tests/test_obs.py --regen).
"""

import json
import pathlib
import types

import pytest

from repro.core.cost_model import AllReduceModel
from repro.core.planner import Planner, SpecDelta, make_plan
from repro.obs import drift, metrics, recorder, timeline
from repro.sim import scenarios, trace
from repro.sim.engine import ClusterSim, JobSpec, Topology
from repro.sim.schedules import LocalSGD
from repro.sim.workers import make_workers

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
MODEL = AllReduceModel(4e-4, 1.5e-9)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_labels_and_kind_guard():
    reg = metrics.Registry()
    c = reg.counter("requests_total", "test")
    c.inc(job="a")
    c.inc(2.0, job="a")
    c.inc(job="b")
    assert c.value(job="a") == 3.0
    assert c.value(job="b") == 1.0
    assert c.value(job="missing") == 0.0
    with pytest.raises(ValueError):
        c.inc(-1.0)
    with pytest.raises(TypeError):
        reg.gauge("requests_total", "redeclared as another kind")


def test_gauge_set_add():
    reg = metrics.Registry()
    g = reg.gauge("depth", "test")
    g.set(5.0)
    g.add(-2.0)
    assert g.value() == 3.0


def test_histogram_buckets_are_exact_and_quantile_bounded():
    reg = metrics.Registry()
    h = reg.histogram("lat", "test")
    values = [0.001, 0.25, 0.5, 1.0, 3.0, 100.0]
    for v in values:
        h.observe(v)
    assert h.count() == len(values)
    q = h.quantile(0.5)
    assert min(values) <= q <= max(values)
    # fixed exponential buckets: same value always lands in the same
    # bucket, so merged histograms are exact integer sums
    assert metrics.bucket_index(0.75) == metrics.bucket_index(0.6)
    assert metrics.bucket_upper_edge(metrics.bucket_index(0.75)) == 1.0


def test_snapshot_delta_and_merge():
    reg = metrics.Registry()
    c = reg.counter("ops_total", "test")
    h = reg.histogram("t", "test")
    c.inc(3.0)
    h.observe(1.0)
    before = reg.snapshot()
    c.inc(2.0)
    h.observe(2.0)
    h.observe(4.0)
    after = reg.snapshot()

    d = after.delta(before)
    assert d.value("ops_total") == 2.0
    assert d.hist("t")["count"] == 2

    merged = before.merge(d)
    assert merged.value("ops_total") == after.value("ops_total")
    assert merged.hist("t") == after.hist("t")

    # registry-independent merge stays exact too
    other = metrics.Registry()
    other.counter("ops_total", "test").inc(10.0)
    assert after.merge(other.snapshot()).value("ops_total") == 15.0


def test_snapshot_dict_round_trip():
    reg = metrics.Registry()
    reg.counter("c", "t").inc(job="x")
    reg.gauge("g", "t").set(2.5)
    reg.histogram("h", "t").observe(0.125)
    snap = reg.snapshot()
    back = metrics.Snapshot.from_dict(
        json.loads(json.dumps(snap.to_dict())))
    assert back.to_dict() == snap.to_dict()


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def _iter_record(i, source="sim", job="train"):
    return recorder.IterationRecord(
        source=source, job=job, iteration=i, start=float(i),
        end=i + 0.75, backward_end=i + 0.5, staleness=i % 2,
        buckets=(recorder.BucketRecord(0, 1024, i + 0.1, i + 0.2,
                                       i + 0.6, comm_s=0.3),),
        worker_compute=(("w0", 0.4), ("w1", 0.5)),
        worker_start=(("w0", float(i)), ("w1", float(i))),
        worker_end=(("w0", i + 0.7), ("w1", i + 0.75)),
        link_bytes=(("net", 1024.0),), link_busy=(("net", 0.3),),
        args={"plan": "abc"})


def test_ring_eviction_is_counted():
    rec = recorder.FlightRecorder(capacity=4)
    for i in range(6):
        rec.record(_iter_record(i))
    assert len(rec) == 4
    assert rec.evicted == 2
    assert rec.recorded == 6
    assert [r.iteration for r in rec.iterations()] == [2, 3, 4, 5]


def test_jsonl_round_trip_is_lossless(tmp_path):
    rec = recorder.FlightRecorder()
    rec.record(_iter_record(0))
    rec.record(recorder.EventRecord(
        kind="planner_update", time=1.0, source="planner",
        args={"plan": "deadbeef", "model_a": 9.72e-4 / 14}))
    rec.record(_iter_record(1, source="train"))
    path = tmp_path / "rec.jsonl"
    rec.write(str(path))
    back = recorder.read_jsonl(str(path))
    assert tuple(back) == rec.records       # bit-for-bit, dataclass ==


def test_unknown_record_type_rejected():
    with pytest.raises(ValueError):
        recorder.record_from_obj({"type": "mystery"})
    with pytest.raises(TypeError):
        recorder.FlightRecorder().record("not a record")


def test_plan_fingerprint_tracks_structure():
    specs, _ = trace.synthetic_specs(12, seed=3)
    p1 = make_plan("mgwfbp", specs, MODEL)
    p2 = make_plan("wfbp", specs, MODEL)
    assert recorder.plan_fingerprint(p1) == recorder.plan_fingerprint(p1)
    assert recorder.plan_fingerprint(p1) != recorder.plan_fingerprint(p2)


# ---------------------------------------------------------------------------
# producers: engine, planner, co-planner
# ---------------------------------------------------------------------------

def _small_sim(recorder_=None, schedule=None, iters=3):
    specs, t_f = trace.synthetic_specs(10, seed=21)
    plan = make_plan("mgwfbp", specs, MODEL)
    job = JobSpec(name="train", specs=specs, plan=plan, t_f=t_f,
                  workers=make_workers(3), topology=Topology(MODEL, 3),
                  iters=iters, schedule=schedule)
    return ClusterSim([job], seed=7, recorder=recorder_)


def test_engine_emits_records_matching_job_result():
    rec = recorder.FlightRecorder()
    res = _small_sim(rec).run()
    its = rec.iterations("train")
    assert len(its) == 3
    for r, it in zip(its, res.job("train").iterations):
        assert r == recorder.from_iteration_result(it, job="train")
    # and the sim_iteration_seconds histogram saw every iteration
    assert metrics.REGISTRY.histogram(
        "sim_iteration_seconds", "").count(job="train") >= 3


def test_engine_without_recorder_emits_nothing():
    sim = _small_sim(None)
    assert sim.recorder is None
    sim.run()        # must not touch the registry's iteration histogram


def test_planner_emits_counters_and_decision_events():
    specs, _ = trace.synthetic_specs(16, seed=4)
    rec = recorder.FlightRecorder()
    before = metrics.REGISTRY.snapshot()
    pl = Planner(specs, MODEL, recorder=rec)
    pl.update(SpecDelta(model=AllReduceModel(MODEL.a * 2, MODEL.b)))
    pl.append(specs[0])
    d = metrics.REGISTRY.snapshot().delta(before)
    assert d.value("planner_scratch_plans_total") == 1.0
    assert d.value("planner_incremental_updates_total") == 2.0
    events = rec.events("planner_update")
    assert len(events) == 2
    assert events[0].args["plan"] == recorder.plan_fingerprint(pl.plan()) \
        or events[0].args["plan"]            # fingerprint present & stable


def test_coplanner_emits_round_events():
    from repro.core.planner import plan_contention_aware
    from repro.core.simulator import simulate

    specs, t_f = trace.synthetic_specs(12, seed=9)
    rec = recorder.FlightRecorder()
    before = metrics.REGISTRY.snapshot()

    def evaluate(plan):
        r = simulate(specs, plan, MODEL, t_f)
        return r.t_iter, [(sum(specs[i].nbytes for i in b),
                           MODEL.time(sum(specs[i].nbytes for i in b)))
                          for b in plan.buckets]

    plan_contention_aware(specs, MODEL, evaluate, t_f=t_f, max_rounds=2,
                          recorder=rec)
    rounds = rec.events("coplan_round")
    assert rounds, "co-planner recorded no rounds"
    kinds = {e.args["round_kind"] for e in rounds}
    assert "response" in kinds
    d = metrics.REGISTRY.snapshot().delta(before)
    assert d.value("coplanner_rounds_total", kind="response") >= 1.0


# ---------------------------------------------------------------------------
# timeline: counters + staleness/frontier tracks
# ---------------------------------------------------------------------------

def test_chrome_trace_with_counters_round_trips():
    spans = [timeline.Span("s", "step", "j", "w", 0.0, 1.0)]
    counters = [timeline.CounterSample("staleness", "j/counters", 0.5,
                                       {"staleness": 2})]
    obj = timeline.to_chrome_trace(spans, counters)
    assert [e["ph"] for e in obj["traceEvents"]] == ["X", "C"]
    assert timeline.from_chrome_trace(obj) == spans
    assert timeline.chrome_counters(obj) == counters
    # counters absent -> byte-identical to the historical format
    # (golden traces depend on this)
    assert timeline.to_chrome_trace(spans) == trace.to_chrome_trace(spans)


def test_staleness_and_frontier_drift_tracks():
    res = _small_sim(schedule=LocalSGD(2), iters=4).run()
    samples = timeline.counter_samples_from(res.job("train"))
    staleness = [c for c in samples if c.name == "staleness"]
    frontier = [c for c in samples if c.name == "frontier_drift"]
    assert len(staleness) == 4 and len(frontier) == 4
    # LocalSGD(2): odd iterations run locally -> staleness sawtooth
    assert [c.values["staleness"] for c in staleness] == [1, 0, 1, 0]
    # every worker appears as a series, drift is nonnegative, and at
    # least one worker sits exactly on the frontier
    for c in frontier:
        assert set(c.values) == {"w0", "w1", "w2"}
        assert min(c.values.values()) == 0.0
        assert all(v >= 0.0 for v in c.values.values())


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------

def test_drift_monitor_silent_then_alerts_then_resets():
    m = drift.DriftMonitor(threshold=0.2, alpha=1.0, warmup=1)
    assert m.observe(0, 1.0, 1.1) is None          # 10% < threshold
    alert = m.observe(1, 1.0, 1.5)
    assert alert is not None and alert.kind == "iteration"
    assert alert.ewma == pytest.approx(0.5)
    m.reset()
    assert m.observe(2, 1.0, 1.05) is None
    assert len(m.alerts) == 1


def test_drift_monitor_per_link():
    m = drift.DriftMonitor(threshold=0.2, alpha=1.0, warmup=1)
    model = {"net": AllReduceModel(1e-3, 1e-9)}
    good = [(1 << 20, 1e-3 + 1e-9 * (1 << 20))]
    bad = [(1 << 20, 5e-3)]
    assert m.observe_links(0, model, {"net": good}) == []
    alerts = m.observe_links(1, model, {"net": bad})
    assert alerts and alerts[0].link == "net"
    assert m.residual("link:net") > 0.2


def test_fit_link_models_skips_degenerate_links():
    model = AllReduceModel(2e-4, 3e-9)
    samples = {"good": [(1 << 18, model.time(1 << 18)),
                        (1 << 22, model.time(1 << 22))],
               "degenerate": [(1 << 20, 1.0), (1 << 20, 1.0)]}
    fitted = drift.fit_link_models(samples)
    assert set(fitted) == {"good"}
    assert fitted["good"].a == pytest.approx(model.a, rel=1e-6)
    assert fitted["good"].b == pytest.approx(model.b, rel=1e-6)


def test_drift_end_to_end_degrade_alert_replan_recover():
    """The obs acceptance criterion: mid-run bandwidth change -> drift
    alert -> refit + replan -> post-replan residual back under
    threshold."""
    specs, t_f = trace.synthetic_specs(24, seed=5)
    rec = recorder.FlightRecorder()
    sim, rep = scenarios.drift_monitored(specs, t_f, iters=8, degrade_at=2,
                                         degrade_factor=4.0, recorder=rec)
    sim.run()
    assert rep.alerts, "degradation never raised a drift alert"
    assert rep.replans >= 1
    assert rep.plans[-1].buckets != rep.plans[0].buckets, \
        "4x slower fabric should change the optimal bucketing"
    # the refit actually learned the degraded per-byte cost
    assert rep.models[-1].b > rep.models[0].b * 2
    post = [r for i, r in rep.residuals
            if i > rep.alerts[-1].iteration]
    assert post and max(post) <= rep.monitor.threshold, post
    # the whole episode is on the flight recorder
    assert rec.events("drift_alert")
    assert rec.events("planner_update")
    assert len(rec.iterations("train")) == 8


def test_drift_calibrated_control_stays_silent():
    specs, t_f = trace.synthetic_specs(24, seed=5)
    sim, rep = scenarios.drift_monitored(specs, t_f, iters=6,
                                         degrade_at=None)
    sim.run()
    assert not rep.alerts
    assert max(r for _, r in rep.residuals) < 1e-9


# ---------------------------------------------------------------------------
# unified sim + real-step trace (golden-pinned)
# ---------------------------------------------------------------------------

def _unified_trace() -> dict:
    """Deterministic sim records + deterministic fake-clock real-step
    records, exported into ONE Chrome trace: the real-step-parity
    acceptance artifact."""
    from repro.train.step import instrument_step

    rec = recorder.FlightRecorder()
    res = _small_sim(rec, schedule=LocalSGD(2), iters=4).run()

    specs, t_f = trace.synthetic_specs(10, seed=21)
    art = types.SimpleNamespace(specs=specs,
                                plan=make_plan("mgwfbp", specs, MODEL),
                                comm_model=MODEL)
    ticks = iter(0.031 * k for k in range(8))
    wrapped = instrument_step(lambda s, b: (s, {}), art, t_f=t_f,
                              job="train", recorder=rec,
                              clock=lambda: next(ticks), sync=False)
    for step in range(3):
        wrapped(None, None)

    spans = list(res.spans) + recorder.record_spans(rec.records)
    counters = timeline.counter_samples_from(res.job("train"))
    return timeline.to_chrome_trace(spans, counters)


def test_sim_and_real_step_records_share_schema():
    obj = _unified_trace()
    # schema parity is a consequence of one dataclass, but pin it
    # explicitly: group spans by source and compare the lane structure
    pids = {e["pid"] for e in obj["traceEvents"] if e["ph"] == "X"}
    assert "sim:train" in pids and "train:train" in pids
    for group in ("sim:train", "train:train"):
        lanes = {e["tid"] for e in obj["traceEvents"]
                 if e["ph"] == "X" and e["pid"] == group}
        assert {"step", "comm"} <= lanes, (group, lanes)
    assert all(e["dur"] >= 0 for e in obj["traceEvents"]
               if e["ph"] == "X")


def test_golden_unified_trace_exact():
    path = GOLDEN_DIR / "obs_unified.trace.json"
    assert path.exists(), \
        f"{path} missing — run `python tests/test_obs.py --regen`"
    with open(path) as f:
        golden = json.load(f)
    current = _unified_trace()
    if current != golden:
        cur, gold = current["traceEvents"], golden["traceEvents"]
        assert len(cur) == len(gold), \
            f"{len(cur)} events vs golden {len(gold)}"
        for i, (a, b) in enumerate(zip(cur, gold)):
            assert a == b, f"event {i} drifted:\n  now: {a}\n  was: {b}"
        raise AssertionError("trace metadata drifted")


# ---------------------------------------------------------------------------
# real multi-device run -> same record schema (subprocess: needs
# XLA_FLAGS set before jax imports; the rest of the suite sees 1 device)
# ---------------------------------------------------------------------------

_MD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json, tempfile
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import make_mesh, use_mesh
from repro.models import registry
from repro.obs import recorder, timeline
from repro.sim import trace
from repro.sim.engine import ClusterSim, JobSpec, Topology
from repro.sim.workers import make_workers
from repro.core.planner import make_plan
from repro.core.cost_model import AllReduceModel
from repro.train.step import build_train_step, instrument_step

bundle = registry.reduced_arch("qwen2-1.5b")
par = dataclasses.replace(bundle.parallel, dp_axes=("data",), zero=0,
                          ep_axis="", attn_chunk=32)
shape = ShapeConfig("tiny", "train", 16, 8)
run_cfg = dataclasses.replace(bundle.run_config("train_4k", par),
                              shape=shape, microbatch=0)
model = bundle.model(par)
mesh = make_mesh((4,), ("data",))
rec = recorder.FlightRecorder()
with use_mesh(mesh):
    step_fn, init_fn, art = build_train_step(model, run_cfg, mesh)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), art.state_pspecs,
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(init_fn(jax.random.PRNGKey(0)), sh)
    pipe = DataPipeline(bundle.cfg, shape, seed=0)
    jstep = jax.jit(step_fn)
    batch = pipe.batch_at(0)
    hlo = jstep.lower(state, batch).compile().as_text()
    wrapped = instrument_step(jstep, art, recorder=rec, hlo_text=hlo)
    for s in range(2):
        state, m = wrapped(state, pipe.batch_at(s))

train = rec.iterations("train")
assert len(train) == 2, train
assert all(r.source == "train" and r.t_iter > 0 for r in train)
assert train[0].buckets, "no per-bucket estimates on the record"
assert train[0].args["estimated_buckets"] is True
assert train[0].args["hlo_cost"]["collective_bytes"] > 0, \\
    "hlo cost analysis saw no collectives in a 4-way DP step"

# same schema as a simulator record, field for field
sim_rec = recorder.FlightRecorder()
specs, t_f = trace.synthetic_specs(8, seed=3)
mdl = AllReduceModel(4e-4, 1.5e-9)
job = JobSpec(name="train", specs=specs,
              plan=make_plan("mgwfbp", specs, mdl), t_f=t_f,
              workers=make_workers(2), topology=Topology(mdl, 2), iters=1)
ClusterSim([job], recorder=sim_rec).run()
fields = lambda r: sorted(dataclasses.asdict(r))
assert fields(train[0]) == fields(sim_rec.iterations()[0])

# ... and both sources export into ONE valid chrome trace
spans = recorder.record_spans(tuple(sim_rec.records) + rec.records)
obj = timeline.to_chrome_trace(spans)
pids = {e["pid"] for e in obj["traceEvents"]}
assert pids == {"sim:train", "train:train"}, pids
assert all(e["dur"] >= 0 for e in obj["traceEvents"])
fd, path = tempfile.mkstemp(suffix=".json"); os.close(fd)
timeline.write_chrome_trace(path, spans)
assert timeline.read_chrome_trace(path) == spans
os.unlink(path)
print("OBS-MULTIDEVICE-PASS")
"""


@pytest.mark.slow
def test_real_step_records_match_sim_schema():
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _MD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "OBS-MULTIDEVICE-PASS" in res.stdout, \
        f"stdout:\n{res.stdout[-3000:]}\nstderr:\n{res.stderr[-3000:]}"


def _regen() -> None:
    GOLDEN_DIR.mkdir(exist_ok=True)
    path = GOLDEN_DIR / "obs_unified.trace.json"
    with open(path, "w") as f:
        json.dump(_unified_trace(), f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
