"""Fault injection (repro.sim.faults) + the faulty_long_run scenario."""

import pytest

from repro.obs.recorder import FlightRecorder
from repro.sim import scenarios, trace
from repro.sim.faults import (CheckpointFailure, FaultPlan, LinkDegradation,
                              Preemption, SlowHostOnset, WorkerCrash)


def _specs():
    return trace.synthetic_specs(16, seed=7)


def _t_iter(specs, t_f):
    return t_f + sum(s.t_b for s in specs)


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_plan_is_time_sorted_and_queryable():
    plan = FaultPlan(events=(WorkerCrash(5.0, worker="w1"),
                             LinkDegradation(1.0),
                             CheckpointFailure(3.0)))
    assert [e.time for e in plan.events] == [1.0, 3.0, 5.0]
    assert len(plan) == 3
    assert plan.of_kind("crash") == (WorkerCrash(5.0, worker="w1"),)


def test_random_plan_is_pure_function_of_args():
    kw = dict(horizon=100.0, workers=[f"w{i}" for i in range(8)],
              links=["net"], n_crashes=2, n_preemptions=2)
    a = FaultPlan.random(3, **kw)
    b = FaultPlan.random(3, **kw)
    assert a == b
    assert a != FaultPlan.random(4, **kw)
    assert all(0 < e.time < 100.0 for e in a.events)
    # crash/preempt targets are distinct while the pool lasts
    targeted = [e.worker for e in a.events if hasattr(e, "worker")]
    assert len(set(targeted)) == len(targeted)


@pytest.mark.parametrize("bad", [
    lambda: WorkerCrash(-1.0, worker="w0"),
    lambda: Preemption(1.0, worker="w0", notice_s=-0.1),
    lambda: LinkDegradation(1.0, factor=0.0),
    lambda: LinkDegradation(1.0, factor=1.5),
    lambda: LinkDegradation(1.0, duration=0.0),
    lambda: SlowHostOnset(1.0, worker="w0", factor=1.0),
    lambda: CheckpointFailure(1.0, count=0),
    lambda: FaultPlan.random(0, horizon=0.0, workers=["w0"]),
])
def test_fault_validation(bad):
    with pytest.raises(ValueError):
        bad()


# ---------------------------------------------------------------------------
# Injector physical effects
# ---------------------------------------------------------------------------

def test_link_degradation_slows_then_restores():
    specs, t_f = _specs()
    t_it = _t_iter(specs, t_f)
    # a 10x bandwidth cut covering iterations ~2-4 of a 8-iteration run
    plan = FaultPlan(events=(LinkDegradation(
        2.0 * t_it, link="net", factor=0.1, duration=2.0 * t_it),))
    sim, _ = scenarios.faulty_long_run(specs, t_f, n_workers=4, iters=8,
                                       plan=plan, resilient=False)
    its = sim.run().job("train").iterations
    clean = its[0].t_iter
    assert max(it.t_iter for it in its[1:5]) > clean * 1.2
    assert its[-1].t_iter == pytest.approx(clean, rel=1e-6)  # restored


def test_slow_host_onset_applies_physical_slowdown():
    specs, t_f = _specs()
    t_it = _t_iter(specs, t_f)
    plan = FaultPlan(events=(SlowHostOnset(
        2.0 * t_it, worker="w1", factor=3.0),))
    sim, _ = scenarios.faulty_long_run(specs, t_f, n_workers=4, iters=6,
                                       plan=plan, resilient=False)
    its = sim.run().job("train").iterations
    run = sim.job_run("train")
    w1 = [w for w in run.workers if w.name == "w1"]
    assert w1 and w1[0].slowdown == pytest.approx(3.0)
    # the synchronous fleet drags at the slow host's pace
    assert its[-1].t_iter > its[0].t_iter * 1.5


def test_preemption_drained_by_controller_ignored_by_baseline():
    specs, t_f = _specs()
    t_it = _t_iter(specs, t_f)
    plan = FaultPlan(events=(Preemption(
        1.5 * t_it, worker="w2", notice_s=3.0 * t_it),))

    sim, rep = scenarios.faulty_long_run(specs, t_f, n_workers=4, iters=8,
                                         plan=plan)
    sim.run()
    assert [(w, c) for _, w, c in rep.evictions] == [("w2", "preempt_drain")]
    assert rep.availability.recoveries == {"preempt": 1}
    assert rep.availability.unrecovered == 0

    sim_n, rep_n = scenarios.faulty_long_run(specs, t_f, n_workers=4,
                                             iters=8, plan=plan,
                                             resilient=False)
    sim_n.run()
    # undrained notice became a crash at the deadline: work was lost
    assert rep_n.evictions == []
    assert rep_n.availability.wasted_steps > 0


def test_crash_evicts_rescales_and_readmits():
    specs, t_f = _specs()
    t_it = _t_iter(specs, t_f)
    plan = FaultPlan(events=(WorkerCrash(2.5 * t_it, worker="w0"),))
    sim, rep = scenarios.faulty_long_run(specs, t_f, n_workers=4, iters=10,
                                         plan=plan)
    sim.run()
    assert [(w, c) for _, w, c in rep.evictions] == [("w0", "crash")]
    assert [n for _, n in rep.readmissions] == ["r1"]
    # back at nominal capacity, on a replacement worker
    run = sim.job_run("train")
    assert len(run.workers) == 4
    assert {w.name for w in run.workers} == {"w1", "w2", "w3", "r1"}
    assert rep.controller.n_active == 4
    assert rep.replans >= 2  # eviction rescale + readmission rescale


# ---------------------------------------------------------------------------
# The pinned end-to-end comparison (mirrors benchmarks --faults)
# ---------------------------------------------------------------------------

def _pinned_plan(t_it):
    return FaultPlan(events=(
        WorkerCrash(3.2 * t_it, worker="w3"),
        Preemption(7.5 * t_it, worker="w1", notice_s=3.0 * t_it),
        LinkDegradation(10.3 * t_it, link="net", factor=0.4,
                        duration=3.0 * t_it),
        CheckpointFailure(5.0 * t_it, count=1),
    ), seed=7)


def test_controller_beats_naive_baseline_with_bounded_recovery():
    specs, t_f = _specs()
    plan = _pinned_plan(_t_iter(specs, t_f))
    sim_a, rep_a = scenarios.faulty_long_run(specs, t_f, n_workers=6,
                                             iters=20, plan=plan)
    sim_a.run()
    sim_b, rep_b = scenarios.faulty_long_run(specs, t_f, n_workers=6,
                                             iters=20, plan=plan,
                                             resilient=False)
    sim_b.run()
    a, b = rep_a.availability, rep_b.availability
    assert a.goodput > b.goodput
    assert a.unrecovered == 0
    bound = max((i.steps_to_recover or 0)
                for i in rep_a.controller.incidents)
    assert bound <= 3
    # the baseline replays every step since its last checkpoint; the
    # controller only loses the one in-flight iteration the crash voided
    # (DP survivors keep the model, nothing is replayed)
    assert a.wasted_steps == 1
    assert b.wasted_steps > a.wasted_steps
    assert a.replayed_fraction < b.replayed_fraction


def test_same_seed_same_flight_recorder_jsonl(tmp_path):
    specs, t_f = _specs()
    plan = _pinned_plan(_t_iter(specs, t_f))

    def one_run(path):
        rec = FlightRecorder(8192)
        sim, _ = scenarios.faulty_long_run(specs, t_f, n_workers=6,
                                           iters=12, plan=plan,
                                           recorder=rec)
        sim.run()
        rec.write(str(path))
        return rec.records, path.read_bytes()

    a, jsonl_a = one_run(tmp_path / "a.jsonl")
    b, jsonl_b = one_run(tmp_path / "b.jsonl")
    assert len(a) > 0
    assert a == b
    assert jsonl_a == jsonl_b  # bit-identical on disk, not just in memory
