"""Bucket assembly: ordering, pack/unpack, bucketed apply."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketer
from repro.core.planner import MergePlan, TensorSpec, plan_fixed_size


def _tree():
    return {"a": {"w": jnp.arange(6.0).reshape(2, 3),
                  "b": jnp.ones((4,))},
            "z": jnp.full((2, 2), 3.0)}


def test_backward_order_is_reversed_flatten():
    tree = _tree()
    order = [p for p, _ in bucketer.leaves_in_backward_order(tree)]
    fwd = [jax.tree_util.keystr(p) for p, _ in
           jax.tree_util.tree_flatten_with_path(tree)[0]]
    assert order == list(reversed(fwd))


def test_leaf_metadata():
    metas = bucketer.leaf_metadata(_tree())
    assert [m.size for m in metas] == [4, 6, 4]
    assert metas[0].path == "['z']"
    assert metas[0].nbytes == 16


def test_pack_unpack_roundtrip():
    tree = _tree()
    metas = bucketer.leaf_metadata(tree)
    leaves = [v for _, v in bucketer.leaves_in_backward_order(tree)]
    buf = bucketer.pack(leaves)
    assert buf.shape == (14,)
    outs = bucketer.unpack(buf, metas)
    for o, l in zip(outs, leaves):
        np.testing.assert_allclose(np.asarray(o), np.asarray(l))


def test_unpack_size_mismatch():
    metas = bucketer.leaf_metadata(_tree())
    with pytest.raises(ValueError):
        bucketer.unpack(jnp.zeros(13), metas)


def test_apply_bucketed_identity():
    tree = _tree()
    metas = bucketer.leaf_metadata(tree)
    specs = [TensorSpec(m.path, m.nbytes, 1e-3) for m in metas]
    plan = plan_fixed_size(specs, 30)
    out = bucketer.apply_bucketed(tree, plan, lambda buf: buf * 2.0)
    for (_, a), (_, b) in zip(
            bucketer.leaves_in_backward_order(out),
            bucketer.leaves_in_backward_order(tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b) * 2.0)


def test_apply_bucketed_plan_mismatch():
    tree = _tree()
    plan = MergePlan(((0,), (1,)))  # only 2 tensors, tree has 3
    with pytest.raises(ValueError):
        bucketer.apply_bucketed(tree, plan, lambda b: b)


def test_pack_unpack_kernel_layout_roundtrip():
    """use_kernel=True speaks the TILE-aligned slot layout end to end."""
    tree = _tree()
    metas = bucketer.leaf_metadata(tree)
    leaves = [v for _, v in bucketer.leaves_in_backward_order(tree)]
    buf = bucketer.pack(leaves, use_kernel=True)
    assert buf.shape == (bucketer.packed_elems(metas, aligned=True),)
    outs = bucketer.unpack(buf, metas, use_kernel=True)
    for o, l in zip(outs, leaves):
        np.testing.assert_allclose(np.asarray(o), np.asarray(l))
    # layout mismatch is loud: an aligned buffer fed to the plain unpack
    with pytest.raises(ValueError):
        bucketer.unpack(buf, metas, use_kernel=False)


def test_slot_elems_and_packed_elems():
    from repro.kernels.bucket_pack.kernel import TILE
    assert bucketer.slot_elems(5) == 5
    assert bucketer.slot_elems(5, aligned=True) == TILE
    assert bucketer.slot_elems(TILE, aligned=True) == TILE
    metas = bucketer.leaf_metadata(_tree())
    assert bucketer.packed_elems(metas) == sum(m.size for m in metas)
    assert bucketer.packed_elems(metas, aligned=True) == \
        sum(bucketer.slot_elems(m.size, aligned=True) for m in metas)


def test_pack_mixed_dtype_matches_ops_default():
    """bucketer.pack and kernels ops.pack agree on the promoted dtype."""
    from repro.kernels.bucket_pack import ops as bp_ops
    leaves = [jnp.ones((3,), jnp.bfloat16), jnp.full((4,), 2.0, jnp.float32)]
    a = bucketer.pack(leaves)
    b = bp_ops.pack(leaves)
    assert a.dtype == b.dtype == jnp.float32


def test_apply_bucketed_kernel_matches_plain():
    tree = _tree()
    metas = bucketer.leaf_metadata(tree)
    specs = [TensorSpec(m.path, m.nbytes, 1e-3) for m in metas]
    plan = plan_fixed_size(specs, 30)
    plain = bucketer.apply_bucketed(tree, plan, lambda buf: buf * 2.0)
    kern = bucketer.apply_bucketed(tree, plan, lambda buf: buf * 2.0,
                                   use_kernel=True)
    for (_, a), (_, b) in zip(bucketer.leaves_in_backward_order(plain),
                              bucketer.leaves_in_backward_order(kern)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tensor_specs_backward_order():
    specs = bucketer.tensor_specs(_tree(), lambda m: m.size * 1e-6)
    assert [s.name for s in specs] == ["['z']", "['a']['w']", "['a']['b']"]
    assert specs[0].t_b == pytest.approx(4e-6)
