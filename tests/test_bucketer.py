"""Bucket assembly: ordering, pack/unpack, bucketed apply."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketer
from repro.core.planner import MergePlan, TensorSpec, plan_fixed_size


def _tree():
    return {"a": {"w": jnp.arange(6.0).reshape(2, 3),
                  "b": jnp.ones((4,))},
            "z": jnp.full((2, 2), 3.0)}


def test_backward_order_is_reversed_flatten():
    tree = _tree()
    order = [p for p, _ in bucketer.leaves_in_backward_order(tree)]
    fwd = [jax.tree_util.keystr(p) for p, _ in
           jax.tree_util.tree_flatten_with_path(tree)[0]]
    assert order == list(reversed(fwd))


def test_leaf_metadata():
    metas = bucketer.leaf_metadata(_tree())
    assert [m.size for m in metas] == [4, 6, 4]
    assert metas[0].path == "['z']"
    assert metas[0].nbytes == 16


def test_pack_unpack_roundtrip():
    tree = _tree()
    metas = bucketer.leaf_metadata(tree)
    leaves = [v for _, v in bucketer.leaves_in_backward_order(tree)]
    buf = bucketer.pack(leaves)
    assert buf.shape == (14,)
    outs = bucketer.unpack(buf, metas)
    for o, l in zip(outs, leaves):
        np.testing.assert_allclose(np.asarray(o), np.asarray(l))


def test_unpack_size_mismatch():
    metas = bucketer.leaf_metadata(_tree())
    with pytest.raises(ValueError):
        bucketer.unpack(jnp.zeros(13), metas)


def test_apply_bucketed_identity():
    tree = _tree()
    metas = bucketer.leaf_metadata(tree)
    specs = [TensorSpec(m.path, m.nbytes, 1e-3) for m in metas]
    plan = plan_fixed_size(specs, 30)
    out = bucketer.apply_bucketed(tree, plan, lambda buf: buf * 2.0)
    for (_, a), (_, b) in zip(
            bucketer.leaves_in_backward_order(out),
            bucketer.leaves_in_backward_order(tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b) * 2.0)


def test_apply_bucketed_plan_mismatch():
    tree = _tree()
    plan = MergePlan(((0,), (1,)))  # only 2 tensors, tree has 3
    with pytest.raises(ValueError):
        bucketer.apply_bucketed(tree, plan, lambda b: b)


def test_tensor_specs_backward_order():
    specs = bucketer.tensor_specs(_tree(), lambda m: m.size * 1e-6)
    assert [s.name for s in specs] == ["['z']", "['a']['w']", "['a']['b']"]
    assert specs[0].t_b == pytest.approx(4e-6)
