"""All-reduce cost models (paper Table 2 / Eq. 10-11) + fitting.

The randomized Eq. 11 merge-gain property lives in
tests/test_cost_model_props.py (hypothesis)."""

import numpy as np
import pytest

from repro.core import cost_model as cm


def test_table2_shapes():
    for name in cm.ALGORITHMS:
        m = cm.make_model(name, 16, alpha=1e-5, beta=1e-9, gamma=1e-10)
        assert m.a >= 0 and m.b >= 0
        assert m.time(0) == 0.0
        assert m.time(1 << 20) > m.a


def test_ring_linear_startup_vs_tree_log():
    """Ring startup grows linearly with N, double binary trees log N —
    the reason the paper's Fig. 10 vs Fig. 11 differ."""
    ring64 = cm.ring(64, 1e-5, 1e-9, 0).a
    ring128 = cm.ring(128, 1e-5, 1e-9, 0).a
    dbt64 = cm.double_binary_trees(64, 1e-5, 1e-9, 0).a
    dbt128 = cm.double_binary_trees(128, 1e-5, 1e-9, 0).a
    assert ring128 / ring64 > 1.9
    assert dbt128 / dbt64 < 1.3


def test_fit_recovers_parameters():
    rng = np.random.default_rng(0)
    a, b = 9.72e-4, 1.97e-9          # paper cluster 1
    sizes = rng.integers(1 << 10, 1 << 26, 200).astype(float)
    times = a + b * sizes + rng.normal(0, 1e-6, 200)
    m = cm.fit(sizes, times)
    assert m.a == pytest.approx(a, rel=0.05)
    assert m.b == pytest.approx(b, rel=0.05)


def test_fit_clamps_negative_intercept():
    m = cm.fit([1e6, 2e6, 3e6], [1e-3, 2e-3, 3e-3])
    assert m.a >= 0


def test_hierarchical_flattens_to_linear():
    h = cm.HierarchicalModel(intra=cm.tpu_ici_ring(16),
                             inter=cm.tpu_dcn(2), intra_size=16)
    flat = h.flat()
    for nbytes in (1 << 10, 1 << 20, 1 << 30):
        assert flat.time(nbytes) == pytest.approx(h.time(nbytes))
    # inter-pod per-byte term is diluted by the intra reduce-scatter
    assert h.b < cm.tpu_ici_ring(16).b + cm.tpu_dcn(2).b


def test_production_comm_model():
    single = cm.production_comm_model((16, 16), ("data", "model"))
    multi = cm.production_comm_model((2, 16, 16), ("pod", "data", "model"))
    assert multi.a > single.a          # DCN startup dominates
    pod_only = cm.production_comm_model((2, 16, 16),
                                        ("pod", "data", "model"), ("pod",))
    assert pod_only.a > 0


def test_unknown_algorithm():
    with pytest.raises(ValueError):
        cm.make_model("gossip", 8, 1e-5, 1e-9)
