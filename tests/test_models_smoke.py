"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-gradient step on CPU, asserting output shapes and finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry

ARCHS = registry.list_archs()


def _batch(bundle, b=2, s=32):
    cfg = bundle.cfg
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                     cfg.vocab_size),
    }
    if cfg.enc_dec:
        batch["enc_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (b, 32, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(4), (b, cfg.frontend_prefix_len,
                                    cfg.d_model)).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_loss_and_grads(arch):
    bundle = registry.reduced_arch(arch)
    model = bundle.model()
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    assert n_params > 1000
    batch = _batch(bundle)
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0
    assert metrics["tokens"] == 64
    gn = 0.0
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32))), \
            f"{arch}: non-finite grads"
        gn += float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
    assert gn > 0, f"{arch}: all-zero gradients"
    # grads cover every parameter leaf
    assert len(jax.tree.leaves(grads)) == len(jax.tree.leaves(params))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_structure_matches_assignment(arch):
    """Full (unreduced) config structural checks against the assignment."""
    expected = {
        "qwen2-1.5b": dict(num_layers=28, d_model=1536, num_heads=12,
                           num_kv_heads=2, d_ff=8960, vocab_size=151936),
        "deepseek-67b": dict(num_layers=95, d_model=8192, num_heads=64,
                             num_kv_heads=8, d_ff=22016, vocab_size=102400),
        "gemma3-12b": dict(num_layers=48, d_model=3840, num_heads=16,
                           num_kv_heads=8, d_ff=15360, vocab_size=262144),
        "stablelm-1.6b": dict(num_layers=24, d_model=2048, num_heads=32,
                              num_kv_heads=32, d_ff=5632,
                              vocab_size=100352),
        "phi-3-vision-4.2b": dict(num_layers=32, d_model=3072, num_heads=32,
                                  num_kv_heads=32, d_ff=8192,
                                  vocab_size=32064),
        "deepseek-moe-16b": dict(num_layers=28, d_model=2048, num_heads=16,
                                 num_kv_heads=16, vocab_size=102400),
        "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                            num_kv_heads=8, d_ff=4864, vocab_size=32000),
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336,
                               vocab_size=65536),
        "whisper-base": dict(num_layers=6, d_model=512, num_heads=8,
                             num_kv_heads=8, d_ff=2048, vocab_size=51865),
        "xlstm-125m": dict(num_layers=12, d_model=768, num_heads=4,
                           num_kv_heads=4, d_ff=0, vocab_size=50304),
    }[arch]
    cfg = registry.get_arch(arch).cfg
    for k, v in expected.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}"
    # MoE structure
    if arch == "deepseek-moe-16b":
        assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 6
        assert cfg.moe.num_shared_experts == 2
        assert cfg.moe.d_expert == 1408
    if arch == "arctic-480b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 2
        assert cfg.dense_residual
    if arch == "jamba-v0.1-52b":
        assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 2
        assert cfg.attn_interval == 8 and cfg.mamba is not None
    if arch == "gemma3-12b":
        assert cfg.sliding_window == 1024 and cfg.global_interval == 6
    if arch == "whisper-base":
        assert cfg.enc_dec and cfg.enc_layers == 6
    if arch == "xlstm-125m":
        assert cfg.xlstm_slstm_interval > 0


def test_full_param_counts_in_expected_range():
    """Total parameter counts are in the advertised ballpark."""
    import re
    expected_b = {"qwen2-1.5b": (1.2, 2.0), "deepseek-67b": (60, 72),
                  "gemma3-12b": (10, 14), "stablelm-1.6b": (1.2, 2.1),
                  "phi-3-vision-4.2b": (3.4, 4.6),
                  "deepseek-moe-16b": (13, 20), "arctic-480b": (420, 520),
                  "jamba-v0.1-52b": (45, 60), "whisper-base": (0.04, 0.12),
                  "xlstm-125m": (0.08, 0.22)}
    for arch, (lo, hi) in expected_b.items():
        bundle = registry.get_arch(arch)
        model = bundle.model()
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        assert lo * 1e9 <= n <= hi * 1e9, f"{arch}: {n/1e9:.2f}B params"


def test_block_kind_patterns():
    cfg = registry.get_arch("jamba-v0.1-52b").cfg
    kinds = [cfg.block_kind(i)["mixer"] for i in range(8)]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    ffns = [cfg.block_kind(i)["ffn"] for i in range(8)]
    assert ffns.count("moe") == 4

    g = registry.get_arch("gemma3-12b").cfg
    wins = [g.block_kind(i)["window"] for i in range(12)]
    assert wins.count(0) == 2 and wins.count(1024) == 10  # 5:1 local:global
