"""Golden-trace regression tests.

Two small canonical scenarios — one BSP, one pipelined, fixed seed — have
their full Chrome-trace JSON checked into ``tests/golden/``.  The tests
re-run the scenarios and assert **exact** JSON equality (every span, every
timestamp, bit for bit), so an engine or schedule refactor that silently
changes timing fails loudly in review instead of drifting.

If a change is *intentional*, regenerate with::

    PYTHONPATH=src python tests/test_golden_traces.py --regen

and commit the diff (which then documents the timing change).
"""

import json
import pathlib

import pytest

from repro.core.cost_model import AllReduceModel
from repro.core.planner import make_plan
from repro.sim import trace
from repro.sim.engine import ClusterSim, JobSpec, Topology
from repro.sim.schedules import PipelinedAllReduce
from repro.sim.workers import make_workers

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
MODEL = AllReduceModel(4e-4, 1.5e-9)


def _run(schedule):
    specs, t_f = trace.synthetic_specs(10, seed=21)
    plan = make_plan("mgwfbp", specs, MODEL)
    job = JobSpec(name="golden", specs=specs, plan=plan, t_f=t_f,
                  workers=make_workers(3, slow={0: 1.5},
                                       jitter_sigma=0.1),
                  topology=Topology(MODEL, n_workers=3), iters=3,
                  compute_mode="events", schedule=schedule)
    res = ClusterSim([job], seed=77).run()
    # frontier lanes ride along so their timing is pinned too
    spans = list(res.spans) + trace.frontier_spans(res.job("golden"))
    return trace.to_chrome_trace(spans)


SCENARIOS = {
    "bsp_canonical": lambda: _run(None),
    "pipelined_canonical": lambda: _run(PipelinedAllReduce(0.5)),
}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_trace_exact(name):
    path = GOLDEN_DIR / f"{name}.trace.json"
    assert path.exists(), \
        f"{path} missing — run `python tests/test_golden_traces.py --regen`"
    with open(path) as f:
        golden = json.load(f)
    current = SCENARIOS[name]()
    # exact equality, float for float: json round-trips Python floats
    # losslessly (repr), so == here means the timeline is unchanged
    if current != golden:
        cur, gold = current["traceEvents"], golden["traceEvents"]
        assert len(cur) == len(gold), \
            f"{name}: {len(cur)} spans vs golden {len(gold)}"
        for i, (a, b) in enumerate(zip(cur, gold)):
            assert a == b, f"{name}: span {i} drifted:\n  now: {a}\n  was: {b}"
        raise AssertionError(f"{name}: trace metadata drifted")


def test_golden_traces_are_loadable_chrome_json():
    """The checked-in artifacts stay valid Chrome traces (viewers load
    them) and round-trip through the reader."""
    for name in SCENARIOS:
        path = GOLDEN_DIR / f"{name}.trace.json"
        spans = trace.read_chrome_trace(str(path))
        assert spans, name
        with open(path) as f:
            obj = json.load(f)
        assert all(ev["ph"] == "X" and ev["dur"] >= 0
                   for ev in obj["traceEvents"])
        assert trace.to_chrome_trace(spans) == obj


def _regen() -> None:
    GOLDEN_DIR.mkdir(exist_ok=True)
    for name, build in SCENARIOS.items():
        path = GOLDEN_DIR / f"{name}.trace.json"
        with open(path, "w") as f:
            json.dump(build(), f, indent=1)
        print(f"wrote {path}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
