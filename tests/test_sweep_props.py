"""Batched-sweep property tests; skipped without the real hypothesis
package."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402

from repro.core.cost_model import AllReduceModel  # noqa: E402
from repro.core.planner import TensorSpec, make_plan  # noqa: E402
from repro.core.simulator import batched_comm_end, simulate  # noqa: E402


@hypothesis.given(st.integers(0, 10_000))
@hypothesis.settings(max_examples=15, deadline=None)
def test_batched_comm_end_matches_simulate(seed):
    """The vectorized recurrence degenerates to simulate() at one point."""
    rng = np.random.default_rng(seed)
    L = int(rng.integers(1, 16))
    specs = [TensorSpec(f"t{i}", int(rng.integers(0, 1 << 22)),
                        float(rng.uniform(0, 5e-3))) for i in range(L)]
    model = AllReduceModel(float(rng.uniform(0, 2e-3)),
                           float(rng.uniform(1e-11, 1e-8)))
    t_f = float(rng.uniform(0, 0.01))
    plan = make_plan("mgwfbp", specs, model)
    res = simulate(specs, plan, model, t_f)
    prefix = np.cumsum([s.t_b for s in specs])
    ready = t_f + prefix[[b[-1] for b in plan.buckets]]
    bucket_t = np.array([model.time(b) for b in plan.bucket_bytes(specs)])
    end = batched_comm_end(bucket_t, ready, t_f + prefix[-1])
    assert float(end) == pytest.approx(t_f + res.comm_end, abs=1e-12)
