"""Fault tolerance: recovery loop, elastic replanning, stragglers."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import AllReduceModel
from repro.core.planner import TensorSpec
from repro.train import checkpoint, fault
from repro.train.train_state import TrainState


class _FakePipe:
    def batch_at(self, step):
        return {"x": np.float32(step)}


def _mk_state(v):
    return TrainState(step=jnp.int32(0), params={"w": jnp.float32(v)},
                      opt_state=[])


def test_recovery_retries_then_restores(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path))
    calls = {"n": 0, "fails": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        # fail persistently at step 5 until a restore resets us
        if float(state.params["w"]) >= 5 and calls["fails"] < 5:
            calls["fails"] += 1
            raise RuntimeError("injected failure")
        return (TrainState(state.step + 1,
                           {"w": state.params["w"] + 1}, []), {})

    state, final = fault.run_with_recovery(
        step_fn, _mk_state(0.0), _FakePipe(), ck, 0, 8, ckpt_every=2,
        max_retries=2)
    assert final == 8
    assert calls["fails"] == 5  # 2 retries + restore + re-fail path
    assert checkpoint.latest_step(str(tmp_path)) == 8


def test_recovery_clean_run(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path))
    seen = []

    def step_fn(state, batch):
        seen.append(float(batch["x"]))
        return (TrainState(state.step + 1, state.params, []), {"loss": 0.0})

    _, final = fault.run_with_recovery(step_fn, _mk_state(0.0), _FakePipe(),
                                       ck, 0, 5, ckpt_every=100)
    assert final == 5
    assert seen == [0.0, 1.0, 2.0, 3.0, 4.0]  # deterministic replayable


def test_elastic_replan_changes_with_scale():
    specs = [TensorSpec(f"t{i}", 1 << 18, 1e-4) for i in range(20)]
    plan16, m16 = fault.replan_for("mgwfbp", specs, (16, 16),
                                   ("data", "model"), ("data",))
    plan512, m512 = fault.replan_for("mgwfbp", specs, (2, 16, 16),
                                     ("pod", "data", "model"),
                                     ("pod", "data"))
    assert m512.a > m16.a
    # bigger startup -> at least as much merging
    assert plan512.num_buckets <= plan16.num_buckets


def test_straggler_monitor():
    mon = fault.StragglerMonitor(warmup=3, threshold=1.5)
    for t in range(10):
        for h in ("h0", "h1", "h2", "h3"):
            mon.record(h, 1.0 if h != "h2" else 2.5)
    assert mon.stragglers() == ["h2"]


def test_straggler_monitor_needs_warmup():
    mon = fault.StragglerMonitor(warmup=5)
    mon.record("a", 1.0)
    mon.record("b", 99.0)
    assert mon.stragglers() == []
