"""Fault tolerance: recovery loop, elastic replanning, stragglers."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import AllReduceModel
from repro.core.planner import TensorSpec
from repro.train import checkpoint, fault
from repro.train.train_state import TrainState


class _FakePipe:
    def batch_at(self, step):
        return {"x": np.float32(step)}


def _mk_state(v):
    return TrainState(step=jnp.int32(0), params={"w": jnp.float32(v)},
                      opt_state=[])


def test_recovery_retries_then_restores(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path))
    calls = {"n": 0, "fails": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        # fail persistently at step 5 until a restore resets us
        if float(state.params["w"]) >= 5 and calls["fails"] < 5:
            calls["fails"] += 1
            raise RuntimeError("injected failure")
        return (TrainState(state.step + 1,
                           {"w": state.params["w"] + 1}, []), {})

    state, final = fault.run_with_recovery(
        step_fn, _mk_state(0.0), _FakePipe(), ck, 0, 8, ckpt_every=2,
        max_retries=2)
    assert final == 8
    assert calls["fails"] == 5  # 2 retries + restore + re-fail path
    assert checkpoint.latest_step(str(tmp_path)) == 8


def test_recovery_clean_run(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path))
    seen = []

    def step_fn(state, batch):
        seen.append(float(batch["x"]))
        return (TrainState(state.step + 1, state.params, []), {"loss": 0.0})

    _, final = fault.run_with_recovery(step_fn, _mk_state(0.0), _FakePipe(),
                                       ck, 0, 5, ckpt_every=100)
    assert final == 5
    assert seen == [0.0, 1.0, 2.0, 3.0, 4.0]  # deterministic replayable


def test_elastic_replan_changes_with_scale():
    specs = [TensorSpec(f"t{i}", 1 << 18, 1e-4) for i in range(20)]
    plan16, m16 = fault.replan_for("mgwfbp", specs, (16, 16),
                                   ("data", "model"), ("data",))
    plan512, m512 = fault.replan_for("mgwfbp", specs, (2, 16, 16),
                                     ("pod", "data", "model"),
                                     ("pod", "data"))
    assert m512.a > m16.a
    # bigger startup -> at least as much merging
    assert plan512.num_buckets <= plan16.num_buckets


def test_straggler_monitor():
    mon = fault.StragglerMonitor(warmup=3, threshold=1.5)
    for t in range(10):
        for h in ("h0", "h1", "h2", "h3"):
            mon.record(h, 1.0 if h != "h2" else 2.5)
    assert mon.stragglers() == ["h2"]


def test_straggler_monitor_needs_warmup():
    mon = fault.StragglerMonitor(warmup=5)
    mon.record("a", 1.0)
    mon.record("b", 99.0)
    assert mon.stragglers() == []


def test_retry_backoff_sleeps_before_each_retry(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path))
    fails = {"left": 3}
    sleeps = []

    def step_fn(state, batch):
        if fails["left"] > 0:
            fails["left"] -= 1
            raise RuntimeError("transient")
        return (TrainState(state.step + 1, state.params, []), {})

    _, final = fault.run_with_recovery(
        step_fn, _mk_state(0.0), _FakePipe(), ck, 0, 3, max_retries=3,
        backoff_base=0.01, backoff_factor=2.0, backoff_max=1.0,
        jitter=0.25, sleep_fn=sleeps.append)
    assert final == 3
    assert len(sleeps) == 3  # one backoff per failed attempt
    # exponential ladder, jitter bounded by +/-25%
    for i, d in enumerate(sleeps):
        base = 0.01 * 2.0 ** i
        assert base * 0.75 <= d <= base * 1.25


def test_restore_budget_exhausted_reraises(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path))
    checkpoint.save(str(tmp_path), 0, _mk_state(0.0))

    def step_fn(state, batch):
        raise RuntimeError("persistent")

    with pytest.raises(RuntimeError, match="persistent"):
        fault.run_with_recovery(
            step_fn, _mk_state(0.0), _FakePipe(), ck, 0, 4,
            max_retries=1, max_restores=2, backoff_base=0.0,
            backoff_max=0.0, jitter=0.0, sleep_fn=lambda d: None)


def test_straggler_monitor_forget():
    mon = fault.StragglerMonitor(warmup=1, threshold=1.5)
    for h, t in (("a", 1.0), ("b", 1.0), ("c", 1.0), ("d", 5.0)):
        mon.record(h, t)
    assert mon.stragglers() == ["d"]
    mon.forget("d")
    assert mon.stragglers() == []
    assert "d" not in mon.ewma and "d" not in mon.counts
    # a replacement reusing the name warms up from scratch
    mon2 = fault.StragglerMonitor(warmup=2, threshold=1.5)
    for h in ("a", "b", "d"):
        mon2.record(h, 1.0)
        mon2.record(h, 1.0)
    mon2.forget("d")
    mon2.record("d", 9.0)
    assert mon2.stragglers() == []  # one sample < warmup


def test_straggler_monitor_even_median():
    # 4 ready hosts: sorted EWMAs [1, 1, 2, 2.8]; the proper even-length
    # median is (1+2)/2 = 1.5, so 2.8 > 1.5*1.5 flags while 2.0 does not
    # (the old upper-middle "median" of 2.0 would have flagged nothing)
    mon = fault.StragglerMonitor(warmup=1, threshold=1.5)
    for h, t in (("a", 1.0), ("b", 1.0), ("c", 2.0), ("d", 2.8)):
        mon.record(h, t)
    assert mon.stragglers() == ["d"]
