"""End-to-end training integration on a single device.

The strongest correctness checks in the suite:

* the loss **decreases** over a short run (real learning on the synthetic
  structured stream);
* all comm strategies (wfbp / single / mgwfbp / fixed) produce **identical
  losses** — gradient merging must be a pure scheduling change (the paper's
  'no side-effect on convergence' claim, §6.3.2);
* checkpoint-restore resumes to bit-identical parameters.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.train import checkpoint
from repro.train.step import build_train_step


def _setup(arch="xlstm-125m", strategy=None, steps=1, zero=0, seed=0,
           lr=1e-2, **run_overrides):
    bundle = registry.reduced_arch(arch)
    par = dataclasses.replace(bundle.parallel, dp_axes=(), zero=zero,
                              ep_axis="", attn_chunk=32)
    shape = ShapeConfig("tiny", "train", 32, 4)
    run = dataclasses.replace(bundle.run_config("train_4k", par),
                              shape=shape, microbatch=0, learning_rate=lr,
                              **run_overrides)
    model = bundle.model(par)
    mesh = make_mesh((1,), ("data",))
    step_fn, init_fn, art = build_train_step(model, run, mesh,
                                             strategy=strategy)
    state = init_fn(jax.random.PRNGKey(seed))
    pipe = DataPipeline(bundle.cfg, shape, seed=seed)
    return jax.jit(step_fn), state, pipe, art


def test_loss_decreases():
    step_fn, state, pipe, _ = _setup("xlstm-125m", lr=3e-2)
    losses = []
    for s in range(40):
        state, metrics = step_fn(state, pipe.batch_at(s))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-moe-16b"])
def test_strategies_identical_losses(arch):
    """Merging is pure scheduling: parameters after N steps are identical
    across comm strategies (single device: collectives are no-ops, but the
    bucketed code paths — pack/unpack, variadic psum grouping — differ)."""
    results = {}
    for strat in ("wfbp", "single", "mgwfbp", "fixed:65536"):
        step_fn, state, pipe, _ = _setup(arch, strategy=strat)
        for s in range(3):
            state, metrics = step_fn(state, pipe.batch_at(s))
        results[strat] = (float(metrics["loss"]),
                          np.asarray(jax.tree.leaves(state.params)[0],
                                     np.float32))
    base_loss, base_w = results["mgwfbp"]
    for strat, (loss, w) in results.items():
        assert loss == pytest.approx(base_loss, rel=1e-5), strat
        np.testing.assert_allclose(w, base_w, rtol=1e-5, atol=1e-6,
                                   err_msg=strat)


@pytest.mark.parametrize("hp", [
    {},
    # non-default AdamW hyperparameters: regression guard for the packed
    # ZeRO-1 update hardcoding b1/b2/eps instead of reading the config —
    # the sharded and replicated paths must agree for ANY betas.
    {"adam_b1": 0.85, "adam_b2": 0.999, "adam_eps": 1e-6},
])
def test_zero1_matches_zero0(hp):
    """ZeRO-1 sharded optimizer == replicated optimizer (1-device)."""
    sA, stA, pipeA, _ = _setup("qwen2-1.5b", zero=0, lr=1e-3, **hp)
    sB, stB, pipeB, _ = _setup("qwen2-1.5b", zero=1, lr=1e-3, **hp)
    for s in range(3):
        stA, mA = sA(stA, pipeA.batch_at(s))
        stB, mB = sB(stB, pipeB.batch_at(s))
    assert float(mA["loss"]) == pytest.approx(float(mB["loss"]), rel=1e-4)
    wA = np.asarray(jax.tree.leaves(stA.params)[0], np.float32)
    wB = np.asarray(jax.tree.leaves(stB.params)[0], np.float32)
    np.testing.assert_allclose(wA, wB, rtol=2e-3, atol=2e-3)


def test_lr_schedule_respects_run_config():
    """warmup_steps / total_steps flow from RunConfig into the step's LR
    schedule (previously hardcoded to 100 / 10000)."""
    step_fn, state, pipe, _ = _setup("xlstm-125m", lr=1e-2,
                                     warmup_steps=4, total_steps=50)
    lrs = []
    for s in range(6):
        state, metrics = step_fn(state, pipe.batch_at(s))
        lrs.append(float(metrics["lr"]))
    # linear warmup over 4 steps: lr(0)=0, rising to peak at step 4
    assert lrs[0] == pytest.approx(0.0, abs=1e-9)
    assert lrs[4] == pytest.approx(1e-2, rel=0.01)
    assert lrs[5] < lrs[4]              # cosine decay has begun (total=50)


def test_plan_override_identical_numerics():
    """plan_override swaps the bucketing but cannot change the math."""
    from repro.core import planner as planner_mod
    sA, stA, pipeA, art = _setup("xlstm-125m", strategy="wfbp")
    override = planner_mod.plan_single(art.specs)
    bundle = registry.reduced_arch("xlstm-125m")
    par = dataclasses.replace(bundle.parallel, dp_axes=(), zero=0,
                              ep_axis="", attn_chunk=32)
    shape = ShapeConfig("tiny", "train", 32, 4)
    run = dataclasses.replace(bundle.run_config("train_4k", par),
                              shape=shape, microbatch=0, learning_rate=1e-2)
    model = bundle.model(par)
    mesh = make_mesh((1,), ("data",))
    sB, initB, artB = build_train_step(model, run, mesh, strategy="wfbp",
                                       plan_override=override)
    assert artB.plan.buckets == override.buckets
    stB = initB(jax.random.PRNGKey(0))
    sB = jax.jit(sB)
    for s in range(3):
        stA, mA = sA(stA, pipeA.batch_at(s))
        stB, mB = sB(stB, pipeA.batch_at(s))
    for a, b in zip(jax.tree.leaves(stA.params),
                    jax.tree.leaves(stB.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_pack_kernel_step_matches_plain():
    """par.pack_kernel routes bucket collectives through the Pallas packed
    layout; on a (1,)-device data mesh the bucketed psums actually execute,
    and the kernel path must be update-for-update identical."""
    bundle = registry.reduced_arch("xlstm-125m")
    shape = ShapeConfig("tiny", "train", 32, 4)
    mesh = make_mesh((1,), ("data",))
    outs = {}
    for kernel in (False, True):
        par = dataclasses.replace(bundle.parallel, dp_axes=("data",), zero=0,
                                  ep_axis="", attn_chunk=32,
                                  pack_kernel=kernel)
        run = dataclasses.replace(bundle.run_config("train_4k", par),
                                  shape=shape, microbatch=0,
                                  learning_rate=1e-2)
        model = bundle.model(par)
        step_fn, init_fn, _ = build_train_step(model, run, mesh,
                                               strategy="mgwfbp")
        state = init_fn(jax.random.PRNGKey(0))
        pipe = DataPipeline(bundle.cfg, shape, seed=0)
        jstep = jax.jit(step_fn)
        for s in range(2):
            state, metrics = jstep(state, pipe.batch_at(s))
        outs[kernel] = jax.tree.leaves(state.params)
    for a, b in zip(outs[False], outs[True]):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_microbatch_accumulation_matches_full_batch():
    bundle = registry.reduced_arch("stablelm-1.6b")
    par = dataclasses.replace(bundle.parallel, dp_axes=(), zero=0,
                              ep_axis="", attn_chunk=32)
    shape = ShapeConfig("tiny", "train", 32, 4)
    mesh = make_mesh((1,), ("data",))
    model = bundle.model(par)
    outs = {}
    for micro in (0, 2):
        run = dataclasses.replace(bundle.run_config("train_4k", par),
                                  shape=shape, microbatch=micro,
                                  learning_rate=1e-3)
        step_fn, init_fn, _ = build_train_step(model, run, mesh)
        state = init_fn(jax.random.PRNGKey(0))
        pipe = DataPipeline(bundle.cfg, shape, seed=0)
        state, metrics = jax.jit(step_fn)(state, pipe.batch_at(0))
        outs[micro] = np.asarray(jax.tree.leaves(state.params)[0],
                                 np.float32)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-3, atol=2e-3)


def test_checkpoint_resume_bitexact(tmp_path):
    step_fn, state, pipe, _ = _setup("xlstm-125m", seed=1)
    for s in range(3):
        state, _ = step_fn(state, pipe.batch_at(s))
    checkpoint.save(str(tmp_path), 3, state)
    # continue original
    cont = state
    for s in range(3, 6):
        cont, _ = step_fn(cont, pipe.batch_at(s))
    # restore + replay
    restored, start, _ = checkpoint.restore(str(tmp_path), state)
    assert start == 3
    for s in range(3, 6):
        restored, _ = step_fn(restored, pipe.batch_at(s))
    for a, b in zip(jax.tree.leaves(cont.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
