"""End-to-end training integration on a single device.

The strongest correctness checks in the suite:

* the loss **decreases** over a short run (real learning on the synthetic
  structured stream);
* all comm strategies (wfbp / single / mgwfbp / fixed) produce **identical
  losses** — gradient merging must be a pure scheduling change (the paper's
  'no side-effect on convergence' claim, §6.3.2);
* checkpoint-restore resumes to bit-identical parameters.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.train import checkpoint
from repro.train.step import build_train_step


def _setup(arch="xlstm-125m", strategy=None, steps=1, zero=0, seed=0,
           lr=1e-2):
    bundle = registry.reduced_arch(arch)
    par = dataclasses.replace(bundle.parallel, dp_axes=(), zero=zero,
                              ep_axis="", attn_chunk=32)
    shape = ShapeConfig("tiny", "train", 32, 4)
    run = dataclasses.replace(bundle.run_config("train_4k", par),
                              shape=shape, microbatch=0, learning_rate=lr)
    model = bundle.model(par)
    mesh = make_mesh((1,), ("data",))
    step_fn, init_fn, art = build_train_step(model, run, mesh,
                                             strategy=strategy)
    state = init_fn(jax.random.PRNGKey(seed))
    pipe = DataPipeline(bundle.cfg, shape, seed=seed)
    return jax.jit(step_fn), state, pipe, art


def test_loss_decreases():
    step_fn, state, pipe, _ = _setup("xlstm-125m", lr=3e-2)
    losses = []
    for s in range(40):
        state, metrics = step_fn(state, pipe.batch_at(s))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-moe-16b"])
def test_strategies_identical_losses(arch):
    """Merging is pure scheduling: parameters after N steps are identical
    across comm strategies (single device: collectives are no-ops, but the
    bucketed code paths — pack/unpack, variadic psum grouping — differ)."""
    results = {}
    for strat in ("wfbp", "single", "mgwfbp", "fixed:65536"):
        step_fn, state, pipe, _ = _setup(arch, strategy=strat)
        for s in range(3):
            state, metrics = step_fn(state, pipe.batch_at(s))
        results[strat] = (float(metrics["loss"]),
                          np.asarray(jax.tree.leaves(state.params)[0],
                                     np.float32))
    base_loss, base_w = results["mgwfbp"]
    for strat, (loss, w) in results.items():
        assert loss == pytest.approx(base_loss, rel=1e-5), strat
        np.testing.assert_allclose(w, base_w, rtol=1e-5, atol=1e-6,
                                   err_msg=strat)


def test_zero1_matches_zero0():
    """ZeRO-1 sharded optimizer == replicated optimizer (1-device)."""
    sA, stA, pipeA, _ = _setup("qwen2-1.5b", zero=0, lr=1e-3)
    sB, stB, pipeB, _ = _setup("qwen2-1.5b", zero=1, lr=1e-3)
    for s in range(3):
        stA, mA = sA(stA, pipeA.batch_at(s))
        stB, mB = sB(stB, pipeB.batch_at(s))
    assert float(mA["loss"]) == pytest.approx(float(mB["loss"]), rel=1e-4)
    wA = np.asarray(jax.tree.leaves(stA.params)[0], np.float32)
    wB = np.asarray(jax.tree.leaves(stB.params)[0], np.float32)
    np.testing.assert_allclose(wA, wB, rtol=2e-3, atol=2e-3)


def test_microbatch_accumulation_matches_full_batch():
    bundle = registry.reduced_arch("stablelm-1.6b")
    par = dataclasses.replace(bundle.parallel, dp_axes=(), zero=0,
                              ep_axis="", attn_chunk=32)
    shape = ShapeConfig("tiny", "train", 32, 4)
    mesh = make_mesh((1,), ("data",))
    model = bundle.model(par)
    outs = {}
    for micro in (0, 2):
        run = dataclasses.replace(bundle.run_config("train_4k", par),
                                  shape=shape, microbatch=micro,
                                  learning_rate=1e-3)
        step_fn, init_fn, _ = build_train_step(model, run, mesh)
        state = init_fn(jax.random.PRNGKey(0))
        pipe = DataPipeline(bundle.cfg, shape, seed=0)
        state, metrics = jax.jit(step_fn)(state, pipe.batch_at(0))
        outs[micro] = np.asarray(jax.tree.leaves(state.params)[0],
                                 np.float32)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-3, atol=2e-3)


def test_checkpoint_resume_bitexact(tmp_path):
    step_fn, state, pipe, _ = _setup("xlstm-125m", seed=1)
    for s in range(3):
        state, _ = step_fn(state, pipe.batch_at(s))
    checkpoint.save(str(tmp_path), 3, state)
    # continue original
    cont = state
    for s in range(3, 6):
        cont, _ = step_fn(cont, pipe.batch_at(s))
    # restore + replay
    restored, start, _ = checkpoint.restore(str(tmp_path), state)
    assert start == 3
    for s in range(3, 6):
        restored, _ = step_fn(restored, pipe.batch_at(s))
    for a, b in zip(jax.tree.leaves(cont.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
