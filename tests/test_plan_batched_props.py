"""Batched-planner property tests: the jitted DP kernel must be
bucket-bit-equal to the exact Python oracle on arbitrary problems, and
its answers must never depend on batch-mates or backend.  Skipped
without the real hypothesis package (and without jax)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
pytest.importorskip("jax", reason="fleet kernel needs jax")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402

from repro.core.coplanner import CoPlanner, coplan  # noqa: E402
from repro.core.cost_model import (AllReduceModel, PathModel,  # noqa: E402
                                   PathPhase)
from repro.core.planner import TensorSpec, plan_dp_optimal  # noqa: E402
from repro.sim.coplan_profiles import make_fleet_jobs  # noqa: E402
from repro.sim.fleet import (FleetEvaluator, make_plan_case,  # noqa: E402
                             plan_batched, plan_cases)


def _random_problem(rng):
    """A random planning problem: ragged L (1 included), zero-byte
    tensors allowed, occasionally a PathModel (flattened by the kernel
    entry point)."""
    L = int(rng.integers(1, 24))
    specs = [TensorSpec(f"t{i}", int(rng.integers(0, 1 << 22)),
                        float(rng.uniform(0, 5e-3))) for i in range(L)]
    if rng.integers(0, 4) == 0:
        model = PathModel((
            PathPhase("ici", float(rng.uniform(0, 1e-3)),
                      float(rng.uniform(1e-11, 5e-9))),
            PathPhase("dcn", float(rng.uniform(0, 1e-3)),
                      float(rng.uniform(1e-11, 5e-9)))))
    else:
        model = AllReduceModel(float(rng.uniform(0, 2e-3)),
                               float(rng.uniform(1e-11, 1e-8)))
    return specs, model


@hypothesis.given(st.integers(0, 10_000))
@hypothesis.settings(max_examples=15, deadline=None)
def test_plan_batched_matches_dp_oracle(seed):
    """Bucket-bit-equality with plan_dp_optimal on a random ragged
    batch, both backends — zero-byte tensors, L=1 problems and
    PathModel flattening included."""
    rng = np.random.default_rng(seed)
    problems = [_random_problem(rng) for _ in range(int(rng.integers(1, 8)))]
    refs = [plan_dp_optimal(s, m) for s, m in problems]
    for backend in ("fleet", "numpy"):
        got = plan_batched(problems, backend=backend)
        for g, r in zip(got, refs):
            assert g.buckets == r.buckets, (backend, g.buckets, r.buckets)
            assert g.strategy == "dp_batched"


@hypothesis.given(st.integers(0, 10_000))
@hypothesis.settings(max_examples=10, deadline=None)
def test_plan_batched_padding_invariance(seed):
    """A problem's plan never depends on its batch-mates: planning it
    alone (small L/C padding) equals planning it beside a much longer
    filler (large padding)."""
    rng = np.random.default_rng(seed)
    problems = [_random_problem(rng) for _ in range(3)]
    filler_specs = [TensorSpec(f"b{i}", 1 << 12, 1e-4) for i in range(40)]
    filler = make_plan_case(filler_specs, AllReduceModel(1e-4, 1e-9))
    cases = [make_plan_case(s, m) for s, m in problems]
    batched = plan_cases(cases + [filler])
    for c, together in zip(cases, batched):
        alone = plan_cases([c])[0]
        assert together.buckets == alone.buckets


@hypothesis.given(st.integers(0, 200))
@hypothesis.settings(max_examples=5, deadline=None)
def test_batched_coplanner_matches_sequential(seed):
    """response_mode='batched' must be bit-equal whether candidates are
    scored through the evaluator's one-call .batch hook or one at a
    time (the hook hidden behind a lambda)."""
    jobs = make_fleet_jobs(6, seed=seed)
    ev = FleetEvaluator(jobs, iters=4)
    res_b = coplan(jobs, ev, max_rounds=4, response_mode="batched")
    res_s = coplan(jobs, lambda p: ev(p), max_rounds=4,
                   response_mode="batched")
    assert res_b.makespan == res_s.makespan
    assert {n: p.buckets for n, p in res_b.plans.items()} == \
        {n: p.buckets for n, p in res_s.plans.items()}


@hypothesis.given(st.integers(0, 200))
@hypothesis.settings(max_examples=5, deadline=None)
def test_batched_coplanner_keeps_seed_guarantee(seed):
    """Batched best-response never loses to the static seed plans, and
    its round-0 batched-DP plans match the per-job oracle."""
    jobs = make_fleet_jobs(5, seed=seed)
    ev = FleetEvaluator(jobs, iters=4)
    res = CoPlanner(jobs, ev, max_rounds=3, response_mode="batched").run()
    seed_best = min(r.makespan for r in res.rounds if r.kind == "seed")
    assert res.makespan <= seed_best + 1e-12
    round0 = next(r for r in res.rounds if r.kind == "response")
    for j in jobs:
        ref = plan_dp_optimal(list(j.specs), j.model)
        assert round0.plans[j.name].buckets == ref.buckets
