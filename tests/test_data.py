"""Data pipeline: determinism (exact resume), sharding, masking."""

import numpy as np

from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import SyntheticConfig, synthetic_batch
from repro.models import registry


def test_batches_deterministic_per_step():
    cfg = SyntheticConfig(vocab_size=1000, seq_len=64)
    b1 = synthetic_batch(cfg, seed=0, step=5, batch=4)
    b2 = synthetic_batch(cfg, seed=0, step=5, batch=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic_batch(cfg, seed=0, step=6, batch=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_shards_differ():
    cfg = SyntheticConfig(vocab_size=1000, seq_len=64)
    s0 = synthetic_batch(cfg, 0, 1, 4, shard=0, num_shards=2)
    s1 = synthetic_batch(cfg, 0, 1, 4, shard=1, num_shards=2)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_labels_are_next_tokens():
    cfg = SyntheticConfig(vocab_size=1000, seq_len=64)
    b = synthetic_batch(cfg, 0, 0, 2)
    # labels[t] predicts tokens[t+1]'s source sequence: check alignment
    assert b["tokens"].shape == b["labels"].shape == (2, 64)
    assert b["tokens"].dtype == np.int32


def test_prefix_masking():
    cfg = SyntheticConfig(vocab_size=1000, seq_len=64, mask_prefix=8)
    b = synthetic_batch(cfg, 0, 0, 2)
    assert (b["labels"][:, :8] == -1).all()
    assert (b["labels"][:, 8:] >= 0).all()


def test_pipeline_resume_identical():
    bundle = registry.reduced_arch("qwen2-1.5b")
    shape = ShapeConfig("t", "train", 32, 4)
    p1 = DataPipeline(bundle.cfg, shape, seed=3)
    p2 = DataPipeline(bundle.cfg, shape, seed=3)
    for step in (0, 17, 100):
        a, b = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))


def test_pipeline_prefetch_thread():
    bundle = registry.reduced_arch("xlstm-125m")
    shape = ShapeConfig("t", "train", 16, 2)
    p = DataPipeline(bundle.cfg, shape, seed=0).start(start_step=0)
    b0 = p.next()
    b1 = p.next()
    p.stop()
    ref = p.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(ref["tokens"]))
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_vlm_pipeline_has_prefix_embeds():
    bundle = registry.reduced_arch("phi-3-vision-4.2b")
    shape = ShapeConfig("t", "train", 32, 2)
    p = DataPipeline(bundle.cfg, shape, seed=0)
    b = p.batch_at(0)
    assert "prefix_embeds" in b
    assert b["prefix_embeds"].shape == (2, bundle.cfg.frontend_prefix_len,
                                        bundle.cfg.d_model)
    assert (np.asarray(b["labels"][:, :bundle.cfg.frontend_prefix_len])
            == -1).all()
