"""Frontier-invariant property tests for repro.sim.schedules; skipped
without the real hypothesis package.

Three families:

* random acyclic :class:`DAGSchedule` graphs always complete — no
  deadlock, whatever the precedence/resource mix;
* per-worker clocks are non-decreasing under every schedule, on random
  profiles with random jitter;
* total communicated bytes is schedule-invariant across the synchronous
  schedules (BSP, pipelined split collectives, 1F1B accumulation) — no
  schedule silently drops or duplicates gradient traffic.
"""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import hypothesis  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
from prop_strategies import mk_specs, specs_strategy  # noqa: E402

from repro.core.cost_model import AllReduceModel  # noqa: E402
from repro.core.planner import make_plan  # noqa: E402
from repro.sim.engine import ClusterSim, JobSpec, Topology  # noqa: E402
from repro.sim.schedules import (BSP, DAGSchedule, DAGTask, LocalSGD,  # noqa: E402
                                 OneFoneB, PipelinedAllReduce)
from repro.sim.workers import make_workers  # noqa: E402

from schedule_harness import assert_frontier_monotone  # noqa: E402

MODEL = AllReduceModel(5e-4, 2e-9)


# -- random DAGs never deadlock ---------------------------------------------

@st.composite
def dag_tasks(draw):
    """Random acyclic task graphs: deps only point at earlier tasks."""
    n = draw(st.integers(1, 12))
    n_workers = draw(st.integers(1, 3))
    n_links = draw(st.integers(0, 2))
    tasks = []
    for i in range(n):
        n_deps = draw(st.integers(0, min(i, 3)))
        deps = tuple(sorted({f"t{d}" for d in draw(st.lists(
            st.integers(0, i - 1), min_size=n_deps, max_size=n_deps))})) \
            if i else ()
        kind = draw(st.integers(0, 2 if n_links else 1))
        worker = f"w{draw(st.integers(0, n_workers - 1))}" \
            if kind == 0 else None
        link = f"l{draw(st.integers(0, n_links - 1))}" \
            if kind == 2 else None
        tasks.append(DAGTask(f"t{i}", duration=draw(st.floats(0.0, 1e-2)),
                             worker=worker, link=link, deps=deps))
    return tuple(tasks)


@hypothesis.given(dag_tasks())
@hypothesis.settings(max_examples=60, deadline=None)
def test_random_dag_schedules_never_deadlock(tasks):
    job = JobSpec(name="dag", specs=[], plan=make_plan("wfbp", []),
                  t_f=0.0, workers=make_workers(1),
                  topology=Topology(MODEL),
                  schedule=DAGSchedule(tasks))
    res = ClusterSim([job]).run()
    jr = res.job("dag")
    assert len(jr.iterations) == 1                 # the graph completed
    ran = {s.name for s in res.spans if s.pid == "dag"}
    assert ran == {t.name for t in tasks}          # every task executed
    # completion respects every dependency edge
    ends = {s.name: s.end for s in res.spans if s.pid == "dag"}
    starts = {s.name: s.start for s in res.spans if s.pid == "dag"}
    for t in tasks:
        for d in t.deps:
            assert starts[t.name] >= ends[d] - 1e-12


# -- per-worker clocks never go backwards -----------------------------------

SCHEDULES = st.sampled_from([
    BSP(), PipelinedAllReduce(0.5), PipelinedAllReduce(0.25),
    OneFoneB(2), OneFoneB(4), LocalSGD(2), LocalSGD(4),
])


@hypothesis.given(SCHEDULES, specs_strategy(min_n=1, max_n=10),
                  st.floats(0.0, 0.4), st.integers(0, 1000),
                  st.sampled_from(["events", "analytic"]))
@hypothesis.settings(max_examples=60, deadline=None)
def test_worker_clocks_non_decreasing(schedule, sizes_times, jitter, seed,
                                      compute_mode):
    specs = mk_specs(*sizes_times)
    plan = make_plan("mgwfbp", specs, MODEL)
    job = JobSpec(name="j", specs=specs, plan=plan, t_f=1e-3,
                  workers=make_workers(3, jitter_sigma=jitter),
                  topology=Topology(MODEL), iters=5,
                  compute_mode=compute_mode, schedule=schedule)
    jr = ClusterSim([job], seed=seed).run().job("j")
    assert len(jr.iterations) == 5
    assert_frontier_monotone(jr)


# -- bytes are schedule-invariant for synchronous schedules -----------------

@hypothesis.given(specs_strategy(min_n=1, max_n=10),
                  st.sampled_from(["wfbp", "single", "mgwfbp"]),
                  st.integers(1, 4))
@hypothesis.settings(max_examples=40, deadline=None)
def test_bytes_schedule_invariant_for_synchronous(sizes_times, strategy,
                                                  iters):
    specs = mk_specs(*sizes_times)
    plan = make_plan(strategy, specs, MODEL)
    expected = sum(s.nbytes for s in specs) * iters

    def bytes_under(schedule):
        job = JobSpec(name="j", specs=specs, plan=plan, t_f=1e-3,
                      workers=make_workers(2), topology=Topology(MODEL),
                      iters=iters, compute_mode="analytic",
                      schedule=schedule)
        return ClusterSim([job]).run().job("j").bytes_communicated

    for schedule in (BSP(), PipelinedAllReduce(0.5),
                     PipelinedAllReduce(0.25), OneFoneB(3)):
        assert schedule.synchronous
        assert bytes_under(schedule) == pytest.approx(expected, rel=1e-12)
