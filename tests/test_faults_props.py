"""Property-based fault-injection invariants (skip without hypothesis).

The liveness bar for the resilience tentpole: NO random fault schedule
may deadlock the engine.  Whatever combination of crashes, preemptions,
link flaps, slow hosts and checkpoint failures a seed draws — under the
controller or the naive baseline — ``sim.run()`` must return with every
iteration completed and sim time finite, and the supervisor's books must
balance (useful + wasted == steps the engine actually ran).
"""

import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.sim import scenarios, trace  # noqa: E402
from repro.sim.faults import FaultPlan  # noqa: E402

SPECS, T_F = trace.synthetic_specs(12, seed=7)
ITERS = 8
HORIZON = ITERS * (T_F + sum(s.t_b for s in SPECS))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       n_crashes=st.integers(min_value=0, max_value=2),
       n_preemptions=st.integers(min_value=0, max_value=2),
       n_degradations=st.integers(min_value=0, max_value=2),
       n_slow=st.integers(min_value=0, max_value=1),
       resilient=st.booleans())
def test_random_fault_plans_never_deadlock(seed, n_crashes, n_preemptions,
                                           n_degradations, n_slow,
                                           resilient):
    plan = FaultPlan.random(
        seed, HORIZON, [f"w{i}" for i in range(6)], links=["net"],
        n_crashes=n_crashes, n_preemptions=n_preemptions,
        n_degradations=n_degradations, n_slow=n_slow, n_ckpt_failures=1)
    sim, report = scenarios.faulty_long_run(
        SPECS, T_F, n_workers=6, iters=ITERS, plan=plan,
        resilient=resilient, seed=seed)
    res = sim.run()
    its = res.job("train").iterations
    assert len(its) == ITERS                      # liveness: all completed
    assert math.isfinite(sim.engine.now)
    assert all(it.t_iter > 0 for it in its)
    avail = report.availability                   # final hook ran
    assert avail is not None
    assert avail.useful_steps + avail.wasted_steps == ITERS
    ctrl = report.controller
    assert ctrl.n_active >= 1
    # links always end with a positive, finite service rate
    for link in sim.links.values():
        assert link.rate_scale > 0 and math.isfinite(link.rate_scale)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_random_plan_determinism(seed):
    kw = dict(horizon=HORIZON, workers=[f"w{i}" for i in range(6)],
              links=["net"], n_crashes=2, n_preemptions=2,
              n_degradations=2, n_slow=1, n_ckpt_failures=2)
    assert FaultPlan.random(seed, **kw) == FaultPlan.random(seed, **kw)
