"""Render a flight-recorder JSONL into a terminal triage summary.

    PYTHONPATH=src python scripts/obs_report.py <records.jsonl> \
        [metrics.json]

Per job: step-time percentiles (p50/p95/p99), comm/compute overlap
fraction, per-link utilization over the job's span; then a recovery
section when the stream holds resilience events (injected faults,
recoveries with MTTR, goodput, per-fault-kind counts — see
``repro.train.resilience``); then the decision / drift-alert event log.
Input is whatever ``FlightRecorder.write`` (or
``repro.obs.recorder.write_jsonl``) produced — simulator runs and real
instrumented train steps share one schema, so one report covers both.

The optional second argument is a metrics-registry snapshot
(``benchmarks/run.py --emit-metrics`` writes one as
``BENCH_metrics.json``); the report then adds a **planning
amortization** section — how many candidate assignments each batched
co-planner evaluation amortized (``coplanner_batched_eval_size``),
batched-DP planning volume, fleet-kernel call counts, geometry-cache
hit rates, and the what-if serving counters/latency.
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.recorder import IterationRecord, read_jsonl  # noqa: E402


def _pct(values: list[float], q: float) -> float:
    """Nearest-rank percentile (no numpy dependency for a triage tool)."""
    if not values:
        return 0.0
    ordered = sorted(values)
    k = min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))
    return ordered[k]


def _group(records) -> dict[str, list[IterationRecord]]:
    jobs: dict[str, list[IterationRecord]] = {}
    for r in records:
        if isinstance(r, IterationRecord):
            jobs.setdefault(f"{r.source}:{r.job}", []).append(r)
    return jobs


def job_summary(key: str, its: list[IterationRecord]) -> list[str]:
    lines = [f"{key}: {len(its)} iterations"]
    steps = [r.t_iter for r in its]
    lines.append(
        f"  step time   p50 {_pct(steps, 0.50) * 1e3:9.3f} ms   "
        f"p95 {_pct(steps, 0.95) * 1e3:9.3f} ms   "
        f"p99 {_pct(steps, 0.99) * 1e3:9.3f} ms")

    # overlap: fraction of communication hidden under computation —
    # comm spilling past backward_end is the non-overlapped tail (Eq. 8)
    comm = sum(r.comm_total for r in its)
    exposed = sum(max(0.0, max((b.end for b in r.buckets),
                               default=r.backward_end) - r.backward_end)
                  for r in its)
    if comm > 0:
        lines.append(f"  comm/compute overlap {max(0.0, 1 - exposed / comm):6.1%}"
                     f"   (comm {comm * 1e3:.3f} ms, exposed "
                     f"{exposed * 1e3:.3f} ms)")

    # per-link utilization: link_busy is cumulative at each record, so
    # the last record's value over the job's span is the honest figure
    span = max(r.end for r in its) - min(r.start for r in its)
    busy = dict(its[-1].link_busy)
    nbytes = dict(its[-1].link_bytes)
    for link in sorted(busy):
        if span > 0:
            lines.append(
                f"  link {link:<12} util {busy[link] / span:6.1%}   "
                f"({nbytes.get(link, 0) / 1e6:.2f} MB on the wire)")
    return lines


def recovery_summary(records) -> list[str]:
    """The resilience view of an event stream: faults injected/detected,
    recoveries with MTTR, wasted steps, the final availability line."""
    events = [r for r in records if not isinstance(r, IterationRecord)]
    recoveries = [e for e in events if e.kind == "recovery"]

    def kind_counts(kind: str) -> dict[str, int]:
        counts: dict[str, int] = {}
        for e in events:
            if e.kind == kind:
                k = str(e.args.get("fault", "?"))
                counts[k] = counts.get(k, 0) + 1
        return counts

    injected = kind_counts("fault_injected")
    detected = kind_counts("fault_detected")
    if not injected and not detected and not recoveries:
        return []
    lines = ["recovery:"]
    if injected:
        lines.append("  injected    " + "  ".join(
            f"{k}={n}" for k, n in sorted(injected.items())))
    if detected:
        lines.append("  detected    " + "  ".join(
            f"{k}={n}" for k, n in sorted(detected.items())))
    mttrs = [float(e.args["mttr"]) for e in recoveries
             if e.args.get("mttr") is not None]
    rec_kinds: dict[str, int] = {}
    for e in recoveries:
        k = str(e.args.get("fault", "?"))
        rec_kinds[k] = rec_kinds.get(k, 0) + 1
    if recoveries:
        lines.append("  recovered   " + "  ".join(
            f"{k}={n}" for k, n in sorted(rec_kinds.items())))
        lines.append(
            f"  mttr        p50 {_pct(mttrs, 0.50) * 1e3:9.3f} ms   "
            f"p95 {_pct(mttrs, 0.95) * 1e3:9.3f} ms   "
            f"max {max(mttrs) * 1e3:9.3f} ms")
    discarded = sum(1 for e in events if e.kind == "step_discarded")
    ckpt_fails = sum(1 for e in events if e.kind == "ckpt_fail")
    if discarded or ckpt_fails:
        lines.append(f"  wasted      discarded_steps={discarded}  "
                     f"ckpt_failures={ckpt_fails}")
    for e in events:
        if e.kind == "availability":
            lines.append(
                f"  availability goodput={e.args.get('goodput', 0):.2f} "
                f"steps/s  useful={e.args.get('useful_steps')}  "
                f"wasted={e.args.get('wasted_steps')}  "
                f"replayed={e.args.get('replayed_fraction', 0):.3f}  "
                f"unrecovered={e.args.get('unrecovered')}")
    return lines


def _series(metrics: dict, name: str) -> dict:
    return metrics.get(name, {}).get("series", {})


def _total(metrics: dict, name: str) -> float:
    return sum(_series(metrics, name).values())


def _hist_line(label: str, h: dict) -> str:
    count = h.get("count", 0)
    mean = h["sum"] / count if count else 0.0
    return (f"  {label:<28} n={count}  mean={mean:g}  "
            f"min={h.get('min', 0):g}  max={h.get('max', 0):g}")


def amortization_summary(metrics: dict) -> list[str]:
    """Planning-stage amortization from a metrics-registry snapshot:
    batched evaluations/planning, kernel calls, caches, what-if serving."""
    lines: list[str] = []
    batched = _total(metrics, "coplanner_batched_evals_total")
    if batched:
        lines.append(f"  batched candidate evals      {batched:g} "
                     f"assignments total")
    for key, h in sorted(
            _series(metrics, "coplanner_batched_eval_size").items()):
        lines.append(_hist_line(
            "assignments / batched eval" + (f" [{key}]" if key else ""),
            h))
    for key, v in sorted(_series(metrics,
                                 "fleet_plan_cases_total").items()):
        lines.append(f"  batched-DP plans [{key or 'all'}]   {v:g}")
    kernel = _series(metrics, "fleet_kernel_calls_total")
    if kernel:
        lines.append("  fleet kernel calls           " + "  ".join(
            f"{k}={v:g}" for k, v in sorted(kernel.items())))
    hits = _total(metrics, "fleet_geom_cache_hits_total")
    evict = _total(metrics, "fleet_geom_cache_evictions_total")
    if hits or evict:
        lines.append(f"  geometry cache               hits={hits:g}  "
                     f"evictions={evict:g}")
    queries = _series(metrics, "whatif_queries_total")
    if queries:
        served = sum(queries.values())
        cached = _total(metrics, "whatif_cache_hits_total")
        lines.append("  what-if queries              " + "  ".join(
            f"{k}={v:g}" for k, v in sorted(queries.items())))
        lines.append(f"  what-if cache                hits={cached:g} "
                     f"({cached / served:.1%} of {served:g} queries)")
    for key, h in sorted(_series(metrics,
                                 "whatif_latency_seconds").items()):
        lines.append(_hist_line(
            "what-if ask() seconds" + (f" [{key}]" if key else ""), h))
    return ["planning amortization:"] + lines if lines else []


def render(path: str, metrics_path: str | None = None) -> str:
    records = read_jsonl(path)
    out = [f"flight recorder: {path} ({len(records)} records)", ""]
    if metrics_path is not None:
        with open(metrics_path) as f:
            amort = amortization_summary(json.load(f))
        if amort:
            out.extend(amort)
            out.append("")
    for key, its in sorted(_group(records).items()):
        out.extend(job_summary(key, its))
        out.append("")
    recovery = recovery_summary(records)
    if recovery:
        out.extend(recovery)
        out.append("")
    events = [r for r in records if not isinstance(r, IterationRecord)]
    if events:
        out.append(f"events ({len(events)}):")
        for e in events:
            detail = ", ".join(f"{k}={v}" for k, v in sorted(e.args.items())
                               if not isinstance(v, dict))
            flag = " <-- DRIFT" if e.kind == "drift_alert" else ""
            out.append(f"  [{e.source}] {e.kind} @ {e.time:g}: "
                       f"{detail}{flag}")
    return "\n".join(out)


def main(argv: list[str]) -> int:
    if len(argv) not in (2, 3):
        print(__doc__)
        return 2
    print(render(argv[1], argv[2] if len(argv) == 3 else None))
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
