"""Render a flight-recorder JSONL into a terminal triage summary.

    PYTHONPATH=src python scripts/obs_report.py <records.jsonl>

Per job: step-time percentiles (p50/p95/p99), comm/compute overlap
fraction, per-link utilization over the job's span; then a recovery
section when the stream holds resilience events (injected faults,
recoveries with MTTR, goodput, per-fault-kind counts — see
``repro.train.resilience``); then the decision / drift-alert event log.
Input is whatever ``FlightRecorder.write`` (or
``repro.obs.recorder.write_jsonl``) produced — simulator runs and real
instrumented train steps share one schema, so one report covers both.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.recorder import IterationRecord, read_jsonl  # noqa: E402


def _pct(values: list[float], q: float) -> float:
    """Nearest-rank percentile (no numpy dependency for a triage tool)."""
    if not values:
        return 0.0
    ordered = sorted(values)
    k = min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))
    return ordered[k]


def _group(records) -> dict[str, list[IterationRecord]]:
    jobs: dict[str, list[IterationRecord]] = {}
    for r in records:
        if isinstance(r, IterationRecord):
            jobs.setdefault(f"{r.source}:{r.job}", []).append(r)
    return jobs


def job_summary(key: str, its: list[IterationRecord]) -> list[str]:
    lines = [f"{key}: {len(its)} iterations"]
    steps = [r.t_iter for r in its]
    lines.append(
        f"  step time   p50 {_pct(steps, 0.50) * 1e3:9.3f} ms   "
        f"p95 {_pct(steps, 0.95) * 1e3:9.3f} ms   "
        f"p99 {_pct(steps, 0.99) * 1e3:9.3f} ms")

    # overlap: fraction of communication hidden under computation —
    # comm spilling past backward_end is the non-overlapped tail (Eq. 8)
    comm = sum(r.comm_total for r in its)
    exposed = sum(max(0.0, max((b.end for b in r.buckets),
                               default=r.backward_end) - r.backward_end)
                  for r in its)
    if comm > 0:
        lines.append(f"  comm/compute overlap {max(0.0, 1 - exposed / comm):6.1%}"
                     f"   (comm {comm * 1e3:.3f} ms, exposed "
                     f"{exposed * 1e3:.3f} ms)")

    # per-link utilization: link_busy is cumulative at each record, so
    # the last record's value over the job's span is the honest figure
    span = max(r.end for r in its) - min(r.start for r in its)
    busy = dict(its[-1].link_busy)
    nbytes = dict(its[-1].link_bytes)
    for link in sorted(busy):
        if span > 0:
            lines.append(
                f"  link {link:<12} util {busy[link] / span:6.1%}   "
                f"({nbytes.get(link, 0) / 1e6:.2f} MB on the wire)")
    return lines


def recovery_summary(records) -> list[str]:
    """The resilience view of an event stream: faults injected/detected,
    recoveries with MTTR, wasted steps, the final availability line."""
    events = [r for r in records if not isinstance(r, IterationRecord)]
    recoveries = [e for e in events if e.kind == "recovery"]

    def kind_counts(kind: str) -> dict[str, int]:
        counts: dict[str, int] = {}
        for e in events:
            if e.kind == kind:
                k = str(e.args.get("fault", "?"))
                counts[k] = counts.get(k, 0) + 1
        return counts

    injected = kind_counts("fault_injected")
    detected = kind_counts("fault_detected")
    if not injected and not detected and not recoveries:
        return []
    lines = ["recovery:"]
    if injected:
        lines.append("  injected    " + "  ".join(
            f"{k}={n}" for k, n in sorted(injected.items())))
    if detected:
        lines.append("  detected    " + "  ".join(
            f"{k}={n}" for k, n in sorted(detected.items())))
    mttrs = [float(e.args["mttr"]) for e in recoveries
             if e.args.get("mttr") is not None]
    rec_kinds: dict[str, int] = {}
    for e in recoveries:
        k = str(e.args.get("fault", "?"))
        rec_kinds[k] = rec_kinds.get(k, 0) + 1
    if recoveries:
        lines.append("  recovered   " + "  ".join(
            f"{k}={n}" for k, n in sorted(rec_kinds.items())))
        lines.append(
            f"  mttr        p50 {_pct(mttrs, 0.50) * 1e3:9.3f} ms   "
            f"p95 {_pct(mttrs, 0.95) * 1e3:9.3f} ms   "
            f"max {max(mttrs) * 1e3:9.3f} ms")
    discarded = sum(1 for e in events if e.kind == "step_discarded")
    ckpt_fails = sum(1 for e in events if e.kind == "ckpt_fail")
    if discarded or ckpt_fails:
        lines.append(f"  wasted      discarded_steps={discarded}  "
                     f"ckpt_failures={ckpt_fails}")
    for e in events:
        if e.kind == "availability":
            lines.append(
                f"  availability goodput={e.args.get('goodput', 0):.2f} "
                f"steps/s  useful={e.args.get('useful_steps')}  "
                f"wasted={e.args.get('wasted_steps')}  "
                f"replayed={e.args.get('replayed_fraction', 0):.3f}  "
                f"unrecovered={e.args.get('unrecovered')}")
    return lines


def render(path: str) -> str:
    records = read_jsonl(path)
    out = [f"flight recorder: {path} ({len(records)} records)", ""]
    for key, its in sorted(_group(records).items()):
        out.extend(job_summary(key, its))
        out.append("")
    recovery = recovery_summary(records)
    if recovery:
        out.extend(recovery)
        out.append("")
    events = [r for r in records if not isinstance(r, IterationRecord)]
    if events:
        out.append(f"events ({len(events)}):")
        for e in events:
            detail = ", ".join(f"{k}={v}" for k, v in sorted(e.args.items())
                               if not isinstance(v, dict))
            flag = " <-- DRIFT" if e.kind == "drift_alert" else ""
            out.append(f"  [{e.source}] {e.kind} @ {e.time:g}: "
                       f"{detail}{flag}")
    return "\n".join(out)


def main(argv: list[str]) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    print(render(argv[1]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
