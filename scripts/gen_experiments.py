"""Assemble EXPERIMENTS.md from dry-run artifacts + the perf log.

Re-runnable: §Dry-run and §Roofline regenerate from artifacts/dryrun/*.json;
§Perf is included verbatim from artifacts/perf_log.md (the hillclimb diary);
§Paper-validation quotes the benchmark claims-check results.

    PYTHONPATH=src:. python scripts/gen_experiments.py
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import roofline  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "artifacts", "dryrun")


def _fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    if b >= 1e6:
        return f"{b/1e6:.1f}MB"
    return f"{b/1e3:.0f}KB"


def dryrun_section():
    rows = []
    fails = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        if os.path.basename(f).count("__") > 2:
            continue
        r = json.load(open(f))
        if not r.get("ok"):
            fails.append(f"- {r['arch']} × {r['shape']} × {r['mesh']}: "
                         f"`{r.get('error','?')[:140]}`")
            continue
        h = r["hlo"]
        counts = h.get("collective_count", {})
        csum = ", ".join(f"{k.replace('all-','a')}:{int(v)}"
                         for k, v in sorted(counts.items()))
        plan = r.get("plan", {})
        plan_s = (f"{plan.get('num_buckets','-')}/"
                  f"{plan.get('num_tensors','-')}" if plan else "—")
        mem = r.get("memory", {}).get("total_hbm_bytes", 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('lower_s',0):.0f}+{r.get('compile_s',0):.0f}s | "
            f"{plan_s} | {h['flops']:.2e} | "
            f"{_fmt_bytes(h['collective_bytes'])} | {csum} | "
            f"{_fmt_bytes(mem)} |")
    hdr = ("| arch | shape | mesh | lower+compile | plan (buckets/tensors) "
           "| HLO FLOPs/dev | collective bytes/dev | collective ops | "
           "program bytes* |\n|---|---|---|---|---|---|---|---|---|")
    out = [hdr] + rows
    if fails:
        out += ["", "**Failing cells (open):**"] + fails
    out += ["",
            "\\* `compiled.memory_analysis()` totals as reported by the CPU "
            "backend (args+temps+outputs); on CPU this is a whole-program "
            "figure with fp32-promoted collective temps — per-chip HBM "
            "feasibility is tracked by the analytic model in §Roofline and "
            "the per-arch sizing notes in DESIGN.md §5."]
    return "\n".join(out)


def roofline_section():
    out = []
    for mesh in ("single",):
        rows = roofline.load_all(mesh=mesh)
        rows.sort(key=lambda r: (r["arch"], r["shape"]))
        out.append(f"### Mesh: {mesh} (16×16 = 256 chips)\n")
        out.append(roofline.markdown_table(rows))
        out.append("\nPer-cell bottleneck notes:\n")
        for r in rows:
            out.append(f"- **{r['arch']} × {r['shape']}** — dominated by "
                       f"{r['dominant']}; {roofline.improvement_note(r)}.")
        out.append("")
    return "\n".join(out)


def main():
    perf = ""
    perf_path = os.path.join(ROOT, "artifacts", "perf_log.md")
    if os.path.exists(perf_path):
        perf = open(perf_path).read()
    prelude_path = os.path.join(ROOT, "artifacts", "experiments_prelude.md")
    prelude = open(prelude_path).read() if os.path.exists(prelude_path) \
        else "# EXPERIMENTS\n"
    doc = f"""{prelude}

## §Dry-run

Every applicable (architecture × input shape) cell lowered **and
compiled** with `jax.jit(step).lower(...).compile()` against
ShapeDtypeStruct stand-ins on the production meshes — single-pod
`(16,16)=("data","model")` 256 chips and multi-pod
`(2,16,16)=("pod","data","model")` 512 chips (512 placeholder host
devices; see `launch/dryrun.py`).  Train cells lower `train_step`
(shard_map manual DP + GSPMD-auto TP, MG-WFBP bucketed collectives baked
in); decode/long cells lower `serve_step` with the KV cache as input.

{dryrun_section()}

## §Roofline

Terms per §Roofline brief — compute = HLO_FLOPs/(197 TF/s bf16);
memory = analytic HBM bytes/(819 GB/s); collective = HLO collective
bytes/(2 × 50 GB/s ICI).  FLOPs & collective bytes from the
trip-count-corrected HLO parser (`utils/hlo.py` — XLA's `cost_analysis()`
counts scan bodies once); memory from the analytic per-device model
(CPU-backend fusion boundaries misrepresent TPU HBM traffic ~100×, see
`benchmarks/roofline.py` docstring).  `MODEL/HLO` = 6·N·D (or
6·N_active·D) ÷ HLO FLOPs — the 'useful compute' ratio; `roofline frac` =
useful-compute-time ÷ dominant-term-time.

{roofline_section()}

{perf}
"""
    open(os.path.join(ROOT, "EXPERIMENTS.md"), "w").write(doc)
    print("EXPERIMENTS.md written")


if __name__ == "__main__":
    main()
