"""Batched serving example: prefill a batch of prompts, decode with a KV
cache (ring-buffered for sliding-window layers), greedy + temperature.

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-12b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    bundle = registry.reduced_arch(args.arch)
    model = bundle.model()
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.max_new + 8,
                         temperature=args.temperature)
    key = jax.random.PRNGKey(11)
    prompts = [jax.random.randint(jax.random.fold_in(key, i),
                                  (args.prompt_len - (i % 3),), 0,
                                  bundle.cfg.vocab_size)
               for i in range(args.requests)]
    extra = {}
    if bundle.cfg.enc_dec:
        extra["enc_embeds"] = jnp.zeros(
            (args.requests, 32, bundle.cfg.d_model), jnp.bfloat16)

    t0 = time.perf_counter()
    outs = engine.generate(prompts, args.max_new, extra_batch=extra)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"{bundle.cfg.name}: {total} tokens / {args.requests} reqs "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs):
        print(f"  req{i} (prompt {len(prompts[i])} toks): {o}")


if __name__ == "__main__":
    main()
