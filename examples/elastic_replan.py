"""Elastic scaling demo: the MG-WFBP plan is a pure function of the
cluster's all-reduce cost model, so membership changes just re-run the
O(L^2) planner (paper §4.2) and restart from the latest checkpoint.

Shows the optimal plan morphing as a deepseek-67b-shaped tensor list moves
across cluster sizes / interconnects — from WFBP-like (fast ICI, few
merges) toward SyncEASGD-like (cross-pod DCN, heavy merging), exactly the
paper's Fig. 10 narrative.

    PYTHONPATH=src python examples/elastic_replan.py
"""

import jax

from repro.core import cost_model, simulate
from repro.core.bucketer import tensor_specs
from repro.core.profiler import analytic_tb
from repro.models import registry
from repro.train.fault import replan_for

bundle = registry.get_arch("deepseek-67b")
params_shape = jax.eval_shape(
    lambda: bundle.model().init(jax.random.PRNGKey(0)))
specs = [s for s in tensor_specs(params_shape, analytic_tb(4096))
         if s.nbytes]

print(f"{bundle.cfg.name}: {len(specs)} gradient tensors, "
      f"{sum(s.nbytes for s in specs)/1e9:.1f} GB per replica\n")
print(f"{'cluster':>28s} {'a(us)':>8s} {'buckets':>8s} "
      f"{'t_iter(ms)':>11s} {'overlap':>8s}")
for name, shape, axes, dp in [
        ("1 pod ring (16 data)", (16, 16), ("data", "model"), ("data",)),
        ("2 pods (DCN+ICI)", (2, 16, 16), ("pod", "data", "model"),
         ("pod", "data")),
        ("8 pods (DCN+ICI)", (8, 16, 16), ("pod", "data", "model"),
         ("pod", "data"))]:
    plan, model = replan_for("mgwfbp", specs, shape, axes, dp)
    res = simulate(specs, plan, model)
    print(f"{name:>28s} {model.a*1e6:8.1f} {plan.num_buckets:8d} "
          f"{res.t_iter*1e3:11.2f} {res.overlap_ratio:8.1%}")

print("\nLarger startup cost (more pods) -> heavier merging, as the paper "
      "predicts;\nthe checkpoint format is mesh-invariant so the restart "
      "reshards transparently.")

# ---------------------------------------------------------------------------
# The same loop, closed *inside* the event-driven cluster simulator: run a
# few iterations, least-squares-refit (a, b) from the observed bucket
# timings, invert to point-to-point constants, predict the post-resize
# model, replan, and keep training — no ground-truth peeking.
# ---------------------------------------------------------------------------
from repro.sim import scenarios

sim, report = scenarios.elastic_resize(specs, t_f=0.05, n_before=8,
                                       n_after=32, resize_at=1, iters=4)
job = sim.run().job("train")
print("\nsimulated elastic resize 8 -> 32 workers (online refit + replan):")
print(f"  iter times (ms): "
      f"{', '.join(f'{t*1e3:.1f}' for t in job.t_iters)}")
if report.fitted is not None:
    print(f"  refit:  a={report.fitted.a*1e6:.1f}us "
          f"b={report.fitted.b*1e12:.2f}ps/B  -> predicted "
          f"a'={report.predicted.a*1e6:.1f}us for N=32")
print(f"  plan: {report.plan_before.num_buckets} buckets -> "
      f"{report.plan_after.num_buckets} buckets after resize")
