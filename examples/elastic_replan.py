"""Elastic scaling demo: the MG-WFBP plan is a pure function of the
cluster's all-reduce cost model, so membership changes just re-run the
O(L^2) planner (paper §4.2) and restart from the latest checkpoint.

Shows the optimal plan morphing as a deepseek-67b-shaped tensor list moves
across cluster sizes / interconnects — from WFBP-like (fast ICI, few
merges) toward SyncEASGD-like (cross-pod DCN, heavy merging), exactly the
paper's Fig. 10 narrative.

    PYTHONPATH=src python examples/elastic_replan.py
"""

import jax

from repro.core import cost_model, simulate
from repro.core.bucketer import tensor_specs
from repro.core.profiler import analytic_tb
from repro.models import registry
from repro.train.fault import replan_for

bundle = registry.get_arch("deepseek-67b")
params_shape = jax.eval_shape(
    lambda: bundle.model().init(jax.random.PRNGKey(0)))
specs = [s for s in tensor_specs(params_shape, analytic_tb(4096))
         if s.nbytes]

print(f"{bundle.cfg.name}: {len(specs)} gradient tensors, "
      f"{sum(s.nbytes for s in specs)/1e9:.1f} GB per replica\n")
print(f"{'cluster':>28s} {'a(us)':>8s} {'buckets':>8s} "
      f"{'t_iter(ms)':>11s} {'overlap':>8s}")
for name, shape, axes, dp in [
        ("1 pod ring (16 data)", (16, 16), ("data", "model"), ("data",)),
        ("2 pods (DCN+ICI)", (2, 16, 16), ("pod", "data", "model"),
         ("pod", "data")),
        ("8 pods (DCN+ICI)", (8, 16, 16), ("pod", "data", "model"),
         ("pod", "data"))]:
    plan, model = replan_for("mgwfbp", specs, shape, axes, dp)
    res = simulate(specs, plan, model)
    print(f"{name:>28s} {model.a*1e6:8.1f} {plan.num_buckets:8d} "
          f"{res.t_iter*1e3:11.2f} {res.overlap_ratio:8.1%}")

print("\nLarger startup cost (more pods) -> heavier merging, as the paper "
      "predicts;\nthe checkpoint format is mesh-invariant so the restart "
      "reshards transparently.")
