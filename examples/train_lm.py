"""End-to-end training driver: a small LM trained for a few hundred steps
on CPU through the full production substrate — MG-WFBP-planned gradient
buckets, AdamW, deterministic data pipeline, async checkpointing, and
fault-tolerant step loop.

    PYTHONPATH=src python examples/train_lm.py                 # ~25M params
    PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --full

The loss should fall from ~ln(V) to well below it within ~200 steps (the
synthetic stream has learnable n-gram structure).
"""

import argparse
import dataclasses
import time

import jax

from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.train import checkpoint, fault
from repro.train.step import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    bundle = (registry.get_arch(args.arch) if args.full else
              registry.reduced_arch(args.arch,
                                    num_layers=4, d_model=256, num_heads=4,
                                    d_ff=512, vocab_size=2048))
    par = dataclasses.replace(bundle.parallel, dp_axes=(), ep_axis="",
                              attn_chunk=64)
    shape = ShapeConfig("example", "train", args.seq, args.batch)
    run = dataclasses.replace(bundle.run_config("train_4k", par),
                              shape=shape, microbatch=0,
                              learning_rate=args.lr)
    model = bundle.model(par)
    mesh = make_mesh((1,), ("data",))
    step_fn, init_fn, art = build_train_step(model, run, mesh)
    state = init_fn(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"{bundle.cfg.name}: {n/1e6:.1f}M params, plan="
          f"{art.plan.strategy} ({art.plan.num_buckets} buckets / "
          f"{art.plan.num_tensors} tensors)")

    pipe = DataPipeline(bundle.cfg, shape, seed=0)
    ck = checkpoint.AsyncCheckpointer(args.ckpt_dir)
    jstep = jax.jit(step_fn, donate_argnums=0)
    hist = []

    def on_metrics(step, metrics, dt):
        hist.append(float(metrics["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d} loss={hist[-1]:7.4f} "
                  f"gnorm={float(metrics['grad_norm']):6.2f} "
                  f"{dt*1e3:6.0f} ms/step", flush=True)

    t0 = time.time()
    state, final = fault.run_with_recovery(
        jstep, state, pipe, ck, 0, args.steps, ckpt_every=100,
        on_metrics=on_metrics)
    print(f"\n{final} steps in {time.time()-t0:.0f}s; "
          f"loss {hist[0]:.3f} -> {min(hist):.3f} "
          f"({'LEARNED' if min(hist) < hist[0] - 0.5 else 'check lr'})")


if __name__ == "__main__":
    main()
