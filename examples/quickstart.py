"""Quickstart: the MG-WFBP planner + simulator in 30 lines.

Builds the paper's comparison (WFBP vs SyncEASGD vs MG-WFBP) for a
ResNet-50-like tensor profile on the paper's measured K80/10GbE cluster
constants, printing per-strategy iteration time and non-overlapped
communication — the core result of the paper, reproducible on a laptop.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (PAPER_CLUSTERS, AllReduceModel, TensorSpec,
                        compare_strategies)

# ResNet-50-ish backward profile: 161 tensors, ~25.5M params (Table 4),
# conv tensors small->large, fc at the end (first in backward order).
rng = np.random.default_rng(0)
sizes = np.concatenate([
    rng.integers(256, 4096, 120),            # BN/bias/small convs
    rng.integers(65536, 1 << 20, 35),        # conv kernels
    np.array([2048 * 1000, 512 * 2048 * 4]), # fc + last conv blocks
])[:161]
sizes = (sizes / sizes.sum() * 25.5e6).astype(int)   # normalize to 25.5M
t_total_backward = 0.120                              # ~K80 backward time
t_b = sizes / sizes.sum() * t_total_backward

specs = [TensorSpec(f"t{i}", int(s) * 4, float(t))     # fp32 bytes
         for i, (s, t) in enumerate(zip(sizes, t_b))]

a, b = PAPER_CLUSTERS["cluster1_k80_10gbe"]
model = AllReduceModel(a, b)

results = compare_strategies(specs, model, t_f=0.060)
print(f"{'strategy':>12s} {'t_iter(ms)':>11s} {'t_c_no(ms)':>11s} "
      f"{'overlap':>8s} {'buckets':>8s}")
for name, r in results.items():
    print(f"{name:>12s} {r.t_iter*1e3:11.2f} {r.t_c_no*1e3:11.2f} "
          f"{r.overlap_ratio:8.2%} {len(r.events):8d}")

best_base = min(results["wfbp"].t_iter, results["single"].t_iter)
print(f"\nMG-WFBP speedup over best(WFBP, SyncEASGD): "
      f"{best_base / results['mgwfbp'].t_iter:.3f}x")
