"""Online what-if serving against a live fleet snapshot.

The fleet backend (``repro.sim.fleet``) made *evaluation* one device
call and *planning* one device call; this module puts an online query
surface on top.  A :class:`FleetSnapshot` freezes what the scheduler
currently believes about a running fleet — the jobs, their incumbent
merge plans, and their FITTED per-job/per-link cost models (the output
of a :class:`~repro.core.coplanner.CoPlanner` run or a live refit loop)
— and :class:`WhatIfServer` answers hypothetical-change questions
against it:

* :meth:`~WhatIfServer.add_job` — admit this job: new joint makespan?
* :meth:`~WhatIfServer.remove_job` — drain that job: what remains?
* :meth:`~WhatIfServer.scale_bandwidth` — give a job k× bandwidth
  (uplink upgrade / traffic-class change): is the replan worth it?
* :meth:`~WhatIfServer.move_job` — place the job on a different path
  (its candidate placement's cost model): makespan there?
* :meth:`~WhatIfServer.resize` — elastic resize: new tensor profile
  and/or forward time for one job.

Every answer is a *predicted joint makespan* under the snapshot's
fitted models — the same per-job independent scoring regime as
:class:`~repro.sim.fleet.FleetEvaluator` (each job under its own model,
contention embedded by the fit; the event engine stays the oracle when
cross-job coupling itself is the question).

**Why it serves.**  Warming a snapshot scores the incumbent fleet once
(one ``evaluate_cases`` call) and keeps the per-job spans.  A query
then only has to (re)plan and (re)score the jobs it *touches* — one
changed job, usually — and a whole burst of queries batches into ONE
``plan_cases`` call plus ONE ``evaluate_cases`` call, no matter how
many jobs the snapshot holds and with no per-job Python planning loop
(``benchmarks/run.py --whatif`` pins that with the obs counters).
Answers are memoized under a key that includes the snapshot
**fingerprint** — a content hash of jobs, plans, models and telemetry
shape — so a cache entry can never survive a fleet change it should
not: a new snapshot has a new fingerprint and misses cleanly.

Counters/histograms: ``whatif_queries_total`` (by kind),
``whatif_cache_hits_total``, ``whatif_latency_seconds``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Mapping, Sequence

from repro.core.coplanner import CoJob, CoPlanResult
from repro.core.cost_model import AllReduceModel, as_linear
from repro.core.planner import MergePlan, TensorSpec
from repro.core.simulator import spec_arrays
from repro.obs.metrics import REGISTRY
from repro.sim import fleet as fleet_backend


def _model_key(model) -> tuple[float, float]:
    """The (a, b) the kernels consume — a PathModel flattens here too."""
    lin = as_linear(model)
    return (float(lin.a), float(lin.b))


def _job_fingerprint(job: CoJob, plan: MergePlan, model) -> tuple:
    pb, pt = spec_arrays(job.specs)
    return (job.name, fleet_backend.profile_fingerprint(pb, pt),
            plan.buckets, _model_key(model), float(job.t_f),
            job.schedule.label if job.schedule is not None else "bsp")


class FleetSnapshot:
    """An immutable view of a live fleet: jobs, incumbent plans, fitted
    models, and a content fingerprint over all of it.

    ``models`` are the *effective* (fitted) models queries should be
    answered under — typically ``CoPlanResult.models``; a job missing
    from the mapping falls back to its exclusive-link ``job.model``.
    ``plans`` likewise default to a batched-DP plan under the job's
    effective model (one ``plan_cases`` call for all defaults).
    """

    def __init__(self, jobs: Sequence[CoJob], *,
                 plans: Mapping[str, MergePlan] | None = None,
                 models: Mapping[str, AllReduceModel] | None = None,
                 iters: int = 8):
        if not jobs:
            raise ValueError("need >= 1 job")
        names = [j.name for j in jobs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate job names: {names}")
        if iters < 1:
            raise ValueError("need >= 1 iteration")
        self.jobs = tuple(jobs)
        self.iters = int(iters)
        self.models = {j.name: (models or {}).get(j.name, j.model)
                       for j in self.jobs}
        plans = dict(plans or {})
        missing = [j for j in self.jobs if j.name not in plans]
        if missing:
            planned = fleet_backend.plan_batched(
                [(j.specs, self.models[j.name]) for j in missing])
            plans.update({j.name: p for j, p in zip(missing, planned)})
        self.plans = {j.name: plans[j.name] for j in self.jobs}
        for j in self.jobs:
            if self.plans[j.name].num_tensors != len(j.specs):
                raise ValueError(
                    f"plan for {j.name!r} covers "
                    f"{self.plans[j.name].num_tensors} tensors, "
                    f"job has {len(j.specs)}")
        h = hashlib.blake2b(digest_size=16)
        h.update(str(self.iters).encode())
        for j in self.jobs:
            h.update(repr(_job_fingerprint(
                j, self.plans[j.name], self.models[j.name])).encode())
        #: telemetry fingerprint — cache keys embed it, so answers can
        #: never leak across fleet states
        self.fingerprint = h.hexdigest()
        self._spans: dict[str, float] | None = None

    @classmethod
    def from_coplan(cls, jobs: Sequence[CoJob], result: CoPlanResult, *,
                    iters: int = 8) -> "FleetSnapshot":
        """Freeze a co-plan's incumbent assignment and fitted models."""
        return cls(jobs, plans=dict(result.plans),
                   models=dict(result.models), iters=iters)

    def job(self, name: str) -> CoJob:
        for j in self.jobs:
            if j.name == name:
                return j
        raise KeyError(f"no job {name!r} in snapshot")

    def warm(self) -> Mapping[str, float]:
        """Baseline per-job spans, scored once (one device call).

        Jobs are independent under the fitted-model regime, so a query
        reuses every untouched job's baseline span — only the jobs a
        query changes are re-scored."""
        if self._spans is None:
            cases = [fleet_backend.make_case(
                j.specs, self.plans[j.name], self.models[j.name],
                schedule=j.schedule, t_f=j.t_f) for j in self.jobs]
            res = fleet_backend.evaluate_cases(cases, iters=self.iters)
            self._spans = {j.name: float(res.span[i, 0])
                           for i, j in enumerate(self.jobs)}
        return self._spans

    @property
    def makespan(self) -> float:
        """Joint makespan of the incumbent fleet (warms the snapshot)."""
        return max(self.warm().values())


@dataclasses.dataclass(frozen=True)
class WhatIfQuery:
    """One hypothetical change (build via the :class:`WhatIfServer`
    constructors or directly; unused fields stay None)."""

    kind: str                                   # add_job | remove_job |
                                                # scale_bandwidth |
                                                # move_job | resize
    name: str                                   # target job name
    job: CoJob | None = None                    # add_job: the candidate
    plan: MergePlan | None = None               # add_job: optional fixed plan
    model: AllReduceModel | None = None         # move_job: target path model
    scale: float | None = None                  # scale_bandwidth factor
    specs: tuple[TensorSpec, ...] | None = None  # resize: new profile
    t_f: float | None = None                    # resize: new forward time


@dataclasses.dataclass(frozen=True)
class WhatIfAnswer:
    """Predicted outcome of one query against the snapshot."""

    query: WhatIfQuery
    makespan: float                 # predicted joint makespan after change
    baseline: float                 # incumbent joint makespan
    job_span: float | None          # changed/added job's own span (None
                                    # for remove_job)
    plan: MergePlan | None          # the plan the changed job would run
    cached: bool = False            # served from the result cache

    @property
    def delta(self) -> float:
        """Positive = the change worsens the joint makespan."""
        return self.makespan - self.baseline


class WhatIfServer:
    """Answer what-if queries against one warm :class:`FleetSnapshot`.

    Single-query methods are conveniences over :meth:`ask`, which is
    the real surface: it plans every touched job in one ``plan_cases``
    call, scores every touched job in one ``evaluate_cases`` call, and
    serves repeats from a snapshot-fingerprint-keyed cache.
    """

    def __init__(self, snapshot: FleetSnapshot, *, cache_size: int = 4096):
        if cache_size < 1:
            raise ValueError("cache_size must be >= 1")
        self.snapshot = snapshot
        self.cache_size = int(cache_size)
        self._cache: dict[tuple, WhatIfAnswer] = {}

    # -- query constructors / single-shot conveniences -------------------

    def add_job(self, job: CoJob,
                plan: MergePlan | None = None) -> WhatIfAnswer:
        """Admit ``job`` (planned under its own model unless given)."""
        return self.ask([WhatIfQuery("add_job", job.name, job=job,
                                     plan=plan)])[0]

    def remove_job(self, name: str) -> WhatIfAnswer:
        """Drain job ``name``: the survivors' joint makespan."""
        return self.ask([WhatIfQuery("remove_job", name)])[0]

    def scale_bandwidth(self, name: str, scale: float) -> WhatIfAnswer:
        """Scale job ``name``'s link bandwidth by ``scale`` (per-byte
        cost divides by it; startup latency stays), replan, re-score."""
        return self.ask([WhatIfQuery("scale_bandwidth", name,
                                     scale=scale)])[0]

    def move_job(self, name: str, model: AllReduceModel) -> WhatIfAnswer:
        """Place job ``name`` on the path priced by ``model``."""
        return self.ask([WhatIfQuery("move_job", name, model=model)])[0]

    def resize(self, name: str,
               specs: Sequence[TensorSpec] | None = None,
               t_f: float | None = None) -> WhatIfAnswer:
        """Elastic resize of job ``name``: a new tensor profile and/or
        forward time (the model stays — pair with ``move_job`` when the
        resize also changes the fabric share)."""
        return self.ask([WhatIfQuery(
            "resize", name,
            specs=tuple(specs) if specs is not None else None,
            t_f=t_f)])[0]

    # -- the batched path ------------------------------------------------

    def _query_key(self, q: WhatIfQuery) -> tuple:
        extra: tuple = ()
        if q.kind == "add_job":
            pb, pt = spec_arrays(q.job.specs)
            extra = (fleet_backend.profile_fingerprint(pb, pt),
                     _model_key(q.job.model), float(q.job.t_f),
                     q.job.schedule.label if q.job.schedule is not None
                     else "bsp",
                     q.plan.buckets if q.plan is not None else None)
        elif q.kind == "scale_bandwidth":
            extra = (float(q.scale),)
        elif q.kind == "move_job":
            extra = (_model_key(q.model),)
        elif q.kind == "resize":
            if q.specs is not None:
                pb, pt = spec_arrays(q.specs)
                extra = (fleet_backend.profile_fingerprint(pb, pt),)
            extra += (q.t_f,)
        return (self.snapshot.fingerprint, q.kind, q.name, extra)

    def _validate(self, q: WhatIfQuery) -> None:
        if q.kind == "add_job":
            if q.job is None:
                raise ValueError("add_job needs a CoJob")
            if any(j.name == q.job.name for j in self.snapshot.jobs):
                raise ValueError(
                    f"job {q.job.name!r} already in snapshot")
            if q.plan is not None and \
                    q.plan.num_tensors != len(q.job.specs):
                raise ValueError("add_job plan/specs mismatch")
            return
        self.snapshot.job(q.name)       # KeyError -> clean error
        if q.kind == "remove_job":
            if len(self.snapshot.jobs) == 1:
                raise ValueError("cannot drain the last job")
        elif q.kind == "scale_bandwidth":
            if q.scale is None or q.scale <= 0:
                raise ValueError(f"need a positive scale, got {q.scale}")
        elif q.kind == "move_job":
            if q.model is None:
                raise ValueError("move_job needs a cost model")
        elif q.kind == "resize":
            if q.specs is None and q.t_f is None:
                raise ValueError("resize changes nothing")
        else:
            raise ValueError(f"unknown query kind {q.kind!r}")

    def ask(self, queries: Sequence[WhatIfQuery]) -> list[WhatIfAnswer]:
        """Answer a burst of queries: ONE batched plan + ONE batched
        evaluation for all cache misses together."""
        t0 = time.perf_counter()
        snap = self.snapshot
        baseline_spans = snap.warm()
        baseline = max(baseline_spans.values())
        answers: list[WhatIfAnswer | None] = [None] * len(queries)
        for q in queries:
            self._validate(q)
            REGISTRY.counter(
                "whatif_queries_total",
                "what-if queries served, by kind").inc(kind=q.kind)

        # cache pass ----------------------------------------------------
        misses: list[int] = []
        for qi, q in enumerate(queries):
            hit = self._cache.get(self._query_key(q))
            if hit is not None:
                answers[qi] = dataclasses.replace(hit, cached=True)
                REGISTRY.counter(
                    "whatif_cache_hits_total",
                    "what-if answers served from the snapshot-"
                    "fingerprint-keyed cache").inc()
            else:
                misses.append(qi)

        # plan pass: every touched job of every miss, one kernel call ---
        # (index into plan_jobs, or None when the query brings/keeps a
        # plan: add_job with an explicit plan, and remove_job)
        plan_jobs: list[tuple[CoJob, AllReduceModel]] = []
        plan_ref: dict[int, int | None] = {}
        touched: dict[int, tuple[CoJob, AllReduceModel] | None] = {}
        for qi in misses:
            q = queries[qi]
            if q.kind == "add_job":
                jm = (q.job, q.job.model)
            elif q.kind == "remove_job":
                touched[qi] = None
                plan_ref[qi] = None
                continue
            elif q.kind == "scale_bandwidth":
                job = snap.job(q.name)
                lin = as_linear(snap.models[q.name])
                jm = (job, AllReduceModel(a=lin.a, b=lin.b / q.scale,
                                          name=f"{lin.name}/x{q.scale}"))
            elif q.kind == "move_job":
                jm = (snap.job(q.name), q.model)
            else:                                   # resize
                job = snap.job(q.name)
                jm = (dataclasses.replace(
                    job,
                    specs=q.specs if q.specs is not None else job.specs,
                    t_f=q.t_f if q.t_f is not None else job.t_f),
                    snap.models[q.name])
            touched[qi] = jm
            if q.kind == "add_job" and q.plan is not None:
                plan_ref[qi] = None
            else:
                plan_ref[qi] = len(plan_jobs)
                plan_jobs.append(jm)
        new_plans = fleet_backend.plan_batched(
            [(j.specs, m) for j, m in plan_jobs]) if plan_jobs else []

        # score pass: every touched job's case, one kernel call ---------
        cases = []
        case_ref: dict[int, int] = {}
        q_plan: dict[int, MergePlan | None] = {}
        for qi in misses:
            q = queries[qi]
            if touched[qi] is None:                 # remove_job
                q_plan[qi] = None
                continue
            job, model = touched[qi]
            plan = q.plan if (q.kind == "add_job" and q.plan is not None) \
                else new_plans[plan_ref[qi]]
            q_plan[qi] = plan
            case_ref[qi] = len(cases)
            cases.append(fleet_backend.make_case(
                job.specs, plan, model, schedule=job.schedule,
                t_f=job.t_f))
        spans = fleet_backend.evaluate_cases(
            cases, iters=snap.iters).span[:, 0] if cases else []

        # assemble + cache ----------------------------------------------
        for qi in misses:
            q = queries[qi]
            if touched[qi] is None:                 # remove_job
                mk = max(s for n, s in baseline_spans.items()
                         if n != q.name)
                span = None
            else:
                span = float(spans[case_ref[qi]])
                others = (s for n, s in baseline_spans.items()
                          if n != q.name)
                mk = max([span, *others])
            ans = WhatIfAnswer(query=q, makespan=mk, baseline=baseline,
                               job_span=span, plan=q_plan[qi])
            answers[qi] = ans
            if len(self._cache) >= self.cache_size:
                self._cache.pop(next(iter(self._cache)))
            self._cache[self._query_key(q)] = ans
        REGISTRY.histogram(
            "whatif_latency_seconds",
            "wall seconds per WhatIfServer.ask call").observe(
                time.perf_counter() - t0)
        return answers  # type: ignore[return-value]
