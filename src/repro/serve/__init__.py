from repro.serve.engine import ServeEngine, build_serve_step
from repro.serve import sampling
from repro.serve.whatif import (FleetSnapshot, WhatIfAnswer, WhatIfQuery,
                                WhatIfServer)
