from repro.serve.engine import ServeEngine, build_serve_step
from repro.serve import sampling
