"""Batched serving engine: prefill + decode steps with sharded KV caches.

Serving has no gradient traffic, so the paper's technique does not apply
here (DESIGN.md §5) — the serve path uses plain GSPMD auto-partitioning:
params TP-sharded over ``model``, request batch over the data axes, and for
``long_500k`` (batch 1) the KV cache sequence dim sharded over ``data``
(flash-decode style — GSPMD partitions the attention contraction and
inserts the partial-softmax reduction).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models import sharding as shd
from repro.models.transformer import LM
from repro.serve import sampling


def cache_pspecs(cache_shape, shape: ShapeConfig, parallel: ParallelConfig,
                 mesh_dims: dict):
    """Shard KV caches: batch over data axes when divisible, else the
    sequence dim (long-context decode); KV heads over model."""
    dp = tuple(a for a in parallel.dp_axes if a in mesh_dims)
    dp_total = 1
    for a in dp:
        dp_total *= mesh_dims[a]
    tp = parallel.tp_axis if (parallel.tp_enabled and
                              parallel.tp_axis in mesh_dims) else None

    def one(path, leaf):
        s = leaf.shape
        k = jax.tree_util.keystr(path)
        if len(s) >= 3 and ("['k']" in k or "['v']" in k or "['xk']" in k
                            or "['xv']" in k):
            # [.., B, S, H, D]
            spec = [None] * len(s)
            bdim, sdim, hdim = len(s) - 4, len(s) - 3, len(s) - 2
            if s[bdim] % max(dp_total, 1) == 0 and dp_total > 1:
                spec[bdim] = dp
            elif "data" in mesh_dims and s[sdim] % mesh_dims["data"] == 0 \
                    and parallel.seq_shard_decode:
                spec[sdim] = "data"
            if tp and s[hdim] % mesh_dims[tp] == 0:
                spec[hdim] = tp
            return P(*spec)
        # recurrent states: batch on first dim when divisible
        spec = [None] * len(s)
        bdim = 1 if len(s) >= 2 and "stages" in k and False else 0
        for d in range(len(s)):
            if s[d] % max(dp_total, 1) == 0 and dp_total > 1:
                spec[d] = dp
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def build_serve_step(model: LM, shape: ShapeConfig, mesh):
    """Returns (decode_fn, prefill_fn, shardings) under GSPMD auto."""
    cfg, par = model.cfg, model.parallel
    if par.ep_axis:
        # serving runs under plain GSPMD (no manual axes): experts are
        # TP-sharded instead of expert-parallel
        par = dataclasses.replace(par, ep_axis="")
        model = LM(cfg, par)
    dims = dict(zip(mesh.axis_names, mesh.devices.shape))
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    tp_axis = par.tp_axis if (par.tp_enabled and par.tp_axis in dims) else ""
    pspecs = shd.param_pspecs(params_shape, ep_axis="", tp_axis=tp_axis)
    pspecs = shd.filter_uneven(pspecs, params_shape, dims)
    enc_len = shape.seq_len if cfg.enc_dec else 0
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, enc_len))
    cspecs = cache_pspecs(cache_shape, shape, par, dims)

    dp = tuple(a for a in par.dp_axes if a in dims)
    dp_total = 1
    for a in dp:
        dp_total *= dims[a]
    tok_spec = P(dp) if (dp and shape.global_batch % dp_total == 0
                         and dp_total > 1) else P()

    def decode_fn(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(params, cache, tokens, pos)
        return logits, new_cache

    def prefill_fn(params, batch):
        return model.prefill(params, batch, max_len=shape.seq_len)

    shardings = {
        "params": jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                               is_leaf=lambda x: isinstance(x, P)),
        "cache": jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                              is_leaf=lambda x: isinstance(x, P)),
        "tokens": NamedSharding(mesh, tok_spec),
        "param_pspecs": pspecs,
        "cache_pspecs": cspecs,
        "token_pspec": tok_spec,
    }
    return decode_fn, prefill_fn, shardings


@dataclasses.dataclass
class Request:
    prompt: jax.Array          # [S] int32
    max_new_tokens: int = 16


class ServeEngine:
    """Minimal batched engine: pad-and-batch prefill, synchronous decode.

    Production continuous batching slots requests into a fixed batch and
    recycles finished rows; here requests are grouped into one batch per
    call (sufficient for the example/serving tests on CPU).
    """

    def __init__(self, model: LM, params, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)

    def generate(self, prompts: list[jax.Array], max_new_tokens: int = 16,
                 extra_batch: dict | None = None) -> list[list[int]]:
        b = len(prompts)
        plen = max(int(p.shape[0]) for p in prompts)
        toks = jnp.stack([jnp.pad(p, (plen - p.shape[0], 0)) for p in
                          prompts])  # left-pad to align last positions
        batch = {"tokens": toks, **(extra_batch or {})}
        logits, cache = self.model.prefill(self.params, batch,
                                           max_len=self.max_len)
        outs: list[list[int]] = [[] for _ in range(b)]
        tok = sampling.greedy(logits)
        for i in range(b):
            outs[i].append(int(tok[i, 0]))
        for t in range(max_new_tokens - 1):
            pos = jnp.int32(plen + t)
            logits, cache = self._decode(self.params, cache, tok, pos)
            lg = logits[:, 0]
            if self.temperature > 0:
                self.key, sk = jax.random.split(self.key)
                tok = sampling.temperature(lg, sk, self.temperature)
            else:
                tok = sampling.greedy(lg)
            for i in range(b):
                outs[i].append(int(tok[i, 0]))
        return outs
