"""Token sampling for the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits: [B, V] -> [B, 1] int32."""
    return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


def temperature(logits: jax.Array, key, temp: float = 1.0,
                top_k: int = 0) -> jax.Array:
    lg = logits.astype(jnp.float32) / max(temp, 1e-6)
    if top_k:
        vals, _ = jax.lax.top_k(lg, top_k)
        cut = vals[..., -1:]
        lg = jnp.where(lg < cut, -1e30, lg)
    return jax.random.categorical(key, lg, axis=-1)[:, None].astype(jnp.int32)
