"""Discrete-event cluster simulator for bucketed data-parallel training.

Where ``core/simulator.simulate`` replays ONE homogeneous pipeline in
closed form (paper Eqs. 6-8), this engine simulates a *cluster*:

* per-worker compute streams — heterogeneous speeds and seeded jitter
  (``workers.py``), each worker's backward producing gradients on its own
  timeline; a bucket's all-reduce may start only when **every** worker has
  produced the bucket's last tensor (synchronous S-SGD semantics);
* shared network links as processor-sharing resources — concurrent
  all-reduces (same job in ``concurrent`` mode, other jobs, background
  bursts) split link bandwidth, startup latency is paid per collective;
* topology-aware collectives (``network.py``) — a collective is a sequence
  of phases over links (e.g. ICI reduce-scatter/all-gather then a DCN leg);
* multi-iteration loops driven by a :class:`~repro.sim.schedules.Schedule`
  — BSP (the paper's global barrier), DeAR-style pipelined all-reduce,
  micro-batched 1F1B, local SGD — with per-iteration hooks for elastic
  resize / replanning (``scenarios.py`` closes the refit -> replan loop).

The iteration loop itself lives in ``schedules.py``: a ``_JobRun`` here is
only the shared context (plan/workers/topology/result + the collective
launcher), and the job's schedule advances each worker's **iteration
frontier**.  Under the default BSP schedule every worker's frontier is the
global barrier at the last all-reduce — on a homogeneous single-job
sequential setup that equals the closed form to ~1e-12 (see
``core/simulator.cross_validate`` and tests/test_cluster_sim.py) — and that
identity anchors everything the engine says about the scenarios the closed
form cannot express.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.planner import MergePlan, TensorSpec
from repro.sim.events import EventQueue
from repro.sim.network import BACKGROUND_OWNER, Burst, Phase, Topology
from repro.sim.trace import Span
from repro.sim.workers import WorkerProfile, scale_array

_EPS = 1e-15


class Engine:
    """Priority-queue event loop.  ``now`` only moves forward."""

    def __init__(self) -> None:
        self.now = 0.0
        self._queue = EventQueue()
        self.events_processed = 0

    def at(self, time: float, fn: Callable[[], None]) -> None:
        if time < self.now - _EPS:
            raise ValueError(f"cannot schedule at {time} < now {self.now}")
        self._queue.push(max(time, self.now), fn)

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        self.at(self.now + max(delay, 0.0), fn)

    def run(self, until: float | None = None,
            max_events: int = 50_000_000) -> None:
        while self._queue:
            if until is not None and self._queue.peek_time() > until:
                break
            ev = self._queue.pop()
            self.now = max(self.now, ev.time)
            ev.fn()
            self.events_processed += 1
            if self.events_processed > max_events:
                raise RuntimeError("event budget exhausted — runaway sim?")


@dataclasses.dataclass
class _Flow:
    target: float             # cumulative link service at which flow drains
    seq: int                  # deterministic tie-break (insertion order)
    on_done: Callable[[], None] = dataclasses.field(compare=False)
    owner: str = dataclasses.field(default=BACKGROUND_OWNER, compare=False)

    def __lt__(self, other: "_Flow") -> bool:
        return (self.target, self.seq) < (other.target, other.seq)


class Link:
    """Shared link with egalitarian processor sharing.

    Each active flow drains at ``1/claimants`` of full rate, where
    claimants = live flows + background flows (bursty neighbours).  Because
    every flow drains at the *same* rate, per-flow residuals never reorder —
    so instead of rescanning all flows on each membership change (the old
    O(flows) ``_advance``/``_reschedule`` hot loop), the link keeps one
    cumulative *service* clock ``S(t) = ∫ dt / claimants(t)`` and each flow
    a fixed completion target ``S_admit + volume``.  Advancing time is O(1),
    the next completion is a heap peek, and a membership change costs
    O(log flows) — stale completion events are invalidated by a generation
    counter exactly as before.

    **Per-owner accounting.**  Every flow is tagged with its owner (the job
    name; background claimants from :class:`~repro.sim.network.Burst` use
    the reserved :data:`~repro.sim.network.BACKGROUND_OWNER`).  The link
    tracks, per owner, the bytes admitted (``owner_bytes``) and the
    bandwidth-share seconds received (``owner_busy``): over an interval
    ``dt`` with ``C`` claimants, each of an owner's ``k`` live flows
    receives ``dt/C`` of service, so the owner is charged ``k * dt/C``.
    Shares over all owners (background included) sum to the link's total
    busy wall time (``busy_s``) — the conservation law the telemetry
    property tests assert.  The attribution gives multi-job planners
    (``repro.core.coplanner``) a per-job view of the fabric: each job's
    observed collectives (and bytes) are its own — a burst or neighbour
    never shows up as a sample in another job's refit, though the
    *durations* of a job's own collectives still embed the
    processor-sharing stretch those claimants cause (which is exactly
    what an effective contended (a, b) must capture).
    """

    def __init__(self, engine: Engine, name: str):
        self.engine = engine
        self.name = name
        self._heap: list[_Flow] = []
        self.background = 0
        self._service = 0.0       # cumulative per-flow service received
        self._last = 0.0
        self._gen = 0
        self._seq = 0
        # capacity multiplier: 1.0 = healthy; a degraded/flapping link
        # (repro.sim.faults.LinkDegradation) runs at rate_scale < 1, so
        # every live flow drains proportionally slower.  Only the service
        # clock scales — busy/share attribution still measures wall time,
        # preserving the conservation law telemetry tests assert.
        self.rate_scale = 1.0
        self.busy_s = 0.0         # wall seconds with >= 1 live flow
        self.owner_bytes: dict[str, float] = {}
        self.owner_busy: dict[str, float] = {}
        self._owner_flows: collections.Counter[str] = collections.Counter()

    @property
    def n_flows(self) -> int:
        return len(self._heap)

    def _claimants(self) -> int:
        return len(self._heap) + self.background

    def _advance(self) -> None:
        now = self.engine.now
        if self._heap and now > self._last:
            dt = now - self._last
            per_flow = dt / self._claimants()
            self._service += per_flow * self.rate_scale
            self.busy_s += dt
            busy = self.owner_busy
            for owner, k in self._owner_flows.items():
                if k:
                    busy[owner] = busy.get(owner, 0.0) + per_flow * k
            if self.background:
                busy[BACKGROUND_OWNER] = busy.get(BACKGROUND_OWNER, 0.0) \
                    + per_flow * self.background
        self._last = now

    def add_flow(self, volume: float, on_done: Callable[[], None], *,
                 owner: str = BACKGROUND_OWNER, nbytes: float = 0.0) -> None:
        if nbytes > 0:
            self.owner_bytes[owner] = \
                self.owner_bytes.get(owner, 0.0) + nbytes
        if volume <= 0:
            on_done()
            return
        self._advance()
        heapq.heappush(self._heap,
                       _Flow(self._service + volume, self._seq, on_done,
                             owner))
        self._owner_flows[owner] += 1
        self._seq += 1
        self._reschedule()

    def set_rate_scale(self, scale: float) -> None:
        """Change the link's capacity multiplier (fault injection: a
        degradation window sets < 1, restoration sets it back).  Settles
        accrued service at the old rate first, then reschedules the next
        completion at the new one."""
        if not (scale > 0) or not np.isfinite(scale):
            raise ValueError(f"rate_scale must be finite and > 0: {scale}")
        self._advance()
        self.rate_scale = scale
        self._reschedule()

    def add_background(self, count: int = 1) -> None:
        self._advance()
        self.background += count
        self._reschedule()

    def remove_background(self, count: int = 1) -> None:
        self._advance()
        self.background = max(0, self.background - count)
        self._reschedule()

    def _reschedule(self) -> None:
        self._gen += 1
        if not self._heap:
            return
        gen = self._gen
        t_next = (self._heap[0].target - self._service) \
            * self._claimants() / self.rate_scale
        self.engine.after(max(t_next, 0.0), lambda: self._complete(gen))

    def _complete(self, gen: int) -> None:
        if gen != self._gen:
            return                    # superseded by a membership change
        self._advance()
        now = self.engine.now
        c = max(self._claimants(), 1)
        done: list[_Flow] = []
        while self._heap:
            remaining = self._heap[0].target - self._service
            # absolute epsilon, plus: a remainder too small for `now + dt`
            # to advance the clock can never drain — count it done (the
            # error is below one float ulp of the current timestamp).
            if remaining <= _EPS \
                    or now + remaining * c / self.rate_scale <= now:
                f = heapq.heappop(self._heap)
                self._owner_flows[f.owner] -= 1
                done.append(f)
            else:
                break
        self._reschedule()
        for f in done:
            f.on_done()

    def telemetry(self, owner: str) -> tuple[float, float]:
        """(bytes admitted, bandwidth-share seconds) for one owner so far.

        Shares are accrued lazily on membership changes; account for the
        open interval since the last event so mid-flight reads (iteration
        boundaries of an overlapping job) are exact."""
        self._advance()
        return (self.owner_bytes.get(owner, 0.0),
                self.owner_busy.get(owner, 0.0))


# ---------------------------------------------------------------------------
# Jobs.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketTiming:
    """One bucket's gradient synchronization in one iteration (engine
    analogue of ``simulator.BucketEvent``, plus the iteration index)."""

    iteration: int
    bucket: int
    nbytes: int
    ready: float        # all workers produced the bucket's last gradient
    start: float        # collective issued (first phase startup begins)
    end: float          # last phase completed
    # actual link-occupancy seconds.  For BSP this equals end - start; for
    # split collectives (pipelined reduce-scatter + deferred all-gather)
    # end - start also contains the idle gap while the all-gather waits for
    # the next iteration's forward, which must NOT pollute (a, b) refits —
    # drivers record the occupancy explicitly.  < 0 means "use end - start".
    comm_s: float = -1.0

    @property
    def duration(self) -> float:
        """Communication time this bucket actually occupied the fabric."""
        return self.comm_s if self.comm_s >= 0 else self.end - self.start


@dataclasses.dataclass(frozen=True)
class IterationResult:
    index: int
    start: float
    end: float
    backward_end: float                     # max over workers
    buckets: tuple[BucketTiming, ...]
    # per-worker compute (forward+backward) seconds this iteration — the
    # per-host step times a StragglerMonitor consumes (name, seconds)
    worker_compute: tuple[tuple[str, float], ...] = ()
    # per-worker iteration frontier: when each worker began / finished its
    # compute for this iteration.  Under BSP all starts coincide (the global
    # barrier); non-BSP schedules let them drift.
    worker_start: tuple[tuple[str, float], ...] = ()
    worker_end: tuple[tuple[str, float], ...] = ()
    # local steps accumulated since the last global gradient synchronization
    # at the end of this iteration: 0 for every synchronous schedule, s for
    # the s-th unsynced step of a LocalSGD(H) round.
    staleness: int = 0
    # per-link fabric telemetry attributed to THIS job, **cumulative** as of
    # the moment this record was built: (link, bytes admitted) and
    # (link, bandwidth-share seconds received).  Cumulative — not per-window
    # deltas — because iterations of overlapping schedules (pipelined tails,
    # LocalSGD round flushes) have no exact per-iteration traffic window;
    # the last record is the job's exact total, and consecutive records
    # diff to per-iteration footprints where windows do abut.  Background
    # Burst traffic is accounted under a reserved owner and never appears
    # here.
    link_bytes: tuple[tuple[str, float], ...] = ()
    link_busy: tuple[tuple[str, float], ...] = ()

    @property
    def t_iter(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class JobSpec:
    """One training job: what to compute, how to merge, on which workers."""

    name: str
    specs: Sequence[TensorSpec]             # backward order
    plan: MergePlan
    t_f: float
    workers: Sequence[WorkerProfile]
    topology: Topology
    iters: int = 1
    start_time: float = 0.0
    comm_mode: str = "sequential"           # "sequential" | "concurrent"
    compute_mode: str = "events"            # "events" | "analytic"
    # how iterations advance: None means BSP (the paper's global barrier).
    # See repro.sim.schedules for PipelinedAllReduce / OneFoneB / LocalSGD.
    schedule: "object | None" = None
    # hook(sim, jobrun, finished_iter_index) runs after that iteration;
    # it may replace the run's workers / plan / topology (elastic resize).
    hooks: Mapping[int, Callable] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.plan.num_tensors != len(self.specs):
            raise ValueError(
                f"plan covers {self.plan.num_tensors} tensors, "
                f"specs has {len(self.specs)}")
        if self.comm_mode not in ("sequential", "concurrent"):
            raise ValueError(f"unknown comm_mode {self.comm_mode!r}")
        if self.compute_mode not in ("events", "analytic"):
            raise ValueError(f"unknown compute_mode {self.compute_mode!r}")
        if self.iters < 1 or not self.workers:
            raise ValueError("need >= 1 iteration and >= 1 worker")
        if self.schedule is not None:
            from repro.sim.schedules import Schedule  # lazy: no cycle
            if not isinstance(self.schedule, Schedule):
                raise TypeError(
                    f"schedule must be a repro.sim.schedules.Schedule, "
                    f"got {type(self.schedule).__name__}")
            self.schedule.validate_spec(self)


@dataclasses.dataclass
class JobResult:
    name: str
    iterations: list[IterationResult]
    # bytes actually moved through collectives (fraction-weighted for split
    # collectives): for synchronous schedules this is plan bytes x iters —
    # schedule-invariant — while LocalSGD(H) moves 1/H of it.
    bytes_communicated: float = 0.0
    # per-link occupancy per observed collective, keyed link ->
    # (iteration, bucket) -> [full message nbytes, seconds the collective
    # occupied that link].  Phases of one collective on the same link
    # (e.g. a split reduce-scatter + all-gather) accumulate into one
    # entry, so the per-link sample set mirrors ``bucket_samples`` leg by
    # leg.  This is what per-link (a_l, b_l) refits consume.
    link_occ: dict = dataclasses.field(default_factory=dict)

    @property
    def t_iters(self) -> list[float]:
        return [it.t_iter for it in self.iterations]

    @property
    def total_time(self) -> float:
        return self.iterations[-1].end - self.iterations[0].start

    @property
    def bucket_samples(self) -> list[tuple[int, float]]:
        """(nbytes, duration) per observed collective — refit fodder.

        ``duration`` is the fabric-occupancy time (``BucketTiming.duration``)
        so split-collective schedules don't leak their deliberate all-gather
        deferral into the (a, b) fit."""
        return [(b.nbytes, b.duration)
                for it in self.iterations for b in it.buckets]

    @property
    def link_samples(self) -> dict[str, list[tuple[int, float]]]:
        """Per-link (nbytes, occupancy seconds) per observed collective.

        ``nbytes`` is the FULL message size (the per-link byte dilution of
        sharded legs lands in the fitted per-byte term, exactly as
        :class:`repro.core.cost_model.PathPhase` encodes it); occupancy
        includes the leg's startup and any processor-sharing stretch on
        that link — the refit input for per-link path models
        (:func:`repro.core.cost_model.fit_path`)."""
        return {link: [(nb, occ) for nb, occ in per.values()]
                for link, per in self.link_occ.items()}

    @property
    def link_telemetry(self) -> dict[str, tuple[float, float]]:
        """Final per-link (bytes, bandwidth-share seconds) for this job —
        the last iteration's cumulative ``link_bytes``/``link_busy``."""
        if not self.iterations:
            return {}
        last = self.iterations[-1]
        busy = dict(last.link_busy)
        return {link: (nbytes, busy.get(link, 0.0))
                for link, nbytes in last.link_bytes}


class _JobRun:
    """Engine-side context for one job.

    The iteration state machine lives in the job's schedule driver
    (``repro.sim.schedules``); this class holds what every schedule shares —
    the mutable plan/workers/topology (iteration hooks may swap them
    mid-run), the accumulating result, per-iteration jitter scales, and the
    collective launcher that turns a bucket into topology phases on shared
    links.
    """

    def __init__(self, sim: "ClusterSim", spec: JobSpec):
        self.sim = sim
        self.spec = spec
        self.name = spec.name
        # mutable copies — iteration hooks may replace them mid-run
        self.plan = spec.plan
        self.workers = list(spec.workers)
        self.topology = spec.topology
        self.result = JobResult(spec.name, [])
        self.it = 0
        # earliest sim time the next iteration may start — fault hooks
        # push it forward (downtime: detection, restore, drain) and every
        # schedule driver funnels its next-iteration start through
        # next_iteration() so the pause is schedule-agnostic
        self.resume_at = 0.0
        if spec.schedule is None:
            from repro.sim.schedules import BSP  # lazy: no import cycle
            self.schedule = BSP()
        else:
            self.schedule = spec.schedule
        self.driver = self.schedule.driver(self)

    def start(self) -> None:
        self.driver.start()

    # -- primitives shared by all schedule drivers ----------------------

    def scales(self, it: int) -> np.ndarray:
        """Per-worker compute-scale vector for iteration ``it``."""
        return scale_array(self.workers, self.sim.seed, self.name, it)

    def backward_prefix(self) -> np.ndarray:
        """Prefix sums of per-tensor backward times (gradient-ready
        offsets from a worker's backward start, before scaling)."""
        t_b = np.array([s.t_b for s in self.spec.specs], dtype=np.float64)
        return np.cumsum(t_b) if len(t_b) else np.zeros(0)

    def bucket_nbytes(self, k: int) -> int:
        return sum(self.spec.specs[i].nbytes for i in self.plan.buckets[k])

    def launch_collective(self, k: int, nbytes: int, *, it: int,
                          fraction: float = 1.0, tag: str = "allreduce",
                          on_done: Callable[[float], None]) -> None:
        """Run one collective (or a ``fraction`` of one — e.g. the
        reduce-scatter half) through the topology's phases on shared links;
        ``on_done(start_time)`` fires when the last phase completes."""
        start = self.sim.engine.now
        # closed-form convention: T(0) == 0 — an empty message is free
        phases = self.topology.phases(nbytes) \
            if nbytes > 0 and fraction > 0 else []
        if fraction != 1.0 and phases:
            phases = [Phase(p.link, p.startup * fraction,
                            p.seconds_per_byte * fraction,
                            p.shard_fraction) for p in phases]

        def next_phase(idx: int) -> None:
            if idx == len(phases):
                self.result.bytes_communicated += nbytes * fraction
                on_done(start)
                return
            ph = phases[idx]
            phase_start = self.sim.engine.now

            def transfer() -> None:
                link = self.sim.links[ph.link]
                # the link is charged the bytes that physically cross it:
                # a sharded leg (shard_fraction < 1) moves only its shard
                link.add_flow(ph.volume(nbytes), lambda: finish(),
                              owner=self.name,
                              nbytes=nbytes * ph.shard_fraction * fraction)

            def finish() -> None:
                args = {"iter": it, "bucket": k, "bytes": nbytes,
                        "phase": idx}
                if fraction != 1.0:
                    args["fraction"] = fraction
                self.sim.record(Span(
                    name=f"{tag}:b{k}", cat="comm", pid=self.name,
                    tid=f"link:{ph.link}", start=phase_start,
                    end=self.sim.engine.now, args=args))
                # per-link occupancy sample (startup + contended
                # transfer), aggregated per collective so split fractions
                # and repeated same-link legs land in ONE sample
                per = self.result.link_occ.setdefault(ph.link, {})
                nb, occ = per.get((it, k), (nbytes, 0.0))
                per[(it, k)] = (nb, occ +
                                (self.sim.engine.now - phase_start))
                next_phase(idx + 1)

            self.sim.engine.after(ph.startup, transfer)

        next_phase(0)

    def finish_iteration(self, result: IterationResult) -> bool:
        """Record one finished iteration, fire its hook, advance the
        iteration counter.  Returns True while more iterations remain.

        Stamps the record with the job's cumulative per-link telemetry
        (every schedule driver funnels through here, so the attribution is
        schedule-agnostic)."""
        tele = self.sim.job_link_telemetry(self.name)
        result = dataclasses.replace(
            result,
            link_bytes=tuple((l, b) for l, (b, _) in tele.items()),
            link_busy=tuple((l, s) for l, (_, s) in tele.items()))
        self.result.iterations.append(result)
        if self.sim.recorder is not None:
            from repro.obs.metrics import REGISTRY
            from repro.obs.recorder import from_iteration_result
            self.sim.recorder.record(
                from_iteration_result(result, job=self.name))
            REGISTRY.histogram(
                "sim_iteration_seconds",
                "simulated iteration wall time").observe(
                    result.t_iter, job=self.name)
        hook = self.spec.hooks.get(result.index)
        if hook is not None:
            hook(self.sim, self, result.index)
        self.it = result.index + 1
        return self.it < self.spec.iters

    def pause_until(self, t: float) -> None:
        """Hold the next iteration until sim time ``t`` (monotone max —
        overlapping downtimes extend, never shrink, the pause)."""
        if not np.isfinite(t):
            raise ValueError(f"pause_until needs a finite time, got {t}")
        self.resume_at = max(self.resume_at, t)

    def next_iteration(self, start_fn: Callable[[], None]) -> None:
        """Start the next iteration now, or at ``resume_at`` if a fault
        hook paused the job.  All schedule drivers route through here."""
        if self.resume_at > self.sim.engine.now:
            self.sim.engine.at(self.resume_at, start_fn)
        else:
            start_fn()


# ---------------------------------------------------------------------------
# Cluster.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClusterResult:
    jobs: dict[str, JobResult]
    spans: list[Span]
    events_processed: int

    def job(self, name: str) -> JobResult:
        return self.jobs[name]

    @property
    def makespan(self) -> float:
        """Joint makespan: latest job end minus earliest job start — the
        objective multi-job co-planning minimizes."""
        return max(r.iterations[-1].end for r in self.jobs.values()) - \
            min(r.iterations[0].start for r in self.jobs.values())


class ClusterSim:
    """A set of jobs sharing link resources, driven by one event engine."""

    def __init__(self, jobs: Sequence[JobSpec], *, seed: int = 0,
                 bursts: Sequence[Burst] = (), recorder=None):
        names = [j.name for j in jobs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate job names: {names}")
        self.engine = Engine()
        self.seed = seed
        # optional repro.obs.recorder.FlightRecorder; when None (the
        # default) the engine emits nothing and pays nothing
        self.recorder = recorder
        self.spans: list[Span] = []
        self.links: dict[str, Link] = {}
        self._runs = [_JobRun(self, j) for j in jobs]
        for run in self._runs:
            self.ensure_links(run.topology)
        for b in bursts:
            self.ensure_link(b.link)
            self.engine.at(b.start,
                           lambda b=b: self.links[b.link].add_background(
                               b.flows))
            self.engine.at(b.end,
                           lambda b=b: self.links[b.link].remove_background(
                               b.flows))
            self.record(Span(name=f"burst x{b.flows}", cat="network",
                             pid="background", tid=f"link:{b.link}",
                             start=b.start, end=b.end,
                             args={"flows": b.flows}))

    def job_run(self, name: str) -> _JobRun:
        """The live run context for one job (fault injectors and
        scenario hooks mutate plan/workers/topology through it)."""
        for r in self._runs:
            if r.name == name:
                return r
        raise KeyError(f"no job named {name!r}")

    def ensure_link(self, name: str) -> Link:
        if name not in self.links:
            self.links[name] = Link(self.engine, name)
        return self.links[name]

    def ensure_links(self, topology: Topology) -> None:
        for name in topology.links:
            self.ensure_link(name)

    def job_link_telemetry(self, owner: str) -> dict[str,
                                                     tuple[float, float]]:
        """Cumulative per-link (bytes, bandwidth-share seconds) attributed
        to one flow owner (a job name, or
        :data:`~repro.sim.network.BACKGROUND_OWNER` for burst traffic).
        Links the owner never touched are omitted."""
        out = {}
        for name in sorted(self.links):
            nbytes, busy = self.links[name].telemetry(owner)
            if nbytes or busy:
                out[name] = (nbytes, busy)
        return out

    def record(self, span: Span) -> None:
        self.spans.append(span)

    def run(self) -> ClusterResult:
        for r in self._runs:
            self.engine.at(r.spec.start_time, r.start)
        self.engine.run()
        return ClusterResult(
            jobs={r.name: r.result for r in self._runs},
            spans=list(self.spans),
            events_processed=self.engine.events_processed)


# ---------------------------------------------------------------------------
# Closed-form bridge.
# ---------------------------------------------------------------------------

def event_driven_t_iter(specs: Sequence[TensorSpec], plan: MergePlan,
                        model, t_f: float = 0.0, *, n_workers: int = 1,
                        iters: int = 1, compute_mode: str = "events",
                        schedule=None) -> float:
    """Iteration time of the homogeneous single-job case via the engine.

    This is the configuration in which the engine must agree with
    ``core/simulator.simulate`` (identical semantics, independent
    mechanics) — the cross-validation oracle.  Pass ``schedule`` to run the
    same configuration under a non-BSP schedule (then the reference is the
    schedule's own closed form, ``Schedule.predict_t_iter``).
    """
    from repro.sim.workers import make_workers

    topo = Topology(model, n_workers=n_workers)
    job = JobSpec(name="job", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_workers), topology=topo,
                  iters=iters, compute_mode=compute_mode, schedule=schedule)
    res = ClusterSim([job]).run()
    return res.job("job").iterations[-1].t_iter
