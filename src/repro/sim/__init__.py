"""Event-driven cluster simulator: contention, stragglers, elasticity.

``core/simulator`` answers "how long is one homogeneous iteration" in
closed form; this package answers everything the closed form cannot —
heterogeneous/jittery workers, link contention between collectives and
jobs, bursty background traffic, two-level topologies, and mid-run elastic
resizes with online cost-model refit.  The two are cross-validated on
their shared domain (``core.simulator.cross_validate``).
"""

from repro.sim.engine import (
    BucketTiming,
    ClusterResult,
    ClusterSim,
    Engine,
    IterationResult,
    JobResult,
    JobSpec,
    Link,
    event_driven_t_iter,
)
from repro.sim.network import (
    BACKGROUND_OWNER,
    Burst,
    FlatTopology,
    HierarchicalTopology,
    Phase,
    Topology,
    invert_double_binary_trees,
    invert_halving_doubling,
    invert_model,
    invert_ring,
    predicted_model,
    predicted_ring,
    topology_for_cluster,
)
from repro.sim.coplan_profiles import make_fleet_jobs
from repro.sim.fleet import (
    FleetCase,
    FleetEvaluator,
    FleetResult,
    evaluate_cases,
    fleet_available,
    make_case,
)
from repro.sim.schedules import (
    BSP,
    DAGSchedule,
    DAGTask,
    FleetForm,
    LocalSGD,
    OneFoneB,
    PipelinedAllReduce,
    SCHEDULES,
    Schedule,
)
from repro.sim.sweep import (
    SweepGrid,
    SweepResult,
    closed_form_valid,
    run_sweep,
)
from repro.sim.trace import (
    Span,
    from_chrome_trace,
    frontier_spans,
    read_chrome_trace,
    refit_model,
    replan_from_samples,
    specs_from_json,
    specs_from_rows,
    specs_to_json,
    synthetic_specs,
    to_chrome_trace,
    write_chrome_trace,
)
from repro.sim.faults import (
    CheckpointFailure,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    LinkDegradation,
    Preemption,
    SlowHostOnset,
    WorkerCrash,
)
from repro.sim.workers import WorkerProfile, make_workers, scale_array
from repro.sim import scenarios

__all__ = [
    "BucketTiming", "ClusterResult", "ClusterSim", "Engine",
    "IterationResult", "JobResult", "JobSpec", "Link",
    "event_driven_t_iter",
    "BACKGROUND_OWNER", "Burst", "FlatTopology", "HierarchicalTopology",
    "Phase", "Topology",
    "invert_double_binary_trees", "invert_halving_doubling", "invert_model",
    "invert_ring", "predicted_model", "predicted_ring",
    "topology_for_cluster",
    "FleetCase", "FleetEvaluator", "FleetResult", "evaluate_cases",
    "fleet_available", "make_case", "make_fleet_jobs",
    "BSP", "DAGSchedule", "DAGTask", "FleetForm", "LocalSGD", "OneFoneB",
    "PipelinedAllReduce", "SCHEDULES", "Schedule",
    "SweepGrid", "SweepResult", "closed_form_valid", "run_sweep",
    "Span", "from_chrome_trace", "frontier_spans", "read_chrome_trace",
    "refit_model", "replan_from_samples", "specs_from_json",
    "specs_from_rows", "specs_to_json", "synthetic_specs",
    "to_chrome_trace", "write_chrome_trace",
    "CheckpointFailure", "FaultEvent", "FaultInjector", "FaultPlan",
    "LinkDegradation", "Preemption", "SlowHostOnset", "WorkerCrash",
    "WorkerProfile", "make_workers", "scale_array", "scenarios",
]
