"""Iteration schedules: per-worker frontiers beyond the BSP barrier.

The paper's pipelining model (§4) — and the engine as originally built —
assumes BSP: a global barrier at the last all-reduce of every iteration,
which is exactly the regime where MG-WFBP's merged-gradient plan is
provably optimal.  This module makes the iteration discipline a pluggable
**schedule**: a :class:`Schedule` names the dependency edges between
compute segments, bucket collectives and optimizer updates
(:meth:`Schedule.dependencies`), owns the engine-side driver that advances
each worker's *iteration frontier*, and carries its own homogeneous
closed form (:meth:`Schedule.predict_t_iter`) so the planner's fixpoint
can optimize bucketing under the schedule actually being run.

Concrete schedules
------------------
* :class:`BSP` — the paper's semantics, bit-identical to the engine's
  original loop (cross-validated against ``core.simulator.simulate``).
* :class:`PipelinedAllReduce` — DeAR-style (arXiv:2302.12445) split
  collectives: the reduce-scatter ``1 - ag_fraction`` of each bucket runs
  eagerly during backward, the all-gather remainder is deferred and
  overlaps the *next* iteration's forward; a worker's next forward starts
  at ``max(own backward end, last reduce-scatter end)`` and its next
  backward additionally waits for all deferred all-gathers (updated
  parameters).  ``ag_fraction=0`` degenerates to BSP exactly.
* :class:`OneFoneB` — ``micro_batches`` 1F1B micro-batch pairs per
  iteration with gradient accumulation: compute totals are unchanged but
  every gradient's final value lands during the *last* micro-batch's
  backward, compressing the WFBP overlap window to a ``1/M`` tail (the
  DP-visible timing of an 1F1B pipeline schedule, where bucket sync happens
  under the final backward).  ``micro_batches=1`` degenerates to BSP.
* :class:`LocalSGD` — communicate every ``h`` steps: between syncs each
  worker's frontier is its own compute stream (clocks drift), the sync
  step bucket-all-reduces like BSP, and ``IterationResult.staleness``
  counts unsynced local steps.  ``h=1`` degenerates to BSP.
* :class:`DAGSchedule` — an explicit task graph (compute streams, link
  occupancies, precedence edges) executed directly; the generic extension
  point, and the substrate for the never-deadlocks property tests.

Every driver speaks to the engine only through ``_JobRun``'s primitives
(``scales`` / ``launch_collective`` / ``finish_iteration``), so schedules
compose with everything the engine already does: heterogeneous + jittery
workers, link contention, bursts, multi-job runs, per-iteration hooks.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import ClassVar, Sequence

import numpy as np

from repro.core.planner import MergePlan, TensorSpec
from repro.core.simulator import simulate
from repro.sim.engine import BucketTiming, IterationResult
from repro.sim.events import Latch
from repro.sim.trace import Span


@dataclasses.dataclass(frozen=True)
class FleetForm:
    """How a schedule's closed form maps onto the data-parallel kernels.

    The sweep fast path and the jitted fleet backend
    (``repro.sim.fleet``) evaluate three kernel shapes, selected by
    ``kind``:

    * ``"barrier"`` — the Eq. 7/8 recurrence with nominal ready times in
      the last micro-batch's ``1/micro_batches`` tail (BSP is
      ``micro_batches == 1``).  Exact under heterogeneity/jitter: the
      per-worker timeline is linear in the compute scale, so the
      synchronous ready time is the nominal one times the fleet max.
    * ``"pipelined"`` — the DeAR cross-iteration recurrence with the
      reduce-scatter fraction ``1 - ag_fraction`` eager and the rest
      deferred past the boundary.  Homogeneous fleets only.
    * ``"localsgd"`` — ``h - 1`` communication-free steps per round plus
      one barrier sync.  Homogeneous fleets only.

    ``heterogeneous_ok`` gates the jitter/straggler domain; schedules the
    kernels cannot express (``DAGSchedule``, custom subclasses) return
    ``None`` from :meth:`Schedule.fleet_form` and always take the engine.
    """

    kind: str                        # "barrier" | "pipelined" | "localsgd"
    micro_batches: int = 1           # barrier: 1F1B tail compression
    ag_fraction: float = 0.0         # pipelined: deferred share
    h: int = 1                       # localsgd: steps per round
    heterogeneous_ok: bool = True

    def __post_init__(self):
        if self.kind not in ("barrier", "pipelined", "localsgd"):
            raise ValueError(f"unknown fleet-form kind {self.kind!r}")


class Schedule:
    """How a job's iterations advance.  Subclasses are frozen dataclasses
    (hashable, usable as test fixtures) providing:

    * :meth:`driver` — the engine-side state machine;
    * :meth:`degenerate` — the parameter point at which the schedule
      provably reduces to BSP (the conformance harness runs both and
      asserts exact equality);
    * :meth:`dependencies` — the per-iteration dependency edges between
      compute segments (``fwd``/``bwd``), bucket collectives
      (``ar{k}``/``rs{k}``/``ag{k}``) and the optimizer update (``opt``);
      a trailing ``'`` marks a node of the next iteration;
    * :meth:`predict_t_iter` — the homogeneous, uncontended closed form
      for the steady-state per-iteration time (the schedule-aware analogue
      of ``core.simulator.simulate``; its validity domain is documented in
      docs/simulator.md);
    * :meth:`fleet_form` — the :class:`FleetForm` descriptor placing the
      closed form on the batched kernels (``None`` = engine only).
    """

    name: ClassVar[str] = "abstract"
    # True iff every iteration's gradients are fully synchronized — for
    # these schedules total communicated bytes is schedule-invariant
    # (property-tested in tests/test_schedule_props.py).
    synchronous: ClassVar[bool] = True

    def driver(self, run) -> "object":
        raise NotImplementedError

    def degenerate(self) -> "Schedule":
        raise NotImplementedError(f"{self.name} has no BSP-degenerate form")

    def validate_spec(self, spec) -> None:
        """Reject JobSpec combinations the driver cannot honour."""

    def dependencies(self, num_buckets: int) -> tuple[tuple[str, str], ...]:
        raise NotImplementedError

    def predict_t_iter(self, specs: Sequence[TensorSpec], plan: MergePlan,
                       model, t_f: float = 0.0) -> float:
        raise NotImplementedError

    def fleet_form(self) -> FleetForm | None:
        """Batched-kernel descriptor, or ``None`` if only the engine can
        run this schedule (the conservative default for subclasses)."""
        return None

    @property
    def label(self) -> str:
        return self.name


def _chain(edges: list[tuple[str, str]], nodes: list[str]) -> None:
    edges.extend(zip(nodes, nodes[1:]))


def _stepwise_dependencies(n_steps: int,
                           num_buckets: int) -> tuple[tuple[str, str], ...]:
    """The shared DAG shape of step-chained schedules (OneFoneB's
    micro-batches, LocalSGD's local steps): fwd/bwd pairs in sequence,
    collectives off the last backward, optimizer, next iteration."""
    edges: list[tuple[str, str]] = []
    for s in range(n_steps):
        edges.append((f"fwd{s}", f"bwd{s}"))
        if s + 1 < n_steps:
            edges.append((f"bwd{s}", f"fwd{s + 1}"))
    ars = [f"ar{k}" for k in range(num_buckets)]
    for ar in ars:
        edges.append((f"bwd{n_steps - 1}", ar))
    _chain(edges, ars)
    edges.append(((ars[-1] if ars else f"bwd{n_steps - 1}"), "opt"))
    edges.append(("opt", "fwd0'"))
    return tuple(edges)


def _schedule_ready_events(run, base: np.ndarray, eff_prefix: np.ndarray,
                           scales: np.ndarray, on_ready) -> None:
    """Schedule each bucket's "all workers produced the last gradient"
    event.  ``base[w]`` is worker w's backward origin; tensor j lands at
    ``base[w] + eff_prefix[j] * scales[w]``.  Analytic mode computes the
    fleet max directly; events mode schedules one arrival per worker per
    bucket-closing tensor through a :class:`Latch` (the faithful stream).
    Shared by the barrier and pipelined drivers so the two stay
    arithmetically identical on their common path."""
    eng = run.sim.engine
    buckets = run.plan.buckets
    if run.spec.compute_mode == "analytic":
        for k, bucket in enumerate(buckets):
            r = float((base + eff_prefix[bucket[-1]] * scales).max())
            eng.at(r, lambda k=k: on_ready(k))
    else:
        last_of = {b[-1]: k for k, b in enumerate(buckets)}
        n = len(run.workers)
        latches = [Latch(n, lambda k=k: on_ready(k))
                   for k in range(len(buckets))]
        for wi in range(n):
            for j, k in last_of.items():
                t = float(base[wi] + eff_prefix[j] * scales[wi])
                eng.at(t, latches[k].arrive)


# ---------------------------------------------------------------------------
# BSP (and the shared synchronous driver).
# ---------------------------------------------------------------------------

class _SyncDriver:
    """Barrier-synchronized iterations: the engine's original BSP state
    machine, generalized to per-worker start vectors (LocalSGD sync steps
    start workers at drifted clocks) and an overridable compute timeline
    (OneFoneB compresses gradient production into the last micro-batch).

    On the BSP path the arithmetic is expression-for-expression the
    pre-schedule engine's — the golden-trace tests and the closed-form
    cross-validation hold bit-identically.
    """

    def __init__(self, schedule: "Schedule", run) -> None:
        self.schedule = schedule
        self.run = run
        # per-iteration transient state
        self._it = 0
        self._ready: dict[int, float] = {}
        self._issued = 0
        self._in_flight = 0
        self._done_buckets: list[BucketTiming] = []
        self._bwd_end = 0.0
        self._iter_start = 0.0
        self._worker_compute: tuple[tuple[str, float], ...] = ()
        self._worker_start: tuple[tuple[str, float], ...] = ()
        self._worker_end: tuple[tuple[str, float], ...] = ()

    def start(self) -> None:
        self.start_iteration()

    def start_iteration(self) -> None:
        self._begin_sync(self.run.it, self.run.sim.engine.now)

    # -- compute-timeline hooks (overridden by OneFoneB) -----------------

    def _timeline(self, starts: np.ndarray, scales: np.ndarray,
                  prefix: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(fwd_end, eff_prefix): tensor j's gradient is final on worker w
        at ``fwd_end[w] + eff_prefix[j] * scales[w]``."""
        return starts + self.run.spec.t_f * scales, prefix

    def _record_compute_spans(self, starts: np.ndarray, scales: np.ndarray,
                              fwd_end: np.ndarray, bwd_end: np.ndarray,
                              it: int) -> None:
        run = self.run
        for wi, w in enumerate(run.workers):
            run.sim.record(Span(
                name="forward", cat="compute", pid=run.name, tid=w.name,
                start=float(starts[wi]), end=float(fwd_end[wi]),
                args={"iter": it}))
            run.sim.record(Span(
                name="backward", cat="compute", pid=run.name, tid=w.name,
                start=float(fwd_end[wi]), end=float(bwd_end[wi]),
                args={"iter": it}))

    # -- one barrier-synchronized iteration ------------------------------

    def _begin_sync(self, it: int, start) -> None:
        run = self.run
        eng = run.sim.engine
        self._it = it
        starts = np.broadcast_to(np.asarray(start, dtype=np.float64),
                                 (len(run.workers),))
        self._iter_start = float(starts.min())
        self._ready = {}
        self._issued = 0
        self._in_flight = 0
        self._done_buckets = []

        prefix = run.backward_prefix()
        scales = run.scales(it)
        fwd_end, eff_prefix = self._timeline(starts, scales, prefix)
        bwd_end = fwd_end + \
            (eff_prefix[-1] if len(eff_prefix) else 0.0) * scales
        self._bwd_end = float(bwd_end.max())
        self._worker_compute = tuple(
            (w.name, float(bwd_end[wi] - starts[wi]))
            for wi, w in enumerate(run.workers))
        self._worker_start = tuple(
            (w.name, float(starts[wi])) for wi, w in enumerate(run.workers))
        self._worker_end = tuple(
            (w.name, float(bwd_end[wi]))
            for wi, w in enumerate(run.workers))
        self._record_compute_spans(starts, scales, fwd_end, bwd_end, it)

        if not run.plan.buckets:
            eng.at(self._bwd_end, self._finish_iteration)
            return
        _schedule_ready_events(run, fwd_end, eff_prefix, scales,
                               self._bucket_ready)

    def _bucket_ready(self, k: int) -> None:
        self._ready[k] = self.run.sim.engine.now
        if self.run.spec.comm_mode == "concurrent":
            self._launch(k)
        else:
            self._try_issue()

    def _try_issue(self) -> None:
        if self._in_flight or self._issued >= self.run.plan.num_buckets:
            return
        if self._issued in self._ready:
            self._launch(self._issued)

    def _launch(self, k: int) -> None:
        run = self.run
        self._in_flight += 1
        self._issued = max(self._issued, k + 1)
        nbytes = run.bucket_nbytes(k)
        run.launch_collective(
            k, nbytes, it=self._it,
            on_done=lambda start, k=k, nbytes=nbytes:
                self._collective_done(k, nbytes, start))

    def _collective_done(self, k: int, nbytes: int, start: float) -> None:
        run = self.run
        self._in_flight -= 1
        self._done_buckets.append(BucketTiming(
            iteration=self._it, bucket=k, nbytes=nbytes,
            ready=self._ready[k], start=start, end=run.sim.engine.now))
        if run.spec.comm_mode == "sequential":
            self._try_issue()
        if len(self._done_buckets) == run.plan.num_buckets:
            end = max(run.sim.engine.now, self._bwd_end)
            run.sim.engine.at(end, self._finish_iteration)

    def _make_result(self, staleness: int = 0) -> IterationResult:
        buckets = tuple(sorted(self._done_buckets, key=lambda b: b.bucket))
        return IterationResult(
            index=self._it, start=self._iter_start,
            end=self.run.sim.engine.now, backward_end=self._bwd_end,
            buckets=buckets, worker_compute=self._worker_compute,
            worker_start=self._worker_start, worker_end=self._worker_end,
            staleness=staleness)

    def _finish_iteration(self) -> None:
        if self.run.finish_iteration(self._make_result()):
            self.run.next_iteration(self.start_iteration)


@dataclasses.dataclass(frozen=True)
class BSP(Schedule):
    """The paper's bulk-synchronous discipline: every worker's frontier is
    the global barrier at max(last all-reduce end, slowest backward)."""

    name: ClassVar[str] = "bsp"
    synchronous: ClassVar[bool] = True

    def driver(self, run):
        return _SyncDriver(self, run)

    def degenerate(self) -> "BSP":
        return self

    def dependencies(self, num_buckets: int) -> tuple[tuple[str, str], ...]:
        edges: list[tuple[str, str]] = [("fwd", "bwd")]
        ars = [f"ar{k}" for k in range(num_buckets)]
        for ar in ars:
            edges.append(("bwd", ar))
        _chain(edges, ars)
        edges.append(((ars[-1] if ars else "bwd"), "opt"))
        edges.append(("opt", "fwd'"))
        return tuple(edges)

    def predict_t_iter(self, specs, plan, model, t_f=0.0) -> float:
        return simulate(specs, plan, model, t_f).t_iter

    def fleet_form(self) -> FleetForm:
        return FleetForm(kind="barrier")


# ---------------------------------------------------------------------------
# OneFoneB: micro-batched 1F1B with gradient accumulation.
# ---------------------------------------------------------------------------

class _OneFoneBDriver(_SyncDriver):
    """Same barrier discipline as BSP; the compute timeline interleaves
    ``micro_batches`` forward/backward pairs, so gradients only finalize
    during the last micro-batch's backward (a ``1/M``-scaled tail)."""

    def _timeline(self, starts, scales, prefix):
        m = self.schedule.micro_batches
        t_f = self.run.spec.t_f
        t_b_total = prefix[-1] if len(prefix) else 0.0
        pair = (t_f + t_b_total) / m
        warm = starts + ((m - 1) * pair) * scales
        return warm + (t_f / m) * scales, prefix / m

    def _record_compute_spans(self, starts, scales, fwd_end, bwd_end, it):
        run = self.run
        m = self.schedule.micro_batches
        t_f = run.spec.t_f
        prefix = run.backward_prefix()
        t_b_total = prefix[-1] if len(prefix) else 0.0
        cur = np.array(starts, dtype=np.float64)
        for mb in range(m):
            f1 = cur + (t_f / m) * scales
            b1 = f1 + (t_b_total / m) * scales
            for wi, w in enumerate(run.workers):
                run.sim.record(Span(
                    name="forward", cat="compute", pid=run.name, tid=w.name,
                    start=float(cur[wi]), end=float(f1[wi]),
                    args={"iter": it, "micro": mb}))
                run.sim.record(Span(
                    name="backward", cat="compute", pid=run.name,
                    tid=w.name, start=float(f1[wi]), end=float(b1[wi]),
                    args={"iter": it, "micro": mb}))
            cur = b1


@dataclasses.dataclass(frozen=True)
class OneFoneB(Schedule):
    """Micro-batched 1F1B with per-worker frontiers and end-of-iteration
    gradient sync (Megatron-style DP x PP interaction): each iteration is
    ``micro_batches`` forward/backward chunk pairs; total compute time is
    unchanged but the bucket-overlap window shrinks to the last backward
    chunk.  ``micro_batches=1`` is exactly BSP."""

    micro_batches: int = 4

    name: ClassVar[str] = "1f1b"
    synchronous: ClassVar[bool] = True

    def __post_init__(self):
        if self.micro_batches < 1:
            raise ValueError(
                f"need >= 1 micro batch, got {self.micro_batches}")

    @property
    def label(self) -> str:
        return f"1f1b{self.micro_batches}"

    def driver(self, run):
        return _OneFoneBDriver(self, run)

    def degenerate(self) -> "OneFoneB":
        return dataclasses.replace(self, micro_batches=1)

    def dependencies(self, num_buckets: int) -> tuple[tuple[str, str], ...]:
        return _stepwise_dependencies(self.micro_batches, num_buckets)

    def predict_t_iter(self, specs, plan, model, t_f=0.0) -> float:
        m = self.micro_batches
        prefix = np.cumsum([s.t_b for s in specs]) if specs \
            else np.zeros(0)
        t_b_total = float(prefix[-1]) if len(prefix) else 0.0
        pair = (t_f + t_b_total) / m
        base = (m - 1) * pair + t_f / m
        end = 0.0
        for bucket, nbytes in zip(plan.buckets,
                                  plan.bucket_bytes(specs)):
            ready = base + float(prefix[bucket[-1]]) / m
            end = max(end, ready) + model.time(nbytes)
        return max(end, t_f + t_b_total)

    def fleet_form(self) -> FleetForm:
        return FleetForm(kind="barrier", micro_batches=self.micro_batches)


# ---------------------------------------------------------------------------
# LocalSGD: communicate every H steps; frontiers drift between syncs.
# ---------------------------------------------------------------------------

class _LocalSGDDriver(_SyncDriver):
    """Rounds of ``h`` steps: the first ``h - 1`` are communication-free
    (each worker's frontier is its own compute stream), the last is a
    BSP-style bucket sync started from the drifted per-worker clocks.
    Iteration results (and hooks) for the local steps are flushed in order
    at the round barrier, where membership changes are safe."""

    def __init__(self, schedule, run):
        super().__init__(schedule, run)
        self._round_results: list[IterationResult] = []

    def start_iteration(self) -> None:
        run = self.run
        spec = run.spec
        first = run.it
        steps = min(self.schedule.h, spec.iters - first)
        T = run.sim.engine.now
        starts = np.full(len(run.workers), T, dtype=np.float64)
        prefix = run.backward_prefix()
        tail = prefix[-1] if len(prefix) else 0.0
        self._round_results = []
        for s in range(steps - 1):
            it = first + s
            scales = run.scales(it)
            fwd_end = starts + spec.t_f * scales
            bwd_end = fwd_end + tail * scales
            for wi, w in enumerate(run.workers):
                run.sim.record(Span(
                    name="forward", cat="compute", pid=run.name,
                    tid=w.name, start=float(starts[wi]),
                    end=float(fwd_end[wi]),
                    args={"iter": it, "local_step": s + 1}))
                run.sim.record(Span(
                    name="backward", cat="compute", pid=run.name,
                    tid=w.name, start=float(fwd_end[wi]),
                    end=float(bwd_end[wi]),
                    args={"iter": it, "local_step": s + 1}))
            self._round_results.append(IterationResult(
                index=it, start=float(starts.min()),
                end=float(bwd_end.max()),
                backward_end=float(bwd_end.max()), buckets=(),
                worker_compute=tuple(
                    (w.name, float(bwd_end[wi] - starts[wi]))
                    for wi, w in enumerate(run.workers)),
                worker_start=tuple(
                    (w.name, float(starts[wi]))
                    for wi, w in enumerate(run.workers)),
                worker_end=tuple(
                    (w.name, float(bwd_end[wi]))
                    for wi, w in enumerate(run.workers)),
                staleness=s + 1))
            starts = bwd_end
        self._begin_sync(first + steps - 1, starts)

    def _finish_iteration(self) -> None:
        run = self.run
        sync_result = self._make_result()
        for r in self._round_results:    # flush local steps, in order
            run.finish_iteration(r)
        self._round_results = []
        # only the sync step closes the round: its index is the round's
        # last, so its return value alone decides continuation
        if run.finish_iteration(sync_result):
            run.next_iteration(self.start_iteration)


@dataclasses.dataclass(frozen=True)
class LocalSGD(Schedule):
    """Communicate every ``h`` steps.  Between syncs workers run free —
    per-worker frontiers drift by heterogeneity and jitter — and the sync
    step all-reduces the accumulated update with the usual bucket overlap.
    ``IterationResult.staleness`` records unsynced steps; total bytes per
    round is one plan's worth (``1/h`` of BSP's per-iteration traffic).
    ``h=1`` is exactly BSP."""

    h: int = 4

    name: ClassVar[str] = "localsgd"
    synchronous: ClassVar[bool] = False

    def __post_init__(self):
        if self.h < 1:
            raise ValueError(f"need h >= 1, got {self.h}")

    @property
    def label(self) -> str:
        return f"localsgd{self.h}"

    def driver(self, run):
        return _LocalSGDDriver(self, run)

    def degenerate(self) -> "LocalSGD":
        return dataclasses.replace(self, h=1)

    def dependencies(self, num_buckets: int) -> tuple[tuple[str, str], ...]:
        return _stepwise_dependencies(self.h, num_buckets)

    def predict_t_iter(self, specs, plan, model, t_f=0.0) -> float:
        """Per-iteration average over one steady round: ``h - 1`` pure
        compute steps plus one BSP-like sync step."""
        t_b_total = sum(s.t_b for s in specs)
        sync = simulate(specs, plan, model, t_f).t_iter
        return ((self.h - 1) * (t_f + t_b_total) + sync) / self.h

    def fleet_form(self) -> FleetForm:
        if self.h == 1:                       # exactly BSP, jitter included
            return FleetForm(kind="barrier")
        return FleetForm(kind="localsgd", h=self.h, heterogeneous_ok=False)


# ---------------------------------------------------------------------------
# PipelinedAllReduce: DeAR-style split collectives across the boundary.
# ---------------------------------------------------------------------------

class _PipelinedDriver:
    """Per-worker frontiers with split collectives.

    Iteration ``it``: each worker forwards from its own frontier, backward
    additionally waits for the previous iteration's deferred all-gathers
    (updated parameters); reduce-scatters (``1 - ag_fraction`` of each
    bucket's cost) issue in order as buckets become ready; after the last
    reduce-scatter the all-gathers stream out in reverse bucket order —
    the order the next forward consumes parameters — overlapping that
    forward.  Worker w's next frontier is
    ``max(bwd_end[w], last reduce-scatter end)``.

    With ``ag_fraction == 0`` the reduce-scatter is the whole collective
    and the all-gathers are free, which reproduces BSP timing (and its
    trace) exactly — the conformance harness asserts this.
    """

    def __init__(self, schedule: "PipelinedAllReduce", run) -> None:
        self.schedule = schedule
        self.run = run
        self._state: dict = {}

    def start(self) -> None:
        run = self.run
        T = run.sim.engine.now
        starts = np.full(len(run.workers), T, dtype=np.float64)
        self._start_iteration(starts, ag_done=T)

    def _start_iteration(self, starts: np.ndarray, ag_done: float) -> None:
        run = self.run
        eng = run.sim.engine
        spec = run.spec
        it = run.it
        scales = run.scales(it)
        prefix = run.backward_prefix()
        tail = prefix[-1] if len(prefix) else 0.0
        fwd_end = starts + spec.t_f * scales
        bwd_start = np.maximum(fwd_end, ag_done)
        bwd_end = bwd_start + tail * scales
        for wi, w in enumerate(run.workers):
            run.sim.record(Span(
                name="forward", cat="compute", pid=run.name, tid=w.name,
                start=float(starts[wi]), end=float(fwd_end[wi]),
                args={"iter": it}))
            if bwd_start[wi] > fwd_end[wi]:
                run.sim.record(Span(
                    name="ag_wait", cat="compute", pid=run.name,
                    tid=w.name, start=float(fwd_end[wi]),
                    end=float(bwd_start[wi]), args={"iter": it}))
            run.sim.record(Span(
                name="backward", cat="compute", pid=run.name, tid=w.name,
                start=float(bwd_start[wi]), end=float(bwd_end[wi]),
                args={"iter": it}))

        self._state = {
            "it": it, "starts": starts, "bwd_end": bwd_end,
            # pure compute, excluding the ag_wait stall: equals BSP's
            # bwd_end - starts bitwise when the wait is zero (x - 0.0 == x)
            "compute": (bwd_end - starts) - (bwd_start - fwd_end),
            "ready": {}, "issued": 0, "in_flight": 0,
            "rs": {}, "ag": {}, "rs_done": 0.0,
        }
        if not run.plan.buckets:
            eng.at(float(bwd_end.max()), self._finalize)
            return
        _schedule_ready_events(run, bwd_start, prefix, scales,
                               self._bucket_ready)

    # -- eager reduce-scatter stream (in-order, one in flight) -----------

    def _bucket_ready(self, k: int) -> None:
        st = self._state
        st["ready"][k] = self.run.sim.engine.now
        self._try_issue()

    def _try_issue(self) -> None:
        st = self._state
        if st["in_flight"] or st["issued"] >= self.run.plan.num_buckets:
            return
        if st["issued"] in st["ready"]:
            self._launch_rs(st["issued"])

    def _launch_rs(self, k: int) -> None:
        st = self._state
        st["in_flight"] += 1
        st["issued"] = max(st["issued"], k + 1)
        nbytes = self.run.bucket_nbytes(k)
        f = self.schedule.ag_fraction
        self.run.launch_collective(
            k, nbytes, it=st["it"], fraction=1.0 - f,
            tag="reduce_scatter" if f > 0 else "allreduce",
            on_done=lambda start, k=k, nbytes=nbytes:
                self._rs_done(k, nbytes, start))

    def _rs_done(self, k: int, nbytes: int, start: float) -> None:
        st = self._state
        now = self.run.sim.engine.now
        st["in_flight"] -= 1
        st["rs"][k] = (nbytes, st["ready"][k], start, now)
        self._try_issue()
        if len(st["rs"]) == self.run.plan.num_buckets:
            st["rs_done"] = now
            self._issue_ags()

    # -- deferred all-gather stream (reverse order, overlaps next fwd) ---

    def _issue_ags(self) -> None:
        st = self._state
        order = list(range(self.run.plan.num_buckets - 1, -1, -1))

        def next_ag(i: int) -> None:
            if i == len(order):
                self._finalize()
                return
            k = order[i]
            nbytes = st["rs"][k][0]

            def done(start: float, k: int = k) -> None:
                st["ag"][k] = (start, self.run.sim.engine.now)
                next_ag(i + 1)

            self.run.launch_collective(
                k, nbytes, it=st["it"],
                fraction=self.schedule.ag_fraction, tag="all_gather",
                on_done=done)

        next_ag(0)

    def _finalize(self) -> None:
        st = self._state
        run = self.run
        now = run.sim.engine.now
        starts, bwd_end = st["starts"], st["bwd_end"]
        timings = []
        for k in range(run.plan.num_buckets):
            nbytes, ready, rs_start, rs_end = st["rs"][k]
            ag_start, ag_end = st["ag"][k]
            timings.append(BucketTiming(
                iteration=st["it"], bucket=k, nbytes=nbytes, ready=ready,
                start=rs_start, end=ag_end,
                comm_s=(rs_end - rs_start) + (ag_end - ag_start)))
        bwd_max = float(bwd_end.max())
        rs_done = st["rs_done"] if timings else bwd_max
        compute = st["compute"]
        result = IterationResult(
            index=st["it"], start=float(starts.min()),
            end=max(now, bwd_max), backward_end=bwd_max,
            buckets=tuple(timings),
            worker_compute=tuple(
                (w.name, float(compute[wi]))
                for wi, w in enumerate(run.workers)),
            worker_start=tuple(
                (w.name, float(starts[wi]))
                for wi, w in enumerate(run.workers)),
            worker_end=tuple(
                (w.name, float(bwd_end[wi]))
                for wi, w in enumerate(run.workers)),
            staleness=0)
        if run.finish_iteration(result):
            if run.resume_at > run.sim.engine.now:
                # a fault hook paused the job: the pipelined overlap is
                # broken anyway, so resynchronize the whole fleet at the
                # resume point (starts and the all-gather frontier alike)
                t = run.resume_at

                def resume(t: float = t) -> None:
                    self._start_iteration(
                        np.full(len(run.workers), t, dtype=np.float64),
                        ag_done=t)

                run.sim.engine.at(t, resume)
            elif len(run.workers) != len(bwd_end):
                # membership changed by a hook: resynchronize the fleet
                nxt = np.full(len(run.workers), max(bwd_max, rs_done),
                              dtype=np.float64)
                self._start_iteration(nxt, ag_done=now)
            else:
                nxt = np.maximum(bwd_end, rs_done)
                self._start_iteration(nxt, ag_done=now)


@dataclasses.dataclass(frozen=True)
class PipelinedAllReduce(Schedule):
    """DeAR-style decoupled all-reduce (arXiv:2302.12445): reduce-scatter
    eagerly during backward, all-gather lazily under the next iteration's
    forward.  ``ag_fraction`` is the share of each collective deferred
    (0.5 models the ring all-reduce's equal halves); 0 degenerates to
    BSP exactly."""

    ag_fraction: float = 0.5

    name: ClassVar[str] = "pipelined"
    synchronous: ClassVar[bool] = True

    def __post_init__(self):
        if not 0.0 <= self.ag_fraction < 1.0:
            raise ValueError(
                f"ag_fraction must be in [0, 1), got {self.ag_fraction}")

    @property
    def label(self) -> str:
        return f"pipelined{self.ag_fraction:g}"

    def validate_spec(self, spec) -> None:
        if spec.comm_mode != "sequential":
            raise ValueError(
                "PipelinedAllReduce defines its own issue order; "
                "comm_mode must be 'sequential'")

    def driver(self, run):
        return _PipelinedDriver(self, run)

    def degenerate(self) -> "PipelinedAllReduce":
        return dataclasses.replace(self, ag_fraction=0.0)

    def dependencies(self, num_buckets: int) -> tuple[tuple[str, str], ...]:
        edges: list[tuple[str, str]] = [("fwd", "bwd")]
        rss = [f"rs{k}" for k in range(num_buckets)]
        ags = [f"ag{k}" for k in range(num_buckets)]
        for rs in rss:
            edges.append(("bwd", rs))
        _chain(edges, rss)
        if rss:
            edges.append((rss[-1], "opt"))       # shard update after RS
            edges.append((rss[-1], ags[-1]))     # AGs follow the last RS
            _chain(edges, list(reversed(ags)))   # reverse: fwd-need order
            edges.append(("opt", "fwd'"))
            edges.append(("bwd", "fwd'"))
            edges.append((ags[0], "bwd'"))       # full params before bwd'
        else:
            edges.extend([("bwd", "opt"), ("opt", "fwd'")])
        return tuple(edges)

    def predict_t_iter(self, specs, plan, model, t_f=0.0,
                       iters: int = 8) -> float:
        """Steady-state period of the cross-iteration recurrence
        (homogeneous, uncontended)."""
        f = self.ag_fraction
        prefix = np.cumsum([s.t_b for s in specs]) if specs \
            else np.zeros(0)
        t_b_total = float(prefix[-1]) if len(prefix) else 0.0
        nbytes = plan.bucket_bytes(specs)
        S, ag_done, period = 0.0, 0.0, 0.0
        for _ in range(max(iters, 2)):
            fwd_end = S + t_f
            bwd_start = max(fwd_end, ag_done)
            bwd_end = bwd_start + t_b_total
            end = 0.0
            for bucket, nb in zip(plan.buckets, nbytes):
                ready = bwd_start + float(prefix[bucket[-1]])
                end = max(end, ready) + (1.0 - f) * model.time(nb)
            rs_done = end if plan.buckets else bwd_end
            ag_done = rs_done + sum(f * model.time(nb) for nb in nbytes)
            s_next = max(bwd_end, rs_done)
            period = s_next - S
            S = s_next
        return period

    def fleet_form(self) -> FleetForm:
        if self.ag_fraction == 0.0:           # exactly BSP, jitter included
            return FleetForm(kind="barrier")
        return FleetForm(kind="pipelined", ag_fraction=self.ag_fraction,
                         heterogeneous_ok=False)


# ---------------------------------------------------------------------------
# DAGSchedule: explicit task graphs (the generic extension point).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DAGTask:
    """One node of an explicit schedule DAG.

    ``worker`` names a compute stream (tasks on one stream serialize,
    FIFO in readiness order); ``link`` names a network resource (the task
    occupies it as a processor-sharing flow of ``duration`` seconds at
    full rate, contending with everything else on that link); neither
    means a pure dependency/delay node."""

    name: str
    duration: float = 0.0
    worker: str | None = None
    link: str | None = None
    deps: tuple[str, ...] = ()

    def __post_init__(self):
        if self.duration < 0:
            raise ValueError(f"negative duration: {self}")
        if self.worker is not None and self.link is not None:
            raise ValueError(
                f"task {self.name!r} cannot occupy a worker and a link")


class _DAGDriver:
    def __init__(self, schedule: "DAGSchedule", run) -> None:
        self.schedule = schedule
        self.run = run

    def start(self) -> None:
        run = self.run
        tasks = self.schedule.tasks
        self._t0 = run.sim.engine.now
        self._by_name = {t.name: t for t in tasks}
        self._dependents: dict[str, list[DAGTask]] = \
            collections.defaultdict(list)
        self._missing = {t.name: len(set(t.deps)) for t in tasks}
        for t in tasks:
            for d in set(t.deps):
                self._dependents[d].append(t)
        self._busy: dict[str, bool] = {}
        self._queues: dict[str, collections.deque] = {}
        self._windows: dict[str, list[float]] = {}   # stream -> [min, max]
        self._done = 0
        if not tasks:
            self._complete()
            return
        for t in tasks:                 # deterministic: declaration order
            if self._missing[t.name] == 0:
                self._dispatch(t)

    def _dispatch(self, t: DAGTask) -> None:
        if t.worker is None:
            self._execute(t)
            return
        if self._busy.get(t.worker):
            self._queues.setdefault(t.worker, collections.deque()).append(t)
        else:
            self._busy[t.worker] = True
            self._execute(t)

    def _execute(self, t: DAGTask) -> None:
        run = self.run
        eng = run.sim.engine
        start = eng.now

        def done() -> None:
            now = eng.now
            tid = t.worker or (f"link:{t.link}" if t.link else "ctrl")
            cat = "compute" if t.worker else ("comm" if t.link else "task")
            run.sim.record(Span(name=t.name, cat=cat, pid=run.name,
                                tid=tid, start=start, end=now,
                                args={"task": t.name}))
            if t.worker is not None:
                w = self._windows.setdefault(t.worker, [start, now])
                w[0], w[1] = min(w[0], start), max(w[1], now)
                q = self._queues.get(t.worker)
                if q:
                    self._execute(q.popleft())
                else:
                    self._busy[t.worker] = False
            self._done += 1
            for dep in self._dependents.get(t.name, ()):
                self._missing[dep.name] -= 1
                if self._missing[dep.name] == 0:
                    self._dispatch(dep)
            if self._done == len(self.schedule.tasks):
                self._complete()

        if t.link is not None:
            run.sim.ensure_link(t.link)
            run.sim.links[t.link].add_flow(t.duration, done,
                                           owner=run.name)
        else:
            eng.after(t.duration, done)

    def _complete(self) -> None:
        run = self.run
        now = run.sim.engine.now
        streams = sorted(self._windows) if self._windows else []
        run.finish_iteration(IterationResult(
            index=run.it, start=self._t0, end=now, backward_end=now,
            buckets=(),
            worker_compute=tuple(
                (s, self._windows[s][1] - self._windows[s][0])
                for s in streams),
            worker_start=tuple((s, self._windows[s][0]) for s in streams),
            worker_end=tuple((s, self._windows[s][1]) for s in streams),
            staleness=0))


@dataclasses.dataclass(frozen=True)
class DAGSchedule(Schedule):
    """Execute an explicit acyclic task graph once.

    The generic escape hatch for schedules the named classes don't cover —
    and the substrate of the frontier property tests: any acyclic task set
    completes (no deadlock), streams serialize deterministically, and link
    tasks contend like every other flow.  Cycles and dangling dependencies
    are rejected at :class:`~repro.sim.engine.JobSpec` construction."""

    tasks: tuple[DAGTask, ...] = ()

    name: ClassVar[str] = "dag"
    synchronous: ClassVar[bool] = False

    def __post_init__(self):
        names = [t.name for t in self.tasks]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate task names: {names}")
        known = set(names)
        for t in self.tasks:
            missing = [d for d in t.deps if d not in known]
            if missing:
                raise ValueError(
                    f"task {t.name!r} depends on unknown {missing}")
        # Kahn's algorithm: anything left over sits on a cycle.
        indeg = {t.name: len(set(t.deps)) for t in self.tasks}
        dependents = collections.defaultdict(list)
        for t in self.tasks:
            for d in set(t.deps):
                dependents[d].append(t.name)
        queue = collections.deque(
            t.name for t in self.tasks if indeg[t.name] == 0)
        seen = 0
        while queue:
            n = queue.popleft()
            seen += 1
            for m in dependents[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    queue.append(m)
        if seen != len(self.tasks):
            stuck = sorted(n for n, d in indeg.items() if d > 0)
            raise ValueError(f"dependency cycle through {stuck}")

    def validate_spec(self, spec) -> None:
        if spec.iters != 1:
            raise ValueError("DAGSchedule runs its graph once; iters must "
                             "be 1 (replicate tasks for more iterations)")

    def driver(self, run):
        return _DAGDriver(self, run)

    def dependencies(self, num_buckets: int) -> tuple[tuple[str, str], ...]:
        return tuple((d, t.name) for t in self.tasks for d in t.deps)


SCHEDULES = {
    "bsp": BSP,
    "pipelined": PipelinedAllReduce,
    "1f1b": OneFoneB,
    "localsgd": LocalSGD,
    "dag": DAGSchedule,
}
