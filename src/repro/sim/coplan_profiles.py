"""Synthetic co-planning fleets: many jobs, mixed schedules, one factory.

The co-planner benchmarks and the fleet-backend tests both need "a
hundred jobs that look like a real shared cluster" — varied model sizes
(the paper's Fig. 5 log-uniform tensor shape via ``synthetic_specs``),
varied link quality, and a mix of execution schedules so a batched
scoring pass exercises every closed-form kind in one device call.  This
module is that factory, kept in ``src`` so benchmarks and tests build
the *same* fleet.
"""

from __future__ import annotations

from repro.core.coplanner import CoJob
from repro.core.cost_model import AllReduceModel
from repro.core.planner import make_plan
from repro.sim.schedules import LocalSGD, OneFoneB, PipelinedAllReduce
from repro.sim.trace import synthetic_specs


def make_fleet_jobs(n_jobs: int, *, seed: int = 0,
                    mixed_schedules: bool = True) -> tuple[CoJob, ...]:
    """Build ``n_jobs`` deterministic :class:`CoJob` profiles.

    Job ``i`` gets a log-uniform synthetic profile of 20-35 tensors
    (seeded ``seed + i``), an affine cost model whose startup/per-byte
    terms spread ~2x across the fleet (fast and slow links coexist, so
    makespan is contested), a WFBP seed plan (the static baseline the
    co-plan must never lose to), and — when ``mixed_schedules`` — a
    schedule cycling through BSP, 1F1B, pipelined all-reduce and
    LocalSGD so batched evaluation covers every ``FleetForm`` kind.
    """
    if n_jobs < 1:
        raise ValueError("need >= 1 job")
    cycle = (None, OneFoneB(micro_batches=4),
             PipelinedAllReduce(ag_fraction=0.5), LocalSGD(h=2)) \
        if mixed_schedules else (None,)
    jobs = []
    for i in range(n_jobs):
        specs, t_f = synthetic_specs(20 + (i * 7) % 16, seed=seed + i)
        model = AllReduceModel(a=200e-6 * (1.0 + (i % 5) / 4.0),
                               b=4e-9 * (1.0 + (i % 3) / 2.0))
        jobs.append(CoJob(
            name=f"job{i:03d}", specs=tuple(specs), model=model, t_f=t_f,
            schedule=cycle[i % len(cycle)],
            seed_plans=(make_plan("wfbp", specs, model),)))
    return tuple(jobs)
