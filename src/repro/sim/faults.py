"""Deterministic fault injection for the cluster simulator.

A :class:`FaultPlan` is a seeded, typed schedule of infrastructure
faults — the failure taxonomy of long S-SGD runs, where one bad worker
or link stalls the whole synchronous fleet:

* :class:`WorkerCrash` — fail-stop: the worker vanishes at ``time``;
  the in-flight iteration's gradient sync is lost and the supervisor
  discovers it at the next iteration boundary.
* :class:`Preemption` — a crash with advance notice (spot/maintenance):
  the notice fires at ``time`` and the worker dies at ``time +
  notice_s`` unless the supervisor drains it first.
* :class:`LinkDegradation` — a bandwidth cut (or flap when short): the
  link runs at ``factor`` of its capacity for ``duration`` seconds.
  Overlapping windows stack multiplicatively.
* :class:`SlowHostOnset` — gray failure: the worker's compute slows by
  ``factor`` from ``time`` on (thermal throttling, a noisy neighbour);
  nothing crashes, the straggler monitor has to notice.
* :class:`CheckpointFailure` — the next ``count`` checkpoint writes
  fail (full disk, flaky object store).

:class:`FaultInjector` arms a plan on a :class:`~repro.sim.engine
.ClusterSim` through ``Engine.at`` hooks, so injection is part of the
deterministic event order — same seed, same trace, golden-comparable
flight-recorder output.  Physical effects the fabric can express
directly (link rate, compute slowdown) are applied by the injector
itself; fail-stop effects are exposed as supervisor *views*
(:meth:`FaultInjector.take_crashes` etc.) because detecting and
repairing them is exactly the resilience controller's job
(``repro.sim.scenarios.faulty_long_run`` closes that loop).
"""

from __future__ import annotations

import dataclasses
import random
from typing import ClassVar, Sequence

from repro.obs.recorder import EventRecord
from repro.sim.trace import Span


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """Base: something goes wrong at ``time`` (sim seconds)."""

    time: float
    kind: ClassVar[str] = "fault"

    def __post_init__(self):
        if not self.time >= 0:
            raise ValueError(f"fault time must be >= 0, got {self.time}")

    def args(self) -> dict:
        """JSON-safe payload for traces and flight-recorder events."""
        d = dataclasses.asdict(self)
        d.pop("time")
        return d


@dataclasses.dataclass(frozen=True)
class WorkerCrash(FaultEvent):
    worker: str = ""
    kind: ClassVar[str] = "crash"


@dataclasses.dataclass(frozen=True)
class Preemption(FaultEvent):
    worker: str = ""
    notice_s: float = 0.5
    kind: ClassVar[str] = "preempt"

    def __post_init__(self):
        super().__post_init__()
        if not self.notice_s >= 0:
            raise ValueError(f"notice_s must be >= 0: {self.notice_s}")


@dataclasses.dataclass(frozen=True)
class LinkDegradation(FaultEvent):
    link: str = "net"
    factor: float = 0.5          # capacity multiplier during the window
    duration: float = 1.0
    kind: ClassVar[str] = "link_degrade"

    def __post_init__(self):
        super().__post_init__()
        if not 0 < self.factor <= 1:
            raise ValueError(f"factor must be in (0, 1]: {self.factor}")
        if not self.duration > 0:
            raise ValueError(f"duration must be > 0: {self.duration}")


@dataclasses.dataclass(frozen=True)
class SlowHostOnset(FaultEvent):
    worker: str = ""
    factor: float = 3.0          # compute slowdown multiplier (> 1)
    kind: ClassVar[str] = "slow_host"

    def __post_init__(self):
        super().__post_init__()
        if not self.factor > 1:
            raise ValueError(f"slowdown factor must be > 1: {self.factor}")


@dataclasses.dataclass(frozen=True)
class CheckpointFailure(FaultEvent):
    count: int = 1               # how many consecutive writes fail
    kind: ClassVar[str] = "ckpt_fail"

    def __post_init__(self):
        super().__post_init__()
        if self.count < 1:
            raise ValueError(f"count must be >= 1: {self.count}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, time-sorted fault schedule.

    Build one explicitly from events, or draw a reproducible random one
    with :meth:`random` — either way the plan is pure data, so the same
    plan against the same cluster yields bit-identical traces.
    """

    events: tuple[FaultEvent, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: e.time)))

    def __len__(self) -> int:
        return len(self.events)

    def of_kind(self, kind: str) -> tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.kind == kind)

    @classmethod
    def random(cls, seed: int, horizon: float,
               workers: Sequence[str], links: Sequence[str] = (), *,
               n_crashes: int = 1, n_preemptions: int = 1,
               n_degradations: int = 1, n_slow: int = 1,
               n_ckpt_failures: int = 1) -> "FaultPlan":
        """A seeded random plan over ``(0, horizon)``.

        Kinds are drawn in a fixed order so the plan is a pure function
        of the arguments.  Worker-targeted events pick distinct workers
        where possible (a crash and a preemption never target the same
        host, so the supervisor's N−k floor is predictable).
        """
        if horizon <= 0:
            raise ValueError(f"horizon must be > 0, got {horizon}")
        rng = random.Random(seed)
        pool = list(workers)
        rng.shuffle(pool)

        def take_worker() -> str:
            return pool.pop() if pool else rng.choice(list(workers))

        def when(lo: float = 0.05, hi: float = 0.85) -> float:
            return horizon * rng.uniform(lo, hi)

        events: list[FaultEvent] = []
        for _ in range(n_crashes):
            events.append(WorkerCrash(when(), worker=take_worker()))
        for _ in range(n_preemptions):
            events.append(Preemption(
                when(), worker=take_worker(),
                notice_s=horizon * rng.uniform(0.02, 0.08)))
        for _ in range(n_degradations):
            if not links:
                break
            events.append(LinkDegradation(
                when(), link=rng.choice(list(links)),
                factor=rng.uniform(0.25, 0.7),
                duration=horizon * rng.uniform(0.05, 0.25)))
        for _ in range(n_slow):
            events.append(SlowHostOnset(
                when(), worker=take_worker(),
                factor=rng.uniform(2.0, 5.0)))
        for _ in range(n_ckpt_failures):
            events.append(CheckpointFailure(when(), count=rng.randint(1, 2)))
        return cls(events=tuple(events), seed=seed)


class FaultInjector:
    """Arms a :class:`FaultPlan` on a live :class:`ClusterSim`.

    Call :meth:`arm` once before ``sim.run()``.  Fabric-level effects
    (link rate, host slowdown) are applied immediately at fire time;
    fail-stop effects accumulate in supervisor views that a scenario
    hook drains at iteration boundaries:

    * :meth:`take_crashes` — workers that died since the last call
      (crashes, plus preemptions whose deadline passed undrained);
    * :meth:`take_notices` — preemption notices awaiting a drain
      decision (call :meth:`mark_drained` once handled);
    * :meth:`take_slow_hosts` / :meth:`take_degradations` — gray
      failures the controller may react to (evict / replan);
    * :meth:`take_ckpt_failure` — consume one budgeted write failure.

    Every fired event lands in the trace (a ``fault`` span) and the
    flight recorder (``fault_injected``), stamped with sim time — the
    determinism tests golden-compare exactly this stream.
    """

    def __init__(self, sim, plan: FaultPlan, job: str):
        self.sim = sim
        self.plan = plan
        self.job = job
        self.fired: list[tuple[float, FaultEvent]] = []
        self._crashes: list[tuple[str, float, str]] = []   # worker, t, kind
        self._notices: list[dict] = []
        self._slow: list[tuple[str, float, float]] = []    # worker, t, factor
        self._degradations: list[dict] = []
        self._ckpt_budget = 0
        self._link_factors: dict[str, list[float]] = {}
        self._armed = False

    # -- arming -----------------------------------------------------------

    def arm(self) -> None:
        if self._armed:
            raise RuntimeError("FaultInjector.arm called twice")
        self._armed = True
        for e in self.plan.events:
            self.sim.engine.at(e.time, lambda e=e: self._fire(e))

    def _record(self, e: FaultEvent, t: float, **extra) -> None:
        args = {**e.args(), **extra}
        self.fired.append((t, e))
        self.sim.record(Span(
            name=f"fault:{e.kind}", cat="fault", pid="faults",
            tid=self.job, start=t, end=t, args=args))
        if self.sim.recorder is not None:
            self.sim.recorder.record(EventRecord(
                kind="fault_injected", time=t, source="sim",
                job=self.job, args={"fault": e.kind, **args}))

    def _fire(self, e: FaultEvent) -> None:
        t = self.sim.engine.now
        self._record(e, t)
        if isinstance(e, WorkerCrash):
            self._crashes.append((e.worker, t, "crash"))
        elif isinstance(e, Preemption):
            note = {"worker": e.worker, "at": t,
                    "deadline": t + e.notice_s, "drained": False}
            self._notices.append(note)
            self.sim.engine.at(
                note["deadline"], lambda n=note: self._preempt_kill(n))
        elif isinstance(e, LinkDegradation):
            self._degrade(e.link, e.factor)
            self._degradations.append(
                {"link": e.link, "at": t, "factor": e.factor,
                 "until": t + e.duration})
            self.sim.engine.at(
                t + e.duration, lambda e=e: self._restore(e.link, e.factor))
        elif isinstance(e, SlowHostOnset):
            self._slow_host(e.worker, e.factor)
            self._slow.append((e.worker, t, e.factor))
        elif isinstance(e, CheckpointFailure):
            self._ckpt_budget += e.count

    # -- physical effects -------------------------------------------------

    def _apply_rate(self, link: str) -> None:
        scale = 1.0
        for f in self._link_factors.get(link, ()):  # windows stack
            scale *= f
        self.sim.ensure_link(link).set_rate_scale(scale)

    def _degrade(self, link: str, factor: float) -> None:
        self._link_factors.setdefault(link, []).append(factor)
        self._apply_rate(link)

    def _restore(self, link: str, factor: float) -> None:
        self._link_factors.get(link, [factor]).remove(factor)
        self._apply_rate(link)
        self.sim.record(Span(
            name="fault:link_restore", cat="fault", pid="faults",
            tid=self.job, start=self.sim.engine.now,
            end=self.sim.engine.now, args={"link": link}))

    def _slow_host(self, worker: str, factor: float) -> None:
        run = self.sim.job_run(self.job)
        run.workers = [
            dataclasses.replace(w, slowdown=w.slowdown * factor)
            if w.name == worker else w for w in run.workers]

    def _preempt_kill(self, note: dict) -> None:
        if not note["drained"]:
            self._crashes.append(
                (note["worker"], self.sim.engine.now, "preempt"))

    # -- supervisor views -------------------------------------------------

    def take_crashes(self) -> list[tuple[str, float, str]]:
        """Workers dead since the last call: (name, time, cause) where
        cause is ``"crash"`` or ``"preempt"`` (deadline expired)."""
        out, self._crashes = self._crashes, []
        return out

    def take_notices(self) -> list[dict]:
        """Open preemption notices (not yet drained, deadline ahead)."""
        now = self.sim.engine.now
        return [n for n in self._notices
                if not n["drained"] and n["deadline"] > now]

    def mark_drained(self, worker: str) -> None:
        """The supervisor checkpointed + evicted ``worker`` before its
        preemption deadline; the kill becomes a no-op."""
        for n in self._notices:
            if n["worker"] == worker:
                n["drained"] = True

    def take_slow_hosts(self) -> list[tuple[str, float, float]]:
        out, self._slow = self._slow, []
        return out

    def take_degradations(self) -> list[dict]:
        out, self._degradations = self._degradations, []
        return out

    def take_ckpt_failure(self) -> bool:
        """Consume one budgeted checkpoint-write failure, if any."""
        if self._ckpt_budget > 0:
            self._ckpt_budget -= 1
            return True
        return False
