"""Batched scenario sweeps: closed form where valid, engine where not.

The 4→2048-worker studies (``benchmarks/scaling_sim.py`` /
``benchmarks/cluster_sim.py``) and the elastic replan loop both need many
(worker count × jitter seed × bandwidth level) evaluations of the same
profile.  Driving the event engine for each point is overkill: on every
scenario a single job owns the link and issues collectives in order, the
engine provably reproduces the closed form (``core/simulator``
cross-validation), so the whole grid collapses to one vectorized
per-bucket recurrence (``core.simulator.batched_comm_end``) — including
heterogeneous/jittery workers, because with one compute scale per worker
per iteration the synchronous ready time is just the nominal ready time
times the fleet's max scale.

The closed form is *invalid* — and this module falls back to the event
engine, per point — exactly when collectives can contend for link
bandwidth: background ``Burst`` traffic, ``comm_mode="concurrent"``, or
multiple jobs (multi-job sweeps should drive ``ClusterSim`` directly).
``SweepResult.used_engine`` records which path produced each point.

Planning across the grid goes through ONE incremental
:class:`repro.core.planner.Planner` — each (N, bandwidth) point is a
cost-model delta, not a from-scratch O(L^2) replan; the planner's counters
are surfaced on the result so benchmarks can assert the fast path was
actually taken.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core import planner
from repro.core.planner import MergePlan, Planner, TensorSpec
from repro.core.simulator import batched_comm_end
from repro.sim.engine import ClusterSim, JobSpec
from repro.sim.network import Burst, FlatTopology
from repro.sim.workers import make_workers


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """The cartesian scenario grid a sweep evaluates."""

    n_workers: tuple[int, ...]
    bandwidth_scales: tuple[float, ...] = (1.0,)   # link speed multipliers
    seeds: tuple[int, ...] = (0,)                  # jitter seeds

    def __post_init__(self):
        if not self.n_workers or not self.bandwidth_scales or not self.seeds:
            raise ValueError(f"empty sweep axis: {self}")
        if any(n < 1 for n in self.n_workers):
            raise ValueError(f"need >= 1 worker: {self.n_workers}")
        if any(s <= 0 for s in self.bandwidth_scales):
            raise ValueError(
                f"bandwidth scales must be positive: {self.bandwidth_scales}")

    @property
    def shape(self) -> tuple[int, int, int]:
        return (len(self.n_workers), len(self.bandwidth_scales),
                len(self.seeds))


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """``t_iter[n_idx, bw_idx, seed_idx, iter]`` plus provenance."""

    grid: SweepGrid
    iters: int
    t_iter: np.ndarray                  # seconds, shape grid.shape + (iters,)
    used_engine: np.ndarray             # bool, shape (len(n), len(bw))
    plans: dict[tuple[int, float], MergePlan]   # (n, bw_scale) -> plan
    planner_scratch: int                # Planner state rebuilds (1 == ideal)
    planner_incremental: int            # incremental replans taken

    def point(self, n: int, bandwidth_scale: float = 1.0,
              seed: int = 0) -> np.ndarray:
        """Per-iteration times for one grid point."""
        return self.t_iter[self.grid.n_workers.index(n),
                           self.grid.bandwidth_scales.index(bandwidth_scale),
                           self.grid.seeds.index(seed)]


def closed_form_valid(*, comm_mode: str = "sequential",
                      bursts: Sequence[Burst] = ()) -> bool:
    """True iff no link contention is possible: a single job issuing
    collectives in order with no background traffic.  Heterogeneity and
    jitter do NOT invalidate the closed form (scales factor out of the
    synchronous max); contention does."""
    return comm_mode == "sequential" and not bursts


def _max_scales(workers, seeds: Sequence[int], iters: int,
                job: str) -> np.ndarray:
    """Fleet-max compute scale per (seed, iteration) — the one number the
    synchronous closed form needs from the whole worker population."""
    out = np.empty((len(seeds), iters), dtype=np.float64)
    for si, seed in enumerate(seeds):
        for it in range(iters):
            out[si, it] = max(w.scale(seed, job, wi, it)
                              for wi, w in enumerate(workers))
    return out


def run_sweep(specs: Sequence[TensorSpec], t_f: float, grid: SweepGrid, *,
              algorithm: str = "ring", strategy: str = "dp_incremental",
              alpha: float, beta: float, gamma: float = 0.0,
              iters: int = 1, jitter_sigma: float = 0.0,
              slow: Mapping[int, float] | None = None,
              bursts: Sequence[Burst] = (),
              comm_mode: str = "sequential",
              force_engine: bool = False,
              job_name: str = "train") -> SweepResult:
    """Evaluate one profile over a scenario grid.

    ``bandwidth_scales`` multiply link speed (scale 2.0 = twice the
    bandwidth, i.e. half the per-byte cost); startup latency ``alpha`` and
    reduction ``gamma`` are unaffected.  Each (N, bandwidth) point gets its
    own merge plan; with the default ``dp_incremental`` strategy all points
    share one :class:`Planner` and replan incrementally.
    """
    if iters < 1:
        raise ValueError("need >= 1 iteration")
    slow = dict(slow or {})
    fast = closed_form_valid(comm_mode=comm_mode, bursts=bursts) \
        and not force_engine

    L = len(specs)
    prefix_t = np.cumsum([s.t_b for s in specs]) if L else np.zeros(0)
    t_b_total = float(prefix_t[-1]) if L else 0.0

    shared: Planner | None = None
    t_iter = np.zeros(grid.shape + (iters,), dtype=np.float64)
    used_engine = np.zeros(grid.shape[:2], dtype=bool)
    plans: dict[tuple[int, float], MergePlan] = {}

    for ni, n in enumerate(grid.n_workers):
        workers = make_workers(
            n, slow={i: f for i, f in slow.items() if 0 <= i < n},
            jitter_sigma=jitter_sigma)
        s_max = _max_scales(workers, grid.seeds, iters, job_name)
        for bi, bw in enumerate(grid.bandwidth_scales):
            topo = FlatTopology(algorithm, n, alpha, beta / bw, gamma)
            model = topo.linear_model()
            if strategy == "dp_incremental":
                if shared is None:
                    shared = Planner(specs, model)
                    plan = shared.plan()
                else:
                    plan = shared.replan(model)
            else:
                plan = planner.make_plan(strategy, specs, model)
            plans[(n, bw)] = plan

            if fast:
                bucket_t = np.array(
                    [model.time(b) for b in plan.bucket_bytes(specs)],
                    dtype=np.float64)
                last = np.array([b[-1] for b in plan.buckets], dtype=int)
                # ready[seed, iter, k] = s_max * (t_f + prefix_t[last_k])
                nominal = t_f + (prefix_t[last] if L else np.zeros(0))
                ready = s_max[..., None] * nominal[None, None, :]
                bwd_end = s_max * (t_f + t_b_total)
                t_iter[ni, bi] = batched_comm_end(
                    bucket_t[None, None, :], ready, bwd_end)
            else:
                used_engine[ni, bi] = True
                for si, seed in enumerate(grid.seeds):
                    job = JobSpec(name=job_name, specs=list(specs),
                                  plan=plan, t_f=t_f, workers=workers,
                                  topology=topo, iters=iters,
                                  comm_mode=comm_mode,
                                  compute_mode="analytic")
                    res = ClusterSim([job], seed=seed,
                                     bursts=bursts).run()
                    t_iter[ni, bi, si] = res.job(job_name).t_iters

    return SweepResult(
        grid=grid, iters=iters, t_iter=t_iter, used_engine=used_engine,
        plans=plans,
        planner_scratch=shared.scratch_plans if shared else 0,
        planner_incremental=shared.incremental_updates if shared else 0)
