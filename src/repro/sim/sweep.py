"""Batched scenario sweeps: closed form where valid, engine where not.

The 4→2048-worker studies (``benchmarks/scaling_sim.py`` /
``benchmarks/cluster_sim.py``) and the elastic replan loop both need many
(worker count × jitter seed × bandwidth level) evaluations of the same
profile.  Driving the event engine for each point is overkill: on every
scenario a single job owns the link and issues collectives in order, the
engine provably reproduces the closed form (``core/simulator``
cross-validation), so the whole grid collapses to one vectorized
per-bucket recurrence (``core.simulator.batched_comm_end``) — including
heterogeneous/jittery workers, because with one compute scale per worker
per iteration the synchronous ready time is just the nominal ready time
times the fleet's max scale.

**Backends.**  Inside the valid domain the grid is evaluated by one of
two equivalent fast paths, selected by ``backend=``:

* ``"numpy"`` — the portable per-point closed forms (one
  ``batched_comm_end`` pass per (N, bandwidth) point);
* ``"fleet"`` — ``repro.sim.fleet``: every point becomes a padded bucket
  column and the WHOLE grid is one jitted jax call (the N=2048 × many-
  bandwidth × many-seed regime; >=10x over numpy on the headline grid,
  enforced by ``benchmarks/fleet_bench.py``);
* ``"auto"`` (default) — fleet when jax is importable and the grid has
  enough elements to amortize the jit compile, numpy otherwise.

``SweepResult.backend`` records which one ran.  Outside the valid
domain every point takes the serial event engine — recorded per point
in ``used_engine``, counted in ``fallback_points``, and surfaced as the
``sweep_fallback_points_total`` metric so large sweeps cannot silently
degrade to the slow path.

**Schedules.**  The fast path is no longer BSP-only: pass ``schedule=``
(``repro.sim.schedules``) and the sweep evaluates that schedule's own
closed form across the grid instead of the engine, on the schedule's
exactness domain — declared by :meth:`Schedule.fleet_form`:

* ``BSP`` / ``OneFoneB(M)``: any heterogeneity/jitter.  1F1B only moves
  *where* gradients land (the 1/M tail of the last micro-batch), and its
  timeline stays per-worker linear in the compute scale, so the fleet-max
  reduction that batches BSP batches it too — same
  ``batched_comm_end`` pass, shifted ready times.
* ``PipelinedAllReduce`` / ``LocalSGD(H)``: homogeneous fleets only
  (their closed forms track cross-iteration frontiers / drifting clocks,
  which do not factor through a per-iteration max); heterogeneity falls
  back to the engine.

The closed form is *invalid* — and this module falls back to the event
engine, per point — exactly when collectives can contend for link
bandwidth: background ``Burst`` traffic, ``comm_mode="concurrent"``, or
multiple jobs (multi-job sweeps should drive ``ClusterSim`` directly —
or the co-planner, ``repro.core.coplanner``).

Planning across the grid goes through ONE incremental
:class:`repro.core.planner.Planner` — each (N, bandwidth) point is a
cost-model delta, not a from-scratch O(L^2) replan; the planner's counters
are surfaced on the result so benchmarks can assert the fast path was
actually taken.  Per-profile prefix sums (``core.simulator.spec_arrays``)
and the worker scale table (:func:`_max_scales_table`) are computed once
per sweep, not per grid point.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core import planner
from repro.core.planner import MergePlan, Planner, TensorSpec
from repro.core.simulator import (batched_comm_end, bucket_arrays,
                                  spec_arrays)
from repro.obs.metrics import REGISTRY
from repro.sim import fleet as fleet_backend
from repro.sim.engine import ClusterSim, JobSpec
from repro.sim.network import Burst, FlatTopology
from repro.sim.schedules import (LocalSGD, OneFoneB, PipelinedAllReduce,
                                 Schedule)
from repro.sim.workers import make_workers

# grid elements (points × iterations) below which backend="auto" stays on
# numpy: the jit compile + dispatch would dominate tiny grids
_FLEET_AUTO_MIN = 512


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """The cartesian scenario grid a sweep evaluates."""

    n_workers: tuple[int, ...]
    bandwidth_scales: tuple[float, ...] = (1.0,)   # link speed multipliers
    seeds: tuple[int, ...] = (0,)                  # jitter seeds

    def __post_init__(self):
        if not self.n_workers or not self.bandwidth_scales or not self.seeds:
            raise ValueError(f"empty sweep axis: {self}")
        if any(n < 1 for n in self.n_workers):
            raise ValueError(f"need >= 1 worker: {self.n_workers}")
        if any(s <= 0 for s in self.bandwidth_scales):
            raise ValueError(
                f"bandwidth scales must be positive: {self.bandwidth_scales}")

    @property
    def shape(self) -> tuple[int, int, int]:
        return (len(self.n_workers), len(self.bandwidth_scales),
                len(self.seeds))


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """``t_iter[n_idx, bw_idx, seed_idx, iter]`` plus provenance.

    ``t_iter`` matches the engine's per-iteration
    ``IterationResult.t_iter`` values point for point on the fast path's
    validity domain.  ``span`` is the whole run's wall time (last
    iteration end minus first start) per grid point — for barrier
    schedules that is just ``t_iter.sum(-1)``, but pipelined iterations
    *overlap* (the deferred all-gather tail runs under the next forward),
    so ``span`` is the number to rate schedules against each other.
    """

    grid: SweepGrid
    iters: int
    t_iter: np.ndarray                  # seconds, shape grid.shape + (iters,)
    span: np.ndarray                    # seconds, shape grid.shape
    used_engine: np.ndarray             # bool, shape (len(n), len(bw))
    plans: dict[tuple[int, float], MergePlan]   # (n, bw_scale) -> plan
    planner_scratch: int                # Planner state rebuilds (1 == ideal)
    planner_incremental: int            # incremental replans taken
    fallback_points: int = 0            # engine-evaluated points × seeds
    backend: str = "numpy"              # "fleet" | "numpy" | "engine"

    def point(self, n: int, bandwidth_scale: float = 1.0,
              seed: int = 0) -> np.ndarray:
        """Per-iteration times for one grid point."""
        return self.t_iter[self.grid.n_workers.index(n),
                           self.grid.bandwidth_scales.index(bandwidth_scale),
                           self.grid.seeds.index(seed)]


def closed_form_valid(*, comm_mode: str = "sequential",
                      bursts: Sequence[Burst] = (),
                      schedule: Schedule | None = None,
                      heterogeneous: bool = False) -> bool:
    """True iff the batched closed form is exact for this configuration.

    Link contention (concurrent issue, background bursts, other jobs)
    always invalidates it.  The per-schedule domain comes from
    :meth:`Schedule.fleet_form`: BSP and OneFoneB tolerate
    heterogeneity/jitter (per-worker scales factor out of the synchronous
    max), PipelinedAllReduce and LocalSGD have homogeneous-only closed
    forms (their BSP-degenerate points are barrier forms, jitter
    included), and anything without a fleet form (DAGSchedule, custom)
    needs the engine."""
    if comm_mode != "sequential" or bursts:
        return False
    if schedule is None:
        return True
    form = schedule.fleet_form()
    if form is None:
        return False
    return form.heterogeneous_ok or not heterogeneous


def _fallback_reason(*, comm_mode: str, bursts, schedule,
                     heterogeneous: bool, force_engine: bool) -> str:
    """Label for the sweep_fallback_points_total counter."""
    if force_engine:
        return "forced"
    if bursts:
        return "bursts"
    if comm_mode != "sequential":
        return "comm_mode"
    if schedule is not None and schedule.fleet_form() is None:
        return "schedule_unsupported"
    if heterogeneous:
        return "schedule_heterogeneous"
    return "unknown"


def _max_scales_table(workers, seeds: Sequence[int], iters: int,
                      job: str) -> np.ndarray:
    """Running fleet-max compute scale, shape (seeds, iters, workers).

    Entry ``[..., w]`` is the max over workers ``0..w``, so slicing
    ``[..., n - 1]`` yields the (seed, iteration) fleet max of the first
    ``n`` workers — one table serves every N in the grid instead of a
    Python rescan per point.  Exact because a worker's scale is keyed on
    its own index (independent of fleet size)."""
    if all(w.jitter_sigma == 0.0 for w in workers):
        cm = np.maximum.accumulate(
            np.array([w.slowdown for w in workers], dtype=np.float64))
        return np.broadcast_to(cm, (len(seeds), iters, len(workers)))
    table = np.empty((len(seeds), iters, len(workers)), dtype=np.float64)
    for si, seed in enumerate(seeds):
        for it in range(iters):
            for wi, w in enumerate(workers):
                table[si, it, wi] = w.scale(seed, job, wi, it)
    return np.maximum.accumulate(table, axis=-1)


# ---------------------------------------------------------------------------
# Per-schedule closed forms over (seed × iteration) blocks (numpy backend).
# ---------------------------------------------------------------------------

def _barrier_t_iter(schedule: Schedule | None, bucket_t: np.ndarray,
                    ready_off: np.ndarray, t_f: float, t_b_total: float,
                    s_max: np.ndarray) -> np.ndarray:
    """BSP / OneFoneB block: ``batched_comm_end`` over (seed, iter) with
    the schedule's nominal gradient-ready offsets, scaled by the fleet
    max.  For OneFoneB(M) the ready times sit in the last micro-batch's
    1/M tail (mirroring ``_OneFoneBDriver._timeline``)."""
    if isinstance(schedule, OneFoneB) and schedule.micro_batches > 1:
        m = schedule.micro_batches
        pair = (t_f + t_b_total) / m
        base = (m - 1) * pair + t_f / m
        nominal = base + ready_off / m
        nominal_bwd = base + t_b_total / m
    else:
        nominal = t_f + ready_off
        nominal_bwd = t_f + t_b_total
    ready = s_max[..., None] * nominal[None, None, :]
    return batched_comm_end(bucket_t[None, None, :], ready,
                            s_max * nominal_bwd)


def _pipelined_windows(ag_fraction: float, bucket_t: np.ndarray,
                       ready_off: np.ndarray, t_f: float, t_b_total: float,
                       iters: int) -> tuple[np.ndarray, float]:
    """Homogeneous pipelined run: per-iteration ``end - start`` windows
    plus the total span, via the exact cross-iteration recurrence the
    engine executes (``_PipelinedDriver``: frontier at
    ``max(own backward end, last reduce-scatter end)``, all-gathers
    deferred past the boundary)."""
    f = ag_fraction
    S, ag_done = 0.0, 0.0
    t_iter = np.empty(iters, dtype=np.float64)
    iter_end = 0.0
    for it in range(iters):
        fwd_end = S + t_f
        bwd_start = max(fwd_end, ag_done)
        bwd_end = bwd_start + t_b_total
        if len(bucket_t):
            end = 0.0
            for k in range(len(bucket_t)):
                end = max(end, bwd_start + ready_off[k]) \
                    + (1.0 - f) * bucket_t[k]
            rs_done = end
            ag_done = rs_done + sum(f * bt for bt in bucket_t)
            iter_end = max(ag_done, bwd_end)
        else:
            rs_done = bwd_end
            ag_done = bwd_end
            iter_end = bwd_end
        t_iter[it] = iter_end - S
        S = max(bwd_end, rs_done)
    return t_iter, iter_end


def _localsgd_t_iter(h: int, bucket_t: np.ndarray, ready_off: np.ndarray,
                     t_f: float, t_b_total: float,
                     iters: int) -> np.ndarray:
    """Homogeneous LocalSGD(H) run: ``H - 1`` communication-free steps of
    ``t_f + t_b`` per round, then one BSP-like sync step (truncated final
    rounds included, mirroring ``_LocalSGDDriver``)."""
    sync_t = float(batched_comm_end(bucket_t, t_f + ready_off,
                                    t_f + t_b_total))
    local_t = t_f + t_b_total
    out = np.empty(iters, dtype=np.float64)
    first = 0
    while first < iters:
        steps = min(h, iters - first)
        out[first:first + steps - 1] = local_t
        out[first + steps - 1] = sync_t
        first += steps
    return out


def run_sweep(specs: Sequence[TensorSpec], t_f: float, grid: SweepGrid, *,
              algorithm: str = "ring", strategy: str = "dp_incremental",
              alpha: float | None = None, beta: float | None = None,
              gamma: float = 0.0,
              iters: int = 1, jitter_sigma: float = 0.0,
              slow: Mapping[int, float] | None = None,
              bursts: Sequence[Burst] = (),
              comm_mode: str = "sequential",
              schedule: Schedule | None = None,
              force_engine: bool = False,
              backend: str = "auto",
              topology_factory=None,
              job_name: str = "train") -> SweepResult:
    """Evaluate one profile over a scenario grid.

    ``bandwidth_scales`` multiply link speed (scale 2.0 = twice the
    bandwidth, i.e. half the per-byte cost); startup latency ``alpha`` and
    reduction ``gamma`` are unaffected.  Each (N, bandwidth) point gets its
    own merge plan; with the default ``dp_incremental`` strategy all points
    share one :class:`Planner` and replan incrementally, and with
    ``dp_batched`` the WHOLE grid's plans come from one batched DP kernel
    call (``repro.sim.fleet.plan_cases`` — same optimum, device-side).  ``schedule``
    runs every point under that iteration discipline — through the
    schedule's closed form where exact (see :func:`closed_form_valid`),
    through the engine otherwise.

    ``backend`` selects the fast-path implementation on the valid domain:
    ``"numpy"`` (portable per-point closed forms), ``"fleet"`` (one
    jitted jax call for the whole grid — raises if jax is missing), or
    ``"auto"`` (fleet for large grids when jax is importable).  The
    backend choice never changes *which* points take the engine fallback,
    only how the fast points are computed.

    ``topology_factory(n_workers, bandwidth_scale) -> Topology`` swaps the
    default flat Table-2 topology for an arbitrary one — e.g. a
    hierarchical ICI+DCN pod whose :class:`~repro.core.cost_model.
    PathModel` flattens to the (a, b) the closed forms consume (a sum of
    per-link affine phases is still affine, so the fast path stays exact
    on its single-job uncontended domain).  With a factory, ``alpha`` /
    ``beta`` / ``algorithm`` are ignored; without one they are required.
    """
    if iters < 1:
        raise ValueError("need >= 1 iteration")
    if topology_factory is None and (alpha is None or beta is None):
        raise ValueError("need alpha and beta (or a topology_factory)")
    if backend not in ("auto", "fleet", "numpy"):
        raise ValueError(f"unknown backend {backend!r}")
    slow = dict(slow or {})
    heterogeneous = jitter_sigma != 0.0 or \
        any(f != 1.0 for f in slow.values())
    fast = closed_form_valid(comm_mode=comm_mode, bursts=bursts,
                             schedule=schedule,
                             heterogeneous=heterogeneous) \
        and not force_engine

    if backend == "fleet":
        if not fleet_backend.fleet_available():
            raise RuntimeError(
                "backend='fleet' requested but jax is not importable")
        use_fleet = fast
    elif backend == "auto":
        n_elements = len(grid.n_workers) * len(grid.bandwidth_scales) \
            * len(grid.seeds) * iters
        use_fleet = fast and n_elements >= _FLEET_AUTO_MIN \
            and fleet_backend.fleet_available()
    else:
        use_fleet = False

    # hoisted per-profile state: prefix sums once, worker scale table once
    prefix_bytes, prefix_t = spec_arrays(specs)
    t_b_total = float(prefix_t[-1]) if len(specs) else 0.0
    max_n = max(grid.n_workers)
    workers_all = make_workers(
        max_n, slow={i: f for i, f in slow.items() if 0 <= i < max_n},
        jitter_sigma=jitter_sigma)
    scale_table = _max_scales_table(workers_all, grid.seeds, iters,
                                    job_name)

    shared: Planner | None = None
    t_iter = np.zeros(grid.shape + (iters,), dtype=np.float64)
    span = np.zeros(grid.shape, dtype=np.float64)
    used_engine = np.zeros(grid.shape[:2], dtype=bool)
    plans: dict[tuple[int, float], MergePlan] = {}
    cases: list[fleet_backend.FleetCase] = []
    case_idx: list[tuple[int, int]] = []
    # (profile fingerprint, plan.buckets) -> bucket geometry, LRU-bounded
    geom_cache = fleet_backend.GeomCache()
    profile_key = fleet_backend.profile_fingerprint(prefix_bytes, prefix_t)

    def _topo(n, bw):
        return (topology_factory(n, bw) if topology_factory is not None
                else FlatTopology(algorithm, n, alpha, beta / bw, gamma))

    batched_plans: dict[tuple[int, float], MergePlan] = {}
    if strategy == "dp_batched":
        # plan the WHOLE grid in one batched-DP call: every (N, bandwidth)
        # point shares this profile's prefix sums, only (a, b) varies
        points = [(n, bw) for n in grid.n_workers
                  for bw in grid.bandwidth_scales]
        pcases = [fleet_backend.make_plan_case(
                      specs, _topo(n, bw).linear_model(),
                      prefix_bytes=prefix_bytes, prefix_t=prefix_t)
                  for n, bw in points]
        batched_plans = dict(zip(points, fleet_backend.plan_cases(pcases)))

    for ni, n in enumerate(grid.n_workers):
        workers = workers_all[:n]
        s_max = scale_table[:, :, n - 1]
        for bi, bw in enumerate(grid.bandwidth_scales):
            topo = _topo(n, bw)
            model = topo.linear_model()
            if strategy == "dp_incremental":
                if shared is None:
                    shared = Planner(specs, model)
                    plan = shared.plan()
                else:
                    plan = shared.replan(model)
            elif strategy == "dp_batched":
                plan = batched_plans[(n, bw)]
            else:
                plan = planner.make_plan(strategy, specs, model)
            plans[(n, bw)] = plan

            if fast and use_fleet:
                cases.append(fleet_backend.make_case(
                    specs, plan, model, schedule=schedule, t_f=t_f,
                    s_max=s_max, prefix_bytes=prefix_bytes,
                    prefix_t=prefix_t, cache=geom_cache,
                    profile_key=profile_key))
                case_idx.append((ni, bi))
            elif fast:
                bucket_bytes, ready_off = bucket_arrays(
                    prefix_bytes, prefix_t, plan)
                bucket_t = np.array([model.time(b) for b in bucket_bytes],
                                    dtype=np.float64)
                if isinstance(schedule, PipelinedAllReduce) and \
                        schedule.ag_fraction > 0:
                    vals, total = _pipelined_windows(
                        schedule.ag_fraction, bucket_t, ready_off, t_f,
                        t_b_total, iters)
                    t_iter[ni, bi] = vals[None, :]
                    span[ni, bi] = total
                elif isinstance(schedule, LocalSGD) and schedule.h > 1:
                    vals = _localsgd_t_iter(schedule.h, bucket_t,
                                            ready_off, t_f, t_b_total,
                                            iters)
                    t_iter[ni, bi] = vals[None, :]
                    span[ni, bi] = float(vals.sum())
                else:
                    # BSP, OneFoneB, and every BSP-degenerate parameter
                    # point (ag_fraction == 0, H == 1, M == 1)
                    t_iter[ni, bi] = _barrier_t_iter(
                        schedule, bucket_t, ready_off, t_f, t_b_total,
                        s_max)
                    span[ni, bi] = t_iter[ni, bi].sum(axis=-1)
            else:
                used_engine[ni, bi] = True
                for si, seed in enumerate(grid.seeds):
                    job = JobSpec(name=job_name, specs=list(specs),
                                  plan=plan, t_f=t_f, workers=workers,
                                  topology=topo, iters=iters,
                                  comm_mode=comm_mode,
                                  compute_mode="analytic",
                                  schedule=schedule)
                    res = ClusterSim([job], seed=seed,
                                     bursts=bursts).run()
                    jr = res.job(job_name)
                    t_iter[ni, bi, si] = jr.t_iters
                    span[ni, bi, si] = jr.iterations[-1].end - \
                        jr.iterations[0].start

    if cases:
        # the whole grid in ONE jitted device call
        fres = fleet_backend.evaluate_cases(cases, iters=iters)
        for c, (ni, bi) in enumerate(case_idx):
            t_iter[ni, bi] = fres.t_iter[c]
            span[ni, bi] = fres.span[c]

    fallback_points = int(used_engine.sum()) * len(grid.seeds)
    if fallback_points:
        REGISTRY.counter(
            "sweep_fallback_points_total",
            "sweep grid points (× seeds) evaluated by the serial event "
            "engine instead of a batched closed form, by reason").inc(
                fallback_points,
                reason=_fallback_reason(
                    comm_mode=comm_mode, bursts=bursts, schedule=schedule,
                    heterogeneous=heterogeneous,
                    force_engine=force_engine),
                schedule=schedule.label if schedule else "bsp")

    return SweepResult(
        grid=grid, iters=iters, t_iter=t_iter, span=span,
        used_engine=used_engine, plans=plans,
        planner_scratch=shared.scratch_plans if shared else 0,
        planner_incremental=shared.incremental_updates if shared else 0,
        fallback_points=fallback_points,
        backend="engine" if not fast else
                ("fleet" if use_fleet else "numpy"))
