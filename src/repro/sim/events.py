"""Deterministic priority-queue event core for the cluster simulator.

Every event is ``(time, seq, callback)``: ``seq`` is a monotonically
increasing tie-breaker, so two events at the same timestamp always fire in
scheduling order and a run is a pure function of its inputs — no set/dict
iteration order, no wall clock, no global RNG.  This is what makes the
engine's timelines reproducible enough to cross-validate against the
closed-form simulator at 1e-9 (see ``core/simulator.cross_validate``).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable


@dataclasses.dataclass(frozen=True)
class Event:
    """A scheduled callback.  Ordering: time, then insertion order."""

    time: float
    seq: int
    fn: Callable[[], None] = dataclasses.field(compare=False)

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class Latch:
    """Counts arrivals and fires ``on_done`` on the ``n``-th.

    The schedule drivers use one latch per synchronization point (e.g. "all
    workers produced bucket k's last gradient"): every per-worker event calls
    :meth:`arrive`, and the callback fires exactly once, inside the event
    that completed the count — so the firing time inherits the event queue's
    deterministic (time, seq) order.
    """

    def __init__(self, n: int, on_done: Callable[[], None]) -> None:
        if n < 1:
            raise ValueError(f"latch needs n >= 1, got {n}")
        self.n = n
        self.count = 0
        self._on_done = on_done

    def arrive(self) -> None:
        self.count += 1
        if self.count == self.n:
            self._on_done()


class EventQueue:
    """Min-heap of :class:`Event` with deterministic tie-breaking."""

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = 0

    def push(self, time: float, fn: Callable[[], None]) -> Event:
        ev = Event(time, self._seq, fn)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek_time(self) -> float:
        return self._heap[0].time

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
