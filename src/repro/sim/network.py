"""Topology-aware collective timing for the event engine.

A *topology* answers two questions:

* ``linear_model()`` — the flat ``T(M) = a + b*M`` view that the MG-WFBP
  planner consumes (reusing :mod:`repro.core.cost_model`'s Table-2
  algorithms and TPU constants);
* ``phases(nbytes)`` — how one all-reduce actually occupies shared link
  resources in the engine: an ordered list of (link, startup, transfer
  seconds at full rate).  Phases on the same link *contend* with other
  collectives via processor sharing, which is what the closed-form model
  cannot express.

Uncontended, the phase times sum exactly to ``linear_model().time(M)`` —
the engine cross-validates against ``core/simulator.simulate`` on that
identity.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import cost_model


@dataclasses.dataclass(frozen=True)
class Phase:
    """One leg of a collective on one link resource — the engine-side
    execution view of a :class:`~repro.core.cost_model.PathPhase`
    (same field order; :meth:`from_path` is the ONE transcription point).

    ``shard_fraction`` is the fraction of the collective's bytes that
    physically cross this link (1.0 for a flat leg, ``1/intra_size`` for
    the cross-pod all-reduce on the intra-pod shard) — it scales the
    link's *byte* accounting, not its timing (``seconds_per_byte`` is
    already per full-message byte)."""

    link: str
    startup: float            # latency before the transfer starts (s)
    seconds_per_byte: float   # transfer cost at full link rate (s/B)
    shard_fraction: float = 1.0

    @staticmethod
    def from_path(p: cost_model.PathPhase) -> "Phase":
        return Phase(p.link, p.a, p.b, p.shard_fraction)

    def volume(self, nbytes: float) -> float:
        """Transfer work in seconds-at-full-rate."""
        return self.seconds_per_byte * float(nbytes)


class Topology:
    """Base: a topology defined directly by a cost model.

    The single source of truth is the :class:`~repro.core.cost_model.
    PathModel`: ``linear_model()`` (the flat (a, b) the planner consumes)
    and ``phases(nbytes)`` (how a collective occupies link resources in
    the engine) are two views of it.  Construct from a flat
    :class:`~repro.core.cost_model.AllReduceModel` (wrapped as a
    one-phase path on ``link``) or directly from a multi-phase
    ``PathModel``.
    """

    def __init__(self, model, link: str = "net", n_workers: int = 1,
                 algorithm: str = "ring"):
        if isinstance(model, cost_model.PathModel):
            self._path = model
            self._model = model.flatten()
            self.link = model.links[0]
        else:
            self._model = model
            self._path = cost_model.single_path(model, link)
            self.link = link
        self.n_workers = n_workers
        self.algorithm = algorithm

    @property
    def links(self) -> tuple[str, ...]:
        return self._path.links

    def linear_model(self) -> cost_model.AllReduceModel:
        return self._model

    def path_model(self) -> cost_model.PathModel:
        """The per-link decomposition ``phases()``/``linear_model()``
        are views of."""
        return self._path

    def phases(self, nbytes: float) -> list[Phase]:
        return [Phase.from_path(p) for p in self._path.phases]

    def rescale(self, n_workers: int) -> "Topology":
        """Same physical links, different membership (elastic resize).

        The base class knows only a fitted model, not the hardware
        constants behind it, so it falls back to the inversion route:
        invert the flat (a, b) through the Table-2 formula for
        ``algorithm`` to point-to-point (alpha, beta), then re-predict
        for the new membership (:func:`predicted_model`).  That
        inversion is only meaningful for a SINGLE-link topology — a
        multi-phase path's composed (a, b) mixes several links' constants
        and inverting it would silently collapse the path onto one link —
        so multi-phase base topologies still refuse (subclasses that know
        their per-level constants, like ``HierarchicalTopology``, rebuild
        exactly instead).
        """
        if n_workers == self.n_workers:
            return self
        if len(self._path.phases) > 1:
            raise NotImplementedError(
                f"cannot invert a {len(self._path.phases)}-phase path "
                f"over links {self._path.links} into single-link "
                f"constants; use a topology subclass that knows its "
                f"per-level hardware parameters")
        model = predicted_model(self.algorithm, self._model.a,
                                self._model.b, self.n_workers, n_workers)
        return Topology(model, self.link, n_workers, self.algorithm)


class FlatTopology(Topology):
    """One shared link running a Table-2 collective algorithm over N."""

    def __init__(self, algorithm: str, n_workers: int, alpha: float,
                 beta: float, gamma: float = 0.0, link: str = "net"):
        self.alpha, self.beta, self.gamma = alpha, beta, gamma
        model = cost_model.make_model(algorithm, n_workers, alpha, beta,
                                      gamma)
        super().__init__(model, link, n_workers, algorithm)

    def rescale(self, n_workers: int) -> "FlatTopology":
        return FlatTopology(self.algorithm, n_workers, self.alpha,
                            self.beta, self.gamma, self.link)

    @staticmethod
    def from_fitted(a: float, b: float, n_workers: int = 1,
                    link: str = "net",
                    algorithm: str = "ring") -> "Topology":
        """Topology from measured (a, b) — e.g. PAPER_CLUSTERS entries.

        ``algorithm`` names the collective the measurements came from; the
        base class uses it for inversion-based :meth:`Topology.rescale`.
        """
        return Topology(cost_model.AllReduceModel(a, b, "fitted"), link,
                        n_workers, algorithm)


class HierarchicalTopology(Topology):
    """Two-level ICI + DCN: reduce-scatter/all-gather intra-pod, all-reduce
    across pods on the 1/intra_size shard (reuses
    ``cost_model.HierarchicalModel`` so the planner sees the identical flat
    (a, b) the production mesh path produces)."""

    ICI_LINK = "ici"
    DCN_LINK = "dcn"

    def __init__(self, pods: int, chips_per_pod: int, *,
                 ici_bw: float = cost_model.ICI_BW_PER_LINK,
                 ici_alpha: float = cost_model.ICI_ALPHA,
                 dcn_bw: float = cost_model.DCN_BW,
                 dcn_alpha: float = cost_model.DCN_ALPHA,
                 ici_link: str | None = None,
                 dcn_link: str | None = None):
        if pods < 1 or chips_per_pod < 1:
            raise ValueError("need >= 1 pod and >= 1 chip per pod")
        self.pods, self.chips_per_pod = pods, chips_per_pod
        # instance link names shadow the class defaults so multi-job
        # fleets can give each job a PRIVATE ici link while sharing one
        # dcn uplink (scenarios.hierarchical_shared_jobs)
        self.ICI_LINK = ici_link if ici_link is not None \
            else type(self).ICI_LINK
        self.DCN_LINK = dcn_link if dcn_link is not None \
            else type(self).DCN_LINK
        self._params = dict(ici_bw=ici_bw, ici_alpha=ici_alpha,
                            dcn_bw=dcn_bw, dcn_alpha=dcn_alpha,
                            ici_link=ici_link, dcn_link=dcn_link)
        intra = (cost_model.tpu_ici_ring(chips_per_pod, bw_per_link=ici_bw,
                                         alpha=ici_alpha)
                 if chips_per_pod > 1
                 else cost_model.AllReduceModel(0.0, 0.0, "noop"))
        if pods > 1:
            inter = cost_model.tpu_dcn(pods, bw=dcn_bw, alpha=dcn_alpha)
            self._hier = cost_model.HierarchicalModel(
                intra=intra, inter=inter, intra_size=chips_per_pod)
            path = self._hier.path(self.ICI_LINK, self.DCN_LINK)
        else:
            self._hier = None
            path = cost_model.single_path(
                cost_model.AllReduceModel(intra.a, intra.b,
                                          "tpu_ici_ring"), self.ICI_LINK)
        super().__init__(path, self.ICI_LINK, pods * chips_per_pod)

    def rescale(self, n_workers: int) -> "HierarchicalTopology":
        """Resize by pod count; chips per pod are fixed hardware."""
        if n_workers % self.chips_per_pod:
            raise ValueError(
                f"{n_workers} workers not divisible by pod size "
                f"{self.chips_per_pod}")
        return HierarchicalTopology(n_workers // self.chips_per_pod,
                                    self.chips_per_pod, **self._params)


# Reserved flow-owner name for background (Burst) claimants in the link
# accounting.  Job names can never collide with it (JobSpec names are
# user-visible identifiers; this one is deliberately non-identifier-like),
# so per-job link telemetry — and therefore every (a, b) refit sample the
# co-planner consumes — structurally excludes burst traffic.
BACKGROUND_OWNER = "<background>"


@dataclasses.dataclass(frozen=True)
class Burst:
    """Background traffic: ``flows`` extra processor-sharing claimants on
    ``link`` during [start, end) — a bursty neighbour job, a checkpoint
    write storm, an incast.  In the link accounting its bandwidth share is
    attributed to :data:`BACKGROUND_OWNER`, never to a job."""

    link: str
    start: float
    end: float
    flows: int = 1

    def __post_init__(self):
        if self.end <= self.start or self.flows < 1:
            raise ValueError(f"malformed burst: {self}")


def invert_ring(a: float, b: float, n: int,
                gamma_ratio: float = 0.0) -> tuple[float, float]:
    """Recover point-to-point (alpha, beta) from a fitted ring (a, b).

    Ring: a = 2(N-1)alpha, b = (2(N-1)/N)beta + ((N-1)/N)gamma; with
    gamma = gamma_ratio * beta.  This is the paper's Fig. 4 fit turned
    inside out — the elastic-replanning loop fits (a, b) online from
    simulated bucket timings at size N, inverts to hardware constants, and
    re-predicts (a', b') for the post-resize N'.
    """
    if n < 2:
        raise ValueError("ring inversion needs N >= 2")
    alpha = a / (2 * (n - 1))
    denom = (2 * (n - 1) / n) + (n - 1) / n * gamma_ratio
    beta = b / denom
    return alpha, beta


def invert_double_binary_trees(a: float, b: float, n: int,
                               gamma_ratio: float = 0.0
                               ) -> tuple[float, float]:
    """Invert the Table-2 double-binary-trees model (NCCL >= 2.4 default).

    a = 2*alpha*log2(N), b = beta + gamma, gamma = gamma_ratio * beta.
    """
    if n < 2:
        raise ValueError("double-binary-trees inversion needs N >= 2")
    alpha = a / (2 * math.log2(n))
    beta = b / (1.0 + gamma_ratio)
    return alpha, beta


def invert_halving_doubling(a: float, b: float, n: int,
                            gamma_ratio: float = 0.0) -> tuple[float, float]:
    """Invert the Table-2 recursive-halving-doubling model.

    a = 2*alpha*log2(N); b = 2*beta - (2*beta + gamma)/N + gamma collapses,
    with gamma = gamma_ratio * beta, to beta * (2 + r) * (N-1)/N.
    """
    if n < 2:
        raise ValueError("halving-doubling inversion needs N >= 2")
    alpha = a / (2 * math.log2(n))
    beta = b * n / ((2.0 + gamma_ratio) * (n - 1))
    return alpha, beta


INVERSIONS = {
    "ring": invert_ring,
    "double_binary_trees": invert_double_binary_trees,
    "recursive_halving_doubling": invert_halving_doubling,
}


def invert_model(algorithm: str, a: float, b: float, n: int,
                 gamma_ratio: float = 0.0) -> tuple[float, float]:
    """Recover (alpha, beta) from a fitted (a, b) for any invertible
    collective algorithm (the online-refit leg of the elastic loop)."""
    try:
        fn = INVERSIONS[algorithm]
    except KeyError:
        raise ValueError(
            f"no (a, b) inversion for algorithm {algorithm!r}; "
            f"choose from {sorted(INVERSIONS)}") from None
    return fn(a, b, n, gamma_ratio)


def predicted_model(algorithm: str, a: float, b: float, n_old: int,
                    n_new: int,
                    gamma_ratio: float = 0.0) -> cost_model.AllReduceModel:
    """Project a fitted (a, b) from N_old membership to N_new by inverting
    to point-to-point constants and re-applying the Table-2 formula."""
    alpha, beta = invert_model(algorithm, a, b, n_old, gamma_ratio)
    return cost_model.make_model(algorithm, n_new, alpha, beta,
                                 gamma_ratio * beta)


def predicted_ring(a: float, b: float, n_old: int, n_new: int,
                   gamma_ratio: float = 0.0) -> cost_model.AllReduceModel:
    """Project a fitted ring model from N_old membership to N_new."""
    return predicted_model("ring", a, b, n_old, n_new, gamma_ratio)


def topology_for_cluster(name: str, n_workers: int) -> Topology:
    """Paper-cluster topology from the measured PAPER_CLUSTERS constants."""
    try:
        a, b = cost_model.PAPER_CLUSTERS[name]
    except KeyError:
        raise ValueError(
            f"unknown paper cluster {name!r}; choose from "
            f"{sorted(cost_model.PAPER_CLUSTERS)}") from None
    return FlatTopology.from_fitted(a, b, n_workers)
