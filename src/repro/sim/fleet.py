"""Fleet-scale vectorized simulation backend: one jitted call per grid.

The closed forms in ``core/simulator`` and ``sim/schedules`` are exact on
their validity domain but were evaluated one (job, N, seed, bandwidth)
point at a time in Python.  This module turns them into data-parallel
kernels: every scenario becomes a **case** — a padded-and-masked bucket
column plus a handful of scalars — and a whole batch of cases (a scaling
grid, a placement-search scoring pass, a CoPlanner round's candidate
assignments) is evaluated by ONE jitted jax.numpy kernel:

* axes: bucket arrays are ``[K, C]`` with the scan (bucket) axis
  **leading** — XLA then fuses each recurrence step into one elementwise
  op over contiguous ``[C, S, I]`` blocks, which is where the >=10x win
  over the per-point Python loop comes from; jitter scales are
  ``[C, S, I]`` (case × seed × iteration) fleet-max values computed on
  the host (``WorkerProfile.scale`` is seeded per (seed, job, worker,
  iteration) — irreproducible with device RNG, and shared by every
  backend anyway);
* padding: ``K`` is the batch-max bucket count rounded up to a power of
  two (stable jit cache across nearby plans); masked steps are bitwise
  no-ops, and a *masked-off* row is distinct from a *real zero-byte
  bucket* (mask on, duration zero — its ready time still gates the
  recurrence, exactly like ``AllReduceModel.time(0) == 0``);
* schedules: the kernel computes all three closed-form shapes —
  barrier (BSP / OneFoneB tail compression), the DeAR pipelined
  cross-iteration recurrence, LocalSGD rounds — and selects per case by
  ``FleetForm.kind``, so heterogeneous batches (a mixed-schedule fleet)
  still take one device call;
* precision: everything runs under ``jax.experimental.enable_x64`` so
  the recurrence arithmetic is float64 like the numpy fast path; the
  scan recurrence itself is operation-for-operation the numpy one
  (agreement to well under 1e-9 — only sum *reductions* may
  re-associate, at ~1 ulp);
* models: any cost model goes through ``cost_model.as_linear`` — a
  hierarchical ``PathModel``'s per-link phases flatten to the one (a, b)
  the closed forms consume (a sum of affine phases is affine), so
  hierarchical ICI+DCN topologies ride the same kernel.

Validity is the sweep's ``closed_form_valid`` domain: single job on its
link, sequential issue, no bursts; heterogeneity/jitter only for
schedules whose :class:`~repro.sim.schedules.FleetForm` says
``heterogeneous_ok``.  ``run_sweep(backend="fleet")`` dispatches here;
the numpy path stays as the portable fallback and the event engine as
the oracle (``tests/test_fleet*.py`` pin all three together at 1e-9).

:class:`FleetEvaluator` is the co-planner face: it scores every
candidate assignment of a round in one device call, each job under its
OWN cost model (no cross-job contention — use the engine-backed
evaluator when contention is the question; this one is for fleet-scale
seed scoring and placement search, where the model already embeds the
contention via refit).

**Planning is batched too** (:func:`plan_cases` / :func:`plan_batched`):
the optimal-bucketing DP of ``core.planner.plan_dp_optimal`` runs as a
jitted ``lax.scan`` over layers with a leading case axis, so a whole
batch of (spec prefix-sums, flattened (a, b) model) planning problems —
a placement search, a co-plan round's responses, a what-if query burst —
is planned in ONE device call.  The recurrence is the O(L²)-masked
batched form (each scan step reduces over all L candidate split points),
which loses to the O(L) incremental ``Planner`` per point but wins on
throughput from a few dozen cases up (see docs/planner.md "Batched
planning" for the measured crossover); ``plan_dp_optimal`` and
``Planner`` stay the per-point oracles.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Mapping, MutableMapping, Sequence

import numpy as np

from repro.core import cost_model
from repro.core.coplanner import CoJob, CoObservation, JobObservation
from repro.core.cost_model import as_linear
from repro.core.planner import MergePlan, TensorSpec
from repro.core.simulator import bucket_arrays, spec_arrays
from repro.obs.metrics import REGISTRY
from repro.sim.schedules import FleetForm, Schedule

_KIND = {"barrier": 0, "pipelined": 1, "localsgd": 2}
_BARRIER, _PIPELINED, _LOCALSGD = 0, 1, 2

# the DP's improvement hysteresis — must match plan_dp_optimal's, so a
# candidate that is smaller only by accumulated-rounding dust does not
# steal the parent slot from an earlier (bigger-merge) candidate
_DP_EPS = 1e-15


def _kernel_call(kernel: str) -> None:
    REGISTRY.counter(
        "fleet_kernel_calls_total",
        "jitted fleet-kernel invocations, by kernel "
        "(evaluate = evaluate_cases, plan = plan_cases)").inc(kernel=kernel)


def fleet_available() -> bool:
    """True iff jax is importable (the kernel compiles lazily)."""
    try:
        import jax  # noqa: F401
    except Exception:  # pragma: no cover - environment-dependent
        return False
    return True


def profile_fingerprint(prefix_bytes: np.ndarray,
                        prefix_t: np.ndarray) -> str:
    """Content hash of one tensor profile's prefix arrays.

    This is the cache-scoping half of the geometry memo key: two
    profiles with identical bytes/ready structure may safely share
    geometry, two that differ never collide — so one cache can span
    jobs and grids (the old ``plan.buckets``-only key silently returned
    the wrong geometry if a caller reused a cache across profiles)."""
    h = hashlib.blake2b(digest_size=16)
    pb = np.ascontiguousarray(prefix_bytes, dtype=np.float64)
    pt = np.ascontiguousarray(prefix_t, dtype=np.float64)
    h.update(len(pb).to_bytes(8, "little"))
    h.update(pb.tobytes())
    h.update(pt.tobytes())
    return h.hexdigest()


class GeomCache(MutableMapping):
    """LRU-bounded geometry memo for :func:`make_case`.

    Keys are ``(profile_fingerprint, plan.buckets)`` so one instance can
    safely span tensor profiles (jobs, grids, snapshots).  Hits and
    evictions surface as ``fleet_geom_cache_hits_total`` /
    ``fleet_geom_cache_evictions_total``."""

    def __init__(self, maxsize: int = 4096):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = int(maxsize)
        self._d: "dict" = {}

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def __getitem__(self, key):
        val = self._d.pop(key)         # KeyError propagates on miss
        self._d[key] = val             # re-insert = move to MRU end
        REGISTRY.counter(
            "fleet_geom_cache_hits_total",
            "make_case geometry-memo hits").inc()
        return val

    def __setitem__(self, key, val):
        self._d.pop(key, None)
        self._d[key] = val
        while len(self._d) > self.maxsize:
            self._d.pop(next(iter(self._d)))
            REGISTRY.counter(
                "fleet_geom_cache_evictions_total",
                "make_case geometry-memo LRU evictions").inc()

    def __delitem__(self, key):
        del self._d[key]

    def __iter__(self):
        return iter(self._d)

    def __len__(self):
        return len(self._d)


@dataclasses.dataclass(frozen=True, eq=False)
class FleetCase:
    """One scenario column of the batch: a (specs, plan, model, schedule,
    scales) point, reduced to the arrays the kernel consumes."""

    bucket_bytes: np.ndarray        # [K_c] float64, per-bucket bytes
    ready_off: np.ndarray           # [K_c] nominal ready offsets (s)
    t_f: float                      # forward compute (s)
    t_b_total: float                # total backward compute (s)
    a: float                        # flat startup term (s)
    b: float                        # flat per-byte term (s/B)
    kind: int = _BARRIER            # _KIND[FleetForm.kind]
    micro_batches: int = 1          # barrier: OneFoneB tail compression
    ag_fraction: float = 0.0        # pipelined: deferred share
    h: int = 1                      # localsgd: steps per round
    s_max: np.ndarray | None = None  # [S, I] fleet-max scales (None = 1.0)


@dataclasses.dataclass(frozen=True, eq=False)
class FleetResult:
    """Kernel output: per-iteration times and total span per case."""

    t_iter: np.ndarray              # [C, S, iters] seconds
    span: np.ndarray                # [C, S] run wall time


def make_case(specs: Sequence[TensorSpec], plan: MergePlan, model, *,
              schedule: Schedule | None = None, t_f: float = 0.0,
              s_max: np.ndarray | None = None,
              prefix_bytes: np.ndarray | None = None,
              prefix_t: np.ndarray | None = None,
              cache: MutableMapping | None = None,
              profile_key: str | None = None) -> FleetCase:
    """Reduce one scenario to a :class:`FleetCase`.

    ``prefix_bytes`` / ``prefix_t`` (``core.simulator.spec_arrays``) can
    be passed in when many cases share one profile — the sweep computes
    them once per grid.  ``s_max`` is the fleet-max compute scale per
    (seed, iteration); rejected when the schedule's closed form is
    homogeneous-only (``FleetForm.heterogeneous_ok``).

    ``cache`` memoizes the per-plan bucket geometry keyed on
    ``(profile fingerprint, plan.buckets)`` — a grid re-scoring the same
    few plan structures under many models (every WFBP/single sweep, most
    DP sweeps) pays the O(num_buckets) Python walk once instead of per
    point.  One cache may safely span tensor profiles (use
    :class:`GeomCache` for an LRU-bounded one with hit/eviction
    counters); ``profile_key`` short-circuits the fingerprint hash when
    the caller already computed :func:`profile_fingerprint` for this
    profile — hot loops should.
    """
    form = schedule.fleet_form() if schedule is not None \
        else FleetForm(kind="barrier")
    if form is None:
        raise ValueError(
            f"schedule {schedule!r} has no fleet form — engine only")
    geom = None
    if cache is not None:
        if profile_key is None:
            if prefix_bytes is None or prefix_t is None:
                prefix_bytes, prefix_t = spec_arrays(specs)
            profile_key = profile_fingerprint(prefix_bytes, prefix_t)
        geom = cache.get((profile_key, plan.buckets))
    if geom is None:
        if plan.num_tensors != len(specs):
            raise ValueError(
                f"plan covers {plan.num_tensors} tensors, "
                f"specs has {len(specs)}")
        if prefix_bytes is None or prefix_t is None:
            prefix_bytes, prefix_t = spec_arrays(specs)
        geom = bucket_arrays(prefix_bytes, prefix_t, plan)
        if cache is not None:
            cache[(profile_key, plan.buckets)] = geom
    elif prefix_t is None:
        _, prefix_t = spec_arrays(specs)
    bucket_bytes, ready_off = geom
    sm = None
    if s_max is not None:
        sm = np.asarray(s_max, dtype=np.float64)
        if sm.ndim != 2:
            raise ValueError(
                f"s_max must be (seeds, iters)-shaped, got {sm.shape}")
        if not form.heterogeneous_ok and np.any(sm != 1.0):
            raise ValueError(
                f"{schedule.label} closed form is homogeneous-only; "
                "heterogeneous fleets need the event engine")
    lin = as_linear(model)
    return FleetCase(
        bucket_bytes=bucket_bytes, ready_off=ready_off, t_f=float(t_f),
        t_b_total=float(prefix_t[-1]) if len(prefix_t) else 0.0,
        a=float(lin.a), b=float(lin.b), kind=_KIND[form.kind],
        micro_batches=form.micro_batches, ag_fraction=form.ag_fraction,
        h=form.h, s_max=sm)


# ---------------------------------------------------------------------------
# The kernel (built lazily so importing this module never needs jax).
# ---------------------------------------------------------------------------

_KERNEL = None


def _get_kernel():
    global _KERNEL
    if _KERNEL is not None:
        return _KERNEL
    import jax
    import jax.numpy as jnp
    from jax import lax

    def kernel(bucket_bytes, ready_off, mask, a, b, t_f, t_b,
               m, ag_f, h, kind, s_max, has_pipelined, has_localsgd):
        # bucket arrays [K, C] (scan axis leading), scalars [C],
        # s_max [C, S, I].  All float64 under enable_x64.
        # has_pipelined / has_localsgd are STATIC: a barrier-only batch
        # (every pure scaling grid) compiles without the pipelined
        # cross-iteration scan — iters x K extra steps it never reads.
        iters = s_max.shape[2]
        dur = a[None, :] + b[None, :] * bucket_bytes
        # real zero-byte buckets cost 0 (AllReduceModel.time semantics)
        # but their mask stays on: the ready-time max still applies
        bt = jnp.where(mask & (bucket_bytes > 0.0), dur, 0.0)

        # -- barrier: Eq. 7/8 with ready times in the last micro-batch's
        #    1/m tail, scaled by the fleet-max compute scale ------------
        pair = (t_f + t_b) / m
        base = (m - 1.0) * pair + t_f / m
        nominal = base[None, :] + ready_off / m[None, :]    # [K, C]
        nominal_bwd = base + t_b / m                        # [C]

        def barrier_step(end, xs):
            bt_k, nom_k, mk = xs
            upd = jnp.maximum(end, nom_k[:, None, None] * s_max) \
                + bt_k[:, None, None]
            return jnp.where(mk[:, None, None], upd, end), None

        end, _ = lax.scan(barrier_step, jnp.zeros_like(s_max),
                          (bt, nominal, mask))
        barrier_t = jnp.maximum(end, nominal_bwd[:, None, None] * s_max)

        t_iter = barrier_t

        # -- localsgd: h-1 free steps per round, barrier on sync steps --
        # (localsgd cases carry m == 1 and s_max == 1, so barrier_t IS
        # the BSP sync time; truncated final rounds sync at iters-1)
        if has_localsgd:
            i_idx = jnp.arange(iters)
            is_sync = (((i_idx[None, :] + 1) % h[:, None]) == 0) \
                | (i_idx[None, :] == iters - 1)             # [C, I]
            local_t = (t_f + t_b)[:, None, None]
            localsgd_t = jnp.where(is_sync[:, None, :], barrier_t,
                                   jnp.broadcast_to(local_t,
                                                    barrier_t.shape))
            t_iter = jnp.where(kind[:, None, None] == _LOCALSGD,
                               localsgd_t, t_iter)

        # -- pipelined: DeAR cross-iteration recurrence (homogeneous) ---
        if has_pipelined:
            has = mask.any(axis=0)                          # [C]
            ag_total = ag_f * bt.sum(axis=0)                # [C]

            def pipe_iter(carry, _):
                S_, ag_done = carry                         # [C] each
                fwd_end = S_ + t_f
                bwd_start = jnp.maximum(fwd_end, ag_done)
                bwd_end = bwd_start + t_b

                def rs_step(end, xs):
                    bt_k, ro_k, mk = xs
                    upd = jnp.maximum(end, bwd_start + ro_k) \
                        + (1.0 - ag_f) * bt_k
                    return jnp.where(mk, upd, end), None

                rs_end, _ = lax.scan(rs_step, jnp.zeros_like(S_),
                                     (bt, ready_off, mask))
                rs_done = jnp.where(has, rs_end, bwd_end)
                ag_done_n = jnp.where(has, rs_done + ag_total, bwd_end)
                iter_end = jnp.maximum(ag_done_n, bwd_end)
                s_next = jnp.maximum(bwd_end, rs_done)
                return (s_next, ag_done_n), (iter_end - S_, iter_end)

            zero_c = jnp.zeros_like(a)
            _, (pipe_t, pipe_end) = lax.scan(pipe_iter, (zero_c, zero_c),
                                             None, length=iters)
            pipe_tb = jnp.broadcast_to(pipe_t.T[:, None, :],
                                       barrier_t.shape)
            t_iter = jnp.where(kind[:, None, None] == _PIPELINED,
                               pipe_tb, t_iter)
            # barrier/localsgd iterations abut (span = sum); pipelined
            # iterations overlap — span is the recurrence's absolute end
            span = jnp.where(kind[:, None] == _PIPELINED,
                             pipe_end[-1][:, None], t_iter.sum(axis=-1))
        else:
            span = t_iter.sum(axis=-1)
        return t_iter, span

    _KERNEL = jax.jit(kernel, static_argnums=(12, 13))
    return _KERNEL


def evaluate_cases(cases: Sequence[FleetCase],
                   iters: int = 1) -> FleetResult:
    """Evaluate a whole batch of cases in one jitted device call.

    Cases may mix schedules, models and bucket counts; bucket axes are
    padded to the batch max (next power of two, for jit-cache stability)
    and masked.  The case axis is padded the same way — fully-masked
    benign columns, sliced off the result — so batch sizes that differ
    only within a power-of-two bracket reuse one compiled kernel (a
    CoPlanner round whose candidate count drifts as the cache fills
    would otherwise recompile every round).  All cases carrying an
    ``s_max`` must agree on the seed count; cases without one broadcast
    a scale of 1.0.
    """
    if not cases:
        raise ValueError("need >= 1 case")
    if iters < 1:
        raise ValueError("need >= 1 iteration")
    if not fleet_available():
        raise RuntimeError(
            "fleet backend needs jax; use run_sweep(backend='numpy')")
    C = len(cases)
    S = 1
    for c in cases:
        if c.s_max is not None:
            if c.s_max.shape[1] != iters:
                raise ValueError(
                    f"s_max covers {c.s_max.shape[1]} iterations, "
                    f"sweep runs {iters}")
            if S == 1:
                S = c.s_max.shape[0]
            elif c.s_max.shape[0] not in (1, S):
                raise ValueError(
                    f"inconsistent seed counts across cases: "
                    f"{c.s_max.shape[0]} vs {S}")
    k_max = max((len(c.bucket_bytes) for c in cases), default=0)
    k_pad = 1 << (max(k_max, 1) - 1).bit_length()
    c_pad = 1 << (C - 1).bit_length()

    bb = np.zeros((k_pad, c_pad), dtype=np.float64)
    ro = np.zeros((k_pad, c_pad), dtype=np.float64)
    mk = np.zeros((k_pad, c_pad), dtype=bool)
    # padding columns are benign barrier cases: m = h = 1, all else 0
    scal = {n: np.zeros(c_pad, dtype=np.float64)
            for n in ("a", "b", "t_f", "t_b", "ag_f")}
    scal["m"] = np.ones(c_pad, dtype=np.float64)
    h = np.ones(c_pad, dtype=np.int32)
    kind = np.zeros(c_pad, dtype=np.int32)
    sm = np.ones((c_pad, S, iters), dtype=np.float64)
    for ci, c in enumerate(cases):
        nk = len(c.bucket_bytes)
        bb[:nk, ci] = c.bucket_bytes
        ro[:nk, ci] = c.ready_off
        mk[:nk, ci] = True
        scal["a"][ci] = c.a
        scal["b"][ci] = c.b
        scal["t_f"][ci] = c.t_f
        scal["t_b"][ci] = c.t_b_total
        scal["m"][ci] = c.micro_batches
        scal["ag_f"][ci] = c.ag_fraction
        h[ci] = c.h
        kind[ci] = c.kind
        if c.s_max is not None:
            sm[ci] = c.s_max
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    kern = _get_kernel()
    _kernel_call("evaluate")
    with enable_x64():
        t_iter, span = kern(
            jnp.asarray(bb), jnp.asarray(ro), jnp.asarray(mk),
            jnp.asarray(scal["a"]), jnp.asarray(scal["b"]),
            jnp.asarray(scal["t_f"]), jnp.asarray(scal["t_b"]),
            jnp.asarray(scal["m"]), jnp.asarray(scal["ag_f"]),
            jnp.asarray(h), jnp.asarray(kind), jnp.asarray(sm),
            bool((kind == _PIPELINED).any()),
            bool((kind == _LOCALSGD).any()))
        return FleetResult(t_iter=np.asarray(t_iter)[:C],
                           span=np.asarray(span)[:C])


# ---------------------------------------------------------------------------
# Batched planning: the optimal-bucketing DP with a leading case axis.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class PlanCase:
    """One planning problem of the batch: a (spec prefix-sums, flat
    (a, b) model) pair, reduced to the arrays the plan kernel consumes."""

    pre: np.ndarray                 # [L+1] float64 prefix bytes (exact)
    ready: np.ndarray               # [L] gradient-ready times (s)
    a: float                        # flat startup term (s)
    b: float                        # flat per-byte term (s/B)

    @property
    def num_tensors(self) -> int:
        return len(self.ready)


def make_plan_case(specs: Sequence[TensorSpec], model, *,
                   prefix_bytes: np.ndarray | None = None,
                   prefix_t: np.ndarray | None = None) -> PlanCase:
    """Reduce one planning problem to a :class:`PlanCase`.

    Any cost model goes through :func:`~repro.core.cost_model.as_linear`
    (a ``PathModel`` flattens to the (a, b) the DP consumes, exactly like
    ``plan_dp_optimal``).  ``prefix_bytes`` / ``prefix_t`` from
    ``core.simulator.spec_arrays`` can be passed when many cases share
    one tensor profile.
    """
    if prefix_bytes is None or prefix_t is None:
        prefix_bytes, prefix_t = spec_arrays(specs)
    lin = as_linear(model)
    return PlanCase(pre=np.asarray(prefix_bytes, dtype=np.float64),
                    ready=np.asarray(prefix_t, dtype=np.float64),
                    a=float(lin.a), b=float(lin.b))


_PLAN_KERNEL = None


def _get_plan_kernel():
    global _PLAN_KERNEL
    if _PLAN_KERNEL is not None:
        return _PLAN_KERNEL
    import jax
    import jax.numpy as jnp
    from jax import lax

    def kernel(pre, ready, a, b):
        # pre [Lp+1, C], ready [Lp, C], a/b [C].  One scan step per layer
        # i; each step reduces over every candidate split point m <= i —
        # the O(L^2) recurrence of plan_dp_optimal, all cases at once:
        #
        #   cand[m] = max(F[m], ready[i]) + T(pre[i+1] - pre[m])
        #   f[i]    = "first candidate within _DP_EPS of the minimum"
        #
        # The winner rule reproduces the host DP's incumbent hysteresis
        # (`cand < f[i] - 1e-15` keeps the earlier, bigger-merge parent):
        # mathematically-tied candidates that round differently — the
        # only near-ties real profiles produce — land inside the window
        # together, and the earliest index wins on host and device alike.
        # That also absorbs XLA's fma contraction of a + b*d (~1 ulp vs
        # the host's separate mul/add), so bucket structure is bit-equal
        # to plan_dp_optimal even though f may differ in the last ulp.
        Lp = ready.shape[0]
        m_idx = jnp.arange(Lp + 1)[:, None]                 # [Lp+1, 1]

        def step(F, xs):
            r_i, p_i1, i = xs                               # [C], [C], []
            d = p_i1[None, :] - pre                         # [Lp+1, C]
            t = jnp.where(d > 0.0, a[None, :] + b[None, :] * d, 0.0)
            cand = jnp.maximum(F, r_i[None, :]) + t
            cand = jnp.where(m_idx <= i, cand, jnp.inf)
            cmin = cand.min(axis=0)                         # [C]
            win = jnp.argmax(cand < (cmin + _DP_EPS)[None, :], axis=0)
            f_i = jnp.take_along_axis(cand, win[None, :], axis=0)[0]
            F = lax.dynamic_update_index_in_dim(F, f_i, i + 1, 0)
            return F, (f_i, win.astype(jnp.int32))

        F0 = jnp.zeros_like(pre)                            # F[m] = f[m-1]
        _, (f, win) = lax.scan(step, F0,
                               (ready, pre[1:], jnp.arange(Lp)))
        return f, win

    _PLAN_KERNEL = jax.jit(kernel)
    return _PLAN_KERNEL


def _plan_recurrence_numpy(pre: np.ndarray, ready: np.ndarray,
                           a: np.ndarray, b: np.ndarray):
    """Portable fallback: the same recurrence, numpy per layer.

    No fma contraction here, so f is bit-identical to the host oracle's
    arithmetic; the winner rule is the same first-within-eps window."""
    Lp = ready.shape[0]
    C = a.shape[0]
    F = np.zeros((Lp + 1, C), dtype=np.float64)
    f = np.zeros((Lp, C), dtype=np.float64)
    win = np.zeros((Lp, C), dtype=np.int32)
    m_idx = np.arange(Lp + 1)[:, None]
    for i in range(Lp):
        d = pre[i + 1][None, :] - pre
        t = np.where(d > 0.0, a[None, :] + b[None, :] * d, 0.0)
        cand = np.maximum(F, ready[i][None, :]) + t
        cand = np.where(m_idx <= i, cand, np.inf)
        cmin = cand.min(axis=0)
        w = np.argmax(cand < (cmin + _DP_EPS)[None, :], axis=0)
        f[i] = cand[w, np.arange(C)]
        win[i] = w
        F[i + 1] = f[i]
    return f, win


def plan_cases(cases: Sequence[PlanCase], *,
               backend: str = "auto") -> list[MergePlan]:
    """Plan a whole batch of problems in one device call.

    Returns one ``MergePlan`` (strategy ``"dp_batched"``) per case,
    bucket-for-bucket equal to ``plan_dp_optimal`` on each.  ``L`` and
    ``C`` are padded to powers of two like :func:`evaluate_cases`
    (masked candidate rows and benign padding columns, sliced off), so
    nearby batch shapes reuse one compiled kernel.  ``backend`` is
    ``"auto"`` (jax when importable), ``"fleet"`` (require jax) or
    ``"numpy"`` (portable fallback, same recurrence per layer — the
    right choice for a handful of cases; the device call wins from a
    few dozen cases up, see docs/planner.md for the crossover).
    """
    if backend not in ("auto", "fleet", "numpy"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "auto":
        backend = "fleet" if fleet_available() else "numpy"
    elif backend == "fleet" and not fleet_available():
        raise RuntimeError(
            "fleet backend needs jax; use plan_cases(backend='numpy')")
    cases = list(cases)
    REGISTRY.counter(
        "fleet_plan_cases_total",
        "planning problems solved by the batched DP, by backend").inc(
            len(cases), backend=backend)
    live = [(ci, c) for ci, c in enumerate(cases) if c.num_tensors > 0]
    out: list[MergePlan | None] = [
        None if c.num_tensors else MergePlan((), "dp_batched")
        for c in cases]
    if not live:
        return [p for p in out if p is not None] if cases else []
    l_max = max(c.num_tensors for _, c in live)
    C = len(live)
    if backend == "fleet":
        l_pad = 1 << (l_max - 1).bit_length()
        c_pad = 1 << (C - 1).bit_length()
    else:
        l_pad, c_pad = l_max, C
    pre = np.zeros((l_pad + 1, c_pad), dtype=np.float64)
    ready = np.zeros((l_pad, c_pad), dtype=np.float64)
    ab = np.zeros((2, c_pad), dtype=np.float64)
    for k, (_, c) in enumerate(live):
        n = c.num_tensors
        pre[:n + 1, k] = c.pre
        pre[n + 1:, k] = c.pre[-1]      # padded layers add zero bytes
        ready[:n, k] = c.ready
        ab[0, k], ab[1, k] = c.a, c.b
    if backend == "fleet":
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        kern = _get_plan_kernel()
        _kernel_call("plan")
        with enable_x64():
            _, win = kern(jnp.asarray(pre), jnp.asarray(ready),
                          jnp.asarray(ab[0]), jnp.asarray(ab[1]))
        win = np.asarray(win)
    else:
        _, win = _plan_recurrence_numpy(pre, ready, ab[0], ab[1])
    # host-side chain reconstruction: parent[i] = win[i] - 1, NEG = -1
    for k, (ci, c) in enumerate(live):
        last, i = [], c.num_tensors - 1
        while i != -1:
            last.append(i)
            i = int(win[i, k]) - 1
        out[ci] = MergePlan.from_boundaries(c.num_tensors, sorted(last),
                                            "dp_batched")
    return out  # type: ignore[return-value]


def plan_batched(problems: Sequence[tuple[Sequence[TensorSpec], object]],
                 *, backend: str = "auto") -> list[MergePlan]:
    """Convenience face over :func:`plan_cases`: a list of
    (specs, model) pairs in, one optimal ``MergePlan`` each out, all
    planned in one device call."""
    return plan_cases([make_plan_case(s, m) for s, m in problems],
                      backend=backend)


# ---------------------------------------------------------------------------
# Co-planner face: score a whole round of assignments in one call.
# ---------------------------------------------------------------------------

class FleetEvaluator:
    """Batched ``CoEvaluate``: one device call per *round* of candidate
    assignments instead of one Python simulation per assignment.

    Each job is scored under its own cost model on its schedule's closed
    form — no cross-job link contention is modelled, which is exactly the
    seed-scoring / placement-search regime (the engine-backed evaluator
    stays the oracle when contention itself is the question; a refit
    contended model slots in transparently since only ``job.model`` is
    read).  ``CoPlanner`` discovers :meth:`batch` via ``getattr`` and
    routes every round's uncached candidates through it.

    Observed iteration time is ``span / iters`` (for barrier schedules,
    exactly the closed form; for pipelined, the average realized window
    including warmup — raise ``iters`` to sharpen the steady state).
    Samples are the exact per-bucket (nbytes, model time) pairs the
    closed form charged, with per-link decomposition for ``PathModel``
    jobs, so a downstream refit reproduces the scoring model.
    """

    def __init__(self, jobs: Sequence[CoJob], *, iters: int = 8):
        if iters < 1:
            raise ValueError("need >= 1 iteration")
        self.jobs = tuple(jobs)
        self.iters = int(iters)
        self._static = {}
        # one profile-fingerprint-keyed LRU spans every job safely
        self._geom = GeomCache()
        for j in self.jobs:
            pb, pt = spec_arrays(j.specs)
            self._static[j.name] = (pb, pt, as_linear(j.model),
                                    profile_fingerprint(pb, pt))
        self._sample_cache: dict = {}

    def _job_samples(self, job: CoJob, plan: MergePlan):
        key = (job.name, plan.buckets)
        cached = self._sample_cache.get(key)
        if cached is None:
            pb, pt, lin, fp = self._static[job.name]
            geom = self._geom._d.get((fp, plan.buckets))
            nbytes = geom[0] if geom is not None \
                else bucket_arrays(pb, pt, plan)
            samples = tuple((int(n), lin.time(n)) for n in nbytes)
            links: tuple = ()
            if isinstance(job.model, cost_model.PathModel):
                per: dict[str, list] = {l: [] for l in job.model.links}
                for n in nbytes:
                    for p in job.model.phases:
                        per[p.link].append((int(n), p.time(n)))
                links = tuple((l, tuple(v)) for l, v in per.items())
            cached = (samples, links)
            self._sample_cache[key] = cached
        return cached

    def batch(self, assignments: Sequence[Mapping[str, MergePlan]]
              ) -> list[CoObservation]:
        cases = []
        for a in assignments:
            for j in self.jobs:
                pb, pt, _, fp = self._static[j.name]
                cases.append(make_case(
                    j.specs, a[j.name], j.model, schedule=j.schedule,
                    t_f=j.t_f, prefix_bytes=pb, prefix_t=pt,
                    cache=self._geom, profile_key=fp))
        res = evaluate_cases(cases, iters=self.iters)
        out: list[CoObservation] = []
        nj = len(self.jobs)
        for ai, a in enumerate(assignments):
            jobs_obs: dict[str, JobObservation] = {}
            makespan = 0.0
            for ji, j in enumerate(self.jobs):
                sp = float(res.span[ai * nj + ji, 0])
                makespan = max(makespan, sp)
                samples, link_samples = self._job_samples(j, a[j.name])
                jobs_obs[j.name] = JobObservation(
                    t_iter=sp / self.iters, samples=samples,
                    link_samples=link_samples)
            out.append(CoObservation(makespan=makespan, jobs=jobs_obs))
        return out

    def __call__(self, plans: Mapping[str, MergePlan]) -> CoObservation:
        return self.batch([plans])[0]
