"""Scenario catalog for the cluster simulator.

Each builder assembles a ready-to-run :class:`ClusterSim`:

* ``paper_scaling``    — the §7 trace-driven study (homogeneous workers,
  Table-2 collective over the paper's fitted cluster constants);
* ``straggler``        — one (or more) persistently slow workers, the
  sweep the closed form cannot express;
* ``straggler_eviction`` — the mitigation loop: a ``StragglerMonitor``
  watches per-worker step times and the scenario hook evicts flagged
  workers mid-run (membership change -> topology rescale -> replan);
* ``elastic_resize``   — mid-run membership change with ONLINE (a, b)
  refit from observed bucket timings -> replan (the loop from
  ``examples/elastic_replan.py``, now closed inside the simulator; any
  invertible collective algorithm, optionally contention-aware);
* ``bursty``           — background traffic bursts contending on the link;
* ``shared_link_jobs`` — N independent training jobs sharing one network,
  each a :class:`CoJobSpec` with its own profile, schedule and strategy
  (``two_jobs`` is the N=2 wrapper);
* ``contended_jobs_plan`` — **joint** contention-aware planning: all N
  jobs replan together through ``repro.core.coplanner.CoPlanner``
  (simulate together -> per-job effective (a, b) refit from link-owner
  telemetry -> per-schedule replan -> best observed assignment by joint
  makespan);
* ``contended_two_jobs_plan`` — the PR-2 one-sided fixpoint
  (``planner.plan_contention_aware``): optimize ONE job against a frozen
  neighbour plan.  Kept as the baseline the joint co-plan is benchmarked
  against (you control your own job; the neighbour does not cooperate);
* ``hierarchical_shared_jobs`` / ``hierarchical_jobs_plan`` — N jobs on
  independent ICI pods sharing one DCN uplink, co-planned with per-link
  :class:`~repro.core.cost_model.PathModel` refits (each link's
  (a_l, b_l) from its own occupancy telemetry; ``shared_model=True``
  pools the DCN samples of all jobs);
* ``job_churn`` — arrival/departure mid-run: re-plan the new fleet
  through ``coplan_incremental`` from the incumbent assignment;
* ``faulty_long_run`` — a seeded :class:`~repro.sim.faults.FaultPlan`
  (crashes, preemptions, link flaps, slow hosts, checkpoint failures)
  against a ``repro.train.resilience`` controller vs. the naive
  restore-everything baseline, with an availability report (goodput,
  MTTR p95, replayed fraction).

Builders take ``(specs, t_f)`` so callers choose the profile source
(``benchmarks/paper_profiles.py``, ``core/profiler.py`` measurements, or
``trace.synthetic_specs``); the zero-argument ``CATALOG`` entries use small
synthetic profiles and exist for docs, smoke tests and quick looks.

Most builders also take ``schedule=`` (``repro.sim.schedules``) to cross a
scenario with a non-BSP iteration discipline — pipelined all-reduce,
micro-batched 1F1B, local SGD — and the catalog carries the crossed
variants (``*_pipelined`` / ``*_1f1b`` / ``*_localsgd``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core import coplanner, cost_model, planner
from repro.core.coplanner import CoJob, CoObservation, JobObservation
from repro.core.planner import MergePlan, Planner, TensorSpec
from repro.sim import network, trace
from repro.sim.engine import ClusterSim, JobSpec
from repro.sim.network import Burst, FlatTopology, HierarchicalTopology
from repro.sim.schedules import (LocalSGD, OneFoneB, PipelinedAllReduce,
                                 Schedule)
from repro.sim.workers import WorkerProfile, make_workers

# Point-to-point constants matching the paper's fitted cluster 1 at N=8
# (ring: a = 2(N-1)alpha -> alpha = 972us/14; b -> beta per byte).  These
# were previously private to benchmarks/scaling_sim.py.
PAPER_ALPHA = 9.72e-4 / 14
PAPER_BETA = 1.97e-9 / (2 * 7 / 8)
PAPER_GAMMA = PAPER_BETA / 10


def _strategy_planner(strategy: str, specs: Sequence[TensorSpec],
                      model: cost_model.AllReduceModel):
    """(initial plan, replan(model) -> plan, Planner | None).

    The in-loop scenarios (elastic resize, straggler eviction) replan on
    every membership change; ``dp_incremental`` shares one
    :class:`Planner` across those replans so each is a DP-frontier reuse,
    while the reference strategies go through ``make_plan`` from scratch.
    """
    if strategy == "dp_incremental":
        inc = Planner(specs, model)
        return inc.plan(), inc.replan, inc
    return (planner.make_plan(strategy, specs, model),
            lambda m: planner.replan(strategy, specs, m), None)


def paper_scaling(specs: Sequence[TensorSpec], t_f: float, n_workers: int,
                  *, algorithm: str = "ring", strategy: str = "mgwfbp",
                  alpha: float = PAPER_ALPHA, beta: float = PAPER_BETA,
                  gamma: float = PAPER_GAMMA, iters: int = 1,
                  compute_mode: str = "analytic", seed: int = 0,
                  name: str = "train", plan: MergePlan | None = None,
                  schedule: Schedule | None = None) -> ClusterSim:
    """Homogeneous N-worker job — the paper's Figs. 10-11 setting.

    Pass ``plan`` to skip the O(L^2) planner when the caller already built
    one for the identical cost model (benchmarks sweep many N points), and
    ``schedule`` to run the same cluster under a non-BSP iteration
    discipline (the schedule-crossed variants of the paper study)."""
    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    if plan is None:
        plan = planner.make_plan(strategy, specs, topo.linear_model())
    job = JobSpec(name=name, specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_workers), topology=topo,
                  iters=iters, compute_mode=compute_mode, schedule=schedule)
    return ClusterSim([job], seed=seed)


def straggler(specs: Sequence[TensorSpec], t_f: float, n_workers: int,
              *, slow_factor: float = 2.0, slow_workers: int = 1,
              jitter_sigma: float = 0.0, algorithm: str = "ring",
              strategy: str = "mgwfbp", alpha: float = PAPER_ALPHA,
              beta: float = PAPER_BETA, gamma: float = PAPER_GAMMA,
              iters: int = 2, compute_mode: str = "analytic",
              seed: int = 0,
              schedule: Schedule | None = None) -> ClusterSim:
    """Synchronous SGD with persistent stragglers: the step time is the max
    over workers, so one slow host drags the fleet (fault.py's
    StragglerMonitor exists to evict exactly these).  Under ``schedule=
    LocalSGD(H)`` the straggler only hurts at sync steps — the contrast
    scenario for straggler-tolerant schedules."""
    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    plan = planner.make_plan(strategy, specs, topo.linear_model())
    slow = {i: slow_factor for i in range(min(slow_workers, n_workers))}
    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_workers, slow=slow,
                                       jitter_sigma=jitter_sigma),
                  topology=topo, iters=iters, compute_mode=compute_mode,
                  schedule=schedule)
    return ClusterSim([job], seed=seed)


@dataclasses.dataclass
class ElasticReport:
    """What the elastic-replanning loop did (filled in by the hook)."""

    plan_before: MergePlan
    plan_after: MergePlan | None = None
    fitted: cost_model.AllReduceModel | None = None
    predicted: cost_model.AllReduceModel | None = None
    used_fallback: bool = False
    fixpoint: "planner.FixpointResult | None" = None
    planner_scratch: int = 0            # incremental-planner counters
    planner_incremental: int = 0


def elastic_resize(specs: Sequence[TensorSpec], t_f: float, *,
                   n_before: int = 8, n_after: int = 32,
                   resize_at: int = 1, iters: int = 4,
                   strategy: str = "mgwfbp", algorithm: str = "ring",
                   alpha: float = PAPER_ALPHA,
                   beta: float = PAPER_BETA, gamma: float = PAPER_GAMMA,
                   compute_mode: str = "analytic", seed: int = 0,
                   contention_aware: bool = False,
                   bursts: Sequence[Burst] = (),
                   ) -> tuple[ClusterSim, ElasticReport]:
    """Mid-run resize N_before -> N_after with online refit + replan.

    After iteration ``resize_at`` the hook (1) least-squares-fits (a, b)
    from the bucket timings observed so far (trace.refit_model), (2)
    inverts the collective's Table-2 formulas to point-to-point
    (alpha, beta) and predicts the post-resize model
    (network.predicted_model — ring, double binary trees, or
    halving-doubling), (3) replans for the new model, and (4) swaps
    workers/topology/plan.  With ``strategy="dp_incremental"`` the replan
    reuses the planner's DP frontier instead of starting from scratch.

    With ``contention_aware=True`` the hook goes one step further and
    replans through the co-planner (planner.plan_contention_aware, the
    N=1 ``repro.core.coplanner.CoPlanner``) against a post-resize probe
    simulation that includes ``bursts`` — so the plan the job resumes
    with is fitted to the *contended* fabric, not the exclusive-link
    model.
    """
    topo = FlatTopology(algorithm, n_before, alpha, beta, gamma)
    plan, replan, inc = _strategy_planner(strategy, specs,
                                          topo.linear_model())
    report = ElasticReport(plan_before=plan)

    def probe(candidate: MergePlan):
        """Evaluate a candidate plan on the post-resize contended fabric."""
        job = JobSpec(name="probe", specs=list(specs), plan=candidate,
                      t_f=t_f, workers=make_workers(n_after),
                      topology=topo.rescale(n_after), iters=1,
                      compute_mode=compute_mode)
        res = ClusterSim([job], seed=seed, bursts=list(bursts)).run()
        jr = res.job("probe")
        return jr.iterations[-1].t_iter, jr.bucket_samples

    def hook(sim: ClusterSim, run, it: int) -> None:
        samples = run.result.bucket_samples
        gamma_ratio = gamma / beta if beta else 0.0
        try:
            fitted = trace.refit_model(samples)
            predicted = network.predicted_model(
                algorithm, fitted.a, fitted.b, n_before, n_after,
                gamma_ratio=gamma_ratio)
        except ValueError:
            # degenerate observation (e.g. plan merged to one bucket) —
            # fall back to the topology's own rescaled model
            fitted = None
            predicted = topo.rescale(n_after).linear_model()
            report.used_fallback = True
        if contention_aware:
            fix = planner.plan_contention_aware(specs, predicted, probe,
                                                t_f=t_f)
            report.fixpoint = fix
            new_plan, predicted = fix.plan, fix.model
            if inc is not None:     # keep the shared planner's model fresh
                replan(fix.model)
        else:
            new_plan = replan(predicted)
        run.workers = make_workers(n_after)
        run.topology = run.topology.rescale(n_after)
        run.plan = new_plan
        sim.ensure_links(run.topology)
        report.fitted, report.predicted = fitted, predicted
        report.plan_after = new_plan
        if inc is not None:
            report.planner_scratch = inc.scratch_plans
            report.planner_incremental = inc.incremental_updates

    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_before), topology=topo,
                  iters=iters, compute_mode=compute_mode,
                  hooks={resize_at: hook})
    return ClusterSim([job], seed=seed, bursts=list(bursts)), report


@dataclasses.dataclass
class DriftReport:
    """What the always-on drift loop saw and did (filled by the hook)."""

    monitor: "drift.DriftMonitor"
    residuals: list[tuple[int, float]] = dataclasses.field(
        default_factory=list)              # (iteration, ewma after observe)
    replans: int = 0
    plans: list[MergePlan] = dataclasses.field(default_factory=list)
    models: list[cost_model.AllReduceModel] = dataclasses.field(
        default_factory=list)

    @property
    def alerts(self):
        return self.monitor.alerts


def drift_monitored(specs: Sequence[TensorSpec], t_f: float, *,
                    n_workers: int = 16, iters: int = 8,
                    degrade_at: int | None = 2,
                    degrade_factor: float = 4.0,
                    threshold: float = 0.15, ewma_alpha: float = 0.5,
                    strategy: str = "dp_incremental",
                    algorithm: str = "ring", alpha: float = PAPER_ALPHA,
                    beta: float = PAPER_BETA, gamma: float = PAPER_GAMMA,
                    compute_mode: str = "analytic", seed: int = 0,
                    recorder=None,
                    ) -> tuple[ClusterSim, DriftReport]:
    """The PR-2 refit fixpoint as a monitored, always-on loop.

    Every iteration the hook compares the closed-form prediction of the
    *live* plan under the *believed* (a, b) model
    (``core.simulator.simulate``) against the iteration time the engine
    actually delivered, feeding a :class:`repro.obs.drift.DriftMonitor`.
    After iteration ``degrade_at`` the fabric silently degrades (per-byte
    cost × ``degrade_factor`` — a congested or renegotiated link) while
    the plan and model stay stale; the EWMA residual climbs, the monitor
    alerts, and the hook reacts the way a production loop would: refit
    the effective (a, b) from the degraded iteration's own bucket
    timings (:func:`repro.core.planner.effective_model`), replan
    (incrementally under ``strategy="dp_incremental"``), adopt the
    fitted model as the new belief, and reset the monitor.  Post-replan
    residuals drop back under threshold — the acceptance criterion the
    drift tests pin.

    ``degrade_at=None`` is the calibrated control: nothing changes
    mid-run, so the monitor must stay silent (also pinned, and asserted
    by the CI obs smoke).

    Pass ``recorder`` (a :class:`repro.obs.recorder.FlightRecorder`) to
    capture the whole episode — per-iteration records from the engine,
    ``drift_alert`` events from the monitor, ``planner_update`` decision
    events from the incremental planner — in one flight-recorder ring.
    """
    from repro.core.simulator import simulate
    from repro.obs import drift

    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    believed = topo.linear_model()
    plan, replan, inc = _strategy_planner(strategy, specs, believed)
    if inc is not None and recorder is not None:
        inc.recorder = recorder
    monitor = drift.DriftMonitor(threshold=threshold, alpha=ewma_alpha,
                                 warmup=1, recorder=recorder, job="train")
    report = DriftReport(monitor=monitor, plans=[plan], models=[believed])
    state = {"plan": plan, "model": believed}

    def hook(sim: ClusterSim, run, it: int) -> None:
        result = run.result.iterations[-1]
        predicted = simulate(specs, state["plan"], state["model"],
                             t_f).t_iter
        alert = monitor.observe(it, predicted, result.t_iter)
        report.residuals.append((it, monitor.residual()))
        if alert is not None:
            samples = [(b.nbytes, b.duration) for b in result.buckets]
            fitted = planner.effective_model(
                samples, cost_model.as_linear(state["model"]))
            new_plan = replan(fitted)
            run.plan = new_plan
            state["plan"], state["model"] = new_plan, fitted
            report.replans += 1
            report.plans.append(new_plan)
            report.models.append(fitted)
            monitor.reset()
        if it == degrade_at:
            # the fabric degrades *silently*: topology (ground truth)
            # changes, the planner's belief does not — that gap is what
            # the monitor exists to close
            run.topology = FlatTopology(algorithm, n_workers, alpha,
                                        beta * degrade_factor, gamma)
            sim.ensure_links(run.topology)

    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_workers), topology=topo,
                  iters=iters, compute_mode=compute_mode,
                  hooks={i: hook for i in range(iters)})
    return ClusterSim([job], seed=seed, recorder=recorder), report


def bursty(specs: Sequence[TensorSpec], t_f: float, n_workers: int = 16,
           *, burst_flows: int = 3, duty: float = 0.5, period: float = 0.25,
           horizon_iters: int = 4, strategy: str = "mgwfbp",
           algorithm: str = "ring", alpha: float = PAPER_ALPHA,
           beta: float = PAPER_BETA, gamma: float = PAPER_GAMMA,
           compute_mode: str = "analytic", seed: int = 0,
           schedule: Schedule | None = None) -> ClusterSim:
    """Periodic background traffic steals link bandwidth during bursts."""
    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    plan = planner.make_plan(strategy, specs, topo.linear_model())
    base = topo.linear_model()
    # size the burst schedule to roughly cover the run
    t_iter_est = t_f + sum(s.t_b for s in specs) + sum(
        base.time(n) for n in plan.bucket_bytes(specs))
    horizon = t_iter_est * horizon_iters * 2
    bursts, t = [], 0.0
    while t < horizon:
        bursts.append(Burst(link=topo.link, start=t, end=t + period * duty,
                            flows=burst_flows))
        t += period
    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_workers), topology=topo,
                  iters=horizon_iters, compute_mode=compute_mode,
                  schedule=schedule)
    return ClusterSim([job], seed=seed, bursts=bursts)


@dataclasses.dataclass(frozen=True)
class CoJobSpec:
    """Planning-level description of one co-located training job.

    The N-job analogue of ``(specs_x, t_f_x, plan_x)`` from the old
    two-job entry points: each job carries its own profile, forward time,
    iteration schedule, merge strategy, membership and start offset.
    ``n_workers=None`` inherits the scenario-level worker count."""

    name: str
    specs: tuple[TensorSpec, ...]
    t_f: float
    strategy: str = "mgwfbp"
    schedule: Schedule | None = None
    n_workers: int | None = None
    start_time: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))
        if self.t_f < 0:
            raise ValueError(f"negative t_f: {self}")


def shared_link_jobs(jobs: Sequence[CoJobSpec], *, n_workers: int = 8,
                     algorithm: str = "ring", alpha: float = PAPER_ALPHA,
                     beta: float = PAPER_BETA, gamma: float = PAPER_GAMMA,
                     iters: int = 2, compute_mode: str = "analytic",
                     seed: int = 0,
                     plans: Mapping[str, MergePlan] | None = None,
                     bursts: Sequence[Burst] = ()) -> ClusterSim:
    """N independent jobs time-sharing one network — every job's
    collectives contend via processor sharing on the common link, and the
    link's per-owner accounting attributes bytes/occupancy per job.

    ``plans`` pins individual jobs' merge plans (co-planners evaluate
    candidate assignments this way); unpinned jobs plan with their own
    ``strategy`` under the exclusive-link model for their membership.
    Mixed schedules are the interesting regime: a pipelined job spreads
    its traffic under the neighbours' forwards while a local-SGD job
    bursts at sync steps."""
    plans = dict(plans or {})
    unknown = set(plans) - {j.name for j in jobs}
    if unknown:
        raise ValueError(f"plans pin unknown jobs: {sorted(unknown)}")
    out = []
    for j in jobs:
        n = j.n_workers if j.n_workers is not None else n_workers
        topo = FlatTopology(algorithm, n, alpha, beta, gamma)
        plan = plans.get(j.name)
        if plan is None:
            plan = planner.make_plan(j.strategy, j.specs,
                                     topo.linear_model())
        out.append(JobSpec(name=j.name, specs=list(j.specs), plan=plan,
                           t_f=j.t_f,
                           workers=make_workers(n, prefix=j.name + ".w"),
                           topology=topo, iters=iters,
                           start_time=j.start_time,
                           compute_mode=compute_mode, schedule=j.schedule))
    return ClusterSim(out, seed=seed, bursts=list(bursts))


def two_jobs(specs_a: Sequence[TensorSpec], t_f_a: float,
             specs_b: Sequence[TensorSpec], t_f_b: float, *,
             n_workers: int = 8, stagger: float = 0.0,
             strategy: str = "mgwfbp", algorithm: str = "ring",
             alpha: float = PAPER_ALPHA, beta: float = PAPER_BETA,
             gamma: float = PAPER_GAMMA, iters: int = 2,
             compute_mode: str = "analytic", seed: int = 0,
             plan_a: MergePlan | None = None,
             plan_b: MergePlan | None = None,
             schedule: Schedule | None = None) -> ClusterSim:
    """Two independent jobs time-sharing one network (the N=2 wrapper
    around :func:`shared_link_jobs`, kept for the original call sites).
    Pass ``plan_a`` / ``plan_b`` to pin a job's merge plan; ``schedule``
    applies to both jobs."""
    plans = {}
    if plan_a is not None:
        plans["job_a"] = plan_a
    if plan_b is not None:
        plans["job_b"] = plan_b
    jobs = [CoJobSpec("job_a", tuple(specs_a), t_f_a, strategy=strategy,
                      schedule=schedule),
            CoJobSpec("job_b", tuple(specs_b), t_f_b, strategy=strategy,
                      schedule=schedule, start_time=stagger)]
    return shared_link_jobs(jobs, n_workers=n_workers, algorithm=algorithm,
                            alpha=alpha, beta=beta, gamma=gamma,
                            iters=iters, compute_mode=compute_mode,
                            seed=seed, plans=plans)


def contended_jobs_plan(jobs: Sequence[CoJobSpec], *, n_workers: int = 8,
                        algorithm: str = "ring",
                        alpha: float = PAPER_ALPHA,
                        beta: float = PAPER_BETA,
                        gamma: float = PAPER_GAMMA, iters: int = 2,
                        compute_mode: str = "analytic", seed: int = 0,
                        max_rounds: int = 5, damping: float = 0.5,
                        shared_model: bool = False,
                        bursts: Sequence[Burst] = (),
                        ) -> "coplanner.CoPlanResult":
    """Jointly co-plan N jobs sharing one network.

    Every job replans through :class:`repro.core.coplanner.CoPlanner`:
    each best-response round simulates ALL jobs together on the shared
    link (via :func:`shared_link_jobs`), refits each job's effective
    (a, b) from its own observed collectives — the link's per-owner
    accounting keeps neighbours' traffic and background ``bursts`` out of
    the samples — and replans each job under its own schedule's closed
    form.  The objective is the **joint makespan** (latest job end minus
    earliest job start across the whole run), and each job's
    exclusive-link ``strategy`` plan rides along as a seed candidate, so
    the co-planned assignment can never lose to independent planning on
    this scenario.

    With ``shared_model=True`` the refit pools all jobs' samples on the
    common link into one contended model per link (the right regime when
    the co-located jobs run comparable collectives; per-job refit is the
    default).  Per-job observed times are span-based rates (pipelined
    iterations overlap, so per-iteration windows would double-count)."""
    jobs = tuple(jobs)
    co_jobs = _flat_co_jobs(jobs, n_workers, algorithm, alpha, beta,
                            gamma)
    evaluate = _joint_evaluate(
        lambda candidate: shared_link_jobs(
            jobs, n_workers=n_workers, algorithm=algorithm, alpha=alpha,
            beta=beta, gamma=gamma, iters=iters,
            compute_mode=compute_mode, seed=seed, plans=candidate,
            bursts=bursts), jobs)

    return coplanner.coplan(co_jobs, evaluate, max_rounds=max_rounds,
                            damping=damping, shared_model=shared_model)


def _flat_co_jobs(jobs: Sequence[CoJobSpec], n_workers: int,
                  algorithm: str, alpha: float, beta: float,
                  gamma: float) -> list[CoJob]:
    """Planning-side CoJobs for a flat shared-link fleet: each job's
    exclusive-link model, its strategy plan as the seed baseline, and
    the common link declared for shared-model pooling (one construction
    point for `contended_jobs_plan` and `job_churn`)."""
    out = []
    for j in jobs:
        n = j.n_workers if j.n_workers is not None else n_workers
        topo = FlatTopology(algorithm, n, alpha, beta, gamma)
        model = topo.linear_model()
        out.append(CoJob(
            name=j.name, specs=j.specs, model=model, t_f=j.t_f,
            schedule=j.schedule,
            seed_plans=(planner.make_plan(j.strategy, j.specs, model),),
            links=(topo.link,)))
    return out


def _joint_evaluate(build_sim: Callable[[Mapping[str, MergePlan]],
                                        ClusterSim],
                    jobs: Sequence[CoJobSpec]) -> "coplanner.CoEvaluate":
    """Joint-evaluation closure shared by every co-plan entry point:
    simulate all jobs together under a candidate assignment and package
    each job's observation — span-based rates (pipelined iterations
    overlap, so per-iteration windows would double-count), the
    whole-collective refit samples, and the per-link telemetry
    (cumulative bytes/busy + the leg-by-leg occupancy samples per-link
    path refits consume)."""
    def evaluate(candidate: Mapping[str, MergePlan]) -> CoObservation:
        res = build_sim(candidate).run()
        observed = {}
        for j in jobs:
            jr = res.job(j.name)
            span = jr.iterations[-1].end - jr.iterations[0].start
            observed[j.name] = JobObservation(
                t_iter=span / len(jr.iterations),
                samples=tuple(jr.bucket_samples),
                link_bytes=jr.iterations[-1].link_bytes,
                link_busy=jr.iterations[-1].link_busy,
                link_samples=tuple(
                    (link, tuple(pairs))
                    for link, pairs in jr.link_samples.items()))
        return CoObservation(makespan=res.makespan, jobs=observed)
    return evaluate


def contended_two_jobs_plan(specs_a: Sequence[TensorSpec], t_f_a: float,
                            specs_b: Sequence[TensorSpec], t_f_b: float, *,
                            n_workers: int = 8, stagger: float = 0.0,
                            baseline_strategy: str = "mgwfbp",
                            algorithm: str = "ring",
                            alpha: float = PAPER_ALPHA,
                            beta: float = PAPER_BETA,
                            gamma: float = PAPER_GAMMA, iters: int = 2,
                            compute_mode: str = "analytic", seed: int = 0,
                            max_rounds: int = 5, damping: float = 0.5,
                            schedule: Schedule | None = None,
                            ) -> "planner.FixpointResult":
    """One-sided contention-aware plan for job_a with a frozen neighbour.

    The neighbour job_b keeps its exclusive-link ``baseline_strategy`` plan
    (you control your own job, not the neighbour's); job_a's plan iterates
    through ``planner.plan_contention_aware`` — i.e. the N=1 co-planner —
    with the two-job engine scenario as the evaluation environment.  When
    you control *every* job on the link, use :func:`contended_jobs_plan`
    instead: jointly replanning the fleet dominates this one-sided loop
    (asserted by the co-plan benchmark).  The fixpoint's objective is
    job_a's mean iteration time; observed per-bucket (bytes, duration)
    samples — which embed the processor-sharing stretch — drive the
    effective (a, b) refit.

    With ``schedule`` both jobs run under that iteration discipline and
    the fixpoint replans for it: the observed samples come from the
    schedule's actual collectives (e.g. reduce-scatter + deferred
    all-gather occupancy) and the round predictions use the schedule's own
    closed form, so the bucketing is optimized for the regime being run —
    not for the BSP barrier the paper assumes.
    """
    model = cost_model.make_model(algorithm, n_workers, alpha, beta, gamma)
    plan_b = planner.make_plan(baseline_strategy, specs_b, model)

    def evaluate(candidate: MergePlan):
        sim = two_jobs(specs_a, t_f_a, specs_b, t_f_b,
                       n_workers=n_workers, stagger=stagger,
                       algorithm=algorithm, alpha=alpha, beta=beta,
                       gamma=gamma, iters=iters, compute_mode=compute_mode,
                       seed=seed, plan_a=candidate, plan_b=plan_b,
                       schedule=schedule)
        job = sim.run().job("job_a")
        # span-based rate, not mean(end - start): pipelined iterations
        # overlap (the deferred all-gather tail runs under the next
        # forward), so per-iteration windows double-count hidden comm.
        # For barrier schedules the two are identical (iterations abut).
        span = job.iterations[-1].end - job.iterations[0].start
        return span / len(job.iterations), job.bucket_samples

    # the exclusive-link baseline plan rides along as a seed candidate, so
    # the contention-aware result can never lose to the static planner on
    # this scenario — the fixpoint only has to find something better.
    return planner.plan_contention_aware(
        specs_a, model, evaluate, t_f=t_f_a, max_rounds=max_rounds,
        damping=damping,
        seed_plans=(planner.make_plan(baseline_strategy, specs_a, model),),
        schedule=schedule)


# ---------------------------------------------------------------------------
# Hierarchical (ICI + shared DCN) co-planning.
# ---------------------------------------------------------------------------

def _pod_topology(name: str, pods: int, chips_per_pod: int,
                  dcn_link: str, **hier_kw) -> HierarchicalTopology:
    """One job's two-level topology: a PRIVATE ici link (per-pod fabric
    nobody else touches) and the fleet-shared DCN uplink."""
    return HierarchicalTopology(pods, chips_per_pod,
                                ici_link=f"{name}.ici",
                                dcn_link=dcn_link, **hier_kw)


def hierarchical_shared_jobs(jobs: Sequence[CoJobSpec], *, pods: int = 2,
                             chips_per_pod: int = 8,
                             dcn_link: str = "dcn",
                             iters: int = 2,
                             compute_mode: str = "analytic", seed: int = 0,
                             plans: Mapping[str, MergePlan] | None = None,
                             bursts: Sequence[Burst] = (),
                             **hier_kw) -> ClusterSim:
    """N jobs on independent ICI pods sharing ONE DCN uplink.

    Every job runs a two-level collective (reduce-scatter/all-gather on
    its own ``<name>.ici`` link, cross-pod all-reduce on the
    ``1/chips_per_pod`` shard over the common ``dcn`` link): the ICI legs
    never contend, the DCN legs all do — the fleet regime the per-link
    path models exist for.  Each job's membership is
    ``pods * chips_per_pod``; ``plans`` pins candidate assignments
    exactly like :func:`shared_link_jobs`; extra ``hier_kw`` forward to
    :class:`~repro.sim.network.HierarchicalTopology` (bandwidths and
    latencies)."""
    plans = dict(plans or {})
    unknown = set(plans) - {j.name for j in jobs}
    if unknown:
        raise ValueError(f"plans pin unknown jobs: {sorted(unknown)}")
    out = []
    n = pods * chips_per_pod
    for j in jobs:
        topo = _pod_topology(j.name, pods, chips_per_pod, dcn_link,
                             **hier_kw)
        plan = plans.get(j.name)
        if plan is None:
            plan = planner.make_plan(j.strategy, j.specs,
                                     topo.linear_model())
        out.append(JobSpec(name=j.name, specs=list(j.specs), plan=plan,
                           t_f=j.t_f,
                           workers=make_workers(n, prefix=j.name + ".w"),
                           topology=topo, iters=iters,
                           start_time=j.start_time,
                           compute_mode=compute_mode, schedule=j.schedule))
    return ClusterSim(out, seed=seed, bursts=list(bursts))


def hierarchical_jobs_plan(jobs: Sequence[CoJobSpec], *, pods: int = 2,
                           chips_per_pod: int = 8, dcn_link: str = "dcn",
                           iters: int = 2,
                           compute_mode: str = "analytic", seed: int = 0,
                           max_rounds: int = 5, damping: float = 0.5,
                           shared_model: bool = False,
                           per_link: bool = True,
                           extra_seed_plans: Mapping[str, MergePlan]
                           | None = None,
                           bursts: Sequence[Burst] = (),
                           **hier_kw) -> "coplanner.CoPlanResult":
    """Jointly co-plan N jobs on independent ICI pods + one shared DCN.

    With ``per_link=True`` (the default) each job's cost model is its
    topology's :class:`~repro.core.cost_model.PathModel` and every refit
    corrects each link separately from that link's own occupancy
    telemetry: the private ICI legs stay pinned at their exclusive fit
    while the shared DCN leg absorbs the contention stretch — and
    ``shared_model=True`` pools the DCN samples of ALL jobs into one
    contended fit per link (the mode that was structurally impossible
    with flat models, which could only pool whole-collective durations of
    same-shape single-link jobs).  ``per_link=False`` is the old
    behavior: one flat effective (a, b) per job smearing ICI and DCN
    together — kept as the baseline the per-link refit is benchmarked
    against.

    ``extra_seed_plans`` inserts a known-good assignment (e.g. the
    flat-refit co-plan's result) at the head of each job's seed list, so
    the returned plan provably never loses to it on this scenario.
    """
    jobs = tuple(jobs)
    co_jobs = []
    for j in jobs:
        topo = _pod_topology(j.name, pods, chips_per_pod, dcn_link,
                             **hier_kw)
        model = topo.path_model() if per_link else topo.linear_model()
        seeds = [planner.make_plan(j.strategy, j.specs,
                                   topo.linear_model())]
        if extra_seed_plans and j.name in extra_seed_plans:
            seeds.insert(0, extra_seed_plans[j.name])
        co_jobs.append(CoJob(
            name=j.name, specs=j.specs, model=model, t_f=j.t_f,
            schedule=j.schedule, seed_plans=tuple(seeds),
            links=topo.links))

    evaluate = _joint_evaluate(
        lambda candidate: hierarchical_shared_jobs(
            jobs, pods=pods, chips_per_pod=chips_per_pod,
            dcn_link=dcn_link, iters=iters, compute_mode=compute_mode,
            seed=seed, plans=candidate, bursts=bursts, **hier_kw), jobs)

    return coplanner.coplan(co_jobs, evaluate, max_rounds=max_rounds,
                            damping=damping, shared_model=shared_model)


# ---------------------------------------------------------------------------
# Job churn: arrival / departure through the incremental co-planner.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChurnReport:
    """What the arrival/departure replan did."""

    incumbent: "coplanner.CoPlanResult"     # converged pre-churn co-plan
    updated: "coplanner.CoPlanResult"       # post-churn incremental co-plan
    arrived: tuple[str, ...] = ()
    departed: tuple[str, ...] = ()

    @property
    def incumbent_reused(self) -> dict[str, bool]:
        """Per surviving job: did the updated assignment keep the
        incumbent plan?"""
        return {n: self.updated.plans[n].buckets == p.buckets
                for n, p in self.incumbent.plans.items()
                if n in self.updated.plans}


def job_churn(jobs: Sequence[CoJobSpec],
              arriving: Sequence[CoJobSpec] = (),
              departing: Sequence[str] = (), *, n_workers: int = 8,
              algorithm: str = "ring", alpha: float = PAPER_ALPHA,
              beta: float = PAPER_BETA, gamma: float = PAPER_GAMMA,
              iters: int = 2, compute_mode: str = "analytic",
              seed: int = 0, max_rounds: int = 5, damping: float = 0.5,
              shared_model: bool = False,
              ) -> tuple[ClusterSim, ChurnReport]:
    """Mid-run fleet churn: co-plan the incumbents, apply the churn
    (``arriving`` jobs join — typically with a ``start_time`` placing
    them mid-run — and ``departing`` names leave), then re-plan the new
    fleet through :func:`repro.core.coplanner.coplan_incremental`, which
    re-enters the best-response loop from the incumbent assignment
    instead of from scratch.  Returns the post-churn cluster running the
    updated assignment plus a :class:`ChurnReport` (the incumbent and
    updated co-plans, and which survivors kept their plan)."""
    incumbent = contended_jobs_plan(
        jobs, n_workers=n_workers, algorithm=algorithm, alpha=alpha,
        beta=beta, gamma=gamma, iters=iters, compute_mode=compute_mode,
        seed=seed, max_rounds=max_rounds, damping=damping,
        shared_model=shared_model)
    gone = set(departing)
    unknown = gone - {j.name for j in jobs}
    if unknown:
        raise ValueError(f"departing unknown jobs: {sorted(unknown)}")
    fleet = tuple(j for j in jobs if j.name not in gone) + tuple(arriving)
    if not fleet:
        raise ValueError("churn would leave an empty fleet")

    co_jobs = _flat_co_jobs(fleet, n_workers, algorithm, alpha, beta,
                            gamma)
    evaluate = _joint_evaluate(
        lambda candidate: shared_link_jobs(
            fleet, n_workers=n_workers, algorithm=algorithm, alpha=alpha,
            beta=beta, gamma=gamma, iters=iters,
            compute_mode=compute_mode, seed=seed, plans=candidate), fleet)
    updated = coplanner.coplan_incremental(
        incumbent, co_jobs, evaluate, max_rounds=max_rounds,
        damping=damping, shared_model=shared_model)
    sim = shared_link_jobs(fleet, n_workers=n_workers,
                           algorithm=algorithm, alpha=alpha, beta=beta,
                           gamma=gamma, iters=iters,
                           compute_mode=compute_mode, seed=seed,
                           plans=updated.plans)
    report = ChurnReport(incumbent=incumbent, updated=updated,
                         arrived=tuple(j.name for j in arriving),
                         departed=tuple(departing))
    return sim, report


@dataclasses.dataclass
class EvictionReport:
    """What the straggler-mitigation loop did (filled in by the hooks)."""

    monitor: object                     # train.fault.StragglerMonitor
    evictions: list[tuple[int, tuple[str, ...]]] = \
        dataclasses.field(default_factory=list)
    plans: list[MergePlan] = dataclasses.field(default_factory=list)
    # one co-planner fixpoint per eviction when contention_aware=True
    fixpoints: list["planner.FixpointResult"] = \
        dataclasses.field(default_factory=list)

    @property
    def evicted_workers(self) -> list[str]:
        return [w for _, names in self.evictions for w in names]


def straggler_eviction(specs: Sequence[TensorSpec], t_f: float,
                       n_workers: int = 8, *, slow_factor: float = 3.0,
                       slow_workers: int = 1, jitter_sigma: float = 0.0,
                       threshold: float = 1.5, warmup: int = 2,
                       min_workers: int = 2, iters: int = 6,
                       strategy: str = "dp_incremental",
                       algorithm: str = "ring",
                       alpha: float = PAPER_ALPHA, beta: float = PAPER_BETA,
                       gamma: float = PAPER_GAMMA,
                       compute_mode: str = "analytic", seed: int = 0,
                       contention_aware: bool = False,
                       bursts: Sequence[Burst] = (),
                       ) -> tuple[ClusterSim, EvictionReport]:
    """Straggler mitigation in the loop: monitor -> evict -> replan.

    ``train.fault.StragglerMonitor`` consumes the engine's per-worker
    compute times after every iteration; once a host's EWMA exceeds the
    fleet median by ``threshold`` (and ``warmup`` samples have arrived),
    the hook evicts it through the engine's membership-change machinery —
    shrink the worker set, rescale the topology, and replan for the new
    (a, b).  Synchronous SGD's step time is a max over workers, so evicting
    a 3x straggler immediately recovers the fleet's pace (the sim twin of
    what ``fault.StragglerMonitor`` + the launcher do in production).

    With ``contention_aware=True`` the post-eviction replan goes through
    the co-planner (``planner.plan_contention_aware``, the N=1
    :class:`repro.core.coplanner.CoPlanner`): the shrunken fleet is probed
    against the contended fabric — including ``bursts`` — so the replaced
    plan is fitted to what the survivors will actually experience, not to
    the exclusive-link model.  The fixpoint lands in
    ``EvictionReport.fixpoints`` per eviction.
    """
    from repro.train.fault import StragglerMonitor  # lazy: keeps sim light

    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    plan, replan, inc = _strategy_planner(strategy, specs,
                                          topo.linear_model())
    monitor = StragglerMonitor(threshold=threshold, warmup=warmup)
    report = EvictionReport(monitor=monitor, plans=[plan])
    slow = {i: slow_factor for i in range(min(slow_workers, n_workers))}

    def probe(n_alive: int):
        """Evaluate a candidate plan on the post-eviction fabric."""
        def evaluate(candidate: MergePlan):
            job = JobSpec(name="probe", specs=list(specs), plan=candidate,
                          t_f=t_f, workers=make_workers(n_alive),
                          topology=topo.rescale(n_alive), iters=1,
                          compute_mode=compute_mode)
            res = ClusterSim([job], seed=seed, bursts=list(bursts)).run()
            jr = res.job("probe")
            return jr.iterations[-1].t_iter, jr.bucket_samples
        return evaluate

    def hook(sim: ClusterSim, run, it: int) -> None:
        for name, seconds in run.result.iterations[-1].worker_compute:
            monitor.record(name, seconds)
        alive = {w.name for w in run.workers}
        flagged = [h for h in monitor.stragglers() if h in alive]
        if not flagged or len(run.workers) - len(flagged) < min_workers:
            return
        keep = [w for w in run.workers if w.name not in flagged]
        for name in flagged:            # forget the evicted hosts' stats
            monitor.forget(name)
        run.workers = keep
        run.topology = run.topology.rescale(len(keep))
        if contention_aware:
            fix = planner.plan_contention_aware(
                specs, run.topology.linear_model(), probe(len(keep)),
                t_f=t_f)
            report.fixpoints.append(fix)
            run.plan = fix.plan
            if inc is not None:     # keep the shared planner's model fresh
                replan(fix.model)
        else:
            run.plan = replan(run.topology.linear_model())
        sim.ensure_links(run.topology)
        report.evictions.append((it, tuple(flagged)))
        report.plans.append(run.plan)

    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_workers, slow=slow,
                                       jitter_sigma=jitter_sigma),
                  topology=topo, iters=iters, compute_mode=compute_mode,
                  hooks={i: hook for i in range(iters)})
    return ClusterSim([job], seed=seed, bursts=list(bursts)), report


@dataclasses.dataclass
class FaultyRunReport:
    """What one faulty long run did, and how well it survived.

    ``availability`` (a :class:`repro.train.resilience
    .AvailabilityReport`) is filled in by the final iteration hook, so it
    is valid as soon as ``sim.run()`` returns."""

    controller: object                  # train.resilience controller
    injector: "faults.FaultInjector"
    resilient: bool
    availability: object = None
    evictions: list[tuple[int, str, str]] = \
        dataclasses.field(default_factory=list)     # (iter, worker, cause)
    readmissions: list[tuple[int, str]] = \
        dataclasses.field(default_factory=list)
    replans: int = 0


def faulty_long_run(specs: Sequence[TensorSpec], t_f: float, *,
                    n_workers: int = 8, iters: int = 30,
                    plan: "faults.FaultPlan | None" = None,
                    resilient: bool = True, ckpt_every: int = 5,
                    strategy: str = "dp_incremental",
                    algorithm: str = "ring",
                    alpha: float = PAPER_ALPHA, beta: float = PAPER_BETA,
                    gamma: float = PAPER_GAMMA,
                    compute_mode: str = "analytic", seed: int = 0,
                    policy=None, recorder=None,
                    ) -> tuple[ClusterSim, FaultyRunReport]:
    """A long-running service under a fault schedule: the tentpole demo.

    A seeded :class:`~repro.sim.faults.FaultPlan` (crashes, preemptions
    with notice, link degradation windows, slow-host onsets, checkpoint
    write failures) is armed on the engine, and a supervisor hook at
    every iteration boundary drives a
    :class:`repro.train.resilience.ResilienceController` through the
    injector's views.  Two policies share the identical physical world:

    * ``resilient=True`` — the controller: crashed workers are evicted
      (surviving data-parallel replicas keep the model, so no restore is
      needed), the topology rescales and the plan is recomputed
      incrementally; preemption notices trigger a proactive drain
      (checkpoint + evict before the deadline — no lost work); flagged
      slow hosts are evicted via the straggler monitor; link windows
      trigger an effective-model refit + replan; replacements are
      re-admitted after a provisioning delay.
    * ``resilient=False`` — the naive baseline: every fail-stop costs a
      full detection + re-provision + checkpoint-restore outage that
      keeps N fixed and replays every step since the last checkpoint;
      notices are ignored; slow hosts drag the synchronous max forever.

    The report's availability numbers (goodput, MTTR p95, replayed
    fraction) are the paper-style comparison the pinned tests assert:
    controller goodput strictly above baseline, bounded recovery.
    """
    from repro.sim import faults
    from repro.train import resilience  # lazy: keeps sim importable light

    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    mplan, replan, inc = _strategy_planner(strategy, specs,
                                           topo.linear_model())
    workers = make_workers(n_workers)
    if plan is None:
        t_iter_est = t_f + sum(s.t_b for s in specs)
        plan = faults.FaultPlan.random(
            seed, iters * t_iter_est, [w.name for w in workers],
            links=["net"])
    pol = policy or resilience.ResiliencePolicy(seed=seed)
    ctrl = resilience.ResilienceController(
        pol, n_workers=n_workers, recorder=recorder, source="sim",
        job="train")

    job = JobSpec(name="train", specs=list(specs), plan=mplan, t_f=t_f,
                  workers=workers, topology=topo, iters=iters,
                  compute_mode=compute_mode)
    sim = ClusterSim([job], seed=seed, recorder=recorder)
    inj = faults.FaultInjector(sim, plan, "train")
    inj.arm()
    report = FaultyRunReport(controller=ctrl, injector=inj,
                             resilient=resilient)
    # hook-closure state: replacement workers awaiting provisioning and
    # the currently-degraded link windows (to replan back when they end)
    pending_readmit: list[tuple[float, str]] = []
    active_deg: list[float] = []
    replacements = [0]

    def rebuild(run, keep) -> None:
        run.workers = keep
        run.topology = run.topology.rescale(len(keep))
        sim.ensure_links(run.topology)
        run.plan = replan(run.topology.linear_model())
        report.replans += 1

    def spawn_name() -> str:
        replacements[0] += 1
        return f"r{replacements[0]}"

    def take_checkpoint(now: float) -> None:
        if inj.take_ckpt_failure():
            ctrl.checkpoint_failed(now)
        else:
            ctrl.checkpoint_saved(ctrl.committed_step, now)

    def hook(sim: ClusterSim, run, it: int) -> None:
        now = sim.engine.now
        res = run.result.iterations[-1]
        alive = {w.name for w in run.workers}
        crashes = [(w, t, cause) for w, t, cause in inj.take_crashes()
                   if w in alive]
        slow_onsets = inj.take_slow_hosts()
        degradations = inj.take_degradations()

        # 1. the just-finished iteration: lost if a member crashed
        #    mid-flight (the synchronous sync never completed validly)
        flagged: list[str] = []
        if crashes:
            ctrl.discard_step(now)
        elif resilient:
            flagged = ctrl.step_ok(now, res.t_iter, res.worker_compute)
        else:
            ctrl.step_ok(now, res.t_iter)

        # 2. fail-stop repair
        for w, t_crash, cause in crashes:
            ctrl.fault_detected(cause, now + pol.detect_s, t_crash,
                                worker=w)
        if crashes:
            names = [w for w, _, _ in crashes]
            if resilient and len(run.workers) - len(names) >= \
                    pol.min_workers:
                # evict + degrade to N-k: DP survivors keep the model
                rebuild(run, [w for w in run.workers
                              if w.name not in names])
                ctrl.evict(names, now, kind="evict_crash")
                run.pause_until(now + pol.detect_s + pol.evict_s)
                for w, _, cause in crashes:
                    pending_readmit.append(
                        (now + pol.provision_s, spawn_name()))
                    report.evictions.append((it, w, cause))
            else:
                # naive: keep N — wait out re-provision, restore from
                # the last checkpoint, replay everything since
                run.workers = [
                    WorkerProfile(spawn_name(),
                                  jitter_sigma=w.jitter_sigma)
                    if w.name in names else w for w in run.workers]
                ctrl.restored(ctrl.last_ckpt_step, now)
                run.pause_until(now + pol.detect_s + pol.provision_s
                                + pol.restore_s)

        # 3. preemption notices: drain proactively (controller only)
        if resilient:
            for note in inj.take_notices():
                w = note["worker"]
                if w not in {x.name for x in run.workers}:
                    continue
                ctrl.fault_detected("preempt", now, note["at"], worker=w)
                if len(run.workers) - 1 < pol.min_workers:
                    continue
                inj.mark_drained(w)
                take_checkpoint(now)
                rebuild(run, [x for x in run.workers if x.name != w])
                ctrl.evict([w], now, kind="preempt_drain")
                run.pause_until(now + pol.ckpt_s + pol.evict_s)
                pending_readmit.append(
                    (now + pol.provision_s, spawn_name()))
                report.evictions.append((it, w, "preempt_drain"))

        # 4. gray failures: slow hosts (monitor-driven) + link windows
        if resilient and slow_onsets:
            for w, t_on, factor in slow_onsets:
                ctrl.fault_detected("slow_host", now, t_on, worker=w)
        if resilient and flagged:
            keep = [w for w in run.workers if w.name not in flagged]
            if len(keep) >= pol.min_workers:
                rebuild(run, keep)
                ctrl.evict(flagged, now, kind="evict_straggler")
                run.pause_until(now + pol.evict_s)
                for w in flagged:
                    pending_readmit.append(
                        (now + pol.provision_s, spawn_name()))
                    report.evictions.append((it, w, "straggler"))
        if resilient and degradations:
            for d in degradations:
                ctrl.fault_detected("link_degrade", now, d["at"],
                                    worker=d["link"])
                active_deg.append(d["until"])
            # refit an effective model from what the collectives
            # actually experienced on the degraded fabric, replan
            samples = [(b.nbytes, b.duration) for b in res.buckets]
            if samples:
                eff = planner.effective_model(
                    samples, cost_model.as_linear(
                        run.topology.linear_model()))
                run.plan = replan(eff)
                report.replans += 1
                ctrl.replanned(now, reason="link_degrade")
        if resilient and active_deg and now > max(active_deg):
            # every window closed: plan back onto the healthy fabric
            active_deg.clear()
            run.plan = replan(run.topology.linear_model())
            report.replans += 1
            ctrl.replanned(now, reason="link_restored")

        # 5. re-admit provisioned replacements (controller only)
        if resilient:
            ready = [x for x in pending_readmit if x[0] <= now]
            if ready:
                pending_readmit[:] = [x for x in pending_readmit
                                      if x[0] > now]
                names = [n for _, n in ready]
                rebuild(run, list(run.workers) + [
                    WorkerProfile(n) for n in names])
                ctrl.readmit(names, now)
                run.pause_until(now + pol.readmit_s)
                for n in names:
                    report.readmissions.append((it, n))

        # 6. checkpoint cadence (write failures come from the injector)
        if (it + 1) % ckpt_every == 0:
            take_checkpoint(now)

        if it == iters - 1:
            report.availability = ctrl.report(now)

    job.hooks = {i: hook for i in range(iters)}
    return sim, report


def hierarchical_pods(specs: Sequence[TensorSpec], t_f: float, *,
                      pods: int = 2, chips_per_pod: int = 16,
                      strategy: str = "mgwfbp", iters: int = 1,
                      compute_mode: str = "analytic",
                      seed: int = 0) -> ClusterSim:
    """Two-level ICI+DCN cluster (the production mesh of launch/mesh.py)."""
    topo = HierarchicalTopology(pods, chips_per_pod)
    plan = planner.make_plan(strategy, specs, topo.linear_model())
    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(pods * chips_per_pod),
                  topology=topo, iters=iters, compute_mode=compute_mode)
    return ClusterSim([job], seed=seed)


# ---------------------------------------------------------------------------
# Zero-argument catalog (synthetic profiles) for docs / smoke tests.
# ---------------------------------------------------------------------------

def _syn():
    return trace.synthetic_specs(48, seed=7)


def _mixed_schedule_jobs(n_tensors: int = 24) -> list[CoJobSpec]:
    """Three co-located jobs under different iteration disciplines."""
    a, t_f_a = trace.synthetic_specs(n_tensors, seed=7)
    b, t_f_b = trace.synthetic_specs(n_tensors, seed=9)
    c, t_f_c = trace.synthetic_specs(n_tensors, seed=11)
    return [
        CoJobSpec("bsp_job", tuple(a), t_f_a),
        CoJobSpec("pipelined_job", tuple(b), t_f_b,
                  schedule=PipelinedAllReduce()),
        CoJobSpec("localsgd_job", tuple(c), t_f_c, schedule=LocalSGD(2)),
    ]


def _coplanned_three_jobs() -> ClusterSim:
    """Mixed-schedule 3-job cluster running its co-planned assignment."""
    jobs = _mixed_schedule_jobs(16)
    fix = contended_jobs_plan(jobs, n_workers=8, iters=2, max_rounds=2)
    return shared_link_jobs(jobs, n_workers=8, iters=2, plans=fix.plans)


def _two_pod_jobs(n_tensors: int = 16) -> list[CoJobSpec]:
    a, t_f_a = trace.synthetic_specs(n_tensors, seed=7)
    b, t_f_b = trace.synthetic_specs(n_tensors, seed=9)
    return [CoJobSpec("pod_a", tuple(a), t_f_a),
            CoJobSpec("pod_b", tuple(b), t_f_b)]


def _coplanned_pod_jobs() -> ClusterSim:
    """Shared-DCN 2-job fleet running its per-link co-planned assignment
    (shared DCN model pooled across jobs)."""
    jobs = _two_pod_jobs()
    fix = hierarchical_jobs_plan(jobs, pods=2, chips_per_pod=4, iters=2,
                                 max_rounds=2, shared_model=True)
    return hierarchical_shared_jobs(jobs, pods=2, chips_per_pod=4,
                                    iters=2, plans=fix.plans)


CATALOG: dict[str, Callable[[], ClusterSim]] = {
    "paper_ring_16": lambda: paper_scaling(*_syn(), 16),
    "paper_dbt_64": lambda: paper_scaling(*_syn(), 64,
                                          algorithm="double_binary_trees"),
    "straggler_2x": lambda: straggler(*_syn(), 16, slow_factor=2.0),
    "straggler_evict": lambda: straggler_eviction(*_syn(), 8,
                                                  slow_factor=3.0)[0],
    "jittery": lambda: straggler(*_syn(), 16, slow_factor=1.0,
                                 jitter_sigma=0.2, iters=4),
    "elastic_8_to_32": lambda: elastic_resize(*_syn())[0],
    "drift_monitored": lambda: drift_monitored(*_syn())[0],
    "elastic_dbt": lambda: elastic_resize(
        *_syn(), algorithm="double_binary_trees",
        strategy="dp_incremental")[0],
    "elastic_contended": lambda: elastic_resize(
        *_syn(), contention_aware=True,
        bursts=(Burst("net", 0.0, 60.0, flows=2),))[0],
    "bursty": lambda: bursty(*_syn()),
    "two_jobs": lambda: two_jobs(*_syn(), *trace.synthetic_specs(32, seed=9)),
    "pods_2x16": lambda: hierarchical_pods(*_syn()),
    # schedule-crossed variants: the paper cluster and the contention
    # scenarios under non-BSP iteration disciplines
    "paper_ring_16_pipelined": lambda: paper_scaling(
        *_syn(), 16, iters=4, schedule=PipelinedAllReduce()),
    "paper_ring_16_1f1b": lambda: paper_scaling(
        *_syn(), 16, iters=4, schedule=OneFoneB(4)),
    "paper_ring_16_localsgd": lambda: paper_scaling(
        *_syn(), 16, iters=8, schedule=LocalSGD(4)),
    "straggler_localsgd": lambda: straggler(
        *_syn(), 16, slow_factor=2.0, iters=8, schedule=LocalSGD(4)),
    "bursty_pipelined": lambda: bursty(
        *_syn(), schedule=PipelinedAllReduce()),
    "two_jobs_pipelined": lambda: two_jobs(
        *_syn(), *trace.synthetic_specs(32, seed=9),
        schedule=PipelinedAllReduce()),
    # N-job co-planning: mixed-schedule fleets on one link, independently
    # planned and jointly co-planned (repro.core.coplanner)
    "three_jobs_mixed": lambda: shared_link_jobs(
        _mixed_schedule_jobs(), n_workers=8, iters=2),
    "three_jobs_coplanned": _coplanned_three_jobs,
    # hierarchical fleets: independent ICI pods sharing one DCN uplink,
    # co-planned with per-link path models (shared DCN fit)
    "pods_shared_dcn": lambda: hierarchical_shared_jobs(
        _two_pod_jobs(), pods=2, chips_per_pod=4, iters=2),
    "pods_coplanned_per_link": _coplanned_pod_jobs,
    # fleet churn: a third job arrives mid-run; the incremental
    # co-planner re-enters best response from the incumbent assignment
    "job_churn": lambda: job_churn(
        _mixed_schedule_jobs(16)[:2],
        arriving=[CoJobSpec("late_job",
                            *trace.synthetic_specs(12, seed=13),
                            start_time=0.05)],
        n_workers=8, iters=2, max_rounds=2)[0],
    "straggler_evict_contended": lambda: straggler_eviction(
        *_syn(), 8, slow_factor=3.0, contention_aware=True,
        bursts=(Burst("net", 0.0, 60.0, flows=2),))[0],
    # fault injection: same seeded fault schedule, with and without the
    # resilience controller (repro.sim.faults + repro.train.resilience)
    "faulty_long_run": lambda: faulty_long_run(*_syn())[0],
    "faulty_long_run_naive": lambda: faulty_long_run(
        *_syn(), resilient=False)[0],
}
