"""Scenario catalog for the cluster simulator.

Each builder assembles a ready-to-run :class:`ClusterSim`:

* ``paper_scaling``    — the §7 trace-driven study (homogeneous workers,
  Table-2 collective over the paper's fitted cluster constants);
* ``straggler``        — one (or more) persistently slow workers, the
  sweep the closed form cannot express;
* ``elastic_resize``   — mid-run membership change with ONLINE (a, b)
  refit from observed bucket timings -> ``planner.replan`` (the loop from
  ``examples/elastic_replan.py``, now closed inside the simulator);
* ``bursty``           — background traffic bursts contending on the link;
* ``two_jobs``         — two training jobs sharing one network.

Builders take ``(specs, t_f)`` so callers choose the profile source
(``benchmarks/paper_profiles.py``, ``core/profiler.py`` measurements, or
``trace.synthetic_specs``); the zero-argument ``CATALOG`` entries use small
synthetic profiles and exist for docs, smoke tests and quick looks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core import cost_model, planner
from repro.core.planner import MergePlan, TensorSpec
from repro.sim import network, trace
from repro.sim.engine import ClusterSim, JobSpec
from repro.sim.network import Burst, FlatTopology, HierarchicalTopology
from repro.sim.workers import make_workers

# Point-to-point constants matching the paper's fitted cluster 1 at N=8
# (ring: a = 2(N-1)alpha -> alpha = 972us/14; b -> beta per byte).  These
# were previously private to benchmarks/scaling_sim.py.
PAPER_ALPHA = 9.72e-4 / 14
PAPER_BETA = 1.97e-9 / (2 * 7 / 8)
PAPER_GAMMA = PAPER_BETA / 10


def paper_scaling(specs: Sequence[TensorSpec], t_f: float, n_workers: int,
                  *, algorithm: str = "ring", strategy: str = "mgwfbp",
                  alpha: float = PAPER_ALPHA, beta: float = PAPER_BETA,
                  gamma: float = PAPER_GAMMA, iters: int = 1,
                  compute_mode: str = "analytic", seed: int = 0,
                  name: str = "train",
                  plan: MergePlan | None = None) -> ClusterSim:
    """Homogeneous N-worker job — the paper's Figs. 10-11 setting.

    Pass ``plan`` to skip the O(L^2) planner when the caller already built
    one for the identical cost model (benchmarks sweep many N points)."""
    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    if plan is None:
        plan = planner.make_plan(strategy, specs, topo.linear_model())
    job = JobSpec(name=name, specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_workers), topology=topo,
                  iters=iters, compute_mode=compute_mode)
    return ClusterSim([job], seed=seed)


def straggler(specs: Sequence[TensorSpec], t_f: float, n_workers: int,
              *, slow_factor: float = 2.0, slow_workers: int = 1,
              jitter_sigma: float = 0.0, algorithm: str = "ring",
              strategy: str = "mgwfbp", alpha: float = PAPER_ALPHA,
              beta: float = PAPER_BETA, gamma: float = PAPER_GAMMA,
              iters: int = 2, compute_mode: str = "analytic",
              seed: int = 0) -> ClusterSim:
    """Synchronous SGD with persistent stragglers: the step time is the max
    over workers, so one slow host drags the fleet (fault.py's
    StragglerMonitor exists to evict exactly these)."""
    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    plan = planner.make_plan(strategy, specs, topo.linear_model())
    slow = {i: slow_factor for i in range(min(slow_workers, n_workers))}
    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_workers, slow=slow,
                                       jitter_sigma=jitter_sigma),
                  topology=topo, iters=iters, compute_mode=compute_mode)
    return ClusterSim([job], seed=seed)


@dataclasses.dataclass
class ElasticReport:
    """What the elastic-replanning loop did (filled in by the hook)."""

    plan_before: MergePlan
    plan_after: MergePlan | None = None
    fitted: cost_model.AllReduceModel | None = None
    predicted: cost_model.AllReduceModel | None = None
    used_fallback: bool = False


def elastic_resize(specs: Sequence[TensorSpec], t_f: float, *,
                   n_before: int = 8, n_after: int = 32,
                   resize_at: int = 1, iters: int = 4,
                   strategy: str = "mgwfbp", alpha: float = PAPER_ALPHA,
                   beta: float = PAPER_BETA, gamma: float = PAPER_GAMMA,
                   compute_mode: str = "analytic", seed: int = 0,
                   ) -> tuple[ClusterSim, ElasticReport]:
    """Mid-run resize N_before -> N_after with online refit + replan.

    After iteration ``resize_at`` the hook (1) least-squares-fits (a, b)
    from the bucket timings observed so far (trace.refit_model), (2)
    inverts the ring formulas to point-to-point (alpha, beta) and predicts
    the post-resize model (network.predicted_ring), (3) reruns the planner
    for the new model, and (4) swaps workers/topology/plan.  Ring only —
    the inversion is algorithm-specific.
    """
    topo = FlatTopology("ring", n_before, alpha, beta, gamma)
    plan = planner.make_plan(strategy, specs, topo.linear_model())
    report = ElasticReport(plan_before=plan)

    def hook(sim: ClusterSim, run, it: int) -> None:
        samples = run.result.bucket_samples
        try:
            fitted = trace.refit_model(samples)
            predicted = network.predicted_ring(
                fitted.a, fitted.b, n_before, n_after,
                gamma_ratio=gamma / beta if beta else 0.0)
        except ValueError:
            # degenerate observation (e.g. plan merged to one bucket) —
            # fall back to the topology's own rescaled model
            fitted = None
            predicted = topo.rescale(n_after).linear_model()
            report.used_fallback = True
        new_plan = planner.replan(strategy, specs, predicted)
        run.workers = make_workers(n_after)
        run.topology = run.topology.rescale(n_after)
        run.plan = new_plan
        sim.ensure_links(run.topology)
        report.fitted, report.predicted = fitted, predicted
        report.plan_after = new_plan

    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_before), topology=topo,
                  iters=iters, compute_mode=compute_mode,
                  hooks={resize_at: hook})
    return ClusterSim([job], seed=seed), report


def bursty(specs: Sequence[TensorSpec], t_f: float, n_workers: int = 16,
           *, burst_flows: int = 3, duty: float = 0.5, period: float = 0.25,
           horizon_iters: int = 4, strategy: str = "mgwfbp",
           algorithm: str = "ring", alpha: float = PAPER_ALPHA,
           beta: float = PAPER_BETA, gamma: float = PAPER_GAMMA,
           compute_mode: str = "analytic", seed: int = 0) -> ClusterSim:
    """Periodic background traffic steals link bandwidth during bursts."""
    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    plan = planner.make_plan(strategy, specs, topo.linear_model())
    base = topo.linear_model()
    # size the burst schedule to roughly cover the run
    t_iter_est = t_f + sum(s.t_b for s in specs) + sum(
        base.time(n) for n in plan.bucket_bytes(specs))
    horizon = t_iter_est * horizon_iters * 2
    bursts, t = [], 0.0
    while t < horizon:
        bursts.append(Burst(link=topo.link, start=t, end=t + period * duty,
                            flows=burst_flows))
        t += period
    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(n_workers), topology=topo,
                  iters=horizon_iters, compute_mode=compute_mode)
    return ClusterSim([job], seed=seed, bursts=bursts)


def two_jobs(specs_a: Sequence[TensorSpec], t_f_a: float,
             specs_b: Sequence[TensorSpec], t_f_b: float, *,
             n_workers: int = 8, stagger: float = 0.0,
             strategy: str = "mgwfbp", algorithm: str = "ring",
             alpha: float = PAPER_ALPHA, beta: float = PAPER_BETA,
             gamma: float = PAPER_GAMMA, iters: int = 2,
             compute_mode: str = "analytic", seed: int = 0) -> ClusterSim:
    """Two independent jobs time-sharing one network — their all-reduces
    contend via processor sharing on the common link."""
    topo = FlatTopology(algorithm, n_workers, alpha, beta, gamma)
    model = topo.linear_model()
    jobs = []
    for name, specs, t_f, start in (("job_a", specs_a, t_f_a, 0.0),
                                    ("job_b", specs_b, t_f_b, stagger)):
        plan = planner.make_plan(strategy, specs, model)
        jobs.append(JobSpec(name=name, specs=list(specs), plan=plan,
                            t_f=t_f, workers=make_workers(n_workers,
                                                          prefix=name + ".w"),
                            topology=topo, iters=iters, start_time=start,
                            compute_mode=compute_mode))
    return ClusterSim(jobs, seed=seed)


def hierarchical_pods(specs: Sequence[TensorSpec], t_f: float, *,
                      pods: int = 2, chips_per_pod: int = 16,
                      strategy: str = "mgwfbp", iters: int = 1,
                      compute_mode: str = "analytic",
                      seed: int = 0) -> ClusterSim:
    """Two-level ICI+DCN cluster (the production mesh of launch/mesh.py)."""
    topo = HierarchicalTopology(pods, chips_per_pod)
    plan = planner.make_plan(strategy, specs, topo.linear_model())
    job = JobSpec(name="train", specs=list(specs), plan=plan, t_f=t_f,
                  workers=make_workers(pods * chips_per_pod),
                  topology=topo, iters=iters, compute_mode=compute_mode)
    return ClusterSim([job], seed=seed)


# ---------------------------------------------------------------------------
# Zero-argument catalog (synthetic profiles) for docs / smoke tests.
# ---------------------------------------------------------------------------

def _syn():
    return trace.synthetic_specs(48, seed=7)


CATALOG: dict[str, Callable[[], ClusterSim]] = {
    "paper_ring_16": lambda: paper_scaling(*_syn(), 16),
    "paper_dbt_64": lambda: paper_scaling(*_syn(), 64,
                                          algorithm="double_binary_trees"),
    "straggler_2x": lambda: straggler(*_syn(), 16, slow_factor=2.0),
    "jittery": lambda: straggler(*_syn(), 16, slow_factor=1.0,
                                 jitter_sigma=0.2, iters=4),
    "elastic_8_to_32": lambda: elastic_resize(*_syn())[0],
    "bursty": lambda: bursty(*_syn()),
    "two_jobs": lambda: two_jobs(*_syn(), *trace.synthetic_specs(32, seed=9)),
    "pods_2x16": lambda: hierarchical_pods(*_syn()),
}
