"""Trace I/O for the cluster simulator.

Three jobs:

* **ingest** per-tensor profiles — from ``benchmarks/paper_profiles.py``
  rows, ``core/profiler.py`` measurements, or a JSON file — into the
  ``TensorSpec`` list the planner/engine consume;
* **export** engine timelines as Chrome-trace JSON (load in
  ``chrome://tracing`` / Perfetto), and round-trip them back losslessly —
  the acceptance gate for every scenario run;
* **refit** the linear all-reduce model online from *observed* bucket
  timings (the engine's analogue of the paper's Fig. 4 measurement pass)
  and feed ``planner.replan`` — closing the elastic-replanning loop from
  ``examples/elastic_replan.py`` without peeking at the simulator's ground
  truth.

The span type and Chrome-trace I/O themselves now live in
``repro.obs.timeline`` (the shared timeline of the whole repo — engine,
co-planner, and real train step all export through it); this module
re-exports them so every existing ``sim.trace`` import keeps working and
the golden traces stay byte-identical.
"""

from __future__ import annotations

import json
from typing import Iterable, Sequence

import numpy as np

from repro.core import cost_model, planner
from repro.core.planner import MergePlan, TensorSpec
from repro.obs.timeline import (    # noqa: F401  (re-exports)
    CounterSample,
    Span,
    chrome_counters,
    from_chrome_trace,
    read_chrome_trace,
    to_chrome_trace,
    write_chrome_trace,
)


# ---------------------------------------------------------------------------
# Profile ingestion.
# ---------------------------------------------------------------------------

def specs_from_rows(rows: Iterable[tuple[str, int, float]]
                    ) -> list[TensorSpec]:
    """(name, nbytes, t_b) rows (backward order) -> TensorSpec list."""
    return [TensorSpec(str(n), int(b), float(t)) for n, b, t in rows]


def specs_from_json(path: str) -> tuple[list[TensorSpec], float]:
    """Load ``{"t_f": s, "tensors": [{"name", "nbytes", "t_b"}, ...]}``."""
    with open(path) as f:
        obj = json.load(f)
    specs = [TensorSpec(t["name"], int(t["nbytes"]), float(t["t_b"]))
             for t in obj["tensors"]]
    return specs, float(obj.get("t_f", 0.0))


def specs_to_json(path: str, specs: Sequence[TensorSpec],
                  t_f: float = 0.0) -> None:
    obj = {"t_f": t_f,
           "tensors": [{"name": s.name, "nbytes": s.nbytes, "t_b": s.t_b}
                       for s in specs]}
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)


def synthetic_specs(n_tensors: int, seed: int = 0, *,
                    mean_bytes: int = 1 << 18,
                    t_b_total: float = 50e-3) -> tuple[list[TensorSpec], float]:
    """Small deterministic profile for tests and scenario defaults —
    log-uniform sizes (many tiny tensors, few big: the paper's Fig. 5
    shape) with backward time proportional to size."""
    rng = np.random.default_rng(seed)
    raw = np.exp(rng.uniform(np.log(64), np.log(mean_bytes * 16), n_tensors))
    sizes = np.maximum(raw.astype(np.int64), 16)
    t_b = sizes / sizes.sum() * t_b_total
    specs = [TensorSpec(f"t{i}", int(s), float(t))
             for i, (s, t) in enumerate(zip(sizes, t_b))]
    return specs, t_b_total / 3.0           # t_f ~ 1/3 of iteration compute


# ---------------------------------------------------------------------------
# Frontier lanes: per-worker iteration windows as Chrome-trace rows.
# ---------------------------------------------------------------------------

def frontier_spans(job_result, pid: str | None = None) -> list[Span]:
    """Render a job's per-worker iteration frontiers as trace lanes.

    One ``X`` span per (worker, iteration): ``[worker_start, worker_end)``
    with the iteration index and staleness in ``args``.  Under BSP every
    worker's spans start together (the global barrier); non-BSP schedules
    (``repro.sim.schedules``) show the drift — local-SGD workers running
    free between syncs, pipelined workers restarting at
    ``max(own backward end, reduce-scatter end)``.  The lanes live in
    their own ``pid`` group (default ``"<job>/frontier"``) so they sit
    next to, not inside, the compute rows in Perfetto.
    """
    name = getattr(job_result, "name", "job")
    group = pid if pid is not None else f"{name}/frontier"
    spans = []
    for it in job_result.iterations:
        ends = dict(it.worker_end)
        for worker, start in it.worker_start:
            spans.append(Span(
                name=f"iter{it.index}", cat="frontier", pid=group,
                tid=worker, start=start, end=ends[worker],
                args={"iter": it.index, "staleness": it.staleness}))
    return spans


# ---------------------------------------------------------------------------
# Online (a, b) refit -> replan.
# ---------------------------------------------------------------------------

def refit_model(bucket_samples: Sequence[tuple[int, float]],
                name: str = "refit") -> cost_model.AllReduceModel:
    """Least-squares (a, b) from observed (nbytes, duration) collectives.

    Needs >= 2 samples spanning >= 2 distinct sizes (otherwise the linear
    system is rank-deficient); sequential-mode durations exclude queueing
    so the fit recovers the effective startup + per-byte cost including
    any contention the collectives experienced.
    """
    if len(bucket_samples) < 2:
        raise ValueError("need >= 2 bucket samples to refit")
    sizes = [float(s) for s, _ in bucket_samples]
    if len(set(sizes)) < 2:
        raise ValueError("need >= 2 distinct bucket sizes to refit")
    times = [float(t) for _, t in bucket_samples]
    return cost_model.fit(sizes, times, name)


def replan_from_samples(strategy: str, specs: Sequence[TensorSpec],
                        bucket_samples: Sequence[tuple[int, float]],
                        ) -> tuple[MergePlan, cost_model.AllReduceModel]:
    """Refit the comm model from observed collectives, then replan."""
    model = refit_model(bucket_samples)
    return planner.replan(strategy, specs, model), model
