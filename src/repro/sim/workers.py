"""Worker profiles: heterogeneous speeds, stragglers, seeded jitter.

A worker's compute times are the job's nominal per-tensor times multiplied
by a per-iteration *scale*:

    scale(iter) = slowdown * lognormal(sigma)        (seeded, reproducible)

``slowdown`` models persistent heterogeneity (an old GPU, a thermally
throttled host, the paper's K80 vs V100 gap); ``jitter_sigma`` models
transient noise (OS scheduling, network interrupts, garbage collection).
The lognormal draw is keyed on ``(seed, job, worker, iteration)`` through a
``numpy`` ``SeedSequence``, so a scenario replays identically regardless of
event interleaving — the engine's determinism-under-seed property tests
depend on this.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkerProfile:
    """One worker's compute behaviour (communication lives in network.py)."""

    name: str
    slowdown: float = 1.0        # >= 1 is slower than nominal
    jitter_sigma: float = 0.0    # lognormal sigma; 0 = deterministic

    def __post_init__(self):
        if self.slowdown <= 0:
            raise ValueError(f"slowdown must be positive: {self}")
        if self.jitter_sigma < 0:
            raise ValueError(f"negative jitter_sigma: {self}")

    def scale(self, seed: int, job: str, worker_idx: int,
              iteration: int) -> float:
        """Compute-time multiplier for one iteration (deterministic)."""
        if self.jitter_sigma == 0.0:
            return self.slowdown
        key = [seed, zlib.crc32(job.encode()), worker_idx, iteration]
        rng = np.random.default_rng(np.random.SeedSequence(key))
        # mean-one lognormal so jitter adds variance, not bias
        draw = rng.lognormal(mean=-0.5 * self.jitter_sigma ** 2,
                             sigma=self.jitter_sigma)
        return self.slowdown * float(draw)


def scale_array(workers: "list[WorkerProfile]", seed: int, job: str,
                iteration: int) -> np.ndarray:
    """Per-worker compute-scale vector for one iteration (float64).

    The one array every schedule driver needs per iteration; kept here so
    all schedules draw jitter through the identical keying (engine BSP,
    pipelined frontiers, local-SGD rounds all replay the same scales for
    the same (seed, job, worker, iteration))."""
    return np.array([w.scale(seed, job, wi, iteration)
                     for wi, w in enumerate(workers)], dtype=np.float64)


def make_workers(n: int, *, slow: dict[int, float] | None = None,
                 jitter_sigma: float = 0.0,
                 prefix: str = "w") -> list[WorkerProfile]:
    """Build ``n`` workers; ``slow`` maps worker index -> slowdown factor."""
    if n < 1:
        raise ValueError("need at least one worker")
    slow = slow or {}
    bad = [i for i in slow if not 0 <= i < n]
    if bad:
        raise ValueError(f"straggler indices out of range: {bad}")
    return [WorkerProfile(f"{prefix}{i}", slowdown=slow.get(i, 1.0),
                          jitter_sigma=jitter_sigma)
            for i in range(n)]
