"""Host-sharded data pipeline with background prefetch.

Each host materializes only its shard of the global batch (process_index /
process_count in a real multi-host launch; a single CPU host here).  The
pipeline is stateless across restarts — ``start_step`` is the only resume
token, persisted in the checkpoint.
"""

from __future__ import annotations

import queue
import threading

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.synthetic import (SyntheticConfig, synthetic_batch,
                                  synthetic_embeds)


class DataPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 batch_override: int = 0, shard: int = 0,
                 num_shards: int = 1, prefetch: int = 2):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.batch = batch_override or shape.global_batch
        self.shard = shard
        self.num_shards = num_shards
        self.syn = SyntheticConfig(
            vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
            mask_prefix=cfg.frontend_prefix_len)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for ``step`` (exact resume / replay)."""
        b = synthetic_batch(self.syn, self.seed, step, self.batch,
                            self.shard, self.num_shards)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
        if self.cfg.enc_dec:
            out["enc_embeds"] = synthetic_embeds(
                self.seed, step, self.batch, self.shape.seq_len,
                self.cfg.d_model)
        if self.cfg.frontend == "vision":
            out["prefix_embeds"] = synthetic_embeds(
                self.seed, step, self.batch, self.cfg.frontend_prefix_len,
                self.cfg.d_model)
        return out

    # --- background prefetch -------------------------------------------
    def start(self, start_step: int = 0):
        self._stop.clear()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def next(self, timeout: float = 60.0) -> dict:
        return self._q.get(timeout=timeout)

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
