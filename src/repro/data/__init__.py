from repro.data.synthetic import synthetic_batch, SyntheticConfig
from repro.data.pipeline import DataPipeline
