"""Deterministic synthetic token pipeline.

Batches are a pure function of (seed, step, shard) — the property that
makes checkpoint-resume and elastic re-sharding exact: a restarted or
re-scaled job regenerates byte-identical batches for any step without
persisting a data-reader state.  Tokens follow a Zipf-ish unigram draw with
a repeated-ngram structure so the LM loss is learnable (examples/ show it
descending) rather than irreducible uniform noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    zipf_a: float = 1.2
    ngram: int = 8          # repeat period -> learnable structure
    mask_prefix: int = 0    # label-mask the first N positions (vlm stub)


def synthetic_batch(cfg: SyntheticConfig, seed: int, step: int,
                    batch: int, shard: int = 0, num_shards: int = 1) -> dict:
    """Return {tokens, labels} with shapes [batch, seq_len] (numpy)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard, num_shards]))
    v = cfg.vocab_size
    # zipf-ish unigram over a truncated vocab for speed
    base = rng.integers(1, max(2, v // 4), size=(batch, cfg.ngram))
    reps = -(-cfg.seq_len // cfg.ngram) + 1
    seq = np.tile(base, (1, reps))[:, :cfg.seq_len + 1]
    noise = rng.random((batch, cfg.seq_len + 1)) < 0.1
    seq = np.where(noise, rng.integers(0, v, size=seq.shape), seq)
    tokens = seq[:, :-1].astype(np.int32)
    labels = seq[:, 1:].astype(np.int32)
    if cfg.mask_prefix:
        labels = labels.copy()
        labels[:, :cfg.mask_prefix] = -1
    return {"tokens": tokens, "labels": labels}


def synthetic_embeds(seed: int, step: int, batch: int, length: int,
                     d_model: int, dtype=jnp.bfloat16) -> jax.Array:
    """Stub modality frontend: deterministic 'precomputed' embeddings."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
    arr = rng.standard_normal((batch, length, d_model), dtype=np.float32)
    return jnp.asarray(arr * 0.02, dtype)
