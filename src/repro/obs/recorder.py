"""Flight recorder: a bounded ring of structured per-iteration records.

One record schema for the whole repo.  The cluster simulator
(``repro.sim.engine``) emits an :class:`IterationRecord` per finished
iteration when a recorder is attached to the :class:`ClusterSim`; the
real training loop emits the *same* dataclass from its host-side timing
hook (``repro.train.step.instrument_step``) — which is what makes the
sim→real measurement loop one spine instead of two ad-hoc channels.
Planner/co-planner decisions and drift alerts ride along as
:class:`EventRecord` entries in the same ring.

Disciplines inherited from the golden-trace machinery:

* the ring is **bounded** (``capacity``): attaching a recorder to an
  unboundedly long run cannot grow memory without bound; evictions are
  counted, never silent;
* JSONL round-trips are **lossless**: ``json`` serializes Python floats
  via ``repr`` so :func:`read_jsonl` reproduces every record
  bit-for-bit (asserted by the round-trip tests — the same gate the
  Chrome traces pass).

This module is stdlib-only; it may import siblings in ``repro.obs`` but
nothing from ``repro.sim`` / ``repro.core`` / ``repro.train`` (they
import *us*).
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
from typing import Iterable, Sequence

from repro.obs.timeline import Span


@dataclasses.dataclass(frozen=True)
class BucketRecord:
    """One bucket's gradient synchronization inside one iteration
    (mirrors ``repro.sim.engine.BucketTiming`` minus the iteration
    index, which lives on the parent record)."""

    bucket: int
    nbytes: int
    ready: float        # bucket's last gradient produced
    start: float        # collective issued
    end: float          # collective completed
    comm_s: float = -1.0   # fabric occupancy; < 0 means "use end - start"

    @property
    def duration(self) -> float:
        return self.comm_s if self.comm_s >= 0 else self.end - self.start


@dataclasses.dataclass(frozen=True)
class IterationRecord:
    """One training iteration, simulator- or real-run-sourced.

    ``source`` distinguishes provenance (``"sim"`` | ``"train"``), not
    schema: both producers fill the same fields, with real runs leaving
    the engine-only telemetry (worker frontiers, link accounting) empty
    and flagging estimated bucket timings in ``args``.
    """

    source: str
    job: str
    iteration: int
    start: float
    end: float
    backward_end: float
    staleness: int = 0
    buckets: tuple[BucketRecord, ...] = ()
    worker_compute: tuple[tuple[str, float], ...] = ()
    worker_start: tuple[tuple[str, float], ...] = ()
    worker_end: tuple[tuple[str, float], ...] = ()
    link_bytes: tuple[tuple[str, float], ...] = ()
    link_busy: tuple[tuple[str, float], ...] = ()
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def t_iter(self) -> float:
        return self.end - self.start

    @property
    def comm_total(self) -> float:
        return sum(b.duration for b in self.buckets)


@dataclasses.dataclass(frozen=True)
class EventRecord:
    """A point decision/alert: planner replans, co-plan rounds, drift
    alerts.  ``time`` is in the emitter's own clock (sim seconds, host
    wall seconds, or a round counter — recorded in ``args`` by
    convention when ambiguous)."""

    kind: str
    time: float
    source: str = "sim"
    job: str = ""
    args: dict = dataclasses.field(default_factory=dict)


Record = IterationRecord | EventRecord


class FlightRecorder:
    """Bounded in-memory ring of :class:`IterationRecord` /
    :class:`EventRecord`, in arrival order."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self.evicted = 0
        self.recorded = 0

    def __len__(self) -> int:
        return len(self._ring)

    def record(self, rec: Record) -> None:
        if not isinstance(rec, (IterationRecord, EventRecord)):
            raise TypeError(f"not a record: {rec!r}")
        if len(self._ring) == self.capacity:
            self.evicted += 1
        self._ring.append(rec)
        self.recorded += 1

    @property
    def records(self) -> tuple[Record, ...]:
        return tuple(self._ring)

    def iterations(self, job: str | None = None) -> list[IterationRecord]:
        return [r for r in self._ring if isinstance(r, IterationRecord)
                and (job is None or r.job == job)]

    def events(self, kind: str | None = None) -> list[EventRecord]:
        return [r for r in self._ring if isinstance(r, EventRecord)
                and (kind is None or r.kind == kind)]

    def clear(self) -> None:
        self._ring.clear()
        self.evicted = 0
        self.recorded = 0

    def write(self, path: str) -> None:
        write_jsonl(path, self._ring)


# ---------------------------------------------------------------------------
# JSONL round-trip (lossless — the golden-trace discipline).
# ---------------------------------------------------------------------------

def record_to_obj(rec: Record) -> dict:
    if isinstance(rec, IterationRecord):
        obj = dataclasses.asdict(rec)
        obj["type"] = "iteration"
        return obj
    obj = dataclasses.asdict(rec)
    obj["type"] = "event"
    return obj


def _pairs(raw) -> tuple[tuple[str, float], ...]:
    return tuple((str(k), v) for k, v in raw)


def record_from_obj(obj: dict) -> Record:
    kind = obj.get("type")
    if kind == "iteration":
        return IterationRecord(
            source=obj["source"], job=obj["job"],
            iteration=obj["iteration"], start=obj["start"], end=obj["end"],
            backward_end=obj["backward_end"],
            staleness=obj.get("staleness", 0),
            buckets=tuple(BucketRecord(**b) for b in obj.get("buckets", ())),
            worker_compute=_pairs(obj.get("worker_compute", ())),
            worker_start=_pairs(obj.get("worker_start", ())),
            worker_end=_pairs(obj.get("worker_end", ())),
            link_bytes=_pairs(obj.get("link_bytes", ())),
            link_busy=_pairs(obj.get("link_busy", ())),
            args=dict(obj.get("args", {})))
    if kind == "event":
        return EventRecord(kind=obj["kind"], time=obj["time"],
                           source=obj.get("source", "sim"),
                           job=obj.get("job", ""),
                           args=dict(obj.get("args", {})))
    raise ValueError(f"unknown record type {kind!r}")


def write_jsonl(path: str, records: Iterable[Record]) -> None:
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(record_to_obj(rec)) + "\n")


def read_jsonl(path: str) -> list[Record]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(record_from_obj(json.loads(line)))
    return out


# ---------------------------------------------------------------------------
# Producers' helpers.
# ---------------------------------------------------------------------------

def plan_fingerprint(plan) -> str:
    """Deterministic short id of a merge plan's bucket structure — the
    "which plan was live" tag on decision events and iteration records.
    Accepts a ``MergePlan`` or a bare buckets tuple."""
    buckets = getattr(plan, "buckets", plan)
    payload = ";".join(",".join(str(i) for i in b) for b in buckets)
    return hashlib.sha1(payload.encode()).hexdigest()[:12]


def from_iteration_result(result, *, job: str, source: str = "sim",
                          args: dict | None = None) -> IterationRecord:
    """Convert an engine ``IterationResult`` (duck-typed) into the
    shared record schema."""
    return IterationRecord(
        source=source, job=job, iteration=result.index,
        start=result.start, end=result.end,
        backward_end=result.backward_end,
        staleness=result.staleness,
        buckets=tuple(BucketRecord(bucket=b.bucket, nbytes=b.nbytes,
                                   ready=b.ready, start=b.start, end=b.end,
                                   comm_s=b.comm_s)
                      for b in result.buckets),
        worker_compute=tuple(result.worker_compute),
        worker_start=tuple(result.worker_start),
        worker_end=tuple(result.worker_end),
        link_bytes=tuple(result.link_bytes),
        link_busy=tuple(result.link_busy),
        args=dict(args or {}))


def record_spans(records: Sequence[Record], *, pid: str | None = None
                 ) -> list[Span]:
    """Render iteration records as timeline spans — one ``step`` lane
    plus a ``comm`` lane of per-bucket collectives per job.

    For simulator runs the engine already exports richer per-worker /
    per-link spans; this renderer exists so *real-run* records (which
    have no engine spans) land in the same Chrome trace, and the two
    sources line up lane for lane."""
    spans = []
    for rec in records:
        if not isinstance(rec, IterationRecord):
            continue
        group = pid if pid is not None else f"{rec.source}:{rec.job}"
        spans.append(Span(
            name=f"iter{rec.iteration}", cat="step", pid=group, tid="step",
            start=rec.start, end=rec.end,
            args={"iter": rec.iteration, "staleness": rec.staleness,
                  **rec.args}))
        for b in rec.buckets:
            spans.append(Span(
                name=f"allreduce:b{b.bucket}", cat="comm", pid=group,
                tid="comm", start=b.start, end=max(b.end, b.start),
                args={"iter": rec.iteration, "bucket": b.bucket,
                      "bytes": b.nbytes}))
    return spans
