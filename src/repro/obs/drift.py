"""Drift monitor: EWMA residuals of predicted vs observed timings.

MG-WFBP's bucketing is only optimal for the (a, b) model and t_b
profile it was planned against; when the fabric or the compute drifts,
the plan silently degrades.  This module watches two residual families:

* **iteration drift** — the closed-form prediction of the live plan
  (``core.simulator.simulate(...).t_iter`` or a schedule's
  ``predict_t_iter``) vs the observed iteration time;
* **link drift** — each fabric link's modeled occupancy
  ``a_l + b_l * nbytes`` vs the occupancies the engine actually
  measured (``JobResult.link_samples``).

Residuals are *relative* (``|obs - pred| / pred``) and smoothed with an
EWMA so a single jittered iteration does not page anyone; a sustained
residual above ``threshold`` raises a :class:`DriftAlert`, which
callers wire to ``Planner.update`` / ``CoPlanner`` re-entry (see
``repro.sim.scenarios.drift_monitored`` for the end-to-end loop:
degrade bandwidth mid-run -> alert -> refit -> replan -> residual back
under threshold).

Alerts also feed the metrics registry (``obs_drift_alerts_total``) and,
when a :class:`~repro.obs.recorder.FlightRecorder` is attached, land as
``drift_alert`` events in the flight-recorder ring.

Zero heavy deps: only ``repro.obs`` siblings at import time; the
least-squares refit helper imports ``repro.core.cost_model`` lazily so
``repro.obs`` never drags planner code in at import.
"""

from __future__ import annotations

import dataclasses

from repro.obs.metrics import REGISTRY
from repro.obs.recorder import EventRecord, FlightRecorder


@dataclasses.dataclass(frozen=True)
class DriftAlert:
    """One threshold crossing.  ``kind`` is ``"iteration"`` or
    ``"link"``; ``ewma`` is the smoothed relative residual that
    crossed ``threshold``."""

    kind: str
    iteration: int
    ewma: float
    threshold: float
    predicted: float
    observed: float
    link: str = ""

    @property
    def key(self) -> str:
        return f"link:{self.link}" if self.kind == "link" else "iteration"


@dataclasses.dataclass
class _Ewma:
    alpha: float
    value: float = 0.0
    n: int = 0

    def update(self, x: float) -> float:
        self.value = x if self.n == 0 else \
            self.alpha * x + (1.0 - self.alpha) * self.value
        self.n += 1
        return self.value


class DriftMonitor:
    """EWMA drift detection over prediction/observation pairs.

    ``observe`` returns a :class:`DriftAlert` when the smoothed relative
    residual for that key exceeds ``threshold`` (after ``warmup``
    samples), else ``None``.  After the caller reacts (refit + replan),
    call :meth:`reset` so the monitor re-learns against the new model
    instead of alerting on stale residual history.
    """

    def __init__(self, threshold: float = 0.15, alpha: float = 0.5,
                 warmup: int = 1, *,
                 recorder: FlightRecorder | None = None,
                 source: str = "sim", job: str = ""):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if threshold <= 0.0:
            raise ValueError(f"threshold must be > 0, got {threshold}")
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.recorder = recorder
        self.source = source
        self.job = job
        self._ewma: dict[str, _Ewma] = {}
        self.alerts: list[DriftAlert] = []

    def residual(self, key: str = "iteration") -> float:
        st = self._ewma.get(key)
        return st.value if st is not None else 0.0

    def observe(self, iteration: int, predicted: float, observed: float,
                *, link: str = "") -> DriftAlert | None:
        """Feed one prediction/observation pair; returns the alert it
        raised, if any."""
        if predicted <= 0.0:
            return None
        kind = "link" if link else "iteration"
        key = f"link:{link}" if link else "iteration"
        st = self._ewma.setdefault(key, _Ewma(self.alpha))
        ewma = st.update(abs(observed - predicted) / predicted)
        if st.n < self.warmup or ewma <= self.threshold:
            return None
        alert = DriftAlert(kind=kind, iteration=iteration, ewma=ewma,
                           threshold=self.threshold, predicted=predicted,
                           observed=observed, link=link)
        self.alerts.append(alert)
        REGISTRY.counter(
            "obs_drift_alerts_total",
            "drift alerts raised, by kind").inc(kind=kind)
        if self.recorder is not None:
            self.recorder.record(EventRecord(
                kind="drift_alert", time=float(iteration),
                source=self.source, job=self.job,
                args={"drift_kind": kind, "link": link, "ewma": ewma,
                      "threshold": self.threshold, "predicted": predicted,
                      "observed": observed}))
        return alert

    def observe_links(self, iteration: int, model,
                      link_samples: dict) -> list[DriftAlert]:
        """Compare a per-link path model against measured occupancies.

        ``model`` is duck-typed as either a mapping ``link -> object
        with .a/.b`` or an object with ``.paths`` (a sequence of phases
        carrying ``.link``/``.a``/``.b`` — the simulator's path models,
        where a link's affine cost is the sum over its phases).
        ``link_samples`` maps ``link -> [(nbytes, occupancy_s), ...]``
        (the engine's ``JobResult.link_samples``).
        """
        coeffs = _link_coefficients(model)
        out = []
        for link, samples in sorted(link_samples.items()):
            ab = coeffs.get(link)
            if ab is None or not samples:
                continue
            a, b = ab
            for nbytes, occ in samples:
                alert = self.observe(iteration, a + b * nbytes, occ,
                                     link=link)
                if alert is not None:
                    out.append(alert)
        return out

    def reset(self, key: str | None = None) -> None:
        """Forget residual history — for one key, or all of them
        (after a refit+replan)."""
        if key is None:
            self._ewma.clear()
        else:
            self._ewma.pop(key, None)


def _link_coefficients(model) -> dict[str, tuple[float, float]]:
    paths = getattr(model, "paths", None)
    if paths is not None:
        coeffs: dict[str, list[float]] = {}
        for phase in paths:
            cur = coeffs.setdefault(phase.link, [0.0, 0.0])
            cur[0] += phase.a
            cur[1] += phase.b
        return {k: (a, b) for k, (a, b) in coeffs.items()}
    out = {}
    for link, m in dict(model).items():
        out[link] = (m.a, m.b)
    return out


def fit_link_models(link_samples: dict) -> dict:
    """Least-squares refit of each link's affine occupancy model from
    engine samples; links with fewer than two distinct sizes (the fit
    would be degenerate) are skipped.  Returns ``link ->
    AllReduceModel``-like fitted models."""
    from repro.core.cost_model import fit   # lazy: keep obs zero-dep

    out = {}
    for link, samples in sorted(link_samples.items()):
        sizes = [n for n, _ in samples]
        if len(set(sizes)) < 2:
            continue
        out[link] = fit(sizes, [t for _, t in samples], name=f"fit:{link}")
    return out
