"""Observability spine: metrics, flight recorder, timeline, drift.

One telemetry layer shared by the simulator (``repro.sim``), the
planning stack (``repro.core``), and the real training loop
(``repro.train``):

* :mod:`repro.obs.metrics` — labeled Counters/Gauges/Histograms with
  exact snapshot/delta/merge algebra (fixed exponential buckets);
* :mod:`repro.obs.timeline` — the shared :class:`Span` type and
  Chrome/Perfetto trace I/O, plus counter tracks (staleness, frontier
  drift);
* :mod:`repro.obs.recorder` — bounded flight-recorder ring of
  per-iteration / event records with lossless JSONL round-trip;
* :mod:`repro.obs.drift` — EWMA predicted-vs-observed residuals with
  threshold alerts that drive refit + replan.

Import order below matters: ``metrics`` and ``timeline`` are leaves,
``recorder`` uses ``timeline``, ``drift`` uses both.  See
``docs/observability.md``.
"""

from repro.obs.metrics import (
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    Registry,
    Snapshot,
    bucket_index,
    bucket_upper_edge,
    counter,
    gauge,
    histogram,
    merge_all,
)
from repro.obs.timeline import (
    CounterSample,
    Span,
    chrome_counters,
    counter_samples_from,
    from_chrome_trace,
    read_chrome_trace,
    to_chrome_trace,
    write_chrome_trace,
)
from repro.obs.recorder import (
    BucketRecord,
    EventRecord,
    FlightRecorder,
    IterationRecord,
    from_iteration_result,
    plan_fingerprint,
    read_jsonl,
    record_spans,
    write_jsonl,
)
from repro.obs.drift import (
    DriftAlert,
    DriftMonitor,
    fit_link_models,
)

__all__ = [
    "REGISTRY", "Counter", "Gauge", "Histogram", "Registry", "Snapshot",
    "bucket_index", "bucket_upper_edge", "counter", "gauge", "histogram",
    "merge_all",
    "CounterSample", "Span", "chrome_counters", "counter_samples_from",
    "from_chrome_trace", "read_chrome_trace", "to_chrome_trace",
    "write_chrome_trace",
    "BucketRecord", "EventRecord", "FlightRecorder", "IterationRecord",
    "from_iteration_result", "plan_fingerprint", "read_jsonl",
    "record_spans", "write_jsonl",
    "DriftAlert", "DriftMonitor", "fit_link_models",
]
