"""Process-local metrics: labeled counters, gauges, histograms.

Prometheus-shaped but zero-dependency (stdlib only), because the
producers live everywhere — the planner's inner loop, the event engine,
the real train step's host side — and none of them may grow a
dependency for the privilege of counting things.

Design points:

* **Labels** are keyword arguments at observation time; each distinct
  label set is its own series (``counter.inc(job="a")`` and
  ``counter.inc(job="b")`` never mix).
* **Histograms use fixed exponential buckets**: a value ``v > 0`` lands
  in bucket ``e`` where ``v ∈ [2^(e-1), 2^e)`` — the binary exponent
  from ``math.frexp``.  Every histogram everywhere shares the same
  bucket edges, so merging two histograms is an *exact* per-bucket
  integer sum — no re-binning error, no configuration to mismatch.
  Non-positive values land in a reserved underflow bucket.
* **Snapshots** (:meth:`Registry.snapshot`) are immutable copies with
  three exact algebraic operations: ``delta`` (what happened since an
  earlier snapshot — counters and histogram buckets subtract,
  monotonically non-negative), ``merge`` (combine two processes' or two
  runs' snapshots — counters/histograms sum exactly, gauges are
  last-write-wins from the right operand), and a lossless
  ``to_dict``/``from_dict`` JSON round-trip (``BENCH_metrics.json``).

A module-level default :data:`REGISTRY` is the single spine the
instrumented call sites share; tests and tools diff snapshots instead
of assuming absolute values, so accumulated state never invalidates
them.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Mapping

# Reserved exponential-bucket index for values <= 0.  Every float's
# frexp exponent is > -1075 (the subnormal floor), so this never
# collides with a real bucket.
UNDERFLOW_BUCKET = -1100


def bucket_index(value: float) -> int:
    """Fixed exponential bucket of ``value``: ``v ∈ [2^(e-1), 2^e) -> e``."""
    if value <= 0 or math.isnan(value):
        return UNDERFLOW_BUCKET
    if math.isinf(value):
        return 1025                       # above every finite exponent
    return math.frexp(value)[1]


def bucket_upper_edge(index: int) -> float:
    """Upper edge ``2^index`` of a bucket (0.0 for the underflow bucket)."""
    if index == UNDERFLOW_BUCKET:
        return 0.0
    try:
        return math.ldexp(1.0, index)
    except OverflowError:
        return math.inf


def _label_key(labels: Mapping[str, object]) -> str:
    """Canonical series key: sorted ``k=v`` pairs joined by ``|``."""
    if not labels:
        return ""
    for k, v in labels.items():
        if "=" in k or "|" in k or "=" in str(v) or "|" in str(v):
            raise ValueError(f"label {k}={v!r} contains a reserved char")
    return "|".join(f"{k}={v}" for k, v in sorted(labels.items()))


def parse_label_key(key: str) -> dict[str, str]:
    """Inverse of the canonical series key (string-valued)."""
    if not key:
        return {}
    return dict(part.split("=", 1) for part in key.split("|"))


# ---------------------------------------------------------------------------
# Live metrics.
# ---------------------------------------------------------------------------

class _Metric:
    kind = "?"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict[str, object] = {}

    def label_keys(self) -> list[str]:
        return sorted(self._series)


class Counter(_Metric):
    """Monotonically increasing per-series float."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {value})")
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))


class Gauge(_Metric):
    """Last-written per-series float (set / add)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_label_key(labels)] = float(value)

    def add(self, value: float, **labels) -> None:
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))


@dataclasses.dataclass
class _HistState:
    counts: dict[int, int] = dataclasses.field(default_factory=dict)
    sum: float = 0.0
    count: int = 0
    min: float = math.inf
    max: float = -math.inf


class Histogram(_Metric):
    """Fixed-exponential-bucket histogram (exact merges; see module doc)."""

    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        st = self._series.get(key)
        if st is None:
            st = self._series[key] = _HistState()
        b = bucket_index(value)
        st.counts[b] = st.counts.get(b, 0) + 1
        st.sum += value
        st.count += 1
        st.min = min(st.min, value)
        st.max = max(st.max, value)

    def count(self, **labels) -> int:
        st = self._series.get(_label_key(labels))
        return st.count if st is not None else 0

    def quantile(self, q: float, **labels) -> float:
        st = self._series.get(_label_key(labels))
        if st is None or st.count == 0:
            return 0.0
        return _hist_quantile(st.counts, st.count, st.min, st.max, q)


def _hist_quantile(counts: Mapping[int, int], total: int, vmin: float,
                   vmax: float, q: float) -> float:
    """Upper-edge quantile estimate from exponential buckets, clamped to
    the observed [min, max] so single-value series are exact."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile {q} outside [0, 1]")
    rank = q * total
    seen = 0.0
    for b in sorted(counts):
        seen += counts[b]
        if seen >= rank:
            return min(max(bucket_upper_edge(b), vmin), vmax)
    return vmax


# ---------------------------------------------------------------------------
# Snapshots: immutable, exact delta/merge, JSON round-trip.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Frozen copy of a registry.

    ``metrics`` maps name -> {"kind", "help", "series": {label_key:
    payload}} where payload is a float (counter/gauge) or a histogram
    dict {"counts": {bucket: n}, "sum", "count", "min", "max"}.
    """

    metrics: dict

    def value(self, name: str, **labels) -> float:
        payload = self._payload(name, labels)
        if isinstance(payload, dict):
            raise TypeError(f"{name} is a histogram; use hist()/quantile()")
        return float(payload) if payload is not None else 0.0

    def hist(self, name: str, **labels) -> dict | None:
        payload = self._payload(name, labels)
        if payload is not None and not isinstance(payload, dict):
            raise TypeError(f"{name} is not a histogram")
        return payload

    def quantile(self, name: str, q: float, **labels) -> float:
        h = self.hist(name, **labels)
        if not h or not h["count"]:
            return 0.0
        return _hist_quantile(h["counts"], h["count"], h["min"], h["max"], q)

    def _payload(self, name: str, labels: Mapping[str, object]):
        m = self.metrics.get(name)
        if m is None:
            return None
        return m["series"].get(_label_key(labels))

    def delta(self, earlier: "Snapshot") -> "Snapshot":
        """What happened between ``earlier`` and ``self``.

        Counters and histogram buckets subtract (exact: integer bucket
        counts, and counter floats that only ever accumulated the same
        addends); gauges keep their current value.  Metrics/series
        absent from ``earlier`` pass through whole.
        """
        out = {}
        for name, m in self.metrics.items():
            prev = earlier.metrics.get(name)
            series = {}
            for key, payload in m["series"].items():
                base = prev["series"].get(key) if prev else None
                series[key] = _sub_payload(m["kind"], payload, base)
            out[name] = {"kind": m["kind"], "help": m["help"],
                         "series": series}
        return Snapshot(out)

    def merge(self, other: "Snapshot") -> "Snapshot":
        """Combine two snapshots: counters and histograms sum exactly
        (shared fixed bucket edges), gauges take ``other``'s value where
        both define a series (right-biased last-write-wins)."""
        out = {name: {"kind": m["kind"], "help": m["help"],
                      "series": dict(m["series"])}
               for name, m in self.metrics.items()}
        for name, m in other.metrics.items():
            if name not in out:
                out[name] = {"kind": m["kind"], "help": m["help"],
                             "series": dict(m["series"])}
                continue
            mine = out[name]
            if mine["kind"] != m["kind"]:
                raise TypeError(f"cannot merge {name}: {mine['kind']} vs "
                                f"{m['kind']}")
            for key, payload in m["series"].items():
                base = mine["series"].get(key)
                mine["series"][key] = _add_payload(m["kind"], base, payload)
        return Snapshot(out)

    def to_dict(self) -> dict:
        """JSON-ready form (histogram bucket keys become strings)."""
        out = {}
        for name, m in self.metrics.items():
            series = {}
            for key, payload in m["series"].items():
                if isinstance(payload, dict):
                    payload = dict(payload, counts={
                        str(b): n for b, n in sorted(payload["counts"].items())})
                series[key] = payload
            out[name] = {"kind": m["kind"], "help": m["help"],
                         "series": series}
        return out

    @staticmethod
    def from_dict(obj: Mapping) -> "Snapshot":
        out = {}
        for name, m in obj.items():
            series = {}
            for key, payload in m["series"].items():
                if isinstance(payload, dict):
                    payload = dict(payload, counts={
                        int(b): n for b, n in payload["counts"].items()})
                series[key] = payload
            out[name] = {"kind": m["kind"], "help": m.get("help", ""),
                         "series": series}
        return Snapshot(out)


def _hist_payload(st: _HistState) -> dict:
    return {"counts": dict(st.counts), "sum": st.sum, "count": st.count,
            "min": st.min, "max": st.max}


def _sub_payload(kind: str, payload, base):
    if base is None:
        return dict(payload, counts=dict(payload["counts"])) \
            if isinstance(payload, dict) else payload
    if kind == "gauge":
        return payload
    if kind == "counter":
        return payload - base
    counts = {}
    for b, n in payload["counts"].items():
        d = n - base["counts"].get(b, 0)
        if d:
            counts[b] = d
    # min/max are not delta-able; report the later window's observed range
    return {"counts": counts, "sum": payload["sum"] - base["sum"],
            "count": payload["count"] - base["count"],
            "min": payload["min"], "max": payload["max"]}


def _add_payload(kind: str, base, payload):
    if base is None:
        return dict(payload, counts=dict(payload["counts"])) \
            if isinstance(payload, dict) else payload
    if kind == "gauge":
        return payload                      # right-biased
    if kind == "counter":
        return base + payload
    counts = dict(base["counts"])
    for b, n in payload["counts"].items():
        counts[b] = counts.get(b, 0) + n
    return {"counts": counts, "sum": base["sum"] + payload["sum"],
            "count": base["count"] + payload["count"],
            "min": min(base["min"], payload["min"]),
            "max": max(base["max"], payload["max"])}


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

class Registry:
    """Named metrics with get-or-create semantics (kind-checked)."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def reset(self) -> None:
        """Drop every series (metric definitions survive)."""
        for m in self._metrics.values():
            m._series.clear()

    def snapshot(self) -> Snapshot:
        out = {}
        for name, m in self._metrics.items():
            series = {}
            for key, payload in m._series.items():
                series[key] = _hist_payload(payload) \
                    if isinstance(payload, _HistState) else payload
            out[name] = {"kind": m.kind, "help": m.help, "series": series}
        return Snapshot(out)


#: The process-wide default registry every instrumented site shares.
REGISTRY = Registry()


def counter(name: str, help: str = "") -> Counter:
    return REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "") -> Histogram:
    return REGISTRY.histogram(name, help)


def merge_all(snapshots: Iterable[Snapshot]) -> Snapshot:
    """Fold :meth:`Snapshot.merge` over many snapshots (exact for
    counters/histograms regardless of grouping — the associativity the
    property tests pin)."""
    out = Snapshot({})
    for s in snapshots:
        out = out.merge(s)
    return out
