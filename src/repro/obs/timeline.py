"""Unified timeline: the shared span type + Chrome-trace I/O.

:class:`Span` started life in ``repro.sim.trace`` as the engine's trace
event; it is now the **shared** span type of the whole repo — engine
runs, co-planning rounds, and real-train-step records all export through
the same Chrome/Perfetto JSON (``sim.trace`` re-exports everything here,
so existing imports keep working and the golden-trace pins are
unchanged byte for byte).

Two event families:

* **complete spans** (``ph: "X"``) — one box per (pid, tid) lane;
  ``ts``/``dur`` are spec-standard microseconds while the ``ts_s`` /
  ``end_s`` sidecar fields (ignored by viewers) keep the exact float
  seconds, so :func:`from_chrome_trace` round-trips losslessly — the
  acceptance gate for every scenario run and the flight recorder's
  JSONL discipline (``repro.obs.recorder``);
* **counter tracks** (``ph: "C"``) — numeric series rendered as stacked
  area charts in Perfetto.  :func:`counter_samples_from` surfaces
  per-iteration ``staleness`` and per-worker frontier drift as counter
  tracks next to a job's span lanes, which is what makes
  LocalSGD/async schedules visually debuggable.  The ``ts_s`` sidecar
  keeps counters lossless too (:func:`chrome_counters`).

This module is dependency-free (stdlib only) by design: everything in
``repro.obs`` must be importable from the planner, the simulator, and
the real training loop without dragging either one in.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Sequence

_US = 1e6   # chrome trace timestamps are microseconds


@dataclasses.dataclass(frozen=True)
class Span:
    """One complete ("ph": "X") trace event."""

    name: str
    cat: str          # "compute" | "comm" | "network" | "step" | ...
    pid: str          # job name (or "background")
    tid: str          # worker name or "link:<name>"
    start: float      # seconds
    end: float        # seconds
    args: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.end < self.start:
            raise ValueError(f"span ends before it starts: {self}")


@dataclasses.dataclass(frozen=True)
class CounterSample:
    """One counter ("ph": "C") trace event: a numeric multi-series sample.

    ``values`` maps series name -> value; Perfetto stacks the series of
    one counter track.  Counter tracks group by (pid, name) — one sample
    per observation time.
    """

    name: str
    pid: str
    time: float                 # seconds
    values: dict = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Chrome trace export / import (round-trips exactly).
# ---------------------------------------------------------------------------

def to_chrome_trace(spans: Sequence[Span],
                    counters: Sequence[CounterSample] = ()) -> dict:
    """Chrome/Perfetto "X" events; ``ts``/``dur`` are microseconds per the
    trace-event spec, while ``ts_s``/``end_s`` (ignored by viewers) keep
    the exact float seconds so a round-trip is lossless.  ``counters``
    append as "C" events after the spans (with a ``ts_s`` sidecar of
    their own); with no counters the output is byte-identical to the
    historical spans-only format, which is what keeps the golden-trace
    pins valid."""
    events = []
    for s in spans:
        events.append({
            "name": s.name, "cat": s.cat, "ph": "X",
            "pid": s.pid, "tid": s.tid,
            "ts": s.start * _US, "dur": (s.end - s.start) * _US,
            "ts_s": s.start, "end_s": s.end,
            "args": dict(s.args),
        })
    for c in counters:
        events.append({
            "name": c.name, "cat": "counter", "ph": "C",
            "pid": c.pid, "ts": c.time * _US, "ts_s": c.time,
            "args": dict(c.values),
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def from_chrome_trace(obj: dict) -> list[Span]:
    spans = []
    for ev in obj.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        if "ts_s" in ev:                      # our lossless sidecar fields
            start, end = ev["ts_s"], ev["end_s"]
        else:                                 # foreign chrome trace
            start = ev["ts"] / _US
            end = start + ev["dur"] / _US
        spans.append(Span(name=ev["name"], cat=ev.get("cat", ""),
                          pid=str(ev["pid"]), tid=str(ev["tid"]),
                          start=start, end=end,
                          args=dict(ev.get("args", {}))))
    return spans


def chrome_counters(obj: dict) -> list[CounterSample]:
    """The counter ("C") events of a trace, losslessly (via ``ts_s``)."""
    out = []
    for ev in obj.get("traceEvents", []):
        if ev.get("ph") != "C":
            continue
        t = ev["ts_s"] if "ts_s" in ev else ev["ts"] / _US
        out.append(CounterSample(name=ev["name"], pid=str(ev["pid"]),
                                 time=t, values=dict(ev.get("args", {}))))
    return out


def write_chrome_trace(path: str, spans: Sequence[Span],
                       counters: Sequence[CounterSample] = ()) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(spans, counters), f)


def read_chrome_trace(path: str) -> list[Span]:
    with open(path) as f:
        return from_chrome_trace(json.load(f))


# ---------------------------------------------------------------------------
# Counter tracks from job results: staleness + frontier drift.
# ---------------------------------------------------------------------------

def counter_samples_from(job_result, pid: str | None = None
                         ) -> list[CounterSample]:
    """Per-iteration counter tracks for one job result (duck-typed:
    anything with ``.iterations`` carrying ``index`` / ``end`` /
    ``staleness`` / ``worker_end``).

    Two tracks, sampled at each iteration's end:

    * ``staleness`` — local steps since the last global sync
      (:class:`repro.sim.engine.IterationResult.staleness`): flat 0 for
      synchronous schedules, a sawtooth for LocalSGD(H);
    * ``frontier_drift`` — per-worker series of each worker's frontier
      lag ``max_w(worker_end) - worker_end[w]``: all-zero under BSP's
      barrier, visibly fanning out for drifting schedules.

    The tracks live in their own ``pid`` group (default
    ``"<job>/counters"``) so they sit next to, not inside, the span
    lanes in Perfetto.
    """
    name = getattr(job_result, "name", "job")
    group = pid if pid is not None else f"{name}/counters"
    out = []
    for it in job_result.iterations:
        out.append(CounterSample(name="staleness", pid=group, time=it.end,
                                 values={"staleness": it.staleness}))
        ends = dict(it.worker_end)
        if ends:
            frontier = max(ends.values())
            out.append(CounterSample(
                name="frontier_drift", pid=group, time=it.end,
                values={w: frontier - e for w, e in sorted(ends.items())}))
    return out
