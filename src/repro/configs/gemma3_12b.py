"""gemma3-12b — dense GQA, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

48L, d_model 3840, 16 heads (GQA kv=8, head_dim 256), d_ff 15360,
vocab 262144.  Local layers use a 1024-token sliding window (ring-buffer KV
cache); every 6th layer is global.  long_500k RUNS for this arch: local
layers are windowed, only the 8 global layers carry full-length KV.
"""

from repro.configs.base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    tie_embeddings=True,
    act="swiglu",
    rope_theta=1e6,
    sliding_window=1024,
    global_interval=6,
)

PARALLEL = ParallelConfig(zero=1, seq_shard_decode=True)
MICROBATCH = {"train_4k": 4}
SKIP_SHAPES = {}
