"""stablelm-1.6b — dense MHA (kv == heads).
[hf:stabilityai/stablelm-2-1_6b; unverified]

24L, d_model 2048, 32 heads (kv=32, head_dim 64), d_ff 5632, vocab 100352.
"""

from repro.configs.base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    act="swiglu",
    rope_theta=1e4,
)

PARALLEL = ParallelConfig(zero=1, tp_enabled=False)
MICROBATCH = {"train_4k": 8}
SKIP_SHAPES = {"long_500k": "pure full-attention arch: 524k decode is not "
                            "sub-quadratic-servable (DESIGN.md §5)"}
