"""jamba-v0.1-52b — Mamba + attention 1:7 hybrid with 16-expert MoE.
[arXiv:2403.19887; hf]

32L, d_model 4096, 32 heads (GQA kv=8, head_dim 128), d_ff 14336,
vocab 65536.  One attention layer per 8 (position 4 of each period), MoE
(16 routed experts, top-2) on every other layer.  Heterogeneous per-layer
backward times make its MG-WFBP plan the most structured of the pool.
long_500k RUNS (hybrid: only 4 layers carry full-length KV).
"""

from repro.configs.base import (MambaConfig, ModelConfig, MoEConfig,
                                ParallelConfig)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    act="swiglu",
    rope_theta=1e4,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
    moe_interval=2,
    attn_interval=8,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)

PARALLEL = ParallelConfig(zero=1, ep_axis="data")
MICROBATCH = {"train_4k": 2}
SKIP_SHAPES = {}
