"""Configuration schema: model architecture, input shapes, parallelism.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``repro/configs/<id>.py``); the four assigned input shapes are global
(:data:`SHAPES`).  Parallelism / communication-scheduling options live in
:class:`ParallelConfig` — ``comm_strategy`` selects the paper's MG-WFBP plan
or one of its baselines (WFBP, SyncEASGD-single, fixed-size buckets).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # FFN hidden size per routed expert
    num_shared_experts: int = 0   # deepseek-moe: always-on shared experts
    shared_d_expert: int = 0      # hidden size of each shared expert
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "swiglu"           # swiglu | gelu
    rope_theta: float = 10000.0
    # --- sliding-window / local-global attention (gemma3) ---
    sliding_window: int = 0       # 0 = full attention
    global_interval: int = 0      # every Nth layer is global (rest local)
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    moe_interval: int = 1         # MoE FFN every k-th layer (jamba: 2)
    moe_skip_first: int = 0       # deepseek-moe: first layer is dense FFN
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    # --- hybrid (jamba): attention every k-th layer, Mamba elsewhere ---
    attn_interval: int = 0        # 0 = attention everywhere
    mamba: Optional[MambaConfig] = None
    # --- xLSTM ---
    xlstm_slstm_interval: int = 0  # every k-th block is sLSTM (rest mLSTM)
    # --- encoder-decoder (whisper) ---
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq_len: int = 1500       # encoder positions (whisper frames / 2)
    # --- modality frontend stub ---
    frontend: str = ""            # "" | "vision" | "audio"
    frontend_prefix_len: int = 0  # patch/frame embeddings prepended to text
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def block_kind(self, layer_idx: int) -> dict:
        """Describe the block at ``layer_idx`` (mixer type, ffn type,
        attention window).  This drives both model construction and the
        repeat-group decomposition used for scanned stacks."""
        if self.attn_interval > 0 and self.mamba is not None:
            mixer = "attn" if layer_idx % self.attn_interval == (
                self.attn_interval // 2) else "mamba"
        elif self.xlstm_slstm_interval > 0:
            mixer = ("slstm" if layer_idx % self.xlstm_slstm_interval ==
                     self.xlstm_slstm_interval - 1 else "mlstm")
        elif self.family == "ssm":
            mixer = "mlstm"
        else:
            mixer = "attn"
        window = 0
        if self.sliding_window and self.global_interval:
            is_global = layer_idx % self.global_interval == self.global_interval - 1
            window = 0 if is_global else self.sliding_window
        elif self.sliding_window:
            window = self.sliding_window
        if self.moe is not None and layer_idx >= self.moe_skip_first and (
                layer_idx % self.moe_interval == self.moe_interval - 1):
            ffn = "moe"
        elif self.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"   # xlstm blocks carry their own projections
        return {"mixer": mixer, "ffn": ffn, "window": window}

    def repeat_period(self) -> int:
        """Length of the repeating block pattern (scan group size)."""
        kinds = [tuple(sorted(self.block_kind(i).items()))
                 for i in range(self.moe_skip_first, self.num_layers)]
        n = len(kinds)
        for period in range(1, n + 1):
            if n % period == 0 and all(
                    kinds[i] == kinds[i % period] for i in range(n)):
                return period
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    dp_axes: tuple[str, ...] = ("data",)   # manual (shard_map) DP axes
    tp_axis: str = "model"                 # GSPMD auto axis
    tp_enabled: bool = True                # False: model axis joins DP
                                           # (small models: TP-16 of 12-head
                                           # attention only buys gathers)
    ep_axis: str = ""                      # "" = experts TP-sharded only
    zero: int = 0                          # 0 replicated, 1 ZeRO-1, 3 FSDP
    comm_strategy: str = "mgwfbp"          # wfbp|single|mgwfbp|dp_optimal|fixed:N
    hierarchical: bool = True              # pod-aware two-level collectives
    wire_dtype: str = ""                   # "" native | "bfloat16" compress
    remat: str = "block"                   # none | block | alternating
                                           # (alternating: remat every 2nd
                                           # group — halves recompute FLOPs
                                           # for ~1 group of live internals)
    scan_layers: bool = True
    attn_chunk: int = 1024                 # KV chunk for online-softmax attn
    seq_shard_decode: bool = False         # shard KV seq over data (batch=1)
    # --- MoE perf knobs (§Perf iterations) ---
    moe_token_shard: bool = False          # shard expert compute over the
                                           # capacity dim instead of d_ff:
                                           # removes the TP all-reduce of the
                                           # 7.5x-capacity down-proj output
                                           # at the cost of replicating
                                           # expert weights across TP
    moe_combine_dtype: str = ""            # "" = fp32 combine (baseline);
                                           # "bfloat16" halves a2a cotangent
                                           # traffic
    moe_capacity_factor: float = 0.0       # 0 = config default
    # --- merged-gradient execution ---
    pack_kernel: bool = False              # route bucket pack/unpack through
                                           # the kernels/bucket_pack Pallas
                                           # kernel (paper §5.3 contiguous
                                           # buffers); False = fused variadic
                                           # psum (TPU-native default)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = ParallelConfig()
    seed: int = 0
    learning_rate: float = 3e-4
    warmup_steps: int = 100                # LR schedule warmup length
    total_steps: int = 10000               # LR schedule horizon
    weight_decay: float = 0.01
    optimizer: str = "adamw"               # adamw | sgdm
    optimizer_state_dtype: str = "float32" # bf16 moments for 480B-class
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    sgd_momentum: float = 0.9
    grad_clip: float = 1.0
    microbatch: int = 0                    # 0 = no gradient accumulation


def reduced(cfg: ModelConfig, *, num_layers: int = 2, d_model: int = 64,
            num_heads: int = 4, num_kv_heads: int = 0, d_ff: int = 128,
            vocab_size: int = 512, num_experts: int = 0) -> ModelConfig:
    """Small same-family config for CPU smoke tests.

    Keeps every structural feature (GQA ratio, MoE, hybrid pattern, enc-dec,
    sliding window) while shrinking widths/depths.
    """
    kv = num_kv_heads or max(1, num_heads * cfg.num_kv_heads // cfg.num_heads)
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=num_experts or min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, num_experts or 8),
            d_expert=max(32, d_ff // 4),
            shared_d_expert=(max(32, d_ff // 4)
                             if cfg.moe.num_shared_experts else 0),
        )
    updates = dict(
        num_layers=num_layers, d_model=d_model, num_heads=num_heads,
        num_kv_heads=kv, d_ff=d_ff if cfg.d_ff > 0 else 0,
        vocab_size=vocab_size, head_dim=0, moe=moe,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
    )
    if cfg.enc_dec:
        updates["enc_layers"] = max(1, num_layers // 2)
        updates["enc_seq_len"] = 32
    if cfg.attn_interval:
        updates["attn_interval"] = min(cfg.attn_interval, num_layers)
    if cfg.global_interval:
        updates["global_interval"] = min(cfg.global_interval, num_layers)
    if cfg.xlstm_slstm_interval:
        updates["xlstm_slstm_interval"] = min(cfg.xlstm_slstm_interval,
                                              num_layers)
    if cfg.mamba is not None:
        updates["mamba"] = dataclasses.replace(cfg.mamba, d_state=8)
    if cfg.frontend:
        updates["frontend_prefix_len"] = 8
    return dataclasses.replace(cfg, **updates)
