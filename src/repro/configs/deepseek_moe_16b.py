"""deepseek-moe-16b — fine-grained MoE: 64 routed top-6 + 2 shared experts.
[arXiv:2401.06066; hf]

28L, d_model 2048, 16 heads (kv=16, head_dim 128), expert d_ff 1408,
vocab 102400.  Layer 0 is a dense FFN (d_ff 10944, faithful to the release);
layers 1..27 route over 64 experts (top-6) with 2 always-on shared experts.
Experts are expert-parallel over the data axis (owned, no DP all-reduce);
the MG-WFBP plan covers the replicated attention/shared tensors.
"""

from repro.configs.base import ModelConfig, MoEConfig, ParallelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,              # layer-0 dense FFN
    vocab_size=102400,
    act="swiglu",
    rope_theta=1e4,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                  num_shared_experts=2, shared_d_expert=1408),
    moe_skip_first=1,
)

PARALLEL = ParallelConfig(zero=1, ep_axis="data")
MICROBATCH = {"train_4k": 8}
SKIP_SHAPES = {"long_500k": "pure full-attention arch: 524k decode is not "
                            "sub-quadratic-servable (DESIGN.md §5)"}
