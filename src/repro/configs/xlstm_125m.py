"""xlstm-125m — sLSTM + mLSTM recurrent blocks. [arXiv:2405.04517; unverified]

12L, d_model 768, 4 heads, vocab 50304, d_ff=0 (blocks carry their own
projections).  Every 6th block is sLSTM (sequential scalar memory), the
rest mLSTM (chunkwise-parallel matrix memory).  Many tiny tensors — the
paper's Fig. 5 regime where gradient merging wins most.  long_500k RUNS
(O(1) recurrent state, no KV cache).
"""

from repro.configs.base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm_slstm_interval=6,
)

PARALLEL = ParallelConfig(zero=0, tp_enabled=False)
MICROBATCH = {}
SKIP_SHAPES = {}
