"""whisper-base — encoder-decoder with conv audio frontend (STUB).
[arXiv:2212.04356; unverified]

6 enc + 6 dec layers, d_model 512, 8 heads (kv=8, head_dim 64), d_ff 2048,
vocab 51865.  The conv frontend is a stub per the assignment:
``input_specs`` provides precomputed frame embeddings [B, S_enc, d_model].
Assigned shapes treat seq_len as both the encoder frame count and the
decoder KV length — a structural stress test; the real model caps at
1500 frames / 448 decoder positions (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,              # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    act="gelu",
    rope_theta=1e4,
    enc_dec=True,
    enc_layers=6,
    frontend="audio",
)

PARALLEL = ParallelConfig(zero=0, tp_enabled=False)
MICROBATCH = {}
SKIP_SHAPES = {"long_500k": "enc-dec audio arch: 524k decode inapplicable "
                            "(30 s context; DESIGN.md §5)"}
