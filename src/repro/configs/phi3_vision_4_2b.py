"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

32L, d_model 3072, 32 heads (kv=32, head_dim 96), d_ff 8192, vocab 32064.
The vision tower is a STUB per the assignment: ``input_specs`` provides 576
precomputed patch embeddings ([B, 576, d_model]) prepended to the text
stream; loss is masked over the image prefix.
"""

from repro.configs.base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    act="swiglu",
    rope_theta=1e4,
    frontend="vision",
    frontend_prefix_len=576,
)

PARALLEL = ParallelConfig(zero=1)
MICROBATCH = {"train_4k": 4}
SKIP_SHAPES = {"long_500k": "pure full-attention arch: 524k decode is not "
                            "sub-quadratic-servable (DESIGN.md §5)"}
