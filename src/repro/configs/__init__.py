"""Per-architecture configuration modules + shared schema."""
from repro.configs.base import (ModelConfig, MoEConfig, MambaConfig,
                                ParallelConfig, RunConfig, ShapeConfig,
                                SHAPES, reduced)
