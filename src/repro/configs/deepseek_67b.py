"""deepseek-67b — llama-architecture dense GQA. [arXiv:2401.02954; hf]

95L, d_model 8192, 64 heads (GQA kv=8, head_dim 128), d_ff 22016,
vocab 102400.  67B params do not fit DP-replicated on 16 GB v5e chips:
trains with FSDP (zero=3) — per-layer merged parameter all-gathers whose
schedule reuses the MG-WFBP plan machinery.
"""

from repro.configs.base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    act="swiglu",
    rope_theta=1e4,
)

PARALLEL = ParallelConfig(zero=3)
MICROBATCH = {"train_4k": 1}
SKIP_SHAPES = {"long_500k": "pure full-attention arch: 524k decode is not "
                            "sub-quadratic-servable (DESIGN.md §5)"}
