"""arctic-480b — 128-expert top-2 MoE with a dense FFN residual.
[hf:Snowflake/snowflake-arctic-base; hf]

35L, d_model 7168, 56 heads (GQA kv=8, head_dim 128), d_ff 4864 (both the
dense residual and each expert), vocab 32000.  ~470B total params: experts
are 2-D sharded (expert dim over the data axis × hidden over the model
axis) and optimizer moments are kept in bf16 so the full training state
fits 16 GB/chip on the 256-chip pod (see EXPERIMENTS.md §Dry-run).
"""

from repro.configs.base import ModelConfig, MoEConfig, ParallelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    act="swiglu",
    rope_theta=1e4,
    moe=MoEConfig(num_experts=128, top_k=2, d_expert=4864),
    dense_residual=True,
)

PARALLEL = ParallelConfig(zero=1, ep_axis="data")
MICROBATCH = {"train_4k": 1}
OPTIMIZER_STATE_DTYPE = "bfloat16"
SKIP_SHAPES = {"long_500k": "pure full-attention arch: 524k decode is not "
                            "sub-quadratic-servable (DESIGN.md §5)"}
