"""qwen2-1.5b — dense GQA decoder with QKV bias. [arXiv:2407.10671; hf]

28L, d_model 1536, 12 heads (GQA kv=2, head_dim 128), d_ff 8960,
vocab 151936.  Small-model/high comm-to-compute ratio: the MG-WFBP sweet
spot (paper regime).
"""

from repro.configs.base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    act="swiglu",
    rope_theta=1e6,
)

PARALLEL = ParallelConfig(zero=1, tp_enabled=False)
MICROBATCH = {"train_4k": 8}
SKIP_SHAPES = {"long_500k": "pure full-attention arch: 524k decode is not "
                            "sub-quadratic-servable (DESIGN.md §5)"}
