"""Compiled-HLO cost extraction with while-loop trip-count scaling.

Why this exists: ``compiled.cost_analysis()`` counts the body of a
``lax.scan``-generated while loop exactly **once**, so any scan-over-layers
program under-reports FLOPs/bytes by ~L×.  Framework-scale models must use
scan for compile-time sanity, so the roofline harness re-derives costs by
parsing ``compiled.as_text()``:

* per-computation op costs (dot / convolution FLOPs from shapes +
  contracting dims; bytes from operand/result buffer sizes resolved through
  a per-computation symbol table — compiled HLO prints operands as bare
  ``%name`` refs),
* fusion ops inherit their called computation's FLOPs, with bytes counted
  at the fusion boundary (the HBM-traffic unit in XLA),
* ``while`` ops multiply their body cost by the trip count parsed from the
  condition computation's comparison constant (lax.scan emits
  ``lt(induction, constant(L))`` with a 0-start, step-1 induction),
* collective ops (all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute, sync or ``-start`` async forms) accumulate operand
  bytes, also trip-count scaled.

The parser is intentionally tolerant: unknown ops contribute zero FLOPs and
their boundary bytes only at top level.  It is validated against
``cost_analysis()`` on loop-free programs (tests/test_hlo.py).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# out_type matched lazily up to the first " opcode(" anchor — tuple types
# contain spaces and /*index=N*/ comments, so no char-class can bound them.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|\.\d)" )
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = {
    "all-reduce", "all-reduce-start", "all-gather", "all-gather-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start", "ragged-all-to-all",
}

_BOOKKEEPING_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "async-done", "copy-done", "partition-id",
    "replica-id", "opt-barrier",
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str]:
    """Dims + dtype of the first array shape in a type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], ""
    dtype, dims = m.groups()
    return ([int(d) for d in dims.split(",")] if dims else []), dtype


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_type: str
    rest: str           # raw text after the opcode's open paren


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    types: dict         # op name -> out_type string


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_type: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    dot_flops: float = 0.0
    conv_flops: float = 0.0

    def add(self, other: "HloCost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.collective_bytes += other.collective_bytes * scale
        self.dot_flops += other.dot_flops * scale
        self.conv_flops += other.conv_flops * scale
        for k, v in other.collective_by_type.items():
            self.collective_by_type[k] += v * scale
        for k, v in other.collective_count.items():
            self.collective_count[k] += v * scale

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_type": dict(self.collective_by_type),
            "collective_count": dict(self.collective_count),
            "dot_flops": self.dot_flops,
            "conv_flops": self.conv_flops,
        }


def _operand_list(rest: str) -> tuple[list[str], str]:
    """Split `rest` (text after the op's open paren) into operand names and
    the trailing attribute text."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner, attrs = rest[:i], rest[i + 1:]
                return _OPERAND_RE.findall(inner), attrs
    return _OPERAND_RE.findall(rest), ""


def parse_computations(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if cur is None:
            if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
                if m:
                    cur = Computation(m.group(1), [], {})
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, out_type, opcode, rest = m.groups()
            op = Op(name, opcode, out_type.strip(), rest)
            cur.ops.append(op)
            cur.types[name] = op.out_type
    return comps


def _operand_bytes(op: Op, comp: Computation) -> int:
    names, _ = _operand_list(op.rest)
    return sum(_shape_bytes(comp.types.get(n, "")) for n in names)


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dims, _ = _shape_dims(op.out_type)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    names, attrs = _operand_list(op.rest)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
    if not names or not m:
        return 2.0 * out_elems
    lhs_dims, _ = _shape_dims(comp.types.get(names[0], ""))
    k = 1
    if m.group(1) and lhs_dims:
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_dims, _ = _shape_dims(op.out_type)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    names, _ = _operand_list(op.rest)
    if len(names) < 2:
        return 2.0 * out_elems
    k_dims, _ = _shape_dims(comp.types.get(names[1], ""))
    k_elems = 1
    for d in k_dims[:-1]:   # exclude the output-feature dim
        k_elems *= d
    return 2.0 * out_elems * k_elems


def _trip_count(cond: Computation) -> float:
    """lax.scan conditions compare the induction var with constant(L)."""
    consts = []
    for op in cond.ops:
        if op.opcode == "constant":
            mm = re.match(r"\s*(\-?\d+)\s*\)", op.rest)
            if mm:
                consts.append(int(mm.group(1)))
    if consts:
        return float(max(consts))
    return 1.0


def _called_comps(op: Op) -> dict[str, str]:
    """Map role -> computation name for ops that call computations."""
    _, attrs = _operand_list(op.rest)
    out = {}
    for role in ("calls", "body", "condition", "to_apply"):
        m = re.search(role + r"=[\{]?%?([\w.\-]+)", attrs)
        if m:
            out[role] = m.group(1)
    return out


def analyze(hlo_text: str, entry: str | None = None) -> HloCost:
    comps = parse_computations(hlo_text)
    if not comps:
        return HloCost()
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
        entry = m.group(1) if m else next(iter(comps))

    memo: dict[str, HloCost] = {}

    def comp_cost(name: str, top_level: bool) -> HloCost:
        key = f"{name}@{top_level}"
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # cycle guard
        cost = HloCost()
        comp = comps.get(name)
        if comp is None:
            return cost
        for op in comp.ops:
            oc = HloCost()
            if op.opcode == "dot":
                oc.flops = oc.dot_flops = _dot_flops(op, comp)
                oc.bytes = _shape_bytes(op.out_type) + _operand_bytes(op, comp)
            elif op.opcode == "convolution":
                oc.flops = oc.conv_flops = _conv_flops(op, comp)
                oc.bytes = _shape_bytes(op.out_type) + _operand_bytes(op, comp)
            elif op.opcode in COLLECTIVE_OPS:
                opbytes = _operand_bytes(op, comp)
                kind = op.opcode.replace("-start", "")
                oc.collective_bytes = opbytes
                oc.collective_by_type[kind] += opbytes
                oc.collective_count[kind] += 1
                oc.bytes = _shape_bytes(op.out_type) + opbytes
            elif op.opcode == "fusion":
                called = _called_comps(op).get("calls")
                if called:
                    inner = comp_cost(called, False)
                    oc.add(inner)
                # fusion boundary == HBM traffic unit
                oc.bytes += _shape_bytes(op.out_type) + _operand_bytes(op, comp)
            elif op.opcode == "while":
                roles = _called_comps(op)
                body, cond = roles.get("body"), roles.get("condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1.0
                if body in comps:
                    oc.add(comp_cost(body, True), scale=trips)
                if cond in comps:
                    oc.add(comp_cost(cond, False), scale=trips)
            elif op.opcode in ("call", "conditional", "custom-call",
                               "async-start"):
                for _, cname in _called_comps(op).items():
                    if cname in comps:
                        oc.add(comp_cost(cname, top_level))
                if op.opcode == "custom-call":
                    oc.bytes += _shape_bytes(op.out_type) + _operand_bytes(op, comp)
            elif op.opcode in _BOOKKEEPING_OPS:
                pass
            else:
                # unfused elementwise/copy/reduce etc.
                if top_level:
                    oc.bytes = _shape_bytes(op.out_type) + _operand_bytes(op, comp)
            cost.add(oc)
        memo[key] = cost
        return cost

    return comp_cost(entry, True)


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Convenience: per-collective-type wire bytes (trip-count scaled)."""
    c = analyze(hlo_text)
    return dict(c.collective_by_type)
