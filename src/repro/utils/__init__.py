from repro.utils import hlo
