"""MODEL_FLOPS accounting: 6·N·D (dense) / 6·N_active·D (MoE).

The roofline's "useful compute" reference.  N counts parameters touched
per token: for MoE, routed experts contribute ``top_k / num_experts`` of
their parameters; shared experts and the dense residual always count.
Attention O(S²) FLOPs are excluded per the 6ND convention (noted in
EXPERIMENTS.md; the HLO-derived FLOPs include them, which is one source of
HLO/MODEL ratio > 1 at long sequence).
"""

from __future__ import annotations

import jax

from repro.configs.base import ModelConfig, ShapeConfig


def param_counts(params_shape) -> tuple[int, int]:
    """(total_params, active_params) from an eval_shape'd tree."""
    import re
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        active += n  # corrected below for expert leaves by caller
    return total, active


def model_flops(cfg: ModelConfig, params_shape, shape: ShapeConfig,
                kind: str) -> dict:
    """Returns {total_params, active_params, tokens, model_flops}."""
    import re
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        k = jax.tree_util.keystr(path)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if re.search(r"w_(gate|up|down)_e", k):
            expert += n
    active = total - expert
    if cfg.moe is not None and expert:
        active += expert * cfg.moe.top_k / cfg.moe.num_experts
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch * 1
        factor = 2.0
    return {
        "total_params": int(total),
        "active_params": int(active),
        "tokens": int(tokens),
        "model_flops": factor * active * tokens,
    }
