"""Global-norm gradient clipping (works on pytrees of local shards; pass a
``psum_axes`` to compute the true global norm across sharded grads)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree, psum_axes=None) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    if psum_axes:
        sq = jax.lax.psum(sq, psum_axes)
    return jnp.sqrt(sq)


def clip_by_global_norm(tree, max_norm: float, norm: jax.Array | None = None,
                        psum_axes=None):
    if max_norm <= 0:
        return tree, global_norm(tree, psum_axes)
    n = norm if norm is not None else global_norm(tree, psum_axes)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), n
