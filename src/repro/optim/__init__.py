"""Optimizers built leaf-wise so the same update runs on full pytrees
(replicated DP) or on flat ZeRO shards (merged reduce-scatter buckets)."""

from repro.optim.optimizers import (Optimizer, adamw, sgdm, make_optimizer)
from repro.optim.schedule import warmup_cosine, constant
from repro.optim.clip import global_norm, clip_by_global_norm

__all__ = ["Optimizer", "adamw", "sgdm", "make_optimizer", "warmup_cosine",
           "constant", "global_norm", "clip_by_global_norm"]
