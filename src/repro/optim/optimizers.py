"""AdamW and SGD-momentum with configurable state dtype.

The ``Optimizer`` interface is deliberately leaf-wise-pure: ``init_leaf``
and ``update_leaf`` map over arrays with no tree structure assumptions, so
the identical math runs on

  * full parameter pytrees (DP-replicated training),
  * flat packed ZeRO-1 shards (the merged reduce-scatter path), and
  * per-expert owned shards (EP training).

``state_dtype`` controls moment precision: bf16 moments keep arctic-480b's
training state inside 16 GB/chip (DESIGN.md §5); fp32 is the default.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init_leaf: Callable          # param -> state pytree (dict of arrays)
    update_leaf: Callable        # (g, p, state, step, lr) -> (new_p, state)
    weight_decay_mask: Callable  # path -> bool (True = decay applies)
    # (g32, p, state, step, lr, decay_mask) -> (new_p, state): the update on
    # a flat packed ZeRO-1 shard where decay eligibility is a per-element
    # mask instead of a per-leaf path.  Built by the factories below from
    # the same hyperparameter closure as ``update_leaf``, so the packed
    # path can never drift from the tree path.
    flat_update: Callable = None
    # factory hyperparameters, exposed for introspection/tests
    hyperparams: tuple[tuple[str, float], ...] = ()

    def init(self, params):
        return jax.tree.map(self.init_leaf, params)

    def update(self, grads, params, state, step, lr):
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_s = treedef.flatten_up_to(state)
        new_p, new_s = [], []
        for g, p, s in zip(flat_g, flat_p, flat_s):
            np_, ns = self.update_leaf(g, p, s, step, lr)
            new_p.append(np_)
            new_s.append(ns)
        return (jax.tree_util.tree_unflatten(treedef, new_p),
                jax.tree_util.tree_unflatten(treedef, new_s))


def _no_decay(path: str) -> bool:
    # norms / biases / scalar gains exempt from weight decay
    for token in ("norm", "bias", "b_q", "b_k", "b_v", "b_up", "b_down",
                  "scale", "A_log", "dt_bias", "b_gates", "b_if"):
        if token in path:
            return False
    return True


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01, state_dtype: str = "float32"
          ) -> Optimizer:
    sdt = jnp.dtype(state_dtype)

    def init_leaf(p):
        return {"m": jnp.zeros(p.shape, sdt), "v": jnp.zeros(p.shape, sdt)}

    def update_leaf(g, p, s, step, lr, decay=True):
        g32 = g.astype(jnp.float32)
        m = s["m"].astype(jnp.float32) * b1 + (1 - b1) * g32
        v = s["v"].astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        t = step.astype(jnp.float32) + 1.0
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        if decay and weight_decay:
            upd = upd + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, {"m": m.astype(sdt), "v": v.astype(sdt)}

    def flat_update(g, p, s, step, lr, decay_mask):
        g32 = g.astype(jnp.float32)
        m = s["m"].astype(jnp.float32) * b1 + (1 - b1) * g32
        v = s["v"].astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        t = step.astype(jnp.float32) + 1.0
        upd = (m / (1 - b1 ** t)) / (jnp.sqrt(v / (1 - b2 ** t)) + eps)
        upd = upd + weight_decay * decay_mask * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, {"m": m.astype(sdt), "v": v.astype(sdt)}

    return Optimizer("adamw", init_leaf, update_leaf, _no_decay,
                     flat_update,
                     (("b1", b1), ("b2", b2), ("eps", eps),
                      ("weight_decay", weight_decay)))


def sgdm(momentum: float = 0.9, weight_decay: float = 0.0,
         state_dtype: str = "float32") -> Optimizer:
    sdt = jnp.dtype(state_dtype)

    def init_leaf(p):
        return {"mu": jnp.zeros(p.shape, sdt)}

    def update_leaf(g, p, s, step, lr, decay=True):
        g32 = g.astype(jnp.float32)
        if decay and weight_decay:
            g32 = g32 + weight_decay * p.astype(jnp.float32)
        mu = s["mu"].astype(jnp.float32) * momentum + g32
        new_p = (p.astype(jnp.float32) - lr * mu).astype(p.dtype)
        return new_p, {"mu": mu.astype(sdt)}

    def flat_update(g, p, s, step, lr, decay_mask):
        g32 = g.astype(jnp.float32) + \
            weight_decay * decay_mask * p.astype(jnp.float32)
        mu = s["mu"].astype(jnp.float32) * momentum + g32
        new_p = (p.astype(jnp.float32) - lr * mu).astype(p.dtype)
        return new_p, {"mu": mu.astype(sdt)}

    return Optimizer("sgdm", init_leaf, update_leaf, _no_decay,
                     flat_update,
                     (("momentum", momentum), ("weight_decay", weight_decay)))


def make_optimizer(name: str, *, weight_decay: float = 0.01,
                   state_dtype: str = "float32", b1: float = 0.9,
                   b2: float = 0.95, eps: float = 1e-8,
                   momentum: float = 0.9) -> Optimizer:
    if name == "adamw":
        return adamw(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
                     state_dtype=state_dtype)
    if name == "sgdm":
        return sgdm(momentum=momentum, weight_decay=weight_decay,
                    state_dtype=state_dtype)
    raise ValueError(f"unknown optimizer {name!r}")
