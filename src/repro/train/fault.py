"""Fault tolerance & elasticity for the training loop.

Mechanisms (scaled-down but production-shaped — see DESIGN.md §4 for the
1000+-node design):

* **checkpoint/restart** — ``run_with_recovery`` wraps the step loop;
  any step exception triggers restore-from-LATEST and replay.  The data
  pipeline is a pure function of the step index, so replayed batches are
  byte-identical.
* **step retry with backoff** — transient failures (preempted host,
  flaky interconnect) retry the same step before escalating.
* **elastic re-plan** — on membership change the MG-WFBP plan depends on
  the cluster only through the all-reduce model's (a, b); ``replan_for``
  recomputes the plan for a new mesh and the caller rebuilds the step.
  Parameters reshard via checkpoint restore (shapes are mesh-invariant).
* **straggler mitigation** — in synchronous SGD the step time is the max
  over workers; ``StragglerMonitor`` tracks per-step wall times and flags
  hosts whose EWMA exceeds the fleet median by a threshold so the launcher
  can evict/replace them (the sync-SGD-compatible mitigation; async
  fallback is out of scope per the paper's S-SGD setting).
"""

from __future__ import annotations

import collections
import logging
import time
from typing import Callable

from repro.core import planner
from repro.train import checkpoint

log = logging.getLogger("repro.fault")


def run_with_recovery(step_fn: Callable, state, pipeline, ckpt: "checkpoint.AsyncCheckpointer",
                      start_step: int, num_steps: int,
                      ckpt_every: int = 50, max_retries: int = 3,
                      state_template=None, on_metrics=None):
    """Drive the training loop with retry + restore-on-failure."""
    step = start_step
    retries = 0
    while step < num_steps:
        batch = pipeline.batch_at(step)
        try:
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0
            if on_metrics:
                on_metrics(step, metrics, dt)
            retries = 0
            step += 1
            if step % ckpt_every == 0:
                ckpt.save(step, state)
        except Exception as e:  # noqa: BLE001 — any step failure
            retries += 1
            log.warning("step %d failed (%s); retry %d/%d", step, e,
                        retries, max_retries)
            if retries > max_retries:
                latest = checkpoint.latest_step(ckpt.ckpt_dir)
                if latest is None:
                    raise
                log.warning("restoring from checkpoint step %d", latest)
                state, step, _ = checkpoint.restore(
                    ckpt.ckpt_dir, state_template or state)
                retries = 0
    ckpt.save(step, state)
    ckpt.wait()
    return state, step


def replan_for(strategy: str, specs, new_mesh_shape, new_mesh_axes,
               dp_axes=("pod", "data")):
    """Elastic resize: new cluster -> new (a, b) -> new optimal plan.

    O(L^2), runs once per membership change (paper §4.2: the plan is a
    one-time computation; elasticity just repeats it)."""
    from repro.core import cost_model
    model = cost_model.production_comm_model(new_mesh_shape, new_mesh_axes,
                                             dp_axes)
    return planner.make_plan(strategy, specs, model), model


class StragglerMonitor:
    """EWMA step-time tracker; flags hosts slower than median * threshold."""

    def __init__(self, alpha: float = 0.2, threshold: float = 1.5,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: dict = {}
        self.counts: dict = collections.Counter()

    def record(self, host: str, step_time: float):
        prev = self.ewma.get(host, step_time)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time
        self.counts[host] += 1

    def stragglers(self) -> list[str]:
        ready = {h: t for h, t in self.ewma.items()
                 if self.counts[h] >= self.warmup}
        if len(ready) < 2:
            return []
        med = sorted(ready.values())[len(ready) // 2]
        return [h for h, t in ready.items() if t > self.threshold * med]
