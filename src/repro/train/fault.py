"""Fault tolerance & elasticity for the training loop.

Mechanisms (scaled-down but production-shaped — see DESIGN.md §4 for the
1000+-node design):

* **checkpoint/restart** — ``run_with_recovery`` wraps the step loop;
  any step exception triggers restore-from-LATEST and replay.  The data
  pipeline is a pure function of the step index, so replayed batches are
  byte-identical.  Restores are budgeted: a persistent failure re-raises
  once ``max_restores`` is spent instead of looping on the same tag.
* **step retry with backoff** — transient failures (preempted host,
  flaky interconnect) retry the same step after a seeded exponential
  backoff with jitter before escalating to a restore.
* **elastic re-plan** — on membership change the MG-WFBP plan depends on
  the cluster only through the all-reduce model's (a, b); ``replan_for``
  recomputes the plan for a new mesh and the caller rebuilds the step.
  Parameters reshard via checkpoint restore (shapes are mesh-invariant).
* **straggler mitigation** — in synchronous SGD the step time is the max
  over workers; ``StragglerMonitor`` tracks per-step wall times and flags
  hosts whose EWMA exceeds the fleet median by a threshold so the launcher
  can evict/replace them (the sync-SGD-compatible mitigation; async
  fallback is out of scope per the paper's S-SGD setting).
"""

from __future__ import annotations

import collections
import logging
import time
from typing import Callable

from repro.core import planner
from repro.train import checkpoint

log = logging.getLogger("repro.fault")


def run_with_recovery(step_fn: Callable, state, pipeline, ckpt: "checkpoint.AsyncCheckpointer",
                      start_step: int, num_steps: int,
                      ckpt_every: int = 50, max_retries: int = 3,
                      state_template=None, on_metrics=None, *,
                      max_restores: int = 3, backoff_base: float = 0.05,
                      backoff_factor: float = 2.0, backoff_max: float = 2.0,
                      jitter: float = 0.25, seed: int = 0,
                      sleep_fn: Callable[[float], None] = time.sleep):
    """Drive the training loop with retry + restore-on-failure.

    Each failed step retries after a seeded exponential backoff with
    jitter; after ``max_retries`` consecutive failures the loop restores
    from the latest checkpoint, and after ``max_restores`` restores a
    persistent failure re-raises instead of looping on the same tag.

    This is the compatibility wrapper over the full supervisor state
    machine in :mod:`repro.train.resilience` (which adds straggler
    eviction, graceful degradation and availability metrics on top);
    both share one retry/restore policy.
    """
    from repro.train import resilience

    policy = resilience.ResiliencePolicy(
        max_retries=max_retries, max_restores=max_restores,
        backoff_base=backoff_base, backoff_factor=backoff_factor,
        backoff_max=backoff_max, jitter=jitter, seed=seed)
    state, step, _ctrl = resilience.run_supervised(
        step_fn, state, pipeline, ckpt, start_step, num_steps,
        ckpt_every=ckpt_every, policy=policy,
        state_template=state_template, on_metrics=on_metrics,
        sleep_fn=sleep_fn)
    return state, step


def replan_for(strategy: str, specs, new_mesh_shape, new_mesh_axes,
               dp_axes=("pod", "data")):
    """Elastic resize: new cluster -> new (a, b) -> new optimal plan.

    O(L^2), runs once per membership change (paper §4.2: the plan is a
    one-time computation; elasticity just repeats it)."""
    from repro.core import cost_model
    model = cost_model.production_comm_model(new_mesh_shape, new_mesh_axes,
                                             dp_axes)
    return planner.make_plan(strategy, specs, model), model


class StragglerMonitor:
    """EWMA step-time tracker; flags hosts slower than median * threshold."""

    def __init__(self, alpha: float = 0.2, threshold: float = 1.5,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: dict = {}
        self.counts: dict = collections.Counter()

    def record(self, host: str, step_time: float):
        prev = self.ewma.get(host, step_time)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time
        self.counts[host] += 1

    def forget(self, host: str) -> None:
        """Drop an evicted host's statistics so its EWMA stops skewing
        the fleet median (and a replacement reusing the name warms up
        from scratch)."""
        self.ewma.pop(host, None)
        self.counts.pop(host, None)

    def stragglers(self) -> list[str]:
        ready = {h: t for h, t in self.ewma.items()
                 if self.counts[h] >= self.warmup}
        if len(ready) < 2:
            return []
        ordered = sorted(ready.values())
        mid = len(ordered) // 2
        med = ordered[mid] if len(ordered) % 2 else \
            0.5 * (ordered[mid - 1] + ordered[mid])
        return [h for h, t in ready.items() if t > self.threshold * med]
