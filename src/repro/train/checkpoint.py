"""Sharded checkpointing: atomic, async, resumable.

Layout (one directory per step)::

    <dir>/step_000123/
        meta.json            # step, tree structure, shapes/dtypes, run id
        shard_00000.npz      # this host's param/opt leaves (addressable)
    <dir>/LATEST             # atomic pointer (renamed into place)

Leaves are saved from each host's *addressable* shards, which makes the
scheme multi-host-correct: every host writes its own ``shard_<pid>.npz``
and restore re-assembles with ``jax.make_array_from_single_device_arrays``
(single-host here, but the code path is the production one).  Writes go to
a temp dir first, every file is fsynced before the rename, and the rename
is atomic — so a crash at ANY point mid-save can never corrupt LATEST or
publish a torn step directory (property-tested at every kill point in
tests/test_checkpoint.py).  ``latest_step`` additionally falls back to
scanning ``step_*`` directories when LATEST is missing or points at a
missing/corrupt tag, so a crash between the step-dir rename and the
LATEST update still resumes from the newest complete step.
``clean_stale_tmp`` sweeps half-written ``.tmp_*`` wreckage on startup and
``gc_keep_last`` bounds disk growth; ``AsyncCheckpointer`` runs both and
moves serialization off the training thread (fault tolerance requirement:
checkpoint cadence must not stall the step loop).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flat_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in flat], treedef


_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
           "int8", "uint64", "uint32", "uint16", "uint8", "bool"}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz can't serialize ml_dtypes (bfloat16, fp8): store a uint view;
    the true dtype lives in meta.json and restore views it back."""
    if str(arr.dtype) in _NATIVE:
        return arr
    width = arr.dtype.itemsize
    return arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[width])


def _from_savable(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) == dtype_str:
        return arr
    import jax.numpy as jnp
    return arr.view(jnp.dtype(dtype_str))


def _fsync_dir(path: str) -> None:
    """Durably record a directory's entries (the rename itself) — best
    effort on filesystems/platforms without directory fsync."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover — e.g. Windows
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover
        pass
    finally:
        os.close(fd)


def save(ckpt_dir: str, step: int, state: Any, extra: dict | None = None):
    """Synchronous sharded save with atomic LATEST update.

    Durability order: shard and meta are written AND fsynced inside the
    temp dir, the temp dir is renamed into place (then the parent
    directory fsynced so the rename survives power loss), and only then
    is LATEST atomically replaced — so LATEST can never point at a step
    that is not fully on disk.
    """
    tag = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f".tmp_{tag}")
    final = os.path.join(ckpt_dir, tag)
    os.makedirs(tmp, exist_ok=True)

    flat, _ = _flat_with_paths(state)
    arrays = {}
    meta_leaves = []
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"leaf_{i:05d}"] = _to_savable(arr)
        meta_leaves.append({"path": path, "shape": list(arr.shape),
                            "dtype": str(arr.dtype)})
    pid = jax.process_index()
    with open(os.path.join(tmp, f"shard_{pid:05d}.npz"), "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    meta = {"step": int(step), "leaves": meta_leaves,
            "extra": extra or {}, "num_shards": jax.process_count()}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_dir(ckpt_dir)
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(tag)
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _fsync_dir(ckpt_dir)
    return final


def _step_of(ckpt_dir: str, tag: str) -> int | None:
    """The step recorded in a tag directory's meta.json, or None if the
    directory is missing, torn, or unparseable."""
    meta_path = os.path.join(ckpt_dir, tag, "meta.json")
    try:
        with open(meta_path) as f:
            return int(json.load(f)["step"])
    except (OSError, ValueError, KeyError, TypeError):
        return None


def scan_steps(ckpt_dir: str) -> list[int]:
    """All complete checkpoint steps on disk (valid meta.json),
    ascending — the ground truth LATEST is only a cache of."""
    try:
        tags = os.listdir(ckpt_dir)
    except OSError:
        return []
    steps = [_step_of(ckpt_dir, t) for t in tags
             if t.startswith("step_") and not t.endswith(".tmp")]
    return sorted(s for s in steps if s is not None)


def latest_step(ckpt_dir: str) -> int | None:
    """Newest complete checkpoint step.

    Trusts LATEST when it points at a complete step directory; when
    LATEST is missing, stale, or points at a missing/corrupt tag (e.g. a
    crash landed between the step-dir rename and the LATEST update),
    falls back to scanning ``step_*`` directories instead of reporting
    no checkpoint while complete ones exist.
    """
    p = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(p):
        with open(p) as f:
            tag = f.read().strip()
        step = _step_of(ckpt_dir, tag)
        if step is not None:
            return step
    steps = scan_steps(ckpt_dir)
    return steps[-1] if steps else None


def clean_stale_tmp(ckpt_dir: str) -> list[str]:
    """Remove half-written ``.tmp_*`` dirs and ``.LATEST.tmp`` left by a
    crash mid-save.  Returns the paths removed (for logging)."""
    removed = []
    try:
        entries = os.listdir(ckpt_dir)
    except OSError:
        return removed
    for name in entries:
        if not (name.startswith(".tmp_") or name == ".LATEST.tmp"):
            continue
        path = os.path.join(ckpt_dir, name)
        if os.path.isdir(path):
            shutil.rmtree(path, ignore_errors=True)
        else:
            try:
                os.remove(path)
            except OSError:  # pragma: no cover — racing cleaner
                continue
        removed.append(path)
    return removed


def gc_keep_last(ckpt_dir: str, keep: int) -> list[int]:
    """Delete all but the newest ``keep`` complete checkpoints (the tag
    LATEST names is always kept).  Returns the steps removed."""
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    steps = scan_steps(ckpt_dir)
    pinned = set(steps[-keep:])
    p = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(p):
        with open(p) as f:
            latest = _step_of(ckpt_dir, f.read().strip())
        if latest is not None:
            pinned.add(latest)
    removed = []
    for s in steps:
        if s in pinned:
            continue
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
        removed.append(s)
    return removed


def restore(ckpt_dir: str, state_like: Any, step: int | None = None):
    """Restore into the structure (and shardings) of ``state_like``.

    ``state_like`` may hold concrete arrays or ShapeDtypeStructs +
    shardings; restored leaves are device_put to the template's sharding
    when available.  Returns (state, step, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    tag = f"step_{step:08d}"
    d = os.path.join(ckpt_dir, tag)
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, f"shard_{jax.process_index():05d}.npz"))
    by_path = {l["path"]: _from_savable(data[f"leaf_{i:05d}"], l["dtype"])
               for i, l in enumerate(meta["leaves"])}

    flat, treedef = _flat_with_paths(state_like)
    leaves = []
    for path, tmpl in flat:
        if path not in by_path:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = by_path[path]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {path}: ckpt {arr.shape} vs "
                f"template {tmpl.shape} (elastic resize requires replan + "
                f"reshard; see train/fault.py)")
        if arr.dtype != tmpl.dtype:
            arr = np.asarray(jax.numpy.asarray(arr).astype(tmpl.dtype))
        sharding = getattr(tmpl, "sharding", None)
        leaf = jax.device_put(arr, sharding) if sharding is not None \
            else jax.numpy.asarray(arr)
        leaves.append(leaf)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, meta["step"], meta.get("extra", {})


class AsyncCheckpointer:
    """Serializes saves on a daemon thread; at most one pending save.

    On construction it sweeps stale ``.tmp_*`` wreckage from a previous
    crash; pass ``keep_last`` to garbage-collect older step dirs after
    every successful save (LATEST's tag is never collected).
    """

    def __init__(self, ckpt_dir: str, keep_last: int | None = None):
        if keep_last is not None and keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        os.makedirs(ckpt_dir, exist_ok=True)
        clean_stale_tmp(ckpt_dir)
        self._pending: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, state: Any, extra: dict | None = None):
        self.wait()
        # device_get on the training thread (cheap on CPU; on TPU this is
        # the D2H copy) then serialize off-thread.
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def run():
            try:
                save(self.ckpt_dir, step, host_state, extra)
                if self.keep_last is not None:
                    gc_keep_last(self.ckpt_dir, self.keep_last)
            except Exception as e:  # pragma: no cover
                self._error = e

        self._pending = threading.Thread(target=run, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e
