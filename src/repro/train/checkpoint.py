"""Sharded checkpointing: atomic, async, resumable.

Layout (one directory per step)::

    <dir>/step_000123/
        meta.json            # step, tree structure, shapes/dtypes, run id
        shard_00000.npz      # this host's param/opt leaves (addressable)
    <dir>/LATEST             # atomic pointer (renamed into place)

Leaves are saved from each host's *addressable* shards, which makes the
scheme multi-host-correct: every host writes its own ``shard_<pid>.npz``
and restore re-assembles with ``jax.make_array_from_single_device_arrays``
(single-host here, but the code path is the production one).  Writes go to
a temp dir first and are renamed into place, so a crash mid-write can never
corrupt LATEST.  ``AsyncCheckpointer`` moves serialization off the training
thread (fault tolerance requirement: checkpoint cadence must not stall the
step loop).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flat_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in flat], treedef


_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
           "int8", "uint64", "uint32", "uint16", "uint8", "bool"}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz can't serialize ml_dtypes (bfloat16, fp8): store a uint view;
    the true dtype lives in meta.json and restore views it back."""
    if str(arr.dtype) in _NATIVE:
        return arr
    width = arr.dtype.itemsize
    return arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[width])


def _from_savable(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) == dtype_str:
        return arr
    import jax.numpy as jnp
    return arr.view(jnp.dtype(dtype_str))


def save(ckpt_dir: str, step: int, state: Any, extra: dict | None = None):
    """Synchronous sharded save with atomic LATEST update."""
    tag = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f".tmp_{tag}")
    final = os.path.join(ckpt_dir, tag)
    os.makedirs(tmp, exist_ok=True)

    flat, _ = _flat_with_paths(state)
    arrays = {}
    meta_leaves = []
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"leaf_{i:05d}"] = _to_savable(arr)
        meta_leaves.append({"path": path, "shape": list(arr.shape),
                            "dtype": str(arr.dtype)})
    pid = jax.process_index()
    np.savez(os.path.join(tmp, f"shard_{pid:05d}.npz"), **arrays)
    meta = {"step": int(step), "leaves": meta_leaves,
            "extra": extra or {}, "num_shards": jax.process_count()}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(tag)
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        tag = f.read().strip()
    meta_path = os.path.join(ckpt_dir, tag, "meta.json")
    if not os.path.exists(meta_path):
        return None
    with open(meta_path) as f:
        return json.load(f)["step"]


def restore(ckpt_dir: str, state_like: Any, step: int | None = None):
    """Restore into the structure (and shardings) of ``state_like``.

    ``state_like`` may hold concrete arrays or ShapeDtypeStructs +
    shardings; restored leaves are device_put to the template's sharding
    when available.  Returns (state, step, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    tag = f"step_{step:08d}"
    d = os.path.join(ckpt_dir, tag)
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, f"shard_{jax.process_index():05d}.npz"))
    by_path = {l["path"]: _from_savable(data[f"leaf_{i:05d}"], l["dtype"])
               for i, l in enumerate(meta["leaves"])}

    flat, treedef = _flat_with_paths(state_like)
    leaves = []
    for path, tmpl in flat:
        if path not in by_path:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = by_path[path]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {path}: ckpt {arr.shape} vs "
                f"template {tmpl.shape} (elastic resize requires replan + "
                f"reshard; see train/fault.py)")
        if arr.dtype != tmpl.dtype:
            arr = np.asarray(jax.numpy.asarray(arr).astype(tmpl.dtype))
        sharding = getattr(tmpl, "sharding", None)
        leaf = jax.device_put(arr, sharding) if sharding is not None \
            else jax.numpy.asarray(arr)
        leaves.append(leaf)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, meta["step"], meta.get("extra", {})


class AsyncCheckpointer:
    """Serializes saves on a daemon thread; at most one pending save."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        os.makedirs(ckpt_dir, exist_ok=True)
        self._pending: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, state: Any, extra: dict | None = None):
        self.wait()
        # device_get on the training thread (cheap on CPU; on TPU this is
        # the D2H copy) then serialize off-thread.
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def run():
            try:
                save(self.ckpt_dir, step, host_state, extra)
            except Exception as e:  # pragma: no cover
                self._error = e

        self._pending = threading.Thread(target=run, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e
