"""Training state container + construction helpers."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jax.Array            # scalar int32
    params: Any                # model param pytree
    opt_state: Any             # tree (zero=0) or per-bucket shards (zero=1)

    @staticmethod
    def create(params, opt_state):
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          opt_state=opt_state)
