"""Distributed train step: MG-WFBP-scheduled gradient communication.

Construction (``build_train_step``) happens once, outside jit:

  1. ``jax.eval_shape`` the parameter tree; split leaves into the
     *DP-replicated* group (attention, norms, dense FFN, shared experts —
     reduced over every data axis) and the *EP-owned* group (``*_e`` expert
     tensors when expert parallelism is on — owned along ``data``,
     replicated only over ``pod``).
  2. Build :class:`TensorSpec`s for the replicated group from the analytic
     per-tensor backward-time model (core/profiler.py) and ask the planner
     for the merge plan (``mgwfbp`` / ``wfbp`` / ``single`` / ``fixed:N`` /
     ``dp_optimal``) against the mesh's all-reduce cost model.
  3. Emit the step: ``shard_map`` with the DP axes *manual* (bucketed psum
     / reduce-scatter collectives placed explicitly, per plan — the paper's
     contribution) and the TP axis *auto* (GSPMD handles head/ffn sharding
     incl. non-divisible head counts).

ZeRO-1 (``parallel.zero == 1``): per-plan-bucket reduce-scatter of grads
over ``data`` (after a pod psum), optimizer on this shard's slice of the
packed bucket, merged all-gather of updated params — the same startup-cost
amortization argument the paper makes for all-reduce, applied to RS+AG.

Note on pytrees: group splitting inserts ``None`` at excluded leaves; JAX
treats ``None`` as an empty subtree, so the pruned trees flow through
bucketer/comm/optim untouched.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.core import bucketer, comm, cost_model, planner, profiler
from repro.models import sharding as shd
from repro.models.transformer import LM
from repro.optim import clip as oclip
from repro.optim.optimizers import Optimizer, make_optimizer
from repro.optim.schedule import warmup_cosine
from repro.train.train_state import TrainState

EP_LEAF_RE = re.compile(r"w_(gate|up|down)_e")


def _microbatch_scan(body, carry, xs, n_micro):
    """lax.scan over microbatches, unrolled where scan cannot lower (old
    JAX inside a shard_map manual subgroup — see layers.unroll_scans_here)."""
    from repro.models import layers as _layers
    if not _layers.unroll_scans_here():
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(n_micro):
        mb = jax.tree.map(lambda x, i=i: x[i], xs)
        carry, y = body(carry, mb)
        ys.append(y)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stacked


def _shard_map(body, mesh, in_specs, out_specs, manual_axes):
    """shard_map across JAX versions: new JAX takes ``axis_names`` (the
    manual set) and ``check_vma``; old JAX (0.4.x) lives in
    jax.experimental and takes the complementary ``auto`` set and
    ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  axis_names=set(manual_axes), check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_old
    auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return sm_old(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False, auto=auto)


@dataclasses.dataclass
class StepArtifacts:
    """Everything the launcher needs besides the step function itself."""
    plan: planner.MergePlan
    ep_plan: planner.MergePlan | None
    specs: list
    comm_model: cost_model.AllReduceModel
    param_pspecs: Any
    state_pspecs: Any
    batch_pspec: P
    dp_axes: tuple
    manual_axes: frozenset


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def _split_groups(tree, ep_on: bool):
    """(replicated, ep_owned) trees with None at excluded leaves."""
    def rep(path, leaf):
        return None if (ep_on and EP_LEAF_RE.search(_keystr(path))) else leaf

    def ep(path, leaf):
        return leaf if (ep_on and EP_LEAF_RE.search(_keystr(path))) else None

    return (jax.tree_util.tree_map_with_path(rep, tree),
            jax.tree_util.tree_map_with_path(ep, tree))


def _merge_groups(template, rep, ep):
    """Inverse of _split_groups: fill template positions from rep/ep."""
    rep_by = {_keystr(p): v for p, v in
              jax.tree_util.tree_flatten_with_path(rep)[0]}
    ep_by = {_keystr(p): v for p, v in
             jax.tree_util.tree_flatten_with_path(ep)[0]} if ep is not None \
        else {}
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = [rep_by.get(_keystr(p), ep_by.get(_keystr(p), v))
           for p, v in flat_t]
    return jax.tree_util.tree_unflatten(treedef, out)


def _axes_size(axes) -> int:
    n = 1
    for a in axes:
        n *= comm.axis_size(a)
    return n


# ---------------------------------------------------------------------------
# Planning.
# ---------------------------------------------------------------------------

def build_plan(params_shape, run: RunConfig, mesh_shape, mesh_axes,
               strategy: str | None = None,
               exclude: set | None = None,
               ep_on: bool | None = None,
               tb_table: dict | None = None,
               comm_model=None):
    """Merge plan(s) + tensor specs + cost model for this run.

    ``exclude``: leaf paths whose DP reduction happens elsewhere (ZeRO-3
    leaves reduce inside autodiff via the gather transpose).
    ``ep_on``: expert-parallel split as decided by the caller — must match
    the step body's _split_groups or the plan's bucket indices point at
    the wrong leaves; defaults to the mesh-derived value.
    ``tb_table``: measured per-tensor backward times (``{path: seconds}``,
    e.g. from ``profiler.measure_loss_profile`` or a refit from real
    ``IterationRecord`` timings) — used where present, with the analytic
    roofline as the fallback prior (paper §5.1 measure-then-plan).
    ``comm_model``: override the mesh-derived all-reduce model with a
    measured/refit one (``train.replan`` feeds the effective model here)."""
    par = run.parallel
    if ep_on is None:
        ep_on = bool(par.ep_axis) and par.ep_axis in mesh_axes
    rep_shape, ep_shape = _split_groups(params_shape, ep_on)
    if exclude:
        rep_shape = jax.tree_util.tree_map_with_path(
            lambda p, l: None if _keystr(p) in exclude else l, rep_shape)
    dims = dict(zip(mesh_axes, mesh_shape))
    dp_total = 1
    for a in par.dp_axes:
        dp_total *= dims.get(a, 1)
    local_batch = max(run.shape.global_batch // max(dp_total, 1), 1)
    micro = min(run.microbatch or local_batch, local_batch)
    t_b = profiler.analytic_tb(micro * run.shape.seq_len)
    if tb_table:
        t_b = profiler.measured_tb(tb_table, t_b)
    specs = [s for s in bucketer.tensor_specs(rep_shape, t_b) if s.nbytes]
    model = comm_model if comm_model is not None else \
        cost_model.production_comm_model(mesh_shape, mesh_axes, par.dp_axes)
    plan = planner.make_plan(strategy or par.comm_strategy, specs, model)
    ep_plan, ep_specs = None, []
    if ep_on:
        ep_specs = [s for s in bucketer.tensor_specs(ep_shape, t_b)
                    if s.nbytes]
        pods = dims.get("pod", 1)
        if ep_specs and pods > 1:
            pod_model = cost_model.production_comm_model(
                mesh_shape, mesh_axes, ("pod",))
            ep_plan = planner.make_plan(strategy or par.comm_strategy,
                                        ep_specs, pod_model)
    return plan, ep_plan, specs, model


# ---------------------------------------------------------------------------
# FSDP (ZeRO-3): parameters sharded over the data axis.
# ---------------------------------------------------------------------------

FSDP_MIN_BYTES = 1 << 20


def fsdp_augment(pspecs, params_shape, zero_axis: str, zero_n: int,
                 ep_on: bool):
    """Add a ``zero_axis`` entry to every large replicated leaf's spec.

    Returns (new_pspecs, {path: gathered_dim}).  Leaves already EP-owned,
    small leaves, and dims not divisible by the axis size are left alone.
    The training step all-gathers marked leaves before the forward pass;
    autodiff's transpose (psum_scatter) then delivers *sharded* gradients —
    ZeRO-3 semantics with the optimizer running entirely on shards.
    """
    fsdp_dims: dict[str, int] = {}

    def one(path, spec, leaf):
        k = _keystr(path)
        if ep_on and EP_LEAF_RE.search(k):
            return spec
        nbytes = 1
        for d in leaf.shape:
            nbytes *= d
        nbytes *= jnp.dtype(leaf.dtype).itemsize
        if nbytes < FSDP_MIN_BYTES:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        # prefer the largest free divisible dim
        order = sorted(range(len(leaf.shape)),
                       key=lambda d: -leaf.shape[d])
        for d in order:
            if entries[d] is None and leaf.shape[d] % zero_n == 0:
                entries[d] = zero_axis
                fsdp_dims[k] = d
                return P(*entries)
        return spec

    new = jax.tree_util.tree_map_with_path(one, pspecs, params_shape)
    return new, fsdp_dims


def gather_fsdp(params, fsdp_dims: dict, zero_axis: str):
    """all_gather marked leaves (inside the manual shard_map region).
    Uses the safe gather so the gradient reduce-scatter survives the
    XLA:CPU 16-bit promotion bug (comm.safe_all_gather)."""
    def one(path, leaf):
        d = fsdp_dims.get(_keystr(path))
        if d is None:
            return leaf
        return comm.safe_all_gather(leaf, zero_axis, axis=d)
    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# State init + shardings.
# ---------------------------------------------------------------------------

def init_state(model: LM, opt: Optimizer, run: RunConfig,
               plan: planner.MergePlan, ep_on: bool, zero_n: int, key,
               eff_zero: int | None = None, aligned: bool = False):
    """Global TrainState (ZeRO-1 moment buffers are full-size; the data-axis
    sharding distributes them).  ``aligned`` sizes the packed buffers for
    the bucket_pack kernel's TILE-aligned slot layout."""
    params = model.init(key)
    zero = run.parallel.zero if eff_zero is None else eff_zero
    if zero != 1:
        return TrainState.create(params, opt.init(params))
    rep_p, ep_p = _split_groups(params, ep_on)
    metas = bucketer.leaf_metadata(rep_p)
    opt_shards = []
    for bucket in plan.buckets:
        total = bucketer.packed_elems([metas[i] for i in bucket],
                                      aligned=aligned)
        padded = total + ((-total) % zero_n)
        opt_shards.append(opt.init_leaf(jnp.zeros((padded,), jnp.float32)))
    if ep_on:
        opt_shards.append(opt.init(ep_p))
    return TrainState.create(params, opt_shards)


def _opt_pspecs_like(params_spec, opt_shape):
    """Moments inherit their parameter's spec ({'m','v','mu'} per leaf)."""
    spec_by = {_keystr(p): v for p, v in
               jax.tree_util.tree_flatten_with_path(
                   params_spec, is_leaf=lambda x: isinstance(x, P))[0]}

    def one(path, leaf):
        k = _keystr(path)
        # strip trailing ['m'] / ['v'] / ['mu']
        base = re.sub(r"\['(m|v|mu)'\]$", "", k)
        return spec_by.get(base, P())
    return jax.tree_util.tree_map_with_path(one, opt_shape)


def state_pspecs(state_shape, params_spec, run: RunConfig, zero_axis: str,
                 ep_on: bool, eff_zero: int | None = None):
    zero = run.parallel.zero if eff_zero is None else eff_zero
    if zero != 1:
        opt_spec = _opt_pspecs_like(params_spec, state_shape.opt_state)
    else:
        opt_spec = []
        n_buckets = len(state_shape.opt_state) - (1 if ep_on else 0)
        for k in range(n_buckets):
            opt_spec.append(jax.tree.map(lambda _: P(zero_axis),
                                         state_shape.opt_state[k]))
        if ep_on:
            opt_spec.append(_opt_pspecs_like(params_spec,
                                             state_shape.opt_state[-1]))
    return TrainState(step=P(), params=params_spec, opt_state=opt_spec)


# ---------------------------------------------------------------------------
# Step builder.
# ---------------------------------------------------------------------------

def build_train_step(model: LM, run: RunConfig, mesh,
                     strategy: str | None = None, donate: bool = True,
                     tb_table: dict | None = None, comm_model=None,
                     plan_override: planner.MergePlan | None = None):
    """Returns (jit-ready step_fn, init_fn, StepArtifacts).

    ``tb_table`` / ``comm_model`` thread measured costs into the plan
    (see :func:`build_plan`); ``plan_override`` installs a specific merge
    plan — the :class:`repro.train.replan.ReplanController` swap path —
    bypassing the strategy planner (bucketing is pure scheduling, so the
    override changes step timing, never numerics)."""
    par = run.parallel
    mesh_axes = tuple(mesh.axis_names)
    mesh_shape = tuple(mesh.devices.shape)
    dims = dict(zip(mesh_axes, mesh_shape))
    dp_axes = tuple(a for a in par.dp_axes if a in mesh_axes)
    manual = frozenset(dp_axes)
    ep_on = bool(par.ep_axis) and par.ep_axis in mesh_axes
    if ep_on and dp_axes and not hasattr(jax, "shard_map"):
        # Old JAX: moe_apply skips the EP all_to_all inside shard_map (see
        # models/moe.py), computing every expert locally — so expert leaves
        # must be treated as replicated here too.
        ep_on = False
    zero_axis = "data" if "data" in dp_axes else (dp_axes[0] if dp_axes
                                                  else "")
    pod_axes = tuple(a for a in dp_axes if a != zero_axis)
    zero_n = _static_size(dims, (zero_axis,)) if zero_axis else 1
    # effective ZeRO mode: sharded-state modes need a real data axis
    eff_zero = par.zero if (zero_axis and dp_axes) else 0
    if eff_zero == 1 and not hasattr(jax, "shard_map"):
        # Old JAX (< 0.5): the merged all-gather of updated params trips the
        # old SPMD partitioner inside a partial-auto shard_map.  ZeRO-1 is
        # numerically identical to the replicated optimizer (see
        # tests/test_train_integration.py::test_zero1_matches_zero0), so
        # degrade to the replicated path rather than crash.
        eff_zero = 0

    opt = make_optimizer(run.optimizer, weight_decay=run.weight_decay,
                         state_dtype=run.optimizer_state_dtype,
                         b1=run.adam_b1, b2=run.adam_b2, eps=run.adam_eps,
                         momentum=run.sgd_momentum)
    lr_fn = warmup_cosine(run.learning_rate, run.warmup_steps,
                          run.total_steps)
    # paper §5.3 contiguous-buffer execution through the bucket_pack Pallas
    # kernel (jnp fallback where Pallas cannot lower, same slot layout)
    use_kernel = bool(par.pack_kernel)

    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    tp_axis = par.tp_axis if (par.tp_enabled and par.tp_axis in mesh_axes
                              and par.tp_axis not in dp_axes) else ""
    pspecs = shd.param_pspecs(params_shape,
                              ep_axis=par.ep_axis if ep_on else "",
                              tp_axis=tp_axis,
                              moe_token_shard=par.moe_token_shard)
    pspecs = shd.filter_uneven(pspecs, params_shape, dims)
    fsdp_dims: dict[str, int] = {}
    if eff_zero == 3:
        pspecs, fsdp_dims = fsdp_augment(pspecs, params_shape, zero_axis,
                                         zero_n, ep_on)
    plan, ep_plan, specs, cmodel = build_plan(params_shape, run, mesh_shape,
                                              mesh_axes, strategy,
                                              exclude=set(fsdp_dims),
                                              ep_on=ep_on,
                                              tb_table=tb_table,
                                              comm_model=comm_model)
    if plan_override is not None:
        if plan_override.num_tensors != len(specs):
            raise ValueError(
                f"plan_override covers {plan_override.num_tensors} tensors "
                f"but the step has {len(specs)}")
        plan = plan_override

    # static per-bucket weight-decay masks (packed ZeRO-1 path only); the
    # kernel layout pads each leaf's slot with zeros — padding never decays
    decay_masks = []
    if eff_zero == 1:
        rep_shape, _ = _split_groups(params_shape, ep_on)
        rep_metas = bucketer.leaf_metadata(rep_shape)
        decay_by_path = {}
        for p, _l in jax.tree_util.tree_flatten_with_path(rep_shape)[0]:
            k = _keystr(p)
            decay_by_path[k] = 1.0 if opt.weight_decay_mask(k) else 0.0
        for bucket in plan.buckets:
            parts = []
            for i in bucket:
                slot = np.zeros(
                    (bucketer.slot_elems(rep_metas[i].size,
                                         aligned=use_kernel),), np.float32)
                slot[:rep_metas[i].size] = decay_by_path[rep_metas[i].path]
                parts.append(slot)
            decay_masks.append(np.concatenate(parts) if parts else
                               np.zeros((0,), np.float32))

    dp_size = _static_size(dims, dp_axes)
    local_batch = max(run.shape.global_batch // max(dp_size, 1), 1)
    micro = min(run.microbatch or local_batch, local_batch)
    n_micro = max(local_batch // micro, 1)

    # ------------------------------------------------------------------

    def compute_grads(params, batch):
        def loss_fn(p, mb):
            return model.loss(p, mb)

        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        resh = jax.tree.map(
            lambda x: x.reshape((n_micro, micro) + x.shape[1:]), batch)

        def mb_body(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc,
                               grads)
            return (acc, loss_acc + loss), metrics

        zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                             params)
        (gacc, loss_sum), metrics = _microbatch_scan(
            mb_body, (zeros, jnp.zeros((), jnp.float32)), resh, n_micro)
        grads = jax.tree.map(lambda g: g / n_micro, gacc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / n_micro, metrics, grads

    def reduce_replicated(rep_g):
        kwargs = dict(mean=True, wire_dtype=par.wire_dtype or None)
        if use_kernel:
            # contiguous merged buffers via the pack kernel require the
            # packed collective mode (fused variadic psum never packs)
            kwargs.update(mode="packed", use_kernel=True)
        if par.hierarchical and pod_axes:
            return comm.hierarchical_allreduce(
                rep_g, plan, intra_axis=zero_axis, inter_axis=pod_axes[0],
                **kwargs)
        if dp_axes:
            return comm.bucketed_allreduce(rep_g, plan, dp_axes, **kwargs)
        return rep_g

    def reduce_ep(ep_g):
        if ep_g is None:
            return None
        if pod_axes and ep_plan is not None:
            return comm.bucketed_allreduce(ep_g, ep_plan, pod_axes,
                                           mean=True)
        return ep_g

    # ------------------------------------------------------------------

    def step_zero0(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        rep_g, ep_g = _split_groups(grads, ep_on)
        rep_g = reduce_replicated(rep_g)
        ep_g = reduce_ep(ep_g) if ep_on else None
        grads = _merge_groups(grads, rep_g, ep_g)
        sq = oclip.global_norm(rep_g) ** 2
        if ep_on and zero_axis:
            sq = sq + jax.lax.psum(oclip.global_norm(ep_g) ** 2, zero_axis)
        gnorm = jnp.sqrt(sq)
        grads, _ = oclip.clip_by_global_norm(grads, run.grad_clip, gnorm)
        lr = lr_fn(state.step)
        new_params, new_opt = opt.update(grads, state.params,
                                         state.opt_state, state.step, lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        if dp_axes:
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp_axes),
                                   metrics)
        return TrainState(state.step + 1, new_params, new_opt), metrics

    def step_zero1(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        rep_g, ep_g = _split_groups(grads, ep_on)
        if pod_axes:
            npod = _static_size(dims, pod_axes)
            rep_g = jax.tree.map(lambda g: g / npod,
                                 comm.safe_psum(rep_g, pod_axes))
        shards, bucket_metas = comm.bucketed_reduce_scatter(
            rep_g, plan, zero_axis, mean=True,
            wire_dtype=par.wire_dtype or None, use_kernel=use_kernel)
        sq = sum(jnp.sum(jnp.square(s.astype(jnp.float32))) for s in shards)
        sq = jax.lax.psum(sq, zero_axis)
        ep_g = reduce_ep(ep_g) if ep_on else None
        if ep_on:
            sq = sq + jax.lax.psum(oclip.global_norm(ep_g) ** 2, zero_axis)
        gnorm = jnp.sqrt(sq)
        scale = (jnp.minimum(1.0, run.grad_clip / jnp.maximum(gnorm, 1e-12))
                 if run.grad_clip > 0 else jnp.ones(()))
        lr = lr_fn(state.step)

        n = _axes_size((zero_axis,))
        rep_p, ep_p = _split_groups(state.params, ep_on)
        flatp, _ = jax.tree_util.tree_flatten_with_path(rep_p)
        by_path = {_keystr(p): v for p, v in flatp}
        new_shards, new_opt = [], []
        for k, (bmetas, gshard) in enumerate(zip(bucket_metas, shards)):
            pbuf = bucketer.pack([by_path[m.path] for m in bmetas],
                                 use_kernel=use_kernel)
            mask = jnp.asarray(decay_masks[k])
            pad = (-pbuf.shape[0]) % n
            if pad:
                pbuf = jnp.pad(pbuf, (0, pad))
                mask = jnp.pad(mask, (0, pad))
            pshard = comm.replicated_shard(pbuf, zero_axis)
            mshard = comm.replicated_shard(mask, zero_axis)
            g = gshard.astype(jnp.float32) * scale
            new_p, new_s = opt.flat_update(g, pshard, state.opt_state[k],
                                           state.step, lr, mshard)
            new_shards.append(new_p)
            new_opt.append(new_s)
        new_rep = comm.bucketed_allgather(new_shards, bucket_metas, rep_p,
                                          zero_axis, use_kernel=use_kernel)
        if ep_on:
            ep_gc = jax.tree.map(lambda g: g * scale, ep_g)
            new_ep, new_ep_opt = opt.update(ep_gc, ep_p,
                                            state.opt_state[-1],
                                            state.step, lr)
            new_opt.append(new_ep_opt)
        else:
            new_ep = None
        new_params = _merge_groups(state.params, new_rep, new_ep)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp_axes), metrics)
        return TrainState(state.step + 1, new_params, new_opt), metrics

    # ------------------------------------------------------------------
    # ZeRO-3 / FSDP: params + optimizer fully sharded over `data`; the
    # forward all-gathers, autodiff reduce-scatters, optimizer is local.
    # ------------------------------------------------------------------

    def step_zero3(state: TrainState, batch):
        dp_n = _axes_size(dp_axes)

        def loss_of_sharded(sharded_params, mb):
            full = gather_fsdp(sharded_params, fsdp_dims, zero_axis)
            return model.loss(full, mb)

        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of_sharded, has_aux=True)(state.params, batch)
        else:
            resh = jax.tree.map(
                lambda x: x.reshape((n_micro, micro) + x.shape[1:]), batch)

            def mb_body(carry, mb):
                acc, loss_acc = carry
                (l, m), g = jax.value_and_grad(
                    loss_of_sharded, has_aux=True)(state.params, mb)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype),
                                   acc, g)
                return (acc, loss_acc + l), m
            zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                 state.params)
            (grads, loss_sum), metrics = _microbatch_scan(
                mb_body, (zeros, jnp.zeros((), jnp.float32)), resh, n_micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
            loss = loss_sum / n_micro

        # fsdp leaves arrive as per-shard sums over `data` (gather
        # transpose); non-fsdp leaves are local and need the plan's
        # bucketed reduction.  EP leaves are owned.
        def split3(tree):
            fs, rest = {}, {}
            flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
            f_leaves, r_leaves = [], []
            for p, v in flat:
                if _keystr(p) in fsdp_dims:
                    f_leaves.append(v)
                    r_leaves.append(None)
                else:
                    f_leaves.append(None)
                    r_leaves.append(v)
            return (jax.tree_util.tree_unflatten(treedef, f_leaves),
                    jax.tree_util.tree_unflatten(treedef, r_leaves))

        fsdp_g, rest_g = split3(grads)
        rep_g, ep_g = _split_groups(rest_g, ep_on)
        rep_g = reduce_replicated(rep_g)
        ep_g = reduce_ep(ep_g) if ep_on else None
        if pod_axes:
            npod = _static_size(dims, pod_axes)
            fsdp_g = jax.tree.map(lambda g: g / npod,
                                  comm.safe_psum(fsdp_g, pod_axes))
        fsdp_g = jax.tree.map(lambda g: g / _axes_size((zero_axis,)),
                              fsdp_g)
        grads = _merge_groups(grads, _merge_groups(rest_g, rep_g, ep_g),
                              fsdp_g)

        sq = oclip.global_norm(rep_g) ** 2
        sq = sq + jax.lax.psum(oclip.global_norm(fsdp_g) ** 2, zero_axis)
        if ep_on:
            sq = sq + jax.lax.psum(oclip.global_norm(ep_g) ** 2, zero_axis)
        gnorm = jnp.sqrt(sq)
        grads, _ = oclip.clip_by_global_norm(grads, run.grad_clip, gnorm)
        lr = lr_fn(state.step)
        new_params, new_opt = opt.update(grads, state.params,
                                         state.opt_state, state.step, lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp_axes), metrics)
        return TrainState(state.step + 1, new_params, new_opt), metrics

    if eff_zero == 3:
        body = step_zero3
    elif eff_zero == 1:
        body = step_zero1
    else:
        body = step_zero0

    # ------------------------------------------------------------------
    # Shardings + shard_map wiring.
    # ------------------------------------------------------------------

    def init_fn(key):
        return init_state(model, opt, run, plan, ep_on, zero_n, key,
                          eff_zero=eff_zero, aligned=use_kernel)

    state_shape = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    st_pspecs = state_pspecs(state_shape, pspecs, run, zero_axis, ep_on,
                             eff_zero=eff_zero)
    batch_pspec = P(dp_axes) if dp_axes else P()

    if dp_axes:
        manual_state = jax.tree.map(
            lambda s: shd.manual_only(s, manual), st_pspecs,
            is_leaf=lambda x: isinstance(x, P))
        step_fn = _shard_map(
            body, mesh,
            in_specs=(manual_state, batch_pspec),
            out_specs=(manual_state, P()),
            manual_axes=manual)
    else:
        step_fn = body

    art = StepArtifacts(plan=plan, ep_plan=ep_plan, specs=specs,
                        comm_model=cmodel, param_pspecs=pspecs,
                        state_pspecs=st_pspecs, batch_pspec=batch_pspec,
                        dp_axes=dp_axes, manual_axes=manual)
    return step_fn, init_fn, art


def _static_size(dims, axes) -> int:
    n = 1
    for a in axes:
        n *= dims.get(a, 1)
    return n


# ---------------------------------------------------------------------------
# Host-side observability: the measurement half of the sim->real loop.
# ---------------------------------------------------------------------------

def instrument_step(step_fn, art: StepArtifacts, *, job: str = "train",
                    t_f: float = 0.0, recorder=None, source: str = "train",
                    clock=None, hlo_text: str | None = None,
                    sync: bool = True, on_record=None):
    """Wrap a (jitted) step function with host-side flight recording.

    Timing happens strictly OUTSIDE the jitted region — wall clock before
    dispatch and after ``jax.block_until_ready`` — so nothing lands on the
    device hot path (no Python callbacks inside jit, acceptance criterion
    of the obs subsystem).  Per-bucket communication windows are not
    host-observable, so each record carries the closed-form per-bucket
    estimate (``core.simulator.simulate`` over the step's own plan, specs
    and comm model — the same Eq. 7/8 replay the planner optimized
    against) rescaled to the measured wall time and flagged
    ``estimated_buckets`` in ``args``.  The result: a real multi-device
    run produces :class:`repro.obs.recorder.IterationRecord`s in exactly
    the simulator's schema, and both export into one Chrome trace
    (``repro.obs.recorder.record_spans``).

    ``hlo_text`` (the compiled step's HLO, e.g. ``jax.jit(step).lower(...)
    .compile().as_text()``) attaches ``utils.hlo.analyze`` cost counters
    to the first record.  ``clock`` injects a time source (deterministic
    golden tests); ``sync=False`` skips the block-until-ready (callers
    that already synchronize, or tests without real devices).

    ``on_record`` receives each :class:`IterationRecord` after it is (op-
    tionally) recorded — the hook a :class:`repro.train.replan.ReplanController`
    uses to consume live measurements without owning the recorder.
    """
    import time

    from repro.core.simulator import simulate
    from repro.obs.metrics import REGISTRY
    from repro.obs.recorder import (BucketRecord, IterationRecord,
                                    plan_fingerprint)

    est = simulate(art.specs, art.plan, art.comm_model, t_f)
    fingerprint = plan_fingerprint(art.plan)
    hlo_cost = None
    if hlo_text is not None:
        from repro.utils import hlo as hlo_mod
        hlo_cost = hlo_mod.analyze(hlo_text).as_dict()
    now = clock if clock is not None else time.perf_counter
    hist = REGISTRY.histogram("train_step_seconds",
                              "real train-step wall time")
    step_idx = 0

    def wrapped(state, batch):
        nonlocal step_idx
        t0 = now()
        out = step_fn(state, batch)
        if sync:
            out = jax.block_until_ready(out)
        t1 = now()
        hist.observe(t1 - t0, job=job)
        if recorder is not None or on_record is not None:
            # map the closed-form timeline (backward-origin clock, total
            # span est.t_iter) onto the measured wall window [t0, t1]
            scale = (t1 - t0) / est.t_iter if est.t_iter > 0 else 0.0
            buckets = tuple(
                BucketRecord(bucket=e.bucket, nbytes=e.nbytes,
                             ready=t0 + (t_f + e.ready) * scale,
                             start=t0 + (t_f + e.start) * scale,
                             end=t0 + (t_f + e.end) * scale)
                for e in est.events)
            args = {"plan": fingerprint, "estimated_buckets": True,
                    "predicted_t_iter": est.t_iter,
                    "overlap_ratio": est.overlap_ratio}
            if step_idx == 0 and hlo_cost is not None:
                args["hlo_cost"] = hlo_cost
            rec = IterationRecord(
                source=source, job=job, iteration=step_idx,
                start=t0, end=t1,
                backward_end=t0 + (t_f + est.t_b_total) * scale,
                buckets=buckets, args=args)
            if recorder is not None:
                recorder.record(rec)
            if on_record is not None:
                on_record(rec)
        step_idx += 1
        return out

    return wrapped
