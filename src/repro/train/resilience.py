"""Resilience supervisor: one state machine from step retry to N−k.

``run_with_recovery`` (repro.train.fault) handles the two innermost
rungs of the recovery ladder — retry a failed step, restore from a
checkpoint.  Long runs on real fleets need the whole ladder, with every
rung observable:

    retry (exponential backoff + jitter)
      → restore from checkpoint (bounded budget, then re-raise)
        → evict stragglers / crashed workers (StragglerMonitor + the
          launcher's membership-change machinery)
          → degrade gracefully to N−k (model rescale via invert_model +
            incremental replan)
            → re-admit replacement workers

:class:`ResilienceController` is that ladder as a clock-agnostic state
machine: callers feed it step completions, step failures and detected
faults (with an explicit timestamp — wall seconds in the real loop, sim
seconds in ``repro.sim.scenarios.faulty_long_run``) and it returns the
next action while keeping SLA-grade books: useful vs replayed steps,
per-incident MTTR, recovery counts by kind.  Every transition lands in
the PR-6 observability spine — ``EventRecord``s in the flight recorder
and ``resilience_*`` metrics in the registry:

* ``resilience_recoveries_total{kind}``  — incidents recovered, by fault
  kind;
* ``resilience_actions_total{kind}``     — recovery actions taken
  (retry / restore / evict / degrade / readmit / drain / replan);
* ``resilience_mttr_seconds``            — histogram of time from fault
  occurrence to the first useful step after recovery;
* ``resilience_wasted_steps_total``      — replayed or discarded steps;
* ``resilience_goodput{job}``            — useful steps per wall second.

:func:`run_supervised` drives a real training loop through the
controller (subsuming ``run_with_recovery``, which is now a thin wrapper
over it); the simulator twin lives in ``repro.sim.scenarios``.
"""

from __future__ import annotations

import dataclasses
import logging
import random
import time
from typing import Callable, Iterable, Sequence

from repro.obs.metrics import REGISTRY
from repro.obs.recorder import EventRecord
from repro.train import checkpoint
from repro.train.fault import StragglerMonitor

log = logging.getLogger("repro.resilience")

# controller states
RUNNING = "running"        # steps completing normally
BACKOFF = "backoff"        # a step failed; waiting to retry
RESTORING = "restoring"    # retries exhausted; replaying from checkpoint
HALTED = "halted"          # budgets exhausted; the failure re-raised


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Knobs of the recovery ladder.

    The timing constants in the second block parameterize the *modeled*
    costs the simulator charges for control actions (detection latency,
    restore/drain downtime, replacement provisioning); the real loop
    pays actual wall time instead and ignores them.
    """

    # step retry: delay = min(base * factor**(attempt-1), max), then
    # ±jitter fraction of itself (seeded — reruns back off identically)
    max_retries: int = 3
    backoff_base: float = 0.05
    backoff_factor: float = 2.0
    backoff_max: float = 2.0
    jitter: float = 0.25
    # escalation: restores before a persistent failure re-raises
    max_restores: int = 3
    # membership: never degrade below min_workers
    min_workers: int = 2
    straggler_threshold: float = 1.5
    straggler_warmup: int = 3
    # modeled control-action costs (simulator scale: seconds of sim time)
    detect_s: float = 0.02        # fail-stop detection latency
    restore_s: float = 0.05       # checkpoint restore downtime
    ckpt_s: float = 0.005         # checkpoint write stall
    evict_s: float = 0.01         # rescale + replan + resume
    provision_s: float = 0.3      # replacement worker provisioning
    readmit_s: float = 0.02       # state sync for a re-admitted worker
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0 or self.max_restores < 0:
            raise ValueError(f"negative budget: {self}")
        if self.backoff_base < 0 or self.backoff_factor < 1 \
                or self.backoff_max < self.backoff_base:
            raise ValueError(f"bad backoff ladder: {self}")
        if not 0 <= self.jitter <= 1:
            raise ValueError(f"jitter must be in [0, 1]: {self}")
        if self.min_workers < 1:
            raise ValueError(f"min_workers must be >= 1: {self}")

    def backoff(self, attempt: int, salt: int = 0) -> float:
        """Deterministic exponential backoff with jitter; attempt >= 1.

        ``salt`` decorrelates successive incidents (the controller feeds
        a monotone draw counter) while keeping the whole sequence a pure
        function of the seed."""
        d = min(self.backoff_base * self.backoff_factor ** (attempt - 1),
                self.backoff_max)
        u = random.Random(f"{self.seed}:{attempt}:{salt}").uniform(-1.0, 1.0)
        return max(0.0, d * (1.0 + self.jitter * u))


@dataclasses.dataclass
class Incident:
    """One fault from occurrence to recovery (recovered is None while
    open; MTTR = recovered - occurred once closed)."""

    kind: str
    occurred: float
    detected: float
    worker: str = ""
    opened_at_step: int = 0
    recovered: float | None = None
    closed_at_step: int | None = None

    @property
    def mttr(self) -> float | None:
        return None if self.recovered is None \
            else self.recovered - self.occurred

    @property
    def steps_to_recover(self) -> int | None:
        """Useful-step distance from detection to recovery — what the
        bounded-recovery acceptance tests pin."""
        return None if self.closed_at_step is None \
            else self.closed_at_step - self.opened_at_step


def _quantile(values: Sequence[float], q: float) -> float:
    if not values:
        return 0.0
    ordered = sorted(values)
    k = min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))
    return ordered[k]


@dataclasses.dataclass(frozen=True)
class AvailabilityReport:
    """The SLA view of one run."""

    wall: float
    useful_steps: int
    wasted_steps: int
    goodput: float                       # useful steps / wall second
    mttr: tuple[float, ...]              # per recovered incident
    mttr_p95: float
    recoveries: dict[str, int]           # incident kind -> recovered count
    actions: dict[str, int]              # action kind -> count
    replayed_fraction: float             # wasted / (useful + wasted)
    unrecovered: int                     # incidents still open at the end
    state: str

    def row_args(self) -> dict:
        """Flat JSON-safe summary (the final ``availability`` event and
        the bench rows both embed this)."""
        return {"wall": self.wall, "useful_steps": self.useful_steps,
                "wasted_steps": self.wasted_steps, "goodput": self.goodput,
                "mttr_p95": self.mttr_p95,
                "replayed_fraction": self.replayed_fraction,
                "unrecovered": self.unrecovered, "state": self.state,
                "recoveries": dict(self.recoveries),
                "actions": dict(self.actions)}


class ResilienceController:
    """The recovery ladder as an explicit state machine.

    Clock-agnostic: every entry point takes ``t_now`` in the caller's
    clock, so one controller serves the real training loop (wall
    seconds) and the cluster simulator (sim seconds).  The controller
    only *decides and accounts*; callers perform the actions (sleep,
    restore, membership change) with whatever machinery their world has.
    """

    def __init__(self, policy: ResiliencePolicy | None = None, *,
                 n_workers: int = 1, recorder=None, source: str = "train",
                 job: str = "train", start_step: int = 0):
        self.policy = policy or ResiliencePolicy()
        self.recorder = recorder
        self.source = source
        self.job = job
        self.state = RUNNING
        self.retries = 0
        self.restores_left = self.policy.max_restores
        self.n_nominal = n_workers
        self.n_active = n_workers
        # logical training progress: committed_step is the next step to
        # run; high_water marks the furthest progress ever reached, so a
        # post-restore step below it is a replay (wasted work)
        self.committed_step = start_step
        self.high_water = start_step
        self.last_ckpt_step = start_step
        self.useful_steps = 0
        self.wasted_steps = 0
        self.incidents: list[Incident] = []
        self.monitor = StragglerMonitor(
            threshold=self.policy.straggler_threshold,
            warmup=self.policy.straggler_warmup)
        self._actions: dict[str, int] = {}
        self._draws = 0

    # -- observability ----------------------------------------------------

    def _emit(self, kind: str, t: float, **args) -> None:
        if self.recorder is not None:
            self.recorder.record(EventRecord(
                kind=kind, time=float(t), source=self.source,
                job=self.job, args=args))

    def _action(self, kind: str, t: float, **args) -> None:
        self._actions[kind] = self._actions.get(kind, 0) + 1
        REGISTRY.counter(
            "resilience_actions_total",
            "recovery actions taken, by kind").inc(kind=kind)
        self._emit(kind, t, **args)

    @property
    def degraded(self) -> bool:
        return self.n_active < self.n_nominal

    @property
    def open_incidents(self) -> list[Incident]:
        return [i for i in self.incidents if i.recovered is None]

    # -- the ladder -------------------------------------------------------

    def step_ok(self, t_now: float, dt: float,
                worker_times: Iterable[tuple[str, float]] = ()
                ) -> list[str]:
        """One step completed.  Closes open incidents (first useful step
        after a fault = recovery), classifies the step useful vs replay,
        and returns hosts the straggler monitor now flags (the caller
        owns the eviction)."""
        replay = self.committed_step < self.high_water
        if replay:
            self.wasted_steps += 1
            REGISTRY.counter(
                "resilience_wasted_steps_total",
                "replayed or discarded training steps").inc(kind="replay")
        else:
            self.useful_steps += 1
        self.committed_step += 1
        self.high_water = max(self.high_water, self.committed_step)
        self.retries = 0
        if not replay:
            # recovery means useful progress, not replaying old ground
            for inc in self.open_incidents:
                inc.recovered = t_now
                inc.closed_at_step = self.committed_step
                REGISTRY.counter(
                    "resilience_recoveries_total",
                    "incidents recovered, by fault kind").inc(
                        kind=inc.kind)
                REGISTRY.histogram(
                    "resilience_mttr_seconds",
                    "fault occurrence to first useful step").observe(
                        inc.mttr)
                self._emit("recovery", t_now, fault=inc.kind,
                           worker=inc.worker, mttr=inc.mttr,
                           steps=inc.steps_to_recover)
            self.state = RUNNING
        for host, seconds in worker_times:
            self.monitor.record(host, seconds)
        return self.monitor.stragglers()

    def step_failed(self, t_now: float,
                    error: object = None) -> tuple[str, float]:
        """One step failed.  Returns (action, delay): ``("retry", d)`` —
        back off d then rerun; ``("restore", 0)`` — replay from the last
        checkpoint (budget charged here); ``("halt", 0)`` — budgets
        exhausted, re-raise."""
        if not self.open_incidents:
            self.incidents.append(Incident(
                kind="step_failure", occurred=t_now, detected=t_now,
                opened_at_step=self.committed_step))
            self._emit("fault_detected", t_now, fault="step_failure",
                       error=repr(error) if error is not None else "")
        self.retries += 1
        if self.retries <= self.policy.max_retries:
            self.state = BACKOFF
            self._draws += 1
            delay = self.policy.backoff(self.retries, self._draws)
            self._action("backoff", t_now, attempt=self.retries,
                         delay=delay)
            return ("retry", delay)
        if self.restores_left > 0:
            self.restores_left -= 1
            self.retries = 0
            self.state = RESTORING
            self._action("restore", t_now,
                         restores_left=self.restores_left,
                         from_step=self.last_ckpt_step)
            return ("restore", 0.0)
        self.state = HALTED
        self._action("halt", t_now)
        return ("halt", 0.0)

    def restored(self, step: int, t_now: float) -> None:
        """The caller finished a checkpoint restore to ``step``; steps
        between it and the previous high-water mark will replay."""
        self.committed_step = step
        self.last_ckpt_step = step
        self._emit("restored", t_now, step=step)

    def discard_step(self, t_now: float) -> None:
        """An in-flight step was voided (e.g. the sync barrier died with
        a crashed worker): pure waste, no progress."""
        self.wasted_steps += 1
        REGISTRY.counter(
            "resilience_wasted_steps_total",
            "replayed or discarded training steps").inc(kind="discard")
        self._emit("step_discarded", t_now, step=self.committed_step)

    def fault_detected(self, kind: str, t_now: float, occurred: float,
                       worker: str = "") -> Incident:
        """An infrastructure fault surfaced (crash, preemption, slow
        host, link degradation).  Opens the incident clock."""
        inc = Incident(kind=kind, occurred=occurred, detected=t_now,
                       worker=worker, opened_at_step=self.committed_step)
        self.incidents.append(inc)
        self._emit("fault_detected", t_now, fault=kind, worker=worker,
                   occurred=occurred)
        return inc

    def evict(self, workers: Sequence[str], t_now: float,
              kind: str = "evict") -> None:
        """Workers left the fleet (straggler eviction or fail-stop
        repair): degrade to N−k and stop counting their step times."""
        self.n_active -= len(workers)
        for w in workers:
            self.monitor.forget(w)
        self._action(kind, t_now, workers=list(workers),
                     n_active=self.n_active)

    def readmit(self, workers: Sequence[str], t_now: float) -> None:
        """Replacement workers joined; capacity recovers toward N."""
        self.n_active += len(workers)
        self._action("readmit", t_now, workers=list(workers),
                     n_active=self.n_active)

    def checkpoint_saved(self, step: int, t_now: float) -> None:
        self.last_ckpt_step = step
        self._emit("checkpoint", t_now, step=step)

    def checkpoint_failed(self, t_now: float, error: object = None) -> None:
        """A checkpoint write failed — tolerated (the run continues on
        the previous tag; the next cadence retries), but counted: a
        later restore replays further."""
        REGISTRY.counter(
            "resilience_ckpt_failures_total",
            "checkpoint writes that failed").inc()
        self._action("ckpt_fail", t_now,
                     error=repr(error) if error is not None else "")

    def replanned(self, t_now: float, reason: str = "") -> None:
        """The caller refit + replanned (link degradation response or a
        membership change) — counted as a recovery action."""
        self._action("replan", t_now, reason=reason)

    # -- reporting --------------------------------------------------------

    def report(self, wall: float) -> AvailabilityReport:
        """Close the books: goodput gauge, recovery tallies, MTTR
        distribution, and the final ``availability`` event."""
        mttr = tuple(i.mttr for i in self.incidents
                     if i.recovered is not None)
        recoveries: dict[str, int] = {}
        for i in self.incidents:
            if i.recovered is not None:
                recoveries[i.kind] = recoveries.get(i.kind, 0) + 1
        goodput = self.useful_steps / wall if wall > 0 else 0.0
        total = self.useful_steps + self.wasted_steps
        rep = AvailabilityReport(
            wall=wall, useful_steps=self.useful_steps,
            wasted_steps=self.wasted_steps, goodput=goodput,
            mttr=mttr, mttr_p95=_quantile(mttr, 0.95),
            recoveries=recoveries, actions=dict(self._actions),
            replayed_fraction=self.wasted_steps / total if total else 0.0,
            unrecovered=len(self.open_incidents), state=self.state)
        REGISTRY.gauge(
            "resilience_goodput",
            "useful steps per wall second").set(goodput, job=self.job)
        self._emit("availability", wall, **rep.row_args())
        return rep


def run_supervised(step_fn: Callable, state, pipeline,
                   ckpt: "checkpoint.AsyncCheckpointer", start_step: int,
                   num_steps: int, *, ckpt_every: int = 50,
                   policy: ResiliencePolicy | None = None,
                   state_template=None, on_metrics=None,
                   sleep_fn: Callable[[float], None] = time.sleep,
                   clock: Callable[[], float] = time.monotonic,
                   recorder=None,
                   controller: ResilienceController | None = None):
    """Drive a real training loop through the resilience ladder.

    The supervisor successor to ``fault.run_with_recovery`` (which now
    delegates here): failed steps retry after seeded exponential backoff
    with jitter, escalate to checkpoint restore under a bounded budget,
    and re-raise when the budget is spent.  Checkpoint-write failures
    are tolerated (counted, retried next cadence) rather than fatal.
    Returns ``(state, step, controller)`` accounting included — callers
    that only want the ``run_with_recovery`` contract take the first
    two.
    """
    ctrl = controller or ResilienceController(
        policy, recorder=recorder, source="train", job="train",
        start_step=start_step)
    t0 = clock()
    step = start_step
    while step < num_steps:
        batch = pipeline.batch_at(step)
        try:
            s0 = clock()
            state, metrics = step_fn(state, batch)
            dt = clock() - s0
            if on_metrics:
                on_metrics(step, metrics, dt)
            ctrl.step_ok(clock() - t0, dt)
            step += 1
            if step % ckpt_every == 0:
                try:
                    ckpt.save(step, state)
                    ctrl.checkpoint_saved(step, clock() - t0)
                except Exception as e:  # noqa: BLE001 — tolerated
                    log.warning("checkpoint at step %d failed: %s",
                                step, e)
                    ctrl.checkpoint_failed(clock() - t0, e)
        except Exception as e:  # noqa: BLE001 — any step failure
            action, delay = ctrl.step_failed(clock() - t0, e)
            log.warning("step %d failed (%s) -> %s", step, e, action)
            if action == "retry":
                sleep_fn(delay)
                continue
            if action == "restore":
                latest = checkpoint.latest_step(ckpt.ckpt_dir)
                if latest is None:
                    raise
                state, step, _ = checkpoint.restore(
                    ckpt.ckpt_dir, state_template or state)
                ctrl.restored(step, clock() - t0)
                continue
            raise
    ckpt.save(step, state)
    ctrl.checkpoint_saved(step, clock() - t0)
    ckpt.wait()
    return state, step, ctrl
