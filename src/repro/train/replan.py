"""Online refit + replan: close the sim->real loop on the live train step.

MG-WFBP's pipeline is measure -> plan -> execute (paper §5.1, Alg. 2) —
but the paper measures once, before training.  On a real fabric the
effective (a, b) drifts (contention, thermal throttling, elastic
membership), and a plan computed from a stale model silently stops being
optimal.  This module keeps the loop closed *during* training:

* :func:`measure_comm_model` — time real jitted collectives over the data
  axes at several message sizes and least-squares fit (a, b)
  (``cost_model.fit``): the measured analogue of
  ``cost_model.production_comm_model``.
* :class:`ReplanController` — a host-side policy that consumes the
  :class:`~repro.obs.recorder.IterationRecord` stream emitted by
  ``train.step.instrument_step`` (via its ``on_record`` hook), refits the
  effective comm model from the observed non-overlapped communication,
  drives the incremental :class:`~repro.core.planner.Planner` (which emits
  ``planner_update`` events), and — when the predicted win of the new plan
  beats a hysteresis threshold — rebuilds the jitted step with
  ``build_train_step(plan_override=...)`` OFF the hot path and swaps it in
  between iterations.  Bucketing is pure communication scheduling, so a
  swap can change step *timing* but never step *numerics* (pinned by
  tests/test_replan.py).
* :func:`closed_loop` — convenience assembly of the whole pipeline:
  measure costs, build the step from them, wrap it with instrumentation,
  and attach a controller whose rebuild callback re-derives the step.

Everything here runs on the host between dispatches; nothing lands inside
jit (same discipline as ``instrument_step``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import cost_model
from repro.core import planner as planner_mod
from repro.core.cost_model import AllReduceModel
from repro.core.planner import MergePlan, SpecDelta, TensorSpec
from repro.core.simulator import simulate
from repro.obs.drift import DriftMonitor
from repro.obs.recorder import IterationRecord, plan_fingerprint

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Measured communication model.
# ---------------------------------------------------------------------------

def measure_comm_model(mesh, dp_axes: Sequence[str],
                       sizes_bytes: Sequence[int] = (1 << 16, 1 << 19,
                                                     1 << 22),
                       *, n_warmup: int = 1, n_iters: int = 5,
                       name: str = "measured") -> AllReduceModel:
    """Fit (a, b) from real timed all-reduces on the mesh's data axes.

    Times ``jax.jit(shard_map(psum))`` per message size (compile + warmup
    excluded, wall clock around ``block_until_ready``) and least-squares
    fits ``T(M) = a + b*M`` — the measured counterpart of the analytic
    ``production_comm_model``.  With no data axes on the mesh the psum is
    an identity; the fit then captures dispatch overhead only, which is
    still the correct effective model for that (degenerate) topology.
    """
    from repro.train.step import _shard_map

    axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    samples_n: list[float] = []
    samples_t: list[float] = []
    for nbytes in sizes_bytes:
        n_elems = max(1, int(nbytes) // 4)
        x = jnp.zeros((n_elems,), jnp.float32)
        if axes:
            body = _shard_map(lambda v: jax.lax.psum(v, axes), mesh,
                              in_specs=(P(),), out_specs=P(),
                              manual_axes=frozenset(axes))
        else:
            def body(v):
                return v + 0.0
        fn = jax.jit(body)
        jax.block_until_ready(fn(x))            # compile
        for _ in range(n_warmup):
            jax.block_until_ready(fn(x))
        t0 = time.perf_counter()
        for _ in range(n_iters):
            jax.block_until_ready(fn(x))
        samples_n.append(float(n_elems * 4))
        samples_t.append((time.perf_counter() - t0) / n_iters)
    if len(set(samples_n)) >= 2:
        return cost_model.fit(samples_n, samples_t, name)
    # single size: degenerate fit -> all latency, zero slope
    return AllReduceModel(max(samples_t[0], _EPS), 0.0, name)


# ---------------------------------------------------------------------------
# The controller.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplanDecision:
    """One refit round: what the controller saw and what it did."""

    iteration: int
    observed_t_iter: float       # window-median wall iteration time
    stretch: float               # observed / predicted non-overlapped comm
    model: AllReduceModel        # effective model AFTER this refit
    old_plan: MergePlan
    new_plan: MergePlan
    predicted_old: float         # t_iter of old plan under the new model
    predicted_new: float         # t_iter of new plan under the new model
    swapped: bool

    @property
    def predicted_win(self) -> float:
        """Relative improvement the swap was judged on."""
        if self.predicted_old <= 0:
            return 0.0
        return (self.predicted_old - self.predicted_new) / self.predicted_old


class ReplanController:
    """Consume live IterationRecords; refit, replan, and swap the step.

    Policy knobs:

    * ``warmup``      — records ignored for refitting (compile jitter);
    * ``interval``    — records per refit window (median over the window
                        rejects stragglers);
    * ``damping``     — weight of the fresh fit against the previous
                        effective model (``cost_model.blend``; 0.5 kills
                        the two-cycle oscillation a full-step update can
                        enter, same rationale as ``plan_contention_aware``);
    * ``hysteresis``  — minimum predicted relative win before a swap is
                        worth a recompile (swaps are off-hot-path but not
                        free);
    * ``min_stretch`` / ``max_stretch`` — clamp on the per-round refit
                        ratio so one pathological window cannot catapult
                        the model.

    The controller plugs into ``instrument_step(..., on_record=ctl.observe)``.
    ``rebuild`` is called with the winning :class:`MergePlan` and must
    return the new (jitted, instrumented) step callable — typically a
    closure over ``build_train_step(..., plan_override=plan)``.  The
    freshly built step is exposed as :attr:`step_fn`; the driving loop
    reads it each iteration (see :func:`closed_loop`).

    Drift alerts: every record also feeds a :class:`DriftMonitor`
    comparing the current plan's closed-form prediction against the wall
    time, so sustained mismatch lands as ``drift_alert`` events in the
    recorder ring alongside the planner's ``planner_update`` events.
    """

    def __init__(self, specs: Sequence[TensorSpec], plan: MergePlan,
                 model: AllReduceModel, *,
                 t_f: float = 0.0,
                 rebuild: Callable[[MergePlan], Callable] | None = None,
                 recorder=None,
                 warmup: int = 2, interval: int = 4,
                 damping: float = 0.5, hysteresis: float = 0.05,
                 drift_threshold: float = 0.15,
                 min_stretch: float = 0.1, max_stretch: float = 10.0):
        if interval < 1:
            raise ValueError(f"interval must be >= 1, got {interval}")
        if not 0.0 <= damping <= 1.0:
            raise ValueError(f"damping must be in [0, 1], got {damping}")
        self.specs = list(specs)
        self.plan = plan
        self.model = cost_model.as_linear(model)
        self.t_f = float(t_f)
        self.rebuild = rebuild
        self.recorder = recorder
        self.warmup = int(warmup)
        self.interval = int(interval)
        self.damping = float(damping)
        self.hysteresis = float(hysteresis)
        self.min_stretch = float(min_stretch)
        self.max_stretch = float(max_stretch)
        self.planner = planner_mod.Planner(self.specs, self.model,
                                           recorder=recorder)
        self.monitor = DriftMonitor(threshold=drift_threshold,
                                    warmup=max(1, warmup),
                                    recorder=recorder,
                                    source="train", job="replan")
        self.step_fn: Callable | None = None   # set by rebuild / closed_loop
        self.decisions: list[ReplanDecision] = []
        self._window: list[float] = []
        self._n = 0

    # -- ingestion -------------------------------------------------------

    def observe(self, rec: IterationRecord) -> ReplanDecision | None:
        """Feed one live record; returns the decision if a refit ran."""
        observed = rec.end - rec.start
        self._n += 1
        pred = simulate(self.specs, self.plan, self.model, self.t_f)
        self.monitor.observe(rec.iteration, pred.t_iter, observed)
        if self._n <= self.warmup:
            return None
        self._window.append(observed)
        if len(self._window) < self.interval:
            return None
        return self._refit(rec.iteration)

    def update_backward_times(self, tb_table: dict[str, float]) -> MergePlan:
        """Point-refit per-tensor backward times (``path -> seconds``),
        e.g. from a fresh ``profiler.measure_loss_profile`` pass.  Routes
        through ``Planner.update`` so only the suffix from the first
        changed tensor is recomputed."""
        updates = {}
        for i, s in enumerate(self.specs):
            t_b = tb_table.get(s.name)
            if t_b is not None and t_b > 0 and t_b != s.t_b:
                updates[i] = dataclasses.replace(s, t_b=float(t_b))
        if not updates:
            return self.planner.plan()
        for i, s in updates.items():
            self.specs[i] = s
        return self.planner.update(SpecDelta(updates=updates))

    # -- the refit round -------------------------------------------------

    def _refit(self, iteration: int) -> ReplanDecision:
        window = sorted(self._window)
        self._window.clear()
        observed = window[len(window) // 2]              # median
        pred = simulate(self.specs, self.plan, self.model, self.t_f)
        # Observed non-overlapped communication: everything the wall
        # clock spent beyond forward + backward compute.  The stretch of
        # that bottleneck against its prediction is the refit signal —
        # uniform rescaling of (a, b) when we cannot separate per-bucket
        # durations (host-side records carry estimates, not measurements).
        obs_t_c_no = max(observed - (self.t_f + pred.t_b_total), 0.0)
        if pred.t_c_no > _EPS:
            stretch = obs_t_c_no / pred.t_c_no
        else:
            stretch = 1.0
        stretch = min(max(stretch, self.min_stretch), self.max_stretch)
        new_model = cost_model.blend(self.model,
                                     self.model.scaled(stretch),
                                     self.damping)
        new_plan = self.planner.replan(new_model)   # planner_update event
        self.model = new_model
        old_plan = self.plan
        t_old = simulate(self.specs, old_plan, new_model, self.t_f).t_iter
        t_new = simulate(self.specs, new_plan, new_model, self.t_f).t_iter
        win = (t_old - t_new) / t_old if t_old > 0 else 0.0
        swapped = False
        if new_plan.buckets != old_plan.buckets and win > self.hysteresis:
            if self.rebuild is not None:
                self.step_fn = self.rebuild(new_plan)
            self.plan = new_plan
            swapped = True
            self.monitor.reset()
        decision = ReplanDecision(
            iteration=iteration, observed_t_iter=observed, stretch=stretch,
            model=new_model, old_plan=old_plan,
            new_plan=new_plan, predicted_old=t_old, predicted_new=t_new,
            swapped=swapped)
        self.decisions.append(decision)
        return decision

    @property
    def swaps(self) -> list[ReplanDecision]:
        return [d for d in self.decisions if d.swapped]


# ---------------------------------------------------------------------------
# End-to-end assembly: measure -> plan -> execute -> refit -> replan.
# ---------------------------------------------------------------------------

def closed_loop(model, run, mesh, *,
                strategy: str | None = None,
                tb_table: dict | None = None,
                comm_model: AllReduceModel | None = None,
                t_f: float = 0.0,
                recorder=None,
                instrument: bool = True,
                donate: bool = True,
                **controller_kwargs):
    """Build a measured-cost train step with a live replan loop attached.

    Returns ``(controller, init_fn, art)``.  ``controller.step_fn`` is
    the instrumented step to drive; after each call the controller may
    have swapped in a rebuilt step (read the attribute fresh every
    iteration — that is the entire swap protocol):

        ctl, init_fn, art = closed_loop(model, run, mesh, ...)
        state = init_fn(jax.random.PRNGKey(0))
        for batch in batches:
            state, metrics = ctl.step_fn(state, batch)

    ``comm_model`` / ``tb_table`` are the measured costs (from
    :func:`measure_comm_model` / ``profiler.measure_loss_profile``);
    omitted, the step falls back to the analytic models and the loop
    simply starts from a worse prior.  The rebuild callback re-invokes
    ``build_train_step`` with ``plan_override`` and re-wraps with
    ``instrument_step`` feeding this same controller, so instrumentation
    and policy survive the swap.
    """
    from repro.train.step import build_train_step, instrument_step

    step_fn, init_fn, art = build_train_step(
        model, run, mesh, strategy=strategy, donate=donate,
        tb_table=tb_table, comm_model=comm_model)

    ctl = ReplanController(art.specs, art.plan, art.comm_model,
                           t_f=t_f, recorder=recorder,
                           **controller_kwargs)

    def _wrap(fn, artifacts):
        fn = jax.jit(fn)
        if not instrument:
            return fn
        return instrument_step(fn, artifacts, t_f=t_f, recorder=recorder,
                               on_record=ctl.observe)

    def rebuild(plan: MergePlan):
        new_fn, _, new_art = build_train_step(
            model, run, mesh, strategy=strategy, donate=donate,
            tb_table=tb_table, comm_model=ctl.model, plan_override=plan)
        art.plan = new_art.plan
        art.comm_model = new_art.comm_model
        return _wrap(new_fn, new_art)

    ctl.rebuild = rebuild
    ctl.step_fn = _wrap(step_fn, art)
    return ctl, init_fn, art
