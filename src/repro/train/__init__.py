from repro.train.train_state import TrainState
from repro.train.step import build_train_step, build_plan, StepArtifacts
from repro.train import checkpoint, fault, replan, resilience
