"""Per-tensor backward-time estimation (paper §5.1).

The planner needs ``t_b[l]``: the backward compute time attributable to each
gradient tensor.  The paper measures it with per-layer CUDA synchronization
over the first few iterations.  We provide both:

* ``measure_backward_times`` — real host timing of per-block VJPs
  (meaningful on CPU for tests / small models; on a real TPU deployment this
  would be driven by profiler traces exactly as in the paper).

* ``analytic_tb`` — a deterministic roofline-style estimate for the TPU
  target: a parameter tensor of p elements touched by B tokens costs
  ``max(4*B*p / (MFU * peak_flops), 3*p*bytes / hbm_bw)`` — 4Bp backward
  matmul FLOPs (dgrad + wgrad), or the bandwidth cost of streaming the
  weight + writing the gradient for bandwidth-bound tensors (norms, biases,
  embeddings).  Only *relative* magnitudes matter to the planner, and this
  model reproduces the paper's key structural fact: DNNs have many tiny
  tensors whose t_b is far below the all-reduce startup time.
"""

from __future__ import annotations

import time
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.core.bucketer import LeafMeta
from repro.core.cost_model import HBM_BW, PEAK_FLOPS_BF16


def analytic_tb(tokens_per_device: int, *, mfu: float = 0.5,
                peak_flops: float = PEAK_FLOPS_BF16, hbm_bw: float = HBM_BW,
                matmul_min_elems: int = 1 << 16) -> Callable[[LeafMeta], float]:
    """Build a ``LeafMeta -> t_b seconds`` function for the TPU target.

    Tensors with >= ``matmul_min_elems`` elements are treated as matmul
    weights (compute-bound at scale); smaller tensors (biases, norm scales)
    are bandwidth-bound.
    """
    if tokens_per_device <= 0:
        raise ValueError("tokens_per_device must be positive")

    def t_b(meta: LeafMeta) -> float:
        p = meta.size
        bw_time = 3.0 * meta.nbytes / hbm_bw
        if p >= matmul_min_elems:
            flop_time = 4.0 * tokens_per_device * p / (mfu * peak_flops)
            return max(flop_time, bw_time)
        return bw_time

    return t_b


def measure_backward_times(block_fns: Sequence[Callable], args_per_block,
                           n_warmup: int = 1, n_iters: int = 3) -> list[float]:
    """Host-side timing of each block's VJP (CPU analogue of paper §5.1).

    ``block_fns[i]`` maps ``args_per_block[i] -> output``; the measured
    quantity is the full vjp (forward + backward) wall time, averaged over
    ``n_iters`` after warmup.  The VJP is jitted once per block and the
    compiled function warmed before the timed loop, so the numbers are pure
    device execution — no Python tracing lands in the measurement the
    planner consumes.  Returns seconds per block, forward order.
    """
    times = []
    for fn, args in zip(block_fns, args_per_block):
        def vjp_fn(*a, fn=fn):
            out, vjp = jax.vjp(fn, *a)
            cot = jax.tree.map(lambda x: jnp.ones(x.shape, x.dtype), out)
            return vjp(cot)

        runj = jax.jit(vjp_fn)
        jax.block_until_ready(runj(*args))          # compile
        for _ in range(n_warmup):
            jax.block_until_ready(runj(*args))
        t0 = time.perf_counter()
        for _ in range(n_iters):
            jax.block_until_ready(runj(*args))
        times.append((time.perf_counter() - t0) / n_iters)
    return times


def distribute_block_times(block_times: Sequence[float],
                           metas_per_block: Sequence[Sequence[LeafMeta]]
                           ) -> list[float]:
    """Split measured per-block time across the block's tensors, weighted by
    element count (backward order within the block)."""
    out = []
    for t, metas in zip(block_times, metas_per_block):
        total = sum(m.size for m in metas) or 1
        out.extend(t * m.size / total for m in metas)
    return out


# ---------------------------------------------------------------------------
# Measured-cost planning inputs (the sim->real loop's "measure" phase).
# ---------------------------------------------------------------------------

def measured_tb(table: Mapping[str, float],
                fallback: Callable[[LeafMeta], float]
                ) -> Callable[[LeafMeta], float]:
    """``LeafMeta -> t_b`` from a measured per-tensor table with an analytic
    prior for unmeasured tensors (paper §5.1: profile the first iterations,
    fall back to the model where no measurement exists)."""
    def t_b(meta: LeafMeta) -> float:
        v = float(table.get(meta.path, 0.0))
        return v if v > 0.0 else fallback(meta)
    return t_b


def measure_loss_profile(loss_fn: Callable, args: tuple,
                         metas: Sequence[LeafMeta], *, n_warmup: int = 1,
                         n_iters: int = 3) -> tuple[float, dict[str, float]]:
    """Real timings for one model: ``(t_f, {path: t_b})``.

    Times the jitted forward (``loss_fn(*args)``) and the jitted full VJP on
    the same arguments; the backward share (VJP minus forward) is
    distributed over ``metas`` by element count
    (:func:`distribute_block_times` with the whole model as one block).
    This is the CPU/host analogue of the paper's per-layer profiling pass:
    absolute scale comes from measurement, per-tensor split from the
    size-proportional model.
    """
    fwd = jax.jit(loss_fn)
    jax.block_until_ready(fwd(*args))               # compile
    for _ in range(n_warmup):
        jax.block_until_ready(fwd(*args))
    t0 = time.perf_counter()
    for _ in range(n_iters):
        jax.block_until_ready(fwd(*args))
    t_f = (time.perf_counter() - t0) / n_iters
    t_vjp = measure_backward_times([loss_fn], [args], n_warmup=n_warmup,
                                   n_iters=n_iters)[0]
    # the VJP replays the forward; floor the backward share so noisy hosts
    # can never hand the planner a zero/negative profile
    t_b_total = max(t_vjp - t_f, 0.1 * t_vjp)
    per = distribute_block_times([t_b_total], [list(metas)])
    return t_f, {m.path: t for m, t in zip(metas, per)}
