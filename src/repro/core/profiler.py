"""Per-tensor backward-time estimation (paper §5.1).

The planner needs ``t_b[l]``: the backward compute time attributable to each
gradient tensor.  The paper measures it with per-layer CUDA synchronization
over the first few iterations.  We provide both:

* ``measure_backward_times`` — real host timing of per-block VJPs
  (meaningful on CPU for tests / small models; on a real TPU deployment this
  would be driven by profiler traces exactly as in the paper).

* ``analytic_tb`` — a deterministic roofline-style estimate for the TPU
  target: a parameter tensor of p elements touched by B tokens costs
  ``max(4*B*p / (MFU * peak_flops), 3*p*bytes / hbm_bw)`` — 4Bp backward
  matmul FLOPs (dgrad + wgrad), or the bandwidth cost of streaming the
  weight + writing the gradient for bandwidth-bound tensors (norms, biases,
  embeddings).  Only *relative* magnitudes matter to the planner, and this
  model reproduces the paper's key structural fact: DNNs have many tiny
  tensors whose t_b is far below the all-reduce startup time.
"""

from __future__ import annotations

import time
from typing import Callable, Sequence

import jax
import numpy as np

from repro.core.bucketer import LeafMeta
from repro.core.cost_model import HBM_BW, PEAK_FLOPS_BF16


def analytic_tb(tokens_per_device: int, *, mfu: float = 0.5,
                peak_flops: float = PEAK_FLOPS_BF16, hbm_bw: float = HBM_BW,
                matmul_min_elems: int = 1 << 16) -> Callable[[LeafMeta], float]:
    """Build a ``LeafMeta -> t_b seconds`` function for the TPU target.

    Tensors with >= ``matmul_min_elems`` elements are treated as matmul
    weights (compute-bound at scale); smaller tensors (biases, norm scales)
    are bandwidth-bound.
    """
    if tokens_per_device <= 0:
        raise ValueError("tokens_per_device must be positive")

    def t_b(meta: LeafMeta) -> float:
        p = meta.size
        bw_time = 3.0 * meta.nbytes / hbm_bw
        if p >= matmul_min_elems:
            flop_time = 4.0 * tokens_per_device * p / (mfu * peak_flops)
            return max(flop_time, bw_time)
        return bw_time

    return t_b


def measure_backward_times(block_fns: Sequence[Callable], args_per_block,
                           n_warmup: int = 1, n_iters: int = 3) -> list[float]:
    """Host-side timing of each block's VJP (CPU analogue of paper §5.1).

    ``block_fns[i]`` maps ``args_per_block[i] -> output``; the measured
    quantity is the full vjp (forward + backward) wall time, averaged over
    ``n_iters`` after warmup.  Returns seconds per block, forward order.
    """
    times = []
    for fn, args in zip(block_fns, args_per_block):
        def run():
            out, vjp = jax.vjp(fn, *args)
            cot = jax.tree.map(lambda x: np.ones(x.shape, x.dtype), out)
            g = vjp(cot)
            jax.block_until_ready(g)

        runj = jax.jit(lambda *a: None)  # placeholder to keep style uniform
        del runj
        for _ in range(n_warmup):
            run()
        t0 = time.perf_counter()
        for _ in range(n_iters):
            run()
        times.append((time.perf_counter() - t0) / n_iters)
    return times


def distribute_block_times(block_times: Sequence[float],
                           metas_per_block: Sequence[Sequence[LeafMeta]]
                           ) -> list[float]:
    """Split measured per-block time across the block's tensors, weighted by
    element count (backward order within the block)."""
    out = []
    for t, metas in zip(block_times, metas_per_block):
        total = sum(m.size for m in metas) or 1
        out.extend(t * m.size / total for m in metas)
    return out
