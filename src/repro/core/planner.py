"""Gradient-merge planners: the paper's Algorithm 1 plus baselines.

Terminology bridge
------------------
The paper indexes layers ``L .. 1`` with backward propagation running from
layer L down to layer 1; a *merged-gradient layer* ``l`` postpones its
communication and merges into ``l-1`` (the tensor produced *after* it during
backward).  We index tensors in **backward production order**: index 0 is the
first gradient produced (the paper's layer L), index ``L-1`` the last (the
paper's layer 1).  A plan is then a partition of ``0..L-1`` into contiguous
*buckets*; every tensor of a bucket except the last is a merged-gradient
layer, and the bucket's all-reduce may start when

  (1) the last tensor's gradient has been produced, and
  (2) the previous bucket's all-reduce has finished           (paper Eq. 7)

Planners
--------
* ``plan_wfbp``        — one bucket per tensor (WFBP baseline, Fig. 1b).
* ``plan_single``      — one bucket for everything (SyncEASGD, Fig. 1c).
* ``plan_fixed_size``  — PyTorch-DDP style byte-capped buckets (beyond-paper
                         baseline).
* ``plan_mgwfbp``      — the paper's Algorithm 1, faithful O(L^2)
                         (reference implementation).
* ``plan_dp_optimal``  — O(L^2) dynamic program that provably minimizes the
                         final communication finish time (reference
                         implementation).
* ``Planner``          — the production fast path: the same optimal DP
                         restructured around prefix-sum recurrences and a
                         monotonic frontier so a from-scratch plan is O(L)
                         and ``Planner.update(SpecDelta)`` replans
                         incrementally (O(L log L) amortized over an update
                         stream) — cheap enough to run *inside* elastic
                         resizes and simulator sweeps.
* ``plan_contention_aware`` — plan -> simulate -> refit (a, b) -> replan
                         fixpoint that corrects the exclusive-link
                         assumption against an observed (contended)
                         environment.
* ``plan_brute_force`` — exhaustive 2^(L-1) search (testing only).

All planners consume a list of :class:`TensorSpec` (backward order) and a
cost model exposing ``a``, ``b`` and ``time(nbytes)`` (see
``cost_model.AllReduceModel``).
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
import itertools
from typing import Callable, Mapping, Sequence

from repro.core import cost_model
from repro.core.cost_model import AllReduceModel
from repro.obs.metrics import REGISTRY
from repro.obs.recorder import EventRecord, plan_fingerprint


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One gradient tensor as seen by the communication scheduler."""

    name: str
    nbytes: int        # bytes to all-reduce for this tensor
    t_b: float         # backward compute time that produces this gradient (s)

    def __post_init__(self):
        if self.nbytes < 0 or self.t_b < 0:
            raise ValueError(f"negative spec: {self}")


@dataclasses.dataclass(frozen=True)
class MergePlan:
    """A partition of backward-ordered tensors into contiguous buckets."""

    buckets: tuple[tuple[int, ...], ...]
    strategy: str = "custom"

    def __post_init__(self):
        flat = [i for b in self.buckets for i in b]
        if flat != list(range(len(flat))):
            raise ValueError(
                f"buckets must be a contiguous partition of 0..L-1, got {self.buckets}")
        if any(len(b) == 0 for b in self.buckets):
            raise ValueError("empty bucket")

    @property
    def num_tensors(self) -> int:
        return sum(len(b) for b in self.buckets)

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    def bucket_of(self) -> list[int]:
        """tensor index -> bucket index."""
        out = [0] * self.num_tensors
        for k, b in enumerate(self.buckets):
            for i in b:
                out[i] = k
        return out

    def merged_flags(self) -> list[bool]:
        """Per-tensor flag: True iff the tensor is a merged-gradient layer
        (i.e. NOT the last element of its bucket).  Matches the paper's
        ``m[l] == l_m`` with the index order reversed."""
        flags = []
        for b in self.buckets:
            flags.extend([True] * (len(b) - 1) + [False])
        return flags

    def bucket_bytes(self, specs: Sequence[TensorSpec]) -> list[int]:
        return [sum(specs[i].nbytes for i in b) for b in self.buckets]

    @staticmethod
    def from_boundaries(num_tensors: int, last_indices: Sequence[int],
                        strategy: str = "custom") -> "MergePlan":
        """Build from the sorted list of bucket-final tensor indices."""
        last = sorted(set(last_indices))
        if not last or last[-1] != num_tensors - 1:
            raise ValueError("final tensor must close a bucket")
        buckets, start = [], 0
        for e in last:
            buckets.append(tuple(range(start, e + 1)))
            start = e + 1
        return MergePlan(tuple(buckets), strategy)

    @staticmethod
    def from_merged_flags(flags: Sequence[bool], strategy: str = "custom") -> "MergePlan":
        last = [i for i, f in enumerate(flags) if not f]
        if flags and flags[-1]:
            # last tensor can never be merged "forward"; force it to close.
            last.append(len(flags) - 1)
        return MergePlan.from_boundaries(len(flags), last, strategy)


# ---------------------------------------------------------------------------
# Baselines.
# ---------------------------------------------------------------------------

def plan_wfbp(specs: Sequence[TensorSpec]) -> MergePlan:
    """Per-tensor communication (WFBP)."""
    return MergePlan(tuple((i,) for i in range(len(specs))), "wfbp")


def plan_single(specs: Sequence[TensorSpec]) -> MergePlan:
    """Single merged communication (SyncEASGD)."""
    return MergePlan((tuple(range(len(specs))),), "single")


def plan_fixed_size(specs: Sequence[TensorSpec], cap_bytes: int) -> MergePlan:
    """PyTorch-DDP-style bucketing: close a bucket once it reaches cap."""
    if cap_bytes <= 0:
        raise ValueError("cap_bytes must be positive")
    last, acc = [], 0
    for i, s in enumerate(specs):
        acc += s.nbytes
        if acc >= cap_bytes:
            last.append(i)
            acc = 0
    if not last or last[-1] != len(specs) - 1:
        last.append(len(specs) - 1)
    return MergePlan.from_boundaries(len(specs), last, f"fixed:{cap_bytes}")


# ---------------------------------------------------------------------------
# Paper Algorithm 1 (faithful).
# ---------------------------------------------------------------------------

def _comm_starts(t_c: list[float], t_b_end: list[float]) -> list[float]:
    """Paper's CALCULATECOMMSTART in backward-order indexing (Eq. 7).

    ``t_b_end[i]`` is the timestamp when tensor i's gradient is ready;
    communication i starts at max(previous comm end, ready time).
    """
    L = len(t_c)
    tau_c = [0.0] * L
    tau_c[0] = t_b_end[0]
    for i in range(1, L):
        tau_c[i] = max(tau_c[i - 1] + t_c[i - 1], t_b_end[i])
    return tau_c


def plan_mgwfbp(specs: Sequence[TensorSpec], model: AllReduceModel) -> MergePlan:
    """The paper's Algorithm 1: optimal merged-gradient assignment.

    Faithful O(L^2) implementation.  Iterates tensors in backward order
    (paper: ``for l = L -> 2``); tensor i becomes a merged-gradient layer iff

        t_b_end[i+1] - tau_c[i] < a                         (paper Eq. 38)

    where ``t_b_end[i+1]`` is when the *next* tensor's gradient is ready and
    ``tau_c[i]`` is when tensor i's communication could start.  After each
    merge the communication start times are recomputed (paper line 13).
    """
    L = len(specs)
    if L == 0:
        return MergePlan((), "mgwfbp")
    model = cost_model.as_linear(model)
    a = model.a
    p = [float(s.nbytes) for s in specs]
    t_c = [model.time(x) for x in p]
    # Gradient-ready timestamps (backward start == 0):
    t_b_end, acc = [], 0.0
    for s in specs:
        acc += s.t_b
        t_b_end.append(acc)

    merged = [False] * L
    tau_c = _comm_starts(t_c, t_b_end)
    for i in range(L - 1):              # paper: l = L..2 (tensor i merges into i+1)
        if t_b_end[i + 1] - tau_c[i] < a:
            merged[i] = True
            # paper MERGE(): zero out this comm, grow the next one.
            p[i + 1] += p[i]
            p[i] = 0.0
            t_c[i] = 0.0
            t_c[i + 1] = model.time(p[i + 1])
            tau_c = _comm_starts(t_c, t_b_end)
    return MergePlan.from_merged_flags(merged, "mgwfbp")


# ---------------------------------------------------------------------------
# Beyond-paper: provably optimal DP and exhaustive search.
# ---------------------------------------------------------------------------

def plan_dp_optimal(specs: Sequence[TensorSpec], model: AllReduceModel) -> MergePlan:
    """O(L^2) dynamic program minimizing the final all-reduce finish time.

    Let ``f[i]`` be the minimum finish time of all communications covering
    tensors ``0..i`` given tensor i closes a bucket.  Buckets are contiguous,
    and a bucket (j+1..i) may start at max(f[j], ready[i]):

        f[i] = min_{j<i} max(f[j], ready[i]) + T(bytes[j+1..i])

    Because every plan's iteration time is ``t_f + max(f[L-1], ready[L-1])``
    and ``f[L-1] >= ready[L-1]`` always, minimizing f[L-1] minimizes the
    iteration time — this gives a certified-optimal reference for Algorithm 1
    (see tests/test_planner.py) and is the planner we ship as default.
    """
    L = len(specs)
    if L == 0:
        return MergePlan((), "dp_optimal")
    model = cost_model.as_linear(model)
    ready, acc = [], 0.0
    for s in specs:
        acc += s.t_b
        ready.append(acc)
    pre = [0] * (L + 1)   # prefix bytes
    for i, s in enumerate(specs):
        pre[i + 1] = pre[i] + s.nbytes

    NEG = -1
    f = [float("inf")] * L
    parent = [NEG] * L
    for i in range(L):
        # bucket = (0..i)
        f[i] = ready[i] + model.time(pre[i + 1])
        parent[i] = NEG
        for j in range(i):
            cand = max(f[j], ready[i]) + model.time(pre[i + 1] - pre[j + 1])
            if cand < f[i] - 1e-15:
                f[i] = cand
                parent[i] = j
    last, i = [], L - 1
    while i != NEG:
        last.append(i)
        i = parent[i]
    return MergePlan.from_boundaries(L, sorted(last), "dp_optimal")


# ---------------------------------------------------------------------------
# Fast path: incremental O(L log L) planner.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpecDelta:
    """A change to a planning problem, consumed by :meth:`Planner.update`.

    Applied in order: ``updates`` (index -> replacement spec), then
    ``truncate`` (keep the first ``truncate`` tensors), then ``append``.
    ``model`` swaps the cost model (elastic resize / online (a, b) refit)
    without touching the specs.
    """

    model: AllReduceModel | None = None
    updates: Mapping[int, TensorSpec] | None = None
    truncate: int | None = None
    append: tuple[TensorSpec, ...] = ()


class Planner:
    """Incremental DP-optimal merge planner.

    Same objective as :func:`plan_dp_optimal` —

        f[i] = min_{j<i}  max(f[j], ready[i]) + T(pre[i+1] - pre[j+1])

    — but evaluated in O(1) amortized per tensor instead of O(L) by
    splitting the candidate set ``j`` on ``f[j] <= ready[i]``:

    * **overlapped** candidates (``f[j] <= ready[i]``): the bucket starts at
      ``ready[i]``, so the best candidate maximizes ``pre[j+1]`` — and since
      prefix bytes are nondecreasing that is simply the *largest* such j.
      Because both ``f`` and ``ready`` are nondecreasing, the split point
      only moves right: a two-pointer suffices.
    * **queued** candidates (``f[j] > ready[i]``): the bucket starts at
      ``f[j]``, so the best candidate minimizes ``g[j] = f[j] - b*pre[j+1]``
      over a window whose ends both move right — a classic monotonic-deque
      sliding minimum.

    The DP frontier (``f``/``parent``/prefix arrays) persists on the
    instance, so :meth:`update` only recomputes the suffix at/after the
    first changed tensor — O(L - k) for a point edit, O(1) amortized for a
    stream of appends, O(L) for a cost-model swap (still a ~L× win over the
    O(L^2) reference planners, which is what makes replanning cheap enough
    for simulator sweeps and contention fixpoints).  ``scratch_plans`` /
    ``incremental_updates`` count how state was (re)built; the benchmark
    smoke guard asserts sweeps never fall back to from-scratch planning.
    """

    strategy = "dp_incremental"

    def __init__(self, specs: Sequence[TensorSpec], model: AllReduceModel,
                 *, recorder=None):
        self.scratch_plans = 0
        self.incremental_updates = 0
        # optional repro.obs.recorder.FlightRecorder for decision events
        self.recorder = recorder
        self._specs: list[TensorSpec] = list(specs)
        # path models flatten to the (a, b) the DP consumes; a flat model
        # passes through untouched (bit-identical to pre-path behavior)
        self._model = cost_model.as_linear(model)
        self._rebuild()

    # -- public API ------------------------------------------------------

    @property
    def specs(self) -> tuple[TensorSpec, ...]:
        return tuple(self._specs)

    @property
    def model(self) -> AllReduceModel:
        return self._model

    @property
    def num_tensors(self) -> int:
        return len(self._specs)

    def plan(self) -> MergePlan:
        """The current optimal plan (cached; O(#buckets) to materialize)."""
        if self._plan is None:
            L = len(self._specs)
            if L == 0:
                self._plan = MergePlan((), self.strategy)
            else:
                last, i = [], L - 1
                while i >= 0:
                    last.append(i)
                    i = self._parent[i]
                self._plan = MergePlan.from_boundaries(L, sorted(last),
                                                       self.strategy)
        return self._plan

    @property
    def finish_time(self) -> float:
        """Optimal final communication finish time f[L-1] (0 if L == 0)."""
        return self._f[-1] if self._f else 0.0

    def update(self, delta: SpecDelta) -> MergePlan:
        """Apply a delta and replan, reusing the unchanged DP prefix."""
        # validate the whole delta before mutating anything — a partial
        # application would leave specs and DP state silently inconsistent
        if delta.updates:
            bad = [i for i in delta.updates if not 0 <= i < len(self._specs)]
            if bad:
                raise IndexError(f"update indices {bad} out of range "
                                 f"0..{len(self._specs) - 1}")
        if delta.truncate is not None and \
                not 0 <= delta.truncate <= len(self._specs):
            raise IndexError(f"truncate {delta.truncate} out of range")
        self.incremental_updates += 1
        dirty = len(self._specs)            # first index whose DP is stale
        if delta.updates:
            for idx, spec in sorted(delta.updates.items()):
                if self._specs[idx] != spec:
                    self._specs[idx] = spec
                    dirty = min(dirty, idx)
        if delta.truncate is not None and delta.truncate < len(self._specs):
            del self._specs[delta.truncate:]
            dirty = min(dirty, delta.truncate)
        if delta.append:
            dirty = min(dirty, len(self._specs))
            self._specs.extend(delta.append)
        if delta.model is not None:
            model = cost_model.as_linear(delta.model)
            if (model.a != self._model.a or
                    model.b != self._model.b):
                dirty = 0                   # every edge cost changed
            self._model = model
        self._refresh(dirty)
        REGISTRY.counter(
            "planner_incremental_updates_total",
            "Planner.update calls (suffix-reuse replans)").inc()
        plan = self.plan()
        if self.recorder is not None:
            self.recorder.record(EventRecord(
                kind="planner_update", time=float(self.incremental_updates),
                source="planner",
                args={"plan": plan_fingerprint(plan),
                      "num_buckets": plan.num_buckets,
                      "dirty_from": dirty,
                      "model_a": self._model.a, "model_b": self._model.b}))
        return plan

    def replan(self, model: AllReduceModel) -> MergePlan:
        """Convenience: elastic resize / (a, b) refit -> new plan."""
        return self.update(SpecDelta(model=model))

    def append(self, *specs: TensorSpec) -> MergePlan:
        """Convenience: streaming profile ingestion."""
        return self.update(SpecDelta(append=tuple(specs)))

    # -- internals -------------------------------------------------------

    def _rebuild(self) -> None:
        """Full state construction from the spec list (counted)."""
        self.scratch_plans += 1
        REGISTRY.counter(
            "planner_scratch_plans_total",
            "Planner from-scratch DP rebuilds").inc()
        self._ready: list[float] = []
        self._pre: list[float] = [0.0]      # prefix bytes, extended index m
        acc_t = 0.0
        for s in self._specs:
            acc_t += s.t_b
            self._ready.append(acc_t)
            self._pre.append(self._pre[-1] + s.nbytes)
        self._F: list[float] = [0.0]        # F[m] = f[m-1], F[0] = 0
        self._g: list[float] = [0.0]        # g[m] = F[m] - b*pre[m]
        self._f: list[float] = []
        self._parent: list[int] = []
        self._dq: collections.deque[int] = collections.deque()
        self._p = 0
        self._plan: MergePlan | None = None
        self._run_dp(0)

    def _refresh(self, dirty: int) -> None:
        """Recompute prefix arrays and DP from ``dirty`` onwards."""
        L = len(self._specs)
        appended_only = dirty >= len(self._ready)
        del self._ready[dirty:]
        del self._pre[dirty + 1:]
        acc_t = self._ready[dirty - 1] if dirty else 0.0
        for s in self._specs[dirty:]:
            acc_t += s.t_b
            self._ready.append(acc_t)
            self._pre.append(self._pre[-1] + s.nbytes)
        del self._f[dirty:]
        del self._parent[dirty:]
        del self._F[dirty + 1:]
        del self._g[dirty + 1:]
        self._plan = None
        if not appended_only:
            # rebuild the frontier (two-pointer + deque) at position dirty
            if dirty == 0:
                self._p, self._dq = 0, collections.deque()
            else:
                # dirty == L after a bare truncate; ready[L-1] is a valid
                # lower bound for the next tensor's ready time (the pointer
                # only ever needs to start at or below its true position).
                r = self._ready[dirty] if dirty < L else self._ready[-1]
                self._p = bisect.bisect_right(self._F, r, 0, dirty + 1) - 1
                self._dq = collections.deque()
                g = self._g
                for m in range(self._p + 1, dirty):
                    while self._dq and g[self._dq[-1]] > g[m]:
                        self._dq.pop()
                    self._dq.append(m)
        self._run_dp(dirty)

    def _run_dp(self, start: int) -> None:
        """The vectorized-recurrence DP loop over tensors [start, L)."""
        L = len(self._specs)
        if start >= L:
            return
        a, b = self._model.a, self._model.b
        ready, pre = self._ready, self._pre
        F, g, f, parent = self._F, self._g, self._f, self._parent
        dq, p = self._dq, self._p
        for i in range(start, L):
            # new candidate m = i (bucket opens after tensor i-1)
            gi = g[i]
            while dq and g[dq[-1]] > gi:
                dq.pop()
            dq.append(i)
            r = ready[i]
            # two-pointer split: F[m] <= ready[i]  <=>  m <= p
            while p < i and F[p + 1] <= r:
                p += 1
            while dq and dq[0] <= p:
                dq.popleft()
            pre_i1 = pre[i + 1]
            # overlapped side: start at ready[i], maximize pre[m] -> m = p
            d = pre_i1 - pre[p]
            best = r + (a + b * d if d > 0 else 0.0)
            best_m = p
            # queued side: start at F[m], minimize g[m] over the window
            if dq:
                m = dq[0]
                d = pre_i1 - pre[m]
                cand = F[m] + (a + b * d if d > 0 else 0.0)
                if cand < best:
                    best, best_m = cand, m
            # zero-byte tail: an empty trailing bucket costs exactly 0, not
            # a — the g-ranking above overcharges it, so handle explicitly.
            if pre[i] == pre_i1:
                m = bisect.bisect_left(pre, pre_i1, 0, i + 1)
                cand = F[m] if F[m] > r else r
                if cand < best:
                    best, best_m = cand, m
            f.append(best)
            parent.append(best_m - 1)
            F.append(best)
            g.append(best - b * pre_i1)
        self._p = p


def plan_incremental(specs: Sequence[TensorSpec],
                     model: AllReduceModel) -> MergePlan:
    """One-shot use of the fast planner (same optimum as plan_dp_optimal)."""
    return Planner(specs, model).plan()


# ---------------------------------------------------------------------------
# Contention-aware planning: plan -> simulate -> refit -> replan fixpoint.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FixpointRound:
    """One iteration of the plan/simulate/refit loop."""

    plan: MergePlan
    model: AllReduceModel       # effective (a, b) AFTER this round's refit
    observed_t: float           # environment-measured iteration time
    predicted_t: float          # closed-form t_iter under the refit model
    planned_under: AllReduceModel | None = None   # model the plan came from


@dataclasses.dataclass(frozen=True)
class FixpointResult:
    plan: MergePlan             # best observed plan
    # the best round's post-refit effective (a, b): the loop's current best
    # estimate of the contended fabric — the model to carry forward into
    # future replans.  The model the plan was *computed from* is the
    # round's ``planned_under``.
    model: AllReduceModel
    rounds: tuple[FixpointRound, ...]
    converged: bool             # plan reached a fixed point (or exact cycle)
    best_round: int

    @property
    def observed_t(self) -> float:
        return self.rounds[self.best_round].observed_t


def effective_model(samples: Sequence[tuple[int, float]],
                    base: AllReduceModel) -> AllReduceModel:
    """Effective (a, b) from observed (nbytes, duration) collectives.

    Least-squares when the samples span >= 2 distinct sizes; otherwise the
    observed *stretch* (duration / exclusive-link prediction) scales the
    base model — the single-bucket plan's degenerate case.
    """
    sized = [(float(n), float(t)) for n, t in samples if n > 0]
    if len({n for n, _ in sized}) >= 2:
        return cost_model.fit([n for n, _ in sized], [t for _, t in sized],
                              "effective")
    stretches = [t / base.time(n) for n, t in sized if base.time(n) > 0]
    if not stretches:
        return base
    return base.scaled(sum(stretches) / len(stretches))


def plan_contention_aware(
        specs: Sequence[TensorSpec],
        model: AllReduceModel,
        evaluate: Callable[[MergePlan],
                           tuple[float, Sequence[tuple[int, float]]]],
        *,
        t_f: float = 0.0,
        max_rounds: int = 5,
        damping: float = 0.5,
        seed_plans: Sequence[MergePlan] = (),
        schedule=None,
        recorder=None,
) -> FixpointResult:
    """Close the loop the static planners leave open.

    The exclusive-link model underlying :func:`plan_mgwfbp` /
    :func:`plan_dp_optimal` mispredicts on shared fabrics: concurrent
    collectives (other jobs, background bursts) stretch each other via
    processor sharing, so the *effective* (a, b) a plan experiences differs
    from the hardware model it was computed for (cf. DeAR,
    arXiv:2302.12445).  This fixpoint iterates:

      1. plan under the current effective model (exclusive-link at round 0);
      2. ``evaluate(plan)`` — simulate (or measure) the plan in its real,
         contended environment, returning the achieved iteration time and
         the observed per-bucket (nbytes, duration) samples;
      3. refit the effective (a, b) from the observations
         (:func:`effective_model`), damped against the previous estimate;
      4. replan incrementally (:meth:`Planner.replan`) and repeat until the
         plan stops changing or ``max_rounds`` is hit.

    Returns the *best observed* plan across rounds.  The candidate set
    always contains the exclusive-link DP plan (round 0) plus any
    ``seed_plans`` (callers pass the static baselines they must not
    regress below — e.g. the exclusive-link Algorithm-1 plan), so the
    result never loses to them on the evaluated environment.  ``damping``
    is the weight of the new fit against the previous effective model; 0.5
    suppresses the two-cycle oscillation a full-step update can fall into.

    ``schedule`` (a ``repro.sim.schedules.Schedule``) tells the loop which
    iteration discipline the evaluated environment actually runs: the
    per-round prediction then uses the schedule's own closed form
    (``Schedule.predict_t_iter``) instead of the BSP Eq. 7/8 replay, so
    the refit is judged — and the bucketing optimized — under that
    schedule.  The DP recurrence itself keeps minimizing the last
    collective's finish time, which remains the right objective for every
    in-order schedule (only the effective (a, b) and the prediction
    change); ``None`` means BSP, exactly as before.

    This is the N=1 special case of :mod:`repro.core.coplanner`: one
    :class:`~repro.core.coplanner.CoJob` whose joint makespan IS its own
    iteration time, run through the same best-response machinery that
    co-plans N jobs — round for round the PR-2 loop (the pre-existing
    fixpoint tests pin the equivalence).

    ``model`` may be a :class:`~repro.core.cost_model.PathModel`: the DP
    plans on its flat composition (bit-identical for a single-phase
    path), and if ``evaluate`` returns a third element — a mapping
    ``link -> [(nbytes, occupancy s), ...]`` like the engine's
    ``JobResult.link_samples`` — the refit corrects each link's
    (a_l, b_l) from that link's own telemetry instead of smearing the
    whole path into one effective pair.
    """
    from repro.core import coplanner    # local import: no cycle

    job = coplanner.CoJob(name="job", specs=tuple(specs), model=model,
                          t_f=t_f, schedule=schedule,
                          seed_plans=tuple(seed_plans))

    def joint_evaluate(plans: Mapping[str, MergePlan]
                       ) -> "coplanner.CoObservation":
        out = evaluate(plans["job"])
        link_samples: Mapping = {}
        if len(out) == 3:
            observed, samples, link_samples = out
        else:
            observed, samples = out
        return coplanner.CoObservation(
            makespan=observed,
            jobs={"job": coplanner.JobObservation(
                t_iter=observed, samples=tuple(samples),
                link_samples=tuple(
                    (link, tuple((int(n), float(t)) for n, t in pairs))
                    for link, pairs in dict(link_samples).items()))})

    co = coplanner.CoPlanner([job], joint_evaluate, max_rounds=max_rounds,
                             damping=damping, recorder=recorder)
    return co.run().fixpoint("job")


def plan_brute_force(specs: Sequence[TensorSpec], model: AllReduceModel) -> MergePlan:
    """Exhaustive search over all 2^(L-1) contiguous partitions (tests only)."""
    from repro.core.simulator import simulate  # local import to avoid cycle

    L = len(specs)
    if L == 0:
        return MergePlan((), "brute_force")
    if L > 18:
        raise ValueError(f"brute force infeasible for L={L}")
    best, best_t = None, float("inf")
    for mask in itertools.product([False, True], repeat=L - 1):
        last = [i for i in range(L - 1) if not mask[i]] + [L - 1]
        plan = MergePlan.from_boundaries(L, last, "brute_force")
        t = simulate(specs, plan, model).t_iter
        if t < best_t - 1e-15:
            best, best_t = plan, t
    return best


# ---------------------------------------------------------------------------
# Dispatch + elastic re-planning.
# ---------------------------------------------------------------------------

def make_plan(strategy: str, specs: Sequence[TensorSpec],
              model: AllReduceModel | None = None) -> MergePlan:
    """Build a plan from a strategy string.

    ``wfbp`` | ``single`` | ``mgwfbp`` | ``dp_optimal`` | ``dp_incremental``
    | ``dp_batched`` | ``fixed:<bytes>``.

    ``dp_batched`` routes through the fleet backend's batched DP kernel
    (``repro.sim.fleet.plan_batched``) — same optimum, bucket-for-bucket
    equal to ``dp_optimal``; pointless for ONE plan (use it to amortize a
    batch) but exposed here so sweeps and configs can name it.
    """
    if strategy == "wfbp":
        return plan_wfbp(specs)
    if strategy == "single":
        return plan_single(specs)
    if strategy.startswith("fixed:"):
        return plan_fixed_size(specs, int(strategy.split(":", 1)[1]))
    if model is None:
        raise ValueError(f"strategy {strategy!r} needs a cost model")
    if strategy == "mgwfbp":
        return plan_mgwfbp(specs, model)
    if strategy == "dp_optimal":
        return plan_dp_optimal(specs, model)
    if strategy == "dp_incremental":
        return plan_incremental(specs, model)
    if strategy == "dp_batched":
        from repro.sim.fleet import plan_batched  # local import: no cycle
        return plan_batched([(specs, model)])[0]
    raise ValueError(f"unknown merge strategy {strategy!r}")


def replan(strategy: str, specs: Sequence[TensorSpec],
           model: AllReduceModel) -> MergePlan:
    """Elastic-scaling hook: membership changed -> (a, b) changed -> replan.

    The paper computes the plan once before training (O(L^2), negligible);
    on an elastic resize we simply recompute it for the new cost model and
    keep training from the latest checkpoint.
    """
    return make_plan(strategy, specs, model)
