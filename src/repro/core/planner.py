"""Gradient-merge planners: the paper's Algorithm 1 plus baselines.

Terminology bridge
------------------
The paper indexes layers ``L .. 1`` with backward propagation running from
layer L down to layer 1; a *merged-gradient layer* ``l`` postpones its
communication and merges into ``l-1`` (the tensor produced *after* it during
backward).  We index tensors in **backward production order**: index 0 is the
first gradient produced (the paper's layer L), index ``L-1`` the last (the
paper's layer 1).  A plan is then a partition of ``0..L-1`` into contiguous
*buckets*; every tensor of a bucket except the last is a merged-gradient
layer, and the bucket's all-reduce may start when

  (1) the last tensor's gradient has been produced, and
  (2) the previous bucket's all-reduce has finished           (paper Eq. 7)

Planners
--------
* ``plan_wfbp``        — one bucket per tensor (WFBP baseline, Fig. 1b).
* ``plan_single``      — one bucket for everything (SyncEASGD, Fig. 1c).
* ``plan_fixed_size``  — PyTorch-DDP style byte-capped buckets (beyond-paper
                         baseline).
* ``plan_mgwfbp``      — the paper's Algorithm 1, faithful O(L^2).
* ``plan_dp_optimal``  — beyond-paper O(L^2) dynamic program that provably
                         minimizes the final communication finish time.
* ``plan_brute_force`` — exhaustive 2^(L-1) search (testing only).

All planners consume a list of :class:`TensorSpec` (backward order) and a
cost model exposing ``a``, ``b`` and ``time(nbytes)`` (see
``cost_model.AllReduceModel``).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

from repro.core.cost_model import AllReduceModel


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One gradient tensor as seen by the communication scheduler."""

    name: str
    nbytes: int        # bytes to all-reduce for this tensor
    t_b: float         # backward compute time that produces this gradient (s)

    def __post_init__(self):
        if self.nbytes < 0 or self.t_b < 0:
            raise ValueError(f"negative spec: {self}")


@dataclasses.dataclass(frozen=True)
class MergePlan:
    """A partition of backward-ordered tensors into contiguous buckets."""

    buckets: tuple[tuple[int, ...], ...]
    strategy: str = "custom"

    def __post_init__(self):
        flat = [i for b in self.buckets for i in b]
        if flat != list(range(len(flat))):
            raise ValueError(
                f"buckets must be a contiguous partition of 0..L-1, got {self.buckets}")
        if any(len(b) == 0 for b in self.buckets):
            raise ValueError("empty bucket")

    @property
    def num_tensors(self) -> int:
        return sum(len(b) for b in self.buckets)

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    def bucket_of(self) -> list[int]:
        """tensor index -> bucket index."""
        out = [0] * self.num_tensors
        for k, b in enumerate(self.buckets):
            for i in b:
                out[i] = k
        return out

    def merged_flags(self) -> list[bool]:
        """Per-tensor flag: True iff the tensor is a merged-gradient layer
        (i.e. NOT the last element of its bucket).  Matches the paper's
        ``m[l] == l_m`` with the index order reversed."""
        flags = []
        for b in self.buckets:
            flags.extend([True] * (len(b) - 1) + [False])
        return flags

    def bucket_bytes(self, specs: Sequence[TensorSpec]) -> list[int]:
        return [sum(specs[i].nbytes for i in b) for b in self.buckets]

    @staticmethod
    def from_boundaries(num_tensors: int, last_indices: Sequence[int],
                        strategy: str = "custom") -> "MergePlan":
        """Build from the sorted list of bucket-final tensor indices."""
        last = sorted(set(last_indices))
        if not last or last[-1] != num_tensors - 1:
            raise ValueError("final tensor must close a bucket")
        buckets, start = [], 0
        for e in last:
            buckets.append(tuple(range(start, e + 1)))
            start = e + 1
        return MergePlan(tuple(buckets), strategy)

    @staticmethod
    def from_merged_flags(flags: Sequence[bool], strategy: str = "custom") -> "MergePlan":
        last = [i for i, f in enumerate(flags) if not f]
        if flags and flags[-1]:
            # last tensor can never be merged "forward"; force it to close.
            last.append(len(flags) - 1)
        return MergePlan.from_boundaries(len(flags), last, strategy)


# ---------------------------------------------------------------------------
# Baselines.
# ---------------------------------------------------------------------------

def plan_wfbp(specs: Sequence[TensorSpec]) -> MergePlan:
    """Per-tensor communication (WFBP)."""
    return MergePlan(tuple((i,) for i in range(len(specs))), "wfbp")


def plan_single(specs: Sequence[TensorSpec]) -> MergePlan:
    """Single merged communication (SyncEASGD)."""
    return MergePlan((tuple(range(len(specs))),), "single")


def plan_fixed_size(specs: Sequence[TensorSpec], cap_bytes: int) -> MergePlan:
    """PyTorch-DDP-style bucketing: close a bucket once it reaches cap."""
    if cap_bytes <= 0:
        raise ValueError("cap_bytes must be positive")
    last, acc = [], 0
    for i, s in enumerate(specs):
        acc += s.nbytes
        if acc >= cap_bytes:
            last.append(i)
            acc = 0
    if not last or last[-1] != len(specs) - 1:
        last.append(len(specs) - 1)
    return MergePlan.from_boundaries(len(specs), last, f"fixed:{cap_bytes}")


# ---------------------------------------------------------------------------
# Paper Algorithm 1 (faithful).
# ---------------------------------------------------------------------------

def _comm_starts(t_c: list[float], t_b_end: list[float]) -> list[float]:
    """Paper's CALCULATECOMMSTART in backward-order indexing (Eq. 7).

    ``t_b_end[i]`` is the timestamp when tensor i's gradient is ready;
    communication i starts at max(previous comm end, ready time).
    """
    L = len(t_c)
    tau_c = [0.0] * L
    tau_c[0] = t_b_end[0]
    for i in range(1, L):
        tau_c[i] = max(tau_c[i - 1] + t_c[i - 1], t_b_end[i])
    return tau_c


def plan_mgwfbp(specs: Sequence[TensorSpec], model: AllReduceModel) -> MergePlan:
    """The paper's Algorithm 1: optimal merged-gradient assignment.

    Faithful O(L^2) implementation.  Iterates tensors in backward order
    (paper: ``for l = L -> 2``); tensor i becomes a merged-gradient layer iff

        t_b_end[i+1] - tau_c[i] < a                         (paper Eq. 38)

    where ``t_b_end[i+1]`` is when the *next* tensor's gradient is ready and
    ``tau_c[i]`` is when tensor i's communication could start.  After each
    merge the communication start times are recomputed (paper line 13).
    """
    L = len(specs)
    if L == 0:
        return MergePlan((), "mgwfbp")
    a = model.a
    p = [float(s.nbytes) for s in specs]
    t_c = [model.time(x) for x in p]
    # Gradient-ready timestamps (backward start == 0):
    t_b_end, acc = [], 0.0
    for s in specs:
        acc += s.t_b
        t_b_end.append(acc)

    merged = [False] * L
    tau_c = _comm_starts(t_c, t_b_end)
    for i in range(L - 1):              # paper: l = L..2 (tensor i merges into i+1)
        if t_b_end[i + 1] - tau_c[i] < a:
            merged[i] = True
            # paper MERGE(): zero out this comm, grow the next one.
            p[i + 1] += p[i]
            p[i] = 0.0
            t_c[i] = 0.0
            t_c[i + 1] = model.time(p[i + 1])
            tau_c = _comm_starts(t_c, t_b_end)
    return MergePlan.from_merged_flags(merged, "mgwfbp")


# ---------------------------------------------------------------------------
# Beyond-paper: provably optimal DP and exhaustive search.
# ---------------------------------------------------------------------------

def plan_dp_optimal(specs: Sequence[TensorSpec], model: AllReduceModel) -> MergePlan:
    """O(L^2) dynamic program minimizing the final all-reduce finish time.

    Let ``f[i]`` be the minimum finish time of all communications covering
    tensors ``0..i`` given tensor i closes a bucket.  Buckets are contiguous,
    and a bucket (j+1..i) may start at max(f[j], ready[i]):

        f[i] = min_{j<i} max(f[j], ready[i]) + T(bytes[j+1..i])

    Because every plan's iteration time is ``t_f + max(f[L-1], ready[L-1])``
    and ``f[L-1] >= ready[L-1]`` always, minimizing f[L-1] minimizes the
    iteration time — this gives a certified-optimal reference for Algorithm 1
    (see tests/test_planner.py) and is the planner we ship as default.
    """
    L = len(specs)
    if L == 0:
        return MergePlan((), "dp_optimal")
    ready, acc = [], 0.0
    for s in specs:
        acc += s.t_b
        ready.append(acc)
    pre = [0] * (L + 1)   # prefix bytes
    for i, s in enumerate(specs):
        pre[i + 1] = pre[i] + s.nbytes

    NEG = -1
    f = [float("inf")] * L
    parent = [NEG] * L
    for i in range(L):
        # bucket = (0..i)
        f[i] = ready[i] + model.time(pre[i + 1])
        parent[i] = NEG
        for j in range(i):
            cand = max(f[j], ready[i]) + model.time(pre[i + 1] - pre[j + 1])
            if cand < f[i] - 1e-15:
                f[i] = cand
                parent[i] = j
    last, i = [], L - 1
    while i != NEG:
        last.append(i)
        i = parent[i]
    return MergePlan.from_boundaries(L, sorted(last), "dp_optimal")


def plan_brute_force(specs: Sequence[TensorSpec], model: AllReduceModel) -> MergePlan:
    """Exhaustive search over all 2^(L-1) contiguous partitions (tests only)."""
    from repro.core.simulator import simulate  # local import to avoid cycle

    L = len(specs)
    if L == 0:
        return MergePlan((), "brute_force")
    if L > 18:
        raise ValueError(f"brute force infeasible for L={L}")
    best, best_t = None, float("inf")
    for mask in itertools.product([False, True], repeat=L - 1):
        last = [i for i in range(L - 1) if not mask[i]] + [L - 1]
        plan = MergePlan.from_boundaries(L, last, "brute_force")
        t = simulate(specs, plan, model).t_iter
        if t < best_t - 1e-15:
            best, best_t = plan, t
    return best


# ---------------------------------------------------------------------------
# Dispatch + elastic re-planning.
# ---------------------------------------------------------------------------

def make_plan(strategy: str, specs: Sequence[TensorSpec],
              model: AllReduceModel | None = None) -> MergePlan:
    """Build a plan from a strategy string.

    ``wfbp`` | ``single`` | ``mgwfbp`` | ``dp_optimal`` | ``fixed:<bytes>``.
    """
    if strategy == "wfbp":
        return plan_wfbp(specs)
    if strategy == "single":
        return plan_single(specs)
    if strategy.startswith("fixed:"):
        return plan_fixed_size(specs, int(strategy.split(":", 1)[1]))
    if model is None:
        raise ValueError(f"strategy {strategy!r} needs a cost model")
    if strategy == "mgwfbp":
        return plan_mgwfbp(specs, model)
    if strategy == "dp_optimal":
        return plan_dp_optimal(specs, model)
    raise ValueError(f"unknown merge strategy {strategy!r}")


def replan(strategy: str, specs: Sequence[TensorSpec],
           model: AllReduceModel) -> MergePlan:
    """Elastic-scaling hook: membership changed -> (a, b) changed -> replan.

    The paper computes the plan once before training (O(L^2), negligible);
    on an elastic resize we simply recompute it for the new cost model and
    keep training from the latest checkpoint.
    """
    return make_plan(strategy, specs, model)
