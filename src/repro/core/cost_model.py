"""All-reduce cost models (paper Table 2) and TPU interconnect models.

The paper models a single all-reduce of M bytes as

    T_ar(M) = a + b * M                                           (Eq. 10)

where ``a`` (startup / latency term) and ``b`` (per-byte term) derive from
the collective algorithm and the point-to-point link parameters:

    alpha : point-to-point latency (s)
    beta  : point-to-point transfer time per byte (s/B)
    gamma : reduction (summation) time per byte on one node (s/B)

Table 2 of the paper gives (a, b) for five classic algorithms.  We implement
all five, a least-squares fitter that recovers (a, b) from measured
(size, time) samples (paper Fig. 4), and a two-level hierarchical model for
TPU pods where the intra-pod ICI and the inter-pod DCN links have very
different (alpha, beta).

The key property exploited by MG-WFBP (paper Eq. 11) is super-additivity of
the startup term:

    T_ar(M1) + T_ar(M2) = 2a + b(M1+M2) > a + b(M1+M2) = T_ar(M1+M2)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Hardware constants for the TPU v5e target (per the roofline brief).
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12      # per chip, FLOP/s
HBM_BW = 819e9                # per chip, B/s
ICI_BW_PER_LINK = 50e9        # B/s per ICI link
ICI_ALPHA = 1e-6              # ~1 us per-hop startup on ICI
DCN_BW = 25e9                 # B/s effective per host across pods
DCN_ALPHA = 2.5e-4            # ~250 us startup for a cross-pod collective

# Paper-measured cluster constants (Fig. 4), used by the reproduction
# benchmarks.  (a in seconds, b in seconds/byte.)
PAPER_CLUSTERS = {
    # 8-node K80, 10GbE
    "cluster1_k80_10gbe": (9.72e-4, 1.97e-9),
    # 4-node V100, 10GbE
    "cluster2_v100_10gbe": (9.08e-4, 7.40e-10),
    # 4-node V100, 56Gb InfiniBand
    "cluster3_v100_ib": (2.36e-4, 4.06e-10),
}


@dataclasses.dataclass(frozen=True)
class AllReduceModel:
    """Linear all-reduce cost model ``T(M) = a + b * M`` (Eq. 10)."""

    a: float            # startup time, seconds
    b: float            # per-byte time, seconds/byte
    name: str = "linear"

    def __post_init__(self):
        if self.a < 0 or self.b < 0:
            raise ValueError(f"negative cost model parameters: a={self.a} b={self.b}")

    def time(self, nbytes: float) -> float:
        """Cost of all-reducing a message of ``nbytes`` bytes."""
        if nbytes <= 0:
            return 0.0
        return self.a + self.b * float(nbytes)

    def merge_gain(self, nbytes_1: float, nbytes_2: float) -> float:
        """Time saved by merging two messages into one (== a; Eq. 11/21)."""
        if nbytes_1 <= 0 or nbytes_2 <= 0:
            return 0.0
        return self.time(nbytes_1) + self.time(nbytes_2) - self.time(
            nbytes_1 + nbytes_2)

    def scaled(self, factor: float) -> "AllReduceModel":
        return AllReduceModel(self.a * factor, self.b * factor, self.name)


def blend(old: AllReduceModel, new: AllReduceModel,
          weight: float) -> AllReduceModel:
    """Damped model update: ``weight`` on the new estimate, rest on the old.

    The contention fixpoint (``planner.plan_contention_aware``) uses this to
    suppress plan/fit oscillation: a full-step update (weight=1) can flip
    between two plans whose observations each justify the other's model.
    """
    if not 0.0 <= weight <= 1.0:
        raise ValueError(f"blend weight must be in [0, 1], got {weight}")
    return AllReduceModel(old.a * (1 - weight) + new.a * weight,
                          old.b * (1 - weight) + new.b * weight,
                          new.name)


# ---------------------------------------------------------------------------
# Table 2: (a, b) per collective algorithm.
# ---------------------------------------------------------------------------

def _log2(n: int) -> float:
    if n < 1:
        raise ValueError(f"need >= 1 workers, got {n}")
    return math.log2(n)


def binary_tree(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Binary tree all-reduce [Rabenseifner'04]."""
    lg = _log2(n)
    return AllReduceModel(2 * alpha * lg, (2 * beta + gamma) * lg, "binary_tree")


def recursive_doubling(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    lg = _log2(n)
    return AllReduceModel(alpha * lg, (beta + gamma) * lg, "recursive_doubling")


def recursive_halving_doubling(n: int, alpha: float, beta: float,
                               gamma: float) -> AllReduceModel:
    lg = _log2(n)
    b = 2 * beta - (2 * beta + gamma) / n + gamma
    return AllReduceModel(2 * alpha * lg, b, "recursive_halving_doubling")


def double_binary_trees(n: int, alpha: float, beta: float,
                        gamma: float) -> AllReduceModel:
    """Double binary trees [Sanders'09] — NCCL >= 2.4 default at scale."""
    lg = _log2(n)
    return AllReduceModel(2 * alpha * lg, beta + gamma, "double_binary_trees")


def ring(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Ring all-reduce — bandwidth optimal, latency linear in N."""
    if n == 1:
        return AllReduceModel(0.0, 0.0, "ring")
    b = 2 * (n - 1) / n * beta + (n - 1) / n * gamma
    return AllReduceModel(2 * (n - 1) * alpha, b, "ring")


ALGORITHMS = {
    "binary_tree": binary_tree,
    "recursive_doubling": recursive_doubling,
    "recursive_halving_doubling": recursive_halving_doubling,
    "double_binary_trees": double_binary_trees,
    "ring": ring,
}


def make_model(algorithm: str, n: int, alpha: float, beta: float,
               gamma: float = 0.0) -> AllReduceModel:
    try:
        fn = ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(
            f"unknown all-reduce algorithm {algorithm!r}; "
            f"choose from {sorted(ALGORITHMS)}") from None
    return fn(n, alpha, beta, gamma)


# ---------------------------------------------------------------------------
# Model fitting (paper Fig. 4: measure all-reduce time vs message size, fit
# the linear model by least squares).
# ---------------------------------------------------------------------------

def fit(sizes_bytes: Sequence[float], times_s: Sequence[float],
        name: str = "fitted") -> AllReduceModel:
    """Least-squares fit of T(M) = a + b*M from measurements.

    Negative intercepts (possible with noisy small-size samples) are clamped
    to zero since a < 0 is non-physical and breaks the merge logic.
    """
    sizes = np.asarray(sizes_bytes, dtype=np.float64)
    times = np.asarray(times_s, dtype=np.float64)
    if sizes.shape != times.shape or sizes.ndim != 1 or sizes.size < 2:
        raise ValueError("need >= 2 paired (size, time) samples")
    A = np.stack([np.ones_like(sizes), sizes], axis=1)
    (a, b), *_ = np.linalg.lstsq(A, times, rcond=None)
    return AllReduceModel(max(float(a), 0.0), max(float(b), 0.0), name)


# ---------------------------------------------------------------------------
# TPU-specific models.
# ---------------------------------------------------------------------------

def tpu_ici_ring(axis_size: int, *, bw_per_link: float = ICI_BW_PER_LINK,
                 alpha: float = ICI_ALPHA, bidirectional: bool = True,
                 gamma: float = 0.0) -> AllReduceModel:
    """Ring all-reduce over one ICI mesh axis.

    A TPU torus axis provides one link per direction; the bidirectional ring
    all-reduce streams both directions, doubling effective bandwidth.
    """
    eff_bw = bw_per_link * (2.0 if bidirectional else 1.0)
    m = ring(axis_size, alpha, 1.0 / eff_bw, gamma)
    return AllReduceModel(m.a, m.b, "tpu_ici_ring")


def tpu_dcn(pods: int, *, bw: float = DCN_BW, alpha: float = DCN_ALPHA,
            gamma: float = 0.0) -> AllReduceModel:
    """Cross-pod (DCN) all-reduce: high-latency, lower-bandwidth level."""
    m = ring(pods, alpha, 1.0 / bw, gamma)
    return AllReduceModel(m.a, m.b, "tpu_dcn")


@dataclasses.dataclass(frozen=True)
class HierarchicalModel:
    """Two-level all-reduce: reduce-scatter intra-pod, all-reduce across
    pods on the 1/intra_size shard, all-gather intra-pod.

    Still linear in M, so it exposes the same (a, b) interface — this is what
    lets the *unmodified* MG-WFBP planner consume multi-pod topologies, which
    is our beyond-paper extension (the paper assumes a flat single-level
    model).
    """

    intra: AllReduceModel       # ICI level (cost of full all-reduce intra)
    inter: AllReduceModel       # DCN level
    intra_size: int             # chips per pod participating in level 1

    @property
    def a(self) -> float:
        # RS + AG each cost ~half of a full all-reduce's bandwidth term but
        # pay the full startup; inter level pays its own startup.
        return self.intra.a + self.inter.a

    @property
    def b(self) -> float:
        return self.intra.b + self.inter.b / max(self.intra_size, 1)

    @property
    def name(self) -> str:  # pragma: no cover - trivial
        return "hierarchical"

    def time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.a + self.b * float(nbytes)

    def flat(self) -> AllReduceModel:
        """Collapse to a flat linear model for the planner."""
        return AllReduceModel(self.a, self.b, "hierarchical")


def production_comm_model(mesh_shape: Sequence[int],
                          mesh_axis_names: Sequence[str],
                          dp_axes: Sequence[str] = ("pod", "data"),
                          algorithm: str = "ring") -> AllReduceModel:
    """Build the gradient all-reduce cost model for a production mesh.

    Single-pod meshes use the ICI model over the data axis; multi-pod meshes
    compose ICI (data axis) with DCN (pod axis) hierarchically.
    """
    dims = dict(zip(mesh_axis_names, mesh_shape))
    data = dims.get("data", 1)
    pods = dims.get("pod", 1)
    if "data" not in dp_axes:
        data = 1
    if "pod" not in dp_axes:
        pods = 1
    intra = tpu_ici_ring(data) if data > 1 else AllReduceModel(0.0, 0.0, "noop")
    if pods <= 1:
        return AllReduceModel(intra.a, intra.b, "tpu_ici_ring")
    inter = tpu_dcn(pods)
    return HierarchicalModel(intra=intra, inter=inter, intra_size=data).flat()
